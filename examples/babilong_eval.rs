//! Tables 3 & 4 analog: evaluate the trained toy ARMT on the synthetic
//! BABILong tasks with and without Diagonal Batching.
//!
//! Paper claims reproduced at toy scale:
//!   * Table 3 — both execution modes score the SAME (diagonal batching
//!     is a drop-in replacement; drift does not change answers);
//!   * Table 4 — wallclock comparison per length. (On the single-core
//!     CPU backend the diagonal mode does more arithmetic per launch, so
//!     the GPU speedups do not transfer; the launch-count ratio — the
//!     quantity a GPU amortizes — is reported alongside. See
//!     EXPERIMENTS.md "CPU-testbed caveat".)
//!
//! Run: `make toy && cargo run --release --example babilong_eval`

use std::time::Instant;

use diagonal_batching::babilong::{accuracy, Generator, Task};
use diagonal_batching::bench::Table;
use diagonal_batching::config::{ExecMode, Manifest};
use diagonal_batching::coordinator::{GenerateRequest, InferenceEngine};
use diagonal_batching::runtime::HloBackend;
use diagonal_batching::scheduler::StepBackend;

fn eval<B: StepBackend>(
    engine: &mut InferenceEngine<B>,
    episodes: &[diagonal_batching::babilong::Episode],
    mode: ExecMode,
) -> (f64, std::time::Duration, u64) {
    let seg = engine.config().seg;
    let mut preds = Vec::new();
    let mut launches = 0;
    let t0 = Instant::now();
    for (i, e) in episodes.iter().enumerate() {
        let mut req = GenerateRequest::new(i as u64, e.tokens.clone());
        req.want_logits = true;
        req.mode = Some(mode);
        let resp = engine.process(&req).unwrap();
        launches += resp.stats.launches;
        let pos = e.query_pos % seg;
        preds.push(resp.logits.unwrap().last().unwrap().argmax_rows()[pos] as u32);
    }
    (accuracy(episodes, &preds), t0.elapsed(), launches)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let manifest = Manifest::load("artifacts/manifest.json")?;
    let entry = manifest.model("toy")?.clone();
    if !entry.trained {
        println!("WARNING: toy weights are untrained (run `make toy`); accuracies are chance-level\n");
    }
    let backend = HloBackend::load(&manifest, "toy")?;
    let mut engine = InferenceEngine::new(backend, ExecMode::Diagonal);
    let seg = entry.config.seg;
    let episodes_per_point = 24;

    let mut acc_table = Table::new(
        "Table 3 analog: BABILong accuracy (%), sequential ARMT vs Diagonal Batching",
        &["task", "length (tokens)", "ARMT", "ARMT + Diagonal Batching"],
    );
    let mut time_table = Table::new(
        "Table 4 analog: wallclock (s) + launch counts per mode",
        &["task", "length", "seq time", "diag time", "seq launches", "diag launches"],
    );

    for task in [Task::QA1, Task::QA2] {
        for n_segments in [1usize, 2, 4, 8] {
            let len = n_segments * seg;
            let mut gen = Generator::new(manifest.babilong.clone(), 7 + n_segments as u64);
            let eps = gen.batch(task, len, episodes_per_point);
            let (acc_s, t_s, l_s) = eval(&mut engine, &eps, ExecMode::Sequential);
            let (acc_d, t_d, l_d) = eval(&mut engine, &eps, ExecMode::Diagonal);
            acc_table.row(vec![
                task.to_string(),
                len.to_string(),
                format!("{:.1}", acc_s * 100.0),
                format!("{:.1}", acc_d * 100.0),
            ]);
            time_table.row(vec![
                task.to_string(),
                len.to_string(),
                format!("{:.2}", t_s.as_secs_f64()),
                format!("{:.2}", t_d.as_secs_f64()),
                l_s.to_string(),
                l_d.to_string(),
            ]);
        }
    }
    acc_table.print();
    time_table.print();
    println!(
        "\nchance accuracy: {:.1}%  |  episodes per point: {episodes_per_point}",
        100.0 / manifest.babilong.n_places as f64
    );
    println!("note: equal accuracy columns == the paper's Table 3 claim (drop-in).");
    Ok(())
}
