//! Streaming generation quickstart — artifact-free.
//!
//! Builds the built-in synthetic model (untrained, random weights — the
//! point is the *lifecycle*, not the prose), then:
//!
//!   1. streams a single generation through `InferenceEngine::generate`
//!      (SegmentDone / Token events as they happen);
//!   2. verifies the streamed continuation is bit-identical to the
//!      sequential single-shot oracle run over prompt + generated;
//!   3. runs a 6-client generation burst through `serve_queue` and
//!      shows the packed wavefront beating the best solo mean group;
//!   4. cancels a request mid-decode via its `RequestHandle`.
//!
//! Run: `cargo run --release --example generate_stream`

use diagonal_batching::config::{ExecMode, ModelConfig};
use diagonal_batching::coordinator::{
    Event, GenerateRequest, InferenceEngine, RequestQueue,
};
use diagonal_batching::model::{NativeBackend, Params};

fn engine(seed: u64) -> InferenceEngine<NativeBackend> {
    let cfg = ModelConfig::synthetic();
    InferenceEngine::new(
        NativeBackend::new(cfg.clone(), Params::random(&cfg, seed)),
        ExecMode::Diagonal,
    )
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = ModelConfig::synthetic();
    let prompt: Vec<u32> = (0..2 * cfg.seg as u32).map(|i| (i * 31 + 7) % cfg.vocab as u32).collect();

    // 1. One streaming generation.
    println!("== streaming one generation (prompt {} tokens + 20 new) ==", prompt.len());
    let mut e = engine(11);
    let req = GenerateRequest::new(1, prompt.clone()).generate(20);
    let mut generated = Vec::new();
    e.generate(&req, |ev| match ev {
        Event::SegmentDone { index, .. } => println!("  segment {index} exited"),
        Event::Token { pos, token } => {
            generated.push(token);
            if pos < 4 {
                println!("  token[{pos}] = {token}");
            }
        }
        Event::Done { stats } => println!(
            "  done: {} segments, {} launches, mean group {:.2}",
            stats.stats.segments,
            stats.stats.launches,
            stats.stats.mean_group()
        ),
        Event::Error { error } => eprintln!("  error: {error}"),
        _ => {}
    })?;

    // 2. Exactness: the same continuation must fall out of the
    // sequential oracle run over prompt + generated tokens.
    let mut oracle = engine(11);
    let solo = oracle.process(
        &GenerateRequest::new(2, prompt.clone()).generate(20).with_mode(ExecMode::Sequential),
    )?;
    assert_eq!(solo.generated, generated, "decode must be exact recurrence");
    println!("OK: streamed decode == sequential oracle, token for token\n");

    // 3. A packed generation burst.
    println!("== 6-client generation burst through serve_queue ==");
    let queue: RequestQueue<(GenerateRequest, u64)> = RequestQueue::new(8);
    for i in 0..6u64 {
        let p: Vec<u32> =
            (0..2 * cfg.seg as u32).map(|t| (t * 13 + i as u32) % cfg.vocab as u32).collect();
        queue.push((GenerateRequest::new(i, p).generate(24), i))?;
    }
    queue.close();
    let mut serving = engine(11).with_lanes(6);
    let mut completions = 0;
    serving.serve_queue(&queue, |_ticket, ev| {
        if let Event::Done { .. } = ev {
            completions += 1;
        }
    })?;
    println!(
        "  {} generations, burst mean group {:.2} (solo ceiling is L = {})\n",
        completions,
        serving.stats.mean_group(),
        cfg.n_layers
    );

    // 4. Mid-decode cancellation.
    println!("== cancel mid-decode via RequestHandle ==");
    let mut e = engine(11);
    let req = GenerateRequest::new(3, prompt).generate(100_000);
    let handle = req.handle();
    let result = e.generate(&req, |ev| {
        if let Event::Token { pos, .. } = ev {
            if pos >= 16 {
                handle.cancel();
            }
        }
    });
    assert!(result.is_err(), "cancelled stream must not complete");
    println!("  cancelled after 16 tokens: {}", result.unwrap_err());
    Ok(())
}
