//! Quickstart: load the AOT artifacts, run one long input through BOTH
//! schedules on the real PJRT backend, and verify the paper's two core
//! claims at demo scale:
//!
//!   1. launches drop from S*L to S+L-1 (Fig. 3);
//!   2. outputs match the sequential baseline (Table 2: < 2% drift).
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use diagonal_batching::config::{ExecMode, Manifest};
use diagonal_batching::coordinator::{GenerateRequest, InferenceEngine};
use diagonal_batching::runtime::HloBackend;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let manifest = Manifest::load("artifacts/manifest.json")?;
    let model = "tiny";
    println!("loading '{model}' artifacts (PJRT CPU)...");
    let backend = HloBackend::load(&manifest, model)?;
    let mut engine = InferenceEngine::new(backend, ExecMode::Diagonal);

    let cfg = engine.config().clone();
    let n_segments = 12;
    let tokens: Vec<u32> = (0..n_segments * cfg.seg)
        .map(|i| ((i as u32) * 31 + 7) % cfg.vocab as u32)
        .collect();
    println!(
        "input: {} tokens = {} segments x {} (model: d={} L={} mem={})\n",
        tokens.len(),
        n_segments,
        cfg.seg,
        cfg.d_model,
        cfg.n_layers,
        cfg.mem
    );

    let mut diag_req = GenerateRequest::new(1, tokens.clone());
    diag_req.want_logits = true;
    diag_req.mode = Some(ExecMode::Diagonal);
    let mut seq_req = diag_req.clone();
    seq_req.id = 2;
    seq_req.mode = Some(ExecMode::Sequential);

    let diag = engine.process(&diag_req)?;
    let seq = engine.process(&seq_req)?;

    println!("schedule     launches   mean group   wall");
    println!(
        "diagonal     {:>8}   {:>10.2}   {:?}",
        diag.stats.launches,
        diag.stats.mean_group(),
        diag.stats.wall
    );
    println!(
        "sequential   {:>8}   {:>10.2}   {:?}",
        seq.stats.launches,
        seq.stats.mean_group(),
        seq.stats.wall
    );
    assert_eq!(diag.stats.launches as usize, n_segments + cfg.n_layers - 1);
    assert_eq!(seq.stats.launches as usize, n_segments * cfg.n_layers);

    // Table 2 drift check.
    let dl = diag.logits.as_ref().unwrap();
    let sl = seq.logits.as_ref().unwrap();
    let mut worst = 0.0f32;
    for (a, b) in dl.iter().zip(sl) {
        worst = worst.max(a.rel_error(b));
    }
    println!("\nmax relative logits drift diagonal vs sequential: {:.5}%", worst * 100.0);
    assert!(worst < 0.02, "drift exceeds the paper's 2% bound");

    // greedy decode agreement
    let agree = dl
        .iter()
        .zip(sl)
        .map(|(a, b)| {
            let (aa, bb) = (a.argmax_rows(), b.argmax_rows());
            aa.iter().zip(&bb).filter(|(x, y)| x == y).count()
        })
        .sum::<usize>() as f64
        / (n_segments * cfg.seg) as f64;
    println!("greedy-token agreement: {:.2}%", agree * 100.0);

    println!("\nOK: diagonal batching preserved outputs with {}x fewer launches", {
        let s = n_segments as f64 * cfg.n_layers as f64;
        let d = (n_segments + cfg.n_layers - 1) as f64;
        format!("{:.1}", s / d)
    });
    Ok(())
}
