//! End-to-end serving driver (the repo's E2E validation run, recorded in
//! EXPERIMENTS.md): load the TRAINED toy ARMT artifacts on the PJRT
//! backend, serve batched BABILong-style long-context requests over the
//! TCP server, and report latency / throughput / answer accuracy —
//! exactly what a downstream deployment of the paper's system would do.
//!
//! Run: `make artifacts && make toy && cargo run --release --example serve_longctx`

use std::time::Instant;

use diagonal_batching::babilong::{accuracy, Generator, Task};
use diagonal_batching::config::{ExecMode, Manifest};
use diagonal_batching::coordinator::InferenceEngine;
use diagonal_batching::json::Value;
use diagonal_batching::runtime::HloBackend;
use diagonal_batching::server::{Client, Server};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let manifest = Manifest::load("artifacts/manifest.json")?;
    let entry = manifest.model("toy")?.clone();
    println!(
        "loading 'toy' (trained={}) on PJRT CPU; serving diagonal-batched ARMT",
        entry.trained
    );
    let backend = HloBackend::load(&manifest, "toy")?;
    let engine = InferenceEngine::new(backend, ExecMode::Diagonal);
    let server = Server::start(engine, "127.0.0.1:0", 32)?;
    let addr = server.addr.to_string();
    println!("server up on {addr}\n");

    let seg = entry.config.seg;
    let n_clients = 4usize;
    let per_client = 8usize;
    let episode_len = 8 * seg; // 8 segments per request

    let mut gen = Generator::new(manifest.babilong.clone(), 2024);
    // Pre-generate every client's episodes (QA1) so accuracy is scorable.
    let episodes: Vec<Vec<diagonal_batching::babilong::Episode>> = (0..n_clients)
        .map(|_| gen.batch(Task::QA1, episode_len, per_client))
        .collect();

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (ci, eps) in episodes.iter().enumerate() {
        let addr = addr.clone();
        let eps = eps.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            let mut lat_ms = Vec::new();
            let mut preds = Vec::new();
            for e in &eps {
                let resp = loop {
                    match client.infer(&e.tokens, None) {
                        Ok(r) => break r,
                        Err(err) if err.to_string().contains("queue full") => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(err) => panic!("client {ci}: {err}"),
                    }
                };
                lat_ms.push(resp.req("latency_ms").unwrap().as_f64().unwrap());
                // greedy_tail holds the final segment's argmax tokens; the
                // answer sits at the query position within that segment.
                let tail = resp.req("greedy_tail").unwrap().as_u32_vec().unwrap();
                preds.push(tail[(e.query_pos) % tail.len().max(1)]);
            }
            (lat_ms, preds)
        }));
    }

    let mut all_lat = Vec::new();
    let mut all_preds = Vec::new();
    for h in handles {
        let (lat, preds) = h.join().unwrap();
        all_lat.extend(lat);
        all_preds.push(preds);
    }
    let wall = t0.elapsed();

    let total_reqs = n_clients * per_client;
    let total_tokens = total_reqs * episode_len;
    all_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| all_lat[((all_lat.len() as f64 * q) as usize).min(all_lat.len() - 1)];

    println!("requests          : {total_reqs} ({n_clients} concurrent clients)");
    println!("tokens/request    : {episode_len} ({} segments)", episode_len / seg);
    println!("total wall        : {wall:?}");
    println!(
        "throughput        : {:.1} req/s | {:.0} tokens/s",
        total_reqs as f64 / wall.as_secs_f64(),
        total_tokens as f64 / wall.as_secs_f64()
    );
    println!(
        "latency ms        : p50 {:.1} | p90 {:.1} | p99 {:.1} | max {:.1}",
        pct(0.50),
        pct(0.90),
        pct(0.99),
        all_lat[all_lat.len() - 1]
    );

    let mut hits = 0usize;
    for (eps, preds) in episodes.iter().zip(&all_preds) {
        hits += (accuracy(eps, preds) * eps.len() as f64).round() as usize;
    }
    println!(
        "QA1 answer accuracy: {:.1}% over {} episodes (chance {:.1}%){}",
        100.0 * hits as f64 / total_reqs as f64,
        total_reqs,
        100.0 / manifest.babilong.n_places as f64,
        if entry.trained { "" } else { "  [untrained weights — run `make toy`]" }
    );

    // stats endpoint sanity
    let mut c = Client::connect(&addr)?;
    let ping = c.roundtrip(&Value::obj(vec![("cmd", Value::Str("ping".into()))]))?;
    println!("server alive after load: {}", ping.get("ok").is_some());

    server.stop();
    println!("server stopped cleanly");
    Ok(())
}
