//! Multi-turn chat over one saved conversation — artifact-free.
//!
//! Demonstrates the memory-state snapshot store's suspend/resume path:
//!
//!   1. turn 1 generates a reply and SAVES the conversation (the final
//!      per-layer associative memory, a few kilobytes — not a KV cache);
//!   2. turn 2 resumes by token, sending ONLY the new user tokens: the
//!      engine seeds the wavefront lane from the snapshot, so **zero
//!      prefill segments are executed for turn-1 history** (asserted);
//!   3. the resumed continuation is verified bit-identical to a
//!      straight-through run over the full concatenated history.
//!
//! Run: `cargo run --release --example chat_resume`

use diagonal_batching::config::{ExecMode, ModelConfig};
use diagonal_batching::coordinator::{GenerateRequest, InferenceEngine};
use diagonal_batching::model::{NativeBackend, Params};

fn engine(seed: u64, mode: ExecMode) -> InferenceEngine<NativeBackend> {
    let cfg = ModelConfig::synthetic();
    InferenceEngine::new(
        NativeBackend::new(cfg.clone(), Params::random(&cfg, seed)),
        mode,
    )
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = ModelConfig::synthetic();
    let seg = cfg.seg;
    let vocab = cfg.vocab as u32;
    // Turn 1: a 3-segment "user message" + a generated reply. The
    // decode budget feeds one full segment back into the recurrence.
    let turn1: Vec<u32> = (0..(3 * seg) as u32).map(|i| (i * 31 + 7) % vocab).collect();
    let turn2: Vec<u32> = (0..seg as u32).map(|i| (i * 17 + 5) % vocab).collect();

    let mut e = engine(42, ExecMode::Diagonal);
    println!("== turn 1: {} prompt tokens, generate {} ==", turn1.len(), 2 * seg);
    let resp1 = e.process(&GenerateRequest::new(1, turn1.clone()).generate(2 * seg).with_save())?;
    let token = resp1.resume_token.expect("conversation saved");
    let history_segments = resp1.final_state.as_ref().expect("snapshot captured").segments;
    println!(
        "  reply: {} tokens; saved conversation {token} covers {history_segments} segments \
         ({} bytes of memory state)",
        resp1.generated.len(),
        resp1.final_state.as_ref().unwrap().byte_size(),
    );

    // Turn 2: resume by token — the request carries ONLY the new
    // tokens. The engine seeds the lane from the snapshot and computes
    // nothing for turn-1 history.
    println!("== turn 2: resume {token} with {} NEW tokens ==", turn2.len());
    let resp2 = e.process(&GenerateRequest::new(2, turn2.clone()).generate(seg).resume_token(token))?;
    println!(
        "  reply: {} tokens; {} history segments reused, {} segments computed",
        resp2.generated.len(),
        resp2.reused_segments,
        resp2.stats.segments,
    );

    // The headline assertion: turn 2 ran ZERO prefill segments for
    // turn-1 history — everything it computed is new work.
    assert_eq!(resp2.reused_segments, history_segments, "history fully reused");
    let new_segments = turn2.len().div_ceil(seg);
    let fed_decode_segments = resp2.generated.len() / seg - 1; // final segment is never fed
    assert_eq!(
        resp2.stats.segments,
        new_segments + fed_decode_segments,
        "turn 2 computed only its own prompt + decode segments — zero history prefill"
    );

    // Exactness: the resumed continuation bit-matches a full recompute
    // over turn-1 history + turn-2 tokens through the sequential oracle
    // (history = turn-1 prompt + the decode segments that were fed).
    let mut full = turn1;
    full.extend_from_slice(&resp1.generated[..seg]); // the fed decode segment
    full.extend_from_slice(&turn2);
    let want = engine(42, ExecMode::Sequential).process(&GenerateRequest::new(3, full).generate(seg))?;
    assert_eq!(resp2.generated, want.generated, "resume is exact recurrence");
    println!("OK: resumed reply == full-recompute oracle, token for token");
    Ok(())
}
