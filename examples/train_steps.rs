//! Training through the diagonal schedule (paper Appendix A: "we
//! implemented backward pass for diagonal batching to support training").
//!
//! This driver runs the diagonal FORWARD wavefront while recording each
//! iteration's inputs, then runs the REVERSE wavefront through the AOT
//! `grouped_step_bwd` executable: output cotangents shift down one layer
//! per reverse iteration (the exact adjoint of the forward shift), state
//! cotangents (dA, dz) flow right-to-left across iterations, and the
//! per-layer parameter gradients accumulate across all iterations.
//!
//! The objective is a simple L2 pull on the final-layer outputs
//! (loss = 0.5 Σ ||y_out||²) — enough to demonstrate end-to-end gradient
//! flow and that SGD on the AOT gradients reduces the loss monotonically.
//!
//! Run: `make artifacts && cargo run --release --example train_steps`

use diagonal_batching::config::Manifest;
use diagonal_batching::model::{PARAM_ORDER};
use diagonal_batching::runtime::HloBackend;
use diagonal_batching::scheduler::StepBackend;
use diagonal_batching::tensor::{self, Rng, Tensor};

struct StepRecord {
    x: Tensor,
    a: Tensor,
    z: Tensor,
    mask: Vec<f32>,
    y: Tensor,
}

/// Diagonal forward pass, recording per-iteration primals.
/// Returns (records, loss) with loss = 0.5 * mean(y_out^2).
fn forward(
    backend: &mut HloBackend,
    segments: &[Vec<u32>],
) -> (Vec<StepRecord>, f64) {
    let cfg = backend.config().clone();
    let (l_total, s_total) = (cfg.n_layers, segments.len());
    let mut x = Tensor::zeros(&[l_total, cfg.seg_total, cfg.d_model]);
    let mut a = Tensor::zeros(&[l_total, cfg.d_model, cfg.phi_dim]);
    let mut z = Tensor::zeros(&[l_total, cfg.phi_dim]);
    let mut active = vec![false; l_total];
    let mut records = Vec::new();
    let mut loss = 0.0f64;

    for i in 0..s_total + l_total - 1 {
        if i < s_total {
            x.set_index0(0, &backend.embed(&segments[i]).unwrap());
            active[0] = true;
        } else {
            active[0] = false;
        }
        let mask: Vec<f32> = active.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect();
        let (y, a2, z2) = backend.grouped_step(&x, &a, &z, &mask).unwrap();
        if active[l_total - 1] {
            let y_out = y.index0(l_total - 1);
            let n = (s_total * y_out.len()) as f64;
            loss += 0.5 * y_out.data().iter().map(|v| (*v as f64).powi(2)).sum::<f64>() / n;
        }
        records.push(StepRecord { x: x.clone(), a: a.clone(), z: z.clone(), mask, y: y.clone() });
        a = a2;
        z = z2;
        for l in (1..l_total).rev() {
            if active[l - 1] {
                x.set_index0(l, &y.index0(l - 1));
            }
            active[l] = active[l - 1];
        }
    }
    (records, loss)
}

/// Reverse wavefront: returns parameter gradients in PARAM_ORDER.
fn backward(backend: &mut HloBackend, records: &[StepRecord]) -> Vec<Tensor> {
    let cfg = backend.config().clone();
    let l_total = cfg.n_layers;
    let mut dx_next = Tensor::zeros(&[l_total, cfg.seg_total, cfg.d_model]);
    let mut da = Tensor::zeros(&[l_total, cfg.d_model, cfg.phi_dim]);
    let mut dz = Tensor::zeros(&[l_total, cfg.phi_dim]);
    let mut param_grads: Option<Vec<Tensor>> = None;

    for rec in records.iter().rev() {
        // dy: adjoint of the forward shift — what iteration i+1 consumed
        // from slot l flows back into slot l's output...
        let mut dy = Tensor::zeros(&[l_total, cfg.seg_total, cfg.d_model]);
        for l in 0..l_total - 1 {
            if rec.mask[l] == 1.0 {
                dy.set_index0(l, &dx_next.index0(l + 1));
            }
        }
        // ...plus the loss tap on completed segments (slot L-1):
        // d(0.5*mean(y^2))/dy = y / N.
        if rec.mask[l_total - 1] == 1.0 {
            let y_out = rec.y.index0(l_total - 1);
            let n = (records.iter().filter(|r| r.mask[l_total - 1] == 1.0).count()
                * y_out.len()) as f32;
            dy.set_index0(l_total - 1, &tensor::scale(&y_out, 1.0 / n));
        }

        let grads = backend
            .grouped_step_bwd(&rec.x, &rec.a, &rec.z, &rec.mask, &dy, &da, &dz)
            .unwrap();
        dx_next = grads[0].clone();
        da = grads[1].clone();
        dz = grads[2].clone();
        let pg = &grads[3..];
        param_grads = Some(match param_grads {
            None => pg.to_vec(),
            Some(acc) => acc.iter().zip(pg).map(|(a, b)| tensor::add(a, b)).collect(),
        });
    }
    param_grads.unwrap()
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let manifest = Manifest::load("artifacts/manifest.json")?;
    let mut backend = HloBackend::load(&manifest, "toy")?;
    let cfg = backend.config().clone();

    let mut rng = Rng::new(11);
    let s_total = 3usize;
    let segments: Vec<Vec<u32>> = (0..s_total)
        .map(|_| (0..cfg.seg).map(|_| rng.below(cfg.vocab) as u32).collect())
        .collect();

    println!(
        "training through the diagonal schedule: toy model, {} segments, lr 1e-4",
        s_total
    );
    println!("objective: 0.5 * mean(final-layer outputs^2) (gradient-flow demo)\n");

    let lr = 1e-4f32;
    let mut prev = f64::INFINITY;
    let mut current = diagonal_batching::model::Params::load(&manifest, "toy")?;
    for step in 0..6 {
        let (records, loss) = forward(&mut backend, &segments);
        println!("step {step}: loss {loss:.4}");
        assert!(
            loss < prev * 1.0001,
            "loss must not increase (step {step}: {loss} vs {prev})"
        );
        prev = loss;

        let grads = backward(&mut backend, &records);
        assert_eq!(grads.len(), PARAM_ORDER.len());

        // SGD on the stacked per-layer parameters (compounding across
        // steps via our `current` copy).
        for (name, g) in PARAM_ORDER.iter().zip(&grads) {
            let p = current.stacked(name)?;
            let t = tensor::sub(p, &tensor::scale(g, lr));
            current.set(name, t)?;
        }
        backend.refresh_params(current.clone())?;
    }
    println!("\nOK: loss decreased monotonically through the AOT backward executable");
    Ok(())
}
