//! Regenerate EVERY table and figure of the paper's evaluation from the
//! roofline simulator (DESIGN.md per-experiment index) in one run.
//!
//! Run: `cargo run --release --example paper_tables [h100]`

use diagonal_batching::bench::{fmt_s, fmt_x, Table};
use diagonal_batching::config::Manifest;
use diagonal_batching::simulator::tables::{
    exec_time_rows, fig1_rows, fig4_grouped_gemm_rows, fig5_attention_rows, fig6_rows, SEQ_LENS,
};
use diagonal_batching::simulator::DeviceSpec;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let manifest = Manifest::load("artifacts/manifest.json")?;
    let dev = if std::env::args().any(|a| a == "h100") {
        DeviceSpec::h100()
    } else {
        DeviceSpec::a100()
    };
    println!("=== simulated device: {} ===", dev.name);

    // ---- Tables 1 / 5 / 6 / 7 (+ the 8/9 speedup rows) ---------------------
    let specs: [(&str, &str, Vec<(usize, usize)>); 4] = [
        ("Table 7", "llama-160m", vec![(1024, 128), (4096, 128)]),
        (
            "Table 1",
            "llama-3.2-1b",
            vec![(512, 128), (1024, 128), (2048, 128), (4096, 128)],
        ),
        ("Table 5", "llama-3.2-3b", vec![(1024, 128), (4096, 128)]),
        ("Table 6", "llama-3.1-8b", vec![(1024, 128), (4096, 128)]),
    ];
    for (table_id, model, configs) in specs {
        let base = manifest.any_config(model)?;
        for (seg, mem) in configs {
            let rows = exec_time_rows(base, &dev, seg, mem, &SEQ_LENS);
            let mut t = Table::new(
                &format!("{table_id}: {model}, configuration ({seg}, {mem})"),
                &["method", "4096", "8192", "16384", "32768", "65536", "131072"],
            );
            let line = |label: &str, f: &dyn Fn(&_) -> String| {
                std::iter::once(label.to_string()).chain(rows.iter().map(f)).collect()
            };
            t.row(line(&format!("{model} (full attn)"), &|r: &_| fmt_s(r.llama_s)));
            t.row(line("ARMT (sequential)", &|r: &_| fmt_s(r.armt_seq_s)));
            t.row(line("ARMT (diagonal)", &|r: &_| fmt_s(r.armt_diag_s)));
            t.row(line("speedup vs ARMT (T9)", &|r: &_| fmt_x(r.speedup_vs_armt())));
            t.row(line("speedup vs llama (T8)", &|r: &_| fmt_x(r.speedup_vs_llama())));
            t.print();
        }
    }

    // ---- Fig. 1 headline ----------------------------------------------------
    let base_1b = manifest.any_config("llama-3.2-1b")?;
    let mut t = Table::new(
        "Fig. 1: 1B headline (seg 1024, mem 128)",
        &["seq len", "llama (s)", "ARMT diag (s)", "speedup", "memory saving"],
    );
    for r in fig1_rows(base_1b, &dev, &SEQ_LENS) {
        t.row(vec![
            r.seq_len.to_string(),
            fmt_s(r.llama_s),
            fmt_s(r.armt_diag_s),
            fmt_x(r.speedup),
            format!("{:.1}x", r.memory_saving),
        ]);
    }
    t.print();

    // ---- Fig. 4 grouped GEMM --------------------------------------------------
    let groups = [1usize, 2, 4, 8, 16, 32];
    for (label, m, n, k) in [
        ("1B linear (1152 x 2048 x 2048)", 1152usize, 2048usize, 2048usize),
        ("8B linear (1152 x 4096 x 4096)", 1152, 4096, 4096),
    ] {
        let mut t = Table::new(
            &format!("Fig. 4: grouped GEMM achieved TFLOP/s — {label}"),
            &["group", "grouped GEMM", "batched GEMM (same shapes)"],
        );
        for (g, grouped, batched) in fig4_grouped_gemm_rows(&dev, m, n, k, &groups) {
            t.row(vec![g.to_string(), format!("{grouped:.1}"), format!("{batched:.1}")]);
        }
        t.print();
    }

    // ---- Fig. 5 attention batching --------------------------------------------
    for seg_len in [640usize, 1152, 2176, 4224] {
        let mut t = Table::new(
            &format!("Fig. 5: attention speedup vs batch (T = {seg_len})"),
            &["batch", "relative FLOPS"],
        );
        for (b, rel) in fig5_attention_rows(&dev, base_1b, seg_len, &[1, 2, 4, 8, 16, 32]) {
            t.row(vec![b.to_string(), format!("{rel:.2}x")]);
        }
        t.print();
    }

    // ---- Fig. 6 diagonal vs minibatch ------------------------------------------
    for model in ["llama-160m", "llama-3.2-1b", "llama-3.2-3b", "llama-3.1-8b"] {
        let base = manifest.any_config(model)?;
        let mut t = Table::new(
            &format!("Fig. 6: time per segment — {model} (seg 1024, 32 segments)"),
            &["batch", "minibatch (s/seg)", "diagonal (s/seg)", "ideal even load (s/seg)"],
        );
        for r in fig6_rows(base, &dev, 1024, 128, 32, &[1, 2, 4, 8, 16]) {
            t.row(vec![
                r.batch.to_string(),
                fmt_s(r.minibatch_s),
                fmt_s(r.diagonal_s),
                fmt_s(r.ideal_s),
            ]);
        }
        t.print();
    }

    println!("\n(Table 2 and Tables 3-4 are measured, not simulated — see");
    println!(" `cargo bench --bench table2_error` and `--example babilong_eval`.)");
    Ok(())
}
