"""AOT pipeline: lower every L2 program to HLO *text* + dump weights.

Emits, per executable config (tiny, toy):

  artifacts/<model>/<exe>.hlo.txt   -- HLO text (NOT a serialized proto:
      jax >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
      rejects; the text parser reassigns ids -- see /opt/xla-example).
  artifacts/<model>/params.bin      -- little-endian f32 blob.
  artifacts/manifest.json           -- shapes/dtypes/offsets for rust,
      plus the paper configs for the roofline simulator and the shared
      BABILong-style task spec.

Run via `make artifacts` (no-op if outputs are newer than inputs).
Python never runs again after this.
"""

import argparse
import json
import os
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import (ArmtConfig, BY_NAME, EXECUTABLE_CONFIGS, PAPER_CONFIGS,
                      TINY, TOY)

jax.config.update("jax_platform_name", "cpu")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io_entry(name, s):
    return {"name": name, "shape": list(s.shape), "dtype": str(s.dtype)}


def lower_one(fn, in_specs, out_dir, exe_name, input_names):
    """Lower fn(*in_specs) and return its manifest entry."""
    lowered = jax.jit(fn).lower(*in_specs)
    text = to_hlo_text(lowered)
    fname = f"{exe_name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    outs = jax.eval_shape(fn, *in_specs)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    return {
        "file": fname,
        "inputs": [_io_entry(n, s) for n, s in zip(input_names, in_specs)],
        "outputs": [_io_entry(f"out{i}", s) for i, s in enumerate(outs)],
        "hlo_bytes": len(text),
    }


def layer_param_specs(cfg: ArmtConfig, g: int):
    """Specs for PARAM_ORDER with leading group axis g."""
    d, f, k = cfg.d_model, cfg.d_ff, cfg.k_assoc
    by_name = {
        "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
        "wg": (d, f), "wu": (d, f), "wd": (f, d),
        "n1": (d,), "n2": (d,),
        "aq": (d, k), "ak": (d, k), "av": (d, d), "ab": (d,),
    }
    return [spec((g,) + by_name[n]) for n in M.PARAM_ORDER]


def dump_params(params: dict, out_dir: str):
    """Write params.bin (f32 LE, PARAM_ORDER then GLOBAL_ORDER) + index."""
    index, offset = [], 0
    blobs = []
    for name in M.PARAM_ORDER + M.GLOBAL_ORDER:
        arr = np.asarray(params[name], dtype="<f4")
        index.append({
            "name": name,
            "shape": list(arr.shape),
            "offset_elems": offset,
            "size_elems": int(arr.size),
        })
        blobs.append(arr.reshape(-1))
        offset += arr.size
    with open(os.path.join(out_dir, "params.bin"), "wb") as f:
        f.write(np.concatenate(blobs).tobytes())
    return index


def build_model_entry(cfg: ArmtConfig, root: str, impl: str) -> dict:
    out_dir = os.path.join(root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    L, d, p, T, seg = (cfg.n_layers, cfg.d_model, cfg.phi_dim,
                       cfg.seg_total, cfg.seg)

    # Trained weights (toy model) override the seed init when present.
    trained_npz = os.path.join(root, f"{cfg.name}_trained.npz")
    trained = os.path.exists(trained_npz)
    if trained:
        with np.load(trained_npz) as npz:
            params = {k: jnp.asarray(npz[k]) for k in npz.files}
    else:
        params = M.init_params(cfg, seed=0)
    index = dump_params(params, out_dir)

    exes = {}

    def step_specs(g):
        return [
            spec((g, T, d)), spec((g, d, p)), spec((g, p)), spec((g, 1)),
        ] + layer_param_specs(cfg, g)

    step_names = ["x", "A", "z", "mask"] + list(M.PARAM_ORDER)

    exes["grouped_step"] = lower_one(
        lambda *a: M.grouped_step(cfg, impl, *a),
        step_specs(L), out_dir, "grouped_step", step_names)
    exes["single_step"] = lower_one(
        lambda *a: M.grouped_step(cfg, impl, *a),
        step_specs(1), out_dir, "single_step", step_names)

    bwd_specs = (step_specs(L)[:4]
                 + [spec((L, T, d)), spec((L, d, p)), spec((L, p))]
                 + layer_param_specs(cfg, L))
    bwd_names = (["x", "A", "z", "mask", "dy", "dA2", "dz2"]
                 + list(M.PARAM_ORDER))
    # Backward always lowers through the ref impl: jax.vjp of the interpret
    # -mode pallas kernels produces very large HLO for no numeric benefit.
    exes["grouped_step_bwd"] = lower_one(
        lambda x, A, z, mask, dy, dA2, dz2, *ps: M.grouped_step_bwd(
            cfg, "ref", x, A, z, mask, dy, dA2, dz2, *ps),
        bwd_specs, out_dir, "grouped_step_bwd", bwd_names)

    exes["embed"] = lower_one(
        lambda t, e, me: M.embed(cfg, t, e, me),
        [spec((seg,), jnp.int32), spec((cfg.vocab, d)), spec((cfg.mem, d))],
        out_dir, "embed", ["tokens", "emb", "mem_emb"])

    exes["lm_head"] = lower_one(
        lambda y, nf, w: M.lm_head(cfg, y, nf, w),
        [spec((T, d)), spec((d,)), spec((d, cfg.vocab))],
        out_dir, "lm_head", ["y", "nf", "w_out"])

    # The baseline uses no associative params; passing them would leave
    # unused HLO parameters that XLA drops during conversion, breaking
    # the positional-argument contract — so the signature excludes them
    # and the model fn re-synthesizes dummy assoc tensors at trace time.
    attn_param_names = [n for n in M.PARAM_ORDER if n not in ("aq", "ak", "av", "ab")]
    attn_specs = [
        s for n, s in zip(M.PARAM_ORDER, layer_param_specs(cfg, L))
        if n in attn_param_names
    ]

    def full_attn_fn(n):
        def fn(t, e, nf, w, *ps):
            by = dict(zip(attn_param_names, ps))
            full = [
                by.get(name, jnp.zeros((L, 1, 1), jnp.float32))
                for name in M.PARAM_ORDER
            ]
            return M.full_attn_forward(cfg, n, t, e, nf, w, *full)
        return fn

    for n_ctx in cfg.attn_buckets:
        name = f"full_attn_{n_ctx}"
        exes[name] = lower_one(
            full_attn_fn(n_ctx),
            [spec((n_ctx,), jnp.int32), spec((cfg.vocab, d)), spec((d,)),
             spec((d, cfg.vocab))] + attn_specs,
            out_dir, name,
            ["tokens", "emb", "nf", "w_out"] + attn_param_names)

    return {
        "dir": cfg.name,
        "impl": impl,
        "trained": trained,
        "config": cfg.asdict(),
        "params_bin": f"{cfg.name}/params.bin",
        "params": index,
        "executables": exes,
    }


# Shared task spec: the rust babilong generator mirrors these constants so
# python-trained toy models and rust-generated eval data agree on the
# token layout (see DESIGN.md substitution #3).
BABILONG_SPEC = {
    "pad": 0, "bos": 1, "query": 2, "sep": 3,
    "agent_base": 10, "n_agents": 8,
    "place_base": 24, "n_places": 16,
    "object_base": 44, "n_objects": 8,
    "filler_base": 56, "n_filler": 40,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/manifest.json",
                    help="manifest path; hlo/params live alongside it")
    ap.add_argument("--impl", default="pallas", choices=["pallas", "ref"])
    ap.add_argument("--models", nargs="*",
                    default=[c.name for c in EXECUTABLE_CONFIGS])
    args = ap.parse_args()

    root = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(root, exist_ok=True)

    # Merge into an existing manifest so `--models toy` (the `make toy`
    # path) refreshes one bundle without dropping the others.
    manifest = {
        "format_version": 1,
        "impl": args.impl,
        "models": {},
        "paper_configs": {c.name: c.asdict() for c in PAPER_CONFIGS},
        "babilong": BABILONG_SPEC,
    }
    if os.path.exists(args.out):
        try:
            with open(args.out) as f:
                manifest["models"] = json.load(f).get("models", {})
        except (json.JSONDecodeError, OSError):
            pass
    for name in args.models:
        cfg = BY_NAME[name]
        # micro is launch-overhead-bound by design: lower it through the
        # plain-jnp impl so interpret-mode grid loops don't add compute.
        impl = "ref" if name in ("micro", "tiny_ref") else args.impl
        print(f"[aot] lowering {name} ({impl}) ...", flush=True)
        manifest["models"][name] = build_model_entry(cfg, root, impl)
        for exe, ent in manifest["models"][name]["executables"].items():
            print(f"[aot]   {exe}: {ent['hlo_bytes'] / 1e3:.1f} kB")

    with open(args.out, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {args.out}")


if __name__ == "__main__":
    main()
