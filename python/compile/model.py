"""L2: the ARMT model as pure-functional jax, built on the L1 kernels.

Everything here is traced ONCE by aot.py into static HLO programs; nothing
in this file ever runs on the request path. The rust coordinator composes
these programs:

  embed        : token ids -> segment hiddens (+ memory-token embeddings)
  grouped_step : one diagonal iteration -- G stacked (segment, layer) cells
                 (assoc read -> transformer layer -> delta-rule update)
  single_step  : the same program specialized to G = 1 (the sequential
                 ARMT baseline executes L of these per segment)
  lm_head      : final-layer segment hiddens -> logits
  full_attn    : the vanilla full-attention LLaMA baseline, per length
                 bucket (quadratic in N -- the thing the paper beats)
  grouped_step_bwd : VJP of grouped_step (training support, paper App. A)

Parameter convention: per-layer tensors are stacked on a leading layer
axis [L, ...] (PARAM_ORDER below); the grouped step consumes G-row slices
of these stacks assembled by the rust scheduler.
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import kernels as K
from .kernels import ref as R
from .configs import ArmtConfig

# Stacked per-layer parameters, in the exact order every executable (and
# the rust side) uses. Shapes (per layer): see init_params.
PARAM_ORDER = (
    "wq", "wk", "wv", "wo",      # attention projections   [d, d]
    "wg", "wu",                  # swiglu gate/up          [d, f]
    "wd",                        # swiglu down             [f, d]
    "n1", "n2",                  # rmsnorm gains           [d]
    "aq", "ak",                  # assoc q/k projections   [d, k]
    "av",                        # assoc value projection  [d, d]
    "ab",                        # assoc beta vector       [d]
)
# Global (unstacked) parameters.
GLOBAL_ORDER = ("emb", "mem_emb", "nf", "w_out")


def init_params(cfg: ArmtConfig, seed: int = 0) -> dict:
    """Random init (trained weights for the toy model replace these)."""
    key = jax.random.PRNGKey(seed)
    d, f, k, L = cfg.d_model, cfg.d_ff, cfg.k_assoc, cfg.n_layers
    shapes = {
        "wq": (L, d, d), "wk": (L, d, d), "wv": (L, d, d), "wo": (L, d, d),
        "wg": (L, d, f), "wu": (L, d, f), "wd": (L, f, d),
        "n1": (L, d), "n2": (L, d),
        "aq": (L, d, k), "ak": (L, d, k), "av": (L, d, d), "ab": (L, d),
        "emb": (cfg.vocab, d), "mem_emb": (cfg.mem, d),
        "nf": (d,), "w_out": (d, cfg.vocab),
    }
    params = {}
    for i, (name, shape) in enumerate(shapes.items()):
        sub = jax.random.fold_in(key, i)
        if name in ("n1", "n2", "nf"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            scale = 0.02 if name in ("emb", "mem_emb") else (1.0 / shape[-2] ** 0.5
                     if len(shape) >= 2 else 0.02)
            params[name] = scale * jax.random.normal(sub, shape, jnp.float32)
    # Keep the associative write conservative at init so the recurrent
    # state does not blow up over many segments before training.
    params["av"] = params["av"] * 0.1
    return params


def _rmsnorm_g(x, g, eps):
    """x: [G, T, d], g: [G, d]."""
    return R.ref_rmsnorm(x, g[:, None, :], eps)


def grouped_step(cfg: ArmtConfig, impl: str, x, A, z, mask, *layer_params):
    """One diagonal iteration over a group of G stacked cells.

    x: [G, T, d] hiddens (T = seg + mem), A: [G, d, p], z: [G, p],
    mask: [G, 1] active flags, layer_params: PARAM_ORDER, each [G, ...].
    Returns (y [G, T, d], A' [G, d, p], z' [G, p]).
    """
    P = dict(zip(PARAM_ORDER, layer_params))
    nu, eps, seg = cfg.dpfp_nu, cfg.eps, cfg.seg

    if impl == "pallas":
        xr = K.assoc_read(x, A, z, P["aq"], nu=nu, eps=eps)
        attn = K.fused_attention(
            _rmsnorm_g(xr, P["n1"], eps), P["wq"], P["wk"], P["wv"], P["wo"],
            n_heads=cfg.n_heads, seg=seg, theta=cfg.rope_theta)
        h = xr + attn
        hn = _rmsnorm_g(h, P["n2"], eps)
        mlp = K.grouped_matmul(
            jax.nn.silu(K.grouped_matmul(hn, P["wg"])) * K.grouped_matmul(hn, P["wu"]),
            P["wd"])
        y = h + mlp
        A2, z2 = K.assoc_update(
            y[:, seg:, :], A, z, P["ak"], P["av"], P["ab"], mask, nu=nu, eps=eps)
    else:
        xr = R.ref_assoc_read_g(x, A, z, P["aq"], nu, eps)
        attn = R.ref_attention_g(
            _rmsnorm_g(xr, P["n1"], eps), P["wq"], P["wk"], P["wv"], P["wo"],
            cfg.n_heads, seg, cfg.rope_theta)
        h = xr + attn
        hn = _rmsnorm_g(h, P["n2"], eps)
        mlp = R.ref_grouped_matmul(
            jax.nn.silu(R.ref_grouped_matmul(hn, P["wg"]))
            * R.ref_grouped_matmul(hn, P["wu"]),
            P["wd"])
        y = h + mlp
        dA2, dz2 = R.ref_assoc_update_g(
            y[:, seg:, :], A, z, P["ak"], P["av"], P["ab"], nu, eps)
        A2 = A + mask[:, :, None] * (dA2 - A)
        z2 = z + mask * (dz2 - z)
    return y, A2, z2


def grouped_step_bwd(cfg: ArmtConfig, impl: str, x, A, z, mask,
                     dy, dA2, dz2, *layer_params):
    """VJP of grouped_step w.r.t. (x, A, z, layer_params...).

    Enables training through the diagonal schedule (paper Appendix A:
    "we implemented backward pass for diagonal batching").
    Returns (dx, dA, dz, *dparams) in PARAM_ORDER.
    """
    def fwd(x_, A_, z_, *ps):
        return grouped_step(cfg, impl, x_, A_, z_, mask, *ps)

    _, vjp = jax.vjp(fwd, x, A, z, *layer_params)
    return vjp((dy, dA2, dz2))


def embed(cfg: ArmtConfig, tokens, emb, mem_emb):
    """tokens: [seg] i32 -> [T, d] (segment embeddings ++ memory tokens)."""
    return jnp.concatenate([emb[tokens], mem_emb], axis=0)


def lm_head(cfg: ArmtConfig, y, nf, w_out):
    """Final-layer hiddens [T, d] -> logits [seg, vocab] (memory positions
    are dropped -- they are state, not output)."""
    h = R.ref_rmsnorm(y[: cfg.seg], nf, cfg.eps)
    return h @ w_out


# ---------------------------------------------------------------------------
# Vanilla full-attention LLaMA baseline (no memory, quadratic in N).
# ---------------------------------------------------------------------------

def full_attn_forward(cfg: ArmtConfig, n_ctx: int, tokens, emb, nf, w_out,
                      *layer_params):
    """tokens: [n_ctx] i32 -> logits [n_ctx, vocab].

    Per-layer params are the same stacked tensors; assoc params are unused
    (the baseline has no memory). Attention is standard causal MHA + RoPE
    over the full context -- this is the O(N^2) cost the paper compares
    against (Tables 1/8, Fig. 1).
    """
    P = dict(zip(PARAM_ORDER, layer_params))
    h = emb[tokens]
    hd = cfg.head_dim
    cos, sin = R.rope_angles(n_ctx, hd, cfg.rope_theta)
    i = jnp.arange(n_ctx)
    causal = jnp.where(i[None, :] <= i[:, None], 0.0, -1e30).astype(jnp.float32)

    for l in range(cfg.n_layers):
        xn = R.ref_rmsnorm(h, P["n1"][l], cfg.eps)

        def split(u):
            return u.reshape(n_ctx, cfg.n_heads, hd).transpose(1, 0, 2)

        q = R.ref_rope(split(xn @ P["wq"][l]), cos, sin)
        k = R.ref_rope(split(xn @ P["wk"][l]), cos, sin)
        v = split(xn @ P["wv"][l])
        s = jnp.einsum("hqe,hke->hqk", q, k) / jnp.sqrt(hd) + causal[None]
        o = jnp.einsum("hqk,hke->hqe", jax.nn.softmax(s, axis=-1), v)
        h = h + o.transpose(1, 0, 2).reshape(n_ctx, cfg.d_model) @ P["wo"][l]
        hn = R.ref_rmsnorm(h, P["n2"][l], cfg.eps)
        h = h + R.ref_swiglu(hn, P["wg"][l], P["wu"][l], P["wd"][l])
    return R.ref_rmsnorm(h, nf, cfg.eps) @ w_out


# ---------------------------------------------------------------------------
# Whole-model reference forward (used by the trainer and by pytest to check
# that composing the AOT pieces reproduces the monolithic model).
# ---------------------------------------------------------------------------

class ArmtState(NamedTuple):
    A: jax.Array   # [L, d, p]
    z: jax.Array   # [L, p]


def zero_state(cfg: ArmtConfig) -> ArmtState:
    return ArmtState(
        A=jnp.zeros((cfg.n_layers, cfg.d_model, cfg.phi_dim), jnp.float32),
        z=jnp.zeros((cfg.n_layers, cfg.phi_dim), jnp.float32),
    )


def armt_forward(cfg: ArmtConfig, params: dict, tokens, impl: str = "ref"):
    """Sequential-schedule reference: tokens [S, seg] -> logits [S, seg, V].

    Processes segments in order, layers in order -- the paper's "base ARMT"
    execution. Segment count S is static (python loop -> unrolled HLO); the
    rust executors must match this exactly (native backend) or to ~1e-3
    relative (HLO backend).
    """
    S = tokens.shape[0]
    st = zero_state(cfg)
    A, z = st.A, st.z
    mask1 = jnp.ones((1, 1), jnp.float32)
    outs = []
    for s in range(S):
        x = embed(cfg, tokens[s], params["emb"], params["mem_emb"])[None]
        for l in range(cfg.n_layers):
            lp = [params[n][l][None] for n in PARAM_ORDER]
            x, Al, zl = grouped_step(
                cfg, impl, x, A[l][None], z[l][None], mask1, *lp)
            A = A.at[l].set(Al[0])
            z = z.at[l].set(zl[0])
        outs.append(lm_head(cfg, x[0], params["nf"], params["w_out"]))
    return jnp.stack(outs)
