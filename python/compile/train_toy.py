"""Train the toy ARMT on the synthetic BABILong-style QA tasks.

This gives the Table 3/4 analog experiments a model whose accuracy is
meaningful: a 2-layer ARMT trained with a curriculum over segment counts
on QA1 (single supporting fact) and QA2 (two supporting facts), mirroring
the paper's "trained on BABILong with curriculum learning" setup at toy
scale. Cross-segment episodes force the model to carry the fact through
the associative memory (there is no other path between segments).

The episode generator here must stay in *distributional* lockstep with
rust `babilong::Generator` (same token layout from aot.BABILONG_SPEC,
same task semantics) — the rust side evaluates the trained model on
freshly generated episodes.

Output: artifacts/toy_trained.npz; `make toy` re-lowers the toy bundle
with these weights.
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .aot import BABILONG_SPEC
from .configs import TOY

jax.config.update("jax_platform_name", "cpu")

S = BABILONG_SPEC


# ---------------------------------------------------------------------------
# Episode generation (mirrors rust/src/babilong/mod.rs)
# ---------------------------------------------------------------------------

def gen_episode(rng: np.random.Generator, task: str, length: int):
    """Returns (tokens [length], answer, query_pos)."""
    toks = rng.integers(
        S["filler_base"], S["filler_base"] + S["n_filler"], size=length
    ).astype(np.int64)
    toks[0] = S["bos"]
    body_end = length - 2

    def agent():
        return S["agent_base"] + rng.integers(S["n_agents"])

    def place():
        return S["place_base"] + rng.integers(S["n_places"])

    def obj():
        return S["object_base"] + rng.integers(S["n_objects"])

    if task == "qa1":
        subject = agent()
        for _ in range(min(3, (body_end - 1) // 4)):
            pos = 1 + rng.integers(body_end - 4)
            toks[pos], toks[pos + 1], toks[pos + 2] = agent(), S["sep"], place()
        answer = place()
        pos = 1 + rng.integers(body_end - 4)
        toks[pos], toks[pos + 1], toks[pos + 2] = subject, S["sep"], answer
        for i in range(pos + 3, body_end):
            if toks[i] == subject:
                toks[i] = S["filler_base"] + rng.integers(S["n_filler"])
    else:  # qa2
        a, o, answer = agent(), obj(), place()
        first = 1 + rng.integers((body_end - 8) // 2)
        second = first + 3 + rng.integers(body_end - first - 6)
        toks[first : first + 3] = (a, S["sep"], o)
        toks[second : second + 3] = (o, S["sep"], answer)
        for i in range(second + 3, body_end):
            if toks[i] == o:
                toks[i] = S["filler_base"] + rng.integers(S["n_filler"])
        subject = o
    toks[body_end] = S["query"]
    toks[body_end + 1] = subject
    return toks, answer, length - 1


def gen_batch(rng, batch, n_segments):
    length = n_segments * TOY.seg
    xs = np.zeros((batch, n_segments, TOY.seg), np.int32)
    ys = np.zeros((batch,), np.int32)
    for b in range(batch):
        task = "qa1" if rng.random() < 0.5 else "qa2"
        toks, ans, _ = gen_episode(rng, task, length)
        xs[b] = toks.reshape(n_segments, TOY.seg)
        ys[b] = ans
    return jnp.asarray(xs), jnp.asarray(ys)


# ---------------------------------------------------------------------------
# Loss / optimizer (hand-rolled Adam; no optax offline)
# ---------------------------------------------------------------------------

def loss_fn(params, xs, ys):
    """xs: [B, S, seg] i32, ys: [B] i32 — CE at the final query position."""
    def one(tokens, y):
        logits = M.armt_forward(TOY, params, tokens, impl="ref")  # [S, seg, V]
        final = logits[-1, -1]  # query token is the last position
        logp = jax.nn.log_softmax(final)
        return -logp[y], jnp.argmax(final) == y

    losses, hits = jax.vmap(one, in_axes=(0, 0))(xs, ys)
    return jnp.mean(losses), jnp.mean(hits.astype(jnp.float32))


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr=1.5e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2**t), v)
    params = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh
    )
    return params, {"m": m, "v": v, "t": t}


def train(steps_per_stage, batch, seed, out_path):
    rng = np.random.default_rng(seed)
    params = M.init_params(TOY, seed=seed)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, xs, ys):
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, xs, ys)
        params, opt = adam_step(params, grads, opt)
        return params, opt, loss, acc

    # Curriculum over segment counts, as in the paper's BABILong training,
    # with replay: each stage samples lengths up to its maximum so earlier
    # lengths are not forgotten (the S=1-only skills collapsed without it).
    for stage, max_segments in enumerate([1, 2, 4]):
        t0 = time.time()
        choices = [s for s in [1, 2, 4] if s <= max_segments]
        for it in range(steps_per_stage[stage]):
            n_segments = choices[rng.integers(len(choices))]
            xs, ys = gen_batch(rng, batch, n_segments)
            params, opt, loss, acc = step(params, opt, xs, ys)
            if it % 50 == 0 or it == steps_per_stage[stage] - 1:
                print(
                    f"[train] stage<= {max_segments} step {it:4d} (S={n_segments}) "
                    f"loss {float(loss):.3f} acc {float(acc):.2f} "
                    f"({time.time() - t0:.0f}s)",
                    flush=True,
                )

    # Held-out eval per task / segment count.
    for task in ["qa1", "qa2"]:
        for n_segments in [1, 2, 4, 8]:
            xs = np.zeros((64, n_segments, TOY.seg), np.int32)
            ys = np.zeros((64,), np.int32)
            for b in range(64):
                toks, ans, _ = gen_episode(rng, task, n_segments * TOY.seg)
                xs[b] = toks.reshape(n_segments, TOY.seg)
                ys[b] = ans
            _, acc = jax.jit(loss_fn)(params, jnp.asarray(xs), jnp.asarray(ys))
            print(f"[eval] {task} S={n_segments}: acc {float(acc):.2f}", flush=True)

    np.savez(out_path, **{k: np.asarray(v) for k, v in params.items()})
    print(f"[train] wrote {out_path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/toy_trained.npz")
    ap.add_argument("--steps", type=int, nargs=3, default=[300, 500, 900])
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    if os.path.exists(args.out) and not args.force:
        print(f"[train] {args.out} exists; use --force to retrain")
        return
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    train(args.steps, args.batch, args.seed, args.out)


if __name__ == "__main__":
    main()
