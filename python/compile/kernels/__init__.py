"""L1 Pallas kernels for the ARMT diagonal-batching stack.

Every kernel has a pure-jnp oracle in ref.py; pytest enforces allclose.
All kernels are lowered with interpret=True (CPU PJRT cannot execute
Mosaic custom-calls) -- see DESIGN.md §Hardware-Adaptation.
"""

from .dpfp import dpfp, dpfp_inline
from .grouped_gemm import grouped_matmul
from .associative import assoc_read, assoc_update
from .attention import fused_attention

__all__ = [
    "dpfp", "dpfp_inline", "grouped_matmul",
    "assoc_read", "assoc_update", "fused_attention",
]
