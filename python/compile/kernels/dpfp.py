"""DPFP-nu feature map as a Pallas kernel plus an in-kernel helper.

DPFP (Deterministic Parameter-Free Projection, Schlag et al. 2021) is the
untrained nonlinearity phi used by the ARMT associative memory. It expands
[..., k] -> [..., 2*nu*k] with only elementwise ops, so on TPU it is a pure
VPU (vector unit) kernel: no MXU traffic, and the natural tiling is "one
row block in VMEM at a time".
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def dpfp_inline(x: jax.Array, nu: int = 3) -> jax.Array:
    """phi(x) on an in-register/VMEM value. Usable inside other kernels.

    jnp.roll lowers to two slices + concat, which Pallas supports in both
    interpret and compiled modes.
    """
    xx = jax.nn.relu(jnp.concatenate([x, -x], axis=-1))
    rolled = [xx * jnp.roll(xx, -r, axis=-1) for r in range(1, nu + 1)]
    return jnp.concatenate(rolled, axis=-1)


def _dpfp_kernel(x_ref, o_ref, *, nu: int):
    o_ref[...] = dpfp_inline(x_ref[...], nu)


@functools.partial(jax.jit, static_argnames=("nu", "block_rows", "interpret"))
def dpfp(x: jax.Array, nu: int = 3, block_rows: int = 128,
         interpret: bool = True) -> jax.Array:
    """phi(x): [R, k] -> [R, 2*nu*k], tiled over row blocks.

    Each grid step streams a [block_rows, k] tile HBM->VMEM, expands it on
    the VPU, and writes the [block_rows, 2*nu*k] result back. VMEM footprint
    per step is block_rows * k * (1 + 2*nu) * 4 bytes.
    """
    rows, k = x.shape
    br = min(block_rows, rows)
    grid = (pl.cdiv(rows, br),)
    return pl.pallas_call(
        functools.partial(_dpfp_kernel, nu=nu),
        grid=grid,
        in_specs=[pl.BlockSpec((br, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, 2 * nu * k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 2 * nu * k), x.dtype),
        interpret=interpret,
    )(x)
