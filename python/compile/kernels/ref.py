"""Pure-jnp reference oracle for every Pallas kernel in this package.

These are the ground-truth semantics of the ARMT cell (paper eqs. 3-6)
and of the grouped primitives. pytest checks each Pallas kernel against
its `ref_*` counterpart with `assert_allclose`; the L2 model can also be
built entirely on these (impl="ref") which is how grouped-vs-sequential
bit-level drift is isolated to scheduling rather than kernel bugs.

Shapes use the following conventions:
  G = group size (number of stacked layers on one diagonal)
  T = seg + mem  (per-segment sequence length incl. memory tokens)
  d = d_model,  k = k_assoc,  p = 2 * nu * k  (DPFP feature dim)
"""

import jax
import jax.numpy as jnp

EPS = 1e-6


# ---------------------------------------------------------------------------
# DPFP-nu feature map (Schlag et al., 2021) -- the untrained nonlinearity phi.
# ---------------------------------------------------------------------------

def ref_dpfp(x: jax.Array, nu: int = 3) -> jax.Array:
    """phi(x): [..., k] -> [..., 2*nu*k].

    phi(x) = concat_{r=1..nu}  relu([x, -x]) * roll(relu([x, -x]), -r)
    All entries are >= 0 and phi(x) != 0 for x != 0, which keeps the
    associative denominators well-behaved.
    """
    xx = jax.nn.relu(jnp.concatenate([x, -x], axis=-1))
    rolled = [xx * jnp.roll(xx, -r, axis=-1) for r in range(1, nu + 1)]
    return jnp.concatenate(rolled, axis=-1)


# ---------------------------------------------------------------------------
# Associative memory (paper eqs. 3-6): quasi-linear attention w/ delta rule.
# ---------------------------------------------------------------------------

def ref_assoc_read(x, A, z, wq, nu: int = 3, eps: float = EPS):
    """Eq. (6) with a residual connection.

    x: [T, d], A: [d, p], z: [p], wq: [d, k]  ->  [T, d]

    out_i = x_i + A phi(W_Q x_i) / (z^T phi(W_Q x_i) + eps)

    With A = 0, z = 0 (segment 0) the read is an exact no-op, which is why
    the scheduler never needs a "skip read" gate.
    """
    q = ref_dpfp(x @ wq, nu)                      # [T, p]
    num = q @ A.T                                 # [T, d]
    den = q @ z + eps                             # [T]
    return x + num / den[:, None]


def ref_assoc_update(y_mem, A, z, ak, av, ab, nu: int = 3, eps: float = EPS):
    """Delta-rule memory update, eqs. (3)-(5).

    y_mem: [m, d] (output hidden states at the memory-token positions)
    A: [d, p], z: [p]; ak: [d, k], av: [d, d], ab: [d]
    Returns (A', z').
    """
    k = ref_dpfp(y_mem @ ak, nu)                  # [m, p]  (phi(k_i))
    v = y_mem @ av                                # [m, d]
    beta = jax.nn.sigmoid(y_mem @ ab)             # [m]
    den = k @ z                                   # [m]     (z^T phi(k_i))
    v_bar = (k @ A.T) / (den + eps)[:, None]      # [m, d]
    norm2 = jnp.sum(k * k, axis=-1)               # [m]     ||phi(k_i)||^2
    gamma = 1.0 - den / (norm2 + eps)             # [m]
    dA = (beta[:, None] * (v - v_bar)).T @ k      # [d, p]
    dz = gamma @ k                                # [p]
    return A + dA, z + dz


def ref_assoc_read_g(x, A, z, wq, nu: int = 3, eps: float = EPS):
    """Grouped read: x [G,T,d], A [G,d,p], z [G,p], wq [G,d,k]."""
    return jax.vmap(lambda xi, Ai, zi, wi: ref_assoc_read(xi, Ai, zi, wi, nu, eps))(
        x, A, z, wq
    )


def ref_assoc_update_g(y_mem, A, z, ak, av, ab, nu: int = 3, eps: float = EPS):
    """Grouped update over leading G axis."""
    return jax.vmap(
        lambda yi, Ai, zi, aki, avi, abi: ref_assoc_update(
            yi, Ai, zi, aki, avi, abi, nu, eps
        )
    )(y_mem, A, z, ak, av, ab)


# ---------------------------------------------------------------------------
# Grouped GEMM -- the CUTLASS GroupedGEMM analog.
# ---------------------------------------------------------------------------

def ref_grouped_matmul(x, w):
    """x: [G, M, K], w: [G, K, N] -> [G, M, N] (per-group matmul)."""
    return jnp.einsum("gmk,gkn->gmn", x, w)


# ---------------------------------------------------------------------------
# Attention (grouped, causal-within-segment, RoPE).
# ---------------------------------------------------------------------------

def rope_angles(T: int, head_dim: int, theta: float = 10000.0):
    """Returns (cos, sin) of shape [T, head_dim//2]."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2) / head_dim))
    t = jnp.arange(T)
    ang = jnp.outer(t, inv)
    return jnp.cos(ang), jnp.sin(ang)


def ref_rope(x, cos, sin):
    """x: [..., T, head_dim]; rotates pairs (even, odd)."""
    x1, x2 = x[..., 0::2], x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape)


def armt_attn_mask(T: int, seg: int) -> jax.Array:
    """[T, T] additive mask: segment tokens are causal; the trailing
    memory (read/write) tokens attend to every position."""
    i = jnp.arange(T)[:, None]
    j = jnp.arange(T)[None, :]
    allowed = (j <= i) | (i >= seg)
    return jnp.where(allowed, 0.0, -1e30).astype(jnp.float32)


def ref_attention(x, wq, wk, wv, wo, n_heads: int, seg: int,
                  theta: float = 10000.0):
    """Single-group MHA with RoPE and the ARMT mask.

    x: [T, d]; wq/wk/wv/wo: [d, d] -> [T, d]
    """
    T, d = x.shape
    hd = d // n_heads

    def split(h):  # [T, d] -> [H, T, hd]
        return h.reshape(T, n_heads, hd).transpose(1, 0, 2)

    cos, sin = rope_angles(T, hd, theta)
    q = ref_rope(split(x @ wq), cos, sin)
    k = ref_rope(split(x @ wk), cos, sin)
    v = split(x @ wv)
    scores = jnp.einsum("hqe,hke->hqk", q, k) / jnp.sqrt(hd)
    scores = scores + armt_attn_mask(T, seg)[None]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hqk,hke->hqe", probs, v)           # [H, T, hd]
    out = out.transpose(1, 0, 2).reshape(T, d)
    return out @ wo


def ref_attention_g(x, wq, wk, wv, wo, n_heads: int, seg: int,
                    theta: float = 10000.0):
    """Grouped attention over leading G axis (the paper's "attention as
    batch over the diagonal group")."""
    return jax.vmap(
        lambda xi, a, b, c, o: ref_attention(xi, a, b, c, o, n_heads, seg, theta)
    )(x, wq, wk, wv, wo)


# ---------------------------------------------------------------------------
# Misc layer pieces shared with model.py
# ---------------------------------------------------------------------------

def ref_rmsnorm(x, g, eps: float = EPS):
    """x: [..., d], g: [d] (or broadcastable, e.g. [G, 1, d])."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def ref_swiglu(x, wg, wu, wd):
    """x: [T, d]; wg/wu: [d, f]; wd: [f, d]."""
    return (jax.nn.silu(x @ wg) * (x @ wu)) @ wd
