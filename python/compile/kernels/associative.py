"""Associative-memory Pallas kernels: the ARMT read (eq. 6) and the
delta-rule update (eqs. 3-5).

These are the paper's compute hot-spot *besides* the transformer layer
itself: every (segment, layer) cell performs one read over T tokens and one
update over m memory tokens. Both kernels are grouped over the diagonal
axis G -- one grid step per group member -- so a whole diagonal's reads (or
updates) are a single kernel launch, mirroring how the paper folds them
into the grouped schedule.

TPU mapping: per grid step the kernel holds one group member's activations
[T, d], its projection [d, k], and its state A [d, p] in VMEM. The
phi-expansion runs on the VPU; the three matmuls (q-projection, A-read,
outer-product update) hit the MXU. For the tiny AOT configs everything is
single-tile; the BlockSpecs below keep the layout identical at scale.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dpfp import dpfp_inline

EPS = 1e-6


def _read_kernel(x_ref, a_ref, z_ref, wq_ref, o_ref, *, nu: int, eps: float):
    x = x_ref[0]                                   # [T, d]
    A = a_ref[0]                                   # [d, p]
    z = z_ref[0]                                   # [p]
    wq = wq_ref[0]                                 # [d, k]
    q = dpfp_inline(jnp.dot(x, wq, preferred_element_type=jnp.float32), nu)
    num = jnp.dot(q, A.T, preferred_element_type=jnp.float32)   # [T, d]
    den = jnp.dot(q, z[:, None], preferred_element_type=jnp.float32) + eps
    o_ref[0] = (x + num / den).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("nu", "eps", "interpret"))
def assoc_read(x, A, z, wq, nu: int = 3, eps: float = EPS,
               interpret: bool = True):
    """Grouped associative read with residual.

    x: [G, T, d], A: [G, d, p], z: [G, p], wq: [G, d, k] -> [G, T, d].
    With A = z = 0 (segment 0) this is an exact identity, so the scheduler
    never needs a skip-read gate.
    """
    g, t, d = x.shape
    p = A.shape[2]
    k = wq.shape[2]
    return pl.pallas_call(
        functools.partial(_read_kernel, nu=nu, eps=eps),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, t, d), lambda gi: (gi, 0, 0)),
            pl.BlockSpec((1, d, p), lambda gi: (gi, 0, 0)),
            pl.BlockSpec((1, p), lambda gi: (gi, 0)),
            pl.BlockSpec((1, d, k), lambda gi: (gi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, d), lambda gi: (gi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, t, d), x.dtype),
        interpret=interpret,
    )(x, A, z, wq)


def _update_kernel(y_ref, a_ref, z_ref, ak_ref, av_ref, ab_ref, m_ref,
                   ao_ref, zo_ref, *, nu: int, eps: float):
    y = y_ref[0]                                   # [m, d]
    A = a_ref[0]                                   # [d, p]
    z = z_ref[0]                                   # [p]
    mask = m_ref[0]                                # [1] active flag
    k = dpfp_inline(jnp.dot(y, ak_ref[0], preferred_element_type=jnp.float32), nu)
    v = jnp.dot(y, av_ref[0], preferred_element_type=jnp.float32)      # [m, d]
    beta = jax.nn.sigmoid(
        jnp.dot(y, ab_ref[0][:, None], preferred_element_type=jnp.float32)
    )                                              # [m, 1]
    den = jnp.dot(k, z[:, None], preferred_element_type=jnp.float32)   # [m, 1]
    v_bar = jnp.dot(k, A.T, preferred_element_type=jnp.float32) / (den + eps)
    norm2 = jnp.sum(k * k, axis=-1, keepdims=True)                     # [m, 1]
    gamma = 1.0 - den / (norm2 + eps)                                  # [m, 1]
    dA = jnp.dot((beta * (v - v_bar)).T, k, preferred_element_type=jnp.float32)
    dz = jnp.dot(gamma.T, k, preferred_element_type=jnp.float32)[0]    # [p]
    # `mask` zeroes the delta for padded (inactive) diagonal slots so
    # ramp-up/-down garbage never touches the recurrent state.
    ao_ref[0] = (A + mask * dA).astype(ao_ref.dtype)
    zo_ref[0] = (z + mask * dz).astype(zo_ref.dtype)


@functools.partial(jax.jit, static_argnames=("nu", "eps", "interpret"))
def assoc_update(y_mem, A, z, ak, av, ab, mask, nu: int = 3,
                 eps: float = EPS, interpret: bool = True):
    """Grouped delta-rule update.

    y_mem: [G, m, d], A: [G, d, p], z: [G, p], ak: [G, d, k],
    av: [G, d, d], ab: [G, d], mask: [G, 1] -> (A', z').
    """
    g, m, d = y_mem.shape
    p = A.shape[2]
    k = ak.shape[2]
    return pl.pallas_call(
        functools.partial(_update_kernel, nu=nu, eps=eps),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, m, d), lambda gi: (gi, 0, 0)),
            pl.BlockSpec((1, d, p), lambda gi: (gi, 0, 0)),
            pl.BlockSpec((1, p), lambda gi: (gi, 0)),
            pl.BlockSpec((1, d, k), lambda gi: (gi, 0, 0)),
            pl.BlockSpec((1, d, d), lambda gi: (gi, 0, 0)),
            pl.BlockSpec((1, d), lambda gi: (gi, 0)),
            pl.BlockSpec((1, 1), lambda gi: (gi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d, p), lambda gi: (gi, 0, 0)),
            pl.BlockSpec((1, p), lambda gi: (gi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, d, p), A.dtype),
            jax.ShapeDtypeStruct((g, p), z.dtype),
        ],
        interpret=interpret,
    )(y_mem, A, z, ak, av, ab, mask)
