"""Grouped fused attention kernel (flash-style) for ARMT segments.

Diagonal batching does not change attention math at all -- it just turns
the per-layer attention into a *batched* attention with batch = group size
(paper 4.2). This kernel makes that explicit: the grid's leading axes are
(group, head) and each step computes one head's attention for one group
member with an online-softmax KV loop, the TPU rethink of the paper's
FlashAttention usage:

  * GPU threadblock tiling over (batch, head, q-block) -> Pallas grid
    (G, H, q-block);
  * shared-memory KV staging -> VMEM-resident [bk, hd] KV tiles via a
    fori_loop over lax.dynamic_slice;
  * warp-level online softmax -> VPU max/exp accumulators carried through
    the loop.

RoPE and the ARMT mask (causal for segment tokens, full for the trailing
memory tokens) are applied in-kernel so the whole attention is one fused
launch per diagonal.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, cos_ref, sin_ref, o_ref,
                 *, seg: int, block_k: int, scale: float):
    """Grid = (G, H). Block shapes: q/k/v [1, 1, T, hd], cos/sin [T, hd/2]."""
    t, hd = q_ref.shape[2], q_ref.shape[3]
    cos, sin = cos_ref[...], sin_ref[...]

    def rope(x):
        x1, x2 = x[:, 0::2], x[:, 1::2]
        r1 = x1 * cos - x2 * sin
        r2 = x1 * sin + x2 * cos
        return jnp.stack([r1, r2], axis=-1).reshape(x.shape)

    q = rope(q_ref[0, 0]) * scale                   # [T, hd]
    k = rope(k_ref[0, 0])                           # [T, hd]
    v = v_ref[0, 0]                                 # [T, hd]

    rows = jax.lax.broadcasted_iota(jnp.int32, (t, block_k), 0)
    n_blocks = t // block_k

    def body(b, carry):
        acc, m_prev, l_prev = carry
        kb = jax.lax.dynamic_slice(k, (b * block_k, 0), (block_k, hd))
        vb = jax.lax.dynamic_slice(v, (b * block_k, 0), (block_k, hd))
        s = jnp.dot(q, kb.T, preferred_element_type=jnp.float32)  # [T, bk]
        cols = jax.lax.broadcasted_iota(jnp.int32, (t, block_k), 1) + b * block_k
        allowed = (cols <= rows) | (rows >= seg)
        s = jnp.where(allowed, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = corr * acc + jnp.dot(p, vb, preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((t, hd), jnp.float32)
    m0 = jnp.full((t, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((t, 1), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, n_blocks, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("n_heads", "seg", "block_k", "theta", "interpret")
)
def fused_attention(x, wq, wk, wv, wo, n_heads: int, seg: int,
                    block_k: int = 0, theta: float = 10000.0,
                    interpret: bool = True):
    """Grouped MHA: x [G, T, d], weights [G, d, d] -> [G, T, d].

    The QKV/output projections stay outside the kernel (they belong to the
    grouped-GEMM path); the kernel fuses RoPE + mask + online softmax.
    block_k = 0 picks the largest divisor of T that is <= 128.
    """
    g, t, d = x.shape
    hd = d // n_heads
    if block_k <= 0:
        block_k = next(b for b in range(min(t, 128), 0, -1) if t % b == 0)
    assert t % block_k == 0, (t, block_k)

    def proj(w):  # [G, T, d] @ [G, d, d] -> [G, H, T, hd]
        h = jnp.einsum("gtd,gde->gte", x, w)
        return h.reshape(g, t, n_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = proj(wq), proj(wk), proj(wv)
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2) / hd))
    ang = jnp.outer(jnp.arange(t), inv)
    cos, sin = jnp.cos(ang).astype(x.dtype), jnp.sin(ang).astype(x.dtype)

    out = pl.pallas_call(
        functools.partial(
            _attn_kernel, seg=seg, block_k=block_k, scale=1.0 / (hd ** 0.5)
        ),
        grid=(g, n_heads),
        in_specs=[
            pl.BlockSpec((1, 1, t, hd), lambda gi, hi: (gi, hi, 0, 0)),
            pl.BlockSpec((1, 1, t, hd), lambda gi, hi: (gi, hi, 0, 0)),
            pl.BlockSpec((1, 1, t, hd), lambda gi, hi: (gi, hi, 0, 0)),
            pl.BlockSpec((t, hd // 2), lambda gi, hi: (0, 0)),
            pl.BlockSpec((t, hd // 2), lambda gi, hi: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, t, hd), lambda gi, hi: (gi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, n_heads, t, hd), x.dtype),
        interpret=interpret,
    )(q, k, v, cos, sin)
    merged = out.transpose(0, 2, 1, 3).reshape(g, t, d)
    return jnp.einsum("gtd,gde->gte", merged, wo)
