"""Grouped GEMM -- the Pallas analog of CUTLASS GroupedGEMM (paper 3.3).

The paper replaces every linear layer of the base model with a grouped
matmul whose group dimension stacks the weights of all N_layers layers, so
one kernel launch serves a whole diagonal. On TPU the natural mapping is:

  * group axis  -> leading grid axis (one systolic pass per group member),
  * (M, N) tile -> MXU-shaped [bm, bn] output tile accumulated in VMEM,
  * K loop      -> innermost grid axis streaming [bm, bk] x [bk, bn] tile
                   pairs HBM->VMEM (BlockSpec plays the role the paper's
                   threadblock scheduling plays on GPU).

The output is pre-allocated as one [G, M, N] tensor and written in place --
the same "single large tensor partitioned into submatrices" trick the paper
applies to CUTLASS output pointers.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gmm_kernel(x_ref, w_ref, o_ref):
    """Grid = (G, M/bm, N/bn, K/bk); accumulate over the trailing K axis.

    The output BlockSpec's index map ignores the K grid axis, so the same
    [1, bm, bn] output tile stays resident in VMEM across the whole K loop
    and doubles as the accumulator (outputs are f32, so this loses no
    precision vs a dedicated scratch accumulator).
    """
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[0], w_ref[0], preferred_element_type=jnp.float32
    )[None].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def grouped_matmul(x: jax.Array, w: jax.Array, bm: int = 128, bn: int = 128,
                   bk: int = 128, interpret: bool = True) -> jax.Array:
    """x: [G, M, K] @ w: [G, K, N] -> [G, M, N].

    Tile sizes default to the 128-lane MXU shape; they are clamped to the
    problem size so tiny AOT configs lower to a single-tile grid.
    VMEM per grid step: (bm*bk + bk*bn + 2*bm*bn) * 4 bytes.
    """
    g, m, k = x.shape
    g2, k2, n = w.shape
    assert g == g2 and k == k2, (x.shape, w.shape)
    bm, bn = min(bm, m), min(bn, n)
    # M/N tails are safe (padded output rows/cols are dropped on write),
    # but a padded K tail would inject garbage into the accumulation, so
    # bk must divide k: take the largest divisor <= the requested bk.
    bk = next(b for b in range(min(bk, k), 0, -1) if k % b == 0)
    grid = (g, pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda gi, mi, ni, ki: (gi, mi, ki)),
            pl.BlockSpec((1, bk, bn), lambda gi, mi, ni, ki: (gi, ki, ni)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gi, mi, ni, ki: (gi, mi, ni)),
        out_shape=jax.ShapeDtypeStruct((g, m, n), x.dtype),
        interpret=interpret,
    )(x, w)
