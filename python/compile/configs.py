"""Model configurations shared by the L2 model, the AOT pipeline and the
toy trainer.

Each config describes an ARMT-ified LLaMA-style decoder. The *paper*
configurations (160M / 1B / 3B / 8B) are only used by the rust roofline
simulator (their dims are recorded in the manifest for cost modelling);
the *tiny* and *toy* configs are actually lowered to HLO and executed on
the CPU PJRT client.
"""

from dataclasses import dataclass, asdict, field
from typing import List


@dataclass(frozen=True)
class ArmtConfig:
    """Architecture + ARMT hyper-parameters for one model variant."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seg: int            # tokens per segment (paper: segment_size)
    mem: int            # number of memory tokens appended to each segment
    k_assoc: int        # associative key dim (paper: assoc memory hidden size)
    dpfp_nu: int = 3    # DPFP-nu feature map; phi dim = 2 * nu * k_assoc
    rope_theta: float = 10000.0
    eps: float = 1e-6   # denominators in eqs. (4) and (6)
    # Full-attention baseline length buckets lowered to HLO.
    attn_buckets: List[int] = field(default_factory=lambda: [128, 256, 512])

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def phi_dim(self) -> int:
        return 2 * self.dpfp_nu * self.k_assoc

    @property
    def seg_total(self) -> int:
        """Per-segment sequence length seen by a layer step (seg + mem)."""
        return self.seg + self.mem

    def asdict(self) -> dict:
        d = asdict(self)
        d["head_dim"] = self.head_dim
        d["phi_dim"] = self.phi_dim
        d["seg_total"] = self.seg_total
        return d


# Lowered + executed on CPU PJRT: shape-validation and the real error /
# launch-amortization experiments (Tables 2, 9-analog on CPU).
TINY = ArmtConfig(
    name="tiny",
    vocab=512,
    d_model=64,
    n_layers=4,
    n_heads=4,
    d_ff=128,
    seg=32,
    mem=8,
    k_assoc=16,
)

# Trained on synthetic BABILong-style QA (Tables 3 / 4 analogs).
TOY = ArmtConfig(
    name="toy",
    vocab=96,
    d_model=64,
    n_layers=2,
    n_heads=4,
    d_ff=128,
    seg=32,
    mem=4,
    k_assoc=16,
    attn_buckets=[128],
)

# Launch-overhead-dominated config: cell compute is so small that PJRT
# call overhead dominates, which is the regime where diagonal batching
# wins WALLCLOCK even on the single-core CPU backend (the CPU analog of
# the paper's kernel-launch amortization; see EXPERIMENTS.md).
MICRO = ArmtConfig(
    name="micro",
    vocab=64,
    d_model=32,
    n_layers=8,
    n_heads=2,
    d_ff=64,
    seg=8,
    mem=2,
    k_assoc=8,
    attn_buckets=[],
)

# Same dims as TINY but lowered through the pure-jnp impl — the §Perf
# A/B that quantifies interpret-mode Pallas overhead on CPU PJRT
# (EXPERIMENTS.md §Perf L2). Serving deployments on CPU should prefer
# this bundle; the pallas bundle is the TPU-shaped path.
TINY_REF = ArmtConfig(
    name="tiny_ref",
    vocab=512,
    d_model=64,
    n_layers=4,
    n_heads=4,
    d_ff=128,
    seg=32,
    mem=8,
    k_assoc=16,
    attn_buckets=[],
)

# Paper configurations — simulator-only (dims feed the roofline model).
LLAMA_160M = ArmtConfig(
    name="llama-160m", vocab=32000, d_model=768, n_layers=12, n_heads=12,
    d_ff=3072, seg=1024, mem=128, k_assoc=64, attn_buckets=[],
)
LLAMA_1B = ArmtConfig(
    name="llama-3.2-1b", vocab=128256, d_model=2048, n_layers=16, n_heads=32,
    d_ff=8192, seg=1024, mem=128, k_assoc=64, attn_buckets=[],
)
LLAMA_3B = ArmtConfig(
    name="llama-3.2-3b", vocab=128256, d_model=3072, n_layers=28, n_heads=24,
    d_ff=8192, seg=1024, mem=128, k_assoc=64, attn_buckets=[],
)
LLAMA_8B = ArmtConfig(
    name="llama-3.1-8b", vocab=128256, d_model=4096, n_layers=32, n_heads=32,
    d_ff=14336, seg=1024, mem=128, k_assoc=64, attn_buckets=[],
)

PAPER_CONFIGS = [LLAMA_160M, LLAMA_1B, LLAMA_3B, LLAMA_8B]
EXECUTABLE_CONFIGS = [TINY, TOY, MICRO, TINY_REF]

BY_NAME = {c.name: c for c in EXECUTABLE_CONFIGS + PAPER_CONFIGS}
