"""L2 correctness: grouped step semantics, schedule equivalence, backward.

The key properties the rust scheduler relies on:
  * grouped_step over G rows == G independent single_steps (row isolation);
  * the sequential reference forward equals a manually-run diagonal
    schedule (the paper's exactness claim, Lemma 3.1 ordering);
  * pallas and ref impls agree to f32 tolerance;
  * grouped_step_bwd equals jax.grad of the step.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.configs import TINY, TOY

jax.config.update("jax_platform_name", "cpu")

CFG = TINY


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def _rand_tokens(rng, s):
    return jnp.asarray(rng.integers(0, CFG.vocab, (s, CFG.seg)), jnp.int32)


def _step_inputs(rng, params, g):
    x = jnp.asarray(
        rng.normal(size=(g, CFG.seg_total, CFG.d_model), scale=0.5), jnp.float32)
    A = jnp.asarray(
        rng.normal(size=(g, CFG.d_model, CFG.phi_dim), scale=0.1), jnp.float32)
    z = jnp.abs(jnp.asarray(
        rng.normal(size=(g, CFG.phi_dim), scale=0.1), jnp.float32))
    mask = jnp.ones((g, 1), jnp.float32)
    lps = [params[n][:g] for n in M.PARAM_ORDER]
    return x, A, z, mask, lps


def test_grouped_rows_are_independent(params):
    """Grouped call == per-row single calls (the scheduler's core
    assumption: stacking cells on a diagonal cannot couple them)."""
    rng = np.random.default_rng(0)
    g = CFG.n_layers
    x, A, z, mask, lps = _step_inputs(rng, params, g)
    y, A2, z2 = M.grouped_step(CFG, "ref", x, A, z, mask, *lps)
    for i in range(g):
        yi, Ai, zi = M.grouped_step(
            CFG, "ref", x[i][None], A[i][None], z[i][None], mask[:1],
            *[p[i][None] for p in lps])
        np.testing.assert_allclose(y[i], yi[0], rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(A2[i], Ai[0], rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(z2[i], zi[0], rtol=3e-5, atol=3e-5)


def test_pallas_matches_ref_step(params):
    rng = np.random.default_rng(1)
    x, A, z, mask, lps = _step_inputs(rng, params, CFG.n_layers)
    yr, Ar, zr = M.grouped_step(CFG, "ref", x, A, z, mask, *lps)
    yp, Ap, zp = M.grouped_step(CFG, "pallas", x, A, z, mask, *lps)
    np.testing.assert_allclose(yp, yr, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(Ap, Ar, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(zp, zr, rtol=2e-3, atol=2e-3)


def test_mask_freezes_state_and_identity_read(params):
    rng = np.random.default_rng(2)
    x, A, z, _, lps = _step_inputs(rng, params, 2)
    mask = jnp.asarray([[1.0], [0.0]], jnp.float32)
    _, A2, z2 = M.grouped_step(CFG, "ref", x, A, z, mask, *lps)
    np.testing.assert_array_equal(np.asarray(A2[1]), np.asarray(A[1]))
    np.testing.assert_array_equal(np.asarray(z2[1]), np.asarray(z[1]))


def _diagonal_forward(cfg, params, tokens, impl="ref"):
    """Manually run the DIAGONAL schedule in python: iteration i executes
    all cells (s, l) with s + l = i as one grouped_step. This mirrors what
    the rust scheduler does and must equal the sequential reference."""
    S = tokens.shape[0]
    L = cfg.n_layers
    A = jnp.zeros((L, cfg.d_model, cfg.phi_dim), jnp.float32)
    z = jnp.zeros((L, cfg.phi_dim), jnp.float32)
    hidden = {}     # segment -> current hidden [T, d]
    outs = [None] * S
    for i in range(S + L - 1):
        cells = [(i - l, l) for l in range(L) if 0 <= i - l < S]
        g = len(cells)
        xs = []
        for s, l in cells:
            if l == 0:
                xs.append(M.embed(cfg, tokens[s], params["emb"],
                                  params["mem_emb"]))
            else:
                xs.append(hidden[s])
        x = jnp.stack(xs)
        idx = jnp.asarray([l for _, l in cells])
        mask = jnp.ones((g, 1), jnp.float32)
        lps = [params[n][idx] for n in M.PARAM_ORDER]
        y, A2, z2 = M.grouped_step(cfg, impl, x, A[idx], z[idx], mask, *lps)
        A = A.at[idx].set(A2)
        z = z.at[idx].set(z2)
        for j, (s, l) in enumerate(cells):
            if l == L - 1:
                outs[s] = M.lm_head(cfg, y[j], params["nf"], params["w_out"])
                hidden.pop(s, None)
            else:
                hidden[s] = y[j]
    return jnp.stack(outs)


def test_diagonal_schedule_equals_sequential(params):
    """The paper's exactness claim at the schedule level."""
    rng = np.random.default_rng(3)
    tokens = _rand_tokens(rng, 6)
    seq = M.armt_forward(CFG, params, tokens, impl="ref")
    diag = _diagonal_forward(CFG, params, tokens, impl="ref")
    err = float(jnp.linalg.norm(diag - seq) / jnp.linalg.norm(seq))
    assert err < 2e-2, err      # paper Table 2: < 2% relative drift
    # and the top-1 predictions should agree almost everywhere
    agree = float(jnp.mean(jnp.argmax(diag, -1) == jnp.argmax(seq, -1)))
    assert agree > 0.99, agree


def test_memory_carries_information(params):
    """Changing segment 0 must change segment 1 logits (through (A, z)
    only -- there is no other path)."""
    rng = np.random.default_rng(4)
    tokens = _rand_tokens(rng, 2)
    base = M.armt_forward(CFG, params, tokens, impl="ref")
    tokens2 = tokens.at[0, 0].set((int(tokens[0, 0]) + 7) % CFG.vocab)
    pert = M.armt_forward(CFG, params, tokens2, impl="ref")
    assert not np.allclose(np.asarray(base[1]), np.asarray(pert[1]), atol=1e-5)


def test_backward_matches_jax_grad(params):
    """grouped_step_bwd == jax.grad on a scalar functional of the step."""
    rng = np.random.default_rng(5)
    g = 2
    x, A, z, mask, lps = _step_inputs(rng, params, g)
    dy = jnp.ones((g, CFG.seg_total, CFG.d_model), jnp.float32)
    dA2 = jnp.zeros((g, CFG.d_model, CFG.phi_dim), jnp.float32)
    dz2 = jnp.zeros((g, CFG.phi_dim), jnp.float32)

    grads = M.grouped_step_bwd(CFG, "ref", x, A, z, mask, dy, dA2, dz2, *lps)

    def loss(x_, A_, z_, *ps):
        y, _, _ = M.grouped_step(CFG, "ref", x_, A_, z_, mask, *ps)
        return jnp.sum(y)

    want = jax.grad(loss, argnums=tuple(range(3 + len(lps))))(x, A, z, *lps)
    for got_i, want_i in zip(grads, want):
        np.testing.assert_allclose(got_i, want_i, rtol=1e-4, atol=1e-4)


def test_embed_and_lm_head_shapes(params):
    tokens = jnp.zeros((CFG.seg,), jnp.int32)
    x = M.embed(CFG, tokens, params["emb"], params["mem_emb"])
    assert x.shape == (CFG.seg_total, CFG.d_model)
    logits = M.lm_head(CFG, x, params["nf"], params["w_out"])
    assert logits.shape == (CFG.seg, CFG.vocab)


def test_full_attn_baseline_runs_and_is_causal():
    cfg = TOY
    params = M.init_params(cfg, seed=1)
    rng = np.random.default_rng(6)
    n = 64
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (n,)), jnp.int32)
    lps = [params[k] for k in M.PARAM_ORDER]
    out = M.full_attn_forward(cfg, n, toks, params["emb"], params["nf"],
                              params["w_out"], *lps)
    assert out.shape == (n, cfg.vocab)
    toks2 = toks.at[-1].set((int(toks[-1]) + 1) % cfg.vocab)
    out2 = M.full_attn_forward(cfg, n, toks2, params["emb"], params["nf"],
                               params["w_out"], *lps)
    np.testing.assert_allclose(out[:-1], out2[:-1], rtol=1e-5, atol=1e-5)
