"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes; fixed-seed numpy provides data. Tolerances are
f32-level: the kernels and oracles differ only in reduction order.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (assoc_read, assoc_update, dpfp, fused_attention,
                             grouped_matmul)
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")

HYP = dict(deadline=None, max_examples=12, derandomize=True)


def arr(rng, *shape, scale=0.5):
    return jnp.asarray(rng.normal(size=shape, scale=scale), jnp.float32)


# ---------------------------------------------------------------- dpfp ----

@settings(**HYP)
@given(rows=st.integers(1, 70), k=st.integers(1, 24), nu=st.integers(1, 4))
def test_dpfp_matches_ref(rows, k, nu):
    rng = np.random.default_rng(rows * 100 + k)
    x = arr(rng, rows, k)
    got = dpfp(x, nu=nu)
    want = R.ref_dpfp(x, nu=nu)
    assert got.shape == (rows, 2 * nu * k)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_dpfp_nonnegative():
    rng = np.random.default_rng(0)
    x = arr(rng, 33, 16)
    assert float(jnp.min(dpfp(x))) >= 0.0


def test_dpfp_zero_is_zero():
    z = jnp.zeros((4, 8), jnp.float32)
    np.testing.assert_array_equal(np.asarray(dpfp(z)), 0.0)


def test_dpfp_block_tiling_invariant():
    """Row-block size must not change the result."""
    rng = np.random.default_rng(3)
    x = arr(rng, 64, 16)
    a = dpfp(x, block_rows=8)
    b = dpfp(x, block_rows=64)
    np.testing.assert_allclose(a, b, rtol=0, atol=0)


# -------------------------------------------------------- grouped gemm ----

@settings(**HYP)
@given(g=st.integers(1, 8), m=st.integers(1, 48), k=st.integers(1, 48),
       n=st.integers(1, 48))
def test_grouped_matmul_matches_ref(g, m, k, n):
    rng = np.random.default_rng(g * 1000 + m + k + n)
    x, w = arr(rng, g, m, k), arr(rng, g, k, n)
    got = grouped_matmul(x, w, bm=16, bn=16, bk=16)
    np.testing.assert_allclose(got, R.ref_grouped_matmul(x, w),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("tiles", [(8, 8, 8), (16, 32, 8), (64, 64, 64)])
def test_grouped_matmul_tile_invariant(tiles):
    rng = np.random.default_rng(7)
    x, w = arr(rng, 4, 40, 64), arr(rng, 4, 64, 24)
    bm, bn, bk = tiles
    got = grouped_matmul(x, w, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, R.ref_grouped_matmul(x, w),
                               rtol=1e-4, atol=1e-4)


def test_grouped_matmul_group_independence():
    """Each group's output depends only on its own slice."""
    rng = np.random.default_rng(9)
    x, w = arr(rng, 3, 8, 8), arr(rng, 3, 8, 8)
    full = grouped_matmul(x, w)
    x2 = x.at[1].set(0.0)
    part = grouped_matmul(x2, w)
    np.testing.assert_allclose(part[0], full[0], atol=0)
    np.testing.assert_allclose(part[2], full[2], atol=0)
    np.testing.assert_allclose(part[1], 0.0, atol=0)


# --------------------------------------------------- associative memory ----

def _assoc_inputs(rng, g, t, d, k, nu=3):
    p = 2 * nu * k
    return (arr(rng, g, t, d), arr(rng, g, d, p),
            jnp.abs(arr(rng, g, p)), arr(rng, g, d, k))


@settings(**HYP)
@given(g=st.integers(1, 6), t=st.integers(1, 48), d=st.sampled_from([16, 64]),
       k=st.sampled_from([4, 16]))
def test_assoc_read_matches_ref(g, t, d, k):
    rng = np.random.default_rng(g + t + d + k)
    x, A, z, wq = _assoc_inputs(rng, g, t, d, k)
    np.testing.assert_allclose(
        assoc_read(x, A, z, wq), R.ref_assoc_read_g(x, A, z, wq),
        rtol=1e-4, atol=1e-4)


def test_assoc_read_zero_state_is_identity():
    """Segment 0: A = z = 0 makes the read an exact no-op (the property
    that lets the scheduler drop the skip-read gate)."""
    rng = np.random.default_rng(11)
    x = arr(rng, 4, 40, 64)
    A = jnp.zeros((4, 64, 96)); z = jnp.zeros((4, 96))
    wq = arr(rng, 4, 64, 16)
    np.testing.assert_allclose(assoc_read(x, A, z, wq), x, atol=1e-6)


@settings(**HYP)
@given(g=st.integers(1, 6), m=st.integers(1, 16), d=st.sampled_from([16, 64]),
       k=st.sampled_from([4, 16]))
def test_assoc_update_matches_ref(g, m, d, k):
    rng = np.random.default_rng(g * 31 + m + d + k)
    p = 6 * k
    y = arr(rng, g, m, d)
    A, z = arr(rng, g, d, p), jnp.abs(arr(rng, g, p))
    ak, av, ab = arr(rng, g, d, k), arr(rng, g, d, d), arr(rng, g, d)
    mask = jnp.ones((g, 1), jnp.float32)
    A2, z2 = assoc_update(y, A, z, ak, av, ab, mask)
    A2r, z2r = R.ref_assoc_update_g(y, A, z, ak, av, ab)
    np.testing.assert_allclose(A2, A2r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(z2, z2r, rtol=1e-4, atol=1e-4)


def test_assoc_update_mask_freezes_state():
    """Inactive diagonal slots must leave (A, z) bit-identical."""
    rng = np.random.default_rng(13)
    g, m, d, k = 3, 8, 32, 8
    y = arr(rng, g, m, d)
    A, z = arr(rng, g, d, 6 * k), jnp.abs(arr(rng, g, 6 * k))
    ak, av, ab = arr(rng, g, d, k), arr(rng, g, d, d), arr(rng, g, d)
    mask = jnp.asarray([[1.0], [0.0], [1.0]], jnp.float32)
    A2, z2 = assoc_update(y, A, z, ak, av, ab, mask)
    np.testing.assert_array_equal(np.asarray(A2[1]), np.asarray(A[1]))
    np.testing.assert_array_equal(np.asarray(z2[1]), np.asarray(z[1]))
    assert not np.allclose(np.asarray(A2[0]), np.asarray(A[0]))


def test_assoc_write_then_read_recovers_value():
    """Delta-rule sanity: after writing (k, v), reading with q = k returns
    approximately v (the associative recall the ARMT relies on)."""
    rng = np.random.default_rng(17)
    d, k = 32, 8
    p = 6 * k
    y = arr(rng, 1, 1, d, scale=1.0)            # one memory token
    A, z = jnp.zeros((1, d, p)), jnp.zeros((1, p))
    ak, av, ab = arr(rng, 1, d, k), arr(rng, 1, d, d), arr(rng, 1, d)
    mask = jnp.ones((1, 1), jnp.float32)
    A2, z2 = assoc_update(y, A, z, ak, av, ab, mask)
    # read with wq = ak so phi(q) == phi(k); the first write stores
    # beta * v (v_bar = 0 and gamma = 1 on a zero state)
    x = y[:, 0:1, :]
    got = assoc_read(x, A2, z2, ak) - x         # the retrieved value
    beta = jax.nn.sigmoid(y[0, 0] @ ab[0])
    want = (beta * (y[0, 0] @ av[0]))[None, None]
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


# ------------------------------------------------------------ attention ----

@settings(**HYP)
@given(g=st.integers(1, 4), heads=st.sampled_from([1, 2, 4]),
       t_seg=st.sampled_from([(8, 4), (40, 32), (24, 16)]))
def test_attention_matches_ref(g, heads, t_seg):
    t, seg = t_seg
    d = 32
    rng = np.random.default_rng(g * 7 + heads + t)
    x = arr(rng, g, t, d)
    ws = [arr(rng, g, d, d) for _ in range(4)]
    got = fused_attention(x, *ws, n_heads=heads, seg=seg)
    want = R.ref_attention_g(x, *ws, n_heads=heads, seg=seg)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_attention_block_k_invariant():
    """Online-softmax KV chunking must not change the output."""
    rng = np.random.default_rng(23)
    g, t, d, seg = 2, 40, 64, 32
    x = arr(rng, g, t, d)
    ws = [arr(rng, g, d, d) for _ in range(4)]
    a = fused_attention(x, *ws, n_heads=4, seg=seg, block_k=8)
    b = fused_attention(x, *ws, n_heads=4, seg=seg, block_k=40)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_attention_causal_within_segment():
    """Changing a *future* segment token must not affect earlier segment
    positions (memory tokens are exempt -- they see everything)."""
    rng = np.random.default_rng(29)
    g, t, d, seg = 1, 40, 32, 32
    x = arr(rng, g, t, d)
    ws = [arr(rng, g, d, d) for _ in range(4)]
    base = fused_attention(x, *ws, n_heads=2, seg=seg)
    x2 = x.at[0, seg - 1].add(5.0)              # last segment token
    pert = fused_attention(x2, *ws, n_heads=2, seg=seg)
    np.testing.assert_allclose(base[0, : seg - 1], pert[0, : seg - 1],
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(base[0, seg:], pert[0, seg:], atol=1e-4)
