"""AOT contract tests: the manifest + params.bin + HLO text that rust
consumes are internally consistent."""

import json
import os

import numpy as np
import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ROOT, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first")


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_models_present(manifest):
    assert "tiny" in manifest["models"]
    assert "toy" in manifest["models"]


def test_params_bin_matches_index(manifest):
    for name, entry in manifest["models"].items():
        blob = os.path.getsize(os.path.join(ROOT, entry["params_bin"]))
        total = sum(p["size_elems"] for p in entry["params"])
        assert blob == 4 * total, name
        # offsets are contiguous and ordered
        off = 0
        for p in entry["params"]:
            assert p["offset_elems"] == off
            assert p["size_elems"] == int(np.prod(p["shape"]))
            off += p["size_elems"]


def test_executables_exist_and_are_hlo(manifest):
    for name, entry in manifest["models"].items():
        for exe, e in entry["executables"].items():
            path = os.path.join(ROOT, entry["dir"], e["file"])
            assert os.path.exists(path), (name, exe)
            with open(path) as f:
                head = f.read(4096)
            assert "HloModule" in head, (name, exe)
            assert e["inputs"] and e["outputs"]


def test_grouped_step_io_shapes(manifest):
    e = manifest["models"]["tiny"]
    cfg = e["config"]
    gs = e["executables"]["grouped_step"]
    L, T, d, p = (cfg["n_layers"], cfg["seg_total"], cfg["d_model"],
                  cfg["phi_dim"])
    by_name = {i["name"]: i["shape"] for i in gs["inputs"]}
    assert by_name["x"] == [L, T, d]
    assert by_name["A"] == [L, d, p]
    assert by_name["z"] == [L, p]
    assert by_name["mask"] == [L, 1]
    assert gs["outputs"][0]["shape"] == [L, T, d]
    ss = e["executables"]["single_step"]
    assert ss["inputs"][0]["shape"] == [1, T, d]


def test_paper_configs_for_simulator(manifest):
    pc = manifest["paper_configs"]
    assert set(pc) == {"llama-160m", "llama-3.2-1b", "llama-3.2-3b",
                       "llama-3.1-8b"}
    assert pc["llama-3.2-1b"]["n_layers"] == 16
    assert pc["llama-3.2-1b"]["d_model"] == 2048


def test_babilong_spec_token_ranges_disjoint(manifest):
    s = manifest["babilong"]
    spans = [
        (s["agent_base"], s["agent_base"] + s["n_agents"]),
        (s["place_base"], s["place_base"] + s["n_places"]),
        (s["object_base"], s["object_base"] + s["n_objects"]),
        (s["filler_base"], s["filler_base"] + s["n_filler"]),
    ]
    spans.sort()
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        assert a1 <= b0
    assert spans[-1][1] <= 96  # fits the toy vocab
