//! Parallel wavefront-step throughput: one long request through a
//! 12-layer model on worker pools of 1/2/4/8 threads, with a
//! byte-identity check across thread counts.
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `parallel_scaling`; this binary is the legacy `cargo bench`
//! entry point and is equivalent to
//! `diagonal-batching bench --suite parallel_scaling`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("parallel_scaling")
}
