//! Table 6: llama-3.1-8b ARMT execution time vs sequence length.
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `table6_llama8b`; this binary is the legacy `cargo bench` entry point
//! and is equivalent to `diagonal-batching bench --suite table6_llama8b`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("table6_llama8b")
}
