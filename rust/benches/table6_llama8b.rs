//! Table 6: llama-3.1-8b ARMT execution time vs sequence length on the A100
//! roofline model. Paper shape: diagonal wins at long contexts; gains
//! shrink as the model (and its per-launch compute) grows.

use diagonal_batching::bench::{fmt_s, fmt_x, Table};
use diagonal_batching::config::Manifest;
use diagonal_batching::simulator::tables::{exec_time_rows, SEQ_LENS};
use diagonal_batching::simulator::DeviceSpec;

fn main() {
    let manifest = Manifest::load("artifacts/manifest.json").expect("make artifacts first");
    let base = manifest.any_config("llama-3.1-8b").unwrap();
    let dev = DeviceSpec::a100();
    for seg in [1024usize, 4096] {
        let rows = exec_time_rows(base, &dev, seg, 128, &SEQ_LENS);
        let mut t = Table::new(
            &format!("Table 6 — llama-3.1-8b, configuration ({seg}, 128) [simulated {}]", dev.name),
            &["method", "4096", "8192", "16384", "32768", "65536", "131072"],
        );
        t.row(std::iter::once("llama-3.1-8b (full attn)".into())
            .chain(rows.iter().map(|r| fmt_s(r.llama_s))).collect());
        t.row(std::iter::once("ARMT sequential".into())
            .chain(rows.iter().map(|r| fmt_s(r.armt_seq_s))).collect());
        t.row(std::iter::once("Diagonal Batching".into())
            .chain(rows.iter().map(|r| fmt_s(r.armt_diag_s))).collect());
        t.row(std::iter::once("speedup".into())
            .chain(rows.iter().map(|r| fmt_x(r.speedup_vs_armt()))).collect());
        t.print();
        let last = rows.last().unwrap();
        assert!(last.speedup_vs_armt() > 1.02,
            "diag speedup at 131k (seg {seg}): {}", last.speedup_vs_armt());
        assert!(rows[0].speedup_vs_armt() <= last.speedup_vs_armt() + 1e-9);
    }
    println!("\nshape checks passed");
}
