//! Hot-path microbenchmarks on the REAL PJRT backend (the §Perf
//! instrument): per-call cost of every executable, the end-to-end
//! diagonal-vs-sequential wallclock on each CPU-runnable model, and the
//! launch-amortization demonstration on the launch-bound micro model.
//!
//! This is the bench the EXPERIMENTS.md §Perf before/after numbers come
//! from. Expectations on this testbed:
//!   * tiny (compute-bound on 1 CPU core): diagonal LOSES wallclock —
//!     grouped steps serialize; the win is launch-count only;
//!   * micro (launch-bound): diagonal WINS wallclock — the CPU analog of
//!     the paper's GPU launch amortization.

use std::time::Duration;

use diagonal_batching::bench::{bench, bench_n, Table};
use diagonal_batching::config::Manifest;
use diagonal_batching::runtime::HloBackend;
use diagonal_batching::scheduler::{Executor, ScheduleMode, StepBackend};
use diagonal_batching::tensor::{Rng, Tensor};

fn per_step(manifest: &Manifest, model: &str) {
    let mut b = HloBackend::load(manifest, model).unwrap();
    let cfg = b.config().clone();
    let l = cfg.n_layers;
    let mut rng = Rng::new(7);
    let x = Tensor::randn(&[l, cfg.seg_total, cfg.d_model], 0.5, &mut rng);
    let a = Tensor::zeros(&[l, cfg.d_model, cfg.phi_dim]);
    let z = Tensor::zeros(&[l, cfg.phi_dim]);
    let mask = vec![1.0; l];
    let x1 = x.index0(0);
    let a1 = a.index0(0);
    let z1 = z.index0(0);
    let toks: Vec<u32> = (0..cfg.seg as u32).collect();

    println!("\n-- {model}: per-call costs (L = {l}) --");
    let g = bench(&format!("{model}/grouped_step"), Duration::from_millis(400), || {
        std::hint::black_box(b.grouped_step(&x, &a, &z, &mask).unwrap());
    });
    println!("{g}");
    let s = bench(&format!("{model}/single_step"), Duration::from_millis(400), || {
        std::hint::black_box(b.single_step(0, &x1, &a1, &z1).unwrap());
    });
    println!("{s}");
    let e = bench(&format!("{model}/embed"), Duration::from_millis(200), || {
        std::hint::black_box(b.embed(&toks).unwrap());
    });
    println!("{e}");
    let y = b.embed(&toks).unwrap();
    let h = bench(&format!("{model}/lm_head"), Duration::from_millis(200), || {
        std::hint::black_box(b.lm_head(&y).unwrap());
    });
    println!("{h}");
    println!(
        "grouped/single ratio: {:.2} (L = {l}; < L means grouping amortizes overhead)",
        g.mean_s() / s.mean_s()
    );
    // §Perf counterfactual: what every step would pay without resident
    // parameter buffers.
    let up = b.param_upload_cost().unwrap();
    println!(
        "param re-upload counterfactual: {up:?}/step avoided ({:.0}% of a grouped step)",
        100.0 * up.as_secs_f64() / g.mean_s()
    );
}

fn end_to_end(manifest: &Manifest, model: &str, n_segments: usize, iters: usize) {
    let mut b = HloBackend::load(manifest, model).unwrap();
    let cfg = b.config().clone();
    let mut rng = Rng::new(11);
    let tokens: Vec<u32> =
        (0..n_segments * cfg.seg).map(|_| rng.below(cfg.vocab) as u32).collect();

    let d = bench_n(&format!("{model}/e2e diagonal S={n_segments}"), iters, || {
        std::hint::black_box(
            Executor::new(&mut b, ScheduleMode::Diagonal).run(&tokens).unwrap(),
        );
    });
    let s = bench_n(&format!("{model}/e2e sequential S={n_segments}"), iters, || {
        std::hint::black_box(
            Executor::new(&mut b, ScheduleMode::Sequential).run(&tokens).unwrap(),
        );
    });
    println!("{d}");
    println!("{s}");
    println!(
        "diagonal speedup: x{:.2}  (launches {} vs {})",
        s.mean_s() / d.mean_s(),
        n_segments + cfg.n_layers - 1,
        n_segments * cfg.n_layers,
    );
}

fn main() {
    let manifest = Manifest::load("artifacts/manifest.json").expect("make artifacts first");

    for model in ["tiny", "tiny_ref", "toy", "micro"] {
        per_step(&manifest, model);
    }
    println!("\n(tiny vs tiny_ref isolates interpret-mode Pallas overhead: same dims,");
    println!(" jnp-lowered HLO instead of pallas interpret — the §Perf L2 A/B.)");

    println!("\n-- end-to-end schedule comparison (PJRT CPU) --");
    end_to_end(&manifest, "tiny", 16, 5);
    end_to_end(&manifest, "micro", 64, 5);

    // Launch-amortization table on the launch-bound model.
    let mut b = HloBackend::load(&manifest, "micro").unwrap();
    let cfg = b.config().clone();
    let mut t = Table::new(
        "micro model: diagonal vs sequential wallclock by segment count",
        &["segments", "diag (ms)", "seq (ms)", "speedup"],
    );
    let mut rng = Rng::new(13);
    for n_segments in [8usize, 16, 32, 64, 128] {
        let tokens: Vec<u32> =
            (0..n_segments * cfg.seg).map(|_| rng.below(cfg.vocab) as u32).collect();
        let d = bench_n("d", 3, || {
            std::hint::black_box(
                Executor::new(&mut b, ScheduleMode::Diagonal).run(&tokens).unwrap(),
            );
        });
        let s = bench_n("s", 3, || {
            std::hint::black_box(
                Executor::new(&mut b, ScheduleMode::Sequential).run(&tokens).unwrap(),
            );
        });
        t.row(vec![
            n_segments.to_string(),
            format!("{:.1}", d.mean_s() * 1e3),
            format!("{:.1}", s.mean_s() * 1e3),
            format!("x{:.2}", s.mean_s() / d.mean_s()),
        ]);
    }
    t.print();
}
