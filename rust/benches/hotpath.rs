//! Hot-path microbenchmarks on the REAL PJRT backend (the §Perf instrument).
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `hotpath`; this binary is the legacy `cargo bench` entry point
//! and is equivalent to `diagonal-batching bench --suite hotpath`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("hotpath")
}
