//! Long-context quality tier: BABILong QA1/QA2 accuracy vs context
//! length under the `overflow` policies (off / select / chunked), plus
//! the policy-off bit-exactness and observability gates.
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `babilong_quality`; this binary is the legacy `cargo bench`
//! entry point and is equivalent to
//! `diagonal-batching bench --suite babilong_quality`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("babilong_quality")
}
