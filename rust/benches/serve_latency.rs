//! `serve_queue` under concurrent synthetic load: latency percentiles
//! (p50/p90/p99) and aggregate utilization from the serving engine.
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `serve_latency`; this binary is the legacy `cargo bench` entry
//! point and is equivalent to `diagonal-batching bench --suite serve_latency`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("serve_latency")
}
