//! Fig. 4: grouped GEMM throughput vs group size (+ measured CPU analog).
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `fig4_grouped_gemm`; this binary is the legacy `cargo bench` entry point
//! and is equivalent to `diagonal-batching bench --suite fig4_grouped_gemm`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("fig4_grouped_gemm")
}
