//! Fig. 4: grouped GEMM throughput scales with group size like batched
//! GEMM scales with batch size (the basis of the whole method, §4.1).
//!
//! Two parts:
//!  1. the A100 roofline model's achieved TFLOP/s per group size for the
//!     1B and 8B linear-layer shapes (paper shape: grouped ~ batched
//!     from group 4, both saturating at device peak);
//!  2. a measured CPU data point: the in-tree grouped matmul vs g
//!     independent matmuls (on one core these tie — recorded to document
//!     why CPU wallclock can't show the GPU effect; see EXPERIMENTS.md).

use std::time::Duration;

use diagonal_batching::bench::{bench, Table};
use diagonal_batching::config::Manifest;
use diagonal_batching::simulator::tables::fig4_grouped_gemm_rows;
use diagonal_batching::simulator::DeviceSpec;
use diagonal_batching::tensor::{grouped_matmul, matmul, Rng, Tensor};

fn main() {
    let _ = Manifest::load("artifacts/manifest.json"); // not required, kept uniform
    let dev = DeviceSpec::a100();
    let groups = [1usize, 2, 4, 8, 16, 32];

    for (label, m, n, k) in [
        ("LLaMA-1B linear: 1152 x 2048 x 2048", 1152usize, 2048usize, 2048usize),
        ("LLaMA-8B linear: 1152 x 4096 x 4096", 1152, 4096, 4096),
    ] {
        let rows = fig4_grouped_gemm_rows(&dev, m, n, k, &groups);
        let mut t = Table::new(
            &format!("Fig. 4 — achieved TFLOP/s, {label} [simulated {}]", dev.name),
            &["group", "grouped GEMM", "batched GEMM"],
        );
        for (g, grouped, batched) in &rows {
            t.row(vec![g.to_string(), format!("{grouped:.1}"), format!("{batched:.1}")]);
        }
        t.print();
        // monotone, and grouped tracks batched within 2x from group 4
        for w in rows.windows(2) {
            assert!(w[1].1 >= w[0].1 * 0.98);
        }
        for (g, grouped, batched) in &rows {
            if *g >= 4 {
                assert!(grouped / batched > 0.5, "group {g}");
            }
        }
    }

    // measured CPU analog (small shapes; 1 core => flat scaling expected)
    let mut rng = Rng::new(1);
    let mut t = Table::new(
        "Fig. 4 (CPU analog) — in-tree grouped matmul, 64x64x64, wallclock per group member",
        &["group", "grouped (us/member)", "independent (us/member)"],
    );
    for g in [1usize, 2, 4, 8] {
        let x = Tensor::randn(&[g, 64, 64], 1.0, &mut rng);
        let w = Tensor::randn(&[g, 64, 64], 1.0, &mut rng);
        let sg = bench(&format!("grouped g={g}"), Duration::from_millis(120), || {
            std::hint::black_box(grouped_matmul(&x, &w));
        });
        let xs: Vec<Tensor> = (0..g).map(|i| x.index0(i)).collect();
        let ws: Vec<Tensor> = (0..g).map(|i| w.index0(i)).collect();
        let si = bench(&format!("indep g={g}"), Duration::from_millis(120), || {
            for i in 0..g {
                std::hint::black_box(matmul(&xs[i], &ws[i]));
            }
        });
        t.row(vec![
            g.to_string(),
            format!("{:.1}", sg.mean_s() * 1e6 / g as f64),
            format!("{:.1}", si.mean_s() * 1e6 / g as f64),
        ]);
    }
    t.print();
    println!("\nshape checks passed");
}
