//! Gateway admission fairness: weighted-fair scheduling vs FIFO under
//! a batch-tenant flood, plus token-bucket and API-key gates.
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `gateway_fairness`; this binary is the legacy `cargo bench`
//! entry point and is equivalent to
//! `diagonal-batching bench --suite gateway_fairness`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("gateway_fairness")
}
