//! Fig. 1 headline: 1B ARMT with Diagonal Batching vs vanilla LLaMA-1B —
//! latency and memory at 128k tokens (paper: 3.3x faster, 167.1x memory
//! savings on A100, seg 1024).

use diagonal_batching::bench::{fmt_s, fmt_x, Table};
use diagonal_batching::config::Manifest;
use diagonal_batching::simulator::tables::{fig1_rows, SEQ_LENS};
use diagonal_batching::simulator::DeviceSpec;

fn main() {
    let manifest = Manifest::load("artifacts/manifest.json").expect("make artifacts first");
    let base = manifest.any_config("llama-3.2-1b").unwrap();
    let dev = DeviceSpec::a100();
    let rows = fig1_rows(base, &dev, &SEQ_LENS);

    let mut t = Table::new(
        "Fig. 1 — LLaMA-1B: full attention vs ARMT + Diagonal Batching (seg 1024)",
        &["seq len", "llama (s)", "diag ARMT (s)", "speedup", "memory saving"],
    );
    for r in &rows {
        t.row(vec![
            r.seq_len.to_string(),
            fmt_s(r.llama_s),
            fmt_s(r.armt_diag_s),
            fmt_x(r.speedup),
            format!("{:.1}x", r.memory_saving),
        ]);
    }
    t.print();

    let last = rows.last().unwrap();
    assert_eq!(last.seq_len, 131072);
    assert!(last.speedup > 1.5, "128k speedup {}", last.speedup);
    assert!(last.memory_saving > 50.0, "memory saving {}", last.memory_saving);
    assert!(rows[0].speedup < 1.0, "short-context crossover must exist");
    println!(
        "\nheadline @128k: {} faster, {:.1}x memory (paper: x3.3, 167.1x — same regime)",
        fmt_x(last.speedup),
        last.memory_saving
    );
}
