//! Fig. 1 headline: 1B ARMT with Diagonal Batching vs vanilla LLaMA-1B.
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `fig1_headline`; this binary is the legacy `cargo bench` entry point
//! and is equivalent to `diagonal-batching bench --suite fig1_headline`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("fig1_headline")
}
