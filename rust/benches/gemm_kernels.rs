//! The tiered GEMM kernel layer, measured: cache-blocked SIMD f32 vs
//! the scalar oracle (bit-identical, timed in the same run), the
//! f16/bf16/int8 weight stores at a memory-bound serving size, and
//! achieved GFLOP/s against the measured CI-host roofline.
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `gemm_kernels`; this binary is the legacy `cargo bench` entry
//! point and is equivalent to
//! `diagonal-batching bench --suite gemm_kernels`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("gemm_kernels")
}
