//! Table 1: LLaMA-3.2-1B ARMT execution time vs sequence length.
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `table1_llama1b`; this binary is the legacy `cargo bench` entry point
//! and is equivalent to `diagonal-batching bench --suite table1_llama1b`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("table1_llama1b")
}
