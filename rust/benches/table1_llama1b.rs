//! Table 1: LLaMA-3.2-1B ARMT execution time vs sequence length, four
//! (segment_size, memory_tokens) configurations, A100 roofline model.
//!
//! Paper shape to reproduce: diagonal speedup over sequential ARMT grows
//! with sequence length, is largest for small segments (x2.7 at seg 512 /
//! 131k) and smallest for big segments (x1.1 at seg 4096), with the
//! short-sequence crossover where diagonal loses (x0.52 at 4096 tokens).

use diagonal_batching::bench::{fmt_s, fmt_x, Table};
use diagonal_batching::config::Manifest;
use diagonal_batching::simulator::tables::{exec_time_rows, SEQ_LENS};
use diagonal_batching::simulator::DeviceSpec;

fn main() {
    let manifest = Manifest::load("artifacts/manifest.json").expect("make artifacts first");
    let base = manifest.any_config("llama-3.2-1b").unwrap();
    let dev = DeviceSpec::a100();

    for (seg, mem) in [(512usize, 128usize), (1024, 128), (2048, 128), (4096, 128)] {
        let rows = exec_time_rows(base, &dev, seg, mem, &SEQ_LENS);
        let mut t = Table::new(
            &format!("Table 1 — LLama-3.2-1B, configuration ({seg}, {mem}) [simulated {}]", dev.name),
            &["method", "4096", "8192", "16384", "32768", "65536", "131072"],
        );
        t.row(std::iter::once("Llama-3.2-1B".into())
            .chain(rows.iter().map(|r| fmt_s(r.llama_s))).collect());
        t.row(std::iter::once("LLama-3.2-1B-ARMT".into())
            .chain(rows.iter().map(|r| fmt_s(r.armt_seq_s))).collect());
        t.row(std::iter::once("Diagonal Batching".into())
            .chain(rows.iter().map(|r| fmt_s(r.armt_diag_s))).collect());
        t.row(std::iter::once("speedup".into())
            .chain(rows.iter().map(|r| fmt_x(r.speedup_vs_armt()))).collect());
        t.print();

        // Shape assertions (who wins / where): the bench doubles as a
        // regression test of the reproduction claims.
        let last = rows.last().unwrap();
        assert!(last.speedup_vs_armt() > 1.0, "diag must win at 131k (seg {seg})");
        assert!(
            rows[0].speedup_vs_armt() < last.speedup_vs_armt(),
            "speedup must grow with length"
        );
    }
    // paper: smaller segments benefit more
    let s512 = exec_time_rows(base, &dev, 512, 128, &[131072])[0].speedup_vs_armt();
    let s4096 = exec_time_rows(base, &dev, 4096, 128, &[131072])[0].speedup_vs_armt();
    assert!(s512 > s4096);
    println!("\nshape checks passed: speedup grows with length; seg 512 ({}) > seg 4096 ({})",
        fmt_x(s512), fmt_x(s4096));
}
