//! Fig. 5: attention throughput rises with batch size — diagonal
//! batching gets the same effect by treating the group as the batch
//! (§4.2, "our method does not modify the attention layer at all").

use diagonal_batching::bench::Table;
use diagonal_batching::config::Manifest;
use diagonal_batching::simulator::tables::fig5_attention_rows;
use diagonal_batching::simulator::DeviceSpec;

fn main() {
    let manifest = Manifest::load("artifacts/manifest.json").expect("make artifacts first");
    let base = manifest.any_config("llama-3.2-1b").unwrap();
    let dev = DeviceSpec::a100();
    let batches = [1usize, 2, 4, 8, 16, 32];

    for t_len in [640usize, 1152, 2176, 4224] {
        let rows = fig5_attention_rows(&dev, base, t_len, &batches);
        let mut t = Table::new(
            &format!(
                "Fig. 5 — attention relative FLOPS vs batch (T = {t_len}) [simulated {}]",
                dev.name
            ),
            &["batch", "relative FLOPS"],
        );
        for (b, rel) in &rows {
            t.row(vec![b.to_string(), format!("{rel:.2}x")]);
        }
        t.print();
        assert!((rows[0].1 - 1.0).abs() < 1e-9);
        for w in rows.windows(2) {
            assert!(w[1].1 >= w[0].1 * 0.98, "monotone in batch");
        }
        // small segments leave more headroom: batch-16 gain shrinks with T
    }
    let small = fig5_attention_rows(&dev, base, 640, &batches)[4].1;
    let large = fig5_attention_rows(&dev, base, 4224, &batches)[4].1;
    assert!(
        small >= large * 0.95,
        "short segments should gain at least as much from batching ({small} vs {large})"
    );
    println!("\nshape checks passed");
}
