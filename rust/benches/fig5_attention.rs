//! Fig. 5: attention throughput rises with batch size.
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `fig5_attention`; this binary is the legacy `cargo bench` entry point
//! and is equivalent to `diagonal-batching bench --suite fig5_attention`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("fig5_attention")
}
