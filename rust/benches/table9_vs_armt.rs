//! Table 9: Diagonal-Batching speedup over the sequential ARMT, plus the
//! runtime-fallback demonstration the table's caption calls out ("in
//! cases when diagonal batching is slower, we can fall back to the
//! original inference algorithm at runtime").
//!
//! Two parts:
//!  1. the simulated A100 table (paper shape: x0.5-x0.8 at 4k where the
//!     fallback triggers, up to x2.7 at 131k);
//!  2. a MEASURED fallback check on the real PJRT CPU backend: the
//!     engine's calibrated Auto policy picks sequential for short
//!     requests and diagonal for long ones on the launch-bound micro
//!     model.

use diagonal_batching::bench::{fmt_x, Table};
use diagonal_batching::config::{ExecMode, Manifest};
use diagonal_batching::coordinator::{InferenceEngine, Request};
use diagonal_batching::runtime::HloBackend;
use diagonal_batching::simulator::tables::{exec_time_rows, SEQ_LENS};
use diagonal_batching::simulator::DeviceSpec;

fn main() {
    let manifest = Manifest::load("artifacts/manifest.json").expect("make artifacts first");
    let base = manifest.any_config("llama-3.2-1b").unwrap();
    let dev = DeviceSpec::a100();

    let mut t = Table::new(
        "Table 9 — Diagonal Batching speedup vs sequential ARMT (LLama-3.2-1B)",
        &["configuration", "4096", "8192", "16384", "32768", "65536", "131072"],
    );
    for seg in [512usize, 1024, 2048, 4096] {
        let rows = exec_time_rows(base, &dev, seg, 128, &SEQ_LENS);
        t.row(
            std::iter::once(format!("({seg}, 128)"))
                .chain(rows.iter().map(|r| fmt_x(r.speedup_vs_armt())))
                .collect(),
        );
    }
    t.print();

    // ---- measured fallback policy on the real backend --------------------
    println!("\nfallback policy (measured, micro model on PJRT CPU):");
    let backend = HloBackend::load(&manifest, "micro").unwrap();
    let mut engine = InferenceEngine::new(backend, ExecMode::Auto);
    let cal = engine.calibrate(5).unwrap();
    println!(
        "  calibrated: grouped {:.3} ms, single {:.3} ms, crossover {} segments",
        cal.grouped_step_s * 1e3,
        cal.single_step_s * 1e3,
        cal.crossover_segments()
    );
    let seg = engine.config().seg;
    let vocab = engine.config().vocab as u32;
    for n_segments in [1usize, 2, 64] {
        let tokens: Vec<u32> = (0..n_segments * seg).map(|i| i as u32 % vocab).collect();
        let resp = engine.process(&Request::new(n_segments as u64, tokens)).unwrap();
        println!(
            "  {n_segments:>3} segments -> {} ({:?})",
            resp.mode_used, resp.stats.wall
        );
        if n_segments >= 64 {
            assert_eq!(resp.mode_used, ExecMode::Diagonal, "long request must go diagonal");
        }
    }
    println!("\nshape checks passed");
}
