//! Table 9: speedup vs sequential ARMT + the runtime-fallback demonstration.
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `table9_vs_armt`; this binary is the legacy `cargo bench` entry point
//! and is equivalent to `diagonal-batching bench --suite table9_vs_armt`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("table9_vs_armt")
}
