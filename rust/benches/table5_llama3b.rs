//! Table 5: llama-3.2-3b ARMT execution time vs sequence length.
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `table5_llama3b`; this binary is the legacy `cargo bench` entry point
//! and is equivalent to `diagonal-batching bench --suite table5_llama3b`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("table5_llama3b")
}
