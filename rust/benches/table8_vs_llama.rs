//! Table 8: Diagonal-Batching ARMT speedup over the vanilla
//! full-attention LLaMA-3.2-1B across sequence lengths and segment
//! configurations. Paper shape: ARMT loses or ties at short lengths
//! (quadratic attention is still cheap) and wins increasingly at long
//! lengths (linear vs quadratic), up to ~3.9x at 131k for seg 4096.

use diagonal_batching::bench::{fmt_x, Table};
use diagonal_batching::config::Manifest;
use diagonal_batching::simulator::tables::{exec_time_rows, SEQ_LENS};
use diagonal_batching::simulator::DeviceSpec;

fn main() {
    let manifest = Manifest::load("artifacts/manifest.json").expect("make artifacts first");
    let base = manifest.any_config("llama-3.2-1b").unwrap();
    let dev = DeviceSpec::a100();

    let mut t = Table::new(
        "Table 8 — Diagonal Batching speedup vs LLama-3.2-1B (full attention)",
        &["configuration", "4096", "8192", "16384", "32768", "65536", "131072"],
    );
    let mut growth_ok = true;
    let mut long_ctx_win = false;
    for seg in [512usize, 1024, 2048, 4096] {
        let rows = exec_time_rows(base, &dev, seg, 128, &SEQ_LENS);
        t.row(
            std::iter::once(format!("({seg}, 128)"))
                .chain(rows.iter().map(|r| fmt_x(r.speedup_vs_llama())))
                .collect(),
        );
        let sp: Vec<f64> = rows.iter().map(|r| r.speedup_vs_llama()).collect();
        growth_ok &= sp.windows(2).all(|w| w[1] >= w[0] * 0.98);
        long_ctx_win |= sp.last().unwrap() > &1.5;
    }
    t.print();
    assert!(growth_ok, "speedup vs llama must grow with length");
    assert!(long_ctx_win, "ARMT must clearly beat full attention at 131k");
    println!("\nshape checks passed: monotone growth, long-context win");
}
