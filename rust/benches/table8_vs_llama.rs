//! Table 8: Diagonal-Batching ARMT speedup over full-attention LLaMA-1B.
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `table8_vs_llama`; this binary is the legacy `cargo bench` entry point
//! and is equivalent to `diagonal-batching bench --suite table8_vs_llama`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("table8_vs_llama")
}
