//! Multi-client generation burst through `serve_queue`: packed
//! in-wavefront decode vs the best solo diagonal run, with bit-exact
//! continuations as a hard gate.
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `serve_generate`; this binary is the legacy `cargo bench` entry
//! point and is equivalent to `diagonal-batching bench --suite serve_generate`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("serve_generate")
}
