//! Memory-state prefix cache under a shared-prefix burst: hit rate,
//! prefill cells saved, and bit-exact outputs vs. a cold run.
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `cache_reuse`; this binary is the legacy `cargo bench` entry
//! point and is equivalent to `diagonal-batching bench --suite cache_reuse`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("cache_reuse")
}
