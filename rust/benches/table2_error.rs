//! Table 2: error accumulation of Diagonal Batching vs the sequential
//! ARMT execution — MEASURED on the real PJRT artifacts (not simulated).
//!
//! Paper: relative Frobenius drift of the logits stays < 2% out to 32
//! segments. On the CPU PJRT backend XLA compiles the grouped and single
//! programs to the same reduction orders, so the diag-vs-seq drift is
//! ~0 — *tighter* than the paper's CUDA kernels. The native-oracle
//! column shows the f32 cross-implementation drift for scale.

use diagonal_batching::bench::Table;
use diagonal_batching::config::Manifest;
use diagonal_batching::model::{NativeBackend, Params};
use diagonal_batching::runtime::HloBackend;
use diagonal_batching::scheduler::{Executor, ScheduleMode, StepBackend};
use diagonal_batching::tensor::Rng;

fn main() {
    let manifest = Manifest::load("artifacts/manifest.json").expect("make artifacts first");
    let mut hlo = HloBackend::load(&manifest, "tiny").unwrap();
    let cfg = hlo.config().clone();
    let params = Params::load(&manifest, "tiny").unwrap();
    let mut native = NativeBackend::new(cfg.clone(), params);

    let mut t = Table::new(
        "Table 2 — relative logits error (%) vs number of segments (tiny model, PJRT CPU)",
        &["segments", "diag vs seq (HLO)", "HLO vs native oracle", "argmax agreement %"],
    );

    let mut rng = Rng::new(2024);
    for n_segments in [1usize, 2, 4, 8, 16, 32] {
        let tokens: Vec<u32> =
            (0..n_segments * cfg.seg).map(|_| rng.below(cfg.vocab) as u32).collect();
        let d = Executor::new(&mut hlo, ScheduleMode::Diagonal).run(&tokens).unwrap();
        let s = Executor::new(&mut hlo, ScheduleMode::Sequential).run(&tokens).unwrap();
        let n = Executor::new(&mut native, ScheduleMode::Sequential).run(&tokens).unwrap();
        let ds = d.stacked().unwrap();
        let ss = s.stacked().unwrap();
        let ns = n.stacked().unwrap();
        let rel_hlo = ds.rel_error(&ss);
        let rel_native = ds.rel_error(&ns);
        let (ad, asq) = (ds.argmax_rows(), ss.argmax_rows());
        let agree =
            ad.iter().zip(&asq).filter(|(x, y)| x == y).count() as f64 / ad.len() as f64;
        t.row(vec![
            n_segments.to_string(),
            format!("{:.5}", rel_hlo * 100.0),
            format!("{:.5}", rel_native * 100.0),
            format!("{:.2}", agree * 100.0),
        ]);
        assert!(rel_hlo < 0.02, "paper bound: < 2% at S={n_segments}");
        assert!(agree > 0.99);
    }
    t.print();
    println!("\nall rows under the paper's 2% bound (CPU-PJRT reduction orders are");
    println!("deterministic, so drift is far below the paper's CUDA measurement).");
}
