//! Table 2: diagonal-vs-sequential logits drift, MEASURED on PJRT artifacts.
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `table2_error`; this binary is the legacy `cargo bench` entry point
//! and is equivalent to `diagonal-batching bench --suite table2_error`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("table2_error")
}
