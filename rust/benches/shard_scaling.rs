//! Sharded serving scaling: lane x1/x2 and layer-split coordinator
//! topologies vs the 1-process engine, over real TCP on localhost.
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `shard_scaling`; this binary is the legacy `cargo bench` entry
//! point and is equivalent to `diagonal-batching bench --suite shard_scaling`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("shard_scaling")
}
