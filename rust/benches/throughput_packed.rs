//! Packed-wavefront serving throughput vs serial per-request diagonal.
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `throughput_packed`; this binary is the legacy `cargo bench` entry point
//! and is equivalent to `diagonal-batching bench --suite throughput_packed`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("throughput_packed")
}
