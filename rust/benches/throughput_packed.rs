//! Packed-wavefront serving throughput: 8 concurrent short requests
//! through one `WavefrontSession` vs the same requests run serially,
//! each as its own diagonal wavefront (the pre-packing serving path).
//!
//! Runs entirely on the native backend — no artifacts needed — because
//! the quantity under test is the *scheduler's* utilization: launches,
//! mean group size and occupancy. On a GPU backend the mean-group gain
//! converts to wallclock via the paper's Fig. 4/5 batching curves; on
//! one CPU core wallclock is flat (same cell count either way), which
//! the table makes visible rather than hiding.
//!
//! Self-checking: asserts the ISSUE's acceptance shape — the packed
//! session's mean group strictly beats serial per-request diagonal for
//! >= 2 concurrent requests, and padded cells per request shrink.

use std::time::Instant;

use diagonal_batching::bench::Table;
use diagonal_batching::config::ModelConfig;
use diagonal_batching::model::{NativeBackend, Params};
use diagonal_batching::scheduler::{Executor, RunStats, ScheduleMode, WavefrontSession};
use diagonal_batching::tensor::Rng;

fn bench_config() -> ModelConfig {
    ModelConfig {
        name: "packed-bench".into(),
        vocab: 64,
        d_model: 32,
        n_layers: 4,
        n_heads: 2,
        d_ff: 48,
        seg: 8,
        mem: 4,
        k_assoc: 8,
        dpfp_nu: 3,
        rope_theta: 10000.0,
        eps: 1e-6,
        attn_buckets: vec![],
        head_dim: 16,
        phi_dim: 48,
        seg_total: 12,
    }
}

fn requests(cfg: &ModelConfig, n: usize, segments: usize) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(2024);
    (0..n)
        .map(|_| (0..segments * cfg.seg).map(|_| rng.below(cfg.vocab) as u32).collect())
        .collect()
}

struct Row {
    label: String,
    stats: RunStats,
    wall_s: f64,
    tokens: usize,
}

fn serial_diagonal(cfg: &ModelConfig, reqs: &[Vec<u32>]) -> Row {
    let mut backend = NativeBackend::new(cfg.clone(), Params::random(cfg, 7));
    let t0 = Instant::now();
    let mut agg = RunStats { mode_diagonal: true, ..RunStats::default() };
    for toks in reqs {
        let out = Executor::new(&mut backend, ScheduleMode::Diagonal).run(toks).unwrap();
        agg.segments += out.stats.segments;
        agg.launches += out.stats.launches;
        agg.cells += out.stats.cells;
        agg.slot_steps += out.stats.slot_steps;
        agg.padded_cells += out.stats.padded_cells;
        agg.tokens += out.stats.tokens;
    }
    Row {
        label: "serial per-request diagonal".into(),
        wall_s: t0.elapsed().as_secs_f64(),
        tokens: agg.tokens,
        stats: agg,
    }
}

fn packed(cfg: &ModelConfig, reqs: &[Vec<u32>], lanes: usize) -> Row {
    let mut backend = NativeBackend::new(cfg.clone(), Params::random(cfg, 7));
    let mut session = WavefrontSession::new(cfg.clone(), lanes);
    let t0 = Instant::now();
    for (i, toks) in reqs.iter().enumerate() {
        session.submit(i as u64, toks).unwrap();
    }
    session.run_to_completion(&mut backend).unwrap();
    assert_eq!(session.drain_completed().len(), reqs.len());
    let stats = session.stats();
    Row {
        label: format!("packed session, {lanes} lane{}", if lanes == 1 { "" } else { "s" }),
        wall_s: t0.elapsed().as_secs_f64(),
        tokens: stats.tokens,
        stats,
    }
}

fn main() {
    let cfg = bench_config();
    let n_requests = 8;
    let segments = 6;
    let reqs = requests(&cfg, n_requests, segments);

    let rows = vec![
        serial_diagonal(&cfg, &reqs),
        packed(&cfg, &reqs, 1),
        packed(&cfg, &reqs, 2),
        packed(&cfg, &reqs, 4),
    ];

    let mut t = Table::new(
        &format!(
            "{n_requests} concurrent requests x {segments} segments (L = {}): \
             packed wavefront vs serial diagonal",
            cfg.n_layers
        ),
        &[
            "schedule",
            "launches",
            "mean group",
            "padded cells",
            "occupancy",
            "padded/request",
            "tokens/s",
        ],
    );
    for r in &rows {
        t.row(vec![
            r.label.clone(),
            r.stats.launches.to_string(),
            format!("{:.2}", r.stats.mean_group()),
            r.stats.padded_cells.to_string(),
            format!("{:.3}", r.stats.occupancy()),
            format!("{:.1}", r.stats.padded_cells as f64 / n_requests as f64),
            format!("{:.0}", r.tokens as f64 / r.wall_s),
        ]);
    }
    t.print();

    // Acceptance shape: packing >= 2 concurrent requests beats serial
    // per-request diagonal on mean group / padded cells per request.
    let serial = &rows[0];
    for packed_row in &rows[1..] {
        assert!(
            packed_row.stats.mean_group() > serial.stats.mean_group(),
            "{}: mean group {:.3} must beat serial {:.3}",
            packed_row.label,
            packed_row.stats.mean_group(),
            serial.stats.mean_group()
        );
        assert!(
            packed_row.stats.padded_cells < serial.stats.padded_cells,
            "{}: padded {} must be below serial {}",
            packed_row.label,
            packed_row.stats.padded_cells,
            serial.stats.padded_cells
        );
        assert_eq!(packed_row.stats.cells, serial.stats.cells, "same work either way");
    }
    println!("\nOK: cross-request packing raised mean group and cut padded cells per request");
}
