//! Fig. 6: time per segment — diagonal batching vs mini-batching of b
//! independent sequences vs the Ideal Even Load upper bound, per model.
//!
//! Paper shape: diagonal batching (a SINGLE sequence) matches the
//! per-sequence cost of mini-batching at moderate batch sizes, and the
//! ideal even load lower-bounds everything.

use diagonal_batching::bench::{fmt_s, Table};
use diagonal_batching::config::Manifest;
use diagonal_batching::simulator::tables::fig6_rows;
use diagonal_batching::simulator::DeviceSpec;

fn main() {
    let manifest = Manifest::load("artifacts/manifest.json").expect("make artifacts first");
    let dev = DeviceSpec::a100();
    let batches = [1usize, 2, 4, 8, 16];

    for model in ["llama-160m", "llama-3.2-1b", "llama-3.2-3b", "llama-3.1-8b"] {
        let base = manifest.any_config(model).unwrap();
        let rows = fig6_rows(base, &dev, 1024, 128, 32, &batches);
        let mut t = Table::new(
            &format!("Fig. 6 — time per segment, {model} (seg 1024, 32 segments)"),
            &["batch", "minibatch (s/seq-seg)", "diagonal (s/seg)", "ideal (s/seg)"],
        );
        for r in &rows {
            t.row(vec![
                r.batch.to_string(),
                fmt_s(r.minibatch_s),
                fmt_s(r.diagonal_s),
                fmt_s(r.ideal_s),
            ]);
        }
        t.print();

        let b1 = &rows[0];
        assert!(
            b1.diagonal_s < b1.minibatch_s,
            "{model}: diagonal must beat unbatched sequential per-segment time"
        );
        assert!(b1.ideal_s <= b1.diagonal_s * 1.02, "{model}: ideal is the bound");
        // minibatch per-sequence time improves with batch; once the batch
        // exceeds L it can pass the L-wide "ideal even load" line (more
        // parallel work than the diagonal can ever expose), so the bound
        // only applies while batch <= n_layers.
        let blast = rows.last().unwrap();
        assert!(blast.minibatch_s < b1.minibatch_s);
        if blast.batch <= base.n_layers {
            assert!(blast.minibatch_s >= blast.ideal_s * 0.90);
        }
    }
    println!("\nshape checks passed");
}
