//! Fig. 6: time per segment — diagonal vs mini-batching vs ideal even load.
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `fig6_diag_vs_minibatch`; this binary is the legacy `cargo bench` entry point
//! and is equivalent to `diagonal-batching bench --suite fig6_diag_vs_minibatch`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("fig6_diag_vs_minibatch")
}
