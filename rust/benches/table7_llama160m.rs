//! Table 7: llama-160m ARMT execution time vs sequence length.
//!
//! The suite body lives in `diagonal_batching::bench::suites` under the
//! name `table7_llama160m`; this binary is the legacy `cargo bench` entry point
//! and is equivalent to `diagonal-batching bench --suite table7_llama160m`.

use std::process::ExitCode;

fn main() -> ExitCode {
    diagonal_batching::bench::run_suite_main("table7_llama160m")
}
