//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The runtime layer (`diagonal_batching::runtime`) is written against the
//! real `xla` crate's API: a PJRT client, compiled executables, device
//! buffers and literals. That crate links against a system XLA/PJRT build
//! that the offline toolchain does not ship, so this package provides the
//! same surface with two behaviors:
//!
//! * **[`Literal`] is real**: host-side literal construction, reshape and
//!   readback work exactly (they are plain byte buffers), so the
//!   `runtime::convert` helpers and their tests run everywhere;
//! * **execution is unavailable**: [`PjRtClient::cpu`] returns an error,
//!   so every HLO-backed path reports "PJRT unavailable" instead of
//!   executing.
//!
//! ## What skips under the stub, and why
//!
//! Everything artifact-dependent guards on `artifacts/manifest.json`
//! (produced by `make artifacts`, which needs the python build side) and
//! skips cleanly when it is absent:
//!
//! * `rust/tests/hlo_parity.rs` — every test (HLO vs native logits
//!   parity needs an executing PJRT client);
//! * `rust/tests/e2e_serving.rs` — only
//!   `serve_hlo_backend_if_artifacts_present`; the rest of the serving
//!   suite runs on the native backend everywhere;
//! * `rust/tests/babilong_integration.rs` — only the `toy`-bundle
//!   parity case;
//! * bench suites tagged `hlo` (`hotpath`, `table2_error` and the
//!   measured half of `table9_vs_armt`) — they report status `skipped`
//!   in `BENCH_*.json` instead of failing.
//!
//! Note the guard is on the *manifest*, not on PJRT itself: with the
//! artifacts present but this stub linked, `HloBackend::load` fails at
//! client construction and those tests fail loudly rather than skip —
//! intentionally, so a misconfigured "real" build cannot silently pass
//! by skipping its coverage.
//!
//! Swapping in the real bindings is a one-line change in
//! `rust/Cargo.toml` (point the `xla` dependency at the actual crate); no
//! source in `diagonal_batching` changes.

use std::fmt;
use std::path::Path;

/// Error type matching the real crate's (stringly) error surface.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT is unavailable in this offline build (xla-stub); \
         use the native backend or link the real xla crate"
    )))
}

/// Array shape of a (non-tuple) literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host literal: raw bytes + dims + element width. Fully functional.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<u8>,
    dims: Vec<i64>,
    elem_bytes: usize,
}

impl Literal {
    /// Rank-1 literal over a copyable element type (f32/i32 in practice).
    pub fn vec1<T: Copy>(data: &[T]) -> Literal {
        let elem_bytes = std::mem::size_of::<T>();
        // SAFETY: T is Copy and we only reinterpret its bytes for storage;
        // readback via `to_vec` checks the element width before the
        // reverse cast.
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
        };
        Literal { data: bytes.to_vec(), dims: vec![data.len() as i64], elem_bytes }
    }

    /// Reinterpret with new dims of equal element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n != self.element_count() as i64 {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec(), elem_bytes: self.elem_bytes })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn element_count(&self) -> usize {
        if self.elem_bytes == 0 {
            0
        } else {
            self.data.len() / self.elem_bytes
        }
    }

    /// Read the literal back as a typed vector.
    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        let w = std::mem::size_of::<T>();
        if w != self.elem_bytes {
            return Err(Error(format!(
                "to_vec element width {w} != literal width {}",
                self.elem_bytes
            )));
        }
        let n = self.element_count();
        let mut out = Vec::with_capacity(n);
        // SAFETY: width checked above; the buffer was produced from a
        // slice of the same element width.
        unsafe {
            let src = self.data.as_ptr() as *const T;
            for i in 0..n {
                out.push(*src.add(i));
            }
        }
        Ok(out)
    }

    /// Tuple destructuring. Stub literals are always arrays (tuples only
    /// come out of execution, which the stub cannot do).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Parsed HLO module (opaque; compilation is unavailable offline).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error(format!("read {}: {e}", path.as_ref().display())))?;
        Ok(HloModuleProto { _text: text })
    }
}

/// Computation wrapper (opaque).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle. Never constructible offline.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle. Never constructible offline.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// PJRT client. `cpu()` fails fast in the stub, which is the single gate
/// every HLO-backed code path funnels through.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(lit.element_count(), 6);
        let r = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(lit.reshape(&[7]).is_err());
    }

    #[test]
    fn literal_width_checked() {
        let lit = Literal::vec1(&[1i32, 2, 3]);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
        assert!(lit.to_vec::<f64>().is_err());
    }

    #[test]
    fn execution_paths_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        let lit = Literal::vec1(&[0.0f32]);
        assert!(lit.to_tuple().is_err());
    }
}
