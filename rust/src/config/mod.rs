//! Configuration: the AOT manifest contract and runtime settings.
//!
//! `artifacts/manifest.json` is produced by `python -m compile.aot` and is
//! the single source of truth for model dims, executable I/O shapes and
//! the params.bin layout. Parsing uses the in-tree [`crate::json`] module
//! (the offline toolchain has no serde).

mod runtime_cfg;

pub use runtime_cfg::{BackendKind, ExecMode, RuntimeConfig};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::json::Value;

/// Mirror of python `ArmtConfig` (see `python/compile/configs.py`).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    /// Tokens per segment (paper: `segment_size`).
    pub seg: usize,
    /// Memory tokens appended to each segment.
    pub mem: usize,
    /// Associative key dim (paper: associative memory hidden size).
    pub k_assoc: usize,
    pub dpfp_nu: usize,
    pub rope_theta: f32,
    pub eps: f32,
    pub attn_buckets: Vec<usize>,
    pub head_dim: usize,
    /// DPFP feature dim p = 2 * nu * k_assoc.
    pub phi_dim: usize,
    /// seg + mem.
    pub seg_total: usize,
}

impl ModelConfig {
    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            name: v.req("name")?.as_str()?.to_string(),
            vocab: v.req("vocab")?.as_usize()?,
            d_model: v.req("d_model")?.as_usize()?,
            n_layers: v.req("n_layers")?.as_usize()?,
            n_heads: v.req("n_heads")?.as_usize()?,
            d_ff: v.req("d_ff")?.as_usize()?,
            seg: v.req("seg")?.as_usize()?,
            mem: v.req("mem")?.as_usize()?,
            k_assoc: v.req("k_assoc")?.as_usize()?,
            dpfp_nu: v.req("dpfp_nu")?.as_usize()?,
            rope_theta: v.req("rope_theta")?.as_f32()?,
            eps: v.req("eps")?.as_f32()?,
            attn_buckets: v
                .get("attn_buckets")
                .map(Value::as_usize_vec)
                .transpose()?
                .unwrap_or_default(),
            head_dim: v.req("head_dim")?.as_usize()?,
            phi_dim: v.req("phi_dim")?.as_usize()?,
            seg_total: v.req("seg_total")?.as_usize()?,
        })
    }

    /// Sanity-check internal consistency (defends against a stale or
    /// hand-edited manifest).
    pub fn validate(&self) -> Result<()> {
        let fail = |msg: String| Err(Error::Config(msg));
        if self.d_model % self.n_heads != 0 {
            return fail(format!("d_model {} % n_heads {}", self.d_model, self.n_heads));
        }
        if self.head_dim != self.d_model / self.n_heads {
            return fail("head_dim mismatch".into());
        }
        if self.phi_dim != 2 * self.dpfp_nu * self.k_assoc {
            return fail("phi_dim mismatch".into());
        }
        if self.seg_total != self.seg + self.mem {
            return fail("seg_total mismatch".into());
        }
        if self.n_layers == 0 || self.seg == 0 || self.mem == 0 {
            return fail("zero-sized dimension".into());
        }
        Ok(())
    }

    /// Total parameter count (simulator memory model; includes both the
    /// embedding and the output head).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let f = self.d_ff;
        let k = self.k_assoc;
        let per_layer = 4 * d * d + 2 * d * f + f * d + 2 * d + 2 * d * k + d * d + d;
        self.n_layers * per_layer + 2 * self.vocab * d + self.mem * d + d
    }

    /// Per-layer associative state floats: A [d, p] + z [p].
    pub fn state_floats_per_layer(&self) -> usize {
        self.d_model * self.phi_dim + self.phi_dim
    }

    /// A small built-in config for artifact-free runs (demos, the CI
    /// serving smoke test): pair it with randomly initialized native
    /// params (`serve --synthetic SEED`). Untrained — outputs are
    /// gibberish but every scheduling/serving property holds.
    pub fn synthetic() -> Self {
        Self {
            name: "synthetic".into(),
            vocab: 64,
            d_model: 32,
            n_layers: 3,
            n_heads: 2,
            d_ff: 48,
            seg: 8,
            mem: 4,
            k_assoc: 8,
            dpfp_nu: 3,
            rope_theta: 10000.0,
            eps: 1e-6,
            attn_buckets: vec![],
            head_dim: 16,
            phi_dim: 48,
            seg_total: 12,
        }
    }
}

/// One stacked parameter's location inside params.bin.
#[derive(Clone, Debug)]
pub struct ParamIndex {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_elems: usize,
    pub size_elems: usize,
}

/// One input or output of an executable.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            name: v.req("name")?.as_str()?.to_string(),
            shape: v.req("shape")?.as_usize_vec()?,
            dtype: v.req("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One AOT-lowered HLO program.
#[derive(Clone, Debug)]
pub struct ExeEntry {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub hlo_bytes: usize,
}

/// One model's artifact bundle.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub dir: String,
    pub impl_: String,
    pub trained: bool,
    pub config: ModelConfig,
    pub params_bin: String,
    pub params: Vec<ParamIndex>,
    pub executables: HashMap<String, ExeEntry>,
}

impl ModelEntry {
    fn from_json(v: &Value) -> Result<Self> {
        let params = v
            .req("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamIndex {
                    name: p.req("name")?.as_str()?.to_string(),
                    shape: p.req("shape")?.as_usize_vec()?,
                    offset_elems: p.req("offset_elems")?.as_usize()?,
                    size_elems: p.req("size_elems")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut executables = HashMap::new();
        for (name, e) in v.req("executables")?.as_obj()? {
            executables.insert(
                name.clone(),
                ExeEntry {
                    file: e.req("file")?.as_str()?.to_string(),
                    inputs: e
                        .req("inputs")?
                        .as_arr()?
                        .iter()
                        .map(IoSpec::from_json)
                        .collect::<Result<_>>()?,
                    outputs: e
                        .req("outputs")?
                        .as_arr()?
                        .iter()
                        .map(IoSpec::from_json)
                        .collect::<Result<_>>()?,
                    hlo_bytes: e.get("hlo_bytes").map(Value::as_usize).transpose()?.unwrap_or(0),
                },
            );
        }
        Ok(Self {
            dir: v.req("dir")?.as_str()?.to_string(),
            impl_: v.req("impl")?.as_str()?.to_string(),
            trained: v.get("trained").map(Value::as_bool).transpose()?.unwrap_or(false),
            config: ModelConfig::from_json(v.req("config")?)?,
            params_bin: v.req("params_bin")?.as_str()?.to_string(),
            params,
            executables,
        })
    }
}

/// Shared BABILong-style task token layout (DESIGN.md substitution #3).
#[derive(Clone, Debug)]
pub struct BabilongSpec {
    pub pad: u32,
    pub bos: u32,
    pub query: u32,
    pub sep: u32,
    pub agent_base: u32,
    pub n_agents: u32,
    pub place_base: u32,
    pub n_places: u32,
    pub object_base: u32,
    pub n_objects: u32,
    pub filler_base: u32,
    pub n_filler: u32,
}

impl BabilongSpec {
    fn from_json(v: &Value) -> Result<Self> {
        let g = |k: &str| -> Result<u32> { v.req(k)?.as_u32() };
        Ok(Self {
            pad: g("pad")?,
            bos: g("bos")?,
            query: g("query")?,
            sep: g("sep")?,
            agent_base: g("agent_base")?,
            n_agents: g("n_agents")?,
            place_base: g("place_base")?,
            n_places: g("n_places")?,
            object_base: g("object_base")?,
            n_objects: g("n_objects")?,
            filler_base: g("filler_base")?,
            n_filler: g("n_filler")?,
        })
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub format_version: usize,
    pub impl_: String,
    pub models: HashMap<String, ModelEntry>,
    pub paper_configs: HashMap<String, ModelConfig>,
    pub babilong: BabilongSpec,
    /// Directory the manifest was loaded from (for resolving artifact
    /// paths); not part of the JSON.
    pub root: PathBuf,
}

impl Manifest {
    /// Load and validate `manifest.json`.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)?;
        let v = Value::parse(&text)?;
        let mut models = HashMap::new();
        for (name, m) in v.req("models")?.as_obj()? {
            models.insert(name.clone(), ModelEntry::from_json(m)?);
        }
        let mut paper_configs = HashMap::new();
        for (name, c) in v.req("paper_configs")?.as_obj()? {
            paper_configs.insert(name.clone(), ModelConfig::from_json(c)?);
        }
        let m = Manifest {
            format_version: v.req("format_version")?.as_usize()?,
            impl_: v.req("impl")?.as_str()?.to_string(),
            models,
            paper_configs,
            babilong: BabilongSpec::from_json(v.req("babilong")?)?,
            root: path.parent().unwrap_or(Path::new(".")).to_path_buf(),
        };
        for entry in m.models.values() {
            entry.config.validate()?;
        }
        for cfg in m.paper_configs.values() {
            cfg.validate()?;
        }
        Ok(m)
    }

    /// Look up an executable model bundle by name.
    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| Error::Missing(format!("model '{name}' in manifest")))
    }

    /// Look up a paper config (simulator-only) OR an executable config.
    pub fn any_config(&self, name: &str) -> Result<&ModelConfig> {
        self.models
            .get(name)
            .map(|e| &e.config)
            .or_else(|| self.paper_configs.get(name))
            .ok_or_else(|| Error::Missing(format!("config '{name}'")))
    }

    /// Absolute path of an artifact file referenced by a model entry.
    pub fn artifact_path(&self, entry: &ModelEntry, file: &str) -> PathBuf {
        self.root.join(&entry.dir).join(file)
    }

    /// Absolute path of a model's params.bin.
    pub fn params_path(&self, entry: &ModelEntry) -> PathBuf {
        self.root.join(&entry.params_bin)
    }
}

/// Default manifest location relative to the repo root.
pub const DEFAULT_MANIFEST: &str = "artifacts/manifest.json";

#[cfg(test)]
pub(crate) fn test_model_config() -> ModelConfig {
    ModelConfig {
        name: "t".into(),
        vocab: 512,
        d_model: 64,
        n_layers: 4,
        n_heads: 4,
        d_ff: 128,
        seg: 32,
        mem: 8,
        k_assoc: 16,
        dpfp_nu: 3,
        rope_theta: 10000.0,
        eps: 1e-6,
        attn_buckets: vec![],
        head_dim: 16,
        phi_dim: 96,
        seg_total: 40,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_accepts_consistent() {
        assert!(test_model_config().validate().is_ok());
        assert!(ModelConfig::synthetic().validate().is_ok());
    }

    #[test]
    fn validate_rejects_bad_phi() {
        let mut c = test_model_config();
        c.phi_dim = 95;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_heads() {
        let mut c = test_model_config();
        c.n_heads = 5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn param_count_monotone_in_layers() {
        let c = test_model_config();
        let mut c2 = c.clone();
        c2.n_layers = 8;
        assert!(c2.param_count() > c.param_count());
    }

    #[test]
    fn config_from_json() {
        let src = r#"{
            "name": "x", "vocab": 512, "d_model": 64, "n_layers": 4,
            "n_heads": 4, "d_ff": 128, "seg": 32, "mem": 8, "k_assoc": 16,
            "dpfp_nu": 3, "rope_theta": 10000.0, "eps": 1e-6,
            "attn_buckets": [128], "head_dim": 16, "phi_dim": 96,
            "seg_total": 40
        }"#;
        let v = Value::parse(src).unwrap();
        let c = ModelConfig::from_json(&v).unwrap();
        assert_eq!(c.attn_buckets, vec![128]);
        c.validate().unwrap();
    }

    #[test]
    fn load_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if std::path::Path::new(path).exists() {
            let m = Manifest::load(path).unwrap();
            assert!(m.models.contains_key("tiny"));
            let e = m.model("tiny").unwrap();
            assert!(e.executables.contains_key("grouped_step"));
            assert_eq!(m.paper_configs.len(), 4);
            assert!(m.model("nope").is_err());
        }
    }
}
