//! Runtime (launcher) configuration: everything the CLI / server needs
//! beyond the model manifest.

use crate::error::{Error, Result};
use crate::json::Value;
use crate::tensor::{KernelPolicy, Precision};

/// How the engine executes the (segment, layer) grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// The paper's contribution: one grouped step per anti-diagonal.
    Diagonal,
    /// Baseline ARMT: layers in order, segments in order.
    Sequential,
    /// Vanilla full-attention LLaMA baseline (quadratic).
    FullAttention,
    /// Pick diagonal vs sequential per request from the cost model
    /// (paper Table 9: "we can fall back to the original inference
    /// algorithm at runtime").
    Auto,
}

impl std::str::FromStr for ExecMode {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "diagonal" | "diag" => Ok(ExecMode::Diagonal),
            "sequential" | "seq" => Ok(ExecMode::Sequential),
            "full" | "full_attention" => Ok(ExecMode::FullAttention),
            "auto" => Ok(ExecMode::Auto),
            other => Err(Error::Config(format!("unknown mode '{other}'"))),
        }
    }
}

impl std::fmt::Display for ExecMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            ExecMode::Diagonal => "diagonal",
            ExecMode::Sequential => "sequential",
            ExecMode::FullAttention => "full_attention",
            ExecMode::Auto => "auto",
        };
        f.write_str(s)
    }
}

/// Which step backend executes grouped/single steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT HLO executables on the PJRT CPU client (the real path).
    Hlo,
    /// Pure-rust reference model (bit-exact oracle, no artifacts needed).
    Native,
}

impl std::str::FromStr for BackendKind {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "hlo" | "pjrt" => Ok(BackendKind::Hlo),
            "native" => Ok(BackendKind::Native),
            other => Err(Error::Config(format!("unknown backend '{other}'"))),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            BackendKind::Hlo => "hlo",
            BackendKind::Native => "native",
        })
    }
}

/// Launcher configuration (CLI flags / JSON file).
#[derive(Clone, Debug)]
pub struct RuntimeConfig {
    /// Path to artifacts/manifest.json.
    pub manifest: String,
    /// Model bundle to load ("tiny", "toy", ...).
    pub model: String,
    pub mode: ExecMode,
    pub backend: BackendKind,
    /// Server bind address.
    pub addr: String,
    /// Max tokens a single request may carry.
    pub max_request_tokens: usize,
    /// Bounded request queue depth (backpressure beyond this).
    pub queue_depth: usize,
    /// Wavefront slot lanes in the serving engine's packed session.
    /// 1 = cross-request stream packing only (always beneficial). >1
    /// additionally batches lanes into one grouped launch on backends
    /// that support it (native); the current AOT HLO artifacts are
    /// single-lane and execute extra lanes serially, so keep this at 1
    /// on the HLO backend until the artifacts grow a lane dimension.
    pub lanes: usize,
    /// Worker threads for the native backend's parallel cell pool
    /// (`--threads N`). `0` = auto: the `PALLAS_THREADS` env var when
    /// set, else the host's available parallelism. `1` forces the
    /// inline sequential code path (the bit-exact reference oracle —
    /// pooled execution is bit-identical to it, just faster). The HLO
    /// backend ignores this (PJRT owns its own threading).
    pub threads: usize,
    /// Auto mode: minimum segments before diagonal pays off (calibrated
    /// at startup or cost-model driven; see coordinator::fallback).
    pub fallback_min_segments: usize,
    /// Byte budget of the memory-state prefix cache (`--cache-bytes`):
    /// prompt-boundary snapshots are stored in an LRU trie and shared
    /// prompt prefixes skip their prefill entirely; saved conversations
    /// resume without re-prefilling history. `0` (the default) disables
    /// the cache — and with it all snapshot capture overhead.
    pub cache_bytes: usize,
    /// GEMM kernel policy (`--kernel scalar|blocked`). `blocked` (the
    /// default) is the cache-blocked SIMD tier, bit-identical to the
    /// `scalar` oracle; `scalar` forces the reference loops. The
    /// `PALLAS_KERNEL` env var seeds the default.
    pub kernel: KernelPolicy,
    /// Weight storage precision for the native backend
    /// (`--precision f32|f16|bf16|int8`). Anything but `f32` trades a
    /// bounded output error for smaller, faster weight reads; the HLO
    /// backend ignores this. The `PALLAS_PRECISION` env var seeds the
    /// default.
    pub precision: Precision,
    /// Shard worker addresses (`--workers a:1,b:2`); non-empty makes
    /// the `shard` subcommand start a
    /// [`ShardCoordinator`](crate::shard::ShardCoordinator) over them
    /// instead of serving locally.
    pub workers: Vec<String>,
    /// Contiguous layer ranges per worker chain (`--layer-split K`);
    /// 1 = whole requests per worker (lane sharding). The worker count
    /// must be a multiple of this.
    pub layer_split: usize,
    /// HTTP/SSE gateway bind address (`--http ADDR`, the `gateway`
    /// subcommand). Empty = TCP line protocol only.
    pub http: String,
    /// Gateway tenant specs, `name:key:class[:rate[:burst]]` each
    /// (`--tenants` CSV; parsed by
    /// [`TenantSpec::parse_list`](crate::gateway::TenantSpec::parse_list)
    /// at server start). Empty = open gateway, everything admits as the
    /// built-in `local` tenant.
    pub tenants: Vec<String>,
    /// Memory-overflow policy for long prompts (`--overflow
    /// off|select|chunked`, [`crate::quality`]): `select` gates
    /// low-value segments out of the recurrent memory write, `chunked`
    /// reroutes saturating prompts through a scored segment window.
    /// `off` (the default) is bit-exact with builds that predate the
    /// quality tier.
    pub overflow: crate::quality::OverflowPolicy,
    /// Chrome-trace output path (`--trace-file PATH`): non-empty turns
    /// the [`trace`](crate::trace) ring on at startup and flushes the
    /// wavefront timeline there on exit. Empty (the default) keeps
    /// tracing off — the hot path records nothing and allocates
    /// nothing.
    pub trace_file: String,
    /// Structured-log threshold (`--log-level error|warn|info|debug|trace`,
    /// or `off`). Empty defers to the `PALLAS_LOG` env var (default
    /// `warn`).
    pub log_level: String,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        Self {
            manifest: super::DEFAULT_MANIFEST.to_string(),
            model: "tiny".to_string(),
            mode: ExecMode::Diagonal,
            backend: BackendKind::Hlo,
            addr: "127.0.0.1:7433".to_string(),
            max_request_tokens: 1 << 20,
            queue_depth: 64,
            lanes: 1,
            threads: 0,
            fallback_min_segments: 4,
            cache_bytes: 0,
            kernel: crate::tensor::env_kernel_policy(),
            precision: crate::tensor::env_precision(),
            workers: Vec::new(),
            layer_split: 1,
            http: String::new(),
            tenants: Vec::new(),
            overflow: crate::quality::OverflowPolicy::Off,
            trace_file: String::new(),
            log_level: String::new(),
        }
    }
}

impl RuntimeConfig {
    /// Build from a parsed JSON object; absent fields keep defaults.
    pub fn from_json(v: &Value) -> Result<Self> {
        let mut c = Self::default();
        if let Some(x) = v.get("manifest") {
            c.manifest = x.as_str()?.to_string();
        }
        if let Some(x) = v.get("model") {
            c.model = x.as_str()?.to_string();
        }
        if let Some(x) = v.get("mode") {
            c.mode = x.as_str()?.parse()?;
        }
        if let Some(x) = v.get("backend") {
            c.backend = x.as_str()?.parse()?;
        }
        if let Some(x) = v.get("addr") {
            c.addr = x.as_str()?.to_string();
        }
        if let Some(x) = v.get("max_request_tokens") {
            c.max_request_tokens = x.as_usize()?;
        }
        if let Some(x) = v.get("queue_depth") {
            c.queue_depth = x.as_usize()?;
        }
        if let Some(x) = v.get("lanes") {
            c.lanes = x.as_usize()?.max(1);
        }
        if let Some(x) = v.get("threads") {
            c.threads = x.as_usize()?;
        }
        if let Some(x) = v.get("fallback_min_segments") {
            c.fallback_min_segments = x.as_usize()?;
        }
        if let Some(x) = v.get("cache_bytes") {
            c.cache_bytes = x.as_usize()?;
        }
        if let Some(x) = v.get("kernel") {
            c.kernel = x.as_str()?.parse()?;
        }
        if let Some(x) = v.get("precision") {
            c.precision = x.as_str()?.parse()?;
        }
        if let Some(x) = v.get("workers") {
            c.workers =
                x.as_arr()?.iter().map(|w| Ok(w.as_str()?.to_string())).collect::<Result<_>>()?;
        }
        if let Some(x) = v.get("layer_split") {
            c.layer_split = x.as_usize()?.max(1);
        }
        if let Some(x) = v.get("http") {
            c.http = x.as_str()?.to_string();
        }
        if let Some(x) = v.get("tenants") {
            c.tenants =
                x.as_arr()?.iter().map(|t| Ok(t.as_str()?.to_string())).collect::<Result<_>>()?;
        }
        if let Some(x) = v.get("overflow") {
            c.overflow = x.as_str()?.parse()?;
        }
        if let Some(x) = v.get("trace_file") {
            c.trace_file = x.as_str()?.to_string();
        }
        if let Some(x) = v.get("log_level") {
            let s = x.as_str()?;
            crate::trace::log::Level::parse(s)
                .ok_or_else(|| Error::Config(format!("unknown log level '{s}'")))?;
            c.log_level = s.to_string();
        }
        Ok(c)
    }

    /// Load from a JSON file.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Value::parse(&text)?)
    }

    /// Resolve [`threads`](Self::threads) to a concrete worker count:
    /// an explicit setting wins, else
    /// [`model::default_threads`](crate::model::default_threads)
    /// (the `PALLAS_THREADS` env var, else available parallelism).
    pub fn resolved_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            crate::model::default_threads()
        }
    }

    /// Serialize for diagnostics.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("manifest", Value::Str(self.manifest.clone())),
            ("model", Value::Str(self.model.clone())),
            ("mode", Value::Str(self.mode.to_string())),
            ("backend", Value::Str(self.backend.to_string())),
            ("addr", Value::Str(self.addr.clone())),
            ("max_request_tokens", Value::Num(self.max_request_tokens as f64)),
            ("queue_depth", Value::Num(self.queue_depth as f64)),
            ("lanes", Value::Num(self.lanes as f64)),
            ("threads", Value::Num(self.threads as f64)),
            ("fallback_min_segments", Value::Num(self.fallback_min_segments as f64)),
            ("cache_bytes", Value::Num(self.cache_bytes as f64)),
            ("kernel", Value::Str(self.kernel.to_string())),
            ("precision", Value::Str(self.precision.to_string())),
            (
                "workers",
                Value::Arr(self.workers.iter().map(|w| Value::Str(w.clone())).collect()),
            ),
            ("layer_split", Value::Num(self.layer_split as f64)),
            ("http", Value::Str(self.http.clone())),
            (
                "tenants",
                Value::Arr(self.tenants.iter().map(|t| Value::Str(t.clone())).collect()),
            ),
            ("overflow", Value::Str(self.overflow.to_string())),
            ("trace_file", Value::Str(self.trace_file.clone())),
            ("log_level", Value::Str(self.log_level.clone())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_roundtrip() {
        for m in [ExecMode::Diagonal, ExecMode::Sequential, ExecMode::FullAttention, ExecMode::Auto]
        {
            let back: ExecMode = m.to_string().parse().unwrap();
            assert_eq!(back, m);
        }
        assert!("bogus".parse::<ExecMode>().is_err());
    }

    #[test]
    fn default_is_sane() {
        let c = RuntimeConfig::default();
        assert_eq!(c.mode, ExecMode::Diagonal);
        assert!(c.queue_depth > 0);
    }

    #[test]
    fn json_roundtrip() {
        let c = RuntimeConfig::default();
        let v = c.to_json();
        let back = RuntimeConfig::from_json(&v).unwrap();
        assert_eq!(back.model, c.model);
        assert_eq!(back.mode, c.mode);
        assert_eq!(back.backend, c.backend);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let v = Value::parse(r#"{"model": "toy", "mode": "seq"}"#).unwrap();
        let c = RuntimeConfig::from_json(&v).unwrap();
        assert_eq!(c.model, "toy");
        assert_eq!(c.mode, ExecMode::Sequential);
        assert_eq!(c.queue_depth, 64);
        assert_eq!(c.lanes, 1);
        assert_eq!(c.threads, 0); // auto
        assert_eq!(c.cache_bytes, 0); // cache off
    }

    #[test]
    fn cache_bytes_roundtrip() {
        let v = Value::parse(r#"{"cache_bytes": 1048576}"#).unwrap();
        let c = RuntimeConfig::from_json(&v).unwrap();
        assert_eq!(c.cache_bytes, 1 << 20);
        let back = RuntimeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.cache_bytes, 1 << 20);
    }

    #[test]
    fn threads_resolve() {
        let explicit = RuntimeConfig { threads: 3, ..RuntimeConfig::default() };
        assert_eq!(explicit.resolved_threads(), 3);
        // Auto (threads = 0) resolves to SOMETHING runnable whatever
        // the host/env.
        assert!(RuntimeConfig::default().resolved_threads() >= 1);
        let v = Value::parse(r#"{"threads": 7}"#).unwrap();
        assert_eq!(RuntimeConfig::from_json(&v).unwrap().threads, 7);
    }

    #[test]
    fn bad_mode_rejected() {
        let v = Value::parse(r#"{"mode": "sideways"}"#).unwrap();
        assert!(RuntimeConfig::from_json(&v).is_err());
    }

    #[test]
    fn kernel_precision_roundtrip() {
        let v = Value::parse(r#"{"kernel": "scalar", "precision": "int8"}"#).unwrap();
        let c = RuntimeConfig::from_json(&v).unwrap();
        assert_eq!(c.kernel, KernelPolicy::Scalar);
        assert_eq!(c.precision, Precision::Int8);
        let back = RuntimeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.kernel, KernelPolicy::Scalar);
        assert_eq!(back.precision, Precision::Int8);
    }

    #[test]
    fn shard_fields_roundtrip() {
        let v = Value::parse(
            r#"{"workers": ["127.0.0.1:7501", "127.0.0.1:7502"], "layer_split": 2}"#,
        )
        .unwrap();
        let c = RuntimeConfig::from_json(&v).unwrap();
        assert_eq!(c.workers, vec!["127.0.0.1:7501", "127.0.0.1:7502"]);
        assert_eq!(c.layer_split, 2);
        let back = RuntimeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.workers, c.workers);
        assert_eq!(back.layer_split, 2);
        // Defaults: no workers, lane mode.
        let d = RuntimeConfig::default();
        assert!(d.workers.is_empty());
        assert_eq!(d.layer_split, 1);
        // 0 clamps to 1 (a chain always has at least one range).
        let v = Value::parse(r#"{"layer_split": 0}"#).unwrap();
        assert_eq!(RuntimeConfig::from_json(&v).unwrap().layer_split, 1);
        // Non-string worker entries are rejected.
        let v = Value::parse(r#"{"workers": [7]}"#).unwrap();
        assert!(RuntimeConfig::from_json(&v).is_err());
    }

    #[test]
    fn gateway_fields_roundtrip() {
        let v = Value::parse(
            r#"{"http": "127.0.0.1:8080", "tenants": ["alice:sk-a:interactive:5:10", "bob:sk-b:batch"]}"#,
        )
        .unwrap();
        let c = RuntimeConfig::from_json(&v).unwrap();
        assert_eq!(c.http, "127.0.0.1:8080");
        assert_eq!(c.tenants.len(), 2);
        let back = RuntimeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.http, c.http);
        assert_eq!(back.tenants, c.tenants);
        // Defaults: gateway off, open admission.
        let d = RuntimeConfig::default();
        assert!(d.http.is_empty());
        assert!(d.tenants.is_empty());
        // Non-string tenant entries are rejected.
        let v = Value::parse(r#"{"tenants": [3]}"#).unwrap();
        assert!(RuntimeConfig::from_json(&v).is_err());
    }

    #[test]
    fn overflow_roundtrip() {
        use crate::quality::OverflowPolicy;
        // Default: policy off — bit-exact with pre-quality builds.
        assert_eq!(RuntimeConfig::default().overflow, OverflowPolicy::Off);
        let v = Value::parse(r#"{"overflow": "select"}"#).unwrap();
        let c = RuntimeConfig::from_json(&v).unwrap();
        assert_eq!(c.overflow, OverflowPolicy::Select);
        let back = RuntimeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.overflow, OverflowPolicy::Select);
        let v = Value::parse(r#"{"overflow": "chunked"}"#).unwrap();
        assert_eq!(RuntimeConfig::from_json(&v).unwrap().overflow, OverflowPolicy::Chunked);
        let v = Value::parse(r#"{"overflow": "warp"}"#).unwrap();
        assert!(RuntimeConfig::from_json(&v).is_err());
    }

    #[test]
    fn trace_fields_roundtrip() {
        // Defaults: tracing off, log level deferred to the env.
        let d = RuntimeConfig::default();
        assert!(d.trace_file.is_empty());
        assert!(d.log_level.is_empty());
        let v = Value::parse(r#"{"trace_file": "/tmp/trace.json", "log_level": "debug"}"#).unwrap();
        let c = RuntimeConfig::from_json(&v).unwrap();
        assert_eq!(c.trace_file, "/tmp/trace.json");
        assert_eq!(c.log_level, "debug");
        let back = RuntimeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.trace_file, c.trace_file);
        assert_eq!(back.log_level, c.log_level);
        // Bogus levels are rejected at parse time, not at startup.
        let v = Value::parse(r#"{"log_level": "shouty"}"#).unwrap();
        assert!(RuntimeConfig::from_json(&v).is_err());
    }

    #[test]
    fn bad_kernel_and_precision_rejected() {
        let v = Value::parse(r#"{"kernel": "vectorish"}"#).unwrap();
        assert!(RuntimeConfig::from_json(&v).is_err());
        let v = Value::parse(r#"{"precision": "fp4"}"#).unwrap();
        assert!(RuntimeConfig::from_json(&v).is_err());
    }
}
