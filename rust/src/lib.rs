//! # diagonal-batching
//!
//! Production-grade reproduction of *"Diagonal Batching Unlocks Parallelism
//! in Recurrent Memory Transformers for Long Contexts"* (Sivtsov et al.,
//! 2025) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build-time python)** — ARMT Pallas kernels + JAX model,
//!   AOT-lowered to HLO text artifacts (`make artifacts`).
//! * **L3 (this crate)** — the paper's contribution: the diagonal-batching
//!   scheduler ([`scheduler`]), plus every substrate it needs: a PJRT
//!   runtime ([`runtime`]), a native reference model ([`model`]), a GPU
//!   roofline simulator ([`simulator`]), a serving coordinator
//!   ([`coordinator`]), a TCP server ([`server`]), a synthetic BABILong
//!   task generator ([`babilong`]), metrics and configuration.
//!
//! Python never runs on the request path: after `make artifacts` the
//! binary is self-contained.
//!
//! ## Quick start
//!
//! ```no_run
//! use diagonal_batching::config::Manifest;
//! use diagonal_batching::model::{NativeBackend, Params};
//! use diagonal_batching::scheduler::{Executor, ScheduleMode};
//!
//! let manifest = Manifest::load("artifacts/manifest.json").unwrap();
//! let entry = manifest.model("tiny").unwrap();
//! let params = Params::load(&manifest, "tiny").unwrap();
//! let mut backend = NativeBackend::new(entry.config.clone(), params);
//! let mut exec = Executor::new(&mut backend, ScheduleMode::Diagonal);
//! let tokens: Vec<u32> = (0..256).map(|i| i % 100).collect();
//! let out = exec.run(&tokens).unwrap();
//! println!("{} segments, {} logits/segment", out.segments(), out.vocab());
//! ```

pub mod babilong;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod json;
pub mod bench;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod simulator;
pub mod tensor;

pub use error::{Error, Result};
