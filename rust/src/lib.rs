//! # diagonal-batching
//!
//! Production-grade reproduction of *"Diagonal Batching Unlocks Parallelism
//! in Recurrent Memory Transformers for Long Contexts"* (Sivtsov et al.,
//! 2025) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build-time python)** — ARMT Pallas kernels + JAX model,
//!   AOT-lowered to HLO text artifacts (`make artifacts`).
//! * **L3 (this crate)** — the paper's contribution: the diagonal-batching
//!   scheduler ([`scheduler`]), plus every substrate it needs: a PJRT
//!   runtime ([`runtime`]), a native reference model ([`model`]), a GPU
//!   roofline simulator ([`simulator`]), a serving coordinator
//!   ([`coordinator`]), a TCP server ([`server`]), a synthetic BABILong
//!   task generator ([`babilong`]), metrics and configuration.
//!
//! Python never runs on the request path: after `make artifacts` the
//! binary is self-contained.
//!
//! ## Quick start
//!
//! The serving primitive is the [`scheduler::WavefrontSession`]: a
//! persistent diagonal wavefront whose `L x B` slot lanes carry
//! `(request, segment)` cells from *multiple concurrent requests*, so
//! one request's ramp-down overlaps the next one's ramp-up and the
//! grouped launches stay full. Submit any number of requests (including
//! mid-flight), step until idle, and collect completions — each
//! request's logits are bit-identical to running it alone:
//!
//! ```no_run
//! use diagonal_batching::config::Manifest;
//! use diagonal_batching::model::{NativeBackend, Params};
//! use diagonal_batching::scheduler::WavefrontSession;
//!
//! let manifest = Manifest::load("artifacts/manifest.json").unwrap();
//! let entry = manifest.model("tiny").unwrap();
//! let params = Params::load(&manifest, "tiny").unwrap();
//! let mut backend = NativeBackend::new(entry.config.clone(), params);
//!
//! // Two concurrent requests packed into one single-lane wavefront.
//! let mut session = WavefrontSession::new(entry.config.clone(), 1);
//! let short: Vec<u32> = (0..256).map(|i| i % 100).collect();
//! let long: Vec<u32> = (0..1024).map(|i| i % 100).collect();
//! session.submit(1, &short).unwrap();
//! session.submit(2, &long).unwrap();
//! session.run_to_completion(&mut backend).unwrap();
//! while let Some(done) = session.pop_completed() {
//!     println!("request {}: {} segments", done.id, done.logits.len());
//! }
//! let stats = session.stats();
//! println!("mean group {:.2}, occupancy {:.2}", stats.mean_group(), stats.occupancy());
//! ```
//!
//! For a single request, [`scheduler::Executor`] with
//! [`scheduler::ScheduleMode::Diagonal`] is the one-request special case
//! of the same machinery (and `ScheduleMode::Sequential` is the
//! baseline ARMT loop). For serving, `coordinator::InferenceEngine::serve_queue`
//! drains a bounded request queue into one long-lived session
//! continuously — that is what [`server`] runs.
//!
//! ## Generation
//!
//! The engine's API is a *streaming lifecycle*: a
//! [`coordinator::GenerateRequest`] (prompt + `max_new_tokens` +
//! [`coordinator::SamplingParams`] + optional deadline) produces a
//! stream of [`coordinator::Event`]s — `SegmentDone` per exited
//! segment, `Token` per generated token, then a terminal
//! `Done`/`Error` — cancellable mid-flight via a
//! [`coordinator::RequestHandle`]. Decode happens *inside the live
//! wavefront*: when a request's prefill segments drain, its sampled
//! continuation is appended to the same lane
//! ([`scheduler::WavefrontSession::append_segment`]), so concurrent
//! generations keep sharing grouped launches — and each continuation is
//! bit-identical to running prompt + generated tokens through the
//! sequential single-shot oracle (decode is just more segments of the
//! same exact recurrence). `InferenceEngine::process` is the
//! collect-all-events special case returning the terminal
//! [`coordinator::Response`]. Try it without artifacts:
//! `diagonal-batching generate --synthetic 42 --tokens 64
//! --max-new-tokens 32`, or `cargo run --release --example
//! generate_stream`.
//!
//! ## Serving
//!
//! `diagonal-batching serve --addr HOST:PORT --lanes N` starts the TCP
//! JSON-lines server (`--synthetic SEED` serves the built-in
//! artifact-free model). `--lanes N` sets the wavefront's slot-lane
//! width `B`: up to `N` concurrent requests batch into every grouped
//! launch on the native backend (keep `N = 1` on the current
//! single-lane HLO artifacts; stream packing still fills ramp bubbles
//! there). Clients send one JSON object per line and receive one line
//! per event (`segment`, `token`, then terminal `done`/`error`);
//! besides requests the protocol has `{"cmd": "ping"}`,
//! `{"cmd": "cancel", "id": N}` (works from any connection),
//! `{"cmd": "save", "id": N}` (conversation suspend), `{"cmd":
//! "shutdown"}` and `{"cmd": "stats"}`, which returns the live
//! [`coordinator::EngineStats`] snapshot — request/launch/cancel
//! counters, `mean_group`, `occupancy`, `padded_cells`,
//! `generated_tokens`, the cache counters (`cache_hits`,
//! `cache_hit_segments`, `cache_bytes`, `evictions`) and
//! `latency_ms_{mean,p50,p90,p99}` (see [`server`] for the exact frame
//! shapes).
//!
//! ## Production gateway
//!
//! `diagonal-batching gateway --synthetic 42 --http 127.0.0.1:8080
//! --tenants alice:sk-a:interactive:5:10,bob:sk-b:batch` (or `serve
//! --http ADDR`) additionally binds the [`gateway`]: an HTTP/1.1 + SSE
//! front end over the same engine with per-tenant API keys,
//! token-bucket rate limiting (`429`), weighted-fair lane scheduling
//! with SLA priority classes replacing FIFO admission, queue-depth
//! load-shedding, and a Prometheus-text `GET /metrics` endpoint
//! exporting every [`coordinator::EngineStats`] field. SSE `data:`
//! payloads are byte-identical to the TCP frames for the same request.
//! See ARCHITECTURE.md "Production gateway".
//!
//! ## Memory-state cache
//!
//! `--cache-bytes N` enables the [`cache`] subsystem: because ARMT's
//! per-layer memory is constant-size, a request's entire inference
//! state after segment `k` is a tiny [`cache::MemSnapshot`]. The
//! engine checkpoints every prompt-segment boundary into a
//! [`cache::PrefixStore`] (a trie over segment token blocks, LRU under
//! the byte budget), so prompts sharing a cached prefix skip its
//! prefill entirely — bit-exactly — and conversations can be saved
//! (`"save": true`, resume tokens) or exported to disk and resumed
//! without ever re-prefilling history. See ARCHITECTURE.md
//! "Memory-state cache" and `examples/chat_resume.rs`.
//!
//! ## Sharded serving
//!
//! The same constant-size snapshots make multi-process serving cheap:
//! `diagonal-batching shard --workers a:1,b:2` starts a [`shard`]
//! coordinator that speaks the ordinary client protocol and spreads
//! requests across `diagonal-batching worker` processes — whole
//! requests per worker (lane sharding), or contiguous layer ranges per
//! worker with activation hand-off (`--layer-split K`). Workers
//! checkpoint each segment boundary back to the coordinator, so a
//! worker killed mid-request fails over to a survivor and the merged
//! client stream stays byte-identical to an uninterrupted run
//! (`rust/tests/shard_failover.rs` proves this under injected death,
//! stall and connection-drop faults). See ARCHITECTURE.md "Sharded
//! serving".
//!
//! ## Quality tier
//!
//! ARMT memory is constant-size, so very long contexts *overflow* it:
//! past a few multiples of `phi_dim` written tokens, new associations
//! interfere with old ones and recall decays even though throughput is
//! fine. The [`quality`] module guards this: a per-request
//! `MemoryMonitor` computes a calibrated `saturation ∈ [0, 1]` at every
//! segment boundary (surfaced in `segment`/`done` frames, `stats`, and
//! `/metrics`), `overflow: "select"` scores prompt segments
//! (query-similarity + novelty) and skips the memory *write* for low
//! scorers (attention still sees every token), and `overflow:
//! "chunked"` re-routes saturating requests to the best
//! capacity-sized window of the context. With the policy off, behavior
//! is bit-identical to a monitor-free build. The `babilong_quality`
//! bench suite pins accuracy-vs-context curves with the policy on and
//! off. See ARCHITECTURE.md "Quality tier".
//!
//! ## Observability
//!
//! The [`trace`] module is an always-compiled, off-by-default tracing
//! tier: per-request spans (queue wait, admission, per-segment
//! prefill, per-token decode, cache hits, shard hand-offs) with
//! trace-id propagation across gateway → engine → shard workers
//! (wire field `"trace"`, HTTP `X-Trace-Id`), exported as
//! Chrome-trace/Perfetto JSON via `--trace-file`, `{"cmd": "trace"}`
//! or `GET /debug/trace` — `tid` is the wavefront lane, so a packed
//! run renders the paper's diagonal. TTFT / inter-token / queue-wait
//! latency histograms export as Prometheus `_bucket`/`_sum`/`_count`
//! series in `/metrics`, and [`trace::log`] is the structured JSON
//! stderr logger (`--log-level`, `PALLAS_LOG`). Tracing off is
//! bit-identical and allocation-free; tracing on changes no output
//! bytes (`rust/tests/trace_invariance.rs`). See ARCHITECTURE.md
//! "Observability tier".
//!
//! ## Benchmarks
//!
//! Every paper figure/table reproduction is a registered suite in
//! [`bench::suites`]; `diagonal-batching bench --suite 'fig*' --json
//! BENCH_diag.json` runs a glob of suites and writes the versioned
//! machine-readable report, and `--compare BENCH_baseline.json
//! --max-regression 1.15` turns it into a regression gate. See
//! `BENCHMARKS.md` and `ARCHITECTURE.md` at the repository root.

pub mod babilong;
pub mod cache;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod gateway;
pub mod json;
pub mod bench;
pub mod metrics;
pub mod model;
pub mod quality;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod shard;
pub mod simulator;
pub mod tensor;
pub mod trace;

pub use error::{Error, Result};
