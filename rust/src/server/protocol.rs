//! Wire <-> coordinator type mapping: request frames in, event frames
//! out (one JSON object per line; see the module docs of
//! [`crate::server`] for the full protocol).

use std::time::{Duration, Instant};

use crate::cache::MemSnapshot;
use crate::config::ExecMode;
use crate::coordinator::{Event, GenerateRequest, Response, SamplingParams};
use crate::error::Result;
use crate::json::Value;

/// Parse a request object; `next_id` supplies an id when absent.
///
/// Recognized fields: `tokens` (required), `id`, `mode`,
/// `want_logits`, `max_new_tokens`, `temperature`, `top_k`, `seed`,
/// `deadline_ms`, `save` (retain the final memory state; the `done`
/// frame then carries `resume_token`), `resume` (a previously
/// returned token — `tokens` then holds only the NEW tokens, the
/// saved history is never re-prefilled), `resume_state` (an inline
/// [`MemSnapshot`] object — the shard coordinator's failover path;
/// takes precedence over `resume`), `checkpoint` (emit boundary
/// `snapshot` frames on the serving path) and `overflow`
/// (`"off" | "select" | "chunked"` — the long-context memory-overflow
/// policy; see [`crate::quality`]). Ids parse through the full `u64`
/// path so large client-chosen ids (up to 2^53, the exact-f64 range)
/// round-trip.
pub fn parse_request(v: &Value, next_id: impl FnOnce() -> u64) -> Result<GenerateRequest> {
    let tokens = v.req("tokens")?.as_u32_vec()?;
    let id = match v.get("id") {
        Some(x) => x.as_u64()?,
        None => next_id(),
    };
    let mode: Option<ExecMode> = match v.get("mode") {
        Some(m) => Some(m.as_str()?.parse()?),
        None => None,
    };
    let want_logits = match v.get("want_logits") {
        Some(w) => w.as_bool()?,
        None => false,
    };
    let max_new_tokens =
        v.get("max_new_tokens").map(Value::as_usize).transpose()?.unwrap_or(0);
    let mut sampling = SamplingParams::default();
    if let Some(t) = v.get("temperature") {
        sampling.temperature = t.as_f32()?;
    }
    if let Some(k) = v.get("top_k") {
        sampling.top_k = k.as_usize()?;
    }
    if let Some(s) = v.get("seed") {
        sampling.seed = s.as_u64()?;
    }
    let mut req =
        GenerateRequest::new(id, tokens).generate(max_new_tokens).with_sampling(sampling);
    if let Some(ms) = v.get("deadline_ms").map(Value::as_u64).transpose()? {
        req = req.with_deadline(Duration::from_millis(ms));
    }
    if v.get("save").map(Value::as_bool).transpose()?.unwrap_or(false) {
        req = req.with_save();
    }
    if let Some(token) = v.get("resume").map(Value::as_u64).transpose()? {
        req = req.resume_token(token);
    }
    if let Some(state) = v.get("resume_state") {
        req = req.resume_snapshot(MemSnapshot::from_json(state)?);
    }
    if v.get("checkpoint").map(Value::as_bool).transpose()?.unwrap_or(false) {
        req = req.with_checkpoint();
    }
    if let Some(policy) = v.get("overflow") {
        req = req.with_overflow(crate::quality::OverflowPolicy::parse(policy.as_str()?)?);
    }
    // Client-supplied trace id: spans at every hop carry it, and the
    // terminal `done` frame echoes it (engine-assigned ids never reach
    // the wire — see [`Response::trace`]).
    if let Some(t) = v.get("trace").map(Value::as_u64).transpose()? {
        req = req.with_trace(t);
    }
    req.mode = mode;
    req.want_logits = want_logits;
    // Queue-wait starts now: parsing is the first thing every front end
    // (TCP, HTTP, shard) does with a request.
    req.enqueued = Some(Instant::now());
    Ok(req)
}

/// Render one engine [`Event`] as a wire frame. Every frame carries the
/// request's wire `id` and an `event` discriminator
/// (`"segment" | "token" | "snapshot" | "done" | "error"`); `done` and
/// `error` are terminal. `snapshot` frames only appear for requests
/// submitted with `"checkpoint": true` — they carry the full boundary
/// [`MemSnapshot`] for the shard coordinator and are NOT forwarded to
/// end clients.
pub fn render_event(id: u64, ev: &Event) -> Value {
    match ev {
        Event::SegmentDone { index, greedy, saturation } => Value::obj(vec![
            ("id", Value::Num(id as f64)),
            ("event", Value::Str("segment".into())),
            ("index", Value::Num(*index as f64)),
            ("greedy", Value::arr_u32(greedy)),
            ("saturation", Value::Num(*saturation)),
        ]),
        Event::Snapshot { index, state } => Value::obj(vec![
            ("id", Value::Num(id as f64)),
            ("event", Value::Str("snapshot".into())),
            ("index", Value::Num(*index as f64)),
            ("state", state.to_json()),
        ]),
        Event::Token { pos, token } => Value::obj(vec![
            ("id", Value::Num(id as f64)),
            ("event", Value::Str("token".into())),
            ("pos", Value::Num(*pos as f64)),
            ("token", Value::Num(*token as f64)),
        ]),
        Event::Done { stats } => render_done(stats),
        Event::Error { error } => Value::obj(vec![
            ("id", Value::Num(id as f64)),
            ("event", Value::Str("error".into())),
            ("error", Value::Str(error.to_string())),
        ]),
    }
}

/// Render the terminal `done` frame (logits are summarized, never
/// shipped raw — the greedy tail / generated tokens plus norms is what
/// serving clients consume).
pub fn render_done(resp: &Response) -> Value {
    let mut fields = vec![
        ("id", Value::Num(resp.id as f64)),
        ("event", Value::Str("done".into())),
        (
            "greedy_tail",
            Value::Arr(resp.greedy_tail.iter().map(|&t| Value::Num(t as f64)).collect()),
        ),
        ("generated", Value::arr_u32(&resp.generated)),
        ("mode", Value::Str(resp.mode_used.to_string())),
        ("latency_ms", Value::Num(resp.latency.as_secs_f64() * 1e3)),
        ("segments", Value::Num(resp.stats.segments as f64)),
        ("launches", Value::Num(resp.stats.launches as f64)),
        ("tokens", Value::Num(resp.stats.tokens as f64)),
        ("mean_group", Value::Num(resp.stats.mean_group())),
        ("cells", Value::Num(resp.stats.cells as f64)),
        ("padded_cells", Value::Num(resp.stats.padded_cells as f64)),
        ("occupancy", Value::Num(resp.stats.occupancy())),
        ("reused_segments", Value::Num(resp.reused_segments as f64)),
        ("segments_skipped", Value::Num(resp.segments_skipped as f64)),
        ("overflow_routed", Value::Bool(resp.overflow_routed)),
        ("saturation", Value::Num(resp.saturation)),
    ];
    if let Some(token) = resp.resume_token {
        fields.push(("resume_token", Value::Num(token as f64)));
    }
    if let Some(t) = resp.trace {
        fields.push(("trace", Value::Num(t as f64)));
    }
    if let Some(logits) = &resp.logits {
        let norms: Vec<Value> =
            logits.iter().map(|t| Value::Num(t.norm() as f64)).collect();
        fields.push(("logits_norms", Value::Arr(norms)));
    }
    Value::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal() {
        let v = Value::parse(r#"{"tokens": [1, 2, 3]}"#).unwrap();
        let r = parse_request(&v, || 42).unwrap();
        assert_eq!(r.id, 42);
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert!(r.mode.is_none());
        assert!(!r.want_logits);
        assert_eq!(r.max_new_tokens, 0);
        assert!(r.deadline.is_none());
        assert!(r.sampling.is_greedy());
    }

    #[test]
    fn parse_full_generation_request() {
        let v = Value::parse(
            r#"{"id": 7, "tokens": [5], "mode": "seq", "want_logits": true,
                "max_new_tokens": 64, "temperature": 0.75, "top_k": 40,
                "seed": 123, "deadline_ms": 1500, "overflow": "select"}"#,
        )
        .unwrap();
        let r = parse_request(&v, || 0).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.overflow, crate::quality::OverflowPolicy::Select);
        assert_eq!(r.mode, Some(ExecMode::Sequential));
        assert!(r.want_logits);
        assert_eq!(r.max_new_tokens, 64);
        assert_eq!(r.sampling.temperature, 0.75);
        assert_eq!(r.sampling.top_k, 40);
        assert_eq!(r.sampling.seed, 123);
        assert_eq!(r.deadline, Some(Duration::from_millis(1500)));
    }

    #[test]
    fn large_client_ids_roundtrip() {
        let big: u64 = (1u64 << 53) - 1;
        let v = Value::parse(&format!(r#"{{"id": {big}, "tokens": [1]}}"#)).unwrap();
        let r = parse_request(&v, || 0).unwrap();
        assert_eq!(r.id, big);
        // ...and the id survives back onto the wire in an event frame.
        let frame = render_event(r.id, &Event::Token { pos: 0, token: 3 });
        assert_eq!(frame.req("id").unwrap().as_u64().unwrap(), big);
    }

    #[test]
    fn event_frames() {
        let seg =
            render_event(4, &Event::SegmentDone { index: 2, greedy: vec![7, 8], saturation: 0.5 });
        assert_eq!(seg.req("event").unwrap().as_str().unwrap(), "segment");
        assert_eq!(seg.req("index").unwrap().as_usize().unwrap(), 2);
        assert_eq!(seg.req("greedy").unwrap().as_u32_vec().unwrap(), vec![7, 8]);
        assert_eq!(seg.req("saturation").unwrap().as_f64().unwrap(), 0.5);

        let tok = render_event(4, &Event::Token { pos: 5, token: 17 });
        assert_eq!(tok.req("event").unwrap().as_str().unwrap(), "token");
        assert_eq!(tok.req("pos").unwrap().as_usize().unwrap(), 5);
        assert_eq!(tok.req("token").unwrap().as_u32().unwrap(), 17);

        let err = render_event(
            4,
            &Event::Error { error: crate::error::Error::Request("nope".into()) },
        );
        assert_eq!(err.req("event").unwrap().as_str().unwrap(), "error");
        assert!(err.req("error").unwrap().as_str().unwrap().contains("nope"));
    }

    #[test]
    fn done_frame_carries_utilization_stats_and_generated() {
        use crate::scheduler::RunStats;
        let resp = Response {
            id: 3,
            greedy_tail: vec![1, 2],
            generated: vec![9, 10, 11],
            logits: None,
            reused_segments: 2,
            segments_skipped: 1,
            overflow_routed: false,
            saturation: 0.25,
            resume_token: Some(3),
            final_state: None,
            mode_used: ExecMode::Diagonal,
            stats: RunStats {
                mode_diagonal: true,
                segments: 4,
                launches: 6,
                cells: 12,
                slot_steps: 18,
                padded_cells: 6,
                wall: Duration::from_millis(1),
                tokens: 32,
            },
            latency: Duration::from_millis(2),
            trace: None,
        };
        let v = render_done(&resp);
        assert_eq!(v.req("event").unwrap().as_str().unwrap(), "done");
        assert_eq!(v.req("reused_segments").unwrap().as_usize().unwrap(), 2);
        assert_eq!(v.req("segments_skipped").unwrap().as_usize().unwrap(), 1);
        assert!(!v.req("overflow_routed").unwrap().as_bool().unwrap());
        assert_eq!(v.req("saturation").unwrap().as_f64().unwrap(), 0.25);
        assert_eq!(v.req("resume_token").unwrap().as_u64().unwrap(), 3);
        assert_eq!(v.req("cells").unwrap().as_usize().unwrap(), 12);
        assert_eq!(v.req("padded_cells").unwrap().as_usize().unwrap(), 6);
        assert_eq!(v.req("generated").unwrap().as_u32_vec().unwrap(), vec![9, 10, 11]);
        let occ = v.req("occupancy").unwrap().as_f64().unwrap();
        assert!((occ - 12.0 / 18.0).abs() < 1e-9, "occupancy {occ}");
        assert_eq!(v.req("mean_group").unwrap().as_f64().unwrap(), 2.0);
        // Terminal done frames also render through render_event.
        let via_event = render_event(3, &Event::Done { stats: Box::new(resp) });
        assert_eq!(via_event, v);
    }

    #[test]
    fn parse_save_and_resume_fields() {
        use crate::coordinator::ResumeFrom;
        let v = Value::parse(r#"{"tokens": [1, 2], "save": true, "resume": 77}"#).unwrap();
        let r = parse_request(&v, || 0).unwrap();
        assert!(r.save_requested());
        assert!(matches!(r.resume, Some(ResumeFrom::Token(77))));
        // Absent fields keep the defaults.
        let v = Value::parse(r#"{"tokens": [1]}"#).unwrap();
        let r = parse_request(&v, || 0).unwrap();
        assert!(!r.save_requested());
        assert!(r.resume.is_none());
        // Type errors are rejected.
        for bad in [
            r#"{"tokens": [1], "save": 1}"#,
            r#"{"tokens": [1], "resume": "x"}"#,
            r#"{"tokens": [1], "resume": -2}"#,
        ] {
            let v = Value::parse(bad).unwrap();
            assert!(parse_request(&v, || 0).is_err(), "{bad}");
        }
    }

    #[test]
    fn parse_checkpoint_and_inline_resume_state() {
        use crate::coordinator::ResumeFrom;
        use crate::tensor::Tensor;
        let snap = MemSnapshot {
            model: "wire".into(),
            n_layers: 1,
            d_model: 2,
            phi_dim: 2,
            seg: 4,
            segments: 3,
            a: vec![Tensor::new(&[2, 2], vec![1.0, -0.0, 2.5, f32::MIN_POSITIVE]).unwrap()],
            z: vec![Tensor::new(&[2], vec![0.25, -7.0]).unwrap()],
        };
        let frame = Value::obj(vec![
            ("tokens", Value::arr_u32(&[1, 2])),
            ("checkpoint", Value::Bool(true)),
            ("resume_state", snap.to_json()),
        ]);
        let r = parse_request(&frame, || 0).unwrap();
        assert!(r.checkpoint);
        match r.resume {
            Some(ResumeFrom::Snapshot(got)) => {
                // f32-bit-exact round trip through the wire field.
                assert_eq!(*got, snap);
            }
            other => panic!("expected an inline snapshot resume, got {other:?}"),
        }
        // checkpoint defaults off; bad types are rejected.
        let v = Value::parse(r#"{"tokens": [1]}"#).unwrap();
        assert!(!parse_request(&v, || 0).unwrap().checkpoint);
        let v = Value::parse(r#"{"tokens": [1], "checkpoint": 1}"#).unwrap();
        assert!(parse_request(&v, || 0).is_err());
        let v = Value::parse(r#"{"tokens": [1], "resume_state": 5}"#).unwrap();
        assert!(parse_request(&v, || 0).is_err());
    }

    #[test]
    fn snapshot_frame_roundtrips_bit_exact() {
        use crate::tensor::Tensor;
        let snap = MemSnapshot {
            model: "wire".into(),
            n_layers: 1,
            d_model: 2,
            phi_dim: 2,
            seg: 4,
            segments: 2,
            a: vec![Tensor::new(&[2, 2], vec![f32::NAN, 0.0, -0.0, 3.5]).unwrap()],
            z: vec![Tensor::new(&[2], vec![1e-40, -1.5]).unwrap()],
        };
        let frame =
            render_event(9, &Event::Snapshot { index: 1, state: Box::new(snap.clone()) });
        assert_eq!(frame.req("event").unwrap().as_str().unwrap(), "snapshot");
        assert_eq!(frame.req("index").unwrap().as_usize().unwrap(), 1);
        let back = MemSnapshot::from_json(frame.req("state").unwrap()).unwrap();
        // Bit patterns, not float equality: NaN payloads, -0.0 and
        // denormals must survive the frame.
        for (a, b) in snap.a[0].data().iter().zip(back.a[0].data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in snap.z[0].data().iter().zip(back.z[0].data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn parse_and_echo_trace_id() {
        let v = Value::parse(r#"{"tokens": [1], "trace": 909}"#).unwrap();
        let r = parse_request(&v, || 0).unwrap();
        assert_eq!(r.trace, Some(909));
        assert!(r.enqueued.is_some(), "parse stamps the queue-wait clock");
        // Absent -> None; the done frame then omits the field entirely.
        let r2 = parse_request(&Value::parse(r#"{"tokens": [1]}"#).unwrap(), || 0).unwrap();
        assert_eq!(r2.trace, None);
        // Bad types are rejected.
        let v = Value::parse(r#"{"tokens": [1], "trace": "abc"}"#).unwrap();
        assert!(parse_request(&v, || 0).is_err());
    }

    #[test]
    fn parse_rejects_bad_fields() {
        for bad in [
            r#"{"mode": "diag"}"#,                       // missing tokens
            r#"{"tokens": "x"}"#,                        // wrong type
            r#"{"tokens": [1], "mode": "warp"}"#,        // bad mode
            r#"{"tokens": [-1]}"#,                       // negative token
            r#"{"tokens": [1], "id": -3}"#,              // negative id
            r#"{"tokens": [1], "max_new_tokens": 1.5}"#, // fractional budget
            r#"{"tokens": [1], "deadline_ms": "soon"}"#, // wrong type
            r#"{"tokens": [1], "overflow": "warp"}"#,    // unknown policy
            r#"{"tokens": [1], "overflow": 1}"#,         // wrong type
        ] {
            let v = Value::parse(bad).unwrap();
            assert!(parse_request(&v, || 0).is_err(), "{bad}");
        }
    }
}
