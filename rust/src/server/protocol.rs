//! Wire <-> coordinator type mapping.

use crate::config::ExecMode;
use crate::coordinator::{Request, Response};
use crate::error::Result;
use crate::json::Value;

/// Parsed request line (before engine processing).
#[derive(Clone, Debug)]
pub struct WireRequest {
    pub request: Request,
}

/// Parse a request object; `next_id` supplies an id when absent.
pub fn parse_request(v: &Value, next_id: impl FnOnce() -> u64) -> Result<Request> {
    let tokens = v.req("tokens")?.as_u32_vec()?;
    let id = match v.get("id") {
        Some(x) => x.as_usize()? as u64,
        None => next_id(),
    };
    let mode: Option<ExecMode> = match v.get("mode") {
        Some(m) => Some(m.as_str()?.parse()?),
        None => None,
    };
    let want_logits = match v.get("want_logits") {
        Some(w) => w.as_bool()?,
        None => false,
    };
    Ok(Request { id, tokens, mode, want_logits })
}

/// Render a successful response (logits are summarized, never shipped raw
/// — the greedy tail plus norms is what serving clients consume).
pub fn render_response(resp: &Response) -> Value {
    let mut fields = vec![
        ("id", Value::Num(resp.id as f64)),
        (
            "greedy_tail",
            Value::Arr(resp.greedy_tail.iter().map(|&t| Value::Num(t as f64)).collect()),
        ),
        ("mode", Value::Str(resp.mode_used.to_string())),
        ("latency_ms", Value::Num(resp.latency.as_secs_f64() * 1e3)),
        ("segments", Value::Num(resp.stats.segments as f64)),
        ("launches", Value::Num(resp.stats.launches as f64)),
        ("tokens", Value::Num(resp.stats.tokens as f64)),
        ("mean_group", Value::Num(resp.stats.mean_group())),
        ("cells", Value::Num(resp.stats.cells as f64)),
        ("padded_cells", Value::Num(resp.stats.padded_cells as f64)),
        ("occupancy", Value::Num(resp.stats.occupancy())),
    ];
    if let Some(logits) = &resp.logits {
        let norms: Vec<Value> =
            logits.iter().map(|t| Value::Num(t.norm() as f64)).collect();
        fields.push(("logits_norms", Value::Arr(norms)));
    }
    Value::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal() {
        let v = Value::parse(r#"{"tokens": [1, 2, 3]}"#).unwrap();
        let r = parse_request(&v, || 42).unwrap();
        assert_eq!(r.id, 42);
        assert_eq!(r.tokens, vec![1, 2, 3]);
        assert!(r.mode.is_none());
        assert!(!r.want_logits);
    }

    #[test]
    fn parse_full() {
        let v = Value::parse(r#"{"id": 7, "tokens": [5], "mode": "seq", "want_logits": true}"#)
            .unwrap();
        let r = parse_request(&v, || 0).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.mode, Some(ExecMode::Sequential));
        assert!(r.want_logits);
    }

    #[test]
    fn response_carries_utilization_stats() {
        use crate::scheduler::RunStats;
        use std::time::Duration;
        let resp = Response {
            id: 3,
            greedy_tail: vec![1, 2],
            logits: None,
            mode_used: ExecMode::Diagonal,
            stats: RunStats {
                mode_diagonal: true,
                segments: 4,
                launches: 6,
                cells: 12,
                slot_steps: 18,
                padded_cells: 6,
                wall: Duration::from_millis(1),
                tokens: 32,
            },
            latency: Duration::from_millis(2),
        };
        let v = render_response(&resp);
        assert_eq!(v.req("cells").unwrap().as_usize().unwrap(), 12);
        assert_eq!(v.req("padded_cells").unwrap().as_usize().unwrap(), 6);
        let occ = v.req("occupancy").unwrap().as_f64().unwrap();
        assert!((occ - 12.0 / 18.0).abs() < 1e-9, "occupancy {occ}");
        assert_eq!(v.req("mean_group").unwrap().as_f64().unwrap(), 2.0);
    }

    #[test]
    fn parse_rejects_bad_fields() {
        for bad in [
            r#"{"mode": "diag"}"#,                   // missing tokens
            r#"{"tokens": "x"}"#,                    // wrong type
            r#"{"tokens": [1], "mode": "warp"}"#,    // bad mode
            r#"{"tokens": [-1]}"#,                   // negative token
        ] {
            let v = Value::parse(bad).unwrap();
            assert!(parse_request(&v, || 0).is_err(), "{bad}");
        }
    }
}
