//! TCP JSON-lines inference server + client: the streaming generation
//! protocol.
//!
//! Wire protocol (one JSON object per line; `<-` lines are frames the
//! server streams back — one line per engine [`Event`]):
//!
//! ```text
//! -> {"id": 1, "tokens": [3, 17, ...], "max_new_tokens": 64,
//!     "temperature": 0.8?, "top_k": 40?, "seed": 7?, "deadline_ms": 5000?,
//!     "mode": "diagonal"?, "want_logits": true?, "save": true?, "resume": 9?,
//!     "overflow": "select"?}
//! <- {"id": 1, "event": "segment", "index": 0, "greedy": [...],
//!     "saturation": 0.38}
//! <- {"id": 1, "event": "token", "pos": 0, "token": 17}
//! <- {"id": 1, "event": "token", "pos": 1, "token": 3}
//! <- {"id": 1, "event": "done", "greedy_tail": [...], "generated": [...],
//!     "mode": "diagonal", "latency_ms": 12.3, "segments": 4, "launches": 7,
//!     "tokens": 128, "mean_group": 2.4, "cells": 12, "padded_cells": 6,
//!     "occupancy": 0.83, "reused_segments": 0, "segments_skipped": 0,
//!     "overflow_routed": false, "saturation": 0.61, "resume_token": 1?}
//! <- {"id": 1, "event": "error", "error": "cancelled"}      # terminal, instead of done
//! -> {"cmd": "cancel", "id": 1}                             # from ANY connection
//! <- {"ok": true, "id": 1}
//! -> {"cmd": "save", "id": 1}          # suspend-on-completion, from ANY connection
//! <- {"ok": true, "id": 1}
//! -> {"cmd": "stats"}
//! <- {"requests": 10, "rejected": 0, "cancelled": 1, "diagonal_runs": 9,
//!     "sequential_runs": 1, "full_attn_runs": 0, "packed_requests": 9,
//!     "tokens": 1280, "generated_tokens": 512, "launches": 63,
//!     "active_cells": 151, "slot_steps": 189, "padded_cells": 38,
//!     "mean_group": 2.4, "occupancy": 0.8,
//!     "cache_hits": 7, "cache_hit_segments": 35, "cache_bytes": 912384,
//!     "evictions": 2, "workers": 4, "pool_cells": 148,
//!     "pool_busy_ms": 310.2, "worker_utilization": 0.71,
//!     "latency_ms_mean": 10.5, "latency_ms_p50": 8.2,
//!     "latency_ms_p90": 16.4, "latency_ms_p99": 32.8,
//!     "saturation": 0.61, "segments_skipped": 3, "overflow_routed": 1}
//! -> {"cmd": "ping"}
//! <- {"ok": true}
//! -> {"cmd": "shutdown"}
//! <- {"ok": true}
//! ```
//!
//! **Shard extensions** (see [`crate::shard`]). Requests accept two
//! extra fields: `"checkpoint": true` streams a non-terminal
//! `{"event": "snapshot", "index": k, "state": {...}}` frame at every
//! segment boundary (the coordinator's failover checkpoints — never
//! forwarded to end clients), and `"resume_state": {...}` carries an
//! inline [`MemSnapshot`](crate::cache::MemSnapshot) to seed the
//! recurrence directly (the failover re-admission path; no prior save
//! on this worker needed). A server started with a shard backend
//! ([`Server::start_with`], the `worker` subcommand) additionally
//! serves the layer-range pipeline protocol — single-reply commands,
//! state travelling as bit-exact snapshot JSON:
//!
//! ```text
//! -> {"cmd": "shard_init", "sid": 9, "lo": 0, "hi": 2}   # host layers [lo, hi)
//! <- {"ok": true, "sid": 9}
//! -> {"cmd": "shard_load", "sid": 9, "lo": 0, "hi": 2, "state": {...}}
//! <- {"ok": true, "sid": 9}
//! -> {"cmd": "shard_segment", "sid": 9, "tokens": [...]}     # first range only
//! -> {"cmd": "shard_segment", "sid": 9, "x_bits": [...], "x_shape": [T, d]}
//! <- {"sid": 9, "segments": 3, "state": {...},               # range [lo, hi) state
//!     "x_bits": [...], "x_shape": [T, d]}                    # or, on the last range:
//! <- {"sid": 9, "segments": 3, "state": {...}, "logits_bits": [...]}
//! -> {"cmd": "shard_state", "sid": 9}
//! <- {"sid": 9, "segments": 3, "state": {...}}
//! -> {"cmd": "shard_drop", "sid": 9}
//! <- {"ok": true, "sid": 9}
//! ```
//!
//! **Memory-state cache.** With `--cache-bytes N` the engine runs the
//! prefix-reuse cache ([`crate::cache`]): prompts sharing a cached
//! segment-block prefix skip its prefill entirely (`reused_segments`
//! in the `done` frame; `segment` event indices start after the
//! reused prefix), bit-exactly. Conversation suspend/resume rides the
//! same snapshots: `"save": true` on a request — or `{"cmd": "save",
//! "id": N}` from any connection while it is active — retains its
//! final memory state under an engine-assigned token, echoed as
//! `resume_token` in the `done` frame (tokens are unique, saves never
//! alias another conversation; retention is LRU-capped); a later
//! request with `"resume": token` continues that conversation
//! carrying ONLY the new tokens (zero history re-prefill). Saved
//! state lives in the engine; mid-flight saves need the cache enabled
//! (capture is only armed for every packed request then — without it
//! the save cmd is refused with an error instead of acking a no-op),
//! while `"save": true` at submission always works.
//!
//! Every request produces a stream of event frames ending in a terminal
//! `done` or `error`; a pure prefill request (`max_new_tokens` absent
//! or 0) streams its per-segment partial results and then `done`.
//! `event` is the frame discriminator; `ping`/`stats`/`cancel` replies
//! are single plain objects. Each connection is strictly sequential —
//! one request, its full event stream, then the next line is read — so
//! a `cancel` for an in-flight stream must come from a *different*
//! connection (which is what the `generate --cancel-after` CLI and a
//! dropped-connection eviction do). Request `id`s must be unique among
//! ACTIVE requests (they key cross-connection `cancel`); omit `id` to
//! have the server assign one.
//!
//! Topology: connection threads parse and enqueue; ONE engine thread
//! drains the bounded queue into a persistent packed wavefront
//! ([`InferenceEngine::serve_queue`]) — concurrent requests (prefill
//! AND in-wavefront decode) share grouped launches and fill each
//! other's ramp bubbles, and events stream back out of submission
//! order (each connection blocks only on its own event channel).
//! Backpressure stays explicit (`{"event": "error", "error": "queue
//! full"}`), and per-request event buffers are bounded: a client that
//! stalls its socket far enough for the buffer to fill is cancelled
//! (slow-consumer eviction) instead of growing server memory. A client
//! that disconnects mid-stream is detected on the next failed frame
//! write; its request is cancelled and evicted from the wavefront,
//! leaving every other in-flight request bit-exact.
//!
//! Admission runs through the gateway's weighted-fair scheduler
//! ([`crate::gateway::FairScheduler`]) rather than a plain FIFO: the
//! TCP path admits as the built-in open `local` tenant (with no
//! configured tenants that is exactly FIFO), and
//! [`ServerOptions::http`] binds the HTTP/1.1 + SSE front end
//! ([`crate::gateway::http`]) on the same scheduler, cancel registry,
//! wire-id namespace and stats — per-tenant API keys, token buckets and
//! `GET /metrics` included. Shutdown (protocol `{"cmd": "shutdown"}`,
//! `POST /admin/shutdown`, or [`Server::stop`]) drains: every request
//! already admitted still streams its terminal `done`/`error` frame,
//! and [`Server::join`]/[`Server::stop`] wait (bounded) for in-flight
//! streams to finish flushing before returning.

mod protocol;

pub use protocol::{parse_request, render_done, render_event};

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ExecMode;
use crate::coordinator::{
    EngineStats, Event, GenerateRequest, InferenceEngine, RequestHandle,
};
use crate::error::{Error, Result};
use crate::gateway::http::{handle_http_conn, HttpShared};
use crate::gateway::{FairScheduler, TenantSpec, LOCAL_TENANT};
use crate::json::Value;
use crate::scheduler::StepBackend;
use crate::shard::{FaultPlan, FaultState, ShardService};

/// Events buffered per in-flight request before the slow-consumer
/// eviction kicks in. Bounds server memory: a stalled client can hold
/// at most this many events (pre-streaming, each request buffered
/// exactly one response; tokens stream now, so give decode some slack).
pub(crate) const EVENT_BUFFER: usize = 1024;

/// Per-connection reply route: a BOUNDED event channel plus the
/// request's cancel handle. The engine thread only ever `try_send`s —
/// if the buffer is full (the client stalled far beyond it), the
/// request is cancelled instead of buffering without bound, and the
/// ticket drop closes the channel to wake the connection thread.
pub(crate) struct ConnTicket {
    pub(crate) tx: mpsc::SyncSender<Event>,
    pub(crate) handle: RequestHandle,
    /// Tenant the request was admitted under (for the completion-time
    /// fair-share re-credit).
    pub(crate) tenant: usize,
    /// Decode budget (`max_new_tokens`) the admission cost charged for;
    /// the unspent part is re-credited on the `done` frame.
    pub(crate) budget: usize,
}

pub(crate) type Job = (GenerateRequest, ConnTicket);

/// Active-request cancellation handles, keyed by wire id (so
/// `{"cmd": "cancel", "id": N}` works from any connection).
pub(crate) type CancelRegistry = Arc<Mutex<HashMap<u64, RequestHandle>>>;

/// Admission cost of a request under weighted-fair scheduling: total
/// tokens it will occupy the wavefront with (prompt + decode budget).
pub(crate) fn job_cost(req: &GenerateRequest) -> f64 {
    (req.prompt.len() + req.max_new_tokens) as f64
}

/// How long `stop`/`join` wait for in-flight streams to flush their
/// terminal frame after the engine and acceptors have exited. Bounded
/// so one client that never drains its socket can't wedge shutdown.
const STREAM_DRAIN_TIMEOUT: Duration = Duration::from_secs(10);

/// Counts connection threads that are inside a request's streaming
/// section (between admission and the terminal frame's flush).
/// `stop`/`join` wait for the count to reach zero so every admitted
/// request's `done`/`error` frame is on the wire before they return —
/// threads idle at the read loop (no request in flight) are not
/// counted and simply die with the process.
#[derive(Clone, Default)]
pub(crate) struct WaitGroup(Arc<(Mutex<usize>, Condvar)>);

impl WaitGroup {
    /// Enter the guarded section; the returned guard exits it on drop.
    pub(crate) fn enter(&self) -> WaitGuard {
        *self.0 .0.lock().unwrap() += 1;
        WaitGuard(self.0.clone())
    }

    /// Wait (bounded) for the count to reach zero. Returns whether it
    /// drained in time.
    fn wait_drained(&self, timeout: Duration) -> bool {
        let (lock, cv) = &*self.0;
        let deadline = Instant::now() + timeout;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = cv.wait_timeout(n, deadline - now).unwrap();
            n = guard;
        }
        true
    }
}

pub(crate) struct WaitGuard(Arc<(Mutex<usize>, Condvar)>);

impl Drop for WaitGuard {
    fn drop(&mut self) {
        let (lock, cv) = &*self.0;
        *lock.lock().unwrap() -= 1;
        cv.notify_all();
    }
}

/// Optional server capabilities beyond plain serving
/// ([`Server::start_with`]).
#[derive(Default)]
pub struct ServerOptions {
    /// Serve the `{"cmd": "shard_*"}` layer-range pipeline protocol
    /// with this backend (what the `worker` subcommand enables). The
    /// shard backend is separate from the engine's: pipeline lanes are
    /// driven per-layer by the coordinator, not by the local wavefront.
    pub shard_backend: Option<Box<dyn StepBackend + Send>>,
    /// Test-only fault injection: die / stall / sever after K protocol
    /// frames (`--fault`, [`FaultPlan`]). `None` = no faults, zero
    /// overhead on the write path beyond one atomic load.
    pub fault: Option<FaultPlan>,
    /// Bind the HTTP/1.1 + SSE gateway ([`crate::gateway::http`]) on
    /// this address alongside the TCP listener (`--http`, the `gateway`
    /// subcommand). Both front ends share one engine, one weighted-fair
    /// scheduler, one cancel registry and one wire-id namespace.
    pub http: Option<String>,
    /// Gateway tenants ([`TenantSpec`], the `--tenants` flag). The
    /// built-in open `local` tenant (used by the TCP path and by
    /// unauthenticated HTTP when this is empty) is always added first.
    pub tenants: Vec<TenantSpec>,
}

/// Handle to a running server.
pub struct Server {
    pub addr: std::net::SocketAddr,
    /// Bound address of the HTTP/SSE gateway ([`ServerOptions::http`]).
    pub http_addr: Option<std::net::SocketAddr>,
    accept_thread: Option<JoinHandle<()>>,
    http_thread: Option<JoinHandle<()>>,
    engine_thread: Option<JoinHandle<()>>,
    queue: Arc<FairScheduler<Job>>,
    shutdown: Arc<AtomicBool>,
    streams: WaitGroup,
    /// Live engine counters (readable after `stop` too).
    pub stats: Arc<EngineStats>,
}

impl Server {
    /// Start serving `engine` on `addr` (use port 0 for an ephemeral
    /// port; the bound address is in `server.addr`).
    pub fn start<B: StepBackend + Send + 'static>(
        engine: InferenceEngine<B>,
        addr: &str,
        queue_depth: usize,
    ) -> Result<Self> {
        Self::start_with(engine, addr, queue_depth, ServerOptions::default())
    }

    /// [`start`](Self::start) plus shard-worker duty and/or fault
    /// injection ([`ServerOptions`]).
    pub fn start_with<B: StepBackend + Send + 'static>(
        mut engine: InferenceEngine<B>,
        addr: &str,
        queue_depth: usize,
        opts: ServerOptions,
    ) -> Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let queue = Arc::new(FairScheduler::<Job>::new(opts.tenants, queue_depth));
        let shutdown = Arc::new(AtomicBool::new(false));
        let stats = engine.stats_handle();
        let streams = WaitGroup::default();
        // Auto-assigned wire ids share one namespace across the TCP and
        // HTTP front ends (cancel-by-id must be unambiguous).
        let next_id = Arc::new(AtomicU64::new(1));
        // Mid-flight {"cmd": "save"} only works when the engine arms
        // snapshot capture for every packed request (cache enabled);
        // the reply must say so instead of acknowledging a no-op.
        let mid_flight_save = engine.cache_enabled();
        let registry: CancelRegistry = Arc::new(Mutex::new(HashMap::new()));
        let shard = opts.shard_backend.map(|b| Arc::new(Mutex::new(ShardService::new(b))));
        let fault = Arc::new(FaultState::new(opts.fault));

        // Engine thread: continuous-batching drain loop — every
        // diagonal-mode request packs into one persistent wavefront;
        // each job's event channel receives its stream as it happens
        // (out of submission order).
        let q2 = queue.clone();
        let engine_thread = std::thread::spawn(move || {
            if let Err(e) = engine.serve_queue(&q2, |t: &ConnTicket, ev| {
                if let Event::Done { stats } = &ev {
                    // Decode-aware re-credit: admission charged the full
                    // prompt + max_new_tokens budget; give the tenant's
                    // fair-share clock back whatever the request didn't
                    // actually generate (EOS, deadline, cancel-free
                    // early stop).
                    let excess = t.budget.saturating_sub(stats.generated.len());
                    q2.recredit(t.tenant, excess as f64);
                }
                if t.tx.try_send(ev).is_err() {
                    // Slow consumer: the connection thread is stalled in
                    // a socket write and the bounded buffer is full.
                    // Cancel the request — the engine evicts its lane;
                    // the doomed stream's dropped events don't matter
                    // because the ticket drop closes the channel and
                    // wakes the connection thread.
                    t.handle.cancel();
                }
            }) {
                crate::logline!(
                    crate::trace::log::Level::Error,
                    "server",
                    "engine loop aborted: {e}"
                );
                // Fail fast instead of stranding clients: close the
                // queue (new pushes get "queue closed") and fail every
                // job already enqueued so its connection thread's
                // rx.recv() returns a terminal event.
                q2.close();
                while let Some((_req, t)) = q2.try_pop() {
                    let _ = t.tx.try_send(Event::Error {
                        error: Error::Request(format!("engine stopped: {e}")),
                    });
                }
            }
        });

        // Acceptor: one lightweight thread per connection.
        let q3 = queue.clone();
        let sd = shutdown.clone();
        let st = stats.clone();
        let reg = registry.clone();
        let ids_tcp = next_id.clone();
        let wg = streams.clone();
        let accept_thread = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if sd.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                if fault.is_dead() {
                    // Injected death: accept and immediately drop, so
                    // health probes see EOF instead of a reply.
                    continue;
                }
                let q = q3.clone();
                let sd2 = sd.clone();
                let ids = ids_tcp.clone();
                let stats = st.clone();
                let registry = reg.clone();
                let shard = shard.clone();
                let fault = fault.clone();
                let wg = wg.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(
                        stream,
                        &q,
                        &sd2,
                        &ids,
                        &stats,
                        &registry,
                        mid_flight_save,
                        shard.as_deref(),
                        &fault,
                        &wg,
                    );
                });
            }
        });

        // Optional HTTP/SSE gateway on the same scheduler + registry.
        let (http_addr, http_thread) = match opts.http {
            None => (None, None),
            Some(http) => {
                let http_listener = TcpListener::bind(http.as_str())?;
                let bound = http_listener.local_addr()?;
                let shared = Arc::new(HttpShared {
                    sched: queue.clone(),
                    registry: registry.clone(),
                    stats: stats.clone(),
                    shutdown: shutdown.clone(),
                    next_id: next_id.clone(),
                    streams: streams.clone(),
                    owners: Arc::new(std::sync::Mutex::new(std::collections::HashMap::new())),
                });
                let sd = shutdown.clone();
                let thread = std::thread::spawn(move || {
                    for stream in http_listener.incoming() {
                        if sd.load(Ordering::SeqCst) {
                            break;
                        }
                        let Ok(stream) = stream else { continue };
                        let shared = shared.clone();
                        std::thread::spawn(move || {
                            let _ = handle_http_conn(stream, &shared);
                        });
                    }
                });
                (Some(bound), Some(thread))
            }
        };

        Ok(Self {
            addr: local,
            http_addr,
            accept_thread: Some(accept_thread),
            http_thread,
            engine_thread: Some(engine_thread),
            queue,
            shutdown,
            streams,
            stats,
        })
    }

    /// Request shutdown and join the worker threads. The acceptors are
    /// unblocked by self-connections; requests already admitted still
    /// stream their terminal frame (bounded wait) before this returns.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.queue.close();
        if let Some(t) = self.engine_thread.take() {
            let _ = t.join();
        }
        self.teardown_front_ends();
    }

    /// Run in the foreground until a protocol `{"cmd": "shutdown"}` /
    /// `POST /admin/shutdown` (or an engine abort) terminates the
    /// engine thread, then tear down the acceptors and return — the
    /// clean-exit path the `serve` subcommand blocks on. In-flight
    /// streams flush their terminal frame first (bounded wait).
    pub fn join(mut self) {
        if let Some(t) = self.engine_thread.take() {
            let _ = t.join();
        }
        self.shutdown.store(true, Ordering::SeqCst);
        self.teardown_front_ends();
    }

    /// Join both acceptors (self-connect to unblock `accept()`), then
    /// wait — bounded — for connection threads still inside a streaming
    /// section to flush their terminal `done`/`error` frame. Called
    /// only after the engine thread has exited, so every in-flight
    /// stream already has its terminal event queued.
    fn teardown_front_ends(&mut self) {
        let _ = TcpStream::connect(self.addr); // unblock accept()
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(addr) = self.http_addr {
            let _ = TcpStream::connect(addr);
        }
        if let Some(t) = self.http_thread.take() {
            let _ = t.join();
        }
        if !self.streams.wait_drained(STREAM_DRAIN_TIMEOUT) {
            crate::logline!(
                crate::trace::log::Level::Warn,
                "server",
                "shutdown: gave up waiting for stalled client streams after {:?}",
                STREAM_DRAIN_TIMEOUT
            );
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_conn(
    stream: TcpStream,
    queue: &FairScheduler<Job>,
    shutdown: &AtomicBool,
    ids: &AtomicU64,
    stats: &EngineStats,
    registry: &CancelRegistry,
    mid_flight_save: bool,
    shard: Option<&Mutex<ShardService>>,
    fault: &FaultState,
    streams: &WaitGroup,
) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if fault.is_dead() {
            return Ok(()); // injected worker death: all connections go silent
        }
        if line.trim().is_empty() {
            continue;
        }
        let v = match Value::parse(&line) {
            Err(e) => {
                writeln!(writer, "{}", error_json(None, &Error::Json(e.to_string())))?;
                continue;
            }
            Ok(v) => v,
        };

        // Control commands reply with a single plain object.
        if let Some(cmd) = v.get("cmd").and_then(|c| c.as_str().ok().map(String::from)) {
            match cmd.as_str() {
                "shutdown" => {
                    shutdown.store(true, Ordering::SeqCst);
                    queue.close();
                    writeln!(
                        writer,
                        "{}",
                        Value::obj(vec![("ok", Value::Bool(true))]).to_json()
                    )?;
                    break;
                }
                "ping" => {
                    writeln!(
                        writer,
                        "{}",
                        Value::obj(vec![("ok", Value::Bool(true))]).to_json()
                    )?;
                }
                "stats" => writeln!(writer, "{}", stats.to_json().to_json())?,
                "trace" => {
                    // Snapshot of the in-memory span ring (Chrome-trace
                    // events, sorted) — works with or without
                    // --trace-file, returns [] when tracing is off.
                    writeln!(
                        writer,
                        "{}",
                        Value::obj(vec![
                            ("ok", Value::Bool(true)),
                            ("enabled", Value::Bool(crate::trace::enabled())),
                            ("dropped", Value::Num(crate::trace::dropped() as f64)),
                            ("events", crate::trace::export_value()),
                        ])
                        .to_json()
                    )?;
                }
                "cancel" | "save" => match v.get("id").map(Value::as_u64).transpose() {
                    Ok(Some(_)) if cmd == "save" && !mid_flight_save => {
                        // Without the cache, capture is only armed for
                        // requests submitted with "save": true — a
                        // mid-flight flag would be a silent no-op, so
                        // refuse it honestly.
                        writeln!(
                            writer,
                            "{}",
                            error_json(
                                None,
                                &Error::Request(
                                    "mid-flight save requires the server to run with \
                                     --cache-bytes; submit the request with \"save\": true \
                                     instead"
                                        .into(),
                                )
                            )
                        )?;
                    }
                    Ok(Some(id)) => {
                        let found = registry
                            .lock()
                            .unwrap()
                            .get(&id)
                            .map(|h| {
                                if cmd == "cancel" {
                                    h.cancel();
                                } else {
                                    // Suspend-on-completion: the engine
                                    // retains the request's final memory
                                    // state under this wire id.
                                    h.request_save();
                                }
                                true
                            })
                            .unwrap_or(false);
                        writeln!(
                            writer,
                            "{}",
                            Value::obj(vec![
                                ("ok", Value::Bool(found)),
                                ("id", Value::Num(id as f64)),
                            ])
                            .to_json()
                        )?;
                    }
                    _ => writeln!(
                        writer,
                        "{}",
                        error_json(None, &Error::Request(format!("{cmd} needs a numeric id")))
                    )?,
                },
                "shard_init" | "shard_load" | "shard_segment" | "shard_state" | "shard_drop" => {
                    let reply = match shard {
                        None => error_json(
                            None,
                            &Error::Request(format!(
                                "{cmd} needs a shard worker (start with `worker`, not `serve`)"
                            )),
                        ),
                        Some(svc) => match svc.lock().unwrap().handle(&cmd, &v) {
                            Ok(val) => val.to_json(),
                            Err(e) => error_json(None, &e),
                        },
                    };
                    // Shard replies count as protocol frames for fault
                    // injection: a "dead" worker severs mid-pipeline.
                    if !fault.before_frame() {
                        return Ok(());
                    }
                    writeln!(writer, "{reply}")?;
                }
                other => writeln!(
                    writer,
                    "{}",
                    error_json(None, &Error::Request(format!("unknown cmd '{other}'")))
                )?,
            }
            continue;
        }

        // Inference request: enqueue, then stream its events back.
        // Auto-assigned ids share a namespace with client-chosen ones,
        // so skip over any id a client currently holds active.
        let next_auto_id = || loop {
            let candidate = ids.fetch_add(1, Ordering::Relaxed);
            if !registry.lock().unwrap().contains_key(&candidate) {
                return candidate;
            }
        };
        let req = match parse_request(&v, next_auto_id) {
            Err(e) => {
                writeln!(writer, "{}", error_json(None, &e))?;
                continue;
            }
            Ok(req) => req,
        };
        let wire_id = req.id;
        let handle = req.handle();
        {
            let mut reg = registry.lock().unwrap();
            if reg.contains_key(&wire_id) {
                drop(reg);
                writeln!(
                    writer,
                    "{}",
                    error_json(
                        Some(wire_id),
                        &Error::Request(format!("id {wire_id} already in flight")),
                    )
                )?;
                continue;
            }
            reg.insert(wire_id, handle.clone());
        }
        let (tx, rx) = mpsc::sync_channel::<Event>(EVENT_BUFFER);
        // Hold a stream guard from admission to terminal-frame flush:
        // `stop`/`join` wait on it so shutdown never strands an
        // admitted request without its `done`/`error` frame.
        let _stream_guard = streams.enter();
        let cost = job_cost(&req);
        let budget = req.max_new_tokens;
        let ticket =
            ConnTicket { tx, handle: handle.clone(), tenant: LOCAL_TENANT, budget };
        if let Err(e) = queue.push(LOCAL_TENANT, cost, (req, ticket)) {
            registry.lock().unwrap().remove(&wire_id);
            writeln!(writer, "{}", error_json(Some(wire_id), &e))?;
            continue;
        }
        // Stream until the terminal event. A failed write means the
        // client disconnected mid-stream: cancel the request (the
        // engine evicts its lane) and keep draining so the channel
        // closes cleanly.
        let mut client_gone = false;
        loop {
            match rx.recv() {
                Ok(ev) => {
                    let terminal = ev.is_terminal();
                    if !client_gone {
                        // Fault injection severs the stream exactly like
                        // a crashed worker: the request is cancelled and
                        // the socket closes without a terminal frame.
                        if !fault.before_frame() {
                            client_gone = true;
                            handle.cancel();
                        } else {
                            let frame = render_event(wire_id, &ev).to_json();
                            if writeln!(writer, "{frame}").is_err() {
                                client_gone = true;
                                handle.cancel();
                            }
                        }
                    }
                    if terminal {
                        break;
                    }
                }
                Err(_) => {
                    // Channel closed without a terminal frame: the
                    // engine thread died, or the slow-consumer eviction
                    // dropped the terminal event after the buffer
                    // filled. Tell the client if it still listens.
                    if !client_gone {
                        let _ = writeln!(
                            writer,
                            "{}",
                            error_json(
                                Some(wire_id),
                                &Error::Request(
                                    "request stream closed (engine stopped or evicted)".into(),
                                )
                            )
                        );
                    }
                    break;
                }
            }
        }
        registry.lock().unwrap().remove(&wire_id);
        if client_gone {
            return Ok(()); // reads would fail too; connection is dead
        }
    }
    Ok(())
}

/// Render a protocol error frame (shared with the HTTP front end,
/// whose error bodies are the same JSON objects).
pub(crate) fn error_json(id: Option<u64>, e: &Error) -> String {
    let mut fields = vec![
        ("event", Value::Str("error".into())),
        ("error", Value::Str(e.to_string())),
    ];
    if let Some(id) = id {
        fields.push(("id", Value::Num(id as f64)));
    }
    Value::obj(fields).to_json()
}

/// Blocking line-protocol client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Self { reader: BufReader::new(stream), writer })
    }

    fn read_frame(&mut self) -> Result<Value> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        if line.is_empty() {
            return Err(Error::Request("server closed connection".into()));
        }
        Value::parse(&line)
    }

    /// Send one object, wait for the one-line reply (control commands).
    pub fn roundtrip(&mut self, v: &Value) -> Result<Value> {
        writeln!(self.writer, "{}", v.to_json())?;
        self.read_frame()
    }

    /// Send a request frame and consume its whole event stream:
    /// non-terminal frames go to `on_event`, the terminal `done` frame
    /// is returned, a terminal `error` frame becomes `Err`.
    pub fn request_stream(
        &mut self,
        v: &Value,
        mut on_event: impl FnMut(&Value),
    ) -> Result<Value> {
        writeln!(self.writer, "{}", v.to_json())?;
        loop {
            let frame = self.read_frame()?;
            match frame.get("event").and_then(|e| e.as_str().ok()) {
                Some("done") => return Ok(frame),
                Some("error") => {
                    let msg = frame
                        .get("error")
                        .and_then(|e| e.as_str().ok())
                        .unwrap_or("?")
                        .to_string();
                    return Err(Error::Request(msg));
                }
                _ => on_event(&frame),
            }
        }
    }

    /// Run inference on a token sequence (prefill only); returns the
    /// terminal `done` frame.
    pub fn infer(&mut self, tokens: &[u32], mode: Option<ExecMode>) -> Result<Value> {
        let mut fields = vec![("tokens", Value::arr_u32(tokens))];
        if let Some(m) = mode {
            fields.push(("mode", Value::Str(m.to_string())));
        }
        self.request_stream(&Value::obj(fields), |_| {})
    }

    /// Stream a generation: `on_event` sees every `segment`/`token`
    /// frame; returns the terminal `done` frame.
    pub fn generate(
        &mut self,
        tokens: &[u32],
        max_new_tokens: usize,
        on_event: impl FnMut(&Value),
    ) -> Result<Value> {
        self.request_stream(
            &Value::obj(vec![
                ("tokens", Value::arr_u32(tokens)),
                ("max_new_tokens", Value::Num(max_new_tokens as f64)),
            ]),
            on_event,
        )
    }

    /// Cancel the active request with wire id `id`. Connections are
    /// strictly sequential, so this must be sent on a connection that
    /// is NOT currently consuming that request's stream (open a second
    /// `Client` to cancel your own). Returns whether the server knew
    /// the id.
    pub fn cancel(&mut self, id: u64) -> Result<bool> {
        let resp = self.roundtrip(&Value::obj(vec![
            ("cmd", Value::Str("cancel".into())),
            ("id", Value::Num(id as f64)),
        ]))?;
        Ok(resp.get("ok").map(|v| v.as_bool().unwrap_or(false)).unwrap_or(false))
    }

    pub fn ping(&mut self) -> Result<bool> {
        let resp = self.roundtrip(&Value::obj(vec![("cmd", Value::Str("ping".into()))]))?;
        Ok(resp.get("ok").map(|v| v.as_bool().unwrap_or(false)).unwrap_or(false))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        let _ = self.roundtrip(&Value::obj(vec![("cmd", Value::Str("shutdown".into()))]))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NativeBackend, Params};

    fn test_engine() -> InferenceEngine<NativeBackend> {
        let cfg = crate::model::tests::test_config();
        let params = Params::random(&cfg, 21);
        InferenceEngine::new(NativeBackend::new(cfg, params), ExecMode::Diagonal)
    }

    #[test]
    fn roundtrip_over_tcp() {
        let server = Server::start(test_engine(), "127.0.0.1:0", 8).unwrap();
        let addr = server.addr.to_string();
        let mut client = Client::connect(&addr).unwrap();
        assert!(client.ping().unwrap());

        let tokens: Vec<u32> = (0..16).map(|i| i % 60).collect();
        let resp = client.infer(&tokens, None).unwrap();
        assert_eq!(resp.req("event").unwrap().as_str().unwrap(), "done");
        assert_eq!(resp.req("mode").unwrap().as_str().unwrap(), "diagonal");
        assert_eq!(resp.req("tokens").unwrap().as_usize().unwrap(), 16);
        assert_eq!(
            resp.req("greedy_tail").unwrap().as_arr().unwrap().len(),
            8 // test config seg
        );
        assert!(resp.req("generated").unwrap().as_u32_vec().unwrap().is_empty());

        // mode override
        let resp = client.infer(&tokens, Some(ExecMode::Sequential)).unwrap();
        assert_eq!(resp.req("mode").unwrap().as_str().unwrap(), "sequential");

        // malformed input -> error frame, connection stays usable
        let bad = client
            .roundtrip(&Value::obj(vec![("tokens", Value::Str("x".into()))]))
            .unwrap();
        assert!(bad.get("error").is_some());
        assert_eq!(bad.req("event").unwrap().as_str().unwrap(), "error");
        assert!(client.ping().unwrap());

        server.stop();
    }

    #[test]
    fn generation_streams_over_tcp() {
        let server = Server::start(test_engine(), "127.0.0.1:0", 8).unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let tokens: Vec<u32> = (0..16).map(|i| i % 60).collect();

        let mut streamed: Vec<u32> = Vec::new();
        let mut segments = 0usize;
        let done = client
            .generate(&tokens, 12, |frame| {
                match frame.req("event").unwrap().as_str().unwrap() {
                    "token" => streamed.push(frame.req("token").unwrap().as_u32().unwrap()),
                    "segment" => segments += 1,
                    other => panic!("unexpected frame {other}"),
                }
            })
            .unwrap();
        let generated = done.req("generated").unwrap().as_u32_vec().unwrap();
        assert_eq!(generated.len(), 12);
        assert_eq!(streamed, generated, "streamed tokens match the aggregate");
        // 2 prompt segments + 1 fed decode segment exited.
        assert_eq!(segments, 3);
        assert_eq!(done.req("segments").unwrap().as_usize().unwrap(), 3);
        server.stop();
    }

    #[test]
    fn cancel_from_second_connection() {
        let server = Server::start(test_engine(), "127.0.0.1:0", 8).unwrap();
        let addr = server.addr.to_string();
        let mut gen_conn = Client::connect(&addr).unwrap();
        let tokens: Vec<u32> = (0..16).map(|i| i % 60).collect();

        // Big decode budget so the cancel lands mid-stream.
        let err = {
            let mut canceller = Client::connect(&addr).unwrap();
            let mut cancelled = false;
            gen_conn
                .request_stream(
                    &Value::obj(vec![
                        ("id", Value::Num(7.0)),
                        ("tokens", Value::arr_u32(&tokens)),
                        ("max_new_tokens", Value::Num(200_000.0)),
                    ]),
                    |frame| {
                        if !cancelled
                            && frame.req("event").unwrap().as_str().unwrap() == "token"
                        {
                            cancelled = true;
                            assert!(canceller.cancel(7).unwrap(), "id 7 must be active");
                        }
                    },
                )
                .unwrap_err()
        };
        assert!(err.to_string().contains("cancelled"), "{err}");

        // Unknown ids report ok: false; the server keeps serving.
        let mut c = Client::connect(&addr).unwrap();
        assert!(!c.cancel(999).unwrap());
        assert!(c.infer(&tokens, None).is_ok());
        let stats = c
            .roundtrip(&Value::obj(vec![("cmd", Value::Str("stats".into()))]))
            .unwrap();
        assert_eq!(stats.req("cancelled").unwrap().as_usize().unwrap(), 1);
        server.stop();
    }

    #[test]
    fn duplicate_active_ids_rejected() {
        let server = Server::start(test_engine(), "127.0.0.1:0", 8).unwrap();
        let addr = server.addr.to_string();
        let mut a = Client::connect(&addr).unwrap();
        let tokens: Vec<u32> = (0..16).map(|i| i % 60).collect();

        let mut b = Client::connect(&addr).unwrap();
        let mut clashed = false;
        // Budget far beyond what can finish before the probe: id 5 is
        // guaranteed active when the second connection tries to reuse
        // it, and the cancel below ends the stream deterministically.
        let err = a
            .request_stream(
                &Value::obj(vec![
                    ("id", Value::Num(5.0)),
                    ("tokens", Value::arr_u32(&tokens)),
                    ("max_new_tokens", Value::Num(200_000.0)),
                ]),
                |frame| {
                    if !clashed && frame.req("event").unwrap().as_str().unwrap() == "token" {
                        clashed = true;
                        // Same id while active -> rejected with an error
                        // frame on the second connection.
                        let err = b.infer_with_id(5, &tokens).unwrap_err();
                        assert!(err.to_string().contains("already in flight"), "{err}");
                        assert!(b.cancel(5).unwrap());
                    }
                },
            )
            .unwrap_err();
        assert!(clashed, "the stream produced tokens");
        assert!(err.to_string().contains("cancelled"), "{err}");
        // After the terminal event the id is free again.
        assert!(b.infer_with_id(5, &tokens).is_ok());
        server.stop();
    }

    impl Client {
        /// Test helper: prefill with an explicit wire id.
        fn infer_with_id(&mut self, id: u64, tokens: &[u32]) -> Result<Value> {
            self.request_stream(
                &Value::obj(vec![
                    ("id", Value::Num(id as f64)),
                    ("tokens", Value::arr_u32(tokens)),
                ]),
                |_| {},
            )
        }
    }

    #[test]
    fn save_and_resume_over_tcp() {
        let cfg = crate::model::tests::test_config();
        let engine = InferenceEngine::new(
            NativeBackend::new(cfg.clone(), Params::random(&cfg, 21)),
            ExecMode::Diagonal,
        )
        .with_cache_bytes(1 << 22);
        let server = Server::start(engine, "127.0.0.1:0", 8).unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let tokens: Vec<u32> = (0..16).map(|i| i % 60).collect();

        // Turn 1: generate 16 tokens and save the conversation. One
        // decode segment (seg = 8) is fed back, so the saved state
        // covers 2 prompt + 1 decode segments.
        let done = client
            .request_stream(
                &Value::obj(vec![
                    ("id", Value::Num(5.0)),
                    ("tokens", Value::arr_u32(&tokens)),
                    ("max_new_tokens", Value::Num(16.0)),
                    ("save", Value::Bool(true)),
                ]),
                |_| {},
            )
            .unwrap();
        let token = done.req("resume_token").unwrap().as_u64().unwrap();
        assert_eq!(done.req("reused_segments").unwrap().as_usize().unwrap(), 0);

        // Turn 2: resume by token, carrying ONLY the new tokens — the
        // saved history is never re-prefilled.
        let new_toks: Vec<u32> = (0..8).map(|i| (i + 7) % 60).collect();
        let done2 = client
            .request_stream(
                &Value::obj(vec![
                    ("tokens", Value::arr_u32(&new_toks)),
                    ("resume", Value::Num(token as f64)),
                ]),
                |_| {},
            )
            .unwrap();
        assert_eq!(done2.req("reused_segments").unwrap().as_usize().unwrap(), 3);
        assert_eq!(done2.req("segments").unwrap().as_usize().unwrap(), 1);

        // Unknown resume tokens fail loudly; stats expose the cache.
        let err = client
            .request_stream(
                &Value::obj(vec![
                    ("tokens", Value::arr_u32(&new_toks)),
                    ("resume", Value::Num(999.0)),
                ]),
                |_| {},
            )
            .unwrap_err();
        assert!(err.to_string().contains("resume token"), "{err}");
        let stats = client
            .roundtrip(&Value::obj(vec![("cmd", Value::Str("stats".into()))]))
            .unwrap();
        for field in ["cache_hits", "cache_hit_segments", "cache_bytes", "evictions"] {
            assert!(stats.get(field).is_some(), "missing stats field {field}");
        }
        assert!(stats.req("cache_bytes").unwrap().as_usize().unwrap() > 0);

        // {"cmd": "save"} without an id is rejected like cancel.
        let bad = client
            .roundtrip(&Value::obj(vec![("cmd", Value::Str("save".into()))]))
            .unwrap();
        assert!(bad.get("error").is_some());
        server.stop();
    }

    #[test]
    fn mid_flight_save_refused_without_cache() {
        // No --cache-bytes: the engine never arms capture for plain
        // requests, so a mid-flight {"cmd": "save"} would silently do
        // nothing — the server must refuse it instead of acking.
        let server = Server::start(test_engine(), "127.0.0.1:0", 8).unwrap();
        let mut c = Client::connect(&server.addr.to_string()).unwrap();
        let resp = c
            .roundtrip(&Value::obj(vec![
                ("cmd", Value::Str("save".into())),
                ("id", Value::Num(1.0)),
            ]))
            .unwrap();
        let err = resp.req("error").unwrap().as_str().unwrap();
        assert!(err.contains("cache-bytes"), "{err}");
        // Submitting WITH "save": true still works without the cache.
        let tokens: Vec<u32> = (0..16).map(|i| i % 60).collect();
        let done = c
            .request_stream(
                &Value::obj(vec![
                    ("tokens", Value::arr_u32(&tokens)),
                    ("save", Value::Bool(true)),
                ]),
                |_| {},
            )
            .unwrap();
        assert!(done.get("resume_token").is_some());
        server.stop();
    }

    #[test]
    fn stats_cmd_reports_utilization() {
        let server = Server::start(test_engine(), "127.0.0.1:0", 8).unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let tokens: Vec<u32> = (0..32).map(|i| i % 60).collect();
        client.infer(&tokens, None).unwrap();
        client.infer(&tokens, Some(ExecMode::Sequential)).unwrap();

        let stats = client
            .roundtrip(&Value::obj(vec![("cmd", Value::Str("stats".into()))]))
            .unwrap();
        for field in [
            "requests",
            "cancelled",
            "diagonal_runs",
            "sequential_runs",
            "packed_requests",
            "generated_tokens",
            "launches",
            "mean_group",
            "padded_cells",
            "occupancy",
            "latency_ms_p50",
            "latency_ms_p90",
            "latency_ms_p99",
        ] {
            assert!(stats.get(field).is_some(), "missing stats field {field}");
        }
        assert_eq!(stats.req("requests").unwrap().as_usize().unwrap(), 2);
        assert_eq!(stats.req("packed_requests").unwrap().as_usize().unwrap(), 1);
        assert!(stats.req("mean_group").unwrap().as_f64().unwrap() > 0.0);
        let occ = stats.req("occupancy").unwrap().as_f64().unwrap();
        assert!(occ > 0.0 && occ <= 1.0, "occupancy {occ}");
        server.stop();
    }

    #[test]
    fn shard_cmds_require_a_worker() {
        let server = Server::start(test_engine(), "127.0.0.1:0", 8).unwrap();
        let mut c = Client::connect(&server.addr.to_string()).unwrap();
        let resp = c
            .roundtrip(&Value::obj(vec![
                ("cmd", Value::Str("shard_init".into())),
                ("sid", Value::Num(1.0)),
                ("lo", Value::Num(0.0)),
                ("hi", Value::Num(1.0)),
            ]))
            .unwrap();
        assert!(resp.req("error").unwrap().as_str().unwrap().contains("worker"));
        server.stop();
    }

    #[test]
    fn shard_segment_roundtrips_over_tcp() {
        let cfg = crate::model::tests::test_config();
        let opts = ServerOptions {
            shard_backend: Some(Box::new(NativeBackend::new(
                cfg.clone(),
                Params::random(&cfg, 21),
            ))),
            ..Default::default()
        };
        let server = Server::start_with(test_engine(), "127.0.0.1:0", 8, opts).unwrap();
        let mut c = Client::connect(&server.addr.to_string()).unwrap();
        let ok = c
            .roundtrip(&Value::obj(vec![
                ("cmd", Value::Str("shard_init".into())),
                ("sid", Value::Num(5.0)),
                ("lo", Value::Num(0.0)),
                ("hi", Value::Num(cfg.n_layers as f64)),
            ]))
            .unwrap();
        assert!(ok.req("ok").unwrap().as_bool().unwrap());
        let toks: Vec<u32> = (0..cfg.seg as u32).map(|i| i % 60).collect();
        let reply = c
            .roundtrip(&Value::obj(vec![
                ("cmd", Value::Str("shard_segment".into())),
                ("sid", Value::Num(5.0)),
                ("tokens", Value::arr_u32(&toks)),
            ]))
            .unwrap();
        // Full range [0, L): the reply is final-stage logits plus the
        // range's post-segment state.
        assert_eq!(reply.req("segments").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            reply.req("logits_bits").unwrap().as_arr().unwrap().len(),
            cfg.seg * cfg.vocab
        );
        let state =
            crate::cache::MemSnapshot::from_json(reply.req("state").unwrap()).unwrap();
        assert_eq!(state.n_layers, cfg.n_layers);
        assert_eq!(state.segments, 1);
        let dropped = c
            .roundtrip(&Value::obj(vec![
                ("cmd", Value::Str("shard_drop".into())),
                ("sid", Value::Num(5.0)),
            ]))
            .unwrap();
        assert!(dropped.req("ok").unwrap().as_bool().unwrap());
        server.stop();
    }

    #[test]
    fn injected_death_severs_streams_and_probes() {
        let opts = ServerOptions {
            fault: Some(FaultPlan::DieAfterFrames(3)),
            ..Default::default()
        };
        let server = Server::start_with(test_engine(), "127.0.0.1:0", 8, opts).unwrap();
        let addr = server.addr.to_string();
        let mut c = Client::connect(&addr).unwrap();
        let tokens: Vec<u32> = (0..16).map(|i| i % 60).collect();
        // The stream dies after 3 frames: no terminal frame, socket EOF.
        let err = c.generate(&tokens, 64, |_| {}).unwrap_err();
        assert!(err.to_string().contains("closed"), "{err}");
        // The worker stays dead: health probes get EOF, not a pong.
        let mut probe = Client::connect(&addr).unwrap();
        assert!(probe.ping().is_err());
        server.stop();
    }

    #[test]
    fn concurrent_clients_all_served() {
        let server = Server::start(test_engine(), "127.0.0.1:0", 16).unwrap();
        let addr = server.addr.to_string();
        let mut handles = Vec::new();
        for t in 0..4 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                let tokens: Vec<u32> = (0..24).map(|i| (i + t) % 60).collect();
                let resp = c.infer(&tokens, None).unwrap();
                resp.req("segments").unwrap().as_usize().unwrap()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 3);
        }
        server.stop();
    }

    /// Send one raw HTTP/1.1 request and read the whole response (the
    /// gateway closes every connection after one request, so EOF
    /// delimits the body — SSE streams included).
    fn http_roundtrip(addr: &std::net::SocketAddr, raw: &str) -> String {
        use std::io::Read as _;
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    fn http_post(
        addr: &std::net::SocketAddr,
        path: &str,
        key: Option<&str>,
        body: &str,
    ) -> String {
        let auth = key
            .map(|k| format!("Authorization: Bearer {k}\r\n"))
            .unwrap_or_default();
        let raw = format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\n{auth}Content-Length: {}\r\n\r\n{body}",
            body.len()
        );
        http_roundtrip(addr, &raw)
    }

    /// The `data:` payloads of an SSE response, in order.
    fn sse_payloads(response: &str) -> Vec<String> {
        response
            .lines()
            .filter_map(|l| l.strip_prefix("data: "))
            .map(String::from)
            .collect()
    }

    #[test]
    fn http_gateway_end_to_end() {
        use crate::gateway::TenantSpec;
        let opts = ServerOptions {
            http: Some("127.0.0.1:0".into()),
            tenants: TenantSpec::parse_list(&[
                "alice:sk-a:interactive".into(),
                // rate 0 + burst 2: a deterministic hard cap of 2
                // admissions — lets the test trip the bucket reliably.
                "capped:sk-c:standard:0:2".into(),
            ])
            .unwrap(),
            ..Default::default()
        };
        let server = Server::start_with(test_engine(), "127.0.0.1:0", 8, opts).unwrap();
        let http = server.http_addr.expect("gateway bound");
        let tcp = server.addr.to_string();

        // Liveness.
        let health = http_roundtrip(&http, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(health.starts_with("HTTP/1.1 200 OK\r\n"), "{health}");
        assert!(health.ends_with("ok\n"), "{health}");

        // Tenants are configured, so a missing key is refused.
        let resp = http_post(&http, "/v1/generate", None, "{\"tokens\": [1, 2, 3]}");
        assert!(resp.starts_with("HTTP/1.1 401 "), "{resp}");
        assert!(resp.contains("missing API key"), "{resp}");

        // Unknown routes / wrong methods are clean errors.
        let resp = http_roundtrip(&http, "GET /nope HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 404 "), "{resp}");
        let resp = http_roundtrip(&http, "GET /v1/generate HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 405 "), "{resp}");

        // The SAME request object over TCP and over HTTP/SSE: the SSE
        // `data:` payloads must be byte-identical to the TCP frame
        // lines (both render through `render_event`). Run TCP first —
        // ids only clash while active.
        let tokens: Vec<u32> = (0..16).map(|i| i % 60).collect();
        let body = Value::obj(vec![
            ("id", Value::Num(41.0)),
            ("tokens", Value::arr_u32(&tokens)),
            ("max_new_tokens", Value::Num(6.0)),
        ])
        .to_json();
        let mut tcp_frames: Vec<String> = Vec::new();
        {
            let mut s = TcpStream::connect(&tcp).unwrap();
            writeln!(s, "{body}").unwrap();
            let mut lines = BufReader::new(s).lines();
            loop {
                let line = lines.next().unwrap().unwrap();
                let done = Value::parse(&line)
                    .unwrap()
                    .req("event")
                    .unwrap()
                    .as_str()
                    .unwrap()
                    == "done";
                tcp_frames.push(line);
                if done {
                    break;
                }
            }
        }
        let resp = http_post(&http, "/v1/generate", Some("sk-a"), &body);
        assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "{resp}");
        assert!(resp.contains("Content-Type: text/event-stream\r\n"), "{resp}");
        assert!(resp.contains("event: token\n"), "{resp}");
        let sse_frames = sse_payloads(&resp);
        assert_eq!(sse_frames.len(), tcp_frames.len());
        // Every non-terminal frame is byte-identical; the terminal
        // `done` frames carry timings, so compare their payload fields.
        for (sse, tcp) in sse_frames.iter().zip(&tcp_frames).take(tcp_frames.len() - 1) {
            assert_eq!(sse, tcp, "SSE payload diverged from the TCP frame");
        }
        let sse_done = Value::parse(sse_frames.last().unwrap()).unwrap();
        let tcp_done = Value::parse(tcp_frames.last().unwrap()).unwrap();
        for field in ["generated", "greedy_tail", "segments", "tokens"] {
            assert_eq!(
                sse_done.req(field).unwrap().to_json(),
                tcp_done.req(field).unwrap().to_json(),
                "done frame field {field} diverged"
            );
        }

        // Trip the capped tenant's bucket: 2 admissions, then 429.
        let small = "{\"tokens\": [1, 2, 3, 4, 5, 6, 7, 8]}";
        for _ in 0..2 {
            let resp = http_post(&http, "/v1/generate", Some("sk-c"), small);
            assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "{resp}");
        }
        let resp = http_post(&http, "/v1/generate", Some("sk-c"), small);
        assert!(resp.starts_with("HTTP/1.1 429 "), "{resp}");
        assert!(resp.contains("Retry-After: 1\r\n"), "{resp}");
        assert!(resp.contains("rate limited"), "{resp}");

        // /metrics: engine counters AND gateway counters, text format.
        let resp = http_roundtrip(&http, "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "{resp}");
        assert!(resp.contains("# TYPE pallas_requests_total counter"), "{resp}");
        assert!(resp.contains("pallas_requests_total 4"), "{resp}");
        assert!(resp.contains("pallas_gateway_sse_streams_total 3"), "{resp}");
        assert!(resp.contains("pallas_gateway_rate_limited_total 1"), "{resp}");
        assert!(resp.contains("pallas_gateway_unauthorized_total 1"), "{resp}");

        // Clean shutdown over HTTP; join() returns once drained.
        let resp = http_post(&http, "/admin/shutdown", None, "");
        assert!(resp.contains("\"ok\": true"), "{resp}");
        server.join();
    }

    #[test]
    fn shutdown_flushes_inflight_streams_before_join_returns() {
        // Regression (drain-loop audit): a stream admitted before
        // shutdown must have its terminal frame ON THE WIRE by the time
        // `join` returns — connection threads used to be detached, so
        // teardown could beat the final flush.
        let server = Server::start(test_engine(), "127.0.0.1:0", 8).unwrap();
        let addr = server.addr.to_string();
        let stats = server.stats.clone();

        // A slow client: submits a generation and reads NOTHING yet.
        let tokens: Vec<u32> = (0..16).map(|i| i % 60).collect();
        let mut slow = TcpStream::connect(&addr).unwrap();
        writeln!(
            slow,
            "{}",
            Value::obj(vec![
                ("id", Value::Num(9.0)),
                ("tokens", Value::arr_u32(&tokens)),
                ("max_new_tokens", Value::Num(4.0)),
            ])
            .to_json()
        )
        .unwrap();

        // Wait until the engine has finished the request...
        for _ in 0..1000 {
            if stats.generated_tokens.get() >= 4 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(stats.generated_tokens.get() >= 4, "generation never finished");

        // ...then shut down from a second connection and join.
        let mut c = Client::connect(&addr).unwrap();
        c.shutdown().unwrap();
        server.join();

        // join() has returned: the slow client's whole stream must
        // already be buffered on its socket. A read timeout converts a
        // missing flush into a loud failure instead of a hang.
        slow.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut lines = BufReader::new(slow).lines();
        let mut saw_done = false;
        for line in &mut lines {
            let line = line.expect("terminal frame was flushed before join returned");
            let v = Value::parse(&line).unwrap();
            if v.req("event").unwrap().as_str().unwrap() == "done" {
                saw_done = true;
                break;
            }
        }
        assert!(saw_done, "stream ended without a terminal done frame");
    }
}
