//! TCP JSON-lines inference server + client.
//!
//! Wire protocol (one JSON object per line):
//!
//! ```text
//! -> {"id": 1, "tokens": [3, 17, ...], "mode": "diagonal"?, "want_logits": true?}
//! <- {"id": 1, "greedy_tail": [...], "mode": "diagonal",
//!     "latency_ms": 12.3, "segments": 4, "launches": 7, "tokens": 128,
//!     "mean_group": 2.4, "cells": 12, "padded_cells": 6, "occupancy": 0.83}
//! -> {"cmd": "stats"}
//! <- {"requests": 10, "rejected": 0, "diagonal_runs": 9, "sequential_runs": 1,
//!     "full_attn_runs": 0, "packed_requests": 9, "tokens": 1280,
//!     "launches": 63, "active_cells": 151, "slot_steps": 189,
//!     "padded_cells": 38, "mean_group": 2.4, "occupancy": 0.8,
//!     "workers": 4, "pool_cells": 148, "pool_busy_ms": 310.2,
//!     "worker_utilization": 0.71,
//!     "latency_ms_mean": 10.5, "latency_ms_p50": 8.2,
//!     "latency_ms_p90": 16.4, "latency_ms_p99": 32.8}
//! -> {"cmd": "ping"}
//! <- {"ok": true}
//! -> {"cmd": "shutdown"}
//! ```
//!
//! Topology: connection threads parse and enqueue; ONE engine thread
//! drains the bounded queue into a persistent packed wavefront
//! ([`InferenceEngine::serve_queue`]) — concurrent requests share
//! grouped launches and fill each other's ramp bubbles, and responses
//! complete out of submission order (each connection blocks only on its
//! own reply channel). Backpressure stays explicit
//! (`{"error": "queue full"}`).

mod protocol;

pub use protocol::{parse_request, render_response, WireRequest};

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use crate::config::ExecMode;
use crate::coordinator::{EngineStats, InferenceEngine, Request, RequestQueue, Response};
use crate::error::{Error, Result};
use crate::json::Value;
use crate::scheduler::StepBackend;

type Job = (Request, mpsc::Sender<Result<Response>>);

/// Handle to a running server.
pub struct Server {
    pub addr: std::net::SocketAddr,
    accept_thread: Option<JoinHandle<()>>,
    engine_thread: Option<JoinHandle<()>>,
    queue: Arc<RequestQueue<Job>>,
    shutdown: Arc<AtomicBool>,
    /// Live engine counters (readable after `stop` too).
    pub stats: Arc<EngineStats>,
}

impl Server {
    /// Start serving `engine` on `addr` (use port 0 for an ephemeral
    /// port; the bound address is in `server.addr`).
    pub fn start<B: StepBackend + Send + 'static>(
        mut engine: InferenceEngine<B>,
        addr: &str,
        queue_depth: usize,
    ) -> Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let queue = Arc::new(RequestQueue::<Job>::new(queue_depth));
        let shutdown = Arc::new(AtomicBool::new(false));
        let stats = engine.stats_handle();

        // Engine thread: continuous-batching drain loop — every
        // diagonal-mode request packs into one persistent wavefront;
        // each job's reply channel receives its response whenever it
        // completes (out of submission order).
        let q2 = queue.clone();
        let engine_thread = std::thread::spawn(move || {
            if let Err(e) = engine.serve_queue(&q2, |reply, resp| {
                let _ = reply.send(resp);
            }) {
                eprintln!("engine loop aborted: {e}");
                // Fail fast instead of stranding clients: close the
                // queue (new pushes get "queue closed") and fail every
                // job already enqueued so its connection thread's
                // rx.recv() returns.
                q2.close();
                while let Some((_req, reply)) = q2.try_pop() {
                    let _ = reply.send(Err(Error::Request(format!("engine stopped: {e}"))));
                }
            }
        });

        // Acceptor: one lightweight thread per connection.
        let q3 = queue.clone();
        let sd = shutdown.clone();
        let st = stats.clone();
        let accept_thread = std::thread::spawn(move || {
            let next_id = Arc::new(AtomicU64::new(1));
            for stream in listener.incoming() {
                if sd.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let q = q3.clone();
                let sd2 = sd.clone();
                let ids = next_id.clone();
                let stats = st.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, &q, &sd2, &ids, &stats);
                });
            }
        });

        Ok(Self {
            addr: local,
            accept_thread: Some(accept_thread),
            engine_thread: Some(engine_thread),
            queue,
            shutdown,
            stats,
        })
    }

    /// Request shutdown and join the worker threads. The acceptor is
    /// unblocked by a self-connection.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr); // unblock accept()
        self.queue.close();
        if let Some(t) = self.engine_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    queue: &RequestQueue<Job>,
    shutdown: &AtomicBool,
    ids: &AtomicU64,
    stats: &EngineStats,
) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply_text = match Value::parse(&line) {
            Err(e) => error_json(None, &Error::Json(e.to_string())),
            Ok(v) => {
                if let Some(cmd) = v.get("cmd").and_then(|c| c.as_str().ok().map(String::from)) {
                    match cmd.as_str() {
                        "shutdown" => {
                            shutdown.store(true, Ordering::SeqCst);
                            queue.close();
                            writeln!(writer, "{}", Value::obj(vec![("ok", Value::Bool(true))]).to_json())?;
                            break;
                        }
                        "ping" => Value::obj(vec![("ok", Value::Bool(true))]).to_json(),
                        "stats" => stats.to_json().to_json(),
                        other => error_json(None, &Error::Request(format!("unknown cmd '{other}'"))),
                    }
                } else {
                    match parse_request(&v, || ids.fetch_add(1, Ordering::Relaxed)) {
                        Err(e) => error_json(None, &e),
                        Ok(req) => {
                            let id = req.id;
                            let (tx, rx) = mpsc::channel();
                            match queue.push((req, tx)) {
                                Err(e) => error_json(Some(id), &e),
                                Ok(()) => match rx.recv() {
                                    Ok(Ok(resp)) => render_response(&resp).to_json(),
                                    Ok(Err(e)) => error_json(Some(id), &e),
                                    Err(_) => error_json(
                                        Some(id),
                                        &Error::Request("engine stopped".into()),
                                    ),
                                },
                            }
                        }
                    }
                }
            }
        };
        writeln!(writer, "{reply_text}")?;
    }
    Ok(())
}

fn error_json(id: Option<u64>, e: &Error) -> String {
    let mut fields = vec![("error", Value::Str(e.to_string()))];
    if let Some(id) = id {
        fields.push(("id", Value::Num(id as f64)));
    }
    Value::obj(fields).to_json()
}

/// Blocking line-protocol client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Self { reader: BufReader::new(stream), writer })
    }

    /// Send one request object, wait for the one-line reply.
    pub fn roundtrip(&mut self, v: &Value) -> Result<Value> {
        writeln!(self.writer, "{}", v.to_json())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        if line.is_empty() {
            return Err(Error::Request("server closed connection".into()));
        }
        Value::parse(&line)
    }

    /// Run inference on a token sequence.
    pub fn infer(&mut self, tokens: &[u32], mode: Option<ExecMode>) -> Result<Value> {
        let mut fields = vec![("tokens", Value::arr_u32(tokens))];
        if let Some(m) = mode {
            fields.push(("mode", Value::Str(m.to_string())));
        }
        let resp = self.roundtrip(&Value::obj(fields))?;
        if let Some(err) = resp.get("error") {
            return Err(Error::Request(err.as_str().unwrap_or("?").to_string()));
        }
        Ok(resp)
    }

    pub fn ping(&mut self) -> Result<bool> {
        let resp = self.roundtrip(&Value::obj(vec![("cmd", Value::Str("ping".into()))]))?;
        Ok(resp.get("ok").map(|v| v.as_bool().unwrap_or(false)).unwrap_or(false))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        let _ = self.roundtrip(&Value::obj(vec![("cmd", Value::Str("shutdown".into()))]))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NativeBackend, Params};

    fn test_engine() -> InferenceEngine<NativeBackend> {
        let cfg = crate::model::tests::test_config();
        let params = Params::random(&cfg, 21);
        InferenceEngine::new(NativeBackend::new(cfg, params), ExecMode::Diagonal)
    }

    #[test]
    fn roundtrip_over_tcp() {
        let server = Server::start(test_engine(), "127.0.0.1:0", 8).unwrap();
        let addr = server.addr.to_string();
        let mut client = Client::connect(&addr).unwrap();
        assert!(client.ping().unwrap());

        let tokens: Vec<u32> = (0..16).map(|i| i % 60).collect();
        let resp = client.infer(&tokens, None).unwrap();
        assert_eq!(resp.req("mode").unwrap().as_str().unwrap(), "diagonal");
        assert_eq!(resp.req("tokens").unwrap().as_usize().unwrap(), 16);
        assert_eq!(
            resp.req("greedy_tail").unwrap().as_arr().unwrap().len(),
            8 // test config seg
        );

        // mode override
        let resp = client.infer(&tokens, Some(ExecMode::Sequential)).unwrap();
        assert_eq!(resp.req("mode").unwrap().as_str().unwrap(), "sequential");

        // malformed input -> error object, connection stays usable
        let bad = client.roundtrip(&Value::obj(vec![("tokens", Value::Str("x".into()))])).unwrap();
        assert!(bad.get("error").is_some());
        assert!(client.ping().unwrap());

        server.stop();
    }

    #[test]
    fn stats_cmd_reports_utilization() {
        let server = Server::start(test_engine(), "127.0.0.1:0", 8).unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let tokens: Vec<u32> = (0..32).map(|i| i % 60).collect();
        client.infer(&tokens, None).unwrap();
        client.infer(&tokens, Some(ExecMode::Sequential)).unwrap();

        let stats = client
            .roundtrip(&Value::obj(vec![("cmd", Value::Str("stats".into()))]))
            .unwrap();
        for field in [
            "requests",
            "diagonal_runs",
            "sequential_runs",
            "packed_requests",
            "launches",
            "mean_group",
            "padded_cells",
            "occupancy",
            "latency_ms_p50",
            "latency_ms_p90",
            "latency_ms_p99",
        ] {
            assert!(stats.get(field).is_some(), "missing stats field {field}");
        }
        assert_eq!(stats.req("requests").unwrap().as_usize().unwrap(), 2);
        assert_eq!(stats.req("packed_requests").unwrap().as_usize().unwrap(), 1);
        assert!(stats.req("mean_group").unwrap().as_f64().unwrap() > 0.0);
        let occ = stats.req("occupancy").unwrap().as_f64().unwrap();
        assert!(occ > 0.0 && occ <= 1.0, "occupancy {occ}");
        server.stop();
    }

    #[test]
    fn concurrent_clients_all_served() {
        let server = Server::start(test_engine(), "127.0.0.1:0", 16).unwrap();
        let addr = server.addr.to_string();
        let mut handles = Vec::new();
        for t in 0..4 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                let tokens: Vec<u32> = (0..24).map(|i| (i + t) % 60).collect();
                let resp = c.infer(&tokens, None).unwrap();
                resp.req("segments").unwrap().as_usize().unwrap()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 3);
        }
        server.stop();
    }
}
