//! Paper-table generators: every evaluation artifact of the paper as a
//! structured-row function over the roofline model. The bench binaries
//! print these; tests assert their qualitative shape (who wins, where
//! crossovers fall).

use super::device::DeviceSpec;
use super::memory;
use super::workload::Workload;
use crate::config::ModelConfig;
use crate::scheduler::Schedule;

/// The paper's sequence-length grid (Tables 1, 5-9).
pub const SEQ_LENS: [usize; 6] = [4096, 8192, 16384, 32768, 65536, 131072];

/// The four evaluated model configurations, in the paper's size order.
pub const PAPER_MODELS: [&str; 4] =
    ["llama-160m", "llama-3.2-1b", "llama-3.2-3b", "llama-3.1-8b"];

/// Built-in copy of the paper's four model configurations (Table 1 /
/// Appendix A dims at the default (1024, 128) segmentation).
///
/// `artifacts/manifest.json` carries the same configs under
/// `paper_configs` and stays the source of truth when present; this
/// constructor lets the simulator-only suites (every `fig*`/`table*`
/// roofline table) run with zero artifacts — e.g. in CI, where
/// `pallas-bench` needs deterministic numbers but no PJRT build.
pub fn paper_config(name: &str) -> Option<ModelConfig> {
    // (d_model, n_layers, n_heads, d_ff, vocab)
    let (d, l, h, f, v) = match name {
        "llama-160m" => (768, 12, 12, 3072, 32000),
        "llama-3.2-1b" => (2048, 16, 32, 8192, 128256),
        "llama-3.2-3b" => (3072, 28, 24, 8192, 128256),
        "llama-3.1-8b" => (4096, 32, 32, 14336, 128256),
        _ => return None,
    };
    let k_assoc = 64;
    let dpfp_nu = 3;
    let cfg = ModelConfig {
        name: name.to_string(),
        vocab: v,
        d_model: d,
        n_layers: l,
        n_heads: h,
        d_ff: f,
        seg: 1024,
        mem: 128,
        k_assoc,
        dpfp_nu,
        rope_theta: 500000.0,
        eps: 1e-5,
        attn_buckets: vec![],
        head_dim: d / h,
        phi_dim: 2 * dpfp_nu * k_assoc,
        seg_total: 1024 + 128,
    };
    debug_assert!(cfg.validate().is_ok());
    Some(cfg)
}

/// A model config re-segmented to a (segment_size, memory_tokens) pair —
/// the tables' "Configuration: (seg, mem)" rows.
pub fn with_segmentation(base: &ModelConfig, seg: usize, mem: usize) -> ModelConfig {
    let mut c = base.clone();
    c.seg = seg;
    c.mem = mem;
    c.seg_total = seg + mem;
    c
}

/// One (sequence length) column of an execution-time table.
#[derive(Clone, Debug)]
pub struct ExecCell {
    pub seq_len: usize,
    pub llama_s: f64,
    pub armt_seq_s: f64,
    pub armt_diag_s: f64,
}

impl ExecCell {
    /// Speedup of diagonal over the sequential ARMT baseline (Table 9).
    pub fn speedup_vs_armt(&self) -> f64 {
        self.armt_seq_s / self.armt_diag_s
    }

    /// Speedup of diagonal ARMT over vanilla LLaMA (Table 8).
    pub fn speedup_vs_llama(&self) -> f64 {
        self.llama_s / self.armt_diag_s
    }
}

/// Rows for one "Configuration: (seg, mem)" block of Tables 1/5/6/7.
pub fn exec_time_rows(
    base: &ModelConfig,
    dev: &DeviceSpec,
    seg: usize,
    mem: usize,
    seq_lens: &[usize],
) -> Vec<ExecCell> {
    let cfg = with_segmentation(base, seg, mem);
    let w = Workload::new(cfg, dev.clone());
    seq_lens
        .iter()
        .map(|&n| {
            let s = w.segments_for(n);
            ExecCell {
                seq_len: n,
                llama_s: w.full_attn_forward_time(n),
                armt_seq_s: w.armt_sequential_time(s),
                armt_diag_s: w.armt_diagonal_time(s),
            }
        })
        .collect()
}

/// Fig. 4: achieved TFLOP/s of grouped GEMM vs group size, against the
/// same-shape batched GEMM (batch on the M dimension, shared weights).
pub fn fig4_grouped_gemm_rows(
    dev: &DeviceSpec,
    m: usize,
    n: usize,
    k: usize,
    groups: &[usize],
) -> Vec<(usize, f64, f64)> {
    groups
        .iter()
        .map(|&g| {
            let grouped = super::ops::grouped_gemm(dev, m, n, k, g);
            let batched = super::ops::gemm(dev, m, n, k, g);
            (
                g,
                dev.achieved_flops(&grouped) / 1e12,
                dev.achieved_flops(&batched) / 1e12,
            )
        })
        .collect()
}

/// Fig. 5: attention speedup from batching (relative achieved FLOPS,
/// batch b vs batch 1) for a given segment length.
pub fn fig5_attention_rows(
    dev: &DeviceSpec,
    cfg: &ModelConfig,
    t: usize,
    batches: &[usize],
) -> Vec<(usize, f64)> {
    let base = super::ops::flash_attention(dev, 1, cfg.n_heads, t, cfg.head_dim, true);
    let base_f = dev.achieved_flops(&base);
    batches
        .iter()
        .map(|&b| {
            let op = super::ops::flash_attention(dev, b, cfg.n_heads, t, cfg.head_dim, true);
            (b, dev.achieved_flops(&op) / base_f)
        })
        .collect()
}

/// Fig. 6: time per segment (per sequence) under mini-batching of `b`
/// independent sequences vs diagonal batching vs the ideal even load.
#[derive(Clone, Debug)]
pub struct Fig6Row {
    pub batch: usize,
    /// Mini-batch of b sequences: per-segment-per-sequence time.
    pub minibatch_s: f64,
    /// Diagonal batching (single sequence): per-segment time.
    pub diagonal_s: f64,
    /// Ideal even load upper bound: per-segment time.
    pub ideal_s: f64,
}

pub fn fig6_rows(
    base: &ModelConfig,
    dev: &DeviceSpec,
    seg: usize,
    mem: usize,
    n_segments: usize,
    batches: &[usize],
) -> Vec<Fig6Row> {
    let cfg = with_segmentation(base, seg, mem);
    let w = Workload::new(cfg.clone(), dev.clone());
    let l = cfg.n_layers;
    let diag = w.schedule_time(&Schedule::diagonal(n_segments, l)) / n_segments as f64;
    let ideal = w.schedule_time(&Schedule::ideal_even_load(n_segments, l)) / n_segments as f64;
    batches
        .iter()
        .map(|&b| {
            // b independent sequences advance together: each layer-step
            // serves b cells; per-sequence cost is total / b.
            let total = n_segments as f64
                * (l as f64 * w.layer_step_time(b) + b as f64 * (w.embed_time(1) + w.lm_head_time()));
            Fig6Row {
                batch: b,
                minibatch_s: total / (b as f64 * n_segments as f64),
                diagonal_s: diag,
                ideal_s: ideal,
            }
        })
        .collect()
}

/// Fig. 1 headline: latency + memory vs vanilla LLaMA at each length.
#[derive(Clone, Debug)]
pub struct Fig1Row {
    pub seq_len: usize,
    pub llama_s: f64,
    pub armt_diag_s: f64,
    pub speedup: f64,
    pub memory_saving: f64,
}

pub fn fig1_rows(base: &ModelConfig, dev: &DeviceSpec, seq_lens: &[usize]) -> Vec<Fig1Row> {
    let cfg = with_segmentation(base, 1024, 128);
    let w = Workload::new(cfg.clone(), dev.clone());
    seq_lens
        .iter()
        .map(|&n| {
            let llama = w.full_attn_forward_time(n);
            let diag = w.armt_diagonal_time(w.segments_for(n));
            Fig1Row {
                seq_len: n,
                llama_s: llama,
                armt_diag_s: diag,
                speedup: llama / diag,
                memory_saving: memory::memory_saving(&cfg, n),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_cfg(name: &str) -> ModelConfig {
        match name {
            "1b" => paper_config("llama-3.2-1b").unwrap(),
            "160m" => paper_config("llama-160m").unwrap(),
            _ => unreachable!(),
        }
    }

    #[test]
    fn builtin_paper_configs_are_consistent() {
        for name in PAPER_MODELS {
            let c = paper_config(name).unwrap();
            c.validate().unwrap();
            assert_eq!(c.name, name);
        }
        assert!(paper_config("llama-70b").is_none());
    }

    #[test]
    fn table1_shape_small_segments_benefit_more() {
        // Paper Table 1: speedup at 131k falls from x2.72 (seg 512) to
        // x1.12 (seg 4096) — smaller segments leave more utilization
        // headroom for grouping.
        let dev = DeviceSpec::a100();
        let base = paper_cfg("1b");
        let s512 = exec_time_rows(&base, &dev, 512, 128, &[131072]);
        let s4096 = exec_time_rows(&base, &dev, 4096, 128, &[131072]);
        assert!(
            s512[0].speedup_vs_armt() > s4096[0].speedup_vs_armt(),
            "{} vs {}",
            s512[0].speedup_vs_armt(),
            s4096[0].speedup_vs_armt()
        );
        assert!(s512[0].speedup_vs_armt() > 1.3);
    }

    #[test]
    fn table1_shape_speedup_grows_with_length() {
        let dev = DeviceSpec::a100();
        let rows = exec_time_rows(&paper_cfg("1b"), &dev, 1024, 128, &SEQ_LENS);
        assert!(rows.last().unwrap().speedup_vs_armt() > rows[0].speedup_vs_armt());
        // and ARMT beats vanilla at the longest length (Fig. 1 headline)
        assert!(rows.last().unwrap().speedup_vs_llama() > 1.5);
    }

    #[test]
    fn table7_shape_small_model_bigger_gains() {
        // Paper: 160M gets up to x3.9, 1B up to x2.7 (same seg 1024).
        let dev = DeviceSpec::a100();
        let small = exec_time_rows(&paper_cfg("160m"), &dev, 1024, 128, &[131072]);
        let big = exec_time_rows(&paper_cfg("1b"), &dev, 1024, 128, &[131072]);
        assert!(small[0].speedup_vs_armt() > big[0].speedup_vs_armt());
    }

    #[test]
    fn fig4_grouped_tracks_batched() {
        let dev = DeviceSpec::a100();
        let rows = fig4_grouped_gemm_rows(&dev, 1152, 2048, 2048, &[1, 2, 4, 8, 16, 32]);
        // monotone in group size, and grouped ~ batched within 2x from g=4
        for w in rows.windows(2) {
            assert!(w[1].1 >= w[0].1 * 0.99);
        }
        for (g, grouped, batched) in &rows {
            if *g >= 4 {
                assert!(grouped / batched > 0.5, "g={g}");
            }
        }
    }

    #[test]
    fn fig5_attention_batch_speedup_monotone() {
        let dev = DeviceSpec::a100();
        let cfg = paper_cfg("1b");
        let rows = fig5_attention_rows(&dev, &cfg, 1152, &[1, 2, 4, 8, 16]);
        assert!((rows[0].1 - 1.0).abs() < 1e-9);
        for w in rows.windows(2) {
            assert!(w[1].1 >= w[0].1 * 0.99);
        }
    }

    #[test]
    fn fig6_diagonal_between_b1_and_ideal() {
        let dev = DeviceSpec::a100();
        let rows = fig6_rows(&paper_cfg("1b"), &dev, 1024, 128, 32, &[1, 4, 16]);
        let b1 = &rows[0];
        assert!(b1.diagonal_s < b1.minibatch_s, "diag beats per-seq b=1");
        assert!(b1.ideal_s <= b1.diagonal_s * 1.05, "ideal is the lower bound");
        // large-batch minibatching approaches the ideal
        let b16 = &rows[2];
        assert!(b16.minibatch_s < b1.minibatch_s);
    }

    #[test]
    fn fig1_headline_regime() {
        let dev = DeviceSpec::a100();
        let rows = fig1_rows(&paper_cfg("1b"), &dev, &SEQ_LENS);
        let last = rows.last().unwrap();
        // paper: 3.3x faster, 167x memory at 128k — require same regime
        assert!(last.speedup > 1.5, "speedup {}", last.speedup);
        assert!(last.memory_saving > 50.0, "mem {}", last.memory_saving);
        // short contexts: vanilla wins (crossover exists)
        assert!(rows[0].speedup < 1.0, "short-context crossover missing");
    }
}
