//! Memory-footprint model: full-attention KV cache vs ARMT constant
//! state (the "167.1x memory savings" headline of Fig. 1).

use crate::config::ModelConfig;

use super::ops::DTYPE;

/// Bytes of KV cache a vanilla transformer holds at context length `n`.
pub fn kv_cache_bytes(cfg: &ModelConfig, n_tokens: usize) -> f64 {
    // K and V, per layer, per token, d_model wide (MHA; the paper's
    // LLaMA-1B uses MHA-sized caches for its 3.2-1B measurements).
    2.0 * cfg.n_layers as f64 * n_tokens as f64 * cfg.d_model as f64 * DTYPE
}

/// Bytes the ARMT inference holds regardless of context length:
/// per-layer associative state (A, z) + the current segment's KV.
pub fn armt_state_bytes(cfg: &ModelConfig) -> f64 {
    let state = cfg.n_layers as f64 * cfg.state_floats_per_layer() as f64 * DTYPE;
    let seg_kv = 2.0 * cfg.n_layers as f64 * cfg.seg_total as f64 * cfg.d_model as f64 * DTYPE;
    state + seg_kv
}

/// The Fig. 1 ratio: vanilla KV footprint / ARMT footprint at `n` tokens.
pub fn memory_saving(cfg: &ModelConfig, n_tokens: usize) -> f64 {
    kv_cache_bytes(cfg, n_tokens) / armt_state_bytes(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::test_model_config;

    #[test]
    fn kv_linear_in_tokens() {
        let c = test_model_config();
        assert!((kv_cache_bytes(&c, 2000) / kv_cache_bytes(&c, 1000) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn armt_state_constant() {
        let c = test_model_config();
        // independent of context length by construction: only cfg matters
        assert!(armt_state_bytes(&c) > 0.0);
    }

    #[test]
    fn saving_grows_with_context() {
        let c = test_model_config();
        assert!(memory_saving(&c, 131072) > memory_saving(&c, 4096));
    }

    #[test]
    fn paper_scale_saving_order_of_magnitude() {
        // 1B-config at 128k should save ~two orders of magnitude
        // (paper headline: 167.1x; our accounting of per-segment KV +
        // f16 states lands in the same regime).
        let mut c = test_model_config();
        c.d_model = 2048;
        c.n_layers = 16;
        c.n_heads = 32;
        c.head_dim = 64;
        c.d_ff = 8192;
        c.seg = 1024;
        c.mem = 128;
        c.seg_total = 1152;
        c.k_assoc = 64;
        c.phi_dim = 384;
        let saving = memory_saving(&c, 131072);
        assert!((50.0..400.0).contains(&saving), "saving {saving}");
    }
}
