//! GPU roofline cost-model simulator.
//!
//! DESIGN.md substitution #1: the paper's wallclock tables were measured
//! on A100/H100 GPUs we don't have; this module models a device as
//! (peak FLOPS, HBM bandwidth, kernel-launch latency, utilization curve)
//! and costs the exact op sequences the rust scheduler would launch. The
//! same `Schedule` objects drive both the real PJRT backend and this
//! model, so who-wins / crossover structure is preserved by construction.
//!
//! * [`device`] — device specs (A100-80G, H100-SXM) and the time model;
//! * [`ops`] — per-op cost builders (GEMM, grouped GEMM, flash attention,
//!   elementwise, associative read/update);
//! * [`workload`] — the op sequences of ARMT layer-steps, full-attention
//!   layers, embeddings and heads for a given model config;
//! * [`memory`] — the memory-footprint model (KV-cache vs ARMT state,
//!   Fig. 1's headline memory saving);
//! * [`tables`] — regenerates every paper table/figure as structured rows.

pub mod device;
pub mod memory;
pub mod ops;
pub mod tables;
pub mod workload;

pub use device::DeviceSpec;
pub use ops::OpCost;
pub use workload::Workload;
