//! Per-op cost builders.
//!
//! Every builder returns an [`OpCost`] = (flops, bytes moved, achieved
//! efficiency, launches). `bytes` is HBM traffic assuming perfect reuse
//! inside the kernel (flash-style tiling); dtype is bf16 (2 bytes) for
//! activations/weights, matching the paper's fp16/bf16 inference setup.

use super::device::DeviceSpec;

/// Activation/weight element size (bf16).
pub const DTYPE: f64 = 2.0;
/// Output-tile edge the efficiency model assumes (MXU/tensor-core tile).
pub const TILE: f64 = 128.0;

/// One kernel's cost under the roofline model.
#[derive(Clone, Copy, Debug)]
pub struct OpCost {
    pub flops: f64,
    pub bytes: f64,
    /// Compute efficiency in (0, 1]; filled by the builders from the
    /// device's utilization curve.
    pub eff: f64,
    pub launches: usize,
}

impl OpCost {
    pub fn zero() -> Self {
        Self { flops: 0.0, bytes: 0.0, eff: 1.0, launches: 0 }
    }

    /// Merge two op costs executed as separate kernels.
    pub fn plus(self, other: OpCost) -> OpCost {
        // NOTE: eff folds into flops-time only at `DeviceSpec::time`;
        // summing costs with different eff would lose information, so we
        // keep ops separate in workloads and only add launch-free costs.
        debug_assert!(other.flops == 0.0 || self.flops == 0.0 || other.eff == self.eff);
        OpCost {
            flops: self.flops + other.flops,
            bytes: self.bytes + other.bytes,
            eff: if self.flops >= other.flops { self.eff } else { other.eff },
            launches: self.launches + other.launches,
        }
    }
}

fn tiles(m: f64, n: f64, batch: f64) -> f64 {
    (m / TILE).ceil() * (n / TILE).ceil() * batch
}

/// Plain GEMM `[m,k] x [k,n]`, optionally batched with shared weights
/// (batch multiplies the M dimension's tile count, weights read once).
pub fn gemm(dev: &DeviceSpec, m: usize, n: usize, k: usize, batch: usize) -> OpCost {
    let (mf, nf, kf, bf) = (m as f64, n as f64, k as f64, batch as f64);
    OpCost {
        flops: 2.0 * mf * nf * kf * bf,
        bytes: DTYPE * (bf * mf * kf + kf * nf + bf * mf * nf),
        eff: dev.gemm_eff(tiles(mf, nf, bf), kf),
        launches: 1,
    }
}

/// Grouped GEMM (CUTLASS GroupedGEMM analog): `group` independent
/// `[m,k] x [k,n]` problems with *distinct* weights in one launch.
pub fn grouped_gemm(dev: &DeviceSpec, m: usize, n: usize, k: usize, group: usize) -> OpCost {
    let (mf, nf, kf, gf) = (m as f64, n as f64, k as f64, group as f64);
    OpCost {
        flops: 2.0 * mf * nf * kf * gf,
        bytes: DTYPE * gf * (mf * kf + kf * nf + mf * nf),
        eff: dev.gemm_eff(tiles(mf, nf, gf), kf),
        launches: 1,
    }
}

/// Flash attention over `batch` sequences of length `t` (causal within
/// the first `seg` rows costs ~half the score flops; memory tokens are a
/// small correction we fold in by using full t x t).
pub fn flash_attention(
    dev: &DeviceSpec,
    batch: usize,
    heads: usize,
    t: usize,
    head_dim: usize,
    causal: bool,
) -> OpCost {
    let (bf, hf, tf, df) = (batch as f64, heads as f64, t as f64, head_dim as f64);
    let frac = if causal { 0.5 } else { 1.0 };
    // QK^T + PV, each 2*t*t*hd flops per head.
    let flops = 2.0 * 2.0 * bf * hf * tf * tf * df * frac;
    // IO-aware attention reads Q,K,V once and writes O once.
    let bytes = DTYPE * 4.0 * bf * hf * tf * df;
    // Tile parallelism: (t/128) q-blocks per (batch, head).
    let eff = dev.gemm_eff((tf / TILE).ceil() * bf * hf, df.max(TILE / 2.0));
    OpCost { flops, bytes, eff, launches: 1 }
}

/// Bandwidth-bound elementwise/norm op over `elems` elements (read+write).
pub fn elementwise(elems: usize) -> OpCost {
    OpCost { flops: 0.0, bytes: DTYPE * 2.0 * elems as f64, eff: 1.0, launches: 1 }
}

/// Associative read (eq. 6) for `group` cells: q-projection GEMM +
/// DPFP expansion (elementwise) + the A-read GEMM.
pub fn assoc_read(
    dev: &DeviceSpec,
    group: usize,
    t: usize,
    d: usize,
    k_assoc: usize,
    phi: usize,
) -> OpCost {
    let proj = grouped_gemm(dev, t, k_assoc, d, group);
    let expand = elementwise(group * t * phi);
    let read = grouped_gemm(dev, t, d, phi, group);
    OpCost {
        flops: proj.flops + read.flops,
        bytes: proj.bytes + expand.bytes + read.bytes,
        eff: read.eff, // dominated by the A-read
        launches: 3,
    }
}

/// Delta-rule update (eqs. 3-5) for `group` cells over `mem` tokens.
pub fn assoc_update(
    dev: &DeviceSpec,
    group: usize,
    mem: usize,
    d: usize,
    k_assoc: usize,
    phi: usize,
) -> OpCost {
    let kproj = grouped_gemm(dev, mem, k_assoc, d, group);
    let vproj = grouped_gemm(dev, mem, d, d, group);
    let vbar = grouped_gemm(dev, mem, d, phi, group);
    let outer = grouped_gemm(dev, d, phi, mem, group);
    // A is read and written once per update: 2 * d * phi traffic.
    let state = elementwise(group * d * phi);
    OpCost {
        flops: kproj.flops + vproj.flops + vbar.flops + outer.flops,
        bytes: kproj.bytes + vproj.bytes + vbar.bytes + outer.bytes + state.bytes,
        eff: outer.eff,
        launches: 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_flops_exact() {
        let d = DeviceSpec::a100();
        let o = gemm(&d, 10, 20, 30, 2);
        assert_eq!(o.flops, 2.0 * 10.0 * 20.0 * 30.0 * 2.0);
        assert_eq!(o.launches, 1);
    }

    #[test]
    fn grouped_gemm_flops_scale_with_group() {
        let d = DeviceSpec::a100();
        let a = grouped_gemm(&d, 128, 256, 256, 1);
        let b = grouped_gemm(&d, 128, 256, 256, 8);
        assert!((b.flops / a.flops - 8.0).abs() < 1e-9);
        assert!(b.eff > a.eff, "batching must raise modeled efficiency");
    }

    #[test]
    fn causal_attention_half_flops() {
        let d = DeviceSpec::a100();
        let c = flash_attention(&d, 1, 8, 1024, 64, true);
        let f = flash_attention(&d, 1, 8, 1024, 64, false);
        assert!((f.flops / c.flops - 2.0).abs() < 1e-9);
    }

    #[test]
    fn assoc_ops_have_positive_cost() {
        let d = DeviceSpec::a100();
        let r = assoc_read(&d, 4, 40, 64, 16, 96);
        let u = assoc_update(&d, 4, 8, 64, 16, 96);
        assert!(r.flops > 0.0 && r.bytes > 0.0 && r.launches == 3);
        assert!(u.flops > 0.0 && u.bytes > 0.0 && u.launches == 4);
    }
}
