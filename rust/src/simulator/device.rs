//! Device models and the roofline time rule.
//!
//! `time(op) = launch + max(flops / (peak · eff), bytes / bandwidth)`
//!
//! The efficiency term `eff` models what the paper's Figs. 4-5 measure:
//! GEMM throughput rises with parallel work (more tiles than SMs) and
//! with inner dimension `k`, saturating at peak. We use a smooth
//! work-occupancy curve rather than a sawtooth wave-quantization model —
//! real kernels overlap waves enough that the envelope is what matters.

use super::ops::OpCost;

/// A modeled accelerator.
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Peak dense bf16 FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Per-kernel launch + sync latency, seconds.
    pub launch_s: f64,
    /// Number of SMs (parallel tile slots).
    pub sms: f64,
    /// Occupancy softness: eff = u / (u + alpha) with u = tiles/SMs.
    pub wave_alpha: f64,
    /// Small-k penalty scale: eff_k = k / (k + k0).
    pub k0: f64,
}

impl DeviceSpec {
    /// NVIDIA A100-SXM4-80GB (the paper's main testbed).
    ///
    /// `wave_alpha` is calibrated against the paper's own Table 1/9
    /// numbers (seg-1024 speedup x1.81, seg-512 x2.72 at 131k): real
    /// per-layer kernels at batch 1 run well below nominal occupancy
    /// (launch gaps, tail waves, python dispatch in the baseline), which
    /// the occupancy-softness term absorbs. See EXPERIMENTS.md
    /// "Simulator calibration".
    pub fn a100() -> Self {
        Self {
            name: "A100-80G",
            peak_flops: 312e12,
            mem_bw: 2.039e12,
            launch_s: 6e-6,
            sms: 108.0,
            wave_alpha: 2.0,
            k0: 96.0,
        }
    }

    /// NVIDIA H100-SXM5.
    pub fn h100() -> Self {
        Self {
            name: "H100-SXM",
            peak_flops: 989e12,
            mem_bw: 3.35e12,
            launch_s: 6e-6,
            sms: 132.0,
            wave_alpha: 2.0,
            k0: 128.0,
        }
    }

    /// The CI runner's CPU, measured — not guessed — with a small C
    /// microbenchmark (gcc -O2 -mavx2 -mfma on the 1-core Xeon @
    /// 2.10 GHz the hosted runners hand out): single-core AVX2 FMA peak
    /// ~22.5-24.6 GFLOP/s, separate mul+add ~22.2-25.1 GFLOP/s,
    /// streaming-read bandwidth ~10.6-11.5 GB/s, copy ~11.6-11.8 GB/s.
    /// `peak_flops`/`mem_bw` take the round midpoints; `sms = 1`
    /// (one core, no wave quantization, hence the tiny `wave_alpha`)
    /// and `k0 = 16` (register-tiled CPU GEMMs saturate at much
    /// smaller k than tensor-core tiles). This is the roofline the
    /// `gemm_kernels` bench suite reports achieved GFLOP/s against.
    pub fn ci_host() -> Self {
        Self {
            name: "ci-host-1core",
            peak_flops: 24e9,
            mem_bw: 11e9,
            launch_s: 5e-6,
            sms: 1.0,
            wave_alpha: 0.25,
            k0: 16.0,
        }
    }

    /// GEMM efficiency for a given tile count and inner dim.
    pub fn gemm_eff(&self, tiles: f64, k: f64) -> f64 {
        let u = tiles / self.sms;
        let eff_occ = u / (u + self.wave_alpha);
        let eff_k = k / (k + self.k0);
        (eff_occ * eff_k).clamp(1e-4, 1.0)
    }

    /// Roofline time for one op.
    pub fn time(&self, op: &OpCost) -> f64 {
        let compute = if op.flops > 0.0 {
            op.flops / (self.peak_flops * op.eff.clamp(1e-4, 1.0))
        } else {
            0.0
        };
        let mem = op.bytes / self.mem_bw;
        self.launch_s * op.launches as f64 + compute.max(mem)
    }

    /// Total time for a sequence of ops.
    pub fn time_all(&self, ops: &[OpCost]) -> f64 {
        ops.iter().map(|o| self.time(o)).sum()
    }

    /// Achieved FLOP/s for an op under this model (Figs. 4-5 y-axis).
    pub fn achieved_flops(&self, op: &OpCost) -> f64 {
        let t = self.time(op);
        if t > 0.0 {
            op.flops / t
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::ops;

    #[test]
    fn eff_monotone_in_tiles_and_k() {
        let d = DeviceSpec::a100();
        assert!(d.gemm_eff(10.0, 2048.0) < d.gemm_eff(100.0, 2048.0));
        assert!(d.gemm_eff(100.0, 2048.0) < d.gemm_eff(1000.0, 2048.0));
        assert!(d.gemm_eff(100.0, 32.0) < d.gemm_eff(100.0, 2048.0));
        assert!(d.gemm_eff(1e9, 1e9) <= 1.0);
    }

    #[test]
    fn roofline_picks_max_of_compute_and_mem() {
        let d = DeviceSpec::a100();
        // Huge compute, tiny memory: compute-bound.
        let c = OpCost { flops: 1e15, bytes: 1.0, eff: 1.0, launches: 1 };
        assert!((d.time(&c) - (1e15 / d.peak_flops + d.launch_s)).abs() < 1e-6);
        // Tiny compute, huge memory: bandwidth-bound.
        let m = OpCost { flops: 1.0, bytes: 1e12, eff: 1.0, launches: 1 };
        assert!((d.time(&m) - (1e12 / d.mem_bw + d.launch_s)).abs() < 1e-4);
    }

    #[test]
    fn launch_overhead_dominates_tiny_ops() {
        let d = DeviceSpec::a100();
        let tiny = ops::gemm(&d, 8, 8, 8, 1);
        assert!(d.time(&tiny) < 2.0 * d.launch_s);
        assert!(d.time(&tiny) >= d.launch_s);
    }

    #[test]
    fn ci_host_is_a_cpu_not_a_gpu() {
        let d = DeviceSpec::ci_host();
        // Orders of magnitude below the accelerators, and single-"SM":
        // occupancy must already be near-saturated at one tile.
        assert!(d.peak_flops < DeviceSpec::a100().peak_flops / 1e3);
        assert!(d.mem_bw < DeviceSpec::a100().mem_bw / 100.0);
        assert!(d.gemm_eff(1.0, 128.0) > 0.7);
        // A bench-sized GEMM lands in single-digit GFLOP/s territory —
        // the regime the gemm_kernels suite actually measures.
        let g = ops::gemm(&d, 96, 96, 192, 1);
        let achieved = d.achieved_flops(&g);
        assert!(achieved > 1e9 && achieved <= d.peak_flops, "{achieved}");
    }

    #[test]
    fn batching_raises_achieved_flops() {
        let d = DeviceSpec::a100();
        let g1 = ops::grouped_gemm(&d, 1152, 2048, 2048, 1);
        let g16 = ops::grouped_gemm(&d, 1152, 2048, 2048, 16);
        assert!(d.achieved_flops(&g16) > d.achieved_flops(&g1));
    }
}
