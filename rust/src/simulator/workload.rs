//! Workload builder: the op sequences one scheduling decision launches.
//!
//! `Workload` binds a model config + device and knows how to cost:
//! * one ARMT layer-step at group size `g` (the paper's grouped layer);
//! * a full vanilla-attention forward at context length `n`;
//! * whole schedules (sequential / diagonal / minibatch / ideal).
//!
//! The op sequence mirrors `python/compile/model.py::grouped_step`
//! exactly: assoc read -> norm -> qkv -> attention -> out-proj ->
//! residual -> norm -> swiglu (3 GEMMs) -> residual -> assoc update.

use super::device::DeviceSpec;
use super::ops::{self, OpCost};
use crate::config::ModelConfig;
use crate::scheduler::{Schedule, ScheduleKind};

/// Cost evaluator for one (model, device) pair.
#[derive(Clone, Debug)]
pub struct Workload {
    pub cfg: ModelConfig,
    pub dev: DeviceSpec,
}

impl Workload {
    pub fn new(cfg: ModelConfig, dev: DeviceSpec) -> Self {
        Self { cfg, dev }
    }

    /// Ops of one grouped ARMT layer-step over `g` cells
    /// (g = 1 is the sequential baseline's cell).
    pub fn layer_step_ops(&self, g: usize) -> Vec<OpCost> {
        let c = &self.cfg;
        let t = c.seg_total;
        let d = c.d_model;
        let dev = &self.dev;
        vec![
            ops::assoc_read(dev, g, t, d, c.k_assoc, c.phi_dim),
            ops::elementwise(g * t * d), // rmsnorm 1
            ops::grouped_gemm(dev, t, d, d, g), // q
            ops::grouped_gemm(dev, t, d, d, g), // k
            ops::grouped_gemm(dev, t, d, d, g), // v
            ops::flash_attention(dev, g, c.n_heads, t, c.head_dim, true),
            ops::grouped_gemm(dev, t, d, d, g), // o
            ops::elementwise(g * t * d), // residual + rmsnorm 2
            ops::grouped_gemm(dev, t, c.d_ff, d, g), // gate
            ops::grouped_gemm(dev, t, c.d_ff, d, g), // up
            ops::grouped_gemm(dev, t, d, c.d_ff, g), // down
            ops::elementwise(g * t * d), // residual
            ops::assoc_update(dev, g, c.mem, d, c.k_assoc, c.phi_dim),
        ]
    }

    /// Time of one grouped layer-step (seconds).
    pub fn layer_step_time(&self, g: usize) -> f64 {
        self.dev.time_all(&self.layer_step_ops(g))
    }

    /// Embedding lookup + memory-token concat for `g` segments.
    pub fn embed_time(&self, g: usize) -> f64 {
        self.dev.time(&ops::elementwise(g * self.cfg.seg_total * self.cfg.d_model))
    }

    /// LM head over one segment.
    pub fn lm_head_time(&self) -> f64 {
        let c = &self.cfg;
        self.dev
            .time(&ops::gemm(&self.dev, c.seg, c.vocab, c.d_model, 1))
    }

    /// One layer of the vanilla full-attention baseline at length `n`.
    pub fn full_attn_layer_time(&self, n: usize) -> f64 {
        let c = &self.cfg;
        let d = c.d_model;
        let dev = &self.dev;
        let ops = vec![
            ops::elementwise(n * d),
            ops::gemm(dev, n, d, d, 1),
            ops::gemm(dev, n, d, d, 1),
            ops::gemm(dev, n, d, d, 1),
            ops::flash_attention(dev, 1, c.n_heads, n, c.head_dim, true),
            ops::gemm(dev, n, d, d, 1),
            ops::elementwise(n * d),
            ops::gemm(dev, n, c.d_ff, d, 1),
            ops::gemm(dev, n, c.d_ff, d, 1),
            ops::gemm(dev, n, d, c.d_ff, 1),
            ops::elementwise(n * d),
        ];
        dev.time_all(&ops)
    }

    /// Full vanilla-LLaMA forward at context length `n` (the paper's
    /// "Llama-3.2-XX" baseline rows).
    pub fn full_attn_forward_time(&self, n: usize) -> f64 {
        let per_layer = self.full_attn_layer_time(n);
        let head = self
            .dev
            .time(&ops::gemm(&self.dev, n, self.cfg.vocab, self.cfg.d_model, 1));
        self.cfg.n_layers as f64 * per_layer + head + self.embed_time(1)
    }

    /// Time a whole schedule produced by [`Schedule`]. Group cost uses the
    /// group's *actual* size (the ramp iterations of the diagonal run
    /// cheaper in the simulator; the fixed-width executor's padding is a
    /// CPU-backend implementation choice, not part of the algorithm).
    pub fn schedule_time(&self, schedule: &Schedule) -> f64 {
        let mut total = 0.0;
        match schedule.kind {
            ScheduleKind::MiniBatch { batch } => {
                // b independent sequences: every group is `batch` same-layer
                // cells; per sequence-step all L layers run once.
                for group in &schedule.groups {
                    total += self.layer_step_time(group.len().max(batch));
                }
            }
            _ => {
                for group in &schedule.groups {
                    total += self.layer_step_time(group.len());
                }
            }
        }
        // Per-segment embed + head (identical across schedules).
        total += schedule.n_segments as f64
            * (self.embed_time(1) + self.lm_head_time());
        total
    }

    /// ARMT sequential-baseline forward time for `s` segments.
    pub fn armt_sequential_time(&self, s: usize) -> f64 {
        self.schedule_time(&Schedule::sequential(s, self.cfg.n_layers))
    }

    /// ARMT diagonal-batching forward time for `s` segments.
    pub fn armt_diagonal_time(&self, s: usize) -> f64 {
        self.schedule_time(&Schedule::diagonal(s, self.cfg.n_layers))
    }

    /// Packed-session forward time for concurrent requests of
    /// `request_segments[i]` segments over `lanes` slot lanes (the
    /// `WavefrontSession` serving model): cross-request ramp overlap
    /// plus lane batching, costed group-by-group like every other
    /// schedule.
    pub fn armt_packed_time(&self, request_segments: &[usize], lanes: usize) -> f64 {
        self.schedule_time(&Schedule::packed(request_segments, self.cfg.n_layers, lanes))
    }

    /// Serial per-request diagonal baseline for the same workload.
    pub fn armt_serial_diagonal_time(&self, request_segments: &[usize]) -> f64 {
        request_segments.iter().map(|&s| self.armt_diagonal_time(s)).sum()
    }

    /// Segments needed for `n` tokens.
    pub fn segments_for(&self, n_tokens: usize) -> usize {
        n_tokens.div_ceil(self.cfg.seg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::test_model_config;

    fn paper_1b() -> ModelConfig {
        let mut c = test_model_config();
        c.name = "llama-1b".into();
        c.vocab = 128256;
        c.d_model = 2048;
        c.n_layers = 16;
        c.n_heads = 32;
        c.head_dim = 64;
        c.d_ff = 8192;
        c.seg = 1024;
        c.mem = 128;
        c.seg_total = 1152;
        c.k_assoc = 64;
        c.phi_dim = 384;
        c
    }

    #[test]
    fn grouped_step_cheaper_than_g_single_steps() {
        let w = Workload::new(paper_1b(), DeviceSpec::a100());
        let g = w.cfg.n_layers;
        let grouped = w.layer_step_time(g);
        let single = g as f64 * w.layer_step_time(1);
        assert!(grouped < single, "grouped {grouped} vs {single}");
    }

    #[test]
    fn diagonal_beats_sequential_at_long_context() {
        let w = Workload::new(paper_1b(), DeviceSpec::a100());
        let s = w.segments_for(131072);
        let seq = w.armt_sequential_time(s);
        let diag = w.armt_diagonal_time(s);
        let speedup = seq / diag;
        // paper table 1 (1024, 128): x1.81 at 131k
        assert!(speedup > 1.2, "speedup {speedup}");
        assert!(speedup < 3.5, "speedup {speedup} suspiciously high");
    }

    #[test]
    fn full_attention_quadratic_overtakes_armt() {
        let w = Workload::new(paper_1b(), DeviceSpec::a100());
        // Short: full attention wins; long: ARMT diagonal wins (Fig. 1).
        let short = 4096;
        let long = 131072;
        assert!(
            w.full_attn_forward_time(short)
                < w.armt_diagonal_time(w.segments_for(short))
        );
        assert!(
            w.full_attn_forward_time(long)
                > w.armt_diagonal_time(w.segments_for(long))
        );
    }

    #[test]
    fn packed_requests_beat_serial_diagonal() {
        // Concurrent short requests fill each other's ramp bubbles and
        // raise per-launch group sizes, so the packed session must beat
        // running the same requests' diagonal schedules back to back —
        // the serving-path analog of the paper's batch-scaling figures.
        let w = Workload::new(paper_1b(), DeviceSpec::a100());
        let reqs = [8usize, 8, 8, 8, 8, 8, 8, 8];
        let serial = w.armt_serial_diagonal_time(&reqs);
        let packed1 = w.armt_packed_time(&reqs, 1);
        let packed4 = w.armt_packed_time(&reqs, 4);
        assert!(packed1 < serial, "packed {packed1} vs serial {serial}");
        assert!(packed4 < packed1, "lanes must help: {packed4} vs {packed1}");
    }

    #[test]
    fn armt_scales_linearly() {
        let w = Workload::new(paper_1b(), DeviceSpec::a100());
        let t1 = w.armt_diagonal_time(w.segments_for(16384));
        let t2 = w.armt_diagonal_time(w.segments_for(32768));
        let ratio = t2 / t1;
        assert!((1.6..2.4).contains(&ratio), "ratio {ratio}");
    }
}
