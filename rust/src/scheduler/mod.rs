//! The paper's contribution: scheduling the ARMT (segment, layer) grid.
//!
//! * [`dag`] — the dependency DAG of (segment, layer) cells and the
//!   Lemma 3.1 machinery (minimum group count, earliest feasible group);
//! * [`plan`] — explicit schedules (diagonal / sequential / mini-batch /
//!   ideal-even-load / cross-request packed) shared by the executors and
//!   the roofline simulator;
//! * [`executor`] — the single-shot executor (sequential baseline +
//!   Algorithm 1) over a pluggable [`StepBackend`];
//! * [`session`] — [`WavefrontSession`], the persistent multi-request
//!   wavefront the serving engine drains continuously. The diagonal
//!   executor is its one-request, one-lane special case.
//!
//! Slot-lane convention: the grouped step always executes at the full
//! static width `L x B` — slot row `l` is permanently bound to layer
//! `l`'s weights, and each row carries `B` independent *lanes*. A lane
//! holds a stream of `(request, segment)` cells; the per-layer recurrent
//! state `(A, z)` lives in the `(layer, lane)` slot and is keyed, at any
//! instant, by the request streaming through that lane (reset to zeros
//! when a new request's first segment arrives). Keeping the shape static
//! lets the HLO programs stay AOT-compiled and parameters stay resident;
//! masked slots cost wasted cell-computations, which is exactly what
//! cross-request packing reclaims: one request's ramp-down bubbles are
//! filled by the next request's ramp-up, and `B > 1` lanes batch
//! concurrent requests into the same launch. `B = 1` with a single
//! request reproduces Algorithm 1 (and its `(L-1)·L/2` per-ramp padding)
//! bit-for-bit.

pub mod dag;
pub mod executor;
pub mod plan;
pub mod session;

pub use dag::Cell;
pub use executor::{
    grouped_dims, segment_tokens, Executor, RunOutput, RunStats, ScheduleMode, StepBackend,
    WorkerStats,
};
pub use plan::{Schedule, ScheduleKind};
pub use session::{SegmentExit, SessionOutput, WavefrontSession};
