//! The paper's contribution: scheduling the ARMT (segment, layer) grid.
//!
//! * [`dag`] — the dependency DAG of (segment, layer) cells and the
//!   Lemma 3.1 machinery (minimum group count, earliest feasible group);
//! * [`plan`] — explicit schedules (diagonal / sequential / mini-batch /
//!   ideal-even-load) shared by the executors and the roofline simulator;
//! * [`executor`] — the streaming wavefront executor (Algorithm 1) over a
//!   pluggable [`StepBackend`].
//!
//! Slot convention: the grouped step is always executed at full width
//! `G = n_layers`, with slot `l` permanently bound to layer `l` and an
//! `active` mask for ramp-up/-down iterations. This keeps the HLO program
//! static-shaped and lets parameters stay resident on the device; the
//! masked slots cost `(L-1)·L/2` wasted cell-computations per request at
//! each ramp, which is negligible for `S >> L` (see DESIGN.md).

pub mod dag;
pub mod executor;
pub mod plan;

pub use dag::Cell;
pub use executor::{Executor, RunOutput, RunStats, ScheduleMode, StepBackend};
pub use plan::{Schedule, ScheduleKind};
