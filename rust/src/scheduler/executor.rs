//! The streaming wavefront executor — Algorithm 1 of the paper.
//!
//! Executes an ARMT forward pass over a pluggable [`StepBackend`] in
//! either schedule:
//!
//! * **Sequential** (baseline): `S x L` single-cell steps, exactly the
//!   original ARMT loop;
//! * **Diagonal**: `S + L - 1` full-width grouped steps. Slot `l` of the
//!   grouped call is bound to layer `l`; each iteration a new segment
//!   enters slot 0 ("prepend segments[i] to GInput"), finished segments
//!   leave slot `L-1` ("GInput.POPLAST"), and between iterations slot
//!   contents shift up one layer. An `active` mask freezes state updates
//!   in padded ramp slots.
//!
//! The executor never materializes the whole schedule — memory is
//! `O(L * T * d)` regardless of sequence length, the paper's "constant
//! memory" property.

use std::time::{Duration, Instant};

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::scheduler::session::WavefrontSession;
use crate::tensor::Tensor;

/// Snapshot of a backend's execution-parallelism counters: how many
/// worker threads execute wavefront cells, and how much work the pool
/// has absorbed. Counters are cumulative (monotone) so callers can take
/// deltas across wavefront iterations — that is how
/// [`EngineStats`](crate::coordinator::EngineStats) derives its
/// worker-utilization ratio.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkerStats {
    /// Worker threads executing cells (1 = inline sequential execution).
    pub threads: usize,
    /// Cells dispatched to pool workers so far.
    pub pool_cells: u64,
    /// Summed busy time across all workers, microseconds.
    pub busy_us: u64,
}

impl Default for WorkerStats {
    fn default() -> Self {
        Self { threads: 1, pool_cells: 0, busy_us: 0 }
    }
}

/// Anything that can execute ARMT cell steps: the PJRT HLO runtime, the
/// native rust model, or the roofline simulator.
pub trait StepBackend {
    fn config(&self) -> &ModelConfig;

    /// Full-width grouped step over `L x B` slots: `x [L, B, T, d]`,
    /// `a [L, B, d, p]`, `z [L, B, p]`, `mask [L * B]` row-major
    /// (1.0 = active). Slot row `l` applies layer `l`'s weights to each
    /// of its `B` lanes independently; lanes may carry cells of
    /// *different requests*. The legacy single-lane layout (`x [L, T, d]`,
    /// `a [L, d, p]`, `z [L, p]`, `mask [L]`) is accepted as `B = 1` and
    /// must behave identically. Returns `(y, a', z')` of the input
    /// shapes. State slots with `mask == 0` must come back bit-identical.
    fn grouped_step(
        &mut self,
        x: &Tensor,
        a: &Tensor,
        z: &Tensor,
        mask: &[f32],
    ) -> Result<(Tensor, Tensor, Tensor)>;

    /// One (segment, layer) cell: `x [T, d]`, `a [d, p]`, `z [p]`.
    fn single_step(
        &mut self,
        layer: usize,
        x: &Tensor,
        a: &Tensor,
        z: &Tensor,
    ) -> Result<(Tensor, Tensor, Tensor)>;

    /// Segment token ids (`seg` of them) -> `[T, d]` hiddens including
    /// the memory-token embeddings.
    fn embed(&mut self, tokens: &[u32]) -> Result<Tensor>;

    /// Final-layer hiddens `[T, d]` -> logits `[seg, vocab]`.
    fn lm_head(&mut self, y: &Tensor) -> Result<Tensor>;

    /// Full-attention baseline over raw tokens (optional; HLO backends
    /// only support their AOT length buckets).
    fn full_attn(&mut self, _tokens: &[u32]) -> Result<Tensor> {
        Err(Error::Config("backend has no full-attention baseline".into()))
    }

    /// Backend calls made so far (instrumentation).
    fn step_calls(&self) -> u64;

    /// Cumulative worker-pool counters. Backends without a pool (the
    /// HLO runtime, the sequential oracle) report the single-threaded
    /// default; [`NativeBackend::with_threads`](crate::model::NativeBackend::with_threads)
    /// overrides with live pool numbers.
    fn worker_stats(&self) -> WorkerStats {
        WorkerStats::default()
    }
}

impl<T: StepBackend + ?Sized> StepBackend for Box<T> {
    fn config(&self) -> &ModelConfig {
        (**self).config()
    }

    fn grouped_step(
        &mut self,
        x: &Tensor,
        a: &Tensor,
        z: &Tensor,
        mask: &[f32],
    ) -> Result<(Tensor, Tensor, Tensor)> {
        (**self).grouped_step(x, a, z, mask)
    }

    fn single_step(
        &mut self,
        layer: usize,
        x: &Tensor,
        a: &Tensor,
        z: &Tensor,
    ) -> Result<(Tensor, Tensor, Tensor)> {
        (**self).single_step(layer, x, a, z)
    }

    fn embed(&mut self, tokens: &[u32]) -> Result<Tensor> {
        (**self).embed(tokens)
    }

    fn lm_head(&mut self, y: &Tensor) -> Result<Tensor> {
        (**self).lm_head(y)
    }

    fn full_attn(&mut self, tokens: &[u32]) -> Result<Tensor> {
        (**self).full_attn(tokens)
    }

    fn step_calls(&self) -> u64 {
        (**self).step_calls()
    }

    fn worker_stats(&self) -> WorkerStats {
        (**self).worker_stats()
    }
}

/// Parse + validate the slot shapes of a [`StepBackend::grouped_step`]
/// call; returns `(n_layers, lanes)`. Rank-3 `x` is the legacy
/// single-lane layout (`B = 1`); rank-4 `x [L, B, T, d]` carries `B`
/// slot lanes. Shared by every backend so the shape contract stays in
/// one place.
pub fn grouped_dims(
    cfg: &ModelConfig,
    x: &Tensor,
    a: &Tensor,
    z: &Tensor,
    mask: &[f32],
) -> Result<(usize, usize)> {
    let shape_err = |what| Error::Shape {
        what,
        expected: vec![cfg.n_layers],
        got: x.shape().to_vec(),
    };
    let (l, b) = match x.rank() {
        3 => (x.shape()[0], 1),
        4 => (x.shape()[0], x.shape()[1]),
        _ => return Err(shape_err("grouped_step x rank")),
    };
    if l != cfg.n_layers || b == 0 {
        return Err(shape_err("grouped_step slot dims"));
    }
    let state_ok = if x.rank() == 3 {
        a.shape() == [l, cfg.d_model, cfg.phi_dim].as_slice()
            && z.shape() == [l, cfg.phi_dim].as_slice()
    } else {
        a.shape() == [l, b, cfg.d_model, cfg.phi_dim].as_slice()
            && z.shape() == [l, b, cfg.phi_dim].as_slice()
    };
    if !state_ok {
        return Err(Error::Shape {
            what: "grouped_step state dims",
            expected: vec![l, b, cfg.d_model, cfg.phi_dim],
            got: a.shape().to_vec(),
        });
    }
    if mask.len() != l * b {
        return Err(Error::Shape {
            what: "grouped_step mask",
            expected: vec![l * b],
            got: vec![mask.len()],
        });
    }
    Ok((l, b))
}

/// Split tokens into `seg`-sized segments, padding the tail with the pad
/// token 0 (the convention shared with the python trainer).
pub fn segment_tokens(cfg: &ModelConfig, tokens: &[u32]) -> Result<Vec<Vec<u32>>> {
    if tokens.is_empty() {
        return Err(Error::Request("empty token sequence".into()));
    }
    let seg = cfg.seg;
    let mut out = Vec::with_capacity(tokens.len().div_ceil(seg));
    for chunk in tokens.chunks(seg) {
        let mut v = chunk.to_vec();
        v.resize(seg, 0);
        out.push(v);
    }
    Ok(out)
}

/// Which executor loop to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleMode {
    Sequential,
    Diagonal,
}

/// Timing + utilization counters for one run (or one packed-session
/// window — see [`WavefrontSession::stats`]).
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    pub mode_diagonal: bool,
    pub segments: usize,
    /// Wavefront iterations spanned: S*L sequential, S+L-1 diagonal —
    /// the paper's Fig. 3 quantity. (For a solo run this equals the
    /// backend step-call count; a packed request reports the iterations
    /// it was in flight.)
    pub launches: u64,
    /// Cells the request's schedule actually needed (S*L).
    pub cells: u64,
    /// Slot-steps the launches spanned: `launches * L * B` for the
    /// fixed-width wavefront, `== cells` for sequential, 0 when no
    /// grouped slots ran (full attention).
    pub slot_steps: u64,
    /// Slot-steps that carried no active cell — of *any* request; in a
    /// packed session other requests' cells fill this request's ramp
    /// bubbles, which is exactly what shrinks this number.
    pub padded_cells: u64,
    pub wall: Duration,
    /// Tokens consumed including padding of the last segment.
    pub tokens: usize,
}

impl RunStats {
    /// Mean active cells per launch (utilization proxy).
    pub fn mean_group(&self) -> f64 {
        if self.launches == 0 {
            0.0
        } else {
            self.cells as f64 / self.launches as f64
        }
    }

    /// Fraction of slot-steps that carried active work — the
    /// per-iteration occupancy that cross-request packing raises.
    pub fn occupancy(&self) -> f64 {
        if self.slot_steps == 0 {
            0.0
        } else {
            (self.slot_steps - self.padded_cells) as f64 / self.slot_steps as f64
        }
    }
}

/// Per-request output: one logits tensor `[seg, vocab]` per segment.
#[derive(Clone, Debug)]
pub struct RunOutput {
    pub logits: Vec<Tensor>,
    pub stats: RunStats,
}

impl RunOutput {
    pub fn segments(&self) -> usize {
        self.logits.len()
    }

    pub fn vocab(&self) -> usize {
        self.logits.first().map(|t| t.shape()[1]).unwrap_or(0)
    }

    /// All logits stacked `[S * seg, vocab]` (error analysis).
    pub fn stacked(&self) -> Result<Tensor> {
        let refs: Vec<&Tensor> = self.logits.iter().collect();
        Tensor::concat0(&refs)
    }

    /// Greedy token per position of the final segment (decode helper).
    pub fn last_segment_argmax(&self) -> Vec<usize> {
        self.logits.last().map(|t| t.argmax_rows()).unwrap_or_default()
    }
}

/// Streaming executor over a backend.
pub struct Executor<'a, B: StepBackend> {
    backend: &'a mut B,
    mode: ScheduleMode,
}

impl<'a, B: StepBackend> Executor<'a, B> {
    pub fn new(backend: &'a mut B, mode: ScheduleMode) -> Self {
        Self { backend, mode }
    }

    pub fn mode(&self) -> ScheduleMode {
        self.mode
    }

    /// Split tokens into `seg`-sized segments, padding the tail with the
    /// pad token 0 (the convention shared with the python trainer).
    pub fn segment(&self, tokens: &[u32]) -> Result<Vec<Vec<u32>>> {
        segment_tokens(self.backend.config(), tokens)
    }

    /// Run the full forward pass.
    pub fn run(&mut self, tokens: &[u32]) -> Result<RunOutput> {
        let segments = self.segment(tokens)?;
        match self.mode {
            ScheduleMode::Sequential => self.run_sequential(&segments),
            ScheduleMode::Diagonal => self.run_diagonal(&segments),
        }
    }

    fn run_sequential(&mut self, segments: &[Vec<u32>]) -> Result<RunOutput> {
        let cfg = self.backend.config().clone();
        let started = Instant::now();
        let calls0 = self.backend.step_calls();
        let l_total = cfg.n_layers;

        // Per-layer recurrent state.
        let mut a: Vec<Tensor> =
            (0..l_total).map(|_| Tensor::zeros(&[cfg.d_model, cfg.phi_dim])).collect();
        let mut z: Vec<Tensor> = (0..l_total).map(|_| Tensor::zeros(&[cfg.phi_dim])).collect();

        let mut logits = Vec::with_capacity(segments.len());
        for seg_tokens in segments {
            let mut x = self.backend.embed(seg_tokens)?;
            for l in 0..l_total {
                let (y, a2, z2) = self.backend.single_step(l, &x, &a[l], &z[l])?;
                x = y;
                a[l] = a2;
                z[l] = z2;
            }
            logits.push(self.backend.lm_head(&x)?);
        }

        let cells = (segments.len() * l_total) as u64;
        let stats = RunStats {
            mode_diagonal: false,
            segments: segments.len(),
            launches: self.backend.step_calls() - calls0,
            cells,
            slot_steps: cells,
            padded_cells: 0,
            wall: started.elapsed(),
            tokens: segments.len() * cfg.seg,
        };
        Ok(RunOutput { logits, stats })
    }

    /// The diagonal wavefront is a one-request [`WavefrontSession`] with
    /// a single slot lane — Algorithm 1 is the `N = 1, B = 1` special
    /// case of the packed scheduler, bit-for-bit.
    fn run_diagonal(&mut self, segments: &[Vec<u32>]) -> Result<RunOutput> {
        let started = Instant::now();
        let mut session = WavefrontSession::new(self.backend.config().clone(), 1);
        session.submit_segments(0, segments.to_vec())?;
        session.run_to_completion(self.backend)?;
        let out = session
            .pop_completed()
            .ok_or_else(|| Error::Schedule("wavefront produced no output".into()))?;
        let mut stats = out.stats;
        stats.wall = started.elapsed();
        Ok(RunOutput { logits: out.logits, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NativeBackend, Params};

    fn backend(seed: u64) -> NativeBackend {
        let cfg = crate::model::tests::test_config();
        let params = Params::random(&cfg, seed);
        NativeBackend::new(cfg, params)
    }

    fn tokens(n: usize) -> Vec<u32> {
        (0..n as u32).map(|i| (i * 7 + 3) % 64).collect()
    }

    #[test]
    fn diagonal_equals_sequential_bitexact_native() {
        // The paper's exactness claim, at its strongest: with an
        // order-preserving backend the two schedules are bit-identical.
        let mut b1 = backend(42);
        let toks = tokens(8 * 5); // 5 segments
        let seq = Executor::new(&mut b1, ScheduleMode::Sequential).run(&toks).unwrap();
        let mut b2 = backend(42);
        let diag = Executor::new(&mut b2, ScheduleMode::Diagonal).run(&toks).unwrap();
        assert_eq!(seq.segments(), diag.segments());
        for (a, b) in seq.logits.iter().zip(&diag.logits) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn launch_counts_match_fig3() {
        let mut b = backend(1);
        let l = b.config().n_layers;
        let toks = tokens(8 * 6);
        let seq = Executor::new(&mut b, ScheduleMode::Sequential).run(&toks).unwrap();
        assert_eq!(seq.stats.launches, (6 * l) as u64);

        let mut b = backend(1);
        let diag = Executor::new(&mut b, ScheduleMode::Diagonal).run(&toks).unwrap();
        assert_eq!(diag.stats.launches, (6 + l - 1) as u64);
        assert!(diag.stats.mean_group() > 1.0);
    }

    #[test]
    fn tail_padding() {
        let mut b = backend(2);
        let toks = tokens(8 * 2 + 3); // ragged tail
        let out = Executor::new(&mut b, ScheduleMode::Diagonal).run(&toks).unwrap();
        assert_eq!(out.segments(), 3);
        assert_eq!(out.stats.tokens, 24);
    }

    #[test]
    fn empty_request_rejected() {
        let mut b = backend(3);
        assert!(Executor::new(&mut b, ScheduleMode::Diagonal).run(&[]).is_err());
    }

    #[test]
    fn short_sequence_fewer_segments_than_layers() {
        // S=2 < L=3 exercises ramp-only wavefronts.
        let mut b1 = backend(4);
        let toks = tokens(8 * 2);
        let seq = Executor::new(&mut b1, ScheduleMode::Sequential).run(&toks).unwrap();
        let mut b2 = backend(4);
        let diag = Executor::new(&mut b2, ScheduleMode::Diagonal).run(&toks).unwrap();
        for (a, b) in seq.logits.iter().zip(&diag.logits) {
            assert_eq!(a, b);
        }
        assert_eq!(diag.stats.launches, (2 + 3 - 1) as u64);
    }

    #[test]
    fn memory_state_isolation_between_runs() {
        // Two identical runs on the same backend must agree (state is
        // per-run, owned by the executor, not the backend).
        let mut b = backend(5);
        let toks = tokens(8 * 3);
        let o1 = Executor::new(&mut b, ScheduleMode::Diagonal).run(&toks).unwrap();
        let o2 = Executor::new(&mut b, ScheduleMode::Diagonal).run(&toks).unwrap();
        for (a, bb) in o1.logits.iter().zip(&o2.logits) {
            assert_eq!(a, bb);
        }
    }
}
