//! [`WavefrontSession`]: the persistent, multi-request diagonal
//! wavefront — continuous batching for the ARMT (segment, layer) grid.
//!
//! Algorithm 1 runs one request's segments through `L` layer-bound slots
//! and pays `(L-1)·L/2` masked slot-steps on each ramp. But the
//! dependency structure (dag.rs) is *per request*: a slot at layer `l`
//! can carry any request's segment, because cell `(r, s, l)` depends
//! only on `(r, s-1, l)` and `(r, s, l-1)`. The session exploits this in
//! two ways:
//!
//! * **stream packing** — when a request's last segment enters slot 0,
//!   the next request's segment 0 follows on the very next iteration, so
//!   one request's ramp-down overlaps the next one's ramp-up and the
//!   pipeline never drains between requests;
//! * **slot lanes** — each of the `L` layer slots is widened to `B`
//!   lanes (`grouped_step` over `[L, B, T, d]`), so up to `B` requests
//!   stream concurrently with a single launch per iteration.
//!
//! Exactness is preserved per request: segments still traverse layers in
//! order against that request's own `(A, z)` memory, which lives in the
//! `(layer, lane)` slot the request streams through. At a request
//! boundary the first segment of the new request zeroes the slot state
//! at each layer it reaches (a fresh request starts from empty memory),
//! so a packed run is bit-identical to per-request execution on an
//! order-preserving backend — the property `rust/tests/scheduler_props`
//! checks (P7).
//!
//! The session is a plain state machine: it owns no backend. Each
//! [`step`](WavefrontSession::step) borrows a [`StepBackend`] for one
//! grouped launch, which keeps it usable from the single-shot
//! [`Executor`](crate::scheduler::Executor) (which is now the
//! one-request special case) and from the serving engine's drain loop
//! ([`InferenceEngine::serve_queue`](crate::coordinator::InferenceEngine::serve_queue)),
//! where new requests are admitted between iterations.
//!
//! **Parallel execution.** Because every `(layer, lane)` cell of one
//! grouped launch is independent, the backend may execute them
//! concurrently — the native backend's
//! [`ParallelCellPool`](crate::model::ParallelCellPool) fans the grid
//! out across worker threads and joins inside `grouped_step`, i.e.
//! strictly before step (5)/(6) below hand each cell's `(y, A', z')`
//! to the next diagonal. The session itself needs no synchronization:
//! by the time `grouped_step` returns, the whole wavefront has landed,
//! and results are written by slot index so a pooled step is
//! bit-identical to a sequential one (`rust/tests/parallel_parity.rs`).

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::scheduler::executor::{segment_tokens, RunStats, StepBackend};
use crate::tensor::Tensor;

/// One wavefront cell's occupant: (request, segment) at a (layer, lane).
#[derive(Clone, Copy, Debug)]
struct CellTag {
    req: u64,
    seg: usize,
}

/// Bookkeeping for a request between `submit` and completion.
struct Inflight {
    segments: Vec<Vec<u32>>,
    /// Next segment index to inject at layer 0.
    next_seg: usize,
    /// Completed per-segment logits, in segment order.
    logits: Vec<Tensor>,
    submitted: Instant,
    /// Iteration counter value when segment 0 was injected.
    first_iter: Option<u64>,
    /// Session counters snapshotted at first injection (for the
    /// request's occupancy window).
    active0: u64,
    slot0: u64,
}

/// A completed request: per-segment logits plus its slice of the
/// session's utilization accounting.
#[derive(Clone, Debug)]
pub struct SessionOutput {
    pub id: u64,
    /// One `[seg, vocab]` logits tensor per segment, in order.
    pub logits: Vec<Tensor>,
    pub stats: RunStats,
}

/// Persistent multi-request diagonal wavefront over `L x B` slots.
///
/// # Examples
///
/// Pack two requests into a single-lane wavefront: the second request's
/// ramp-up fills the first one's ramp-down bubbles, and each request's
/// logits stay bit-identical to running it alone:
///
/// ```no_run
/// use diagonal_batching::config::Manifest;
/// use diagonal_batching::model::{NativeBackend, Params};
/// use diagonal_batching::scheduler::WavefrontSession;
///
/// let manifest = Manifest::load("artifacts/manifest.json").unwrap();
/// let entry = manifest.model("tiny").unwrap();
/// let mut backend =
///     NativeBackend::new(entry.config.clone(), Params::load(&manifest, "tiny").unwrap());
///
/// let mut session = WavefrontSession::new(entry.config.clone(), 1);
/// session.submit(1, &[3, 1, 4, 1, 5, 9, 2, 6]).unwrap();
/// session.submit(2, &(0..1024).map(|i| i % 100).collect::<Vec<u32>>()).unwrap();
/// // Step manually (a server admits new requests between steps)...
/// while session.step(&mut backend).unwrap() {
///     if let Some(done) = session.pop_completed() {
///         println!("request {} finished: {} segments", done.id, done.logits.len());
///     }
/// }
/// // ...or drain in one call: session.run_to_completion(&mut backend).
/// let stats = session.stats();
/// println!("mean group {:.2}, occupancy {:.2}", stats.mean_group(), stats.occupancy());
/// ```
pub struct WavefrontSession {
    cfg: ModelConfig,
    lanes: usize,
    /// Hidden-state slots `[L, B, T, d]`; slot row `l` is bound to layer
    /// `l`, lanes are independent streams.
    x_slots: Tensor,
    /// Associative memory `[L, B, d, p]`, keyed by whichever request is
    /// streaming through the lane.
    a: Tensor,
    /// Normalizer state `[L, B, p]`.
    z: Tensor,
    /// Cell occupancy, row-major `[L * B]`; `None` = masked slot.
    tags: Vec<Option<CellTag>>,
    /// Per-lane request currently streaming segments into slot 0.
    streams: Vec<Option<u64>>,
    /// Admitted requests waiting for a free lane (FIFO).
    pending: VecDeque<u64>,
    inflight: HashMap<u64, Inflight>,
    done: VecDeque<SessionOutput>,
    iterations: u64,
    active_cells: u64,
    slot_steps: u64,
    segments_done: usize,
    tokens_done: usize,
    started: Instant,
}

impl WavefrontSession {
    /// A session over `lanes` slot lanes (`lanes = 1` reproduces the
    /// single-request executor's launch shapes exactly).
    pub fn new(cfg: ModelConfig, lanes: usize) -> Self {
        let lanes = lanes.max(1);
        let l = cfg.n_layers;
        Self {
            x_slots: Tensor::zeros(&[l, lanes, cfg.seg_total, cfg.d_model]),
            a: Tensor::zeros(&[l, lanes, cfg.d_model, cfg.phi_dim]),
            z: Tensor::zeros(&[l, lanes, cfg.phi_dim]),
            tags: vec![None; l * lanes],
            streams: vec![None; lanes],
            pending: VecDeque::new(),
            inflight: HashMap::new(),
            done: VecDeque::new(),
            iterations: 0,
            active_cells: 0,
            slot_steps: 0,
            segments_done: 0,
            tokens_done: 0,
            started: Instant::now(),
            cfg,
            lanes,
        }
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Requests admitted but not yet streaming (no free lane yet).
    pub fn backlog(&self) -> usize {
        self.pending.len()
    }

    /// Requests admitted and not yet completed.
    pub fn in_flight(&self) -> usize {
        self.inflight.len()
    }

    /// True when every admitted request has completed.
    pub fn is_idle(&self) -> bool {
        self.inflight.is_empty()
    }

    /// Admit a request; it starts streaming as soon as a lane frees up
    /// (possibly this very iteration). `id` must be unique among
    /// in-flight requests.
    pub fn submit(&mut self, id: u64, tokens: &[u32]) -> Result<()> {
        let segments = segment_tokens(&self.cfg, tokens)?;
        self.submit_segments(id, segments)
    }

    /// [`submit`](Self::submit) for pre-segmented input.
    pub fn submit_segments(&mut self, id: u64, segments: Vec<Vec<u32>>) -> Result<()> {
        if segments.is_empty() {
            return Err(Error::Request("empty token sequence".into()));
        }
        if segments.iter().any(|s| s.len() != self.cfg.seg) {
            return Err(Error::Request(format!(
                "every segment must hold exactly {} tokens",
                self.cfg.seg
            )));
        }
        if self.inflight.contains_key(&id) {
            return Err(Error::Request(format!("request id {id} already in flight")));
        }
        self.inflight.insert(
            id,
            Inflight {
                segments,
                next_seg: 0,
                logits: Vec::new(),
                submitted: Instant::now(),
                first_iter: None,
                active0: 0,
                slot0: 0,
            },
        );
        self.pending.push_back(id);
        Ok(())
    }

    /// Next completed request, in completion order (which is generally
    /// NOT submission order once requests of different lengths pack).
    pub fn pop_completed(&mut self) -> Option<SessionOutput> {
        self.done.pop_front()
    }

    /// All completed requests accumulated so far.
    pub fn drain_completed(&mut self) -> Vec<SessionOutput> {
        self.done.drain(..).collect()
    }

    /// Session-aggregate utilization: `launches` = wavefront iterations,
    /// `cells` = active cells across all requests, and the padded /
    /// occupancy accounting over every slot-step since construction.
    pub fn stats(&self) -> RunStats {
        RunStats {
            mode_diagonal: true,
            segments: self.segments_done,
            launches: self.iterations,
            cells: self.active_cells,
            slot_steps: self.slot_steps,
            padded_cells: self.slot_steps - self.active_cells,
            wall: self.started.elapsed(),
            tokens: self.tokens_done,
        }
    }

    /// Advance the wavefront one iteration: inject segments into free
    /// slot-0 lanes, run one grouped step, emit finished segments at
    /// layer L-1, shift. Returns `false` (without touching the backend)
    /// when there is nothing in flight.
    pub fn step<B: StepBackend + ?Sized>(&mut self, backend: &mut B) -> Result<bool> {
        let l_total = self.cfg.n_layers;
        let b_total = self.lanes;
        if backend.config() != &self.cfg {
            return Err(Error::Config(
                "WavefrontSession config does not match the backend's".into(),
            ));
        }

        // (1) Injection: each lane pulls the next segment of its stream,
        // or starts the next pending request the moment its stream ends.
        for lane in 0..b_total {
            let tag = loop {
                match self.streams[lane] {
                    Some(req) => {
                        let fl = self.inflight.get_mut(&req).expect("stream request in flight");
                        if fl.next_seg < fl.segments.len() {
                            let seg_idx = fl.next_seg;
                            fl.next_seg += 1;
                            if fl.first_iter.is_none() {
                                fl.first_iter = Some(self.iterations);
                                fl.active0 = self.active_cells;
                                fl.slot0 = self.slot_steps;
                            }
                            let emb = backend.embed(&fl.segments[seg_idx])?;
                            self.x_slots.set_index01(0, lane, &emb);
                            break Some(CellTag { req, seg: seg_idx });
                        }
                        // Stream exhausted; free the lane and retry.
                        self.streams[lane] = None;
                    }
                    None => match self.pending.pop_front() {
                        Some(req) => self.streams[lane] = Some(req),
                        None => break None,
                    },
                }
            };
            self.tags[lane] = tag;
        }

        // (2) Occupancy accounting; bail out if the wavefront is empty.
        let active = self.tags.iter().flatten().count() as u64;
        if active == 0 {
            debug_assert!(self.inflight.is_empty(), "idle wavefront with requests in flight");
            return Ok(false);
        }
        self.iterations += 1;
        self.active_cells += active;
        self.slot_steps += (l_total * b_total) as u64;

        // (3) Request boundary: a first segment reaching layer `l` finds
        // the previous request's final state in the lane — reset to the
        // empty memory a fresh request starts from.
        let mut mask = vec![0.0f32; l_total * b_total];
        for l in 0..l_total {
            for lane in 0..b_total {
                if let Some(t) = self.tags[l * b_total + lane] {
                    mask[l * b_total + lane] = 1.0;
                    if t.seg == 0 {
                        self.a.zero_index01(l, lane);
                        self.z.zero_index01(l, lane);
                    }
                }
            }
        }

        // (4) One grouped launch over all L x B slots.
        let (y, a2, z2) = backend.grouped_step(&self.x_slots, &self.a, &self.z, &mask)?;
        self.a = a2;
        self.z = z2;

        // (5) Segments exit fully processed at the last layer; a
        // request completes when its final segment exits.
        for lane in 0..b_total {
            if let Some(t) = self.tags[(l_total - 1) * b_total + lane] {
                let logits = backend.lm_head(&y.index01(l_total - 1, lane))?;
                let finished = {
                    let fl = self.inflight.get_mut(&t.req).expect("exiting request in flight");
                    debug_assert_eq!(fl.logits.len(), t.seg, "segments exit in order");
                    fl.logits.push(logits);
                    fl.logits.len() == fl.segments.len()
                };
                if finished {
                    let fl = self.inflight.remove(&t.req).expect("finished request");
                    let s_total = fl.segments.len();
                    let span = self.iterations - fl.first_iter.expect("completed => injected");
                    let slot_span = self.slot_steps - fl.slot0;
                    let active_span = self.active_cells - fl.active0;
                    let stats = RunStats {
                        mode_diagonal: true,
                        segments: s_total,
                        launches: span,
                        cells: (s_total * l_total) as u64,
                        slot_steps: slot_span,
                        padded_cells: slot_span - active_span,
                        wall: fl.submitted.elapsed(),
                        tokens: s_total * self.cfg.seg,
                    };
                    self.segments_done += s_total;
                    self.tokens_done += stats.tokens;
                    self.done.push_back(SessionOutput { id: t.req, logits: fl.logits, stats });
                }
            }
        }

        // (6) Shift: next iteration, slot (l, lane) holds what (l-1,
        // lane) just produced — each cell advanced one layer.
        for l in (1..l_total).rev() {
            for lane in 0..b_total {
                if self.tags[(l - 1) * b_total + lane].is_some() {
                    self.x_slots.set_index01(l, lane, &y.index01(l - 1, lane));
                }
                self.tags[l * b_total + lane] = self.tags[(l - 1) * b_total + lane];
            }
        }
        Ok(true)
    }

    /// Step until every admitted request has completed.
    pub fn run_to_completion<B: StepBackend + ?Sized>(&mut self, backend: &mut B) -> Result<()> {
        while self.step(backend)? {}
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NativeBackend, Params};
    use crate::scheduler::{Executor, ScheduleMode};

    fn cfg() -> ModelConfig {
        crate::model::tests::test_config() // L = 3, seg = 8
    }

    fn backend(seed: u64) -> NativeBackend {
        let c = cfg();
        let params = Params::random(&c, seed);
        NativeBackend::new(c, params)
    }

    fn tokens(n: usize, salt: u32) -> Vec<u32> {
        (0..n as u32).map(|i| (i * 7 + salt) % 64).collect()
    }

    /// Reference: each request alone through the sequential executor on
    /// a fresh backend with the same weights.
    fn sequential_reference(seed: u64, toks: &[u32]) -> Vec<Tensor> {
        let mut b = backend(seed);
        Executor::new(&mut b, ScheduleMode::Sequential).run(toks).unwrap().logits
    }

    #[test]
    fn two_requests_one_lane_fill_each_others_ramps() {
        let mut b = backend(41);
        let mut session = WavefrontSession::new(cfg(), 1);
        let r1 = tokens(8 * 4, 3);
        let r2 = tokens(8 * 4, 11);
        session.submit(1, &r1).unwrap();
        session.submit(2, &r2).unwrap();
        session.run_to_completion(&mut b).unwrap();

        // Packed: 2S + L - 1 iterations instead of 2 * (S + L - 1).
        let stats = session.stats();
        assert_eq!(stats.launches, (2 * 4 + 3 - 1) as u64);
        assert_eq!(stats.cells, (2 * 4 * 3) as u64);
        let solo = (4 * 3) as f64 / (4 + 3 - 1) as f64;
        assert!(stats.mean_group() > solo, "{} vs solo {solo}", stats.mean_group());

        let mut outs = session.drain_completed();
        outs.sort_by_key(|o| o.id);
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].logits, sequential_reference(41, &r1));
        assert_eq!(outs[1].logits, sequential_reference(41, &r2));
    }

    #[test]
    fn multi_lane_bitexact_and_out_of_order_completion() {
        let mut b = backend(42);
        let mut session = WavefrontSession::new(cfg(), 2);
        let long = tokens(8 * 6, 5);
        let short = tokens(8 * 2, 9);
        session.submit(10, &long).unwrap();
        session.submit(11, &short).unwrap();
        session.run_to_completion(&mut b).unwrap();

        // The short request finishes first despite later submission.
        let first = session.pop_completed().unwrap();
        assert_eq!(first.id, 11);
        assert_eq!(first.logits, sequential_reference(42, &short));
        let second = session.pop_completed().unwrap();
        assert_eq!(second.id, 10);
        assert_eq!(second.logits, sequential_reference(42, &long));
        assert!(session.pop_completed().is_none());
    }

    #[test]
    fn mid_flight_admission_is_exact() {
        let mut b = backend(43);
        let mut session = WavefrontSession::new(cfg(), 1);
        let r1 = tokens(8 * 5, 2);
        session.submit(1, &r1).unwrap();
        for _ in 0..3 {
            session.step(&mut b).unwrap();
        }
        let r2 = tokens(8 * 3 - 2, 6); // ragged tail
        session.submit(2, &r2).unwrap();
        session.run_to_completion(&mut b).unwrap();
        let mut outs = session.drain_completed();
        outs.sort_by_key(|o| o.id);
        assert_eq!(outs[0].logits, sequential_reference(43, &r1));
        assert_eq!(outs[1].logits, sequential_reference(43, &r2));
    }

    #[test]
    fn per_request_stats_match_solo_shapes() {
        // A lone request in a 1-lane session must report exactly the
        // Fig. 3 arithmetic of the single-shot diagonal executor.
        let mut b = backend(44);
        let mut session = WavefrontSession::new(cfg(), 1);
        session.submit(7, &tokens(8 * 5, 1)).unwrap();
        session.run_to_completion(&mut b).unwrap();
        let out = session.pop_completed().unwrap();
        let (s, l) = (5u64, 3u64);
        assert_eq!(out.stats.launches, s + l - 1);
        assert_eq!(out.stats.cells, s * l);
        assert_eq!(out.stats.slot_steps, (s + l - 1) * l);
        assert_eq!(out.stats.padded_cells, l * (l - 1));
        assert_eq!(out.stats.segments, 5);
        assert!(out.stats.occupancy() > 0.0 && out.stats.occupancy() < 1.0);
    }

    #[test]
    fn rejects_empty_and_duplicate_ids() {
        let mut session = WavefrontSession::new(cfg(), 2);
        assert!(session.submit(1, &[]).is_err());
        session.submit(1, &tokens(8, 0)).unwrap();
        assert!(session.submit(1, &tokens(8, 0)).is_err());
    }

    #[test]
    fn idle_step_is_a_no_op() {
        let mut b = backend(45);
        let mut session = WavefrontSession::new(cfg(), 1);
        assert!(!session.step(&mut b).unwrap());
        assert!(session.is_idle());
        session.submit(1, &tokens(8, 4)).unwrap();
        assert!(session.step(&mut b).unwrap());
        session.run_to_completion(&mut b).unwrap();
        assert!(!session.step(&mut b).unwrap());
        assert_eq!(session.drain_completed().len(), 1);
    }
}
