//! [`WavefrontSession`]: the persistent, multi-request diagonal
//! wavefront — continuous batching for the ARMT (segment, layer) grid.
//!
//! Algorithm 1 runs one request's segments through `L` layer-bound slots
//! and pays `(L-1)·L/2` masked slot-steps on each ramp. But the
//! dependency structure (dag.rs) is *per request*: a slot at layer `l`
//! can carry any request's segment, because cell `(r, s, l)` depends
//! only on `(r, s-1, l)` and `(r, s, l-1)`. The session exploits this in
//! two ways:
//!
//! * **stream packing** — when a request's last segment enters slot 0,
//!   the next request's segment 0 follows on the very next iteration, so
//!   one request's ramp-down overlaps the next one's ramp-up and the
//!   pipeline never drains between requests;
//! * **slot lanes** — each of the `L` layer slots is widened to `B`
//!   lanes (`grouped_step` over `[L, B, T, d]`), so up to `B` requests
//!   stream concurrently with a single launch per iteration.
//!
//! Exactness is preserved per request: segments still traverse layers in
//! order against that request's own `(A, z)` memory, which lives in the
//! `(layer, lane)` slot the request streams through. At a request
//! boundary the first segment of the new request zeroes the slot state
//! at each layer it reaches (a fresh request starts from empty memory),
//! so a packed run is bit-identical to per-request execution on an
//! order-preserving backend — the property `rust/tests/scheduler_props`
//! checks (P7).
//!
//! The session is a plain state machine: it owns no backend. Each
//! [`step`](WavefrontSession::step) borrows a [`StepBackend`] for one
//! grouped launch, which keeps it usable from the single-shot
//! [`Executor`](crate::scheduler::Executor) (which is now the
//! one-request special case) and from the serving engine's drain loop
//! ([`InferenceEngine::serve_queue`](crate::coordinator::InferenceEngine::serve_queue)),
//! where new requests are admitted between iterations.
//!
//! **Parallel execution.** Because every `(layer, lane)` cell of one
//! grouped launch is independent, the backend may execute them
//! concurrently — the native backend's
//! [`ParallelCellPool`](crate::model::ParallelCellPool) fans the grid
//! out across worker threads and joins inside `grouped_step`, i.e.
//! strictly before step (5)/(6) below hand each cell's `(y, A', z')`
//! to the next diagonal. The session itself needs no synchronization:
//! by the time `grouped_step` returns, the whole wavefront has landed,
//! and results are written by slot index so a pooled step is
//! bit-identical to a sequential one (`rust/tests/parallel_parity.rs`).
//!
//! **Decode phase (streaming generation).** A request submitted with
//! [`submit_stream`](WavefrontSession::submit_stream) keeps its token
//! stream *open*: after the queued segments drain, the lane stays
//! reserved and the caller may feed further segments with
//! [`append_segment`](WavefrontSession::append_segment) — this is how
//! the serving engine implements autoregressive decode: each segment
//! that exits the last layer is surfaced immediately as a
//! [`SegmentExit`] (via [`pop_exited`](WavefrontSession::pop_exited)),
//! the engine samples the next segment from its logits and appends it
//! to the *same live wavefront*. Exact recurrence is preserved by
//! construction — a decode segment is just one more segment of the same
//! request, streaming through the same lane against the same `(A, z)`
//! memory, so the generated continuation is bit-identical to running
//! prompt + generated tokens through the single-shot executor. While a
//! request waits for its frontier segment to exit (the inherent
//! `L - 1`-iteration recurrence latency of autoregressive decode), its
//! lane injects nothing — but *other* lanes and requests keep filling
//! the grouped launches, which is what keeps multi-user generation
//! packed instead of serialized. [`finish_stream`](WavefrontSession::finish_stream)
//! closes an open stream; [`cancel`](WavefrontSession::cancel) evicts a
//! request anywhere in its lifecycle, freeing its lane and zeroing its
//! memory slots.
//!
//! **Snapshots and resume (memory-state cache).** Because the per-lane
//! recurrent state is constant-size, a request's inference can be
//! frozen after any segment `k` as a [`MemSnapshot`] and continued
//! later — bit-exactly. Two primitives carry the whole
//! [`crate::cache`] subsystem:
//!
//! * [`submit_stream_resumed`](WavefrontSession::submit_stream_resumed)
//!   admits a request whose first `snapshot.segments` segments were
//!   already computed elsewhere: instead of zeroing each layer's
//!   `(A, z)` as its first segment arrives (the request-boundary
//!   rule), the lane is seeded from the snapshot layer by layer, and
//!   segment indices continue from the snapshot's recurrence counter —
//!   so the resumed cells are indistinguishable, state-wise, from the
//!   cells a full run would have executed;
//! * [`capture_after`](WavefrontSession::capture_after) /
//!   [`capture_final`](WavefrontSession::capture_final) record a
//!   request's post-segment state as it streams: a targeted segment's
//!   per-layer states are collected while it ascends the wavefront and
//!   the completed snapshot rides its [`SegmentExit`]; the final
//!   memory state (after the last segment, whatever index that turns
//!   out to be) lands in [`SessionOutput::final_state`]. Capture never
//!   perturbs execution — it only clones state the step already
//!   produced.

use std::collections::{HashMap, HashSet, VecDeque};
use std::time::Instant;

use crate::cache::MemSnapshot;
use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::quality::SegmentSignals;
use crate::scheduler::executor::{segment_tokens, RunStats, StepBackend};
use crate::tensor::Tensor;

/// One wavefront cell's occupant: (request, segment) at a (layer, lane).
#[derive(Clone, Copy, Debug)]
struct CellTag {
    req: u64,
    seg: usize,
}

/// Snapshot-capture bookkeeping for one request (only allocated when
/// the caller asked for snapshots).
struct Capture {
    /// Absolute segment indices to snapshot after. A set: the capture
    /// loop probes it once per tagged cell per step, and with the
    /// prefix cache enabled every prompt boundary is a target — a Vec
    /// scan would be quadratic in prompt length.
    targets: HashSet<usize>,
    /// Per-target per-layer post-cell states, filled as the target
    /// segment ascends the wavefront; complete exactly when the target
    /// exits layer `L - 1`.
    building: HashMap<usize, Vec<Option<(Tensor, Tensor)>>>,
    /// Keep the latest post-cell state per layer; at completion this is
    /// the request's final memory (segments traverse a layer in order,
    /// so the last write per layer is the last segment's).
    capture_final: bool,
    last: Vec<Option<(Tensor, Tensor)>>,
}

impl Capture {
    fn new(n_layers: usize) -> Self {
        Self {
            targets: HashSet::new(),
            building: HashMap::new(),
            capture_final: false,
            last: vec![None; n_layers],
        }
    }
}

/// Bookkeeping for a request between `submit` and completion.
struct Inflight {
    segments: Vec<Vec<u32>>,
    /// Next segment index to inject at layer 0 (relative to
    /// `segments`; absolute index = `seg_offset + next_seg`).
    next_seg: usize,
    /// Segments that have exited the last layer so far (count of
    /// *computed* segments, excluding any resumed prefix).
    exited: usize,
    /// Absolute index of `segments[0]`: 0 for fresh requests, the
    /// snapshot's recurrence counter for resumed ones.
    seg_offset: usize,
    /// Memory state seeding the lane instead of the zero reset, applied
    /// layer-by-layer as the first (resumed) segment arrives.
    resume: Option<MemSnapshot>,
    /// Snapshot-capture state ([`WavefrontSession::capture_after`] /
    /// [`WavefrontSession::capture_final`]).
    capture: Option<Capture>,
    /// Open streams (`submit_stream`) may still grow via
    /// `append_segment`; their lane stays reserved while they wait.
    open: bool,
    /// Surface per-segment exits through the [`SegmentExit`] queue.
    events: bool,
    /// Accumulate per-segment logits for the final [`SessionOutput`]
    /// (off for streaming requests that only consume exit events).
    keep_logits: bool,
    /// Completed per-segment logits, in segment order (`keep_logits`).
    logits: Vec<Tensor>,
    /// Absolute segment indices whose recurrent memory write is gated
    /// (quality tier, `overflow: "select"`): the cell still runs and
    /// its attention output feeds the next layer, but the `(A, z)`
    /// state it would have written is restored after the launch.
    gated: HashSet<usize>,
    /// Quality-tier observation: `|Δ‖A‖²|` accumulated over this
    /// request's cells since its previous segment exit.
    energy_update_acc: f64,
    submitted: Instant,
    /// Iteration counter value when segment 0 was injected.
    first_iter: Option<u64>,
    /// Session counters snapshotted at first injection (for the
    /// request's occupancy window).
    active0: u64,
    slot0: u64,
}

impl Inflight {
    /// Pop the completed targeted snapshot for `seg` (absolute index),
    /// if one was requested and every layer's state landed. Called at
    /// the segment's exit — layer `L - 1` is the last to run, so the
    /// snapshot completes in the exit's own iteration.
    fn take_ready_snapshot(&mut self, cfg: &ModelConfig, seg: usize) -> Option<MemSnapshot> {
        let cap = self.capture.as_mut()?;
        if !cap.targets.remove(&seg) {
            return None;
        }
        let layers = cap.building.remove(&seg)?;
        let layers: Option<Vec<(Tensor, Tensor)>> = layers.into_iter().collect();
        MemSnapshot::from_layers(cfg, seg + 1, layers?).ok()
    }
}

/// A segment that just exited the last layer — the streaming
/// observation the decode loop feeds on. Only emitted for requests
/// admitted via [`WavefrontSession::submit_stream`].
#[derive(Clone, Debug)]
pub struct SegmentExit {
    pub id: u64,
    /// Absolute segment index within the request (resumed requests
    /// continue counting from their snapshot), in exit order.
    pub index: usize,
    /// `[seg, vocab]` logits of the exited segment.
    pub logits: Tensor,
    /// The post-segment memory state, when this segment was requested
    /// via [`WavefrontSession::capture_after`].
    pub snapshot: Option<MemSnapshot>,
    /// Quality-tier saturation signals: how much the request's
    /// associative memory moved for this segment vs how much it already
    /// holds. Observation only — computed on the engine thread in fixed
    /// slot order, so they are deterministic across worker thread
    /// counts and never influence the arithmetic.
    pub signals: SegmentSignals,
}

/// A completed request: per-segment logits plus its slice of the
/// session's utilization accounting.
#[derive(Clone, Debug)]
pub struct SessionOutput {
    pub id: u64,
    /// One `[seg, vocab]` logits tensor per segment, in order.
    pub logits: Vec<Tensor>,
    /// The request's final memory state, when requested via
    /// [`WavefrontSession::capture_final`] — the suspend half of
    /// conversation suspend/resume.
    pub final_state: Option<MemSnapshot>,
    pub stats: RunStats,
}

/// Persistent multi-request diagonal wavefront over `L x B` slots.
///
/// # Examples
///
/// Pack two requests into a single-lane wavefront: the second request's
/// ramp-up fills the first one's ramp-down bubbles, and each request's
/// logits stay bit-identical to running it alone:
///
/// ```no_run
/// use diagonal_batching::config::Manifest;
/// use diagonal_batching::model::{NativeBackend, Params};
/// use diagonal_batching::scheduler::WavefrontSession;
///
/// let manifest = Manifest::load("artifacts/manifest.json").unwrap();
/// let entry = manifest.model("tiny").unwrap();
/// let mut backend =
///     NativeBackend::new(entry.config.clone(), Params::load(&manifest, "tiny").unwrap());
///
/// let mut session = WavefrontSession::new(entry.config.clone(), 1);
/// session.submit(1, &[3, 1, 4, 1, 5, 9, 2, 6]).unwrap();
/// session.submit(2, &(0..1024).map(|i| i % 100).collect::<Vec<u32>>()).unwrap();
/// // Step manually (a server admits new requests between steps)...
/// while session.step(&mut backend).unwrap() {
///     if let Some(done) = session.pop_completed() {
///         println!("request {} finished: {} segments", done.id, done.logits.len());
///     }
/// }
/// // ...or drain in one call: session.run_to_completion(&mut backend).
/// let stats = session.stats();
/// println!("mean group {:.2}, occupancy {:.2}", stats.mean_group(), stats.occupancy());
/// ```
pub struct WavefrontSession {
    cfg: ModelConfig,
    lanes: usize,
    /// Hidden-state slots `[L, B, T, d]`; slot row `l` is bound to layer
    /// `l`, lanes are independent streams.
    x_slots: Tensor,
    /// Associative memory `[L, B, d, p]`, keyed by whichever request is
    /// streaming through the lane.
    a: Tensor,
    /// Normalizer state `[L, B, p]`.
    z: Tensor,
    /// Cell occupancy, row-major `[L * B]`; `None` = masked slot.
    tags: Vec<Option<CellTag>>,
    /// Quality-tier observation: `‖A‖²` per `(layer, lane)` slot after
    /// the most recent launch (f64, accumulated in fixed order on the
    /// engine thread — deterministic across worker thread counts).
    a_energy: Vec<f64>,
    /// Per-lane request currently streaming segments into slot 0.
    streams: Vec<Option<u64>>,
    /// Admitted requests waiting for a free lane (FIFO).
    pending: VecDeque<u64>,
    inflight: HashMap<u64, Inflight>,
    done: VecDeque<SessionOutput>,
    /// Per-segment exits of event-emitting requests, in exit order.
    exits: VecDeque<SegmentExit>,
    iterations: u64,
    active_cells: u64,
    slot_steps: u64,
    segments_done: usize,
    tokens_done: usize,
    started: Instant,
}

impl WavefrontSession {
    /// A session over `lanes` slot lanes (`lanes = 1` reproduces the
    /// single-request executor's launch shapes exactly).
    pub fn new(cfg: ModelConfig, lanes: usize) -> Self {
        let lanes = lanes.max(1);
        let l = cfg.n_layers;
        Self {
            x_slots: Tensor::zeros(&[l, lanes, cfg.seg_total, cfg.d_model]),
            a: Tensor::zeros(&[l, lanes, cfg.d_model, cfg.phi_dim]),
            z: Tensor::zeros(&[l, lanes, cfg.phi_dim]),
            tags: vec![None; l * lanes],
            a_energy: vec![0.0; l * lanes],
            streams: vec![None; lanes],
            pending: VecDeque::new(),
            inflight: HashMap::new(),
            done: VecDeque::new(),
            exits: VecDeque::new(),
            iterations: 0,
            active_cells: 0,
            slot_steps: 0,
            segments_done: 0,
            tokens_done: 0,
            started: Instant::now(),
            cfg,
            lanes,
        }
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Requests admitted but not yet streaming (no free lane yet).
    pub fn backlog(&self) -> usize {
        self.pending.len()
    }

    /// Requests admitted and not yet completed.
    pub fn in_flight(&self) -> usize {
        self.inflight.len()
    }

    /// True when every admitted request has completed.
    pub fn is_idle(&self) -> bool {
        self.inflight.is_empty()
    }

    /// Lane request `id` currently streams through, or `None` while it
    /// is still backlogged (or unknown). Spans use this as their
    /// Chrome-trace `tid`, so a packed wavefront renders one timeline
    /// row per lane.
    pub fn lane_of(&self, id: u64) -> Option<usize> {
        self.streams.iter().position(|s| *s == Some(id))
    }

    /// Admit a request; it starts streaming as soon as a lane frees up
    /// (possibly this very iteration). `id` must be unique among
    /// in-flight requests.
    pub fn submit(&mut self, id: u64, tokens: &[u32]) -> Result<()> {
        let segments = segment_tokens(&self.cfg, tokens)?;
        self.submit_segments(id, segments)
    }

    /// [`submit`](Self::submit) for pre-segmented input.
    pub fn submit_segments(&mut self, id: u64, segments: Vec<Vec<u32>>) -> Result<()> {
        self.admit(id, segments, false, false, true, 0, None)
    }

    /// Admit a request with an *open* token stream: after the queued
    /// `segments` drain, the request's lane stays reserved and further
    /// segments may be fed with [`append_segment`](Self::append_segment)
    /// (autoregressive decode) until [`finish_stream`](Self::finish_stream)
    /// closes it. Every exiting segment is surfaced as a [`SegmentExit`].
    /// `keep_logits` controls whether the final [`SessionOutput`] also
    /// accumulates per-segment logits (streaming consumers usually only
    /// need the exit events).
    pub fn submit_stream(
        &mut self,
        id: u64,
        segments: Vec<Vec<u32>>,
        keep_logits: bool,
    ) -> Result<()> {
        self.admit(id, segments, true, true, keep_logits, 0, None)
    }

    /// [`submit_stream`](Self::submit_stream) for a request whose first
    /// `snapshot.segments` segments were already computed: the lane is
    /// seeded from the snapshot's per-layer `(A, z)` instead of the
    /// zero reset, `remaining` holds only the segments still to run,
    /// and segment indices (exit events, [`capture_after`](Self::capture_after)
    /// targets) continue from the snapshot's recurrence counter. The
    /// computed cells are bit-identical to the tail of a full run —
    /// the cache subsystem's exactness contract
    /// (`rust/tests/cache_resume.rs`, P11).
    pub fn submit_stream_resumed(
        &mut self,
        id: u64,
        snapshot: MemSnapshot,
        remaining: Vec<Vec<u32>>,
        keep_logits: bool,
    ) -> Result<()> {
        snapshot.validate_for(&self.cfg)?;
        let offset = snapshot.segments;
        self.admit(id, remaining, true, true, keep_logits, offset, Some(snapshot))
    }

    #[allow(clippy::too_many_arguments)]
    fn admit(
        &mut self,
        id: u64,
        segments: Vec<Vec<u32>>,
        open: bool,
        events: bool,
        keep_logits: bool,
        seg_offset: usize,
        resume: Option<MemSnapshot>,
    ) -> Result<()> {
        if segments.is_empty() {
            return Err(Error::Request("empty token sequence".into()));
        }
        if segments.iter().any(|s| s.len() != self.cfg.seg) {
            return Err(Error::Request(format!(
                "every segment must hold exactly {} tokens",
                self.cfg.seg
            )));
        }
        if self.inflight.contains_key(&id) {
            return Err(Error::Request(format!("request id {id} already in flight")));
        }
        self.inflight.insert(
            id,
            Inflight {
                segments,
                next_seg: 0,
                exited: 0,
                seg_offset,
                resume,
                capture: None,
                open,
                events,
                keep_logits,
                logits: Vec::new(),
                gated: HashSet::new(),
                energy_update_acc: 0.0,
                submitted: Instant::now(),
                first_iter: None,
                active0: 0,
                slot0: 0,
            },
        );
        self.pending.push_back(id);
        Ok(())
    }

    /// Request the post-segment memory state of absolute segment
    /// `seg_index`: once that segment exits the last layer, its
    /// [`SegmentExit::snapshot`] carries a complete [`MemSnapshot`]
    /// (recurrence counter `seg_index + 1`). Only stream submissions
    /// accept targets (the exit event is the delivery channel), and the
    /// target must not have entered the wavefront yet — call right
    /// after submission, or for decode segments not yet appended.
    pub fn capture_after(&mut self, id: u64, seg_index: usize) -> Result<()> {
        let l_total = self.cfg.n_layers;
        match self.inflight.get_mut(&id) {
            None => Err(Error::Request(format!("request id {id} not in flight"))),
            Some(fl) if !fl.events => Err(Error::Request(format!(
                "request id {id}: targeted snapshots need a stream submission \
                 (exit events deliver them)"
            ))),
            Some(fl) => {
                if seg_index < fl.seg_offset + fl.next_seg {
                    return Err(Error::Request(format!(
                        "request id {id}: segment {seg_index} already entered the wavefront"
                    )));
                }
                fl.capture
                    .get_or_insert_with(|| Capture::new(l_total))
                    .targets
                    .insert(seg_index);
                Ok(())
            }
        }
    }

    /// Keep the request's *final* memory state (after its last segment,
    /// whatever index that turns out to be — decode lengths are not
    /// known up front): delivered in [`SessionOutput::final_state`] at
    /// completion. Works for open and closed submissions alike.
    pub fn capture_final(&mut self, id: u64) -> Result<()> {
        let l_total = self.cfg.n_layers;
        match self.inflight.get_mut(&id) {
            None => Err(Error::Request(format!("request id {id} not in flight"))),
            Some(fl) => {
                fl.capture.get_or_insert_with(|| Capture::new(l_total)).capture_final = true;
                Ok(())
            }
        }
    }

    /// Gate the recurrent memory write for the given ABSOLUTE segment
    /// indices of an in-flight request (quality tier,
    /// `overflow: "select"`). A gated segment still runs — its
    /// attention output feeds the next layer and its logits exit
    /// normally — but the `(A, z)` state its cells would have written
    /// is restored to the pre-segment value, as if the segment had
    /// never entered memory. Call before the gated segments enter the
    /// wavefront (right after submission); an empty set (the default)
    /// is bit-identical to a build without this mechanism.
    pub fn set_memory_gates(&mut self, id: u64, gates: HashSet<usize>) -> Result<()> {
        match self.inflight.get_mut(&id) {
            None => Err(Error::Request(format!("request id {id} not in flight"))),
            Some(fl) => {
                fl.gated = gates;
                Ok(())
            }
        }
    }

    /// Feed one more segment to an open stream (the decode hand-off:
    /// the engine samples this segment from the previous [`SegmentExit`]'s
    /// logits). The segment enters the request's reserved lane at the
    /// next [`step`](Self::step).
    pub fn append_segment(&mut self, id: u64, tokens: Vec<u32>) -> Result<()> {
        if tokens.len() != self.cfg.seg {
            return Err(Error::Request(format!(
                "every segment must hold exactly {} tokens",
                self.cfg.seg
            )));
        }
        match self.inflight.get_mut(&id) {
            None => Err(Error::Request(format!("request id {id} not in flight"))),
            Some(fl) if !fl.open => {
                Err(Error::Request(format!("request id {id}: stream already closed")))
            }
            Some(fl) => {
                fl.segments.push(tokens);
                Ok(())
            }
        }
    }

    /// Close an open stream: no further [`append_segment`](Self::append_segment)
    /// calls are accepted and the request completes when its last queued
    /// segment exits (immediately, if that already happened). Idempotent
    /// on already-closed streams.
    pub fn finish_stream(&mut self, id: u64) -> Result<()> {
        let Some(fl) = self.inflight.get_mut(&id) else {
            return Err(Error::Request(format!("request id {id} not in flight")));
        };
        let was_open = fl.open;
        fl.open = false;
        // Closing hand-off for the final-state capture: while the
        // stream was open its lane was reserved, so the per-layer state
        // never needed copying — seed `last` from the lane ONCE now.
        // Layers the remaining in-flight segments have not reached yet
        // hold stale (pre-final) state here, but step (4b) keeps
        // overwriting those as the tail ascends (the stream is closed
        // from this point on), so `last` is complete and final by the
        // time the last segment exits.
        if was_open && fl.capture.as_ref().is_some_and(|c| c.capture_final) {
            if let Some(lane) = self.streams.iter().position(|s| *s == Some(id)) {
                let l_total = self.cfg.n_layers;
                let fl = self.inflight.get_mut(&id).expect("present above");
                let cap = fl.capture.as_mut().expect("checked above");
                for l in 0..l_total {
                    cap.last[l] = Some((self.a.index01(l, lane), self.z.index01(l, lane)));
                }
            }
        }
        self.try_complete(id);
        Ok(())
    }

    /// Evict a request anywhere in its lifecycle (pending, streaming, or
    /// mid-decode): its in-flight cells vanish from the wavefront and
    /// its lane is freed for the next pending request. Returns `false`
    /// when `id` is not in flight (unknown or already completed). The
    /// evicted request never reaches the completion queue.
    ///
    /// Memory hygiene needs no scrubbing here: the victim's leftover
    /// `(A, z)` state is overwritten by the standard request-boundary
    /// rule — the next occupant's first segment zeroes each layer as it
    /// arrives (step (3)). Actively zeroing the lane would be WRONG:
    /// a predecessor's trailing segments may still be traversing the
    /// lane's upper layers, and they depend on the memory their own
    /// earlier segments wrote there.
    pub fn cancel(&mut self, id: u64) -> bool {
        if self.inflight.remove(&id).is_none() {
            return false;
        }
        self.pending.retain(|&p| p != id);
        self.exits.retain(|e| e.id != id);
        let (l_total, b_total) = (self.cfg.n_layers, self.lanes);
        for lane in 0..b_total {
            if self.streams[lane] == Some(id) {
                self.streams[lane] = None;
            }
            for l in 0..l_total {
                let slot = l * b_total + lane;
                if matches!(self.tags[slot], Some(t) if t.req == id) {
                    self.tags[slot] = None;
                }
            }
        }
        true
    }

    /// Next completed request, in completion order (which is generally
    /// NOT submission order once requests of different lengths pack).
    pub fn pop_completed(&mut self) -> Option<SessionOutput> {
        self.done.pop_front()
    }

    /// All completed requests accumulated so far.
    pub fn drain_completed(&mut self) -> Vec<SessionOutput> {
        self.done.drain(..).collect()
    }

    /// Next segment exit of an event-emitting request
    /// ([`submit_stream`](Self::submit_stream)), in exit order. Drain
    /// after every [`step`](Self::step) — this is the decode loop's
    /// heartbeat.
    pub fn pop_exited(&mut self) -> Option<SegmentExit> {
        self.exits.pop_front()
    }

    /// Session-aggregate utilization: `launches` = wavefront iterations,
    /// `cells` = active cells across all requests, and the padded /
    /// occupancy accounting over every slot-step since construction.
    pub fn stats(&self) -> RunStats {
        RunStats {
            mode_diagonal: true,
            segments: self.segments_done,
            launches: self.iterations,
            cells: self.active_cells,
            slot_steps: self.slot_steps,
            padded_cells: self.slot_steps - self.active_cells,
            wall: self.started.elapsed(),
            tokens: self.tokens_done,
        }
    }

    /// Advance the wavefront one iteration: inject segments into free
    /// slot-0 lanes, run one grouped step, emit finished segments at
    /// layer L-1, shift. Returns `false` (without touching the backend)
    /// when there is nothing in flight.
    pub fn step<B: StepBackend + ?Sized>(&mut self, backend: &mut B) -> Result<bool> {
        let l_total = self.cfg.n_layers;
        let b_total = self.lanes;
        if backend.config() != &self.cfg {
            return Err(Error::Config(
                "WavefrontSession config does not match the backend's".into(),
            ));
        }

        // (1) Injection: each lane pulls the next segment of its stream,
        // or starts the next pending request the moment its stream ends.
        // An OPEN stream that ran out of queued segments keeps its lane
        // reserved (injecting nothing) until the caller appends the next
        // decode segment or closes it.
        for lane in 0..b_total {
            let tag = loop {
                match self.streams[lane] {
                    Some(req) => {
                        let fl = self.inflight.get_mut(&req).expect("stream request in flight");
                        if fl.next_seg < fl.segments.len() {
                            let seg_idx = fl.next_seg;
                            fl.next_seg += 1;
                            if fl.first_iter.is_none() {
                                fl.first_iter = Some(self.iterations);
                                fl.active0 = self.active_cells;
                                fl.slot0 = self.slot_steps;
                            }
                            let emb = backend.embed(&fl.segments[seg_idx])?;
                            self.x_slots.set_index01(0, lane, &emb);
                            // Tags carry ABSOLUTE segment indices so a
                            // resumed request's cells/exits continue the
                            // numbering of its cached prefix.
                            break Some(CellTag { req, seg: fl.seg_offset + seg_idx });
                        }
                        if fl.open {
                            // Awaiting append_segment (decode frontier in
                            // flight); the lane idles but stays owned.
                            break None;
                        }
                        // Stream exhausted; free the lane and retry.
                        self.streams[lane] = None;
                    }
                    None => match self.pending.pop_front() {
                        Some(req) => self.streams[lane] = Some(req),
                        None => break None,
                    },
                }
            };
            self.tags[lane] = tag;
        }

        // (2) Occupancy accounting; bail out if the wavefront is empty.
        // (Can legitimately happen mid-generation: every in-flight
        // request may be an open stream awaiting its next appended
        // segment, with all lanes idle-but-reserved.)
        let active = self.tags.iter().flatten().count() as u64;
        if active == 0 {
            return Ok(false);
        }
        self.iterations += 1;
        self.active_cells += active;
        self.slot_steps += (l_total * b_total) as u64;

        // (3) Request boundary: a first segment reaching layer `l` finds
        // the previous request's final state in the lane — reset to the
        // empty memory a fresh request starts from, or, for a resumed
        // request, to the snapshot state its cached prefix produced
        // (the same timing either way: exactly when the first segment
        // arrives at the layer, never earlier — a predecessor's tail
        // may still be traversing the slots above).
        let mut mask = vec![0.0f32; l_total * b_total];
        for l in 0..l_total {
            for lane in 0..b_total {
                if let Some(t) = self.tags[l * b_total + lane] {
                    mask[l * b_total + lane] = 1.0;
                    let fl = self.inflight.get(&t.req).expect("tagged request in flight");
                    if t.seg == fl.seg_offset {
                        self.a_energy[l * b_total + lane] = match &fl.resume {
                            Some(snap) => {
                                self.a.set_index01(l, lane, &snap.a[l]);
                                self.z.set_index01(l, lane, &snap.z[l]);
                                snap.a[l].data().iter().map(|&v| (v as f64) * (v as f64)).sum()
                            }
                            None => {
                                self.a.zero_index01(l, lane);
                                self.z.zero_index01(l, lane);
                                0.0
                            }
                        };
                    }
                }
            }
        }

        // (3b) Memory gates (`overflow: "select"`): clone the (A, z)
        // each gated cell is about to overwrite, to restore after the
        // launch. The clone happens AFTER the boundary reset so a gated
        // first segment restores the fresh (zero / snapshot) state.
        let mut gate_saves: Vec<(usize, usize, Tensor, Tensor)> = Vec::new();
        for l in 0..l_total {
            for lane in 0..b_total {
                let Some(t) = self.tags[l * b_total + lane] else { continue };
                let fl = self.inflight.get(&t.req).expect("tagged request in flight");
                if fl.gated.contains(&t.seg) {
                    gate_saves.push((l, lane, self.a.index01(l, lane), self.z.index01(l, lane)));
                }
            }
        }

        // (4) One grouped launch over all L x B slots.
        let (y, a2, z2) = backend.grouped_step(&self.x_slots, &self.a, &self.z, &mask)?;
        self.a = a2;
        self.z = z2;

        // (4a) Undo gated cells' memory writes: attention output `y`
        // keeps flowing to the next layer; the recurrent state reverts.
        for (l, lane, a_prev, z_prev) in gate_saves {
            self.a.set_index01(l, lane, &a_prev);
            self.z.set_index01(l, lane, &z_prev);
        }

        // (4a') Quality-tier observation (always on; pure): per-cell
        // ‖A‖² after the launch, accumulated in fixed slot order on the
        // engine thread so the signals are deterministic across worker
        // thread counts. |Δ| flows into the owning request's
        // update-energy until its next segment exit. A gated cell's
        // state was just restored, so its delta is exactly zero.
        {
            let cell_floats = self.cfg.d_model * self.cfg.phi_dim;
            let a_data = self.a.data();
            for idx in 0..l_total * b_total {
                let Some(t) = self.tags[idx] else { continue };
                let slice = &a_data[idx * cell_floats..(idx + 1) * cell_floats];
                let e: f64 = slice.iter().map(|&v| (v as f64) * (v as f64)).sum();
                let delta = (e - self.a_energy[idx]).abs();
                self.a_energy[idx] = e;
                let fl = self.inflight.get_mut(&t.req).expect("tagged request in flight");
                fl.energy_update_acc += delta;
            }
        }

        // (4b) Snapshot capture: clone post-cell memory for
        // capture-enabled requests. Runs before (5) so a targeted
        // segment completing at layer L-1 delivers its snapshot on the
        // very exit event that announces it. Pure observation — the
        // wavefront's own state is untouched.
        for l in 0..l_total {
            for lane in 0..b_total {
                let Some(t) = self.tags[l * b_total + lane] else { continue };
                let Some(fl) = self.inflight.get_mut(&t.req) else { continue };
                let Some(cap) = fl.capture.as_mut() else { continue };
                let targeted = cap.targets.contains(&t.seg);
                // The running `last` copy is only needed once the
                // stream is CLOSED: from then on the lane can be handed
                // to a successor while the tail traverses the upper
                // layers, so the state must be copied as it is
                // produced. While the stream is open the lane stays
                // reserved — `finish_stream` seeds `last` from the lane
                // at close time, keeping the decode hot path free of
                // per-step state clones.
                let keep_last = cap.capture_final && !fl.open;
                if !targeted && !keep_last {
                    continue;
                }
                let state = (self.a.index01(l, lane), self.z.index01(l, lane));
                if targeted {
                    let slots =
                        cap.building.entry(t.seg).or_insert_with(|| vec![None; l_total]);
                    slots[l] = Some(state.clone());
                }
                if keep_last {
                    cap.last[l] = Some(state);
                }
            }
        }

        // (5) Segments exit fully processed at the last layer; a
        // request completes when its final segment exits with the
        // stream closed.
        for lane in 0..b_total {
            if let Some(t) = self.tags[(l_total - 1) * b_total + lane] {
                let logits = backend.lm_head(&y.index01(l_total - 1, lane))?;
                // Quality-tier signals for this exit: state energy =
                // Σ‖A‖² over the request's live cells (post-launch).
                let state_energy: f64 = self
                    .tags
                    .iter()
                    .enumerate()
                    .filter(|(_, tag)| matches!(tag, Some(x) if x.req == t.req))
                    .map(|(idx, _)| self.a_energy[idx])
                    .sum();
                // The tensor is cloned only when BOTH the per-request
                // accumulator and the exit-event queue need it; the
                // common single-consumer cases move it.
                let (event_logits, snapshot, update_energy) = {
                    let fl = self.inflight.get_mut(&t.req).expect("exiting request in flight");
                    debug_assert_eq!(fl.seg_offset + fl.exited, t.seg, "segments exit in order");
                    fl.exited += 1;
                    let snapshot = fl.take_ready_snapshot(&self.cfg, t.seg);
                    let update_energy = fl.energy_update_acc;
                    fl.energy_update_acc = 0.0;
                    if fl.events {
                        if fl.keep_logits {
                            fl.logits.push(logits.clone());
                        }
                        (Some(logits), snapshot, update_energy)
                    } else {
                        if fl.keep_logits {
                            fl.logits.push(logits);
                        }
                        (None, snapshot, update_energy)
                    }
                };
                if let Some(logits) = event_logits {
                    self.exits.push_back(SegmentExit {
                        id: t.req,
                        index: t.seg,
                        logits,
                        snapshot,
                        signals: SegmentSignals { update_energy, state_energy },
                    });
                }
                self.try_complete(t.req);
            }
        }

        // (6) Shift: next iteration, slot (l, lane) holds what (l-1,
        // lane) just produced — each cell advanced one layer.
        for l in (1..l_total).rev() {
            for lane in 0..b_total {
                if self.tags[(l - 1) * b_total + lane].is_some() {
                    self.x_slots.set_index01(l, lane, &y.index01(l - 1, lane));
                }
                self.tags[l * b_total + lane] = self.tags[(l - 1) * b_total + lane];
            }
        }
        Ok(true)
    }

    /// Step until every admitted request has completed.
    ///
    /// Open streams are the caller's responsibility: an open stream
    /// awaiting [`append_segment`](Self::append_segment) makes the
    /// wavefront idle without being complete, and this loop returns.
    pub fn run_to_completion<B: StepBackend + ?Sized>(&mut self, backend: &mut B) -> Result<()> {
        while self.step(backend)? {}
        Ok(())
    }

    /// Move a request to the completion queue once its stream is closed
    /// and every queued segment has exited.
    fn try_complete(&mut self, id: u64) {
        let ready = match self.inflight.get(&id) {
            Some(fl) => !fl.open && fl.exited == fl.segments.len(),
            None => false,
        };
        if !ready {
            return;
        }
        let mut fl = self.inflight.remove(&id).expect("checked above");
        // Assemble the final memory state (capture_final): every layer
        // has processed the last segment by now, so the per-layer
        // `last` writes are exactly the post-final-segment memory.
        let total_segments = fl.seg_offset + fl.segments.len();
        let final_state = fl.capture.take().and_then(|cap| {
            if !cap.capture_final {
                return None;
            }
            let layers: Option<Vec<(Tensor, Tensor)>> = cap.last.into_iter().collect();
            MemSnapshot::from_layers(&self.cfg, total_segments, layers?).ok()
        });
        // Free the lane if the request still holds one (open streams
        // keep theirs until completion; closed streams released it when
        // injection exhausted them, possibly to a successor — only a
        // slot still pointing at `id` is ours to clear).
        for s in self.streams.iter_mut() {
            if *s == Some(id) {
                *s = None;
            }
        }
        let l_total = self.cfg.n_layers;
        let s_total = fl.segments.len();
        let span = self.iterations - fl.first_iter.expect("completed => injected");
        let slot_span = self.slot_steps - fl.slot0;
        let active_span = self.active_cells - fl.active0;
        let stats = RunStats {
            mode_diagonal: true,
            segments: s_total,
            launches: span,
            cells: (s_total * l_total) as u64,
            slot_steps: slot_span,
            padded_cells: slot_span - active_span,
            wall: fl.submitted.elapsed(),
            tokens: s_total * self.cfg.seg,
        };
        self.segments_done += s_total;
        self.tokens_done += stats.tokens;
        self.done.push_back(SessionOutput { id, logits: fl.logits, final_state, stats });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NativeBackend, Params};
    use crate::scheduler::{Executor, ScheduleMode};

    fn cfg() -> ModelConfig {
        crate::model::tests::test_config() // L = 3, seg = 8
    }

    fn backend(seed: u64) -> NativeBackend {
        let c = cfg();
        let params = Params::random(&c, seed);
        NativeBackend::new(c, params)
    }

    fn tokens(n: usize, salt: u32) -> Vec<u32> {
        (0..n as u32).map(|i| (i * 7 + salt) % 64).collect()
    }

    /// Reference: each request alone through the sequential executor on
    /// a fresh backend with the same weights.
    fn sequential_reference(seed: u64, toks: &[u32]) -> Vec<Tensor> {
        let mut b = backend(seed);
        Executor::new(&mut b, ScheduleMode::Sequential).run(toks).unwrap().logits
    }

    #[test]
    fn two_requests_one_lane_fill_each_others_ramps() {
        let mut b = backend(41);
        let mut session = WavefrontSession::new(cfg(), 1);
        let r1 = tokens(8 * 4, 3);
        let r2 = tokens(8 * 4, 11);
        session.submit(1, &r1).unwrap();
        session.submit(2, &r2).unwrap();
        session.run_to_completion(&mut b).unwrap();

        // Packed: 2S + L - 1 iterations instead of 2 * (S + L - 1).
        let stats = session.stats();
        assert_eq!(stats.launches, (2 * 4 + 3 - 1) as u64);
        assert_eq!(stats.cells, (2 * 4 * 3) as u64);
        let solo = (4 * 3) as f64 / (4 + 3 - 1) as f64;
        assert!(stats.mean_group() > solo, "{} vs solo {solo}", stats.mean_group());

        let mut outs = session.drain_completed();
        outs.sort_by_key(|o| o.id);
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].logits, sequential_reference(41, &r1));
        assert_eq!(outs[1].logits, sequential_reference(41, &r2));
    }

    #[test]
    fn multi_lane_bitexact_and_out_of_order_completion() {
        let mut b = backend(42);
        let mut session = WavefrontSession::new(cfg(), 2);
        let long = tokens(8 * 6, 5);
        let short = tokens(8 * 2, 9);
        session.submit(10, &long).unwrap();
        session.submit(11, &short).unwrap();
        session.run_to_completion(&mut b).unwrap();

        // The short request finishes first despite later submission.
        let first = session.pop_completed().unwrap();
        assert_eq!(first.id, 11);
        assert_eq!(first.logits, sequential_reference(42, &short));
        let second = session.pop_completed().unwrap();
        assert_eq!(second.id, 10);
        assert_eq!(second.logits, sequential_reference(42, &long));
        assert!(session.pop_completed().is_none());
    }

    #[test]
    fn mid_flight_admission_is_exact() {
        let mut b = backend(43);
        let mut session = WavefrontSession::new(cfg(), 1);
        let r1 = tokens(8 * 5, 2);
        session.submit(1, &r1).unwrap();
        for _ in 0..3 {
            session.step(&mut b).unwrap();
        }
        let r2 = tokens(8 * 3 - 2, 6); // ragged tail
        session.submit(2, &r2).unwrap();
        session.run_to_completion(&mut b).unwrap();
        let mut outs = session.drain_completed();
        outs.sort_by_key(|o| o.id);
        assert_eq!(outs[0].logits, sequential_reference(43, &r1));
        assert_eq!(outs[1].logits, sequential_reference(43, &r2));
    }

    #[test]
    fn per_request_stats_match_solo_shapes() {
        // A lone request in a 1-lane session must report exactly the
        // Fig. 3 arithmetic of the single-shot diagonal executor.
        let mut b = backend(44);
        let mut session = WavefrontSession::new(cfg(), 1);
        session.submit(7, &tokens(8 * 5, 1)).unwrap();
        session.run_to_completion(&mut b).unwrap();
        let out = session.pop_completed().unwrap();
        let (s, l) = (5u64, 3u64);
        assert_eq!(out.stats.launches, s + l - 1);
        assert_eq!(out.stats.cells, s * l);
        assert_eq!(out.stats.slot_steps, (s + l - 1) * l);
        assert_eq!(out.stats.padded_cells, l * (l - 1));
        assert_eq!(out.stats.segments, 5);
        assert!(out.stats.occupancy() > 0.0 && out.stats.occupancy() < 1.0);
    }

    #[test]
    fn rejects_empty_and_duplicate_ids() {
        let mut session = WavefrontSession::new(cfg(), 2);
        assert!(session.submit(1, &[]).is_err());
        session.submit(1, &tokens(8, 0)).unwrap();
        assert!(session.submit(1, &tokens(8, 0)).is_err());
    }

    /// Drive an open stream by hand: feed the argmax of each frontier
    /// exit back as the next segment, `decode_segments` times, then
    /// close. Returns (output, generated-token segments).
    fn drive_decode(
        b: &mut NativeBackend,
        session: &mut WavefrontSession,
        id: u64,
        prompt_segments: usize,
        decode_segments: usize,
    ) -> (SessionOutput, Vec<Vec<u32>>) {
        let mut fed = prompt_segments;
        let mut appended = 0;
        let mut generated = Vec::new();
        for _ in 0..10_000 {
            session.step(b).unwrap();
            while let Some(exit) = session.pop_exited() {
                assert_eq!(exit.id, id);
                if exit.index + 1 == fed {
                    if appended < decode_segments {
                        let seg: Vec<u32> =
                            exit.logits.argmax_rows().iter().map(|&t| t as u32).collect();
                        session.append_segment(id, seg.clone()).unwrap();
                        generated.push(seg);
                        fed += 1;
                        appended += 1;
                    } else {
                        session.finish_stream(id).unwrap();
                    }
                }
            }
            if let Some(out) = session.pop_completed() {
                return (out, generated);
            }
        }
        panic!("decode did not complete");
    }

    #[test]
    fn open_stream_decode_is_exact_recurrence() {
        // Streamed decode (prompt, then two greedy segments appended to
        // the LIVE wavefront) must be bit-identical to running
        // prompt + generated through the single-shot sequential oracle.
        let mut b = backend(50);
        let mut session = WavefrontSession::new(cfg(), 1);
        let prompt = tokens(8 * 2, 7);
        let segments = crate::scheduler::segment_tokens(&cfg(), &prompt).unwrap();
        session.submit_stream(1, segments, true).unwrap();
        let (out, generated) = drive_decode(&mut b, &mut session, 1, 2, 2);

        assert_eq!(out.stats.segments, 4); // 2 prompt + 2 decode
        let mut full = prompt.clone();
        for seg in &generated {
            full.extend_from_slice(seg);
        }
        let oracle = sequential_reference(50, &full);
        assert_eq!(out.logits.len(), oracle.len());
        for (a, o) in out.logits.iter().zip(&oracle) {
            // f32::to_bits equality — PartialEq on the tensors is
            // equivalent here, but make bit-exactness explicit.
            let (ab, ob): (Vec<u32>, Vec<u32>) = (
                a.data().iter().map(|x| x.to_bits()).collect(),
                o.data().iter().map(|x| x.to_bits()).collect(),
            );
            assert_eq!(ab, ob);
        }
    }

    #[test]
    fn decode_packs_with_other_requests() {
        // A second lane keeps serving closed requests (bit-exactly)
        // while lane 0 decodes; the decoding stream's bubbles do not
        // stall anyone else.
        let mut b = backend(51);
        let mut session = WavefrontSession::new(cfg(), 2);
        let prompt = tokens(8, 1);
        let other = tokens(8 * 4, 9);
        session
            .submit_stream(1, crate::scheduler::segment_tokens(&cfg(), &prompt).unwrap(), true)
            .unwrap();
        session.submit(2, &other).unwrap();

        let mut fed = 1;
        let mut appended = 0;
        let mut done_other = None;
        let mut done_gen = None;
        for _ in 0..10_000 {
            session.step(&mut b).unwrap();
            while let Some(exit) = session.pop_exited() {
                assert_eq!(exit.id, 1, "closed submits emit no exit events");
                if exit.index + 1 == fed {
                    if appended < 3 {
                        let seg: Vec<u32> =
                            exit.logits.argmax_rows().iter().map(|&t| t as u32).collect();
                        session.append_segment(1, seg).unwrap();
                        fed += 1;
                        appended += 1;
                    } else {
                        session.finish_stream(1).unwrap();
                    }
                }
            }
            while let Some(out) = session.pop_completed() {
                match out.id {
                    1 => done_gen = Some(out),
                    _ => done_other = Some(out),
                }
            }
            if done_gen.is_some() && done_other.is_some() {
                break;
            }
        }
        let done_other = done_other.expect("closed request finished");
        assert_eq!(done_other.logits, sequential_reference(51, &other));
        assert_eq!(done_gen.expect("decode finished").stats.segments, 4);
    }

    #[test]
    fn cancel_frees_reserved_lane_for_pending_request() {
        // Single lane: an open stream parks on the lane; a closed
        // request waits behind it; cancelling the stream hands the lane
        // over and the survivor stays bit-exact.
        let mut b = backend(52);
        let mut session = WavefrontSession::new(cfg(), 1);
        let gen_prompt = tokens(8, 3);
        let waiting = tokens(8 * 3, 5);
        session
            .submit_stream(1, crate::scheduler::segment_tokens(&cfg(), &gen_prompt).unwrap(), true)
            .unwrap();
        session.submit(2, &waiting).unwrap();
        // Let the open stream's only segment travel a couple of layers.
        session.step(&mut b).unwrap();
        session.step(&mut b).unwrap();
        assert_eq!(session.backlog(), 1, "closed request still waits for the lane");

        assert!(session.cancel(1));
        assert!(!session.cancel(1), "double cancel is a no-op");
        session.run_to_completion(&mut b).unwrap();
        let out = session.pop_completed().unwrap();
        assert_eq!(out.id, 2);
        assert_eq!(out.logits, sequential_reference(52, &waiting));
        assert!(session.is_idle());
        assert!(session.pop_exited().is_none(), "cancel purged the victim's exit events");
    }

    #[test]
    fn cancel_does_not_corrupt_predecessor_still_in_lane() {
        // Single lane: request A's stream is exhausted and the lane
        // hands over to B while A's tail segments still traverse the
        // upper layers (they depend on the memory A's earlier segments
        // wrote there). Cancelling B must not touch that state — A's
        // remaining logits stay bit-exact.
        let mut b = backend(55);
        let mut session = WavefrontSession::new(cfg(), 1);
        let a_toks = tokens(8 * 2, 1);
        let b_toks = tokens(8 * 3, 2);
        session.submit(1, &a_toks).unwrap();
        session.submit(2, &b_toks).unwrap();
        // 3 steps (L = 3): A fully injected, B's segment 0 entered the
        // lane, A's last segment still one layer short of the top.
        for _ in 0..3 {
            session.step(&mut b).unwrap();
        }
        assert!(session.cancel(2));
        session.run_to_completion(&mut b).unwrap();
        let out = session.pop_completed().unwrap();
        assert_eq!(out.id, 1);
        assert_eq!(out.logits, sequential_reference(55, &a_toks));
        // The reclaimed lane still serves a fresh request exactly.
        let late = tokens(8 * 2, 9);
        session.submit(3, &late).unwrap();
        session.run_to_completion(&mut b).unwrap();
        assert_eq!(session.pop_completed().unwrap().logits, sequential_reference(55, &late));
    }

    #[test]
    fn cancel_mid_flight_keeps_survivors_bitexact() {
        let mut b = backend(53);
        let mut session = WavefrontSession::new(cfg(), 2);
        let victim = tokens(8 * 6, 2);
        let survivor = tokens(8 * 4, 8);
        session.submit(1, &victim).unwrap();
        session.submit(2, &survivor).unwrap();
        for _ in 0..3 {
            session.step(&mut b).unwrap();
        }
        assert!(session.cancel(1));
        session.run_to_completion(&mut b).unwrap();
        let outs = session.drain_completed();
        assert_eq!(outs.len(), 1, "the victim must never complete");
        assert_eq!(outs[0].id, 2);
        assert_eq!(outs[0].logits, sequential_reference(53, &survivor));
        // The freed lane serves the next request from a clean slate.
        let late = tokens(8 * 2, 4);
        session.submit(3, &late).unwrap();
        session.run_to_completion(&mut b).unwrap();
        assert_eq!(session.pop_completed().unwrap().logits, sequential_reference(53, &late));
    }

    #[test]
    fn stream_guards() {
        let mut session = WavefrontSession::new(cfg(), 1);
        assert!(session.append_segment(9, tokens(8, 0)).is_err(), "unknown id");
        assert!(session.finish_stream(9).is_err(), "unknown id");
        assert!(!session.cancel(9), "unknown id");

        session.submit(1, &tokens(8, 0)).unwrap();
        assert!(
            session.append_segment(1, tokens(8, 1)).is_err(),
            "closed submissions reject appends"
        );

        let segs = crate::scheduler::segment_tokens(&cfg(), &tokens(8, 2)).unwrap();
        session.submit_stream(2, segs, false).unwrap();
        assert!(session.append_segment(2, tokens(4, 0)).is_err(), "wrong segment length");
        session.append_segment(2, tokens(8, 3)).unwrap();
        session.finish_stream(2).unwrap();
        assert!(session.finish_stream(2).is_ok(), "finish is idempotent");
        assert!(session.append_segment(2, tokens(8, 4)).is_err(), "closed after finish");
    }

    #[test]
    fn finish_without_logits_completes_with_empty_logits() {
        let mut b = backend(54);
        let mut session = WavefrontSession::new(cfg(), 1);
        let segs = crate::scheduler::segment_tokens(&cfg(), &tokens(8 * 2, 6)).unwrap();
        session.submit_stream(1, segs, false).unwrap();
        session.finish_stream(1).unwrap();
        let mut exits = 0;
        while session.step(&mut b).unwrap() {
            while session.pop_exited().is_some() {
                exits += 1;
            }
        }
        assert_eq!(exits, 2, "exit events still flow without kept logits");
        let out = session.pop_completed().unwrap();
        assert!(out.logits.is_empty());
        assert_eq!(out.stats.segments, 2);
    }

    /// Run `prefix` segments through a throwaway 1-lane session and
    /// return the captured post-prefix snapshot.
    fn snapshot_after(b: &mut NativeBackend, prefix: &[Vec<u32>]) -> MemSnapshot {
        let mut session = WavefrontSession::new(cfg(), 1);
        session.submit_stream(99, prefix.to_vec(), false).unwrap();
        session.capture_after(99, prefix.len() - 1).unwrap();
        session.finish_stream(99).unwrap();
        let mut snap = None;
        while session.step(b).unwrap() {
            while let Some(exit) = session.pop_exited() {
                if let Some(s) = exit.snapshot {
                    assert_eq!(exit.index + 1, s.segments);
                    snap = Some(s);
                }
            }
        }
        session.drain_completed();
        snap.expect("prefix snapshot delivered on its exit")
    }

    #[test]
    fn resume_after_any_k_is_bitexact() {
        // Suspend after segment k, resume with the remaining segments:
        // the computed tail must match the straight-through sequential
        // oracle byte for byte — for every k.
        let toks = tokens(8 * 5, 13);
        let reference = sequential_reference(61, &toks);
        let segments = crate::scheduler::segment_tokens(&cfg(), &toks).unwrap();
        let mut b = backend(61);
        for k in 1..segments.len() {
            let snap = snapshot_after(&mut b, &segments[..k]);
            assert_eq!(snap.segments, k);

            let mut session = WavefrontSession::new(cfg(), 1);
            session
                .submit_stream_resumed(1, snap, segments[k..].to_vec(), true)
                .unwrap();
            session.finish_stream(1).unwrap();
            session.run_to_completion(&mut b).unwrap();
            let out = session.pop_completed().unwrap();
            assert_eq!(out.logits.len(), segments.len() - k, "k = {k}");
            for (i, (got, want)) in out.logits.iter().zip(&reference[k..]).enumerate() {
                let (gb, wb): (Vec<u32>, Vec<u32>) = (
                    got.data().iter().map(|x| x.to_bits()).collect(),
                    want.data().iter().map(|x| x.to_bits()).collect(),
                );
                assert_eq!(gb, wb, "k = {k}, resumed segment {i}");
            }
        }
    }

    #[test]
    fn capture_final_matches_targeted_last_segment() {
        // The running final capture and a targeted snapshot of the last
        // segment are two routes to the same state — they must agree
        // exactly, and carry the right recurrence counter.
        let mut b = backend(62);
        let segments = crate::scheduler::segment_tokens(&cfg(), &tokens(8 * 3, 4)).unwrap();
        let mut session = WavefrontSession::new(cfg(), 1);
        session.submit_stream(1, segments.clone(), false).unwrap();
        session.capture_after(1, 2).unwrap();
        session.capture_final(1).unwrap();
        session.finish_stream(1).unwrap();
        let mut targeted = None;
        while session.step(&mut b).unwrap() {
            while let Some(exit) = session.pop_exited() {
                if let Some(s) = exit.snapshot {
                    targeted = Some(s);
                }
            }
        }
        let out = session.pop_completed().unwrap();
        let final_state = out.final_state.expect("capture_final delivered");
        let targeted = targeted.expect("targeted snapshot delivered");
        assert_eq!(final_state.segments, 3);
        assert_eq!(final_state, targeted);
    }

    #[test]
    fn resumed_request_packs_with_others_bitexact() {
        // A resumed request shares the wavefront with a fresh one; both
        // stay exact, and the resumed request reports only the cells it
        // actually computed.
        let long = tokens(8 * 5, 3);
        let other = tokens(8 * 4, 9);
        let reference = sequential_reference(63, &long);
        let segments = crate::scheduler::segment_tokens(&cfg(), &long).unwrap();
        let mut b = backend(63);
        let snap = snapshot_after(&mut b, &segments[..2]);

        let mut session = WavefrontSession::new(cfg(), 2);
        session.submit_stream_resumed(1, snap, segments[2..].to_vec(), true).unwrap();
        session.finish_stream(1).unwrap();
        session.submit(2, &other).unwrap();
        session.run_to_completion(&mut b).unwrap();
        let mut outs = session.drain_completed();
        outs.sort_by_key(|o| o.id);
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].logits, reference[2..].to_vec());
        assert_eq!(outs[0].stats.segments, 3, "only computed segments counted");
        assert_eq!(outs[0].stats.cells, (3 * 3) as u64);
        assert_eq!(outs[1].logits, sequential_reference(63, &other));
    }

    #[test]
    fn capture_and_resume_guards() {
        let mut b = backend(64);
        let mut session = WavefrontSession::new(cfg(), 1);
        assert!(session.capture_after(9, 0).is_err(), "unknown id");
        assert!(session.capture_final(9).is_err(), "unknown id");

        session.submit(1, &tokens(8 * 2, 1)).unwrap();
        assert!(
            session.capture_after(1, 0).is_err(),
            "closed submissions have no exit events to deliver snapshots on"
        );
        assert!(session.capture_final(1).is_ok(), "final capture works on closed submits");

        let segs = crate::scheduler::segment_tokens(&cfg(), &tokens(8 * 3, 2)).unwrap();
        session.submit_stream(2, segs, false).unwrap();
        session.capture_after(2, 2).unwrap();
        session.step(&mut b).unwrap();
        // Request 1 holds the lane; request 2 has not injected yet, so
        // early targets are still available — but once its segment 0
        // enters, that target is gone.
        for _ in 0..10 {
            session.step(&mut b).unwrap();
        }
        assert!(session.capture_after(2, 0).is_err(), "segment already entered");

        // A snapshot from a mismatched model is refused.
        let other_cfg = ModelConfig { d_model: 64, ..cfg() };
        let bad = MemSnapshot {
            model: cfg().name,
            n_layers: cfg().n_layers,
            d_model: other_cfg.d_model,
            phi_dim: cfg().phi_dim,
            seg: cfg().seg,
            segments: 1,
            a: vec![Tensor::zeros(&[other_cfg.d_model, cfg().phi_dim]); cfg().n_layers],
            z: vec![Tensor::zeros(&[cfg().phi_dim]); cfg().n_layers],
        };
        assert!(session
            .submit_stream_resumed(3, bad, vec![tokens(8, 0)], false)
            .is_err());
    }

    #[test]
    fn gated_segment_leaves_memory_untouched() {
        // Gate segment 0's memory write: segment 0's own logits are
        // unchanged (the gate only undoes the recurrent update), and
        // segment 1 then sees EMPTY memory — bit-identical to running
        // its tokens as a fresh request's first segment.
        let mut b = backend(66);
        let t1 = tokens(8, 3);
        let t2 = tokens(8, 21);
        let mut both = t1.clone();
        both.extend_from_slice(&t2);

        let mut session = WavefrontSession::new(cfg(), 1);
        session.submit(1, &both).unwrap();
        session.set_memory_gates(1, [0usize].into_iter().collect()).unwrap();
        session.run_to_completion(&mut b).unwrap();
        let out = session.pop_completed().unwrap();
        assert_eq!(out.logits.len(), 2);
        assert_eq!(out.logits[0], sequential_reference(66, &t1)[0]);
        assert_eq!(out.logits[1], sequential_reference(66, &t2)[0]);

        // No gates => the plain packed result (the off-policy identity).
        let mut session = WavefrontSession::new(cfg(), 1);
        session.submit(2, &both).unwrap();
        session.set_memory_gates(2, HashSet::new()).unwrap();
        session.run_to_completion(&mut b).unwrap();
        let out = session.pop_completed().unwrap();
        assert_eq!(out.logits, sequential_reference(66, &both));
        assert!(session.set_memory_gates(2, HashSet::new()).is_err(), "completed id");
    }

    #[test]
    fn exits_carry_energy_signals() {
        let mut b = backend(67);
        let mut session = WavefrontSession::new(cfg(), 1);
        let segs = crate::scheduler::segment_tokens(&cfg(), &tokens(8 * 3, 5)).unwrap();
        session.submit_stream(1, segs, false).unwrap();
        session.finish_stream(1).unwrap();
        let mut seen = 0;
        while session.step(&mut b).unwrap() {
            while let Some(exit) = session.pop_exited() {
                assert!(
                    exit.signals.state_energy > 0.0,
                    "segment {} carries no state energy",
                    exit.index
                );
                assert!(exit.signals.update_energy > 0.0);
                seen += 1;
            }
        }
        assert_eq!(seen, 3);
    }

    #[test]
    fn idle_step_is_a_no_op() {
        let mut b = backend(45);
        let mut session = WavefrontSession::new(cfg(), 1);
        assert!(!session.step(&mut b).unwrap());
        assert!(session.is_idle());
        session.submit(1, &tokens(8, 4)).unwrap();
        assert!(session.step(&mut b).unwrap());
        session.run_to_completion(&mut b).unwrap();
        assert!(!session.step(&mut b).unwrap());
        assert_eq!(session.drain_completed().len(), 1);
    }
}
