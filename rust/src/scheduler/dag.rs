//! The (segment, layer) dependency DAG and Lemma 3.1.
//!
//! In a PRMT, cell `(s, l)` depends on `(s, l-1)` (hidden states flow up
//! through layers) and `(s-1, l)` (per-layer memory flows across
//! segments). All cells on an anti-diagonal `s + l = i` are therefore
//! independent, and the diagonal schedule completes the DAG in the
//! minimum possible `S + L - 1` groups, placing each cell in its earliest
//! feasible group (Lemma 3.1 — proven here as executable checks,
//! exercised by proptests in `rust/tests/`).

use crate::error::{Error, Result};

/// One node of the computation grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Cell {
    pub seg: usize,
    pub layer: usize,
}

impl Cell {
    pub fn new(seg: usize, layer: usize) -> Self {
        Self { seg, layer }
    }

    /// Direct dependencies: `(s-1, l)` and `(s, l-1)` when they exist.
    pub fn deps(&self) -> impl Iterator<Item = Cell> {
        let mut v = Vec::with_capacity(2);
        if self.seg > 0 {
            v.push(Cell::new(self.seg - 1, self.layer));
        }
        if self.layer > 0 {
            v.push(Cell::new(self.seg, self.layer - 1));
        }
        v.into_iter()
    }

    /// Earliest feasible group index (the longest dependency chain into
    /// this cell has exactly `seg + layer` predecessors).
    pub fn earliest_group(&self) -> usize {
        self.seg + self.layer
    }
}

/// Minimum number of groups any schedule of an `S x L` grid needs
/// (Lemma 3.1: the critical path `(0,0) .. (S-1, L-1)` has this length).
pub fn min_groups(n_segments: usize, n_layers: usize) -> usize {
    if n_segments == 0 || n_layers == 0 {
        0
    } else {
        n_segments + n_layers - 1
    }
}

/// The cells of anti-diagonal `i` of an `S x L` grid, ordered by layer.
pub fn diagonal_cells(i: usize, n_segments: usize, n_layers: usize) -> Vec<Cell> {
    let mut out = Vec::new();
    for layer in 0..n_layers {
        if let Some(seg) = i.checked_sub(layer) {
            if seg < n_segments {
                out.push(Cell::new(seg, layer));
            }
        }
    }
    out
}

/// Validate that `groups` is a correct schedule of the full `S x L` grid:
/// every cell appears exactly once, and every dependency is scheduled in
/// a strictly earlier group.
pub fn validate_schedule(groups: &[Vec<Cell>], n_segments: usize, n_layers: usize) -> Result<()> {
    let mut group_of = vec![vec![usize::MAX; n_layers]; n_segments];
    let mut seen = 0usize;
    for (gi, group) in groups.iter().enumerate() {
        for cell in group {
            if cell.seg >= n_segments || cell.layer >= n_layers {
                return Err(Error::Schedule(format!("cell out of grid: {cell:?}")));
            }
            if group_of[cell.seg][cell.layer] != usize::MAX {
                return Err(Error::Schedule(format!("cell scheduled twice: {cell:?}")));
            }
            group_of[cell.seg][cell.layer] = gi;
            seen += 1;
        }
    }
    if seen != n_segments * n_layers {
        return Err(Error::Schedule(format!(
            "{seen} cells scheduled, grid has {}",
            n_segments * n_layers
        )));
    }
    for s in 0..n_segments {
        for l in 0..n_layers {
            let gi = group_of[s][l];
            for dep in Cell::new(s, l).deps() {
                let gd = group_of[dep.seg][dep.layer];
                if gd >= gi {
                    return Err(Error::Schedule(format!(
                        "dependency {dep:?} (group {gd}) not before ({s},{l}) (group {gi})"
                    )));
                }
            }
        }
    }
    Ok(())
}

/// Lemma 3.1, part 1: a valid schedule cannot use fewer than
/// [`min_groups`] groups. Returns Err if `groups` claims otherwise.
pub fn check_minimality(groups: &[Vec<Cell>], n_segments: usize, n_layers: usize) -> Result<()> {
    validate_schedule(groups, n_segments, n_layers)?;
    let lb = min_groups(n_segments, n_layers);
    if groups.len() < lb {
        // Impossible for a *valid* schedule; reaching this means
        // validate_schedule has a bug.
        return Err(Error::Schedule(format!(
            "schedule with {} groups beats the critical-path bound {lb}",
            groups.len()
        )));
    }
    Ok(())
}

/// Lemma 3.1, part 2: the diagonal schedule places every cell at its
/// earliest feasible group.
pub fn check_earliest_placement(groups: &[Vec<Cell>]) -> Result<()> {
    for (gi, group) in groups.iter().enumerate() {
        for cell in group {
            if cell.earliest_group() != gi {
                return Err(Error::Schedule(format!(
                    "{cell:?} in group {gi}, earliest feasible is {}",
                    cell.earliest_group()
                )));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deps_of_origin_empty() {
        assert_eq!(Cell::new(0, 0).deps().count(), 0);
        assert_eq!(Cell::new(1, 0).deps().count(), 1);
        assert_eq!(Cell::new(1, 1).deps().count(), 2);
    }

    #[test]
    fn diagonal_cells_cover_grid() {
        let (s, l) = (5, 3);
        let mut count = 0;
        for i in 0..min_groups(s, l) {
            let cells = diagonal_cells(i, s, l);
            assert!(!cells.is_empty());
            for c in &cells {
                assert_eq!(c.earliest_group(), i);
            }
            count += cells.len();
        }
        assert_eq!(count, s * l);
    }

    #[test]
    fn group_sizes_ramp_and_saturate() {
        // S=6, L=3: sizes 1,2,3,3,3,3,2,1
        let sizes: Vec<usize> =
            (0..min_groups(6, 3)).map(|i| diagonal_cells(i, 6, 3).len()).collect();
        assert_eq!(sizes, vec![1, 2, 3, 3, 3, 3, 2, 1]);
    }

    #[test]
    fn validate_accepts_diagonal() {
        let (s, l) = (4, 3);
        let groups: Vec<Vec<Cell>> =
            (0..min_groups(s, l)).map(|i| diagonal_cells(i, s, l)).collect();
        validate_schedule(&groups, s, l).unwrap();
        check_minimality(&groups, s, l).unwrap();
        check_earliest_placement(&groups).unwrap();
        assert_eq!(groups.len(), min_groups(s, l));
    }

    #[test]
    fn validate_rejects_dependency_violation() {
        // (0,1) before (0,0)
        let groups = vec![
            vec![Cell::new(0, 1)],
            vec![Cell::new(0, 0)],
            vec![Cell::new(1, 0)],
            vec![Cell::new(1, 1)],
        ];
        assert!(validate_schedule(&groups, 2, 2).is_err());
    }

    #[test]
    fn validate_rejects_same_group_dependency() {
        let groups = vec![vec![Cell::new(0, 0), Cell::new(0, 1)], vec![
            Cell::new(1, 0),
            Cell::new(1, 1),
        ]];
        assert!(validate_schedule(&groups, 2, 2).is_err());
    }

    #[test]
    fn validate_rejects_missing_and_duplicate() {
        let missing = vec![vec![Cell::new(0, 0)]];
        assert!(validate_schedule(&missing, 2, 1).is_err());
        let dup = vec![vec![Cell::new(0, 0)], vec![Cell::new(0, 0), Cell::new(1, 0)]];
        assert!(validate_schedule(&dup, 2, 1).is_err());
    }

    #[test]
    fn min_groups_edges() {
        assert_eq!(min_groups(0, 5), 0);
        assert_eq!(min_groups(1, 1), 1);
        assert_eq!(min_groups(1, 16), 16);
        assert_eq!(min_groups(128, 16), 143);
    }
}
