//! Explicit schedules over the (segment, layer) grid.
//!
//! The executor streams the diagonal schedule without materializing it;
//! these explicit plans exist for (a) the roofline simulator, which costs
//! arbitrary schedules, (b) the mini-batching comparison of Fig. 6, and
//! (c) tests that check schedule properties directly.

use super::dag::{self, Cell};
use crate::error::Result;

/// Which scheduling policy produced a plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleKind {
    /// Paper Fig. 3a: `S x L` groups of one cell, segment-major.
    Sequential,
    /// Paper Fig. 3b / Algorithm 1: `S + L - 1` anti-diagonal groups.
    Diagonal,
    /// Mini-batching `b` *independent requests*: per layer-step, `b`
    /// same-layer cells run together (the paper's batch-scaling
    /// comparison, Fig. 6). Within one request this is NOT a valid
    /// schedule of the grid — segments of one sequence cannot batch at
    /// the same layer — so this kind models `b` parallel sequences.
    MiniBatch { batch: usize },
    /// Upper bound: every group magically full at `L` cells ("Ideal Even
    /// Load" in Fig. 6) — `ceil(S*L / L) = S` groups of L.
    IdealEvenLoad,
    /// Cross-request wavefront packing: `requests` independent sequences
    /// stream through `lanes` slot lanes of one persistent diagonal
    /// wavefront (the `WavefrontSession` execution model). Within one
    /// request the diagonal dependency order holds; across requests the
    /// ramps overlap, so the padded fraction falls below the solo
    /// diagonal's.
    Packed { lanes: usize, requests: usize },
}

/// A materialized schedule: ordered groups of cells that execute as one
/// kernel-launch each.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub kind: ScheduleKind,
    pub n_segments: usize,
    pub n_layers: usize,
    pub groups: Vec<Vec<Cell>>,
}

impl Schedule {
    /// Sequential baseline: segments outer, layers inner, one cell per
    /// group (each cell is its own kernel launch — `S * L` launches).
    pub fn sequential(n_segments: usize, n_layers: usize) -> Self {
        let mut groups = Vec::with_capacity(n_segments * n_layers);
        for s in 0..n_segments {
            for l in 0..n_layers {
                groups.push(vec![Cell::new(s, l)]);
            }
        }
        Self { kind: ScheduleKind::Sequential, n_segments, n_layers, groups }
    }

    /// The diagonal schedule (Lemma 3.1-optimal).
    pub fn diagonal(n_segments: usize, n_layers: usize) -> Self {
        let groups = (0..dag::min_groups(n_segments, n_layers))
            .map(|i| dag::diagonal_cells(i, n_segments, n_layers))
            .collect();
        Self { kind: ScheduleKind::Diagonal, n_segments, n_layers, groups }
    }

    /// `batch` independent sequences processed together, layer by layer,
    /// segment by segment: groups of exactly `batch` same-(s,l) cells.
    /// Cells carry the *segment* coordinate; the batch multiplicity is in
    /// the kind (the simulator costs it as batched compute).
    pub fn minibatch(n_segments: usize, n_layers: usize, batch: usize) -> Self {
        let mut groups = Vec::with_capacity(n_segments * n_layers);
        for s in 0..n_segments {
            for l in 0..n_layers {
                groups.push(vec![Cell::new(s, l); batch.max(1)]);
            }
        }
        Self { kind: ScheduleKind::MiniBatch { batch }, n_segments, n_layers, groups }
    }

    /// Fig. 6 upper bound: S groups, each a full group of L cells.
    pub fn ideal_even_load(n_segments: usize, n_layers: usize) -> Self {
        let mut groups = Vec::with_capacity(n_segments);
        let mut pending: Vec<Cell> = Vec::new();
        for s in 0..n_segments {
            for l in 0..n_layers {
                pending.push(Cell::new(s, l));
            }
        }
        for chunk in pending.chunks(n_layers.max(1)) {
            groups.push(chunk.to_vec());
        }
        Self { kind: ScheduleKind::IdealEvenLoad, n_segments, n_layers, groups }
    }

    /// The packed-session schedule: simulate the `WavefrontSession`
    /// admission loop over `request_segments[i]`-segment requests and
    /// `lanes` slot lanes, materializing one group per wavefront
    /// iteration. Cell coordinates are per-request (duplicates across
    /// requests are expected); only the group *sizes* feed the cost
    /// model. `n_segments` records the total across requests.
    pub fn packed(request_segments: &[usize], n_layers: usize, lanes: usize) -> Self {
        let lanes = lanes.max(1);
        let total: usize = request_segments.iter().sum();
        let mut groups = Vec::new();
        if n_layers == 0 || total == 0 {
            return Self {
                kind: ScheduleKind::Packed { lanes, requests: request_segments.len() },
                n_segments: total,
                n_layers,
                groups,
            };
        }
        // Per-lane pipeline of per-request segment cursors, mirroring
        // session.rs: a lane injects its stream's next segment each
        // iteration and picks up the next pending request immediately
        // when the stream ends.
        let mut pending: std::collections::VecDeque<usize> = (0..request_segments.len())
            .filter(|&r| request_segments[r] > 0)
            .collect();
        let mut streams: Vec<Option<(usize, usize)>> = vec![None; lanes]; // (req, next_seg)
        let mut slots: Vec<Vec<Option<Cell>>> = vec![vec![None; lanes]; n_layers];
        loop {
            // Injection at layer 0.
            for lane in 0..lanes {
                slots[0][lane] = loop {
                    match streams[lane] {
                        Some((req, seg)) if seg < request_segments[req] => {
                            streams[lane] = Some((req, seg + 1));
                            break Some(Cell::new(seg, 0));
                        }
                        Some(_) => streams[lane] = None,
                        None => match pending.pop_front() {
                            Some(req) => streams[lane] = Some((req, 0)),
                            None => break None,
                        },
                    }
                };
            }
            let group: Vec<Cell> = slots.iter().flatten().flatten().copied().collect();
            if group.is_empty() {
                break;
            }
            groups.push(group);
            // Shift one layer up.
            for l in (1..n_layers).rev() {
                for lane in 0..lanes {
                    slots[l][lane] = slots[l - 1][lane].map(|c| Cell::new(c.seg, l));
                }
            }
        }
        Self {
            kind: ScheduleKind::Packed { lanes, requests: request_segments.len() },
            n_segments: total,
            n_layers,
            groups,
        }
    }

    pub fn group_count(&self) -> usize {
        self.groups.len()
    }

    pub fn cell_count(&self) -> usize {
        self.groups.iter().map(Vec::len).sum()
    }

    pub fn max_group(&self) -> usize {
        self.groups.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Mean cells per group — the GPU-utilization proxy the paper's
    /// speedup comes from.
    pub fn mean_group(&self) -> f64 {
        if self.groups.is_empty() {
            0.0
        } else {
            self.cell_count() as f64 / self.group_count() as f64
        }
    }

    /// Fraction of padded (wasted) slots when executed at the fixed
    /// wavefront width (`n_layers`, times the lane count for packed
    /// schedules — the executors' static-shape policy).
    pub fn pad_fraction(&self) -> f64 {
        let width = match self.kind {
            ScheduleKind::Packed { lanes, .. } => self.n_layers * lanes,
            _ => self.n_layers,
        };
        let total = self.group_count() * width;
        if total == 0 {
            0.0
        } else {
            1.0 - self.cell_count() as f64 / total as f64
        }
    }

    /// Validity per the DAG. The mini-batch and packed kinds model
    /// independent sequences (cell coordinates repeat across requests)
    /// and are exempt by construction — packed per-request ordering is
    /// instead covered by the scheduler proptests (P7 bit-exactness).
    pub fn validate(&self) -> Result<()> {
        match self.kind {
            ScheduleKind::MiniBatch { .. }
            | ScheduleKind::IdealEvenLoad
            | ScheduleKind::Packed { .. } => Ok(()),
            _ => dag::validate_schedule(&self.groups, self.n_segments, self.n_layers),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_is_optimal_and_valid() {
        for (s, l) in [(1, 1), (3, 5), (8, 4), (33, 16)] {
            let d = Schedule::diagonal(s, l);
            d.validate().unwrap();
            assert_eq!(d.group_count(), dag::min_groups(s, l));
            assert_eq!(d.cell_count(), s * l);
            dag::check_earliest_placement(&d.groups).unwrap();
        }
    }

    #[test]
    fn sequential_is_valid_but_not_optimal() {
        let s = Schedule::sequential(8, 4);
        s.validate().unwrap();
        assert_eq!(s.group_count(), 32);
        assert!(s.group_count() > dag::min_groups(8, 4));
        assert_eq!(s.max_group(), 1);
    }

    #[test]
    fn group_count_reduction_matches_paper() {
        // paper fig 3: n_layers*n_segments -> n_layers+n_segments
        let (s, l) = (128, 16);
        assert_eq!(Schedule::sequential(s, l).group_count(), s * l);
        assert_eq!(Schedule::diagonal(s, l).group_count(), s + l - 1);
    }

    #[test]
    fn pad_fraction_shrinks_with_segments() {
        let small = Schedule::diagonal(4, 16).pad_fraction();
        let large = Schedule::diagonal(256, 16).pad_fraction();
        assert!(large < small);
        assert!(large < 0.06, "pad {large}");
    }

    #[test]
    fn minibatch_and_ideal_shapes() {
        let m = Schedule::minibatch(4, 3, 8);
        assert_eq!(m.group_count(), 12);
        assert!(m.groups.iter().all(|g| g.len() == 8));
        let i = Schedule::ideal_even_load(4, 3);
        assert_eq!(i.cell_count(), 12);
        assert!(i.groups.iter().all(|g| g.len() == 3));
    }

    #[test]
    fn mean_group_approaches_l() {
        let d = Schedule::diagonal(512, 16);
        assert!(d.mean_group() > 15.0);
    }

    #[test]
    fn packed_covers_all_cells_in_fewer_groups() {
        let (l, reqs) = (4usize, [6usize, 3, 5, 2]);
        let p = Schedule::packed(&reqs, l, 1);
        p.validate().unwrap();
        let total: usize = reqs.iter().sum();
        assert_eq!(p.cell_count(), total * l);
        // One lane: ramps overlap, so the whole batch needs
        // sum(S) + L - 1 groups instead of sum(S + L - 1).
        assert_eq!(p.group_count(), total + l - 1);
        let serial: usize = reqs.iter().map(|s| s + l - 1).sum();
        assert!(p.group_count() < serial);
        // And the padded fraction drops below the worst solo request's.
        let solo = Schedule::diagonal(2, l);
        assert!(p.pad_fraction() < solo.pad_fraction());
    }

    #[test]
    fn packed_lanes_shrink_iterations() {
        let reqs = [4usize, 4, 4, 4];
        let one = Schedule::packed(&reqs, 3, 1);
        let two = Schedule::packed(&reqs, 3, 2);
        assert_eq!(one.cell_count(), two.cell_count());
        assert!(two.group_count() < one.group_count());
        // 2 lanes x 2 requests each: 8 injections per lane -> 8 + L - 1.
        assert_eq!(two.group_count(), 8 + 3 - 1);
        assert!(two.max_group() <= 3 * 2);
    }

    #[test]
    fn packed_degenerate_shapes() {
        assert_eq!(Schedule::packed(&[], 4, 2).group_count(), 0);
        assert_eq!(Schedule::packed(&[0, 0], 4, 2).group_count(), 0);
        let single = Schedule::packed(&[5], 4, 3);
        assert_eq!(single.group_count(), 5 + 4 - 1);
        assert_eq!(single.cell_count(), 20);
    }
}
