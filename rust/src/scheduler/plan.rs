//! Explicit schedules over the (segment, layer) grid.
//!
//! The executor streams the diagonal schedule without materializing it;
//! these explicit plans exist for (a) the roofline simulator, which costs
//! arbitrary schedules, (b) the mini-batching comparison of Fig. 6, and
//! (c) tests that check schedule properties directly.

use super::dag::{self, Cell};
use crate::error::Result;

/// Which scheduling policy produced a plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleKind {
    /// Paper Fig. 3a: `S x L` groups of one cell, segment-major.
    Sequential,
    /// Paper Fig. 3b / Algorithm 1: `S + L - 1` anti-diagonal groups.
    Diagonal,
    /// Mini-batching `b` *independent requests*: per layer-step, `b`
    /// same-layer cells run together (the paper's batch-scaling
    /// comparison, Fig. 6). Within one request this is NOT a valid
    /// schedule of the grid — segments of one sequence cannot batch at
    /// the same layer — so this kind models `b` parallel sequences.
    MiniBatch { batch: usize },
    /// Upper bound: every group magically full at `L` cells ("Ideal Even
    /// Load" in Fig. 6) — `ceil(S*L / L) = S` groups of L.
    IdealEvenLoad,
}

/// A materialized schedule: ordered groups of cells that execute as one
/// kernel-launch each.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub kind: ScheduleKind,
    pub n_segments: usize,
    pub n_layers: usize,
    pub groups: Vec<Vec<Cell>>,
}

impl Schedule {
    /// Sequential baseline: segments outer, layers inner, one cell per
    /// group (each cell is its own kernel launch — `S * L` launches).
    pub fn sequential(n_segments: usize, n_layers: usize) -> Self {
        let mut groups = Vec::with_capacity(n_segments * n_layers);
        for s in 0..n_segments {
            for l in 0..n_layers {
                groups.push(vec![Cell::new(s, l)]);
            }
        }
        Self { kind: ScheduleKind::Sequential, n_segments, n_layers, groups }
    }

    /// The diagonal schedule (Lemma 3.1-optimal).
    pub fn diagonal(n_segments: usize, n_layers: usize) -> Self {
        let groups = (0..dag::min_groups(n_segments, n_layers))
            .map(|i| dag::diagonal_cells(i, n_segments, n_layers))
            .collect();
        Self { kind: ScheduleKind::Diagonal, n_segments, n_layers, groups }
    }

    /// `batch` independent sequences processed together, layer by layer,
    /// segment by segment: groups of exactly `batch` same-(s,l) cells.
    /// Cells carry the *segment* coordinate; the batch multiplicity is in
    /// the kind (the simulator costs it as batched compute).
    pub fn minibatch(n_segments: usize, n_layers: usize, batch: usize) -> Self {
        let mut groups = Vec::with_capacity(n_segments * n_layers);
        for s in 0..n_segments {
            for l in 0..n_layers {
                groups.push(vec![Cell::new(s, l); batch.max(1)]);
            }
        }
        Self { kind: ScheduleKind::MiniBatch { batch }, n_segments, n_layers, groups }
    }

    /// Fig. 6 upper bound: S groups, each a full group of L cells.
    pub fn ideal_even_load(n_segments: usize, n_layers: usize) -> Self {
        let mut groups = Vec::with_capacity(n_segments);
        let mut pending: Vec<Cell> = Vec::new();
        for s in 0..n_segments {
            for l in 0..n_layers {
                pending.push(Cell::new(s, l));
            }
        }
        for chunk in pending.chunks(n_layers.max(1)) {
            groups.push(chunk.to_vec());
        }
        Self { kind: ScheduleKind::IdealEvenLoad, n_segments, n_layers, groups }
    }

    pub fn group_count(&self) -> usize {
        self.groups.len()
    }

    pub fn cell_count(&self) -> usize {
        self.groups.iter().map(Vec::len).sum()
    }

    pub fn max_group(&self) -> usize {
        self.groups.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Mean cells per group — the GPU-utilization proxy the paper's
    /// speedup comes from.
    pub fn mean_group(&self) -> f64 {
        if self.groups.is_empty() {
            0.0
        } else {
            self.cell_count() as f64 / self.group_count() as f64
        }
    }

    /// Fraction of padded (wasted) slots when executed at fixed width
    /// `n_layers` (the executor's static-shape policy).
    pub fn pad_fraction(&self) -> f64 {
        let total = self.group_count() * self.n_layers;
        if total == 0 {
            0.0
        } else {
            1.0 - self.cell_count() as f64 / total as f64
        }
    }

    /// Validity per the DAG (the mini-batch kind models independent
    /// sequences and is exempt by construction).
    pub fn validate(&self) -> Result<()> {
        match self.kind {
            ScheduleKind::MiniBatch { .. } | ScheduleKind::IdealEvenLoad => Ok(()),
            _ => dag::validate_schedule(&self.groups, self.n_segments, self.n_layers),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_is_optimal_and_valid() {
        for (s, l) in [(1, 1), (3, 5), (8, 4), (33, 16)] {
            let d = Schedule::diagonal(s, l);
            d.validate().unwrap();
            assert_eq!(d.group_count(), dag::min_groups(s, l));
            assert_eq!(d.cell_count(), s * l);
            dag::check_earliest_placement(&d.groups).unwrap();
        }
    }

    #[test]
    fn sequential_is_valid_but_not_optimal() {
        let s = Schedule::sequential(8, 4);
        s.validate().unwrap();
        assert_eq!(s.group_count(), 32);
        assert!(s.group_count() > dag::min_groups(8, 4));
        assert_eq!(s.max_group(), 1);
    }

    #[test]
    fn group_count_reduction_matches_paper() {
        // paper fig 3: n_layers*n_segments -> n_layers+n_segments
        let (s, l) = (128, 16);
        assert_eq!(Schedule::sequential(s, l).group_count(), s * l);
        assert_eq!(Schedule::diagonal(s, l).group_count(), s + l - 1);
    }

    #[test]
    fn pad_fraction_shrinks_with_segments() {
        let small = Schedule::diagonal(4, 16).pad_fraction();
        let large = Schedule::diagonal(256, 16).pad_fraction();
        assert!(large < small);
        assert!(large < 0.06, "pad {large}");
    }

    #[test]
    fn minibatch_and_ideal_shapes() {
        let m = Schedule::minibatch(4, 3, 8);
        assert_eq!(m.group_count(), 12);
        assert!(m.groups.iter().all(|g| g.len() == 8));
        let i = Schedule::ideal_even_load(4, 3);
        assert_eq!(i.cell_count(), 12);
        assert!(i.groups.iter().all(|g| g.len() == 3));
    }

    #[test]
    fn mean_group_approaches_l() {
        let d = Schedule::diagonal(512, 16);
        assert!(d.mean_group() > 15.0);
    }
}
