//! Minimal JSON parser + writer (substrate).
//!
//! The offline toolchain has no `serde`/`serde_json`, so the manifest
//! loader, runtime config and the server's wire protocol parse JSON with
//! this module. It implements the full JSON grammar (RFC 8259) except
//! `\u` surrogate pairs are passed through unpaired; numbers parse as
//! f64 with integer accessors.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- typed accessors ------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object member lookup that errors with a path-ish message.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| Error::Json(format!("missing key '{key}'")))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => Err(Error::Json(format!("expected number, got {self:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 || n > usize::MAX as f64 {
            return Err(Error::Json(format!("expected unsigned int, got {n}")));
        }
        Ok(n as usize)
    }

    pub fn as_u32(&self) -> Result<u32> {
        let n = self.as_usize()?;
        u32::try_from(n).map_err(|_| Error::Json(format!("{n} > u32::MAX")))
    }

    /// Unsigned 64-bit integer. JSON numbers are f64, so only integers
    /// below 2^53 round-trip exactly — larger values are rejected
    /// rather than silently rounded (wire ids must stay stable). 2^53
    /// itself is excluded too: 2^53 + 1 rounds onto it during parsing,
    /// so accepting it would silently alias the two.
    pub fn as_u64(&self) -> Result<u64> {
        const MAX_EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 || n >= MAX_EXACT {
            return Err(Error::Json(format!(
                "expected unsigned integer in the exact f64 range (0..2^53), got {n}"
            )));
        }
        Ok(n as u64)
    }

    pub fn as_f32(&self) -> Result<f32> {
        Ok(self.as_f64()? as f32)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => Err(Error::Json(format!("expected string, got {self:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => Err(Error::Json(format!("expected bool, got {self:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => Err(Error::Json(format!("expected array, got {self:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => Err(Error::Json(format!("expected object, got {self:?}"))),
        }
    }

    /// `[1, 2, 3]` -> Vec<usize>.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(Value::as_usize).collect()
    }

    /// `[1, 2, 3]` -> Vec<u32> (token lists on the wire).
    pub fn as_u32_vec(&self) -> Result<Vec<u32>> {
        self.as_arr()?.iter().map(Value::as_u32).collect()
    }

    // ----- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
    }

    pub fn arr_u32(xs: &[u32]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
    }

    /// Serialize (compact).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Value::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("eof in string"))? {
                b'"' => return Ok(s),
                b'\\' => match self.bump().ok_or_else(|| self.err("eof in escape"))? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        if self.pos + 4 > self.bytes.len() {
                            return Err(self.err("short \\u escape"));
                        }
                        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                            .map_err(|_| self.err("bad \\u escape"))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                b if b < 0x80 => s.push(b as char),
                b => {
                    // multi-byte utf-8: count continuation bytes
                    let len = if b >= 0xF0 {
                        4
                    } else if b >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>().map(Value::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(Value::parse(r#""hi\n""#).unwrap(), Value::Str("hi\n".into()));
    }

    #[test]
    fn nested_roundtrip() {
        let src = r#"{"a": [1, 2.5, {"b": "x", "c": null}], "d": true}"#;
        let v = Value::parse(src).unwrap();
        let re = Value::parse(&v.to_json()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Value::parse(r#""café \"q\" \\ 日本""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café \"q\" \\ 日本");
        let re = Value::parse(&v.to_json()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "\"\\x\""] {
            assert!(Value::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn typed_accessors() {
        let v = Value::parse(r#"{"n": 7, "s": "x", "a": [1,2], "b": false}"#).unwrap();
        assert_eq!(v.req("n").unwrap().as_usize().unwrap(), 7);
        assert_eq!(v.req("a").unwrap().as_usize_vec().unwrap(), vec![1, 2]);
        assert!(!v.req("b").unwrap().as_bool().unwrap());
        assert!(v.req("missing").is_err());
        assert!(v.req("s").unwrap().as_f64().is_err());
        assert!(Value::Num(1.5).as_usize().is_err());
        assert!(Value::Num(-1.0).as_usize().is_err());
    }

    #[test]
    fn u64_roundtrip_large_ids() {
        // The largest exactly-representable integer survives a
        // serialize -> parse -> as_u64 round trip (client-chosen wire
        // ids must not be mangled).
        let big: u64 = (1u64 << 53) - 1;
        let text = Value::Num(big as f64).to_json();
        let back = Value::parse(&text).unwrap().as_u64().unwrap();
        assert_eq!(back, big);
        assert_eq!(Value::Num(0.0).as_u64().unwrap(), 0);
        assert!(Value::Num(-1.0).as_u64().is_err());
        assert!(Value::Num(1.5).as_u64().is_err());
        assert!(Value::Num(1e18).as_u64().is_err(), "beyond exact f64 integers");
        // 2^53 is rejected: 2^53 + 1 parses to the same f64, so
        // accepting it would alias distinct wire values.
        assert!(Value::Num(9_007_199_254_740_992.0).as_u64().is_err());
        assert!(Value::Str("7".into()).as_u64().is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Value::Num(42.0).to_json(), "42");
        assert_eq!(Value::Num(0.5).to_json(), "0.5");
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Value::parse(&text).unwrap();
            assert!(v.get("models").is_some());
        }
    }
}
