//! [`PrefixStore`]: a trie of segment token blocks mapping
//! longest-cached-prefix → [`MemSnapshot`], with LRU eviction under a
//! configurable byte budget.
//!
//! The serving analog of vLLM-style prefix caching / RadixAttention —
//! except the cached object per prefix is a constant-size memory state
//! instead of a paged KV pool. Keys are exact `seg`-sized token blocks
//! (what [`segment_tokens`](crate::scheduler::segment_tokens)
//! produces); edges are addressed by a rolling chain hash of the block
//! sequence, and every edge stores its block verbatim so a hash
//! collision can never alias two different prefixes — on a colliding
//! insert the store refuses rather than corrupt, and on lookup a
//! mismatching block terminates the walk. Exactness beats memory here.
//!
//! Eviction is least-recently-used over *snapshot entries* (interior
//! trie nodes carry no state worth accounting): every lookup hit and
//! insert advances a logical clock, and when the accounted bytes
//! exceed the budget, the entry with the oldest clock goes — emptied
//! branches are pruned on the way out.

use std::collections::HashMap;

use crate::cache::MemSnapshot;

/// Seed/offset pair of FNV-1a 64.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Rolling chain hash: the edge key of `block` under a parent whose
/// own chain hash is `parent` (root = 0). Exposed so callers can log
/// or shard by prefix identity.
pub fn chain_hash(parent: u64, block: &[u32]) -> u64 {
    let mut h = FNV_OFFSET;
    for byte in parent.to_le_bytes() {
        h = (h ^ byte as u64).wrapping_mul(FNV_PRIME);
    }
    for tok in block {
        for byte in tok.to_le_bytes() {
            h = (h ^ byte as u64).wrapping_mul(FNV_PRIME);
        }
    }
    h
}

struct Entry {
    snap: MemSnapshot,
    bytes: usize,
    last_used: u64,
}

#[derive(Default)]
struct Node {
    /// Edges keyed by the child's chain hash; the child records its
    /// block so collisions are detected, never silently merged.
    children: HashMap<u64, Child>,
    entry: Option<Entry>,
}

struct Child {
    block: Vec<u32>,
    node: Node,
}

// Node has no methods: traversal lives in `PrefixStore::evict_lru` and
// is ITERATIVE on purpose — a prompt of S segments builds an S-deep
// chain, and recursing per level would overflow the engine thread's
// stack on exactly the long-context workloads this repo is about.

/// Trie of cached memory states keyed on segment-block prefixes.
pub struct PrefixStore {
    root: Node,
    budget: usize,
    bytes: usize,
    entries: usize,
    clock: u64,
    evictions: u64,
}

impl PrefixStore {
    /// A store that evicts least-recently-used snapshots once the
    /// accounted bytes exceed `budget_bytes` (the `--cache-bytes`
    /// setting).
    pub fn new(budget_bytes: usize) -> Self {
        Self {
            root: Node::default(),
            budget: budget_bytes,
            bytes: 0,
            entries: 0,
            clock: 0,
            evictions: 0,
        }
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// Bytes currently accounted against the budget.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Cached snapshots (not trie nodes).
    pub fn len(&self) -> usize {
        self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries == 0
    }

    /// Snapshots evicted by the byte budget so far (monotone).
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Longest cached prefix of `blocks`: the deepest `p <= blocks.len()`
    /// such that a snapshot is stored for exactly `blocks[..p]`.
    /// Returns `(p, snapshot)` and refreshes that entry's LRU clock.
    /// Pass `&blocks[..blocks.len() - 1]` to guarantee at least one
    /// segment is left to compute (a run needs an exit to produce
    /// logits from).
    pub fn lookup(&mut self, blocks: &[Vec<u32>]) -> Option<(usize, MemSnapshot)> {
        // Pass 1 (immutable): find the deepest depth holding an entry.
        let mut node = &self.root;
        let mut hash = 0u64;
        let mut best: Option<usize> = None;
        for (i, block) in blocks.iter().enumerate() {
            hash = chain_hash(hash, block);
            match node.children.get(&hash) {
                Some(child) if child.block == *block => {
                    node = &child.node;
                    if node.entry.is_some() {
                        best = Some(i + 1);
                    }
                }
                // Absent edge, or a hash collision (different block
                // behind the same key): nothing deeper can match.
                _ => break,
            }
        }
        let depth = best?;
        // Pass 2 (mutable): walk to `depth`, touch, clone out.
        self.clock += 1;
        let clock = self.clock;
        let mut node = &mut self.root;
        let mut hash = 0u64;
        for block in &blocks[..depth] {
            hash = chain_hash(hash, block);
            node = &mut node.children.get_mut(&hash).expect("walked in pass 1").node;
        }
        let entry = node.entry.as_mut().expect("found in pass 1");
        entry.last_used = clock;
        Some((depth, entry.snap.clone()))
    }

    /// Cache `snap` as the state after exactly the prefix `blocks`
    /// (`snap.segments` must equal `blocks.len()`). Replaces an
    /// existing entry for the same prefix (refreshing its clock), then
    /// evicts LRU entries until the byte budget holds again. Returns
    /// the number of entries evicted. A hash collision along the path
    /// refuses the insert (exactness over coverage); a snapshot larger
    /// than the whole budget is evicted right back out.
    pub fn insert(&mut self, blocks: &[Vec<u32>], snap: MemSnapshot) -> u64 {
        debug_assert_eq!(
            snap.segments,
            blocks.len(),
            "snapshot recurrence counter must match its key depth"
        );
        if blocks.is_empty() {
            return 0;
        }
        let mut node = &mut self.root;
        let mut hash = 0u64;
        for block in blocks {
            hash = chain_hash(hash, block);
            let child = node
                .children
                .entry(hash)
                .or_insert_with(|| Child { block: block.clone(), node: Node::default() });
            if child.block != *block {
                // FNV collision between distinct blocks under one
                // parent: ~2^-64 per pair. Refuse — a silent merge
                // would hand request B request A's memory.
                return 0;
            }
            node = &mut child.node;
        }
        self.clock += 1;
        // Accounting is linear in actual storage: trie edges are shared
        // between entries, so each entry is charged its snapshot plus
        // only its OWN (unshared) tail block — charging the whole key
        // path would grow quadratically with prompt length and evict
        // far earlier than the configured budget warrants.
        let bytes =
            snap.byte_size() + blocks.last().map_or(0, |b| b.len() * std::mem::size_of::<u32>());
        if let Some(old) = node.entry.take() {
            self.bytes -= old.bytes;
            self.entries -= 1;
        }
        node.entry = Some(Entry { snap, bytes, last_used: self.clock });
        self.bytes += bytes;
        self.entries += 1;
        self.enforce_budget()
    }

    /// LRU eviction is a full-trie scan per victim — O(entries),
    /// simple, and iterative (explicit stacks; no recursion to blow on
    /// deep chains). Fine for stores sized in the
    /// hundreds-to-thousands of snapshots; revisit with an intrusive
    /// clock->node index if budgets ever hold orders of magnitude more.
    fn enforce_budget(&mut self) -> u64 {
        let mut evicted = 0;
        while self.bytes > self.budget && self.entries > 0 {
            let Some(freed) = self.evict_lru() else { break };
            self.bytes -= freed;
            self.entries -= 1;
            self.evictions += 1;
            evicted += 1;
        }
        evicted
    }

    /// Remove the least-recently-used entry; returns its accounted
    /// bytes. A leaf entry's now-dead chain is pruned back to the
    /// deepest surviving ancestor (a node holding its own entry or a
    /// second branch).
    fn evict_lru(&mut self) -> Option<usize> {
        // Pass 1 (iterative DFS): the oldest entry and its edge path.
        let mut best: Option<(u64, Vec<u64>)> = None;
        let mut stack: Vec<(&Node, Vec<u64>)> = vec![(&self.root, Vec::new())];
        while let Some((node, path)) = stack.pop() {
            if let Some(e) = &node.entry {
                if best.as_ref().is_none_or(|(clock, _)| e.last_used < *clock) {
                    best = Some((e.last_used, path.clone()));
                }
            }
            for (key, child) in &node.children {
                let mut p = path.clone();
                p.push(*key);
                stack.push((&child.node, p));
            }
        }
        let (_, path) = best?;
        // Pass 2 (immutable walk): the target's bytes, whether it has
        // children (then only its entry goes), and the prune point —
        // the deepest ancestor that keeps an entry or another branch.
        // Every node strictly below the prune point carries nothing
        // but the victim, so cutting that one edge drops exactly it.
        let (cut, target_has_children, bytes) = {
            let mut node = &self.root;
            let mut cut = 0usize;
            for (i, key) in path.iter().enumerate() {
                if i > 0 && (node.entry.is_some() || node.children.len() > 1) {
                    cut = i;
                }
                node = &node.children[key].node;
            }
            (cut, !node.children.is_empty(), node.entry.as_ref().map(|e| e.bytes)?)
        };
        // Pass 3 (mutable walk): remove.
        if target_has_children || path.is_empty() {
            let mut node = &mut self.root;
            for key in &path {
                node = &mut node.children.get_mut(key).expect("walked in pass 2").node;
            }
            node.entry.take();
        } else {
            let mut node = &mut self.root;
            for key in &path[..cut] {
                node = &mut node.children.get_mut(key).expect("walked in pass 2").node;
            }
            node.children.remove(&path[cut]);
        }
        Some(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::tensor::Tensor;

    fn cfg() -> ModelConfig {
        ModelConfig::synthetic()
    }

    fn snap(segments: usize, fill: f32) -> MemSnapshot {
        let c = cfg();
        let layers = (0..c.n_layers)
            .map(|_| {
                (
                    Tensor::full(&[c.d_model, c.phi_dim], fill),
                    Tensor::full(&[c.phi_dim], fill),
                )
            })
            .collect();
        MemSnapshot::from_layers(&c, segments, layers).unwrap()
    }

    fn blocks(tags: &[u32]) -> Vec<Vec<u32>> {
        let seg = cfg().seg;
        tags.iter().map(|&t| (0..seg as u32).map(|i| t * 100 + i).collect()).collect()
    }

    #[test]
    fn longest_prefix_wins() {
        let mut store = PrefixStore::new(usize::MAX);
        store.insert(&blocks(&[1]), snap(1, 0.1));
        store.insert(&blocks(&[1, 2, 3]), snap(3, 0.3));
        assert_eq!(store.len(), 2);

        // Deepest stored prefix below the query depth.
        let q = blocks(&[1, 2, 3, 4]);
        let (p, s) = store.lookup(&q).unwrap();
        assert_eq!(p, 3);
        assert_eq!(s.segments, 3);
        assert_eq!(s.a[0].data()[0], 0.3);

        // Falls back to the shorter prefix when the path diverges.
        let q = blocks(&[1, 9]);
        let (p, s) = store.lookup(&q).unwrap();
        assert_eq!(p, 1);
        assert_eq!(s.segments, 1);

        // Nothing cached along a different root.
        assert!(store.lookup(&blocks(&[7, 8])).is_none());
    }

    #[test]
    fn shared_prefix_across_divergent_tails() {
        // The serving shape: many prompts share a long prefix and
        // diverge at the tail. A snapshot stored at the shared depth
        // serves them all.
        let mut store = PrefixStore::new(usize::MAX);
        store.insert(&blocks(&[5, 6]), snap(2, 0.2));
        for tail in [10u32, 11, 12] {
            let (p, s) = store.lookup(&blocks(&[5, 6, tail])).unwrap();
            assert_eq!((p, s.segments), (2, 2));
        }
    }

    #[test]
    fn insert_replaces_same_prefix_without_leaking_bytes() {
        let mut store = PrefixStore::new(usize::MAX);
        store.insert(&blocks(&[1, 2]), snap(2, 0.1));
        let bytes_one = store.bytes();
        store.insert(&blocks(&[1, 2]), snap(2, 0.9));
        assert_eq!(store.len(), 1);
        assert_eq!(store.bytes(), bytes_one);
        let (_, s) = store.lookup(&blocks(&[1, 2])).unwrap();
        assert_eq!(s.a[0].data()[0], 0.9);
    }

    #[test]
    fn lru_eviction_under_byte_budget() {
        let one = snap(1, 0.0).byte_size() + cfg().seg * 4;
        // Room for two entries, not three.
        let mut store = PrefixStore::new(2 * one + one / 2);
        store.insert(&blocks(&[1]), snap(1, 0.1));
        store.insert(&blocks(&[2]), snap(1, 0.2));
        assert_eq!(store.evictions(), 0);

        // Touch [1] so [2] is the LRU victim.
        assert!(store.lookup(&blocks(&[1])).is_some());
        let evicted = store.insert(&blocks(&[3]), snap(1, 0.3));
        assert_eq!(evicted, 1);
        assert_eq!(store.evictions(), 1);
        assert_eq!(store.len(), 2);
        assert!(store.bytes() <= store.budget_bytes());
        assert!(store.lookup(&blocks(&[1])).is_some(), "recently used survives");
        assert!(store.lookup(&blocks(&[2])).is_none(), "LRU entry evicted");
        assert!(store.lookup(&blocks(&[3])).is_some());
    }

    #[test]
    fn oversized_snapshot_evicts_itself() {
        let mut store = PrefixStore::new(16); // smaller than any snapshot
        let evicted = store.insert(&blocks(&[1]), snap(1, 0.1));
        assert_eq!(evicted, 1);
        assert!(store.is_empty());
        assert_eq!(store.bytes(), 0);
        assert!(store.lookup(&blocks(&[1])).is_none());
    }

    #[test]
    fn eviction_prunes_empty_branches() {
        // Entries are charged their snapshot + own tail block only
        // (shared edges are not double-counted).
        let one = snap(3, 0.0).byte_size() + cfg().seg * 4;
        let mut store = PrefixStore::new(one + one / 2);
        store.insert(&blocks(&[1, 2, 3]), snap(3, 0.1));
        store.insert(&blocks(&[4, 5, 6]), snap(3, 0.2));
        assert_eq!(store.evictions(), 1);
        // The evicted chain is fully gone, including interior nodes.
        assert!(store.root.children.len() == 1, "emptied branch pruned");
        assert!(store.lookup(&blocks(&[4, 5, 6])).is_some());
    }

    #[test]
    fn eviction_handles_interior_entries_and_deep_chains() {
        // A 64-deep chain with a second entry at depth 1: evicting the
        // interior (older) entry must keep the chain below it intact,
        // evicting the deep leaf later must prune the dead chain — and
        // the iterative walkers must take the depth in stride.
        let tags: Vec<u32> = (0..64).collect();
        let deep = blocks(&tags);
        let one = snap(1, 0.0).byte_size() + cfg().seg * 4;
        let mut store = PrefixStore::new(one + one / 2);

        store.insert(&deep[..1], snap(1, 0.1));
        let evicted = store.insert(&deep, snap(64, 0.9));
        // Budget holds one entry: the older interior entry goes, the
        // deep chain survives it untouched.
        assert_eq!(evicted, 1);
        assert_eq!(store.len(), 1);
        assert!(store.lookup(&deep[..1]).is_none(), "interior entry evicted");
        let (p, s) = store.lookup(&deep).unwrap();
        assert_eq!((p, s.segments), (64, 64));

        // A fresh unrelated insert now evicts the deep leaf; its whole
        // dead chain is pruned back to the root.
        store.insert(&blocks(&[999]), snap(1, 0.5));
        assert_eq!(store.len(), 1);
        assert!(store.lookup(&deep).is_none());
        assert_eq!(store.root.children.len(), 1, "dead 64-deep chain pruned");
        assert!(store.lookup(&blocks(&[999])).is_some());
    }

    #[test]
    fn lookup_capped_by_caller_slice() {
        // The engine passes blocks[..len-1] so at least one segment is
        // always computed; a full-length entry is then unreachable.
        let mut store = PrefixStore::new(usize::MAX);
        let q = blocks(&[1, 2]);
        store.insert(&q, snap(2, 0.5));
        assert!(store.lookup(&q[..q.len() - 1]).is_none());
    }

    #[test]
    fn chain_hash_is_order_sensitive() {
        let a = blocks(&[1, 2]);
        let b = blocks(&[2, 1]);
        let ha = chain_hash(chain_hash(0, &a[0]), &a[1]);
        let hb = chain_hash(chain_hash(0, &b[0]), &b[1]);
        assert_ne!(ha, hb);
        assert_ne!(chain_hash(0, &a[0]), chain_hash(0, &a[1]));
    }
}
