//! Memory-state snapshot store: prefix-reuse cache + session
//! suspend/resume.
//!
//! ARMT's per-layer associative memory is constant-size regardless of
//! context length (`simulator/memory.rs` quantifies the gap vs. a
//! KV cache), so checkpointing a request's entire inference state
//! after segment `k` is almost free. This module turns that into two
//! serving features:
//!
//! * **Prefix reuse** — [`PrefixStore`] is a trie keyed on rolling
//!   hashes of segment token blocks, mapping longest-cached-prefix →
//!   [`MemSnapshot`], LRU-evicted under a byte budget
//!   (`--cache-bytes`). The engine consults it on admission: a request
//!   whose prompt shares a cached prefix seeds its wavefront lane from
//!   the snapshot and skips the cached prefill segments entirely
//!   ([`WavefrontSession::submit_stream_resumed`]) — the RMT analog of
//!   vLLM prefix caching / SGLang RadixAttention, with a few hundred
//!   kilobytes of state where those systems manage a paged KV pool.
//! * **Suspend/resume** — a completed request's final memory state is
//!   a [`MemSnapshot`] too: retained in the engine under an
//!   engine-assigned resume token (`"save": true` / `{"cmd": "save",
//!   "id": N}`; the `done` frame echoes the token, and a later request
//!   with `"resume": token` carries only the *new* tokens; retention
//!   is LRU-capped) or exported to disk
//!   ([`MemSnapshot::save`]/[`load`](MemSnapshot::load)) — multi-turn
//!   conversations never re-prefill their history.
//!
//! The load-bearing invariant (gated by `rust/tests/cache_resume.rs`
//! and the `cache_reuse` bench suite): a run resumed from a snapshot —
//! in-memory hit or disk round-trip — is **byte-identical**
//! (`f32::to_bits`) to recomputing the full prompt through the
//! sequential oracle. Serialization therefore ships raw f32 bit
//! patterns, and the trie verifies stored blocks verbatim instead of
//! trusting hashes.
//!
//! [`WavefrontSession::submit_stream_resumed`]: crate::scheduler::WavefrontSession::submit_stream_resumed

mod prefix;
mod snapshot;

pub use prefix::{chain_hash, PrefixStore};
pub use snapshot::MemSnapshot;
