//! [`MemSnapshot`]: one request's frozen recurrent memory.
//!
//! ARMT's whole pitch (vs. a KV cache) is that the per-layer state is
//! *constant-size*: `A [d_model, phi_dim]` plus `z [phi_dim]` per
//! layer, regardless of how many segments have streamed through. That
//! makes checkpointing an entire inference after segment `k` almost
//! free — the snapshot is a few hundred kilobytes for the paper
//! configs, not a paged KV pool — which is what the prefix-reuse cache
//! ([`crate::cache::PrefixStore`]) and conversation suspend/resume are
//! built on.
//!
//! Exactness contract: a snapshot restored into a wavefront lane
//! ([`WavefrontSession::submit_stream_resumed`](crate::scheduler::WavefrontSession::submit_stream_resumed))
//! or the sequential loop must reproduce the full-recompute run **bit
//! for bit** (`f32::to_bits`), including through a disk round-trip. So
//! serialization never goes through decimal floats: every f32 is
//! stored as its raw `u32` bit pattern (exact in JSON — integers below
//! 2^53 survive the f64 number model losslessly), preserving NaN
//! payloads, signed zeros and denormals.

use std::path::Path;

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::json::Value;
use crate::tensor::Tensor;

/// One lane's per-layer associative memory + recurrence counter after
/// some segment `k` — everything needed to continue the recurrence as
/// if the first `k` segments had just been computed.
#[derive(Clone, Debug, PartialEq)]
pub struct MemSnapshot {
    /// Model the state was produced by (`ModelConfig::name`); a
    /// best-effort guard — dimensions are checked exactly, weights
    /// cannot be.
    pub model: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub phi_dim: usize,
    /// Tokens per segment of the producing model (keys in the prefix
    /// trie are `seg`-sized token blocks).
    pub seg: usize,
    /// Recurrence counter: segments consumed to reach this state. A
    /// resumed run's next segment has absolute index `segments`.
    pub segments: usize,
    /// Per-layer associative memory `A [d_model, phi_dim]`.
    pub a: Vec<Tensor>,
    /// Per-layer normalizer state `z [phi_dim]`.
    pub z: Vec<Tensor>,
}

impl MemSnapshot {
    /// Assemble from per-layer `(A, z)` pairs in layer order.
    pub fn from_layers(
        cfg: &ModelConfig,
        segments: usize,
        layers: Vec<(Tensor, Tensor)>,
    ) -> Result<Self> {
        if layers.len() != cfg.n_layers {
            return Err(Error::Config(format!(
                "snapshot needs {} layers, got {}",
                cfg.n_layers,
                layers.len()
            )));
        }
        let (a, z): (Vec<Tensor>, Vec<Tensor>) = layers.into_iter().unzip();
        let snap = Self {
            model: cfg.name.clone(),
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
            phi_dim: cfg.phi_dim,
            seg: cfg.seg,
            segments,
            a,
            z,
        };
        snap.validate_for(cfg)?;
        Ok(snap)
    }

    /// Check this snapshot can seed a lane of `cfg`'s wavefront: every
    /// dimension must match and the state tensors must have the
    /// declared shapes. (The model *name* is compared too — a rename
    /// is the only weight-mismatch signal available at this layer.)
    pub fn validate_for(&self, cfg: &ModelConfig) -> Result<()> {
        let fail = |msg: String| Err(Error::Config(format!("snapshot mismatch: {msg}")));
        if self.model != cfg.name {
            return fail(format!("model '{}' vs engine '{}'", self.model, cfg.name));
        }
        if self.n_layers != cfg.n_layers
            || self.d_model != cfg.d_model
            || self.phi_dim != cfg.phi_dim
            || self.seg != cfg.seg
        {
            return fail(format!(
                "dims (L {}, d {}, p {}, seg {}) vs (L {}, d {}, p {}, seg {})",
                self.n_layers,
                self.d_model,
                self.phi_dim,
                self.seg,
                cfg.n_layers,
                cfg.d_model,
                cfg.phi_dim,
                cfg.seg
            ));
        }
        if self.segments == 0 {
            return fail("zero-segment snapshot (nothing was consumed)".into());
        }
        if self.a.len() != self.n_layers || self.z.len() != self.n_layers {
            return fail(format!("{} A / {} z layers", self.a.len(), self.z.len()));
        }
        for (l, (a, z)) in self.a.iter().zip(&self.z).enumerate() {
            if a.shape() != [self.d_model, self.phi_dim] {
                return fail(format!("layer {l} A shape {:?}", a.shape()));
            }
            if z.shape() != [self.phi_dim] {
                return fail(format!("layer {l} z shape {:?}", z.shape()));
            }
        }
        Ok(())
    }

    /// Approximate resident size — what the [`PrefixStore`]'s byte
    /// budget accounts (state floats dominate; per-entry bookkeeping
    /// is folded in as a small constant).
    ///
    /// [`PrefixStore`]: crate::cache::PrefixStore
    pub fn byte_size(&self) -> usize {
        let floats = self.n_layers * (self.d_model * self.phi_dim + self.phi_dim);
        floats * std::mem::size_of::<f32>() + self.model.len() + 128
    }

    /// Serialize. Floats travel as raw `u32` bit patterns
    /// (`f32::to_bits`), so the round-trip is bit-exact — NaNs, signed
    /// zeros and denormals included.
    pub fn to_json(&self) -> Value {
        let bits = |t: &Tensor| {
            Value::Arr(t.data().iter().map(|f| Value::Num(f.to_bits() as f64)).collect())
        };
        Value::obj(vec![
            ("model", Value::Str(self.model.clone())),
            ("n_layers", Value::Num(self.n_layers as f64)),
            ("d_model", Value::Num(self.d_model as f64)),
            ("phi_dim", Value::Num(self.phi_dim as f64)),
            ("seg", Value::Num(self.seg as f64)),
            ("segments", Value::Num(self.segments as f64)),
            ("a_bits", Value::Arr(self.a.iter().map(&bits).collect())),
            ("z_bits", Value::Arr(self.z.iter().map(&bits).collect())),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let n_layers = v.req("n_layers")?.as_usize()?;
        let d_model = v.req("d_model")?.as_usize()?;
        let phi_dim = v.req("phi_dim")?.as_usize()?;
        let tensor_from_bits = |v: &Value, shape: &[usize]| -> Result<Tensor> {
            let data = v
                .as_arr()?
                .iter()
                .map(|b| {
                    let bits = b.as_u64()?;
                    let bits = u32::try_from(bits)
                        .map_err(|_| Error::Json(format!("f32 bit pattern {bits} > u32")))?;
                    Ok(f32::from_bits(bits))
                })
                .collect::<Result<Vec<f32>>>()?;
            Tensor::new(shape, data)
        };
        let read_layers = |key: &str, shape: &[usize]| -> Result<Vec<Tensor>> {
            let arr = v.req(key)?.as_arr()?;
            if arr.len() != n_layers {
                return Err(Error::Json(format!(
                    "snapshot {key}: {} layers, expected {n_layers}",
                    arr.len()
                )));
            }
            arr.iter().map(|t| tensor_from_bits(t, shape)).collect()
        };
        Ok(Self {
            model: v.req("model")?.as_str()?.to_string(),
            n_layers,
            d_model,
            phi_dim,
            seg: v.req("seg")?.as_usize()?,
            segments: v.req("segments")?.as_usize()?,
            a: read_layers("a_bits", &[d_model, phi_dim])?,
            z: read_layers("z_bits", &[phi_dim])?,
        })
    }

    /// Write to disk (one JSON document) — the suspend half of
    /// conversation suspend/resume.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, self.to_json().to_json() + "\n")?;
        Ok(())
    }

    /// Read back from disk. `load(p)` after `save(p)` is bit-identical
    /// to the original snapshot.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Self::from_json(&Value::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn cfg() -> ModelConfig {
        ModelConfig::synthetic()
    }

    fn snap(seed: u64, segments: usize) -> MemSnapshot {
        let c = cfg();
        let mut rng = Rng::new(seed);
        let layers = (0..c.n_layers)
            .map(|_| {
                (
                    Tensor::randn(&[c.d_model, c.phi_dim], 0.3, &mut rng),
                    Tensor::randn(&[c.phi_dim], 0.3, &mut rng),
                )
            })
            .collect();
        MemSnapshot::from_layers(&c, segments, layers).unwrap()
    }

    #[test]
    fn json_roundtrip_is_bit_exact() {
        let s = snap(7, 5);
        let back = MemSnapshot::from_json(&Value::parse(&s.to_json().to_json()).unwrap()).unwrap();
        assert_eq!(back, s);
        for (a, b) in s.a.iter().zip(&back.a) {
            let (ab, bb): (Vec<u32>, Vec<u32>) = (
                a.data().iter().map(|x| x.to_bits()).collect(),
                b.data().iter().map(|x| x.to_bits()).collect(),
            );
            assert_eq!(ab, bb);
        }
    }

    #[test]
    fn roundtrip_preserves_special_float_bits() {
        // NaN payloads, -0.0, denormals and infinities must survive —
        // decimal formatting would destroy all of them.
        let mut s = snap(8, 1);
        let d = s.a[0].data_mut();
        d[0] = f32::from_bits(0x7fc0_0abc); // NaN with payload
        d[1] = -0.0;
        d[2] = f32::from_bits(1); // smallest denormal
        d[3] = f32::INFINITY;
        d[4] = f32::NEG_INFINITY;
        let back = MemSnapshot::from_json(&Value::parse(&s.to_json().to_json()).unwrap()).unwrap();
        for (x, y) in s.a[0].data().iter().zip(back.a[0].data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn disk_roundtrip() {
        let s = snap(9, 3);
        let path = std::env::temp_dir().join(format!("snap_test_{}.json", std::process::id()));
        s.save(&path).unwrap();
        let back = MemSnapshot::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(back, s);
    }

    #[test]
    fn validate_rejects_mismatches() {
        let c = cfg();
        assert!(snap(1, 2).validate_for(&c).is_ok());

        let mut wrong_model = snap(1, 2);
        wrong_model.model = "other".into();
        assert!(wrong_model.validate_for(&c).is_err());

        let mut wrong_dim = snap(1, 2);
        wrong_dim.d_model += 1;
        assert!(wrong_dim.validate_for(&c).is_err());

        let mut zero_segments = snap(1, 2);
        zero_segments.segments = 0;
        assert!(zero_segments.validate_for(&c).is_err());

        let mut missing_layer = snap(1, 2);
        missing_layer.a.pop();
        assert!(missing_layer.validate_for(&c).is_err());

        // from_layers refuses a short layer list outright.
        assert!(MemSnapshot::from_layers(&c, 1, vec![]).is_err());
    }

    #[test]
    fn byte_size_covers_state() {
        let c = cfg();
        let s = snap(2, 1);
        let floats = c.n_layers * (c.d_model * c.phi_dim + c.phi_dim);
        assert!(s.byte_size() >= floats * 4);
    }
}
