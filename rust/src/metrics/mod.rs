//! Metrics: counters, gauges, histograms and a registry, shared by the
//! coordinator and the server. No external deps; snapshotting is
//! lock-based and cheap (the hot path only bumps atomics).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Monotone event counter.
#[derive(Default, Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-value gauge (u64).
#[derive(Default, Debug)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Two-counter ratio (numerator / denominator) for utilization-style
/// metrics — e.g. wavefront occupancy = active cells / slot-steps. Both
/// sides are relaxed atomics; the hot path only adds.
#[derive(Default, Debug)]
pub struct Ratio {
    num: AtomicU64,
    den: AtomicU64,
}

impl Ratio {
    /// Requires `num <= den` per observation (an occupancy can't exceed
    /// its slot count). Writes den before num (Release) while readers
    /// load num before den (Acquire), so a concurrent snapshot can
    /// never observe `num > den` — `den - num` stays subtraction-safe.
    pub fn add(&self, num: u64, den: u64) {
        debug_assert!(num <= den, "Ratio::add: {num} > {den}");
        self.den.fetch_add(den, Ordering::Release);
        self.num.fetch_add(num, Ordering::Release);
    }

    pub fn parts(&self) -> (u64, u64) {
        let num = self.num.load(Ordering::Acquire);
        let den = self.den.load(Ordering::Acquire);
        (num, den)
    }

    /// num / den, or 0.0 before any observation.
    pub fn value(&self) -> f64 {
        let (n, d) = self.parts();
        if d == 0 {
            0.0
        } else {
            n as f64 / d as f64
        }
    }
}

/// Log-scaled latency histogram: buckets at 1us * 2^i, i in 0..32.
///
/// This is what backs the serving latency percentiles — the engine
/// observes per-request latency into
/// [`EngineStats::latency`](crate::coordinator::EngineStats), and the
/// server's `{"cmd": "stats"}` reply exports
/// `latency_ms_{mean,p50,p90,p99}` from it.
///
/// # Examples
///
/// ```
/// use std::time::Duration;
/// use diagonal_batching::metrics::Histogram;
///
/// let h = Histogram::new();
/// h.observe(Duration::from_micros(250));
/// // Quantiles report the upper edge of the containing power-of-two
/// // bucket: coarse (within 2x), but allocation- and lock-free.
/// assert!(h.quantile(0.5) >= Duration::from_micros(250));
/// assert!(h.quantile(0.99) >= h.quantile(0.5));
/// ```
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..32).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(31);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            Duration::ZERO
        } else {
            Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / c)
        }
    }

    /// Total observed time in microseconds (the Prometheus `_sum`,
    /// before unit conversion).
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Per-bucket counts, index i = observations in [2^i, 2^(i+1)) us
    /// (observations clamp to >= 1us; the last bucket is open-ended).
    /// A relaxed snapshot — pair with [`count`](Self::count) from the
    /// same moment only loosely (scrapes tolerate small skew).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Upper edge of bucket `i` in microseconds (`le` label for the
    /// Prometheus exposition): 2^(i+1) us, matching
    /// [`quantile`](Self::quantile)'s convention.
    pub fn bucket_edge_us(i: usize) -> u64 {
        1u64 << (i + 1).min(63)
    }

    /// Upper edge of the bucket containing quantile `q` (0..1) — a
    /// coarse (2x) but allocation-free percentile.
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_micros(1 << (i + 1));
            }
        }
        Duration::from_micros(1 << 31)
    }
}

/// Named metric registry, snapshot-able to JSON.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, u64>>,
}

impl Registry {
    pub fn record(&self, name: &str, v: u64) {
        let mut m = self.counters.lock().unwrap();
        *m.entry(name.to_string()).or_insert(0) += v;
    }

    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        self.counters.lock().unwrap().clone()
    }

    pub fn to_json(&self) -> crate::json::Value {
        crate::json::Value::Obj(
            self.snapshot()
                .into_iter()
                .map(|(k, v)| (k, crate::json::Value::Num(v as f64)))
                .collect(),
        )
    }
}

/// RAII timer that records into a histogram on drop.
pub struct Stopwatch<'a> {
    hist: &'a Histogram,
    start: std::time::Instant,
}

impl<'a> Stopwatch<'a> {
    pub fn start(hist: &'a Histogram) -> Self {
        Self { hist, start: std::time::Instant::now() }
    }
}

impl Drop for Stopwatch<'_> {
    fn drop(&mut self) {
        self.hist.observe(self.start.elapsed());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::default();
        g.set(7);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn ratio_accumulates() {
        let r = Ratio::default();
        assert_eq!(r.value(), 0.0);
        r.add(3, 4);
        r.add(1, 4);
        assert_eq!(r.parts(), (4, 8));
        assert!((r.value() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let h = Histogram::new();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            for _ in 0..20 {
                h.observe(Duration::from_micros(us));
            }
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(h.mean() > Duration::ZERO);
    }

    #[test]
    fn histogram_buckets_are_exportable() {
        let h = Histogram::new();
        h.observe(Duration::from_micros(1)); // bucket 0
        h.observe(Duration::from_micros(3)); // bucket 1
        h.observe(Duration::from_micros(3)); // bucket 1
        let counts = h.bucket_counts();
        assert_eq!(counts.len(), 32);
        assert_eq!(counts[0], 1);
        assert_eq!(counts[1], 2);
        assert_eq!(counts.iter().sum::<u64>(), h.count());
        assert_eq!(h.sum_us(), 7);
        // Edges are the same convention quantile() reports.
        assert_eq!(Histogram::bucket_edge_us(0), 2);
        assert_eq!(Histogram::bucket_edge_us(4), 32);
    }

    #[test]
    fn stopwatch_records() {
        let h = Histogram::new();
        {
            let _sw = Stopwatch::start(&h);
        }
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn registry_snapshot() {
        let r = Registry::default();
        r.record("a", 2);
        r.record("a", 3);
        r.record("b", 1);
        let s = r.snapshot();
        assert_eq!(s["a"], 5);
        assert_eq!(s["b"], 1);
        assert!(r.to_json().to_json().contains("\"a\":5"));
    }
}
