//! Hand-rolled HTTP/1.1 + SSE front end (std-only, like the rest of
//! the crate — no hyper/tokio, the `json.rs` idiom applied to HTTP).
//!
//! Routes:
//!
//! ```text
//! POST /v1/generate      body = the TCP request object (same fields)
//!   -> 200 text/event-stream; each engine Event is one SSE frame:
//!      event: token\n data: {"id":1,"event":"token","pos":0,...}\n\n
//!      The `data:` payload is byte-identical to the TCP line protocol's
//!      frame for the same request (both come from `render_event`).
//!   -> 401 unknown/missing API key (when tenants are configured)
//!   -> 429 token bucket tripped, or tenant queue full (load shed)
//!   -> 400 malformed JSON / request
//! POST /v1/cancel/{id}   cancel an in-flight request (auth-checked:
//!                        only the admitting tenant; unknown and
//!                        foreign ids both 404, so ids can't be probed)
//! GET  /metrics          Prometheus text: every EngineStats field +
//!                        latency histograms + gateway admission counters
//! GET  /debug/trace      Chrome-trace JSON snapshot of the span ring
//!                        (empty array when tracing is off)
//! GET  /healthz          200 "ok"
//! POST /admin/shutdown   initiate engine shutdown (drains in-flight)
//! ```
//!
//! Trace ids: an `X-Trace-Id` header (or the body field `"trace"`,
//! which wins) stitches the request's spans across the gateway, the
//! engine and any shard hops; non-numeric header values are hashed to
//! a stable 48-bit id.
//!
//! Authentication: `Authorization: Bearer <key>` or `X-Api-Key: <key>`,
//! resolved against the configured [`TenantSpec`](super::TenantSpec)s;
//! with none configured the gateway is open and everything admits as
//! the built-in `local` tenant. Each connection serves one request and
//! closes (`Connection: close`) — SSE streams hold the socket for the
//! request lifetime anyway.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use crate::coordinator::EngineStats;
use crate::error::{Error, Result};
use crate::gateway::metrics::{append_tenant_series, render_prometheus};
use crate::gateway::FairScheduler;
use crate::json::Value;
use crate::server::{
    error_json, parse_request, render_event, CancelRegistry, ConnTicket, Job, WaitGroup,
    EVENT_BUFFER,
};

/// Cap on request bodies (a 1M-token prompt in JSON is ~7 MB; leave
/// generous headroom without letting one socket balloon memory).
const MAX_BODY: usize = 64 << 20;
const MAX_HEADERS: usize = 100;

/// Everything one HTTP connection needs, shared with the TCP server
/// (same scheduler, same cancel registry, same wire-id namespace — a
/// request admitted over HTTP can be cancelled over TCP and vice
/// versa).
pub(crate) struct HttpShared {
    pub(crate) sched: Arc<FairScheduler<Job>>,
    pub(crate) registry: CancelRegistry,
    pub(crate) stats: Arc<EngineStats>,
    pub(crate) shutdown: Arc<AtomicBool>,
    pub(crate) next_id: Arc<AtomicU64>,
    /// Streaming sections register here so `Server::stop`/`join` wait
    /// for in-flight SSE streams to flush their terminal frame.
    pub(crate) streams: WaitGroup,
    /// Which tenant admitted each in-flight HTTP wire id — the
    /// `POST /v1/cancel/{id}` ownership check.
    pub(crate) owners: Arc<Mutex<HashMap<u64, usize>>>,
}

/// A parsed HTTP/1.1 request (header names lowercased).
pub(crate) struct HttpRequest {
    pub method: String,
    pub path: String,
    headers: Vec<(String, String)>,
    pub body: String,
}

impl HttpRequest {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }

    /// The presented API key: `Authorization: Bearer <key>` wins, then
    /// `X-Api-Key: <key>`.
    pub fn api_key(&self) -> Option<&str> {
        if let Some(auth) = self.header("authorization") {
            if let Some(rest) = auth.strip_prefix("Bearer ").or_else(|| {
                auth.strip_prefix("bearer ")
            }) {
                let key = rest.trim();
                if !key.is_empty() {
                    return Some(key);
                }
            }
        }
        self.header("x-api-key").map(str::trim).filter(|k| !k.is_empty())
    }
}

/// Read one request off the stream. `Ok(None)` = clean EOF before a
/// request line (client connected and left).
pub(crate) fn read_http_request(reader: &mut impl BufRead) -> Result<Option<HttpRequest>> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Ok(None);
    }
    let line = line.trim_end();
    let mut parts = line.split_whitespace();
    let (Some(method), Some(target), Some(version)) =
        (parts.next(), parts.next(), parts.next())
    else {
        return Err(Error::Request(format!("malformed request line '{line}'")));
    };
    if !version.starts_with("HTTP/1.") {
        return Err(Error::Request(format!("unsupported protocol '{version}'")));
    }
    // Route on the path alone; a query string is tolerated and ignored.
    let path = target.split('?').next().unwrap_or(target).to_string();

    let mut headers = Vec::new();
    loop {
        if headers.len() > MAX_HEADERS {
            return Err(Error::Request("too many headers".into()));
        }
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            return Err(Error::Request("connection closed mid-headers".into()));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let Some((name, value)) = h.split_once(':') else {
            return Err(Error::Request(format!("malformed header '{h}'")));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }

    let len = match headers.iter().find(|(n, _)| n == "content-length") {
        None => 0,
        Some((_, v)) => v
            .parse::<usize>()
            .map_err(|_| Error::Request(format!("bad content-length '{v}'")))?,
    };
    if len > MAX_BODY {
        return Err(Error::Request(format!("body of {len} bytes exceeds {MAX_BODY}")));
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    let body = String::from_utf8(body)
        .map_err(|_| Error::Request("request body is not UTF-8".into()))?;
    Ok(Some(HttpRequest { method: method.to_string(), path, headers, body }))
}

fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        401 => "Unauthorized",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        429 => "Too Many Requests",
        _ => "Internal Server Error",
    }
}

fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &str,
    extra: &[(&str, &str)],
) -> Result<()> {
    write!(
        w,
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n",
        status_reason(status),
        body.len()
    )?;
    for (name, value) in extra {
        write!(w, "{name}: {value}\r\n")?;
    }
    write!(w, "\r\n{body}")?;
    w.flush()?;
    Ok(())
}

/// Error reply: the SAME error object the TCP protocol uses, as the
/// HTTP body, with the status carrying the HTTP-level semantics.
fn write_error(
    w: &mut impl Write,
    status: u16,
    id: Option<u64>,
    e: &Error,
    extra: &[(&str, &str)],
) -> Result<()> {
    let mut body = error_json(id, e);
    body.push('\n');
    write_response(w, status, "application/json", &body, extra)
}

/// Serve one HTTP connection (one request, then close).
pub(crate) fn handle_http_conn(stream: TcpStream, sh: &HttpShared) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let Some(req) = read_http_request(&mut reader)? else {
        return Ok(());
    };
    sh.sched.stats.http_requests.inc();
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            write_response(&mut writer, 200, "text/plain; charset=utf-8", "ok\n", &[])
        }
        ("GET", "/metrics") => {
            let mut body = render_prometheus(&sh.stats, Some(&sh.sched.stats));
            append_tenant_series(&sh.sched, &mut body);
            write_response(
                &mut writer,
                200,
                "text/plain; version=0.0.4; charset=utf-8",
                &body,
                &[],
            )
        }
        ("POST", "/admin/shutdown") => {
            sh.shutdown.store(true, Ordering::SeqCst);
            sh.sched.close();
            write_response(
                &mut writer,
                200,
                "application/json",
                "{\"ok\": true}\n",
                &[],
            )
        }
        ("POST", "/v1/generate") => stream_generate(&req, &mut writer, sh),
        ("POST", p) if p.starts_with("/v1/cancel/") => {
            cancel_request(&req, &mut writer, sh)
        }
        ("GET", "/debug/trace") => {
            let mut body = crate::trace::export_chrome();
            body.push('\n');
            write_response(&mut writer, 200, "application/json", &body, &[])
        }
        (_, p) if p.starts_with("/v1/cancel/") => write_error(
            &mut writer,
            405,
            None,
            &Error::Request(format!("method {} not allowed here", req.method)),
            &[],
        ),
        (_, "/healthz" | "/metrics" | "/admin/shutdown" | "/v1/generate"
        | "/debug/trace") => write_error(
            &mut writer,
            405,
            None,
            &Error::Request(format!("method {} not allowed here", req.method)),
            &[],
        ),
        (_, path) => write_error(
            &mut writer,
            404,
            None,
            &Error::Request(format!("no route '{path}'")),
            &[],
        ),
    }
}

/// Minimal metrics-only HTTP listener: `GET /metrics` and `GET
/// /healthz` over a shared stats block. This is the shard
/// coordinator's observability endpoint (`shard --http ADDR`) — the
/// coordinator speaks the TCP protocol for traffic, so only the
/// scrape/probe routes exist here. The accept thread is detached and
/// lives for the process (the coordinator has no drain phase for it to
/// join).
pub fn serve_metrics(
    addr: &str,
    stats: Arc<EngineStats>,
) -> Result<std::net::SocketAddr> {
    let listener = std::net::TcpListener::bind(addr)?;
    let bound = listener.local_addr()?;
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let stats = stats.clone();
            std::thread::spawn(move || {
                let Ok(mut writer) = stream.try_clone() else { return };
                let mut reader = BufReader::new(stream);
                let Ok(Some(req)) = read_http_request(&mut reader) else { return };
                let _ = match (req.method.as_str(), req.path.as_str()) {
                    ("GET", "/healthz") => write_response(
                        &mut writer,
                        200,
                        "text/plain; charset=utf-8",
                        "ok\n",
                        &[],
                    ),
                    ("GET", "/metrics") => {
                        let body = render_prometheus(&stats, None);
                        write_response(
                            &mut writer,
                            200,
                            "text/plain; version=0.0.4; charset=utf-8",
                            &body,
                            &[],
                        )
                    }
                    (_, path) => write_error(
                        &mut writer,
                        404,
                        None,
                        &Error::Request(format!("no route '{path}'")),
                        &[],
                    ),
                };
            });
        }
    });
    Ok(bound)
}

/// `POST /v1/cancel/{id}`: fire the cancel handle of an in-flight
/// request admitted over HTTP. Auth-checked against the admitting
/// tenant; unknown and foreign-tenant ids are indistinguishable (404),
/// so wire ids cannot be probed across tenants. The engine's cancel
/// sweep then ends the request span with `cancelled: true` and the
/// SSE stream terminates with the standard error frame — exactly the
/// TCP `{"cmd": "cancel"}` semantics.
fn cancel_request(req: &HttpRequest, w: &mut TcpStream, sh: &HttpShared) -> Result<()> {
    let tenant = match sh.sched.authenticate(req.api_key()) {
        Ok(t) => t,
        Err(e) => {
            sh.sched.stats.unauthorized.inc();
            return write_error(w, 401, None, &e, &[]);
        }
    };
    let id_str = req.path.strip_prefix("/v1/cancel/").unwrap_or("");
    let Ok(id) = id_str.parse::<u64>() else {
        return write_error(
            w,
            400,
            None,
            &Error::Request(format!("bad request id '{id_str}'")),
            &[],
        );
    };
    let owned = sh.owners.lock().unwrap().get(&id) == Some(&tenant);
    let handle = if owned { sh.registry.lock().unwrap().get(&id).cloned() } else { None };
    match handle {
        Some(h) => {
            h.cancel();
            sh.sched.stats.http_cancels.inc();
            write_response(
                w,
                200,
                "application/json",
                &format!("{{\"ok\": true, \"id\": {id}}}\n"),
                &[],
            )
        }
        None => write_error(
            w,
            404,
            Some(id),
            &Error::Request(format!("no in-flight request {id}")),
            &[],
        ),
    }
}

/// `POST /v1/generate`: authenticate, rate-limit, admit into the
/// weighted-fair scheduler, stream the event frames back as SSE.
fn stream_generate(req: &HttpRequest, w: &mut TcpStream, sh: &HttpShared) -> Result<()> {
    // Per-tenant API key -> tenant lane.
    let tenant = match sh.sched.authenticate(req.api_key()) {
        Ok(t) => t,
        Err(e) => {
            sh.sched.stats.unauthorized.inc();
            return write_error(w, 401, None, &e, &[]);
        }
    };
    // Token bucket: over-rate tenants shed HERE, before touching the
    // queue — backpressure turns into a clean 429, not producer spin.
    if !sh.sched.try_acquire(tenant) {
        sh.sched.stats.rate_limited.inc();
        sh.sched.tenant_stats[tenant].rate_limited.inc();
        return write_error(
            w,
            429,
            None,
            &Error::Request("rate limited".into()),
            &[("Retry-After", "1")],
        );
    }
    let v = match Value::parse(&req.body) {
        Ok(v) => v,
        Err(e) => return write_error(w, 400, None, &e, &[]),
    };
    // Same wire-id namespace as the TCP acceptor: auto ids skip over
    // anything currently active on either front end.
    let next_auto_id = || loop {
        let candidate = sh.next_id.fetch_add(1, Ordering::Relaxed);
        if !sh.registry.lock().unwrap().contains_key(&candidate) {
            return candidate;
        }
    };
    let mut greq = match parse_request(&v, next_auto_id) {
        Ok(r) => r,
        Err(e) => return write_error(w, 400, None, &e, &[]),
    };
    // Trace propagation: the body field `"trace"` wins; otherwise an
    // `X-Trace-Id` header stitches this hop into the caller's trace
    // (non-numeric values hash to a stable 48-bit id).
    if greq.trace.is_none() {
        if let Some(h) = req.header("x-trace-id") {
            greq = greq.with_trace(crate::trace::trace_id_from_str(h));
        }
    }
    let wire_id = greq.id;
    let handle = greq.handle();
    {
        let mut reg = sh.registry.lock().unwrap();
        if reg.contains_key(&wire_id) {
            drop(reg);
            return write_error(
                w,
                409,
                Some(wire_id),
                &Error::Request(format!("id {wire_id} already in flight")),
                &[],
            );
        }
        reg.insert(wire_id, handle.clone());
    }
    sh.owners.lock().unwrap().insert(wire_id, tenant);
    // Fair-share cost = the work the request buys: prompt + decode
    // budget, in tokens. A 1M-token burst debits its tenant
    // accordingly; small interactive requests stay cheap.
    let cost = (greq.prompt.len() + greq.max_new_tokens) as f64;
    let budget = greq.max_new_tokens;
    let (tx, rx) = mpsc::sync_channel(EVENT_BUFFER);
    // Guard from admission to terminal-frame flush: server shutdown
    // waits on it so an admitted SSE stream always gets its terminal
    // frame onto the wire.
    let _stream_guard = sh.streams.enter();
    let ticket = ConnTicket { tx, handle: handle.clone(), tenant, budget };
    if let Err(e) = sh.sched.push(tenant, cost, (greq, ticket)) {
        sh.registry.lock().unwrap().remove(&wire_id);
        sh.owners.lock().unwrap().remove(&wire_id);
        // Queue-full load shed (or closed during shutdown): 429 with
        // the standard error object, mirroring the TCP queue-full
        // frame.
        return write_error(w, 429, Some(wire_id), &e, &[("Retry-After", "1")]);
    }
    sh.sched.stats.sse_streams.inc();
    sh.sched.tenant_stats[tenant].sse_streams.inc();

    // SSE header; frames follow unframed (no Content-Length, the
    // stream ends when the socket closes after the terminal frame).
    write!(
        w,
        "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n"
    )?;
    let mut client_gone = false;
    loop {
        match rx.recv() {
            Ok(ev) => {
                let terminal = ev.is_terminal();
                if !client_gone {
                    let frame = render_event(wire_id, &ev);
                    let name = frame
                        .get("event")
                        .and_then(|e| e.as_str().ok())
                        .unwrap_or("message")
                        .to_string();
                    let data = frame.to_json();
                    if write!(w, "event: {name}\ndata: {data}\n\n")
                        .and_then(|_| w.flush())
                        .is_err()
                    {
                        // Client went away mid-stream: free the lane.
                        client_gone = true;
                        handle.cancel();
                    }
                }
                if terminal {
                    break;
                }
            }
            Err(_) => {
                // Channel closed without a terminal frame (engine died
                // or slow-consumer eviction): tell the client if it
                // still listens.
                if !client_gone {
                    let msg = error_json(
                        Some(wire_id),
                        &Error::Request(
                            "request stream closed (engine stopped or evicted)".into(),
                        ),
                    );
                    let _ = write!(w, "event: error\ndata: {msg}\n\n");
                    let _ = w.flush();
                }
                break;
            }
        }
    }
    sh.registry.lock().unwrap().remove(&wire_id);
    sh.owners.lock().unwrap().remove(&wire_id);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<Option<HttpRequest>> {
        read_http_request(&mut Cursor::new(raw.as_bytes()))
    }

    #[test]
    fn parses_a_post_with_body() {
        let raw = "POST /v1/generate HTTP/1.1\r\nHost: x\r\nAuthorization: Bearer sk-1\r\nContent-Length: 13\r\n\r\n{\"tokens\":[]}";
        let req = parse(raw).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.body, "{\"tokens\":[]}");
        assert_eq!(req.api_key(), Some("sk-1"));
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"));
    }

    #[test]
    fn parses_get_without_body_and_strips_query() {
        let raw = "GET /metrics?debug=1 HTTP/1.0\r\n\r\n";
        let req = parse(raw).unwrap().unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/metrics");
        assert_eq!(req.body, "");
        assert_eq!(req.api_key(), None);
    }

    #[test]
    fn x_api_key_is_a_fallback() {
        let raw = "GET / HTTP/1.1\r\nX-Api-Key: sk-2\r\n\r\n";
        let req = parse(raw).unwrap().unwrap();
        assert_eq!(req.api_key(), Some("sk-2"));
        // Bearer wins when both are present.
        let raw = "GET / HTTP/1.1\r\nAuthorization: Bearer a\r\nX-Api-Key: b\r\n\r\n";
        assert_eq!(parse(raw).unwrap().unwrap().api_key(), Some("a"));
        // A non-bearer Authorization falls through to X-Api-Key.
        let raw = "GET / HTTP/1.1\r\nAuthorization: Basic xyz\r\nX-Api-Key: b\r\n\r\n";
        assert_eq!(parse(raw).unwrap().unwrap().api_key(), Some("b"));
    }

    #[test]
    fn eof_and_malformed_inputs() {
        assert!(parse("").unwrap().is_none());
        assert!(parse("GARBAGE\r\n\r\n").is_err());
        assert!(parse("GET / SPDY/3\r\n\r\n").is_err());
        assert!(parse("GET / HTTP/1.1\r\nno-colon-here\r\n\r\n").is_err());
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: frog\r\n\r\n").is_err());
        // Body shorter than content-length -> read_exact EOF error.
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc").is_err());
    }

    #[test]
    fn response_writer_formats_status_and_headers() {
        let mut buf = Vec::new();
        write_response(&mut buf, 429, "application/json", "{}", &[("Retry-After", "1")])
            .unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("Content-Length: 2\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));
    }
}
