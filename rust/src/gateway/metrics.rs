//! Prometheus text exposition (`GET /metrics`) over
//! [`EngineStats`](crate::coordinator::EngineStats) and
//! [`GatewayStats`](crate::gateway::GatewayStats).
//!
//! The export iterates `EngineStats::to_json()` generically, so every
//! stats field — present and future — appears in `/metrics` without a
//! second hand-maintained list (the completeness test below enforces
//! it). Monotone fields get the Prometheus `_total` suffix and
//! `counter` type; instantaneous fields are `gauge`s. The per-kernel
//! breakdown becomes `kernel`-labelled series, and the active GEMM
//! policy an info-style gauge.

use std::fmt::Write as _;

use crate::coordinator::EngineStats;
use crate::gateway::{FairScheduler, GatewayStats, TenantCounters};
use crate::json::Value;
use crate::metrics::Histogram;

/// Engine fields that only ever increase (exported as counters with
/// the `_total` suffix). Everything else numeric is a gauge.
const MONOTONE: &[&str] = &[
    "requests",
    "rejected",
    "cancelled",
    "diagonal_runs",
    "sequential_runs",
    "full_attn_runs",
    "packed_requests",
    "tokens",
    "generated_tokens",
    "launches",
    "active_cells",
    "slot_steps",
    "padded_cells",
    "cache_hits",
    "cache_hit_segments",
    "evictions",
    "pool_cells",
    "pool_busy_ms",
    "kernel_flops",
    "kernel_time_ms",
    "shard_routed",
    "shard_failovers",
    "shard_handoffs",
    "shard_handoff_bytes",
    "segments_skipped",
    "overflow_routed",
];

fn fmt_num(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 9.0e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn series(out: &mut String, name: &str, kind: &str, help: &str, body: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
    out.push_str(body);
}

/// Render one latency [`Histogram`] as a Prometheus histogram:
/// cumulative `_bucket{le="..."}` samples (bucket edges converted from
/// the internal log2-microsecond scale to milliseconds), `_sum` (ms)
/// and `_count`. `+Inf` repeats the last cumulative count so the
/// series stays monotone even against a racing observation.
fn append_histogram(out: &mut String, name: &str, help: &str, h: &Histogram) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    let mut cum = 0u64;
    for (i, c) in h.bucket_counts().iter().enumerate() {
        cum += c;
        let le = Histogram::bucket_edge_us(i) as f64 / 1000.0;
        let _ = writeln!(out, "{name}_bucket{{le=\"{}\"}} {cum}", fmt_num(le));
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
    let _ = writeln!(out, "{name}_sum {}", fmt_num(h.sum_us() as f64 / 1000.0));
    let _ = writeln!(out, "{name}_count {cum}");
}

/// Render the full `/metrics` payload: every engine stats field, plus
/// the gateway admission counters when the HTTP front end is running.
pub fn render_prometheus(engine: &EngineStats, gateway: Option<&GatewayStats>) -> String {
    let mut out = String::new();
    let Value::Obj(fields) = engine.to_json() else {
        unreachable!("EngineStats::to_json() is an object");
    };
    for (key, val) in &fields {
        match (key.as_str(), val) {
            ("kernels", Value::Obj(kernels)) => {
                // Per-kernel breakdown -> kernel-labelled series.
                for (stat, kind, help) in [
                    ("calls", "counter", "Invocations of this GEMM kernel."),
                    ("flops", "counter", "Floating-point ops executed by this kernel."),
                    ("time_ms", "counter", "Milliseconds spent in this kernel."),
                    ("gflops", "gauge", "Achieved GFLOP/s of this kernel."),
                ] {
                    let mut body = String::new();
                    for (kname, kval) in kernels {
                        let Some(v) = kval.get(stat).and_then(|v| v.as_f64().ok()) else {
                            continue;
                        };
                        let suffix = if kind == "counter" { "_total" } else { "" };
                        let _ = writeln!(
                            body,
                            "pallas_kernel_{stat}{suffix}{{kernel=\"{}\"}} {}",
                            escape_label(kname),
                            fmt_num(v)
                        );
                    }
                    if !body.is_empty() {
                        let suffix = if kind == "counter" { "_total" } else { "" };
                        series(
                            &mut out,
                            &format!("pallas_kernel_{stat}{suffix}"),
                            kind,
                            help,
                            &body,
                        );
                    }
                }
            }
            ("kernel_policy", Value::Str(policy)) => {
                series(
                    &mut out,
                    "pallas_kernel_policy",
                    "gauge",
                    "Active GEMM kernel policy (info-style; value is always 1).",
                    &format!(
                        "pallas_kernel_policy{{policy=\"{}\"}} 1\n",
                        escape_label(policy)
                    ),
                );
            }
            (k, Value::Num(v)) => {
                let monotone = MONOTONE.contains(&k);
                let (name, kind) = if monotone {
                    (format!("pallas_{k}_total"), "counter")
                } else {
                    (format!("pallas_{k}"), "gauge")
                };
                series(
                    &mut out,
                    &name,
                    kind,
                    &format!("Engine stats field `{k}`."),
                    &format!("{name} {}\n", fmt_num(*v)),
                );
            }
            // Non-numeric additions surface as info gauges so the
            // export stays complete even for field types this module
            // doesn't know yet.
            (k, other) => {
                let name = format!("pallas_{k}");
                series(
                    &mut out,
                    &name,
                    "gauge",
                    &format!("Engine stats field `{k}` (non-numeric)."),
                    &format!(
                        "{name}{{value=\"{}\"}} 1\n",
                        escape_label(&other.to_json())
                    ),
                );
            }
        }
    }
    // Full latency distributions (the scalar p50/p99 gauges above come
    // from these same histograms; the bucket series is what Prometheus
    // quantile queries consume).
    for (name, help, h) in [
        (
            "pallas_latency_ms",
            "End-to-end request latency, milliseconds.",
            &engine.latency,
        ),
        (
            "pallas_ttft_ms",
            "Time from wavefront admission to first generated token, milliseconds.",
            &engine.ttft,
        ),
        (
            "pallas_inter_token_ms",
            "Gap between consecutive generated tokens, milliseconds.",
            &engine.inter_token,
        ),
        (
            "pallas_queue_wait_ms",
            "Front-end enqueue to engine admission, milliseconds.",
            &engine.queue_wait,
        ),
    ] {
        append_histogram(&mut out, name, help, h);
    }
    if let Some(gw) = gateway {
        let Value::Obj(fields) = gw.to_json() else {
            unreachable!("GatewayStats::to_json() is an object");
        };
        for (key, val) in &fields {
            let Value::Num(v) = val else { continue };
            let name = format!("pallas_gateway_{key}_total");
            series(
                &mut out,
                &name,
                "counter",
                &format!("Gateway admission counter `{key}`."),
                &format!("{name} {}\n", fmt_num(*v)),
            );
        }
    }
    out
}

/// Append the per-tenant `tenant`-labelled admission series
/// ([`TenantCounters`]) to a rendered `/metrics` payload. The
/// unlabelled aggregates written by [`render_prometheus`] stay
/// byte-identical (existing scrape contracts and the CI smoke grep
/// match on them); the labelled samples follow as a trailing block and
/// always sum to those aggregates (both are incremented at the same
/// admission sites).
pub fn append_tenant_series<J>(sched: &FairScheduler<J>, out: &mut String) {
    type Get = fn(&TenantCounters) -> u64;
    let stats: [(&str, Get); 4] = [
        ("admitted", |c| c.admitted.get()),
        ("shed", |c| c.shed.get()),
        ("rate_limited", |c| c.rate_limited.get()),
        ("sse_streams", |c| c.sse_streams.get()),
    ];
    for (stat, get) in stats {
        for t in 0..sched.n_tenants() {
            let _ = writeln!(
                out,
                "pallas_gateway_{stat}_total{{tenant=\"{}\"}} {}",
                escape_label(sched.tenant_name(t)),
                get(&sched.tenant_stats[t])
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_engine_stats_field_is_exported() {
        let stats = EngineStats::default();
        stats.requests.add(7);
        stats.cache_bytes.set(4096);
        stats.occupancy.add(3, 4);
        let out = render_prometheus(&stats, None);
        let Value::Obj(fields) = stats.to_json() else { unreachable!() };
        for key in fields.keys() {
            let probe = if key == "kernels" {
                // Per-kernel series may be empty in a fresh process;
                // the aggregate kernel counters always export.
                "pallas_kernel_flops".to_string()
            } else {
                format!("pallas_{key}")
            };
            assert!(out.contains(&probe), "stats field '{key}' missing from /metrics");
        }
        assert!(out.contains("pallas_requests_total 7"));
        assert!(out.contains("# TYPE pallas_requests_total counter"));
        assert!(out.contains("pallas_cache_bytes 4096"));
        assert!(out.contains("# TYPE pallas_cache_bytes gauge"));
        assert!(out.contains("pallas_occupancy 0.75"));
        assert!(out.contains("pallas_kernel_policy{policy="));
        // Quality-tier fields: skip/route counts are counters, the
        // calibrated saturation level is a gauge.
        assert!(out.contains("# TYPE pallas_segments_skipped_total counter"));
        assert!(out.contains("# TYPE pallas_overflow_routed_total counter"));
        assert!(out.contains("# TYPE pallas_saturation gauge"));
    }

    #[test]
    fn latency_histograms_export_bucket_sum_count() {
        use std::time::Duration;
        let stats = EngineStats::default();
        stats.ttft.observe(Duration::from_micros(1500)); // bucket le="2.048"
        stats.queue_wait.observe(Duration::from_micros(100));
        stats.queue_wait.observe(Duration::from_micros(100));
        let out = render_prometheus(&stats, None);
        for name in
            ["pallas_latency_ms", "pallas_ttft_ms", "pallas_inter_token_ms", "pallas_queue_wait_ms"]
        {
            assert!(out.contains(&format!("# TYPE {name} histogram")), "{name}");
            assert!(out.contains(&format!("{name}_bucket{{le=\"+Inf\"}}")), "{name}");
            assert!(out.contains(&format!("{name}_sum")), "{name}");
            assert!(out.contains(&format!("{name}_count")), "{name}");
        }
        assert!(out.contains("pallas_ttft_ms_count 1"), "{out}");
        assert!(out.contains("pallas_ttft_ms_sum 1.5"), "{out}");
        assert!(out.contains("pallas_queue_wait_ms_count 2"));
        // Buckets are cumulative: the 1.5ms TTFT observation lands in
        // le="2.048" (2048us edge) and stays in every later bucket.
        assert!(out.contains("pallas_ttft_ms_bucket{le=\"2.048\"} 1"), "{out}");
        assert!(out.contains("pallas_ttft_ms_bucket{le=\"1.024\"} 0"), "{out}");
        assert!(out.contains("pallas_ttft_ms_bucket{le=\"+Inf\"} 1"));
    }

    #[test]
    fn gateway_counters_ride_along() {
        let stats = EngineStats::default();
        let gw = GatewayStats::default();
        gw.http_requests.add(3);
        gw.rate_limited.inc();
        let out = render_prometheus(&stats, Some(&gw));
        assert!(out.contains("pallas_gateway_http_requests_total 3"));
        assert!(out.contains("pallas_gateway_rate_limited_total 1"));
        assert!(out.contains("pallas_gateway_shed_total 0"));
        assert!(out.contains("# TYPE pallas_gateway_admitted_total counter"));
    }

    #[test]
    fn tenant_labelled_series_follow_the_aggregates() {
        use crate::gateway::TenantSpec;
        let s: FairScheduler<u32> =
            FairScheduler::new(vec![TenantSpec::parse("acme:sk-a:standard").unwrap()], 4);
        s.push(1, 1.0, 0).unwrap();
        let stats = EngineStats::default();
        let mut out = render_prometheus(&stats, Some(&s.stats));
        let agg = "pallas_gateway_admitted_total 1";
        assert!(out.contains(agg), "{out}");
        append_tenant_series(&s, &mut out);
        assert!(out.contains("pallas_gateway_admitted_total{tenant=\"acme\"} 1"), "{out}");
        assert!(out.contains("pallas_gateway_admitted_total{tenant=\"local\"} 0"), "{out}");
        assert!(out.contains("pallas_gateway_sse_streams_total{tenant=\"acme\"} 0"));
        // The aggregate line is untouched and precedes the labels.
        assert!(out.find(agg).unwrap() < out.find("tenant=\"acme\"").unwrap());
    }

    #[test]
    fn number_formatting_is_prometheus_friendly() {
        assert_eq!(fmt_num(0.0), "0");
        assert_eq!(fmt_num(42.0), "42");
        assert_eq!(fmt_num(0.75), "0.75");
        assert_eq!(escape_label("a\"b\\c"), "a\\\"b\\\\c");
    }
}
