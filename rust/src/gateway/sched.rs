//! Weighted-fair admission: per-tenant queues under a virtual-time
//! scheduler, plus token-bucket rate limiting.
//!
//! [`FairScheduler`] replaces the FIFO
//! [`RequestQueue`](crate::coordinator::RequestQueue) at the
//! `serve_queue` admission seam (it implements
//! [`JobSource`](crate::coordinator::JobSource)). Each tenant owns a
//! bounded FIFO; dequeue picks the backlogged tenant with the smallest
//! *virtual time* and advances it by `cost / weight` (self-clocked fair
//! queueing). A tenant that goes idle has its virtual time clamped up
//! to the global virtual time on its next arrival, so returning tenants
//! neither burst on stale credit nor starve on stale debt — every
//! backlogged tenant is served within a bounded number of dequeues of
//! its weighted share.
//!
//! Fairness only reorders ADMISSION. Each admitted request's event
//! stream is produced by the same wavefront machinery and stays
//! bit-exact vs. a solo run (proptest P13), exactly as FIFO admission
//! does (P7/P12).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::JobSource;
use crate::error::{Error, Result};
use crate::json::Value;
use crate::metrics::Counter;

/// SLA priority class; maps to a weighted-fair share multiplier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PriorityClass {
    /// Latency-sensitive traffic: 4x the standard share.
    Interactive,
    /// The default share.
    Standard,
    /// Throughput traffic that yields to everyone else: 1/4 share.
    Batch,
}

impl PriorityClass {
    /// The fair-share weight this class resolves to when the tenant
    /// spec doesn't carry an explicit weight.
    pub fn weight(self) -> f64 {
        match self {
            PriorityClass::Interactive => 4.0,
            PriorityClass::Standard => 1.0,
            PriorityClass::Batch => 0.25,
        }
    }
}

impl std::str::FromStr for PriorityClass {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "interactive" => Ok(PriorityClass::Interactive),
            "standard" | "" => Ok(PriorityClass::Standard),
            "batch" => Ok(PriorityClass::Batch),
            other => Err(Error::Config(format!("unknown priority class '{other}'"))),
        }
    }
}

impl std::fmt::Display for PriorityClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            PriorityClass::Interactive => "interactive",
            PriorityClass::Standard => "standard",
            PriorityClass::Batch => "batch",
        })
    }
}

/// One tenant of the gateway: API key, fair-share class, rate limit.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    pub name: String,
    /// Bearer key presented in `Authorization` / `X-Api-Key`. `None`
    /// means the tenant is open (no authentication) — only the built-in
    /// local tenant is.
    pub key: Option<String>,
    pub class: PriorityClass,
    /// Explicit fair-share weight; `0.0` derives it from `class`.
    pub weight: f64,
    /// Token-bucket refill in requests/second. `0.0` with `burst == 0`
    /// = unlimited; `0.0` with `burst > 0` = a hard total of `burst`
    /// requests (never refills — deterministic, used by tests and CI).
    pub rate: f64,
    /// Token-bucket capacity (burst size).
    pub burst: f64,
}

impl TenantSpec {
    /// An open tenant with the standard share and no rate limit.
    pub fn open(name: &str) -> Self {
        Self {
            name: name.to_string(),
            key: None,
            class: PriorityClass::Standard,
            weight: 0.0,
            rate: 0.0,
            burst: 0.0,
        }
    }

    /// Parse a CLI/config spec: `name:key:class[:rate[:burst]]`, e.g.
    /// `alice:sk-alice:interactive:5:10` (5 req/s, burst 10) or
    /// `bob:sk-bob:batch`.
    pub fn parse(spec: &str) -> Result<Self> {
        let parts: Vec<&str> = spec.split(':').collect();
        if parts.len() < 2 || parts.len() > 5 {
            return Err(Error::Config(format!(
                "tenant spec '{spec}' must be name:key:class[:rate[:burst]]"
            )));
        }
        let bad = |what: &str, v: &str| {
            Error::Config(format!("tenant spec '{spec}': bad {what} '{v}'"))
        };
        if parts[0].is_empty() || parts[1].is_empty() {
            return Err(Error::Config(format!(
                "tenant spec '{spec}' needs a non-empty name and key"
            )));
        }
        let class: PriorityClass =
            parts.get(2).copied().unwrap_or("standard").parse()?;
        let rate = match parts.get(3) {
            None => 0.0,
            Some(v) => v.parse::<f64>().map_err(|_| bad("rate", v))?,
        };
        let burst = match parts.get(4) {
            None => {
                if rate > 0.0 {
                    rate.ceil()
                } else {
                    0.0
                }
            }
            Some(v) => v.parse::<f64>().map_err(|_| bad("burst", v))?,
        };
        if rate < 0.0 || burst < 0.0 {
            return Err(Error::Config(format!(
                "tenant spec '{spec}': rate/burst must be >= 0"
            )));
        }
        Ok(Self {
            name: parts[0].to_string(),
            key: Some(parts[1].to_string()),
            class,
            weight: 0.0,
            rate,
            burst,
        })
    }

    /// Parse a list of spec strings (config file / `--tenants` CSV).
    pub fn parse_list(specs: &[String]) -> Result<Vec<Self>> {
        let parsed: Vec<Self> =
            specs.iter().map(|s| Self::parse(s)).collect::<Result<_>>()?;
        for (i, a) in parsed.iter().enumerate() {
            for b in &parsed[i + 1..] {
                if a.name == b.name {
                    return Err(Error::Config(format!("duplicate tenant '{}'", a.name)));
                }
                if a.key.is_some() && a.key == b.key {
                    return Err(Error::Config(format!(
                        "tenants '{}' and '{}' share an API key",
                        a.name, b.name
                    )));
                }
            }
        }
        Ok(parsed)
    }

    fn resolved_weight(&self) -> f64 {
        if self.weight > 0.0 {
            self.weight
        } else {
            self.class.weight()
        }
    }
}

/// Gateway-side counters (admission edge; engine work lives in
/// [`EngineStats`](crate::coordinator::EngineStats)). All monotone.
#[derive(Default)]
pub struct GatewayStats {
    /// HTTP requests accepted by the front end (any route).
    pub http_requests: Counter,
    /// SSE generation streams opened.
    pub sse_streams: Counter,
    /// Requests refused for a missing/unknown API key (HTTP 401).
    pub unauthorized: Counter,
    /// Requests refused by a tenant's token bucket (HTTP 429).
    pub rate_limited: Counter,
    /// Requests shed on a full queue (HTTP 429 / queue-full frame).
    pub shed: Counter,
    /// Requests admitted into the scheduler.
    pub admitted: Counter,
    /// Completions that returned unused decode budget to their tenant's
    /// fair-share clock ([`FairScheduler::recredit`]).
    pub recredited: Counter,
    /// In-flight requests cancelled via `POST /v1/cancel/{id}`.
    pub http_cancels: Counter,
}

impl GatewayStats {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("http_requests", Value::Num(self.http_requests.get() as f64)),
            ("sse_streams", Value::Num(self.sse_streams.get() as f64)),
            ("unauthorized", Value::Num(self.unauthorized.get() as f64)),
            ("rate_limited", Value::Num(self.rate_limited.get() as f64)),
            ("shed", Value::Num(self.shed.get() as f64)),
            ("admitted", Value::Num(self.admitted.get() as f64)),
            ("recredited", Value::Num(self.recredited.get() as f64)),
            ("http_cancels", Value::Num(self.http_cancels.get() as f64)),
        ])
    }
}

/// Per-tenant admission counters — the `tenant="<name>"` label
/// dimension on `/metrics`. Aggregates stay in [`GatewayStats`]
/// (incremented at the same sites), so the labelled series always sum
/// to the unlabelled totals.
#[derive(Default)]
pub struct TenantCounters {
    pub admitted: Counter,
    pub shed: Counter,
    pub rate_limited: Counter,
    pub sse_streams: Counter,
}

struct Tenant {
    spec: TenantSpec,
    weight: f64,
}

struct Entry<J> {
    cost: f64,
    job: J,
}

struct Sched<J> {
    queues: Vec<VecDeque<Entry<J>>>,
    /// Per-tenant virtual finish time.
    vtime: Vec<f64>,
    /// Virtual time of the last dequeue (arrival clamp for idle tenants).
    global_v: f64,
    len: usize,
    closed: bool,
    buckets: Vec<Bucket>,
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Weighted-fair, multi-tenant job scheduler (see module docs).
///
/// Tenant `0` is always the built-in open `local` tenant (the TCP line
/// protocol and an unauthenticated gateway admit through it); configured
/// tenants follow at `1..`. Each tenant's queue is bounded by `depth`,
/// so one tenant's flood sheds *its own* traffic while other tenants
/// keep admitting.
pub struct FairScheduler<J> {
    inner: Mutex<Sched<J>>,
    not_empty: Condvar,
    not_full: Condvar,
    /// Per-tenant queue bound.
    depth: usize,
    tenants: Vec<Tenant>,
    /// Admission-edge counters, shared with the HTTP front end.
    pub stats: GatewayStats,
    /// Per-tenant counters, parallel to the tenant table (lock-free —
    /// each is atomic; the metrics endpoint reads them without taking
    /// the scheduler mutex).
    pub tenant_stats: Vec<TenantCounters>,
}

/// Index of the built-in open tenant.
pub const LOCAL_TENANT: usize = 0;

impl<J> FairScheduler<J> {
    /// Build over the configured tenants (empty = local tenant only,
    /// which makes the scheduler FIFO-equivalent). `depth` bounds each
    /// tenant's queue, matching `RequestQueue::new(depth)` semantics in
    /// the single-tenant case.
    pub fn new(specs: Vec<TenantSpec>, depth: usize) -> Self {
        let now = Instant::now();
        let mut tenants = vec![Tenant { spec: TenantSpec::open("local"), weight: 1.0 }];
        tenants.extend(specs.into_iter().map(|spec| {
            let weight = spec.resolved_weight();
            Tenant { spec, weight }
        }));
        let n = tenants.len();
        let buckets = tenants
            .iter()
            .map(|t| Bucket { tokens: t.spec.burst, last: now })
            .collect();
        let tenant_stats = (0..n).map(|_| TenantCounters::default()).collect();
        Self {
            inner: Mutex::new(Sched {
                queues: (0..n).map(|_| VecDeque::new()).collect(),
                vtime: vec![0.0; n],
                global_v: 0.0,
                len: 0,
                closed: false,
                buckets,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            depth: depth.max(1),
            tenants,
            stats: GatewayStats::default(),
            tenant_stats,
        }
    }

    pub fn n_tenants(&self) -> usize {
        self.tenants.len()
    }

    pub fn tenant_name(&self, tenant: usize) -> &str {
        &self.tenants[tenant].spec.name
    }

    /// Resolve an API key to a tenant index. With no configured tenants
    /// the gateway is open: any (or no) key admits as the local tenant.
    /// With tenants configured, a missing or unknown key is refused.
    pub fn authenticate(&self, key: Option<&str>) -> Result<usize> {
        if self.tenants.len() == 1 {
            return Ok(LOCAL_TENANT);
        }
        match key {
            None => Err(Error::Request("missing API key".into())),
            Some(k) => self
                .tenants
                .iter()
                .position(|t| t.spec.key.as_deref() == Some(k))
                .ok_or_else(|| Error::Request("unknown API key".into())),
        }
    }

    /// Token-bucket check for one admission. `true` = within rate.
    /// Unlimited tenants (`rate == 0 && burst == 0`) always pass;
    /// `rate == 0 && burst > 0` is a deterministic hard cap of `burst`
    /// admissions (never refills).
    pub fn try_acquire(&self, tenant: usize) -> bool {
        let spec = &self.tenants[tenant].spec;
        if spec.rate == 0.0 && spec.burst == 0.0 {
            return true;
        }
        let mut g = self.inner.lock().unwrap();
        let b = &mut g.buckets[tenant];
        if spec.rate > 0.0 {
            let now = Instant::now();
            let dt = now.duration_since(b.last).as_secs_f64();
            b.last = now;
            b.tokens = (b.tokens + dt * spec.rate).min(spec.burst.max(1.0));
        }
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Non-blocking push for `tenant`, with `cost` in the tenant's
    /// fair-share currency (the server uses prompt + decode tokens, so
    /// a 1M-token burst debits its tenant 1M tokens of share).
    /// `Err(Request("queue full"))` when the tenant's queue is at
    /// depth — the gateway's 429 / the TCP path's queue-full frame.
    pub fn push(&self, tenant: usize, cost: f64, job: J) -> Result<()> {
        match self.push_inner(tenant, cost, job, None) {
            Ok(()) => Ok(()),
            Err((_job, e)) => Err(e),
        }
    }

    /// Bounded blocking push: wait up to `timeout` for the tenant's
    /// queue to drain below depth. On failure the job comes back to the
    /// caller with the reason (mirrors
    /// [`RequestQueue::push_timeout`](crate::coordinator::RequestQueue::push_timeout)).
    pub fn push_timeout(
        &self,
        tenant: usize,
        cost: f64,
        job: J,
        timeout: Duration,
    ) -> std::result::Result<(), (J, Error)> {
        self.push_inner(tenant, cost, job, Some(timeout))
    }

    fn push_inner(
        &self,
        tenant: usize,
        cost: f64,
        job: J,
        timeout: Option<Duration>,
    ) -> std::result::Result<(), (J, Error)> {
        let deadline = timeout.map(|t| Instant::now() + t);
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err((job, Error::Request("queue closed".into())));
            }
            if g.queues[tenant].len() < self.depth {
                let was_empty = g.queues[tenant].is_empty();
                if was_empty {
                    // Arrival clamp: an idle tenant rejoins at the
                    // current global virtual time (no stale credit, no
                    // stale debt).
                    g.vtime[tenant] = g.vtime[tenant].max(g.global_v);
                }
                g.queues[tenant].push_back(Entry { cost: cost.max(1.0), job });
                g.len += 1;
                drop(g);
                self.stats.admitted.inc();
                self.tenant_stats[tenant].admitted.inc();
                self.not_empty.notify_one();
                return Ok(());
            }
            let now = Instant::now();
            let Some(deadline) = deadline else {
                self.stats.shed.inc();
                self.tenant_stats[tenant].shed.inc();
                return Err((job, Error::Request("queue full".into())));
            };
            if now >= deadline {
                self.stats.shed.inc();
                self.tenant_stats[tenant].shed.inc();
                return Err((job, Error::Request("queue full".into())));
            }
            let (guard, _res) = self.not_full.wait_timeout(g, deadline - now).unwrap();
            g = guard; // loop re-checks closed / space / deadline
        }
    }

    /// Pick the backlogged tenant with the smallest virtual time (ties
    /// go to the lowest index — deterministic) and advance the clock.
    fn pop_locked(&self, g: &mut Sched<J>) -> Option<J> {
        let mut best: Option<usize> = None;
        for t in 0..g.queues.len() {
            if g.queues[t].is_empty() {
                continue;
            }
            if best.is_none_or(|b| g.vtime[t] < g.vtime[b]) {
                best = Some(t);
            }
        }
        let t = best?;
        let e = g.queues[t].pop_front().expect("non-empty by selection");
        g.len -= 1;
        g.global_v = g.vtime[t];
        g.vtime[t] += e.cost / self.tenants[t].weight;
        Some(e.job)
    }

    /// Return unused share to a tenant after its job completed.
    ///
    /// Admission debits the full worst-case cost (prompt + decode
    /// *budget*), but a request that stops early — EOS-free prefill,
    /// cancellation, deadline — occupies the wavefront for less than it
    /// paid. Moving the tenant's virtual clock back by the unspent cost
    /// over its weight restores the share, so a tenant of short-lived
    /// requests is not taxed at its worst case. Clamped at the global
    /// virtual time: a tenant can never bank credit below the clock
    /// (which would let it burst ahead of its fair share — the same
    /// no-stale-credit rule as the arrival clamp).
    pub fn recredit(&self, tenant: usize, excess_cost: f64) {
        if excess_cost <= 0.0 {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        let back = excess_cost / self.tenants[tenant].weight;
        g.vtime[tenant] = (g.vtime[tenant] - back).max(g.global_v);
        drop(g);
        self.stats.recredited.inc();
    }

    /// Non-blocking weighted-fair pop.
    pub fn try_pop(&self) -> Option<J> {
        let mut g = self.inner.lock().unwrap();
        let job = self.pop_locked(&mut g);
        drop(g);
        if job.is_some() {
            self.not_full.notify_all();
        }
        job
    }

    /// Blocking weighted-fair pop; `None` once closed AND drained.
    pub fn pop(&self) -> Option<J> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(job) = self.pop_locked(&mut g) {
                drop(g);
                self.not_full.notify_all();
                return Some(job);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Close: producers fail fast, the drain loop drains then stops.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().len == 0
    }
}

impl<J> JobSource<J> for FairScheduler<J> {
    fn pop_job(&self) -> Option<J> {
        self.pop()
    }
    fn try_pop_job(&self) -> Option<J> {
        self.try_pop()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, class: PriorityClass) -> TenantSpec {
        TenantSpec {
            name: name.into(),
            key: Some(format!("key-{name}")),
            class,
            weight: 0.0,
            rate: 0.0,
            burst: 0.0,
        }
    }

    #[test]
    fn parse_tenant_specs() {
        let t = TenantSpec::parse("alice:sk-a:interactive:5:10").unwrap();
        assert_eq!(t.name, "alice");
        assert_eq!(t.key.as_deref(), Some("sk-a"));
        assert_eq!(t.class, PriorityClass::Interactive);
        assert_eq!(t.rate, 5.0);
        assert_eq!(t.burst, 10.0);
        // class/rate/burst optional; rate implies a default burst.
        let t = TenantSpec::parse("bob:sk-b").unwrap();
        assert_eq!(t.class, PriorityClass::Standard);
        assert_eq!((t.rate, t.burst), (0.0, 0.0));
        let t = TenantSpec::parse("carol:sk-c:batch:2.5").unwrap();
        assert_eq!(t.burst, 3.0);
        assert!(TenantSpec::parse("nokey").is_err());
        assert!(TenantSpec::parse("x:k:warp9").is_err());
        assert!(TenantSpec::parse("x:k:standard:fast").is_err());
        // Duplicate names / shared keys are config errors.
        assert!(TenantSpec::parse_list(&["a:k1".into(), "a:k2".into()]).is_err());
        assert!(TenantSpec::parse_list(&["a:k:standard".into(), "b:k".into()]).is_err());
        assert_eq!(
            TenantSpec::parse_list(&["a:k1".into(), "b:k2:batch".into()]).unwrap().len(),
            2
        );
    }

    #[test]
    fn single_tenant_is_fifo() {
        let s: FairScheduler<u32> = FairScheduler::new(vec![], 8);
        for i in 0..6 {
            s.push(LOCAL_TENANT, 1.0, i).unwrap();
        }
        let order: Vec<u32> = std::iter::from_fn(|| s.try_pop()).collect();
        assert_eq!(order, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn backpressure_is_per_tenant() {
        let s: FairScheduler<u32> =
            FairScheduler::new(vec![spec("a", PriorityClass::Standard)], 2);
        s.push(1, 1.0, 10).unwrap();
        s.push(1, 1.0, 11).unwrap();
        let err = s.push(1, 1.0, 12).unwrap_err();
        assert!(err.to_string().contains("queue full"), "{err}");
        assert_eq!(s.stats.shed.get(), 1);
        // The flood sheds tenant a's traffic; local still admits.
        s.push(LOCAL_TENANT, 1.0, 0).unwrap();
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn weighted_share_over_a_backlog() {
        // A (weight 3) vs B (weight 1), both saturated with cost-1 jobs:
        // every prefix of the dequeue order gives A its 3/4 share within
        // a constant.
        let a = TenantSpec { weight: 3.0, ..spec("a", PriorityClass::Standard) };
        let b = spec("b", PriorityClass::Standard);
        let s: FairScheduler<(usize, u32)> = FairScheduler::new(vec![a, b], 64);
        for i in 0..40u32 {
            s.push(1, 1.0, (1, i)).unwrap();
            s.push(2, 1.0, (2, i)).unwrap();
        }
        let order: Vec<(usize, u32)> = std::iter::from_fn(|| s.try_pop()).collect();
        assert_eq!(order.len(), 80);
        let mut served_a = 0usize;
        for (n, &(tenant, _)) in order.iter().enumerate() {
            if tenant == 1 {
                served_a += 1;
            }
            let expect = (n + 1) as f64 * 0.75;
            // Both stay backlogged through the first 53 dequeues (A's 40
            // jobs last until ~n=53 at share 3/4).
            if n < 50 {
                assert!(
                    (served_a as f64 - expect).abs() <= 2.0,
                    "prefix {}: A served {served_a}, expected ~{expect:.1}",
                    n + 1
                );
            }
        }
        // Per-tenant FIFO order is preserved.
        let a_jobs: Vec<u32> =
            order.iter().filter(|(t, _)| *t == 1).map(|&(_, i)| i).collect();
        assert_eq!(a_jobs, (0..40).collect::<Vec<_>>());
    }

    #[test]
    fn returning_tenant_is_not_starved() {
        // Batch tenant floods; an interactive job arriving late must be
        // served within a couple of dequeues (arrival clamp).
        let s: FairScheduler<&'static str> = FairScheduler::new(
            vec![spec("batch", PriorityClass::Batch), spec("live", PriorityClass::Interactive)],
            128,
        );
        for _ in 0..100 {
            s.push(1, 1.0, "batch").unwrap();
        }
        for _ in 0..20 {
            s.try_pop().unwrap();
        }
        s.push(2, 1.0, "live").unwrap();
        let next = s.try_pop().unwrap();
        assert_eq!(next, "live", "interactive arrival preempts the backlog");
    }

    #[test]
    fn cost_weights_the_share() {
        // Equal weights, but A's jobs cost 10x: B gets ~10 dequeues per
        // A dequeue once both are backlogged.
        let s: FairScheduler<usize> = FairScheduler::new(
            vec![spec("a", PriorityClass::Standard), spec("b", PriorityClass::Standard)],
            64,
        );
        for i in 0..5 {
            s.push(1, 10.0, 100 + i).unwrap();
        }
        for i in 0..50 {
            s.push(2, 1.0, i).unwrap();
        }
        let order: Vec<usize> = std::iter::from_fn(|| s.try_pop()).collect();
        let first_20 = &order[..20];
        let a_in_first_20 = first_20.iter().filter(|&&j| j >= 100).count();
        assert!(a_in_first_20 <= 3, "heavy jobs took {a_in_first_20}/20 early slots");
    }

    #[test]
    fn token_bucket_hard_cap_and_refill() {
        let mut capped = spec("capped", PriorityClass::Standard);
        capped.burst = 2.0; // rate 0: never refills — deterministic cap
        let mut limited = spec("limited", PriorityClass::Standard);
        limited.rate = 200.0;
        limited.burst = 1.0;
        let s: FairScheduler<u32> = FairScheduler::new(vec![capped, limited], 8);
        assert!(s.try_acquire(1));
        assert!(s.try_acquire(1));
        assert!(!s.try_acquire(1), "hard cap of 2");
        assert!(s.try_acquire(2));
        assert!(!s.try_acquire(2), "burst 1 spent");
        std::thread::sleep(Duration::from_millis(20));
        assert!(s.try_acquire(2), "refilled at 200/s");
        // Unlimited local tenant never trips.
        for _ in 0..1000 {
            assert!(s.try_acquire(LOCAL_TENANT));
        }
    }

    #[test]
    fn authenticate_resolves_keys() {
        let open: FairScheduler<u32> = FairScheduler::new(vec![], 8);
        assert_eq!(open.authenticate(None).unwrap(), LOCAL_TENANT);
        assert_eq!(open.authenticate(Some("anything")).unwrap(), LOCAL_TENANT);

        let s: FairScheduler<u32> = FairScheduler::new(
            vec![spec("a", PriorityClass::Standard), spec("b", PriorityClass::Batch)],
            8,
        );
        assert_eq!(s.authenticate(Some("key-a")).unwrap(), 1);
        assert_eq!(s.authenticate(Some("key-b")).unwrap(), 2);
        assert!(s.authenticate(Some("nope")).is_err());
        assert!(s.authenticate(None).is_err());
        assert_eq!(s.tenant_name(0), "local");
        assert_eq!(s.tenant_name(2), "b");
    }

    #[test]
    fn recredit_returns_unspent_budget() {
        let s: FairScheduler<u32> = FairScheduler::new(
            vec![spec("a", PriorityClass::Standard), spec("b", PriorityClass::Standard)],
            64,
        );
        // a pays a 100-token decode budget up front; b pays 1.
        s.push(1, 100.0, 0).unwrap();
        s.push(2, 1.0, 1).unwrap();
        assert_eq!(s.try_pop(), Some(0));
        assert_eq!(s.try_pop(), Some(1));
        // a's request actually generated only 10 of the 100: re-credit
        // the other 90. Its clock drops from 100 to 10.
        s.recredit(1, 90.0);
        assert_eq!(s.stats.recredited.get(), 1);
        s.push(1, 1.0, 99).unwrap();
        for i in 0..20 {
            s.push(2, 1.0, 200 + i).unwrap();
        }
        let order: Vec<u32> = std::iter::from_fn(|| s.try_pop()).collect();
        let pos = order.iter().position(|&j| j == 99).unwrap();
        // Without the re-credit a would wait out ~99 of b's dequeues;
        // with it, ~9.
        assert!(pos <= 12, "re-credited tenant served at position {pos}: {order:?}");
    }

    #[test]
    fn recredit_clamps_at_the_global_clock() {
        let s: FairScheduler<u32> = FairScheduler::new(
            vec![spec("a", PriorityClass::Standard), spec("b", PriorityClass::Standard)],
            64,
        );
        s.push(1, 5.0, 0).unwrap();
        s.try_pop();
        // Returning far more than was ever spent clamps to the global
        // virtual time instead of banking credit below the clock.
        s.recredit(1, 1e9);
        for i in 0..4 {
            s.push(1, 1.0, 10 + i).unwrap();
            s.push(2, 1.0, 20 + i).unwrap();
        }
        let order: Vec<u32> = std::iter::from_fn(|| s.try_pop()).collect();
        // a is back at parity — both tenants appear in the first two
        // dequeues rather than a draining first on hoarded credit.
        assert!(order[..2].contains(&10) && order[..2].contains(&20), "{order:?}");
        // Zero/negative excess is a no-op (doesn't count a re-credit).
        s.recredit(1, 0.0);
        assert_eq!(s.stats.recredited.get(), 1);
    }

    #[test]
    fn per_tenant_counters_track_admission() {
        let s: FairScheduler<u32> =
            FairScheduler::new(vec![spec("a", PriorityClass::Standard)], 1);
        s.push(1, 1.0, 0).unwrap();
        assert!(s.push(1, 1.0, 1).is_err());
        s.push(LOCAL_TENANT, 1.0, 2).unwrap();
        assert_eq!(s.tenant_stats[1].admitted.get(), 1);
        assert_eq!(s.tenant_stats[1].shed.get(), 1);
        assert_eq!(s.tenant_stats[0].admitted.get(), 1);
        assert_eq!(s.tenant_stats[0].shed.get(), 0);
        // Per-tenant counts sum to the aggregates.
        assert_eq!(s.stats.admitted.get(), 2);
        assert_eq!(s.stats.shed.get(), 1);
    }

    #[test]
    fn close_drains_then_none() {
        let s: FairScheduler<u32> = FairScheduler::new(vec![], 8);
        s.push(0, 1.0, 1).unwrap();
        s.close();
        assert!(s.push(0, 1.0, 2).is_err());
        assert_eq!(s.pop(), Some(1));
        assert_eq!(s.pop(), None);
    }

    #[test]
    fn blocking_pop_wakes_on_push() {
        use std::sync::Arc;
        let s: Arc<FairScheduler<u32>> = Arc::new(FairScheduler::new(vec![], 8));
        let s2 = s.clone();
        let consumer = std::thread::spawn(move || s2.pop());
        std::thread::sleep(Duration::from_millis(20));
        s.push(LOCAL_TENANT, 1.0, 42).unwrap();
        assert_eq!(consumer.join().unwrap(), Some(42));
    }

    #[test]
    fn push_timeout_blocks_until_drained() {
        use std::sync::Arc;
        let s: Arc<FairScheduler<u32>> = Arc::new(FairScheduler::new(vec![], 1));
        s.push(LOCAL_TENANT, 1.0, 1).unwrap();
        let s2 = s.clone();
        let drainer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            s2.try_pop()
        });
        s.push_timeout(LOCAL_TENANT, 1.0, 2, Duration::from_secs(5)).unwrap();
        assert_eq!(drainer.join().unwrap(), Some(1));
        let (job, err) =
            s.push_timeout(LOCAL_TENANT, 1.0, 3, Duration::from_millis(30)).unwrap_err();
        assert_eq!(job, 3);
        assert!(err.to_string().contains("queue full"), "{err}");
    }
}
