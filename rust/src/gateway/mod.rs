//! Production gateway: multi-tenant admission, fairness, and
//! observability over the serving engine.
//!
//! The TCP line protocol ([`crate::server`]) gives one process
//! streaming, cancel, deadlines and save/resume — this module wraps it
//! in what a service at scale needs at the front door:
//!
//! * [`sched`] — [`FairScheduler`]: per-tenant bounded queues under a
//!   virtual-time weighted-fair scheduler with SLA
//!   [`PriorityClass`]es and token-bucket rate limits. It replaces the
//!   FIFO [`RequestQueue`](crate::coordinator::RequestQueue) at the
//!   `serve_queue` admission seam (both are
//!   [`JobSource`](crate::coordinator::JobSource)s); with no tenants
//!   configured it degenerates to exactly the old FIFO behaviour.
//! * [`http`] — a std-only HTTP/1.1 + SSE front end: `POST
//!   /v1/generate` streams the engine's event frames as SSE with
//!   `data:` payloads byte-identical to the TCP protocol's lines,
//!   authenticated by per-tenant API keys; overload is shed as clean
//!   `429`s instead of producer spin or unbounded latency.
//! * [`metrics`] — `GET /metrics` Prometheus text exposition of every
//!   [`EngineStats`](crate::coordinator::EngineStats) field plus the
//!   gateway's own admission counters ([`GatewayStats`]).
//!
//! Fairness only reorders *admission*. Each admitted request runs on
//! the same wavefront machinery and its event stream stays bit-exact
//! vs. a solo run (proptest P13 — the standing P7/P12 invariant).
//!
//! Wiring: [`Server`](crate::server::Server) owns the scheduler; pass
//! [`ServerOptions::http`](crate::server::ServerOptions) (the `serve
//! --http` flag or the `gateway` subcommand) to bind the HTTP front
//! end alongside the TCP listener, sharing one engine, one scheduler,
//! one cancel registry and one stats block.

pub mod http;
pub mod metrics;
pub mod sched;

pub use http::serve_metrics;
pub use metrics::render_prometheus;
pub use sched::{
    FairScheduler, GatewayStats, PriorityClass, TenantCounters, TenantSpec, LOCAL_TENANT,
};
