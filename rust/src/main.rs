//! `diagonal-batching` — the L3 launcher.
//!
//! ```text
//! diagonal-batching serve    [--model tiny] [--mode diagonal] [--addr HOST:PORT]
//!                            [--lanes N] [--threads N] [--synthetic SEED]
//!                            [--cache-bytes N]      # memory-state prefix cache
//!                            [--http HOST:PORT] [--tenants SPEC,SPEC]
//! diagonal-batching gateway  [serve flags]          # serve with the HTTP/SSE
//!                            gateway on (default --http 127.0.0.1:8080)
//! diagonal-batching worker   [serve flags] [--fault die_after=K|stall_after=K:MS
//!                            |drop_after=K]         # serve + shard_* range service
//! diagonal-batching shard    --workers A:P,B:P [--layer-split K] [--addr HOST:PORT]
//!                            [--synthetic SEED]     # coordinator over workers
//! diagonal-batching generate [--tokens N] [--max-new-tokens M] [--temperature T]
//!                            [--top-k K] [--seed S] [--connect HOST:PORT]
//!                            [--overflow off|select|chunked]  # quality tier
//!                            [--cancel-after K]     # stream tokens to stdout
//!                            [--save true | --resume TOKEN]       # with --connect
//!                            [--save-file P | --resume-file P]    # local engine
//! diagonal-batching ctl      --connect HOST:PORT --cmd ping|stats|shutdown|cancel|save
//!                            [--id N]               # control a running server
//! diagonal-batching run      [--model tiny] [--mode diagonal|seq|full|auto]
//!                            [--tokens N] [--backend hlo|native] [--compare true]
//! diagonal-batching bench    [--suite GLOB] [--json PATH] [--compare BASELINE]
//!                            [--max-regression 1.15] [--fast true] [--list true]
//! diagonal-batching tables   [--device a100|h100]   # regenerate paper tables
//! diagonal-batching babilong [--task qa1|qa2] [--len N] [--episodes N]
//!                            [--overflow off|select|chunked]
//! diagonal-batching info     [--model tiny]         # artifact inventory
//! ```
//!
//! Hand-rolled flag parsing (offline toolchain has no clap); every
//! subcommand accepts `--manifest PATH` (default artifacts/manifest.json),
//! `--kernel scalar|blocked` (GEMM tier), and `--precision
//! f32|f16|bf16|int8` (native-backend weight storage).

use std::collections::HashMap;
use std::process::ExitCode;

use diagonal_batching::babilong::{self, Task};
use diagonal_batching::cache::MemSnapshot;
use diagonal_batching::config::{BackendKind, ExecMode, Manifest, ModelConfig, RuntimeConfig};
use diagonal_batching::coordinator::{
    Event, GenerateRequest, InferenceEngine, SamplingParams,
};
use diagonal_batching::json::Value;
use diagonal_batching::model::{NativeBackend, Params};
use diagonal_batching::runtime::HloBackend;
use diagonal_batching::scheduler::StepBackend;
use diagonal_batching::server::{Client, Server, ServerOptions};
use diagonal_batching::shard::{CoordinatorOptions, FaultPlan, ShardCoordinator};
use diagonal_batching::simulator::{tables, DeviceSpec};
use diagonal_batching::tensor::Precision;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

/// Parse `--key value` flags after the subcommand.
fn parse_flags(args: &[String]) -> Result<HashMap<String, String>, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let k = args[i]
            .strip_prefix("--")
            .ok_or_else(|| format!("expected --flag, got '{}'", args[i]))?;
        if let Some(v) = args.get(i + 1) {
            flags.insert(k.to_string(), v.clone());
            i += 2;
        } else {
            return Err(format!("flag --{k} needs a value"));
        }
    }
    Ok(flags)
}

fn run(args: Vec<String>) -> Result<(), Box<dyn std::error::Error>> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    let mut cfg = RuntimeConfig::default();
    if let Some(path) = flags.get("config") {
        cfg = RuntimeConfig::load(path)?;
    }
    if let Some(m) = flags.get("manifest") {
        cfg.manifest = m.clone();
    }
    if let Some(m) = flags.get("model") {
        cfg.model = m.clone();
    }
    if let Some(m) = flags.get("mode") {
        cfg.mode = m.parse()?;
    }
    if let Some(b) = flags.get("backend") {
        cfg.backend = b.parse()?;
    }
    if let Some(a) = flags.get("addr") {
        cfg.addr = a.clone();
    }
    if let Some(l) = flags.get("lanes") {
        cfg.lanes = l.parse::<usize>()?.max(1);
    }
    if let Some(t) = flags.get("threads") {
        cfg.threads = t.parse::<usize>()?;
    }
    if let Some(b) = flags.get("cache-bytes") {
        cfg.cache_bytes = b.parse::<usize>()?;
    }
    if let Some(k) = flags.get("kernel") {
        cfg.kernel = k.parse()?;
    }
    if let Some(p) = flags.get("precision") {
        cfg.precision = p.parse()?;
    }
    if let Some(w) = flags.get("workers") {
        cfg.workers = w.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
    }
    if let Some(k) = flags.get("layer-split") {
        cfg.layer_split = k.parse::<usize>()?.max(1);
    }
    if let Some(h) = flags.get("http") {
        cfg.http = h.clone();
    }
    if let Some(t) = flags.get("tenants") {
        cfg.tenants =
            t.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
    }
    if let Some(o) = flags.get("overflow") {
        cfg.overflow = o.parse()?;
    }
    if let Some(p) = flags.get("trace-file") {
        cfg.trace_file = p.clone();
    }
    if let Some(l) = flags.get("log-level") {
        cfg.log_level = l.clone();
    }
    // One global switch: the tensor entry points dispatch on it and the
    // config default already honors PALLAS_KERNEL, so an explicit flag
    // or config file wins over the env var here.
    diagonal_batching::tensor::set_kernel_policy(cfg.kernel);
    // Same deal for observability: an explicit --log-level wins over
    // PALLAS_LOG, and a --trace-file turns the span ring on for the
    // whole process (flushed on the way out, below).
    if !cfg.log_level.is_empty() {
        let l = diagonal_batching::trace::log::Level::parse(&cfg.log_level)
            .ok_or_else(|| format!("unknown log level '{}'", cfg.log_level))?;
        diagonal_batching::trace::log::set_level(l);
    }
    if !cfg.trace_file.is_empty() {
        diagonal_batching::trace::enable();
    }

    let result = match cmd.as_str() {
        "serve" => cmd_serve(&cfg, &flags),
        // `gateway` is `serve` with the HTTP/SSE front end on by
        // default; an explicit --http still picks the bind address.
        "gateway" => {
            if cfg.http.is_empty() {
                cfg.http = "127.0.0.1:8080".to_string();
            }
            cmd_serve(&cfg, &flags)
        }
        "worker" => cmd_worker(&cfg, &flags),
        "shard" => cmd_shard(&cfg, &flags),
        "generate" => cmd_generate(&cfg, &flags),
        "ctl" => cmd_ctl(&flags),
        "run" => cmd_run(&cfg, &flags),
        "bench" => cmd_bench(&cfg, &flags),
        "tables" => cmd_tables(&cfg, &flags),
        "babilong" => cmd_babilong(&cfg, &flags),
        "info" => cmd_info(&cfg),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(format!("unknown command '{other}' (try: help)").into()),
    };
    if !cfg.trace_file.is_empty() {
        let n = diagonal_batching::trace::len();
        diagonal_batching::trace::write_file(&cfg.trace_file)?;
        eprintln!(
            "wrote {n} trace events to {} ({} dropped) — load in chrome://tracing or ui.perfetto.dev",
            cfg.trace_file,
            diagonal_batching::trace::dropped()
        );
    }
    result
}

fn print_usage() {
    println!(
        "diagonal-batching — Diagonal Batching for Recurrent Memory Transformers

USAGE:
  diagonal-batching <serve|gateway|worker|shard|generate|ctl|run|bench|tables|babilong|info> [--flags]

COMMON FLAGS:
  --manifest PATH   artifacts/manifest.json
  --model NAME      tiny | toy
  --mode MODE       diagonal | seq | full | auto
  --backend KIND    hlo | native
  --kernel POLICY   blocked | scalar — GEMM tier: cache-blocked SIMD
                    (default, bit-identical) or the reference loops
  --precision P     f32 | f16 | bf16 | int8 — native-backend weight
                    storage (sub-f32 trades bounded error for speed)
  --overflow P      off | select | chunked — long-context memory-overflow
                    policy applied to the requests this CLI builds
                    (generate, babilong): select gates low-value segments
                    out of the recurrent memory write, chunked reroutes
                    saturating prompts through a scored segment window;
                    servers take the policy per request as the wire
                    field \"overflow\" instead
  --trace-file PATH record request spans + the wavefront timeline and
                    write Chrome-trace JSON here on exit (load in
                    chrome://tracing or ui.perfetto.dev; tid = lane).
                    Off by default — and when off, the hot path records
                    and allocates nothing
  --log-level L     off | error | warn | info | debug | trace — JSON-lines
                    structured logs on stderr (overrides PALLAS_LOG;
                    default warn)
  --config PATH     RuntimeConfig JSON

SUBCOMMANDS:
  serve     --addr HOST:PORT                 start the TCP JSON-lines server
                                             (streaming event frames; see the
                                             server module docs for the wire
                                             protocol)
            --lanes N                        N wavefront lanes batch N concurrent
                                             requests per launch on the native
                                             backend; the current single-lane HLO
                                             artifacts execute lanes serially, so
                                             keep N=1 there (stream packing still
                                             fills ramp bubbles at N=1)
            --threads N                      run each grouped step's cells on an
                                             N-wide worker pool (native backend;
                                             0 = auto from PALLAS_THREADS / CPU
                                             count, 1 = the sequential reference
                                             path — bit-identical results either
                                             way)
            --synthetic SEED                 serve a built-in untrained synthetic
                                             model (native backend, no artifacts
                                             needed — demos and CI smoke tests)
            --cache-bytes N                  enable the memory-state prefix cache
                                             with an N-byte LRU budget: shared
                                             prompt prefixes skip their prefill
                                             (bit-exactly) and conversations can
                                             be saved/resumed; 0 = off (default)
            --http HOST:PORT                 also bind the HTTP/SSE gateway:
                                             POST /v1/generate streams SSE
                                             frames byte-identical to the TCP
                                             protocol; GET /metrics exports
                                             every engine counter as Prometheus
                                             text; 429s shed overload cleanly
            --tenants SPEC[,SPEC...]         multi-tenant admission, one spec
                                             per tenant: name:key:class[:rate
                                             [:burst]] with class interactive|
                                             standard|batch — weighted-fair
                                             scheduling with per-tenant API
                                             keys and token-bucket rate limits
  gateway   [serve flags]                    serve with the gateway on by
                                             default (--http 127.0.0.1:8080
                                             unless overridden)
  worker    [serve flags]                    a serve process that additionally
                                             hosts the shard_* layer-range
                                             service, so a coordinator can lane-
                                             or layer-shard onto it
            --fault SPEC                     deterministic fault injection for
                                             failover drills: die_after=K,
                                             stall_after=K:MS or drop_after=K
                                             (K counts protocol frames)
  shard     --workers A:P,B:P[,...]          start the sharding coordinator:
                                             clients speak the ordinary protocol
                                             to --addr, requests spread across
                                             the worker processes with snapshot
                                             failover when one dies mid-request
            --layer-split K                  contiguous layer ranges per chain
                                             (worker count must be a multiple);
                                             1 = whole requests per worker
            --synthetic SEED                 coordinate the built-in synthetic
                                             model (workers must match)
            --http HOST:PORT                 metrics-only listener over the
                                             coordinator's stats (GET /metrics,
                                             GET /healthz)
  generate  --tokens N                       synthesize an N-token prompt and
            --max-new-tokens M               stream M generated tokens to stdout
            --temperature T --top-k K        sampling (default greedy)
            --seed S
            --connect HOST:PORT              drive a running server instead of
                                             an in-process engine
            --cancel-after K                 (with --connect) cancel the request
                                             after K streamed events — exercises
                                             the mid-stream cancel path
            --save true                      (with --connect) save the finished
                                             conversation server-side; the done
                                             frame echoes a resume token
            --resume TOKEN                   (with --connect) continue a saved
                                             conversation — the prompt carries
                                             only NEW tokens, zero re-prefill
            --save-file PATH                 (local) write the final memory
                                             state to disk after generating
            --resume-file PATH               (local) resume from a state saved
                                             with --save-file
            --synthetic SEED                 local engine without artifacts
  ctl       --connect HOST:PORT              one control command against a
            --cmd ping|stats|shutdown|      running server (cancel and save
                  cancel|save|trace          take --id N; trace dumps the
                                             server's span ring as JSON)
  run       --tokens N --compare true        one forward pass (+drift check)
  bench     --suite GLOB --json PATH         the pallas-bench harness: run the
            --compare BASELINE               registered suites matching GLOB
            --max-regression 1.15            (name or tag; e.g. 'fig*', 'serve',
            --fast true                      'fig*,table*'), write the versioned
            --device a100|h100|ci
            --list true                      BENCH_*.json report, and optionally
                                             gate against a baseline report
                                             (nonzero exit on regressions)
  tables    --device a100|h100               regenerate the paper tables
  babilong  --task qa1|qa2 --len N --episodes N
  info                                       print artifact inventory"
    );
}

fn boxed_backend(
    cfg: &RuntimeConfig,
    manifest: &Manifest,
) -> Result<Box<dyn StepBackend + Send>, Box<dyn std::error::Error>> {
    Ok(match cfg.backend {
        // PJRT owns its own threading; --threads applies to native only.
        BackendKind::Hlo => {
            if cfg.precision != Precision::F32 {
                eprintln!(
                    "note: --precision {} applies to the native backend only; \
                     the HLO artifacts stay f32",
                    cfg.precision
                );
            }
            Box::new(HloBackend::load(manifest, &cfg.model)?)
        }
        BackendKind::Native => {
            let entry = manifest.model(&cfg.model)?;
            Box::new(
                NativeBackend::new(
                    entry.config.clone(),
                    Params::load(manifest, &cfg.model)?,
                )
                .with_threads(cfg.resolved_threads())
                .with_precision(cfg.precision),
            )
        }
    })
}

/// The serve/generate backends: either the manifest-driven real model
/// or the built-in synthetic one (`--synthetic SEED`, artifact-free).
fn serving_backend(
    cfg: &RuntimeConfig,
    flags: &HashMap<String, String>,
) -> Result<Box<dyn StepBackend + Send>, Box<dyn std::error::Error>> {
    if let Some(seed) = flags.get("synthetic") {
        let seed: u64 = seed.parse()?;
        let mc = ModelConfig::synthetic();
        println!(
            "synthetic model (seed {seed}): d={} L={} seg={} — untrained, artifact-free",
            mc.d_model, mc.n_layers, mc.seg
        );
        return Ok(Box::new(
            NativeBackend::new(mc.clone(), Params::random(&mc, seed))
                .with_threads(cfg.resolved_threads())
                .with_precision(cfg.precision),
        ));
    }
    let manifest = Manifest::load(&cfg.manifest)?;
    println!("loading model '{}' (backend {})...", cfg.model, cfg.backend);
    boxed_backend(cfg, &manifest)
}

fn cmd_serve(
    cfg: &RuntimeConfig,
    flags: &HashMap<String, String>,
) -> Result<(), Box<dyn std::error::Error>> {
    let backend = serving_backend(cfg, flags)?;
    let mut engine = InferenceEngine::new(backend, cfg.mode)
        .with_max_tokens(cfg.max_request_tokens)
        .with_lanes(cfg.lanes)
        .with_cache_bytes(cfg.cache_bytes);
    if cfg.mode == ExecMode::Auto {
        let cal = engine.calibrate(3)?;
        println!(
            "calibrated: grouped {:.3}ms single {:.3}ms crossover {} segments",
            cal.grouped_step_s * 1e3,
            cal.single_step_s * 1e3,
            cal.crossover_segments()
        );
    }
    let threads = match (flags.contains_key("synthetic"), cfg.backend) {
        (true, _) | (false, BackendKind::Native) => cfg.resolved_threads(),
        (false, BackendKind::Hlo) => 1,
    };
    let tenants = diagonal_batching::gateway::TenantSpec::parse_list(&cfg.tenants)?;
    let opts = ServerOptions {
        http: (!cfg.http.is_empty()).then(|| cfg.http.clone()),
        tenants,
        ..Default::default()
    };
    let server = Server::start_with(engine, &cfg.addr, cfg.queue_depth, opts)?;
    let cache = if cfg.cache_bytes == 0 {
        "off".to_string()
    } else {
        format!("{} bytes", cfg.cache_bytes)
    };
    println!(
        "serving on {} (mode {}, {} wavefront lane{}, {} worker thread{}, prefix cache {cache}) — \
         {{\"cmd\": \"shutdown\"}} or Ctrl-C to stop",
        server.addr,
        cfg.mode,
        cfg.lanes,
        if cfg.lanes == 1 { "" } else { "s" },
        threads,
        if threads == 1 { "" } else { "s" }
    );
    if let Some(http) = server.http_addr {
        println!(
            "gateway on http://{http} — POST /v1/generate (SSE), POST /v1/cancel/ID, \
             GET /metrics, GET /debug/trace, GET /healthz, POST /admin/shutdown{}",
            if cfg.tenants.is_empty() {
                " (open: no tenants configured)".to_string()
            } else {
                format!(" ({} tenants, API keys required)", cfg.tenants.len())
            }
        );
    }
    // Blocks until a protocol shutdown drains the engine, then exits
    // cleanly (the CI smoke test watchdogs this path).
    server.join();
    println!("server stopped cleanly");
    Ok(())
}

/// A shard worker: the ordinary server plus the `shard_*` layer-range
/// service, so one process can serve whole requests (lane sharding)
/// AND host layer ranges for a pipeline coordinator. `--fault` arms
/// deterministic fault injection (failover drills / CI chaos tests).
fn cmd_worker(
    cfg: &RuntimeConfig,
    flags: &HashMap<String, String>,
) -> Result<(), Box<dyn std::error::Error>> {
    let backend = serving_backend(cfg, flags)?;
    // The range service steps outside the engine's wavefront, so it
    // gets its own backend instance (same weights).
    let shard_backend = serving_backend(cfg, flags)?;
    let engine = InferenceEngine::new(backend, cfg.mode)
        .with_max_tokens(cfg.max_request_tokens)
        .with_lanes(cfg.lanes)
        .with_cache_bytes(cfg.cache_bytes);
    let fault = flags.get("fault").map(|s| FaultPlan::parse(s)).transpose()?;
    if let Some(f) = &fault {
        eprintln!("fault injection armed: {f:?}");
    }
    let server = Server::start_with(
        engine,
        &cfg.addr,
        cfg.queue_depth,
        ServerOptions { shard_backend: Some(shard_backend), fault, ..Default::default() },
    )?;
    println!(
        "shard worker on {} (mode {}) — {{\"cmd\": \"shutdown\"}} or Ctrl-C to stop",
        server.addr, cfg.mode
    );
    server.join();
    println!("worker stopped cleanly");
    Ok(())
}

/// The shard coordinator: client-facing protocol on `--addr`, work
/// spread across `--workers` (comma-separated `worker` addresses),
/// whole requests per worker or `--layer-split K` contiguous layer
/// ranges per chain. See the `shard` module docs.
fn cmd_shard(
    cfg: &RuntimeConfig,
    flags: &HashMap<String, String>,
) -> Result<(), Box<dyn std::error::Error>> {
    if cfg.workers.is_empty() {
        return Err("shard needs --workers HOST:PORT[,HOST:PORT...]".into());
    }
    let model_cfg = if flags.contains_key("synthetic") {
        ModelConfig::synthetic()
    } else {
        Manifest::load(&cfg.manifest)?.model(&cfg.model)?.config.clone()
    };
    let coord = ShardCoordinator::start(
        model_cfg,
        &cfg.workers,
        &cfg.addr,
        CoordinatorOptions { layer_split: cfg.layer_split, ..CoordinatorOptions::default() },
    )?;
    println!(
        "shard coordinator on {} — {} worker{}, layer split {} — \
         {{\"cmd\": \"shutdown\"}} or Ctrl-C to stop",
        coord.addr,
        cfg.workers.len(),
        if cfg.workers.len() == 1 { "" } else { "s" },
        cfg.layer_split
    );
    // Observability pass-through: the coordinator's stats block (shard
    // routing/failover counters included) on a metrics-only listener.
    if !cfg.http.is_empty() {
        let bound = diagonal_batching::gateway::serve_metrics(&cfg.http, coord.stats())?;
        println!("metrics on http://{bound}/metrics");
    }
    coord.join();
    println!("coordinator stopped cleanly");
    Ok(())
}

/// Stream a generation to stdout: token ids on stdout (one line at the
/// end), progress/summary on stderr. Local engine by default,
/// `--connect` drives a running server over TCP instead.
fn cmd_generate(
    cfg: &RuntimeConfig,
    flags: &HashMap<String, String>,
) -> Result<(), Box<dyn std::error::Error>> {
    let n_tokens: usize = flags.get("tokens").map(|s| s.parse()).transpose()?.unwrap_or(64);
    let max_new: usize =
        flags.get("max-new-tokens").map(|s| s.parse()).transpose()?.unwrap_or(32);
    let sampling = SamplingParams {
        temperature: flags.get("temperature").map(|s| s.parse()).transpose()?.unwrap_or(0.0),
        top_k: flags.get("top-k").map(|s| s.parse()).transpose()?.unwrap_or(0),
        seed: flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(0),
    };

    if let Some(addr) = flags.get("connect") {
        return generate_remote(addr, n_tokens, max_new, sampling, flags);
    }

    let backend = serving_backend(cfg, flags)?;
    let vocab = backend.config().vocab as u32;
    let prompt: Vec<u32> = (0..n_tokens as u32).map(|i| (i * 31 + 7) % vocab).collect();
    let mut engine =
        InferenceEngine::new(backend, cfg.mode).with_cache_bytes(cfg.cache_bytes);
    let mut req = GenerateRequest::new(1, prompt)
        .generate(max_new)
        .with_sampling(sampling)
        .with_overflow(cfg.overflow);
    // Conversation suspend/resume to disk: --resume-file seeds the
    // recurrence from a saved snapshot (the prompt is then only the NEW
    // tokens), --save-file writes the final state back out.
    if let Some(path) = flags.get("resume-file") {
        let snap = MemSnapshot::load(path)?;
        eprintln!("resuming from {path}: {} history segments stay frozen", snap.segments);
        req = req.resume_snapshot(snap);
    }
    let save_file = flags.get("save-file").cloned();
    if save_file.is_some() {
        req = req.with_save();
    }
    let mut produced = Vec::new();
    let mut final_state = None;
    engine.generate(&req, |ev| match ev {
        Event::SegmentDone { index, .. } => eprintln!("segment {index} done"),
        Event::Token { token, .. } => produced.push(token),
        Event::Done { stats } => {
            eprintln!(
                "done: {} segments ({} reused, {} skipped), {} launches, mean group {:.2}, \
                 saturation {:.2}{}, {:?}",
                stats.stats.segments,
                stats.reused_segments,
                stats.segments_skipped,
                stats.stats.launches,
                stats.stats.mean_group(),
                stats.saturation,
                if stats.overflow_routed { ", overflow-routed" } else { "" },
                stats.latency
            );
            final_state = stats.final_state.clone();
        }
        Event::Error { error } => eprintln!("error: {error}"),
        _ => {}
    })?;
    if let Some(path) = save_file {
        let snap = final_state.ok_or("no final state was captured")?;
        snap.save(&path)?;
        eprintln!("saved conversation ({} segments) to {path}", snap.segments);
    }
    println!(
        "{}",
        produced.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" ")
    );
    Ok(())
}

fn generate_remote(
    addr: &str,
    n_tokens: usize,
    max_new: usize,
    sampling: SamplingParams,
    flags: &HashMap<String, String>,
) -> Result<(), Box<dyn std::error::Error>> {
    let vocab: u32 = flags.get("vocab").map(|s| s.parse()).transpose()?.unwrap_or(64);
    let cancel_after: Option<usize> =
        flags.get("cancel-after").map(|s| s.parse()).transpose()?;
    let prompt: Vec<u32> = (0..n_tokens as u32).map(|i| (i * 31 + 7) % vocab).collect();
    // Wire id: unique enough for one CLI invocation against one server.
    let id = 1_000_000 + std::process::id() as u64;

    let mut fields = vec![
        ("id", Value::Num(id as f64)),
        ("tokens", Value::arr_u32(&prompt)),
        ("max_new_tokens", Value::Num(max_new as f64)),
    ];
    if !sampling.is_greedy() {
        fields.push(("temperature", Value::Num(sampling.temperature as f64)));
        fields.push(("top_k", Value::Num(sampling.top_k as f64)));
        fields.push(("seed", Value::Num(sampling.seed as f64)));
    }
    // Conversation suspend/resume against a running server: --save true
    // retains the final memory state under this request's wire id (the
    // done frame echoes it as resume_token), --resume TOKEN continues a
    // saved conversation with only the new tokens.
    if flags.get("save").map(|s| s.parse()).transpose()?.unwrap_or(false) {
        fields.push(("save", Value::Bool(true)));
    }
    if let Some(token) = flags.get("resume") {
        fields.push(("resume", Value::Num(token.parse::<u64>()? as f64)));
    }
    // Quality tier: ship the overflow policy as the wire field; the
    // server validates the value at parse time.
    if let Some(policy) = flags.get("overflow") {
        fields.push(("overflow", Value::Str(policy.clone())));
    }
    // Distributed tracing: a client-supplied trace id rides the wire
    // field, stitches the server's spans to ours, and is echoed on the
    // done frame.
    if let Some(t) = flags.get("trace") {
        fields.push(("trace", Value::Num(t.parse::<u64>()? as f64)));
    }

    let mut client = Client::connect(addr)?;
    // The canceller rides a second connection, like a real operator.
    let mut canceller = match cancel_after {
        Some(_) => Some(Client::connect(addr)?),
        None => None,
    };
    let mut events = 0usize;
    let mut produced = Vec::new();
    let mut cancel_sent = false;
    let result = client.request_stream(&Value::obj(fields), |frame| {
        events += 1;
        if let Some(Ok(tok)) = frame.get("token").map(Value::as_u32) {
            produced.push(tok);
        }
        if let (Some(k), Some(c), false) = (cancel_after, canceller.as_mut(), cancel_sent) {
            if events >= k {
                cancel_sent = true;
                match c.cancel(id) {
                    Ok(ok) => eprintln!("cancel sent after {events} events (active: {ok})"),
                    Err(e) => eprintln!("cancel failed: {e}"),
                }
            }
        }
    });
    println!(
        "{}",
        produced.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" ")
    );
    match result {
        Ok(done) => {
            eprintln!(
                "done: {} generated, {} prefill segments reused, latency {} ms",
                done.req("generated")?.as_u32_vec()?.len(),
                done.req("reused_segments")?.as_usize()?,
                done.req("latency_ms")?.as_f64()?
            );
            if let Some(token) = done.get("resume_token") {
                eprintln!(
                    "conversation saved — resume with: generate --connect ... --resume {}",
                    token.as_u64()?
                );
            }
            if cancel_after.is_some() {
                return Err("expected the stream to be cancelled, but it completed".into());
            }
            Ok(())
        }
        // A deliberate mid-stream cancel terminating the stream is this
        // invocation's success condition.
        Err(e) if cancel_sent && e.to_string().contains("cancelled") => {
            eprintln!("stream cancelled mid-generation after {} tokens — OK", produced.len());
            Ok(())
        }
        Err(e) => Err(e.into()),
    }
}

/// One control command against a running server.
fn cmd_ctl(flags: &HashMap<String, String>) -> Result<(), Box<dyn std::error::Error>> {
    let addr = flags.get("connect").ok_or("ctl needs --connect HOST:PORT")?;
    let cmd = flags.get("cmd").ok_or("ctl needs --cmd ping|stats|shutdown|cancel|save|trace")?;
    let mut client = Client::connect(addr)?;
    let mut fields = vec![("cmd", Value::Str(cmd.clone()))];
    if let Some(id) = flags.get("id") {
        fields.push(("id", Value::Num(id.parse::<u64>()? as f64)));
    }
    let resp = client.roundtrip(&Value::obj(fields))?;
    println!("{}", resp.to_json());
    if resp.get("error").is_some() {
        return Err(format!("server refused: {}", resp.to_json()).into());
    }
    Ok(())
}

fn cmd_run(
    cfg: &RuntimeConfig,
    flags: &HashMap<String, String>,
) -> Result<(), Box<dyn std::error::Error>> {
    let manifest = Manifest::load(&cfg.manifest)?;
    let n_tokens: usize = flags.get("tokens").map(|s| s.parse()).transpose()?.unwrap_or(256);
    let compare: bool = flags.get("compare").map(|s| s.parse()).transpose()?.unwrap_or(false);
    let entry = manifest.model(&cfg.model)?;
    let vocab = entry.config.vocab as u32;
    let tokens: Vec<u32> = (0..n_tokens as u32).map(|i| (i * 31 + 7) % vocab).collect();

    let backend = boxed_backend(cfg, &manifest)?;
    let mut engine = InferenceEngine::new(backend, cfg.mode);
    let mut req = GenerateRequest::new(1, tokens.clone());
    req.want_logits = true;
    let resp = engine.process(&req)?;
    println!(
        "mode={} segments={} launches={} mean_group={:.2} wall={:?}",
        resp.mode_used,
        resp.stats.segments,
        resp.stats.launches,
        resp.stats.mean_group(),
        resp.stats.wall
    );
    if compare {
        // Diagonal vs sequential drift — the paper's Table 2 metric.
        let mut rd = GenerateRequest::new(2, tokens.clone());
        rd.want_logits = true;
        rd.mode = Some(ExecMode::Diagonal);
        let mut rs = rd.clone();
        rs.id = 3;
        rs.mode = Some(ExecMode::Sequential);
        let d = engine.process(&rd)?;
        let s = engine.process(&rs)?;
        let dl = d.logits.unwrap();
        let sl = s.logits.unwrap();
        let mut worst = 0.0f32;
        for (a, b) in dl.iter().zip(&sl) {
            worst = worst.max(a.rel_error(b));
        }
        println!(
            "diagonal {:?} vs sequential {:?}; rel logits error {:.4}%",
            d.stats.wall,
            s.stats.wall,
            worst * 100.0
        );
    }
    Ok(())
}

/// The `pallas-bench` harness: run registered suites in-process, emit
/// the machine-readable `BENCH_*.json` report alongside the human
/// tables, and optionally gate against a baseline report.
fn cmd_bench(
    cfg: &RuntimeConfig,
    flags: &HashMap<String, String>,
) -> Result<(), Box<dyn std::error::Error>> {
    use diagonal_batching::bench::{self, BenchSettings, SuiteStatus};

    if flags.get("list").map(|s| s.parse()).transpose()?.unwrap_or(false) {
        println!("{:<24} {:<40} tags", "suite", "description");
        for s in diagonal_batching::bench::suites::all() {
            println!("{:<24} {:<40} {}", s.name, s.about, s.tags.join(","));
        }
        return Ok(());
    }

    let pattern = flags.get("suite").cloned().unwrap_or_else(|| "*".to_string());
    let settings = BenchSettings {
        manifest_path: cfg.manifest.clone(),
        device: flags.get("device").cloned().unwrap_or_else(|| "a100".to_string()),
        fast: flags.get("fast").map(|s| s.parse()).transpose()?.unwrap_or(false),
        // The serving suites need >= 2 lanes to show packing; honor an
        // explicit --lanes, default to 2 otherwise.
        lanes: if flags.contains_key("lanes") { cfg.lanes } else { 2 },
    };
    let report = bench::run_matching(&pattern, &settings);
    if report.suites.is_empty() {
        return Err(format!("no registered suite matches '{pattern}' (try --list true)").into());
    }

    println!("\n==== summary ({}, sha {}) ====", report.meta.device, report.meta.git_sha);
    for s in &report.suites {
        let extra = match s.status {
            SuiteStatus::Ok => format!("{} samples, {} metrics", s.samples.len(), s.metrics.len()),
            _ => s.detail.clone(),
        };
        println!("{:<24} {:<8} {extra}", s.name, s.status.as_str());
    }

    if let Some(path) = flags.get("json") {
        report.save(path)?;
        println!("\nwrote {path}");
    }

    if let Some(baseline_path) = flags.get("compare") {
        let max_ratio: f64 =
            flags.get("max-regression").map(|s| s.parse()).transpose()?.unwrap_or(1.15);
        let baseline = diagonal_batching::bench::BenchReport::load(baseline_path)?;
        let outcome = bench::compare(&baseline, &report, max_ratio);
        println!(
            "\ncompare vs {baseline_path} (max ratio {max_ratio}): \
             {} gated quantities, {} improved-or-equal, {} regressions",
            outcome.compared,
            outcome.improved_or_equal,
            outcome.regressions.len()
        );
        for m in &outcome.meta_mismatches {
            println!("  warning: run metadata mismatch — {m}");
        }
        for m in &outcome.missing_in_current {
            println!("  warning: baseline entry not in this run: {m}");
        }
        for r in &outcome.regressions {
            println!(
                "  REGRESSION {}/{}: {:.6} -> {:.6} ({:.1}% worse)",
                r.suite,
                r.what,
                r.baseline,
                r.current,
                (r.ratio - 1.0) * 100.0
            );
        }
        if !outcome.passed() {
            let why = if outcome.incomparable {
                format!("baseline incomparable: {}", outcome.meta_mismatches.join("; "))
            } else {
                format!(
                    "{} benchmark regression(s) beyond x{max_ratio}",
                    outcome.regressions.len()
                )
            };
            return Err(why.into());
        }
        println!("regression gate passed");
    }

    let failed: Vec<&str> = report
        .suites
        .iter()
        .filter(|s| s.status == SuiteStatus::Failed)
        .map(|s| s.name.as_str())
        .collect();
    if !failed.is_empty() {
        return Err(format!("suite invariant failures: {}", failed.join(", ")).into());
    }
    Ok(())
}

fn cmd_tables(
    cfg: &RuntimeConfig,
    flags: &HashMap<String, String>,
) -> Result<(), Box<dyn std::error::Error>> {
    let manifest = Manifest::load(&cfg.manifest)?;
    let dev = match flags.get("device").map(String::as_str) {
        Some("h100") => DeviceSpec::h100(),
        _ => DeviceSpec::a100(),
    };
    println!("device model: {}", dev.name);
    for (name, segs) in [
        ("llama-160m", vec![(1024, 128), (4096, 128)]),
        ("llama-3.2-1b", vec![(512, 128), (1024, 128), (2048, 128), (4096, 128)]),
        ("llama-3.2-3b", vec![(1024, 128), (4096, 128)]),
        ("llama-3.1-8b", vec![(1024, 128), (4096, 128)]),
    ] {
        let base = manifest.any_config(name)?;
        println!("\n### {name}");
        for (seg, mem) in segs {
            println!("Configuration: ({seg}, {mem})");
            let rows = tables::exec_time_rows(base, &dev, seg, mem, &tables::SEQ_LENS);
            let cols: Vec<(&str, Box<dyn Fn(&tables::ExecCell) -> String>)> = vec![
                ("seq len", Box::new(|r: &tables::ExecCell| r.seq_len.to_string())),
                ("llama (s)", Box::new(|r| format!("{:.3}", r.llama_s))),
                ("ARMT seq (s)", Box::new(|r| format!("{:.3}", r.armt_seq_s))),
                ("ARMT diag (s)", Box::new(|r| format!("{:.3}", r.armt_diag_s))),
                ("speedup vs ARMT", Box::new(|r| format!("x{:.2}", r.speedup_vs_armt()))),
                ("speedup vs llama", Box::new(|r| format!("x{:.2}", r.speedup_vs_llama()))),
            ];
            for (label, f) in &cols {
                print!("{label:>18}:");
                for r in &rows {
                    print!("{:>10}", f(r));
                }
                println!();
            }
        }
    }
    Ok(())
}

fn cmd_babilong(
    cfg: &RuntimeConfig,
    flags: &HashMap<String, String>,
) -> Result<(), Box<dyn std::error::Error>> {
    let manifest = Manifest::load(&cfg.manifest)?;
    let task = match flags.get("task").map(String::as_str) {
        Some("qa2") => Task::QA2,
        _ => Task::QA1,
    };
    let len: usize = flags.get("len").map(|s| s.parse()).transpose()?.unwrap_or(256);
    let episodes: usize = flags.get("episodes").map(|s| s.parse()).transpose()?.unwrap_or(8);

    let mut gen = babilong::Generator::new(manifest.babilong.clone(), 42);
    let eps = gen.batch(task, len, episodes);

    let entry = manifest.model(&cfg.model)?.clone();
    let backend = boxed_backend(cfg, &manifest)?;
    let mut engine = InferenceEngine::new(backend, cfg.mode);

    let seg = engine.config().seg;
    let mut preds = Vec::new();
    let t0 = std::time::Instant::now();
    for (i, e) in eps.iter().enumerate() {
        let mut req =
            GenerateRequest::new(i as u64, e.tokens.clone()).with_overflow(cfg.overflow);
        req.want_logits = true;
        let resp = engine.process(&req)?;
        // the answer is predicted at the query position of the last segment
        let pos_in_seg = e.query_pos % seg;
        let logits = resp.logits.as_ref().unwrap();
        let pred = logits.last().unwrap().argmax_rows()[pos_in_seg] as u32;
        preds.push(pred);
    }
    let acc = babilong::accuracy(&eps, &preds);
    println!(
        "{task} len={len} episodes={episodes} mode={} overflow={} acc={:.1}% total={:?} trained={}",
        cfg.mode,
        cfg.overflow,
        acc * 100.0,
        t0.elapsed(),
        entry.trained
    );
    if !entry.trained {
        println!("note: weights are untrained (run `make toy`); accuracy is chance-level");
    }
    Ok(())
}

fn cmd_info(cfg: &RuntimeConfig) -> Result<(), Box<dyn std::error::Error>> {
    let manifest = Manifest::load(&cfg.manifest)?;
    println!("manifest: {} (impl {})", cfg.manifest, manifest.impl_);
    let mut names: Vec<_> = manifest.models.keys().collect();
    names.sort();
    for name in names {
        let entry = &manifest.models[name];
        let c = &entry.config;
        println!(
            "\nmodel '{name}' (trained={}): d={} L={} heads={} ff={} seg={} mem={} k={}",
            entry.trained, c.d_model, c.n_layers, c.n_heads, c.d_ff, c.seg, c.mem, c.k_assoc
        );
        let mut exes: Vec<_> = entry.executables.iter().collect();
        exes.sort_by_key(|(n, _)| (*n).clone());
        for (exe, e) in exes {
            println!(
                "  {exe:<20} {:>8.1} kB  {} inputs",
                e.hlo_bytes as f64 / 1e3,
                e.inputs.len()
            );
        }
    }
    println!("\npaper configs (simulator-only): {:?}", {
        let mut v: Vec<_> = manifest.paper_configs.keys().collect();
        v.sort();
        v
    });
    Ok(())
}
