//! Tiny leveled structured logger: JSON lines to stderr, trace-id
//! correlation, no dependencies.
//!
//! One line per record: `{"ts_us": 1234, "level": "warn", "target":
//! "server", "msg": "...", "trace": 77}` (`trace` only when the record
//! is correlated with a request trace id — grep a trace id across
//! stderr and the Chrome trace to line logs up with spans).
//!
//! The level comes from `--log-level` ([`set_level`]) or the
//! `PALLAS_LOG` env var (`error|warn|info|debug|trace|off`), default
//! **warn**. The off path for a disabled level is one relaxed atomic
//! load — the [`logline!`](crate::logline) macro checks [`enabled`]
//! before formatting, so disabled records never allocate.

use std::io::Write as _;
use std::sync::atomic::{AtomicU8, Ordering};

use crate::json::Value;

/// Log severity, most severe first. `Off` disables everything.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    /// Parse a level name (case-insensitive). `None` for unknown names.
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "none" => Some(Level::Off),
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }

    fn from_u8(n: u8) -> Level {
        match n {
            0 => Level::Off,
            1 => Level::Error,
            2 => Level::Warn,
            3 => Level::Info,
            4 => Level::Debug,
            _ => Level::Trace,
        }
    }
}

/// Sentinel: level not set yet; first read resolves `PALLAS_LOG`.
const UNSET: u8 = 0xff;

static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

/// Set the level explicitly (`--log-level`; wins over `PALLAS_LOG`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Current level. First call resolves `PALLAS_LOG` (default warn) and
/// caches it; a racing first call resolves the same value, so the
/// race is benign.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        UNSET => {
            let l = std::env::var("PALLAS_LOG")
                .ok()
                .and_then(|s| Level::parse(&s))
                .unwrap_or(Level::Warn);
            LEVEL.store(l as u8, Ordering::Relaxed);
            l
        }
        n => Level::from_u8(n),
    }
}

/// Would a record at `l` be emitted? The cheap guard — call before
/// formatting the message.
#[inline]
pub fn enabled(l: Level) -> bool {
    l != Level::Off && (l as u8) <= (level() as u8)
}

/// Emit one structured record (level-gated). `trace` correlates the
/// line with a request's span trace id.
pub fn write(level: Level, target: &str, msg: &str, trace: Option<u64>) {
    if !enabled(level) {
        return;
    }
    let mut fields = vec![
        ("ts_us", Value::Num(crate::trace::now_us() as f64)),
        ("level", Value::Str(level.name().into())),
        ("target", Value::Str(target.into())),
        ("msg", Value::Str(msg.into())),
    ];
    if let Some(t) = trace {
        fields.push(("trace", Value::Num(t as f64)));
    }
    let line = Value::obj(fields).to_json();
    let stderr = std::io::stderr();
    let mut out = stderr.lock();
    let _ = writeln!(out, "{line}");
}

/// Level-gated structured log line: `logline!(Level::Warn, "server",
/// "engine loop aborted: {e}")`. Formats nothing when the level is
/// disabled.
#[macro_export]
macro_rules! logline {
    ($lvl:expr, $target:expr, $($arg:tt)+) => {
        if $crate::trace::log::enabled($lvl) {
            $crate::trace::log::write($lvl, $target, &format!($($arg)+), None);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_order() {
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("WARNING"), Some(Level::Warn));
        assert_eq!(Level::parse(" info "), Some(Level::Info));
        assert_eq!(Level::parse("off"), Some(Level::Off));
        assert_eq!(Level::parse("nope"), None);
        assert!(Level::Error < Level::Trace);
    }

    #[test]
    fn gating_follows_level() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Trace));
        set_level(Level::Off);
        assert!(!enabled(Level::Error));
        // Off is never "enabled", even at level trace.
        set_level(Level::Trace);
        assert!(!enabled(Level::Off));
        assert!(enabled(Level::Trace));
        // Restore the default so concurrent tests aren't spammed.
        set_level(Level::Warn);
    }
}
