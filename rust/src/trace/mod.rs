//! Zero-dependency tracing: request spans, wavefront timeline rows,
//! Chrome-trace export.
//!
//! Always compiled, **off by default**. The off path is one relaxed
//! atomic load and no allocation — every call site guards with
//! [`enabled`] before building span names or attrs, so serving with
//! tracing disabled is bit-identical *and* allocation-free relative to
//! a build without this module. Turning tracing on changes no output
//! bytes either: spans only record timing metadata around the same
//! computation (proven in `tests/trace_invariance.rs` against the
//! sequential oracle).
//!
//! Events land in a bounded in-memory ring ([`RING_CAPACITY`] newest
//! events; older ones are overwritten and counted in [`dropped`]).
//! Snapshots export as Chrome-trace / Perfetto JSON — an array of
//! complete events `{"name", "ph": "X", "ts", "dur", "pid", "tid",
//! "args"}` with `ts`/`dur` in microseconds — via:
//!
//! * `--trace-file PATH` (written on engine exit),
//! * `{"cmd": "trace"}` on the TCP protocol,
//! * `GET /debug/trace` on the HTTP gateway.
//!
//! `tid` is the **wavefront lane**, `pid` the worker process, so a
//! packed run renders as the paper's Fig. 3 diagonal: staggered
//! per-lane prefill spans overlapping in wall time. Per-iteration
//! `wavefront_step` rows (group size, padded cells, kernel time) land
//! on the reserved [`TID_WAVEFRONT`] track above the lanes.
//!
//! Trace **ids** stitch one request's spans across processes: the
//! gateway (or client, via the wire field `"trace"` / HTTP
//! `X-Trace-Id`) assigns an id, the engine tags every span with it,
//! and shard hops forward it verbatim. Ids are 48-bit (exact in JSON
//! f64 numbers) and process-salted so independent assigners do not
//! collide.

pub mod log;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::json::Value;

/// Bounded ring size: newest events win, overwritten ones are counted
/// in [`dropped`]. 64Ki complete events ≈ a few MB — enough for
/// thousands of requests between snapshots.
pub const RING_CAPACITY: usize = 65536;

/// Reserved `tid` for per-iteration wavefront rows (`wavefront_step`),
/// kept clear of real lane indices so the track sorts above them.
pub const TID_WAVEFRONT: u64 = 1_000_000;

/// Reserved `tid` for process-scoped control spans (admission, queue,
/// shard hand-off bookkeeping) that do not belong to one lane.
pub const TID_CONTROL: u64 = 1_000_001;

static ENABLED: AtomicBool = AtomicBool::new(false);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// One complete ("X") event on the timeline.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub name: &'static str,
    /// Microseconds since the process trace epoch ([`now_us`]).
    pub ts_us: u64,
    pub dur_us: u64,
    /// Lane index, or one of the reserved `TID_*` tracks.
    pub tid: u64,
    /// Structured attributes (`args` in the Chrome JSON). Put the
    /// trace id here under `"trace"` so Perfetto search finds it.
    pub args: Vec<(&'static str, Value)>,
}

/// Fixed-capacity overwrite ring. `next` is the slot the next event
/// lands in once the buffer is full.
struct Ring {
    buf: Vec<TraceEvent>,
    next: usize,
}

static RING: Mutex<Ring> = Mutex::new(Ring { buf: Vec::new(), next: 0 });

/// Process-wide monotonic epoch all `ts` values are relative to.
/// Initialized on first use (or at [`enable`], so spans recorded right
/// after enabling don't pay the init).
fn anchor() -> &'static Instant {
    static ANCHOR: OnceLock<Instant> = OnceLock::new();
    ANCHOR.get_or_init(Instant::now)
}

/// Is tracing on? One relaxed load — THE hot-path guard. Call sites
/// must check this before allocating span attrs.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the collector on (idempotent). Pins the trace epoch.
pub fn enable() {
    let _ = anchor();
    ENABLED.store(true, Ordering::Relaxed);
}

pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Drop every buffered event and reset the overwrite counter.
pub fn clear() {
    let mut r = RING.lock().unwrap();
    r.buf.clear();
    r.next = 0;
    DROPPED.store(0, Ordering::Relaxed);
}

/// Microseconds since the process trace epoch (monotonic).
#[inline]
pub fn now_us() -> u64 {
    anchor().elapsed().as_micros() as u64
}

/// Events overwritten since the last [`clear`] (ring overflow).
pub fn dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Buffered event count.
pub fn len() -> usize {
    RING.lock().unwrap().buf.len()
}

/// Record a complete span that started at `start_us` and ends now.
/// No-op (after one atomic load) when tracing is off — but prefer
/// guarding with [`enabled`] so `args` is never even built.
pub fn complete(name: &'static str, start_us: u64, tid: u64, args: Vec<(&'static str, Value)>) {
    if !enabled() {
        return;
    }
    let dur_us = now_us().saturating_sub(start_us);
    record(TraceEvent { name, ts_us: start_us, dur_us, tid, args });
}

/// Record a fully-specified event (explicit duration — the
/// per-iteration wavefront rows use this).
pub fn record(ev: TraceEvent) {
    if !enabled() {
        return;
    }
    let mut r = RING.lock().unwrap();
    if r.buf.len() < RING_CAPACITY {
        r.buf.push(ev);
    } else {
        let slot = r.next;
        r.buf[slot] = ev;
        r.next = (slot + 1) % RING_CAPACITY;
        DROPPED.fetch_add(1, Ordering::Relaxed);
    }
}

/// Allocate a fresh trace id: 48 bits, low 16 of the process id salted
/// into the top so gateway- and worker-assigned ids do not collide.
/// 48 bits keeps ids exact as JSON numbers (f64) on the wire.
pub fn next_trace_id() -> u64 {
    let n = NEXT_ID.fetch_add(1, Ordering::Relaxed) & 0xffff_ffff;
    (((std::process::id() as u64) & 0xffff) << 32) | n
}

/// Parse a caller-supplied trace id (the HTTP `X-Trace-Id` header):
/// decimal ids pass through (masked to 48 bits), anything else is
/// FNV-1a hashed so arbitrary correlation strings still stitch.
pub fn trace_id_from_str(s: &str) -> u64 {
    let s = s.trim();
    if let Ok(n) = s.parse::<u64>() {
        if n != 0 {
            return n & 0xffff_ffff_ffff;
        }
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    let h = h & 0xffff_ffff_ffff;
    if h == 0 {
        1
    } else {
        h
    }
}

/// Snapshot the ring as a Chrome-trace JSON value: an array of
/// complete events sorted by start time, `pid` = this process.
pub fn export_value() -> Value {
    let pid = std::process::id();
    let r = RING.lock().unwrap();
    let mut evs: Vec<TraceEvent> = r.buf.clone();
    drop(r);
    evs.sort_by_key(|e| (e.ts_us, e.tid));
    Value::Arr(
        evs.into_iter()
            .map(|e| {
                Value::obj(vec![
                    ("name", Value::Str(e.name.into())),
                    ("ph", Value::Str("X".into())),
                    ("ts", Value::Num(e.ts_us as f64)),
                    ("dur", Value::Num(e.dur_us as f64)),
                    ("pid", Value::Num(pid as f64)),
                    ("tid", Value::Num(e.tid as f64)),
                    ("args", Value::obj(e.args)),
                ])
            })
            .collect(),
    )
}

/// [`export_value`] serialized — the exact bytes `--trace-file`,
/// `{"cmd": "trace"}` and `GET /debug/trace` ship.
pub fn export_chrome() -> String {
    export_value().to_json()
}

/// Write the current snapshot to `path` (the `--trace-file` flush).
pub fn write_file(path: &str) -> std::io::Result<()> {
    std::fs::write(path, export_chrome())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_by_default_records_nothing() {
        // The global collector may have been enabled by a concurrent
        // test; force-off, record, and check nothing landed with our
        // marker name. (Events are filtered by name because the ring
        // is process-global.)
        disable();
        record(TraceEvent {
            name: "trace_test_off_marker",
            ts_us: 0,
            dur_us: 1,
            tid: 0,
            args: vec![],
        });
        let json = export_chrome();
        assert!(!json.contains("trace_test_off_marker"));
    }

    #[test]
    fn complete_events_export_chrome_schema() {
        enable();
        let start = now_us();
        complete(
            "trace_test_span",
            start,
            3,
            vec![("trace", Value::Num(42.0)), ("segment", Value::Num(1.0))],
        );
        let v = export_value();
        let arr = v.as_arr().unwrap();
        let ev = arr
            .iter()
            .find(|e| {
                e.get("name").and_then(|n| n.as_str().ok()) == Some("trace_test_span")
            })
            .expect("span recorded");
        assert_eq!(ev.req("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(ev.req("tid").unwrap().as_u64().unwrap(), 3);
        assert!(ev.req("ts").unwrap().as_u64().unwrap() >= start);
        assert_eq!(
            ev.req("args").unwrap().req("trace").unwrap().as_u64().unwrap(),
            42
        );
        // The export is valid JSON end-to-end.
        let reparsed = Value::parse(&export_chrome()).unwrap();
        assert!(reparsed.as_arr().unwrap().len() >= 1);
        disable();
    }

    #[test]
    fn trace_ids_are_48_bit_and_nonzero() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert_ne!(a, b);
        assert!(a != 0 && a < (1u64 << 48));
        assert!(b < (1u64 << 48));

        assert_eq!(trace_id_from_str("1234"), 1234);
        assert_eq!(trace_id_from_str(" 99 "), 99);
        let h = trace_id_from_str("req-abc-123");
        assert!(h != 0 && h < (1u64 << 48));
        // Deterministic and distinct from other strings.
        assert_eq!(h, trace_id_from_str("req-abc-123"));
        assert_ne!(h, trace_id_from_str("req-abc-124"));
        // id 0 / empty fall back to a nonzero hash.
        assert_ne!(trace_id_from_str("0"), 0);
        assert_ne!(trace_id_from_str(""), 0);
    }

    #[test]
    fn ring_is_bounded() {
        // Can't fill 64Ki events cheaply in a unit test without
        // swamping concurrent tests' exports; assert the invariant on
        // the counters instead: len() never exceeds capacity.
        enable();
        for _ in 0..64 {
            record(TraceEvent {
                name: "trace_test_fill",
                ts_us: now_us(),
                dur_us: 0,
                tid: TID_CONTROL,
                args: vec![],
            });
        }
        assert!(len() <= RING_CAPACITY);
        disable();
    }
}
