//! Synthetic BABILong-style long-context QA generator + scorer
//! (DESIGN.md substitution #3).
//!
//! Mirrors `python/compile/aot.py::BABILONG_SPEC` exactly, so the toy
//! model trained in python and the evaluation data generated here agree
//! on the token layout. Two tasks, shaped after BABILong QA1/QA2:
//!
//! * **QA1** (single supporting fact): facts "agent SEP place" are
//!   scattered in filler text; the query asks the *latest* place of one
//!   agent.
//! * **QA2** (two supporting facts): "agent SEP object" then
//!   "object SEP place"; the query asks where the object's holder's
//!   object ended up (resolve two hops: object -> agent -> place).
//!
//! Episodes end with `QUERY subject` and the answer is a single place
//! token predicted at the final position.

use crate::config::BabilongSpec;
use crate::tensor::Rng;

/// Which task to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    QA1,
    QA2,
}

impl std::fmt::Display for Task {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Task::QA1 => "QA1",
            Task::QA2 => "QA2",
        })
    }
}

/// One generated episode.
#[derive(Clone, Debug)]
pub struct Episode {
    pub tokens: Vec<u32>,
    /// The correct answer (a place token).
    pub answer: u32,
    /// Position of the final (query) token — predict the answer there.
    pub query_pos: usize,
    pub task: Task,
}

/// Episode generator bound to a token-layout spec.
pub struct Generator {
    spec: BabilongSpec,
    rng: Rng,
}

impl Generator {
    pub fn new(spec: BabilongSpec, seed: u64) -> Self {
        Self { spec, rng: Rng::new(seed) }
    }

    fn agent(&mut self) -> u32 {
        self.spec.agent_base + self.rng.below(self.spec.n_agents as usize) as u32
    }

    fn place(&mut self) -> u32 {
        self.spec.place_base + self.rng.below(self.spec.n_places as usize) as u32
    }

    fn object(&mut self) -> u32 {
        self.spec.object_base + self.rng.below(self.spec.n_objects as usize) as u32
    }

    fn filler(&mut self) -> u32 {
        self.spec.filler_base + self.rng.below(self.spec.n_filler as usize) as u32
    }

    /// Generate one episode of exactly `len` tokens (len >= 8).
    pub fn episode(&mut self, task: Task, len: usize) -> Episode {
        assert!(len >= 8, "episode too short");
        let s = self.spec.clone();
        let mut tokens = vec![0u32; len];
        for t in tokens.iter_mut() {
            *t = self.filler();
        }
        tokens[0] = s.bos;

        // Reserve the final two positions for "QUERY subject"; the model
        // predicts the answer at the last position.
        let body_end = len - 2;

        let (answer, query_subject) = match task {
            Task::QA1 => {
                let agent = self.agent();
                // several distractor facts about OTHER agents
                let n_facts = 3.min((body_end - 1) / 4);
                for _ in 0..n_facts {
                    let a = self.agent();
                    let p = self.place();
                    let pos = 1 + self.rng.below(body_end - 4);
                    tokens[pos] = a;
                    tokens[pos + 1] = s.sep;
                    tokens[pos + 2] = p;
                }
                // the supporting fact, placed last-wins at a random spot;
                // overwrite any distractor collisions deterministically
                let place = self.place();
                let pos = 1 + self.rng.below(body_end - 4);
                tokens[pos] = agent;
                tokens[pos + 1] = s.sep;
                tokens[pos + 2] = place;
                // ensure no LATER mention of this agent contradicts the fact
                let mut i = pos + 3;
                while i + 2 < body_end {
                    if tokens[i] == agent {
                        tokens[i] = self.filler();
                    }
                    i += 1;
                }
                (place, agent)
            }
            Task::QA2 => {
                // agent SEP object ... object SEP place; query object.
                let agent = self.agent();
                let object = self.object();
                let place = self.place();
                let first = 1 + self.rng.below((body_end - 8) / 2);
                let second = first + 3 + self.rng.below(body_end - first - 6);
                tokens[first] = agent;
                tokens[first + 1] = s.sep;
                tokens[first + 2] = object;
                tokens[second] = object;
                tokens[second + 1] = s.sep;
                tokens[second + 2] = place;
                // scrub later collisions
                let mut i = second + 3;
                while i < body_end {
                    if tokens[i] == object {
                        tokens[i] = self.filler();
                    }
                    i += 1;
                }
                (place, object)
            }
        };

        tokens[body_end] = s.query;
        tokens[body_end + 1] = query_subject;
        Episode { tokens, answer, query_pos: len - 1, task }
    }

    /// Generate a batch of episodes.
    pub fn batch(&mut self, task: Task, len: usize, n: usize) -> Vec<Episode> {
        (0..n).map(|_| self.episode(task, len)).collect()
    }
}

/// Accuracy of predicted answers: `preds[i]` is the predicted token at
/// the query position of `episodes[i]`.
pub fn accuracy(episodes: &[Episode], preds: &[u32]) -> f64 {
    assert_eq!(episodes.len(), preds.len());
    if episodes.is_empty() {
        return 0.0;
    }
    let hits = episodes.iter().zip(preds).filter(|(e, &p)| e.answer == p).count();
    hits as f64 / episodes.len() as f64
}

#[cfg(test)]
pub(crate) fn test_spec() -> BabilongSpec {
    BabilongSpec {
        pad: 0,
        bos: 1,
        query: 2,
        sep: 3,
        agent_base: 10,
        n_agents: 8,
        place_base: 24,
        n_places: 16,
        object_base: 44,
        n_objects: 8,
        filler_base: 56,
        n_filler: 40,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qa1_episode_well_formed() {
        let mut g = Generator::new(test_spec(), 1);
        let e = g.episode(Task::QA1, 64);
        assert_eq!(e.tokens.len(), 64);
        assert_eq!(e.tokens[62], 2, "QUERY marker");
        let subj = e.tokens[63];
        assert!((10..18).contains(&subj), "query subject is an agent");
        assert!((24..40).contains(&e.answer), "answer is a place");
        // the supporting fact exists: agent SEP answer somewhere
        let found = e.tokens.windows(3).any(|w| w[0] == subj && w[1] == 3 && w[2] == e.answer);
        assert!(found, "supporting fact present");
    }

    #[test]
    fn qa1_answer_is_last_fact_about_agent() {
        let mut g = Generator::new(test_spec(), 2);
        for _ in 0..50 {
            let e = g.episode(Task::QA1, 96);
            let subj = e.tokens[95];
            let mut last_place = None;
            for w in e.tokens[..94].windows(3) {
                if w[0] == subj && w[1] == 3 {
                    last_place = Some(w[2]);
                }
            }
            assert_eq!(last_place, Some(e.answer));
        }
    }

    #[test]
    fn qa2_two_hop_consistent() {
        let mut g = Generator::new(test_spec(), 3);
        for _ in 0..50 {
            let e = g.episode(Task::QA2, 96);
            let obj = e.tokens[95];
            assert!((44..52).contains(&obj), "query subject is an object");
            let mut place = None;
            for w in e.tokens[..94].windows(3) {
                if w[0] == obj && w[1] == 3 && (24..40).contains(&w[2]) {
                    place = Some(w[2]);
                }
            }
            assert_eq!(place, Some(e.answer));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = Generator::new(test_spec(), 7);
        let mut b = Generator::new(test_spec(), 7);
        assert_eq!(a.episode(Task::QA1, 64).tokens, b.episode(Task::QA1, 64).tokens);
    }

    #[test]
    fn accuracy_counts_hits() {
        let mut g = Generator::new(test_spec(), 9);
        let eps = g.batch(Task::QA1, 64, 4);
        let mut preds: Vec<u32> = eps.iter().map(|e| e.answer).collect();
        assert_eq!(accuracy(&eps, &preds), 1.0);
        preds[0] = 0;
        assert_eq!(accuracy(&eps, &preds), 0.75);
    }

    #[test]
    fn tokens_fit_toy_vocab() {
        let mut g = Generator::new(test_spec(), 11);
        for task in [Task::QA1, Task::QA2] {
            let e = g.episode(task, 128);
            assert!(e.tokens.iter().all(|&t| t < 96), "{task}");
        }
    }
}
