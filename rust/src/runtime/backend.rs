//! [`HloBackend`]: the production [`StepBackend`] that executes the AOT
//! HLO programs on the PJRT CPU client.
//!
//! Weights live as resident device buffers (uploaded once). Every step is
//! a single `execute_b` call — one "kernel launch" in the paper's
//! accounting — so the diagonal executor's launch counts are directly
//! comparable with the sequential baseline's.

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::model::Params;
use crate::runtime::convert::literal_to_tensor;
use crate::runtime::ArtifactStore;
use crate::scheduler::StepBackend;
use crate::tensor::Tensor;

/// Order of the stacked per-layer parameters in every step executable's
/// argument list (after x, A, z, mask) — must match python `PARAM_ORDER`.
const PARAM_ORDER: [&str; 13] = crate::model::params_order();

pub struct HloBackend {
    store: ArtifactStore,
    cfg: ModelConfig,
    /// Stacked [L, ...] parameter buffers for `grouped_step` (+bwd).
    grouped_params: Vec<xla::PjRtBuffer>,
    /// Per-layer [1, ...] parameter buffers for `single_step`.
    layer_params: Vec<Vec<xla::PjRtBuffer>>,
    /// (emb, mem_emb) for `embed`.
    embed_params: Vec<xla::PjRtBuffer>,
    /// (nf, w_out) for `lm_head`.
    head_params: Vec<xla::PjRtBuffer>,
    /// (emb, nf, w_out, params...) for `full_attn_*`; built lazily.
    full_attn_params: Vec<xla::PjRtBuffer>,
    /// Host copy kept for slicing / diagnostics / trainer.
    host_params: Params,
    /// Interior-mutable launch counter so execution helpers can take
    /// `&self` while args hold borrows of resident param buffers.
    step_calls: std::cell::Cell<u64>,
    /// Constant mask literal [L,1] of ones, re-used when all slots active.
    ones_mask: Tensor,
}

impl HloBackend {
    /// Load a model bundle: compile the step executables and upload all
    /// weights to the device.
    pub fn load(manifest: &crate::config::Manifest, model: &str) -> Result<Self> {
        let mut store = ArtifactStore::open(manifest, model)?;
        let cfg = store.entry().config.clone();
        cfg.validate()?;
        for exe in ["grouped_step", "single_step", "embed", "lm_head"] {
            store.executable(exe)?;
        }
        // full-attention buckets + backward compile lazily on first use
        let host_params = Params::load(manifest, model)?;

        let upload = |store: &ArtifactStore, t: &Tensor| -> Result<xla::PjRtBuffer> {
            Ok(store.client().buffer_from_host_buffer(t.data(), t.shape(), None)?)
        };

        let mut grouped_params = Vec::with_capacity(PARAM_ORDER.len());
        for name in PARAM_ORDER {
            grouped_params.push(upload(&store, host_params.stacked(name)?)?);
        }
        let mut layer_params = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let mut row = Vec::with_capacity(PARAM_ORDER.len());
            for name in PARAM_ORDER {
                let t = host_params.stacked(name)?.slice0(l, l + 1); // keep [1, ...]
                row.push(upload(&store, &t)?);
            }
            layer_params.push(row);
        }
        let embed_params = vec![
            upload(&store, host_params.global("emb")?)?,
            upload(&store, host_params.global("mem_emb")?)?,
        ];
        let head_params = vec![
            upload(&store, host_params.global("nf")?)?,
            upload(&store, host_params.global("w_out")?)?,
        ];

        let ones_mask = Tensor::full(&[cfg.n_layers, 1], 1.0);
        Ok(Self {
            store,
            cfg,
            grouped_params,
            layer_params,
            embed_params,
            head_params,
            full_attn_params: Vec::new(),
            host_params,
            step_calls: std::cell::Cell::new(0),
            ones_mask,
        })
    }

    pub fn store(&self) -> &ArtifactStore {
        &self.store
    }

    pub fn host_params(&self) -> &Params {
        &self.host_params
    }

    fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        Ok(self.store.client().buffer_from_host_buffer(t.data(), t.shape(), None)?)
    }

    fn upload_tokens(&self, tokens: &[u32]) -> Result<xla::PjRtBuffer> {
        // NOTE: must go through buffer_from_host_buffer (HostBufferSemantics
        // kImmutableOnlyDuringCall => synchronous copy). BufferFromHostLiteral
        // is asynchronous in the TFRT CPU client and the source literal
        // would be dropped before the transfer completes (use-after-free
        // manifesting as nondeterministic size-check aborts).
        let v: Vec<i32> = tokens
            .iter()
            .map(|&t| {
                i32::try_from(t).map_err(|_| Error::Request(format!("token {t} > i32::MAX")))
            })
            .collect::<Result<_>>()?;
        Ok(self.store.client().buffer_from_host_buffer(&v, &[tokens.len()], None)?)
    }

    /// Measure the cost of re-uploading every stacked parameter tensor
    /// (the §Perf counterfactual for the resident-buffer design: without
    /// residency the hot loop would pay this on EVERY step).
    pub fn param_upload_cost(&self) -> Result<std::time::Duration> {
        let t0 = std::time::Instant::now();
        let mut uploaded = Vec::with_capacity(PARAM_ORDER.len());
        for name in PARAM_ORDER {
            uploaded.push(self.upload(self.host_params.stacked(name)?)?);
        }
        std::hint::black_box(&uploaded);
        Ok(t0.elapsed())
    }

    /// Backward pass of the grouped step (training support):
    /// given primals (x, a, z, mask) and cotangents (dy, da2, dz2),
    /// returns (dx, da, dz, dparams...) in PARAM_ORDER.
    pub fn grouped_step_bwd(
        &mut self,
        x: &Tensor,
        a: &Tensor,
        z: &Tensor,
        mask: &[f32],
        dy: &Tensor,
        da2: &Tensor,
        dz2: &Tensor,
    ) -> Result<Vec<Tensor>> {
        self.store.executable("grouped_step_bwd")?;
        let mask_t = Tensor::new(&[mask.len(), 1], mask.to_vec())?;
        let xs = [
            self.upload(x)?,
            self.upload(a)?,
            self.upload(z)?,
            self.upload(&mask_t)?,
            self.upload(dy)?,
            self.upload(da2)?,
            self.upload(dz2)?,
        ];
        let mut args: Vec<&xla::PjRtBuffer> = xs.iter().collect();
        args.extend(self.grouped_params.iter());
        self.step_calls.set(self.step_calls.get() + 1);
        let exe = self.store.get("grouped_step_bwd")?;
        let result = exe.execute_b(&args)?;
        let lit = result[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        parts.iter().map(literal_to_tensor).collect()
    }

    /// Re-upload (updated) host params — trainer support after an
    /// optimizer step.
    pub fn refresh_params(&mut self, params: Params) -> Result<()> {
        self.host_params = params;
        let mut grouped = Vec::with_capacity(PARAM_ORDER.len());
        for name in PARAM_ORDER {
            grouped.push(self.upload(self.host_params.stacked(name)?)?);
        }
        self.grouped_params = grouped;
        let mut layers = Vec::with_capacity(self.cfg.n_layers);
        for l in 0..self.cfg.n_layers {
            let mut row = Vec::with_capacity(PARAM_ORDER.len());
            for name in PARAM_ORDER {
                let t = self.host_params.stacked(name)?.slice0(l, l + 1);
                row.push(self.upload(&t)?);
            }
            layers.push(row);
        }
        self.layer_params = layers;
        self.embed_params = vec![
            self.upload(self.host_params.global("emb")?)?,
            self.upload(self.host_params.global("mem_emb")?)?,
        ];
        self.head_params = vec![
            self.upload(self.host_params.global("nf")?)?,
            self.upload(self.host_params.global("w_out")?)?,
        ];
        Ok(())
    }
}

// SAFETY: `HloBackend` owns its PJRT client, executables and buffers as a
// closed object graph — the `Rc` clones of the client held by buffers and
// executables never escape this struct, and the coordinator moves the
// whole backend into exactly ONE engine thread (`Server::start`) which is
// the only thread that ever touches it afterwards. Moving the graph
// between threads is therefore sound even though `Rc`/raw PJRT pointers
// are not `Send` in general.
unsafe impl Send for HloBackend {}

impl StepBackend for HloBackend {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn grouped_step(
        &mut self,
        x: &Tensor,
        a: &Tensor,
        z: &Tensor,
        mask: &[f32],
    ) -> Result<(Tensor, Tensor, Tensor)> {
        let (l, b) = crate::scheduler::grouped_dims(&self.cfg, x, a, z, mask)?;
        if b == 1 {
            // Single lane: a rank-4 [L, 1, T, d] call is the same bytes
            // as the AOT program's [L, T, d] — the upload relabels the
            // dims without copying, and the rank-3 outputs are relabeled
            // back to the caller's rank (reshape is metadata-only).
            let (y, a2, z2) = self.grouped_step_single_lane(x, a, z, mask)?;
            return Ok((
                y.reshape(x.shape())?,
                a2.reshape(a.shape())?,
                z2.reshape(z.shape())?,
            ));
        }
        // The AOT grouped_step program is compiled for one lane, so wider
        // wavefronts execute lane-serially (B launches) and reassemble.
        // Correctness is identical; regenerating the artifacts with a
        // lane-batched program turns this into one launch again.
        let mut y = x.clone();
        let mut a2 = a.clone();
        let mut z2 = z.clone();
        for lane in 0..b {
            let lane_mask: Vec<f32> = (0..l).map(|li| mask[li * b + lane]).collect();
            if lane_mask.iter().all(|&m| m == 0.0) {
                continue; // fully idle lane: nothing to launch
            }
            let gather = |t: &Tensor| -> Result<Tensor> {
                let parts: Vec<Tensor> = (0..l).map(|li| t.index01(li, lane)).collect();
                let refs: Vec<&Tensor> = parts.iter().collect();
                Tensor::stack(&refs)
            };
            let (yl, al, zl) = self.grouped_step_single_lane(
                &gather(x)?,
                &gather(a)?,
                &gather(z)?,
                &lane_mask,
            )?;
            for li in 0..l {
                y.set_index01(li, lane, &yl.index0(li));
                a2.set_index01(li, lane, &al.index0(li));
                z2.set_index01(li, lane, &zl.index0(li));
            }
        }
        Ok((y, a2, z2))
    }

    fn single_step(
        &mut self,
        layer: usize,
        x: &Tensor,
        a: &Tensor,
        z: &Tensor,
    ) -> Result<(Tensor, Tensor, Tensor)> {
        if layer >= self.cfg.n_layers {
            return Err(Error::Missing(format!("layer {layer}")));
        }
        // single_step consumes [1, ...] shapes.
        let x1 = x.clone().reshape(&[1, self.cfg.seg_total, self.cfg.d_model])?;
        let a1 = a.clone().reshape(&[1, self.cfg.d_model, self.cfg.phi_dim])?;
        let z1 = z.clone().reshape(&[1, self.cfg.phi_dim])?;
        let mask = Tensor::full(&[1, 1], 1.0);
        let io = [self.upload(&x1)?, self.upload(&a1)?, self.upload(&z1)?, self.upload(&mask)?];
        let mut args: Vec<&xla::PjRtBuffer> = io.iter().collect();
        args.extend(self.layer_params[layer].iter());
        let mut out = {
            self.step_calls.set(self.step_calls.get() + 1);
            let exe = self.store.get("single_step")?;
            let result = exe.execute_b(&args)?;
            let lit = result[0][0].to_literal_sync()?;
            lit.to_tuple()?
                .iter()
                .map(literal_to_tensor)
                .collect::<Result<Vec<Tensor>>>()?
        };
        let z2 = out.pop().unwrap().reshape(&[self.cfg.phi_dim])?;
        let a2 = out.pop().unwrap().reshape(&[self.cfg.d_model, self.cfg.phi_dim])?;
        let y = out.pop().unwrap().reshape(&[self.cfg.seg_total, self.cfg.d_model])?;
        Ok((y, a2, z2))
    }

    fn embed(&mut self, tokens: &[u32]) -> Result<Tensor> {
        if tokens.len() != self.cfg.seg {
            return Err(Error::Shape {
                what: "hlo embed tokens",
                expected: vec![self.cfg.seg],
                got: vec![tokens.len()],
            });
        }
        let tok = self.upload_tokens(tokens)?;
        let args: Vec<&xla::PjRtBuffer> =
            std::iter::once(&tok).chain(self.embed_params.iter()).collect();
        let mut out = self.call_raw("embed", &args)?;
        out.pop().ok_or_else(|| Error::Xla("embed returned no output".into()))
    }

    fn lm_head(&mut self, y: &Tensor) -> Result<Tensor> {
        let yb = self.upload(y)?;
        let args: Vec<&xla::PjRtBuffer> =
            std::iter::once(&yb).chain(self.head_params.iter()).collect();
        let mut out = self.call_raw("lm_head", &args)?;
        out.pop().ok_or_else(|| Error::Xla("lm_head returned no output".into()))
    }

    fn full_attn(&mut self, tokens: &[u32]) -> Result<Tensor> {
        let n = tokens.len();
        let bucket = self
            .store
            .attn_bucket_for(n)
            .ok_or_else(|| Error::Config("model has no full-attention buckets".into()))?;
        if n > bucket {
            return Err(Error::Request(format!(
                "sequence {n} exceeds largest full-attention bucket {bucket}"
            )));
        }
        let exe_name = format!("full_attn_{bucket}");
        self.store.executable(&exe_name)?;
        if self.full_attn_params.is_empty() {
            self.full_attn_params = {
                let mut v = vec![
                    self.upload(self.host_params.global("emb")?)?,
                    self.upload(self.host_params.global("nf")?)?,
                    self.upload(self.host_params.global("w_out")?)?,
                ];
                // the baseline has no associative memory: its AOT
                // signature excludes aq/ak/av/ab (they would be dead
                // parameters XLA strips during conversion)
                for name in PARAM_ORDER {
                    if !matches!(name, "aq" | "ak" | "av" | "ab") {
                        v.push(self.upload(self.host_params.stacked(name)?)?);
                    }
                }
                v
            };
        }
        let mut padded = tokens.to_vec();
        padded.resize(bucket, 0);
        let tok = self.upload_tokens(&padded)?;
        let args: Vec<&xla::PjRtBuffer> =
            std::iter::once(&tok).chain(self.full_attn_params.iter()).collect();
        let mut out = self.call_raw(&exe_name, &args)?;
        let logits = out.pop().ok_or_else(|| Error::Xla("full_attn empty".into()))?;
        Ok(logits.slice0(0, n))
    }

    fn step_calls(&self) -> u64 {
        self.step_calls.get()
    }
}

impl HloBackend {
    /// Upload a tensor under explicit dims (same element count) — lets
    /// a rank-4 `[L, 1, T, d]` slot tensor feed the rank-3 AOT argument
    /// without a host-side copy.
    fn upload_as(&self, t: &Tensor, dims: &[usize]) -> Result<xla::PjRtBuffer> {
        if t.len() != dims.iter().product::<usize>() {
            return Err(Error::Shape {
                what: "upload_as dims",
                expected: dims.to_vec(),
                got: t.shape().to_vec(),
            });
        }
        Ok(self.store.client().buffer_from_host_buffer(t.data(), dims, None)?)
    }

    /// One launch of the AOT `grouped_step` program at its compiled
    /// single-lane shapes: `x [L, T, d]`, `a [L, d, p]`, `z [L, p]`,
    /// `mask [L]`. Inputs may carry a unit lane dim (`[L, 1, ...]`);
    /// outputs are always canonical rank-3.
    fn grouped_step_single_lane(
        &mut self,
        x: &Tensor,
        a: &Tensor,
        z: &Tensor,
        mask: &[f32],
    ) -> Result<(Tensor, Tensor, Tensor)> {
        let l = self.cfg.n_layers;
        let all_active = mask.iter().all(|&m| m == 1.0);
        let mask_t = if all_active {
            self.ones_mask.clone()
        } else {
            Tensor::new(&[l, 1], mask.to_vec())?
        };
        let io = [
            self.upload_as(x, &[l, self.cfg.seg_total, self.cfg.d_model])?,
            self.upload_as(a, &[l, self.cfg.d_model, self.cfg.phi_dim])?,
            self.upload_as(z, &[l, self.cfg.phi_dim])?,
            self.upload(&mask_t)?,
        ];
        let mut args: Vec<&xla::PjRtBuffer> = io.iter().collect();
        args.extend(self.grouped_params.iter());
        let mut out = {
            self.step_calls.set(self.step_calls.get() + 1);
            let exe = self.store.get("grouped_step")?;
            let result = exe.execute_b(&args)?;
            let lit = result[0][0].to_literal_sync()?;
            lit.to_tuple()?
                .iter()
                .map(literal_to_tensor)
                .collect::<Result<Vec<Tensor>>>()?
        };
        if out.len() != 3 {
            return Err(Error::Xla(format!("grouped_step returned {} outputs", out.len())));
        }
        let z2 = out.pop().unwrap();
        let a2 = out.pop().unwrap();
        let y = out.pop().unwrap();
        Ok((y, a2, z2))
    }

    /// Shared execute/untuple path for the non-step executables
    /// (embed / lm_head / full_attn). Does NOT bump `step_calls`: that
    /// counter means *cell-step launches* so its arithmetic matches the
    /// paper's Fig. 3 (S*L sequential vs S+L-1 diagonal) and the native
    /// backend's accounting.
    fn call_raw(&self, exe: &str, args: &[&xla::PjRtBuffer]) -> Result<Vec<Tensor>> {
        let exe = self.store.get(exe)?;
        let result = exe.execute_b(args)?;
        let lit = result[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        parts.iter().map(literal_to_tensor).collect()
    }
}
