//! Tensor <-> xla::Literal conversions.

use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// f32 [`Tensor`] -> [`xla::Literal`] of the same shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(t.data());
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// f32 [`xla::Literal`] -> [`Tensor`] (shape read from the literal).
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Tensor::new(&dims, data)
}

/// Token ids -> i32 literal of shape [n].
pub fn tokens_to_literal(tokens: &[u32]) -> Result<xla::Literal> {
    let v: Vec<i32> = tokens
        .iter()
        .map(|&t| {
            i32::try_from(t).map_err(|_| Error::Request(format!("token {t} > i32::MAX")))
        })
        .collect::<Result<_>>()?;
    Ok(xla::Literal::vec1(&v))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn tensor_literal_roundtrip() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(&[3, 4, 5], 1.0, &mut rng);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn tokens_literal_shape() {
        let lit = tokens_to_literal(&[1, 2, 3]).unwrap();
        assert_eq!(lit.element_count(), 3);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
    }
}
