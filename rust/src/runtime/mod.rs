//! PJRT runtime: loads the AOT HLO-text artifacts and executes them.
//!
//! This is the only module that touches the `xla` crate. The interchange
//! contract (HLO *text*, tuple outputs, f32/i32 dtypes) is documented in
//! DESIGN.md "Artifacts contract" and matches what `python/compile/aot.py`
//! emits.
//!
//! Performance notes (see EXPERIMENTS.md §Perf):
//! * all parameter tensors are uploaded to device buffers ONCE at load
//!   time and every step runs via `execute_b` (buffer args), so the hot
//!   loop never re-uploads weights;
//! * activations/state round-trip through the host between steps — the
//!   structural cost of the current `xla` crate's tuple outputs; the
//!   per-step overhead is measured by `benches/hotpath.rs`.

mod artifacts;
mod backend;
mod convert;

pub use artifacts::ArtifactStore;
pub use backend::HloBackend;
pub use convert::{literal_to_tensor, tensor_to_literal, tokens_to_literal};
