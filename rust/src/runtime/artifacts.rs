//! Artifact store: one PJRT client + the compiled executables of a model
//! bundle, with lazy compilation and caching.

use std::collections::HashMap;

use crate::config::{ExeEntry, Manifest, ModelEntry};
use crate::error::{Error, Result};

/// Owns the PJRT client and the compiled executables of one model.
pub struct ArtifactStore {
    client: xla::PjRtClient,
    entry: ModelEntry,
    root: std::path::PathBuf,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl ArtifactStore {
    /// Create a CPU PJRT client and bind it to a manifest model entry.
    /// Nothing is compiled yet; executables compile on first use (or all
    /// at once via [`compile_all`]).
    pub fn open(manifest: &Manifest, model: &str) -> Result<Self> {
        let entry = manifest.model(model)?.clone();
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            root: manifest.root.join(&entry.dir),
            entry,
            compiled: HashMap::new(),
        })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    /// The manifest metadata of one executable.
    pub fn exe_entry(&self, name: &str) -> Result<&ExeEntry> {
        self.entry
            .executables
            .get(name)
            .ok_or_else(|| Error::Missing(format!("executable '{name}'")))
    }

    /// Load + parse + compile one HLO program (cached).
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(name) {
            let file = self.exe_entry(name)?.file.clone();
            let path = self.root.join(&file);
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.compiled.insert(name.to_string(), exe);
        }
        Ok(&self.compiled[name])
    }

    /// Immutable lookup of an already-compiled executable (use after
    /// [`Self::compile_all`] / [`Self::executable`] so the hot path never
    /// needs `&mut self`).
    pub fn get(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.compiled
            .get(name)
            .ok_or_else(|| Error::Missing(format!("executable '{name}' not compiled")))
    }

    /// Eagerly compile every executable in the bundle (startup cost paid
    /// once, keeps the request path compile-free).
    pub fn compile_all(&mut self) -> Result<()> {
        let names: Vec<String> = self.entry.executables.keys().cloned().collect();
        for n in names {
            self.executable(&n)?;
        }
        Ok(())
    }

    /// Names of available executables (sorted, for diagnostics).
    pub fn available(&self) -> Vec<String> {
        let mut v: Vec<String> = self.entry.executables.keys().cloned().collect();
        v.sort();
        v
    }

    /// Largest full-attention bucket <= n, if any.
    pub fn attn_bucket_for(&self, n: usize) -> Option<usize> {
        self.entry
            .config
            .attn_buckets
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .or_else(|| self.entry.config.attn_buckets.iter().copied().max())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Manifest;

    fn manifest() -> Option<Manifest> {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        std::path::Path::new(path).exists().then(|| Manifest::load(path).unwrap())
    }

    #[test]
    fn open_and_compile_embed() {
        let Some(m) = manifest() else { return };
        let mut store = ArtifactStore::open(&m, "tiny").unwrap();
        assert!(store.available().contains(&"grouped_step".to_string()));
        store.executable("embed").unwrap();
        // cached second call
        store.executable("embed").unwrap();
    }

    #[test]
    fn missing_exe_is_error() {
        let Some(m) = manifest() else { return };
        let mut store = ArtifactStore::open(&m, "tiny").unwrap();
        assert!(store.executable("nope").is_err());
    }

    #[test]
    fn attn_bucket_selection() {
        let Some(m) = manifest() else { return };
        let store = ArtifactStore::open(&m, "tiny").unwrap();
        assert_eq!(store.attn_bucket_for(100), Some(128));
        assert_eq!(store.attn_bucket_for(128), Some(128));
        assert_eq!(store.attn_bucket_for(200), Some(256));
        // beyond the largest bucket, fall back to the largest
        assert_eq!(store.attn_bucket_for(4096), Some(512));
    }
}
