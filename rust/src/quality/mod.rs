//! Quality tier: memory-overflow detection, segment selection and chunked
//! fallback for long contexts.
//!
//! ARMT's associative memory is constant-size (`phi_dim` feature slots per
//! layer, see `simulator::memory::armt_state_bytes`), so past a few
//! multiples of `phi_dim` written tokens new associations interfere with
//! old ones and recall degrades — the overflow regime of Ben-Kish et al.
//! This module supplies the three production countermeasures:
//!
//! * [`MemoryMonitor`] — cheap online saturation signals at every segment
//!   boundary: token fill vs the capacity model, plus the update/state
//!   energy ratio of the associative matrices (fresh memory absorbs
//!   updates; saturated memory barely moves relative to its own norm).
//!   The calibrated `saturation ∈ [0, 1]` is surfaced in `SegmentDone`
//!   events, the done frame, `EngineStats` and `/metrics`.
//! * segment **selection** ([`plan_selection`]) — when a request opts in
//!   (`overflow: "select"`), score prompt segments by query similarity and
//!   novelty and *skip the recurrent memory write* for low scorers.
//!   Attention still sees every segment; only the `(A, z)` update is
//!   gated, so the schedule and all other arithmetic are untouched.
//! * **chunked fallback** ([`choose_window`]) — when saturation crosses
//!   [`CHUNK_THRESHOLD`] (`overflow: "chunked"`), re-route the request to
//!   a capacity-sized window of the context chosen by query similarity,
//!   answering from the best window instead of an overflowed memory.
//!
//! Everything here is pure, integer/float arithmetic over token ids and
//! scalar energies — deterministic across thread counts by construction.
//! With the policy off the engine never consults this module for control
//! flow, preserving bit-exactness (monitoring is observation-only).

use std::collections::HashSet;

use crate::config::ModelConfig;
use crate::error::{Error, Result};

/// Per-request overflow handling policy (wire field `overflow`, CLI
/// `--overflow`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum OverflowPolicy {
    /// No intervention: memory is written for every segment (bit-exact
    /// with all pre-quality-tier behavior). Saturation is still measured.
    #[default]
    Off,
    /// Score prompt segments and skip the memory write for low scorers.
    Select,
    /// Route to chunked processing when (predicted or observed)
    /// saturation crosses [`CHUNK_THRESHOLD`].
    Chunked,
}

impl OverflowPolicy {
    /// Parse the wire/CLI spelling. Empty string means `Off`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "" | "off" => Ok(OverflowPolicy::Off),
            "select" => Ok(OverflowPolicy::Select),
            "chunked" => Ok(OverflowPolicy::Chunked),
            other => Err(Error::Config(format!(
                "unknown overflow policy {other:?} (expected off|select|chunked)"
            ))),
        }
    }

    /// The wire/CLI spelling (inverse of [`OverflowPolicy::parse`]).
    pub fn as_str(&self) -> &'static str {
        match self {
            OverflowPolicy::Off => "off",
            OverflowPolicy::Select => "select",
            OverflowPolicy::Chunked => "chunked",
        }
    }
}

impl std::str::FromStr for OverflowPolicy {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        OverflowPolicy::parse(s)
    }
}

impl std::fmt::Display for OverflowPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Observation-only energy signals for one exited segment, computed on
/// the engine thread in a fixed slot order (deterministic across worker
/// thread counts).
#[derive(Clone, Copy, Debug, Default)]
pub struct SegmentSignals {
    /// Sum over the request's live cells of `|‖A‖² after − ‖A‖² before|`
    /// accumulated since the previous segment exit: how much the
    /// associative matrices actually moved.
    pub update_energy: f64,
    /// Sum of `‖A‖²` over the request's live cells after the exit step:
    /// how much is already stored.
    pub state_energy: f64,
}

/// Saturation above which `overflow: "chunked"` re-routes a request to
/// windowed processing.
pub const CHUNK_THRESHOLD: f64 = 0.6;

/// Per-request saturation estimator, fed once per exited segment.
///
/// Two blended signals, each mapped into `[0, 1)`:
///
/// * **fill** — tokens written into memory vs the capacity model.  An
///   ARMT layer stores at most ~`phi_dim` roughly-orthogonal
///   associations (the DPFP feature dimension — the same quantity that
///   sizes `simulator::memory::armt_state_bytes`), so capacity is
///   `phi_dim` tokens and `fill/(1+fill)` maps unbounded fill smoothly
///   into `[0, 1)`.
/// * **energy** — `1 − update/state`: fresh memory moves as much as it
///   holds (ratio ≈ 1 → 0 saturation); saturated memory barely moves
///   relative to its own norm (ratio → 0 → saturation → 1).
#[derive(Clone, Debug)]
pub struct MemoryMonitor {
    capacity_tokens: f64,
    consumed_tokens: f64,
    update_energy: f64,
    state_energy: f64,
    segments_seen: u64,
}

impl MemoryMonitor {
    pub fn new(cfg: &ModelConfig) -> Self {
        MemoryMonitor {
            capacity_tokens: cfg.phi_dim.max(1) as f64,
            consumed_tokens: 0.0,
            update_energy: 0.0,
            state_energy: 0.0,
            segments_seen: 0,
        }
    }

    /// Record one segment boundary: `tokens` entered memory (0 for a
    /// gated segment), with optional energy signals from the session.
    pub fn observe(&mut self, tokens: usize, signals: Option<&SegmentSignals>) {
        self.consumed_tokens += tokens as f64;
        if let Some(s) = signals {
            self.update_energy = s.update_energy;
            self.state_energy = s.state_energy;
        }
        self.segments_seen += 1;
    }

    pub fn segments_seen(&self) -> u64 {
        self.segments_seen
    }

    /// Calibrated saturation in `[0, 1]`. Strictly positive once at
    /// least one segment has been written (fill is already nonzero).
    pub fn saturation(&self) -> f64 {
        if self.segments_seen == 0 {
            return 0.0;
        }
        let fill = self.consumed_tokens / self.capacity_tokens;
        let s_fill = fill / (1.0 + fill);
        let s_energy = if self.state_energy > 0.0 {
            (1.0 - (self.update_energy / self.state_energy).clamp(0.0, 1.0)).clamp(0.0, 1.0)
        } else {
            0.0
        };
        (0.6 * s_fill + 0.4 * s_energy).clamp(0.0, 1.0)
    }
}

/// Predicted saturation of an `n_tokens`-token prompt, used at
/// admission time before any segment has run. Only the fill signal is
/// available up front; late in prefill the energy ratio of a memory
/// filled this far tracks the fill curve, so the predictor assumes
/// `s_energy ≈ s_fill` — both blend weights collapse and the
/// prediction is the fill curve itself. Crossing [`CHUNK_THRESHOLD`]
/// therefore means the prompt exceeds `1.5 × phi_dim` tokens.
pub fn predicted_saturation(cfg: &ModelConfig, n_tokens: usize) -> f64 {
    let fill = n_tokens as f64 / cfg.phi_dim.max(1) as f64;
    fill / (1.0 + fill)
}

/// Score prompt segments for memory admission. The final segment is the
/// query carrier (BABILong places the question last; chat places the
/// newest turn last) and is the reference:
///
/// * **similarity** — fraction of the segment's distinct tokens that
///   also appear in the query segment;
/// * **novelty** — fraction of the segment's distinct tokens not seen
///   in any earlier segment (repeated filler scores low).
///
/// Returns one score per segment; the final segment always scores
/// `f64::INFINITY` (it is never a skip candidate).
pub fn score_segments(segments: &[Vec<u32>]) -> Vec<f64> {
    if segments.is_empty() {
        return Vec::new();
    }
    let query: HashSet<u32> = segments[segments.len() - 1].iter().copied().collect();
    let mut seen: HashSet<u32> = HashSet::new();
    let mut scores = Vec::with_capacity(segments.len());
    for (i, seg) in segments.iter().enumerate() {
        if i == segments.len() - 1 {
            scores.push(f64::INFINITY);
            break;
        }
        let distinct: HashSet<u32> = seg.iter().copied().collect();
        let n = distinct.len().max(1) as f64;
        let sim = distinct.iter().filter(|t| query.contains(t)).count() as f64 / n;
        let novel = distinct.iter().filter(|t| !seen.contains(t)).count() as f64 / n;
        scores.push(2.0 * sim + 0.25 * novel);
        seen.extend(distinct);
    }
    scores
}

/// Decide which prompt segments skip the memory write under
/// `overflow: "select"`: a segment is skipped when its score falls
/// below half the mean score of the skip candidates. Returns
/// `skip[i] == true` for gated segments; the final (query) segment and
/// single-segment prompts are never skipped.
pub fn plan_selection(segments: &[Vec<u32>]) -> Vec<bool> {
    let scores = score_segments(segments);
    let n = scores.len();
    if n <= 1 {
        return vec![false; n];
    }
    let candidates = &scores[..n - 1];
    let mean = candidates.iter().sum::<f64>() / candidates.len() as f64;
    let threshold = 0.5 * mean;
    let mut skip: Vec<bool> = candidates.iter().map(|&s| s < threshold).collect();
    skip.push(false);
    skip
}

/// Pick the best `window_segs`-segment window of the pre-query context
/// for chunked fallback: the window whose distinct tokens overlap the
/// query segment the most (ties broken toward the earliest window, so
/// the choice is deterministic). Returns the `[start, end)` segment
/// range; the query segment (`segments.len() - 1`) is excluded from the
/// window and must be re-appended by the caller.
pub fn choose_window(segments: &[Vec<u32>], window_segs: usize) -> (usize, usize) {
    let n_ctx = segments.len().saturating_sub(1);
    let w = window_segs.clamp(1, n_ctx.max(1));
    if n_ctx <= w {
        return (0, n_ctx);
    }
    let query: HashSet<u32> = segments[segments.len() - 1].iter().copied().collect();
    let seg_score: Vec<usize> = segments[..n_ctx]
        .iter()
        .map(|seg| {
            let distinct: HashSet<u32> = seg.iter().copied().collect();
            distinct.iter().filter(|t| query.contains(t)).count()
        })
        .collect();
    let mut best = (0usize, 0usize);
    let mut best_score = usize::MAX; // sentinel: replaced on first window
    for start in 0..=n_ctx - w {
        let s: usize = seg_score[start..start + w].iter().sum();
        if best_score == usize::MAX || s > best_score {
            best = (start, start + w);
            best_score = s;
        }
    }
    best
}

/// Split a flat prompt into `seg`-sized segments (ragged tail kept), the
/// same cut the scheduler makes — selection and windowing must see the
/// exact segment boundaries the wavefront will use.
pub fn segment_tokens(tokens: &[u32], seg: usize) -> Vec<Vec<u32>> {
    tokens.chunks(seg.max(1)).map(|c| c.to_vec()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        crate::model::tests::test_config()
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [OverflowPolicy::Off, OverflowPolicy::Select, OverflowPolicy::Chunked] {
            assert_eq!(OverflowPolicy::parse(p.as_str()).unwrap(), p);
        }
        assert_eq!(OverflowPolicy::parse("").unwrap(), OverflowPolicy::Off);
        assert!(OverflowPolicy::parse("sideways").is_err());
    }

    #[test]
    fn saturation_starts_at_zero_grows_monotone_and_stays_bounded() {
        let cfg = cfg();
        let mut m = MemoryMonitor::new(&cfg);
        assert_eq!(m.saturation(), 0.0);
        let mut last = 0.0;
        for _ in 0..64 {
            m.observe(cfg.seg, None);
            let s = m.saturation();
            assert!(s > 0.0 && s <= 1.0, "saturation {s} out of range");
            assert!(s >= last, "fill-only saturation must be monotone");
            last = s;
        }
        // 64 segments x 8 tokens >> phi_dim: deep in the overflow regime.
        assert!(last > 0.5, "deeply overflowed but saturation only {last}");
    }

    #[test]
    fn energy_ratio_moves_saturation() {
        let cfg = cfg();
        let mut fresh = MemoryMonitor::new(&cfg);
        fresh.observe(cfg.seg, Some(&SegmentSignals { update_energy: 5.0, state_energy: 5.0 }));
        let mut stale = MemoryMonitor::new(&cfg);
        stale.observe(cfg.seg, Some(&SegmentSignals { update_energy: 0.05, state_energy: 5.0 }));
        assert!(
            stale.saturation() > fresh.saturation(),
            "small updates against a large state must read as more saturated"
        );
    }

    #[test]
    fn predicted_matches_fill_only_observation() {
        let cfg = cfg();
        let n = 10 * cfg.seg;
        let mut m = MemoryMonitor::new(&cfg);
        for chunk in segment_tokens(&vec![0u32; n], cfg.seg) {
            m.observe(chunk.len(), None);
        }
        // Signal-free observation carries only the fill term (weight
        // 0.6); the predictor assumes the energy term tracks fill.
        assert!((m.saturation() - 0.6 * predicted_saturation(&cfg, n)).abs() < 1e-12);
        // A prompt 1.5x capacity is exactly the routing threshold.
        let at = (3 * cfg.phi_dim) / 2;
        assert!(predicted_saturation(&cfg, at + 1) > CHUNK_THRESHOLD);
        assert!(predicted_saturation(&cfg, at - 1) < CHUNK_THRESHOLD);
    }

    #[test]
    fn selection_keeps_query_and_query_relevant_segments() {
        // Segment layout: [query-overlapping fact] [junk] [junk] [query].
        let segments = vec![
            vec![10, 24, 3, 10],       // shares tokens 10, 24 with the query
            vec![60, 61, 62, 63],      // filler, novel
            vec![60, 61, 62, 63],      // filler, repeated: low novelty too
            vec![2, 10, 24],           // query segment
        ];
        let skip = plan_selection(&segments);
        assert_eq!(skip.len(), 4);
        assert!(!skip[0], "query-relevant segment must be kept");
        assert!(!skip[3], "query segment must never be skipped");
        assert!(skip[2], "repeated filler must be gated");
        let scores = score_segments(&segments);
        assert_eq!(scores[3], f64::INFINITY);
        assert!(scores[0] > scores[2]);
    }

    #[test]
    fn selection_never_skips_trivial_prompts() {
        assert_eq!(plan_selection(&[vec![1, 2, 3]]), vec![false]);
        assert!(plan_selection(&[]).is_empty());
    }

    #[test]
    fn window_choice_is_deterministic_and_query_driven() {
        let segments = vec![
            vec![50, 51, 52], // no overlap
            vec![7, 8, 9],    // full overlap with the query
            vec![7, 60, 61],  // partial
            vec![7, 8, 9],    // query segment
        ];
        assert_eq!(choose_window(&segments, 1), (1, 2));
        // Window of 2: [1,3) scores 3+1=4, beats [0,2)=3 and ties none.
        assert_eq!(choose_window(&segments, 2), (1, 3));
        // Window covering everything degenerates to the full context.
        assert_eq!(choose_window(&segments, 16), (0, 3));
        // All-equal scores: earliest window wins (tie-break).
        let flat = vec![vec![1], vec![1], vec![1], vec![9]];
        assert_eq!(choose_window(&flat, 1), (0, 1));
    }

    #[test]
    fn segmentation_matches_scheduler_cut() {
        let toks: Vec<u32> = (0..19).collect();
        let segs = segment_tokens(&toks, 8);
        assert_eq!(segs.len(), 3);
        assert_eq!(segs[2], vec![16, 17, 18]);
    }
}
