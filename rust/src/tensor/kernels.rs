//! Tiered GEMM kernel layer: policy selection, cache-blocked SIMD f32
//! row kernels, reduced-precision weight storage, and per-kernel flop
//! accounting.
//!
//! ## Oracle-vs-fast contract
//!
//! The scalar triple loops in [`super::linalg`] are the *bit-exactness
//! oracle*: every parity/proptest suite pins its expectations to those
//! accumulation orders. The blocked f32 kernels here are required to be
//! **byte-identical** to the oracle, not merely close. That works
//! because they preserve, per output element, the exact chain of
//! `mul`-then-`add` operations in ascending-`p` order:
//!
//! * the j-register tile ([`JTILE`]) partitions *output columns*; each
//!   element's partial-sum chain is untouched,
//! * the `av == 0.0` skip (or its absence, for the dot-product variant)
//!   is replicated per entry point,
//! * multiplication and addition stay separate operations — the AVX2
//!   paths enable **only** the `avx2` feature, never `fma`, and Rust
//!   never contracts `a + b * c` without explicit fma calls.
//!
//! The reduced-precision paths (f16 / bf16 / int8 weights, f32
//! activations and accumulation) are *not* byte-gated; they gate on
//! bounded relative error against the f32 oracle over real cell
//! workloads (see the `*_CELL_ERR_BUDGET` constants and
//! `tests/kernel_parity.rs`).
//!
//! ## Policy selection
//!
//! [`KernelPolicy`] picks scalar vs blocked for all f32 entry points,
//! resolved in order: [`set_kernel_policy`] (the CLI's `--kernel` flag)
//! beats the `PALLAS_KERNEL` env var beats the default (`blocked` —
//! safe, because blocked is byte-identical). An unparseable env value
//! falls back to the default silently; the CLI flag errors loudly.

use super::Tensor;
use crate::error::Error;
use std::fmt;
use std::str::FromStr;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::time::Instant;

// ---------------------------------------------------------------------
// Kernel policy
// ---------------------------------------------------------------------

/// Which f32 GEMM implementation the `matmul*` entry points run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelPolicy {
    /// The original triple loops — the bit-exactness oracle.
    Scalar,
    /// Cache-blocked, SIMD-dispatched kernels, byte-identical to
    /// [`KernelPolicy::Scalar`] by construction.
    Blocked,
}

impl FromStr for KernelPolicy {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self, Error> {
        match s {
            "scalar" => Ok(KernelPolicy::Scalar),
            "blocked" => Ok(KernelPolicy::Blocked),
            other => Err(Error::Config(format!(
                "unknown kernel policy '{other}' (expected scalar | blocked)"
            ))),
        }
    }
}

impl fmt::Display for KernelPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            KernelPolicy::Scalar => "scalar",
            KernelPolicy::Blocked => "blocked",
        })
    }
}

/// 0 = unset (resolve from env on first read), 1 = scalar, 2 = blocked.
static POLICY: AtomicU8 = AtomicU8::new(0);

/// The policy the `PALLAS_KERNEL` env var requests (default: blocked).
pub fn env_kernel_policy() -> KernelPolicy {
    match std::env::var("PALLAS_KERNEL") {
        Ok(v) => v.parse().unwrap_or(KernelPolicy::Blocked),
        Err(_) => KernelPolicy::Blocked,
    }
}

/// Process-wide kernel policy; lazily seeded from the environment.
pub fn kernel_policy() -> KernelPolicy {
    match POLICY.load(Ordering::Relaxed) {
        1 => KernelPolicy::Scalar,
        2 => KernelPolicy::Blocked,
        _ => {
            let p = env_kernel_policy();
            set_kernel_policy(p);
            p
        }
    }
}

/// Override the process-wide kernel policy (the CLI's `--kernel`).
pub fn set_kernel_policy(p: KernelPolicy) {
    let v = match p {
        KernelPolicy::Scalar => 1,
        KernelPolicy::Blocked => 2,
    };
    POLICY.store(v, Ordering::Relaxed);
}

// ---------------------------------------------------------------------
// Weight precision
// ---------------------------------------------------------------------

/// Storage format for model weights (activations and accumulation stay
/// f32 in every mode).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Exact f32 weights — byte-identical to the unprepared path.
    F32,
    /// IEEE 754 half weights, software-converted, f32 accumulate.
    F16,
    /// bfloat16 weights (truncated-exponent-preserving), f32 accumulate.
    Bf16,
    /// Per-row-scale symmetric int8 weights, f32 accumulate.
    Int8,
}

impl FromStr for Precision {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self, Error> {
        match s {
            "f32" | "fp32" => Ok(Precision::F32),
            "f16" | "fp16" => Ok(Precision::F16),
            "bf16" => Ok(Precision::Bf16),
            "int8" | "i8" | "q8" => Ok(Precision::Int8),
            other => Err(Error::Config(format!(
                "unknown precision '{other}' (expected f32 | f16 | bf16 | int8)"
            ))),
        }
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Precision::F32 => "f32",
            Precision::F16 => "f16",
            Precision::Bf16 => "bf16",
            Precision::Int8 => "int8",
        })
    }
}

/// The precision the `PALLAS_PRECISION` env var requests (default f32).
pub fn env_precision() -> Precision {
    match std::env::var("PALLAS_PRECISION") {
        Ok(v) => v.parse().unwrap_or(Precision::F32),
        Err(_) => Precision::F32,
    }
}

// ---------------------------------------------------------------------
// Per-kernel flop accounting
// ---------------------------------------------------------------------

/// The distinct kernels the accounting layer attributes work to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// `matmul` / `matmul_rows` (f32, policy-dispatched).
    MatMul,
    /// `matmul_at` (f32, policy-dispatched).
    MatMulAt,
    /// `matmul_bt` (f32, policy-dispatched).
    MatMulBt,
    /// Weight-view matmul over f16 weights.
    MatMulF16,
    /// Weight-view matmul over bf16 weights.
    MatMulBf16,
    /// Weight-view matmul over int8 per-row-scale weights.
    MatMulInt8,
}

impl KernelKind {
    /// Every kind, in counter-slot order.
    pub const ALL: [KernelKind; 6] = [
        KernelKind::MatMul,
        KernelKind::MatMulAt,
        KernelKind::MatMulBt,
        KernelKind::MatMulF16,
        KernelKind::MatMulBf16,
        KernelKind::MatMulInt8,
    ];

    /// Stable name used in stats JSON and bench reports.
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::MatMul => "matmul_f32",
            KernelKind::MatMulAt => "matmul_at_f32",
            KernelKind::MatMulBt => "matmul_bt_f32",
            KernelKind::MatMulF16 => "matmul_f16",
            KernelKind::MatMulBf16 => "matmul_bf16",
            KernelKind::MatMulInt8 => "matmul_int8",
        }
    }
}

struct KernelStat {
    calls: AtomicU64,
    flops: AtomicU64,
    ns: AtomicU64,
}

impl KernelStat {
    const fn new() -> Self {
        Self { calls: AtomicU64::new(0), flops: AtomicU64::new(0), ns: AtomicU64::new(0) }
    }
}

/// One slot per [`KernelKind`], indexed by discriminant.
static STATS: [KernelStat; 6] = [
    KernelStat::new(),
    KernelStat::new(),
    KernelStat::new(),
    KernelStat::new(),
    KernelStat::new(),
    KernelStat::new(),
];

/// Record one kernel invocation. Called from the policy-dispatching
/// entry points only — the forced `*_scalar` / `*_blocked` variants
/// stay unrecorded so microbenchmarks can wall-time them without
/// polluting the serving counters.
pub(crate) fn record(kind: KernelKind, flops: u64, ns: u64) {
    let s = &STATS[kind as usize];
    s.calls.fetch_add(1, Ordering::Relaxed);
    s.flops.fetch_add(flops, Ordering::Relaxed);
    s.ns.fetch_add(ns, Ordering::Relaxed);
}

/// Point-in-time copy of one kernel's counters.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KernelSnapshot {
    /// [`KernelKind::name`] of the kernel.
    pub name: &'static str,
    /// Invocations since process start.
    pub calls: u64,
    /// Useful floating-point work (2·m·n·k per matmul).
    pub flops: u64,
    /// Wall time spent inside the kernel, summed over all threads.
    pub ns: u64,
}

impl KernelSnapshot {
    /// Achieved throughput: flops / ns happens to *be* GFLOP/s.
    pub fn gflops(&self) -> f64 {
        if self.ns == 0 {
            0.0
        } else {
            self.flops as f64 / self.ns as f64
        }
    }
}

/// Snapshot of all kernel counters (zero-call kinds included, so two
/// snapshots always subtract slot-for-slot).
pub fn kernel_snapshot() -> Vec<KernelSnapshot> {
    KernelKind::ALL
        .iter()
        .map(|&kind| {
            let s = &STATS[kind as usize];
            KernelSnapshot {
                name: kind.name(),
                calls: s.calls.load(Ordering::Relaxed),
                flops: s.flops.load(Ordering::Relaxed),
                ns: s.ns.load(Ordering::Relaxed),
            }
        })
        .collect()
}

/// Total (flops, ns) across every kernel since process start.
pub fn kernel_totals() -> (u64, u64) {
    let mut flops = 0u64;
    let mut ns = 0u64;
    for s in &STATS {
        flops += s.flops.load(Ordering::Relaxed);
        ns += s.ns.load(Ordering::Relaxed);
    }
    (flops, ns)
}

// ---------------------------------------------------------------------
// Blocked row kernels
// ---------------------------------------------------------------------

/// Output-column register tile. 32 f32 = 4 AVX2 vectors — wide enough
/// to keep 8-wide FMA-less pipelines busy, small enough to stay in
/// registers. Tiling columns never reorders any single element's
/// accumulation chain, which is what keeps blocked == scalar byte-wise.
pub(crate) const JTILE: usize = 32;

/// Blocked body of the skip-accumulate row kernel (`matmul` /
/// `matmul_rows` / `matmul_at` semantics): `orow[j] += arow[p] * B[p,j]`
/// in ascending-`p` order, skipping `arow[p] == 0.0` — the oracle's
/// exact per-element chain, j-tiled.
#[inline(always)]
fn row_f32_skip_body(arow: &[f32], bd: &[f32], n: usize, orow: &mut [f32]) {
    let mut j0 = 0usize;
    while j0 + JTILE <= n {
        let mut acc = [0.0f32; JTILE];
        acc.copy_from_slice(&orow[j0..j0 + JTILE]);
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &bd[p * n + j0..p * n + j0 + JTILE];
            for (a, &b) in acc.iter_mut().zip(brow) {
                *a += av * b;
            }
        }
        orow[j0..j0 + JTILE].copy_from_slice(&acc);
        j0 += JTILE;
    }
    if j0 < n {
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &bd[p * n..(p + 1) * n];
            for j in j0..n {
                orow[j] += av * brow[j];
            }
        }
    }
}

/// Blocked body of the dot-product row kernel (`matmul_bt` semantics
/// over a pre-transposed `[k, n]` operand): fresh zero accumulator, no
/// zero-skip, `orow[j] = acc` assignment — again the oracle's exact
/// per-element chain.
#[inline(always)]
fn row_f32_dot_body(arow: &[f32], bd: &[f32], n: usize, orow: &mut [f32]) {
    let mut j0 = 0usize;
    while j0 + JTILE <= n {
        let mut acc = [0.0f32; JTILE];
        for (p, &av) in arow.iter().enumerate() {
            let brow = &bd[p * n + j0..p * n + j0 + JTILE];
            for (a, &b) in acc.iter_mut().zip(brow) {
                *a += av * b;
            }
        }
        orow[j0..j0 + JTILE].copy_from_slice(&acc);
        j0 += JTILE;
    }
    for j in j0..n {
        let mut acc = 0.0f32;
        for (p, &av) in arow.iter().enumerate() {
            acc += av * bd[p * n + j];
        }
        orow[j] = acc;
    }
}

/// f16-weight row kernel body: decode inline, accumulate in f32.
#[inline(always)]
fn row_f16_body(arow: &[f32], bd: &[u16], n: usize, orow: &mut [f32]) {
    let mut j0 = 0usize;
    while j0 + JTILE <= n {
        let mut acc = [0.0f32; JTILE];
        acc.copy_from_slice(&orow[j0..j0 + JTILE]);
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &bd[p * n + j0..p * n + j0 + JTILE];
            for (a, &b) in acc.iter_mut().zip(brow) {
                *a += av * f16_bits_to_f32(b);
            }
        }
        orow[j0..j0 + JTILE].copy_from_slice(&acc);
        j0 += JTILE;
    }
    if j0 < n {
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &bd[p * n..(p + 1) * n];
            for j in j0..n {
                orow[j] += av * f16_bits_to_f32(brow[j]);
            }
        }
    }
}

/// bf16-weight row kernel body: decode is a 16-bit shift, so this runs
/// at nearly f32 speed with half the weight traffic.
#[inline(always)]
fn row_bf16_body(arow: &[f32], bd: &[u16], n: usize, orow: &mut [f32]) {
    let mut j0 = 0usize;
    while j0 + JTILE <= n {
        let mut acc = [0.0f32; JTILE];
        acc.copy_from_slice(&orow[j0..j0 + JTILE]);
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &bd[p * n + j0..p * n + j0 + JTILE];
            for (a, &b) in acc.iter_mut().zip(brow) {
                *a += av * f32::from_bits((b as u32) << 16);
            }
        }
        orow[j0..j0 + JTILE].copy_from_slice(&acc);
        j0 += JTILE;
    }
    if j0 < n {
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &bd[p * n..(p + 1) * n];
            for j in j0..n {
                orow[j] += av * f32::from_bits((brow[j] as u32) << 16);
            }
        }
    }
}

/// int8-weight row kernel body: the per-row scale folds into the
/// activation once (`coef = av * scale[p]`), so the inner loop is one
/// int→float convert + mul + add per element at a quarter of the f32
/// weight traffic.
#[inline(always)]
fn row_i8_body(arow: &[f32], q: &[i8], scales: &[f32], n: usize, orow: &mut [f32]) {
    let mut j0 = 0usize;
    while j0 + JTILE <= n {
        let mut acc = [0.0f32; JTILE];
        acc.copy_from_slice(&orow[j0..j0 + JTILE]);
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let coef = av * scales[p];
            let brow = &q[p * n + j0..p * n + j0 + JTILE];
            for (a, &b) in acc.iter_mut().zip(brow) {
                *a += coef * b as f32;
            }
        }
        orow[j0..j0 + JTILE].copy_from_slice(&acc);
        j0 += JTILE;
    }
    if j0 < n {
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let coef = av * scales[p];
            let brow = &q[p * n..(p + 1) * n];
            for j in j0..n {
                orow[j] += coef * brow[j] as f32;
            }
        }
    }
}

/// Generate the SIMD-dispatched public wrapper for a row-kernel body:
/// an `avx2`-target-feature clone (the `#[inline(always)]` body
/// recompiles 8-wide inside it — only `avx2`, never `fma`, so mul and
/// add stay separate ops and byte-identity holds) plus a portable
/// fallback, selected once per call via the std feature-detection
/// cache.
macro_rules! simd_dispatch {
    ($(#[$meta:meta])* $vis:vis fn $name:ident / $avx:ident = $body:ident (
        $($arg:ident : $ty:ty),* $(,)?
    )) => {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        #[target_feature(enable = "avx2")]
        unsafe fn $avx($($arg: $ty),*) {
            $body($($arg),*)
        }

        $(#[$meta])*
        $vis fn $name($($arg: $ty),*) {
            #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
            if std::arch::is_x86_feature_detected!("avx2") {
                // SAFETY: AVX2 support was verified on the line above.
                return unsafe { $avx($($arg),*) };
            }
            $body($($arg),*)
        }
    };
}

simd_dispatch! {
    /// One blocked output row of skip-accumulate matmul (see
    /// [`row_f32_skip_body`]), dispatched to AVX2 when available.
    pub(crate) fn row_f32_skip / row_f32_skip_avx2 = row_f32_skip_body(
        arow: &[f32], bd: &[f32], n: usize, orow: &mut [f32]
    )
}

simd_dispatch! {
    /// One blocked output row of dot-product matmul (see
    /// [`row_f32_dot_body`]), dispatched to AVX2 when available.
    pub(crate) fn row_f32_dot / row_f32_dot_avx2 = row_f32_dot_body(
        arow: &[f32], bd: &[f32], n: usize, orow: &mut [f32]
    )
}

simd_dispatch! {
    /// One output row over f16 weights, dispatched to AVX2.
    pub(crate) fn row_f16 / row_f16_avx2 = row_f16_body(
        arow: &[f32], bd: &[u16], n: usize, orow: &mut [f32]
    )
}

simd_dispatch! {
    /// One output row over bf16 weights, dispatched to AVX2.
    pub(crate) fn row_bf16 / row_bf16_avx2 = row_bf16_body(
        arow: &[f32], bd: &[u16], n: usize, orow: &mut [f32]
    )
}

simd_dispatch! {
    /// One output row over int8 per-row-scale weights, dispatched to
    /// AVX2.
    pub(crate) fn row_i8 / row_i8_avx2 = row_i8_body(
        arow: &[f32], q: &[i8], scales: &[f32], n: usize, orow: &mut [f32]
    )
}

// ---------------------------------------------------------------------
// f16 / bf16 software conversion
// ---------------------------------------------------------------------

/// f32 → IEEE 754 binary16 bits, round-to-nearest-even, full
/// subnormal / overflow / NaN handling (no hardware f16 required).
pub fn f32_to_f16_bits(v: f32) -> u16 {
    let bits = v.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let em = bits & 0x7fff_ffff;
    if em > 0x7f80_0000 {
        // NaN: quiet it, keep the sign.
        return sign | 0x7e00;
    }
    if em >= 0x4780_0000 {
        // |v| >= 65536 (or f32 inf): overflows f16.
        return sign | 0x7c00;
    }
    if em < 0x3880_0000 {
        // |v| < 2^-14: f16 subnormal (or zero). Scale to units of
        // 2^-24 (exact — power-of-two multiply), then round to integer
        // via the add-2^23 trick: f32 addition's own
        // round-to-nearest-even does the rounding.
        let units = f32::from_bits(em) * 16_777_216.0;
        let h = ((units + 8_388_608.0).to_bits() & 0x7f_ffff) as u16;
        return sign | h;
    }
    // Normal range: rebias 127 → 15, round-to-nearest-even on the 13
    // dropped mantissa bits. The carry is allowed to overflow into the
    // exponent — that is exactly right both for mantissa rollover and
    // for 65520 <= |v| < 65536 rounding up to infinity.
    let mut h = ((em - 0x3800_0000) >> 13) as u16;
    let rem = em & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && (h & 1) == 1) {
        h = h.wrapping_add(1);
    }
    sign | h
}

/// IEEE 754 binary16 bits → f32 (exact — every f16 is an f32).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = (h >> 10) & 0x1f;
    let man = (h & 0x3ff) as u32;
    if exp == 0 {
        // Zero / subnormal: man · 2^-24. The multiply is exact; the
        // sign is OR'd bitwise so -0.0 survives.
        let mag = man as f32 * f32::from_bits(0x3380_0000);
        return f32::from_bits(sign | mag.to_bits());
    }
    if exp == 0x1f {
        if man == 0 {
            return f32::from_bits(sign | 0x7f80_0000);
        }
        // NaN: quiet, payload preserved in the top mantissa bits.
        return f32::from_bits(sign | 0x7fc0_0000 | (man << 13));
    }
    f32::from_bits(sign | ((exp as u32 + 112) << 23) | (man << 13))
}

/// f32 → bfloat16 bits, round-to-nearest-even (bf16 keeps f32's
/// exponent range, so there is no subnormal/overflow special-casing
/// beyond NaN quieting).
pub fn f32_to_bf16_bits(v: f32) -> u16 {
    let bits = v.to_bits();
    if v.is_nan() {
        // Quiet it so truncation can never produce an infinity.
        return ((bits >> 16) as u16) | 0x0040;
    }
    let mut h = (bits >> 16) as u16;
    let rem = bits & 0xffff;
    if rem > 0x8000 || (rem == 0x8000 && (h & 1) == 1) {
        // Carry may roll the max finite value over to inf — correct.
        h = h.wrapping_add(1);
    }
    h
}

/// bfloat16 bits → f32 (exact: pad with 16 zero mantissa bits).
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Symmetric per-row int8 quantization of a `[k, n]` weight matrix:
/// `scale[p] = max|W[p, :]| / 127`, `q[p, j] = round(W[p, j] / scale[p])`.
/// A row whose max-abs is zero or non-finite keeps `q = 0, scale = 1`
/// (NaN/inf weights cannot be represented; such rows dequantize to
/// zero — callers quantizing garbage get deterministic garbage, not
/// UB or poisoned scales).
pub fn quantize_rows_i8(data: &[f32], k: usize, n: usize) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(data.len(), k * n, "quantize_rows_i8 size");
    let mut q = vec![0i8; k * n];
    let mut scales = vec![1.0f32; k];
    for p in 0..k {
        let row = &data[p * n..(p + 1) * n];
        let amax = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        if !amax.is_finite() || amax == 0.0 {
            continue;
        }
        let scale = amax / 127.0;
        scales[p] = scale;
        for (dst, &v) in q[p * n..(p + 1) * n].iter_mut().zip(row) {
            // NaN saturates to 0 through the `as` cast; finite values
            // are already clamped to ±127.
            *dst = (v / scale).round().clamp(-127.0, 127.0) as i8;
        }
    }
    (q, scales)
}

// ---------------------------------------------------------------------
// Weight storage
// ---------------------------------------------------------------------

/// Error budgets for end-to-end cell outputs (relative Frobenius error
/// vs the f32 oracle, [`Tensor::rel_error`] style). Checked in
/// `tests/kernel_parity.rs` and re-checked at bench time by the
/// `gemm_kernels` suite. Deliberately conservative: a cell chains ~10
/// weight matmuls through normalization, so per-weight rounding error
/// (f16 ~6e-4, bf16/int8 ~4e-3) can amplify a few times over.
pub const F16_CELL_ERR_BUDGET: f32 = 2e-2;
/// See [`F16_CELL_ERR_BUDGET`].
pub const BF16_CELL_ERR_BUDGET: f32 = 8e-2;
/// See [`F16_CELL_ERR_BUDGET`].
pub const INT8_CELL_ERR_BUDGET: f32 = 8e-2;

/// Owned storage of one `[k, n]` weight matrix in a [`Precision`].
#[derive(Clone, Debug)]
pub struct WeightMat {
    k: usize,
    n: usize,
    store: Store,
}

#[derive(Clone, Debug)]
enum Store {
    F32(Vec<f32>),
    F16(Vec<u16>),
    Bf16(Vec<u16>),
    Int8 { q: Vec<i8>, scales: Vec<f32> },
}

impl WeightMat {
    /// Convert a rank-2 f32 tensor into `prec` storage.
    pub fn from_tensor(t: &Tensor, prec: Precision) -> Self {
        assert_eq!(t.rank(), 2, "WeightMat::from_tensor wants rank 2");
        let (k, n) = (t.shape()[0], t.shape()[1]);
        let d = t.data();
        let store = match prec {
            Precision::F32 => Store::F32(d.to_vec()),
            Precision::F16 => Store::F16(d.iter().map(|&v| f32_to_f16_bits(v)).collect()),
            Precision::Bf16 => Store::Bf16(d.iter().map(|&v| f32_to_bf16_bits(v)).collect()),
            Precision::Int8 => {
                let (q, scales) = quantize_rows_i8(d, k, n);
                Store::Int8 { q, scales }
            }
        };
        Self { k, n, store }
    }

    /// The storage precision.
    pub fn precision(&self) -> Precision {
        match self.store {
            Store::F32(_) => Precision::F32,
            Store::F16(_) => Precision::F16,
            Store::Bf16(_) => Precision::Bf16,
            Store::Int8 { .. } => Precision::Int8,
        }
    }

    /// `(k, n)` of the stored matrix.
    pub fn shape(&self) -> (usize, usize) {
        (self.k, self.n)
    }

    /// Bytes of weight payload actually stored (the footprint the
    /// reduced-precision tiers exist to shrink).
    pub fn bytes(&self) -> usize {
        match &self.store {
            Store::F32(v) => v.len() * 4,
            Store::F16(v) | Store::Bf16(v) => v.len() * 2,
            Store::Int8 { q, scales } => q.len() + scales.len() * 4,
        }
    }

    /// Decode back to an f32 tensor (exact for F32; the round-tripped
    /// values for the quantized formats).
    pub fn dequantize(&self) -> Tensor {
        let data: Vec<f32> = match &self.store {
            Store::F32(v) => v.clone(),
            Store::F16(v) => v.iter().map(|&h| f16_bits_to_f32(h)).collect(),
            Store::Bf16(v) => v.iter().map(|&h| bf16_bits_to_f32(h)).collect(),
            Store::Int8 { q, scales } => {
                let n = self.n;
                q.iter()
                    .enumerate()
                    .map(|(i, &b)| b as f32 * scales[i / n])
                    .collect()
            }
        };
        Tensor::new(&[self.k, self.n], data).expect("dequantize shape")
    }

    /// Borrow as a [`WeightView`] for the matmul kernels.
    pub fn view(&self) -> WeightView<'_> {
        let data = match &self.store {
            Store::F32(v) => WeightData::F32(v),
            Store::F16(v) => WeightData::F16(v),
            Store::Bf16(v) => WeightData::Bf16(v),
            Store::Int8 { q, scales } => WeightData::Int8 { q, scales },
        };
        WeightView { k: self.k, n: self.n, data }
    }
}

/// Borrowed `[k, n]` weight operand in any storage precision — what the
/// cell math actually multiplies by.
#[derive(Clone, Copy, Debug)]
pub struct WeightView<'a> {
    k: usize,
    n: usize,
    data: WeightData<'a>,
}

#[derive(Clone, Copy, Debug)]
enum WeightData<'a> {
    F32(&'a [f32]),
    F16(&'a [u16]),
    Bf16(&'a [u16]),
    Int8 { q: &'a [i8], scales: &'a [f32] },
}

impl<'a> WeightView<'a> {
    /// View a plain rank-2 f32 tensor as an exact-precision weight.
    pub fn from_tensor(t: &'a Tensor) -> Self {
        assert_eq!(t.rank(), 2, "WeightView::from_tensor wants rank 2");
        Self { k: t.shape()[0], n: t.shape()[1], data: WeightData::F32(t.data()) }
    }

    /// `(k, n)` of the viewed matrix.
    pub fn shape(&self) -> (usize, usize) {
        (self.k, self.n)
    }

    /// `x[m, k] @ W[k, n] -> [m, n]`, f32 activations and accumulation.
    /// The F32 storage path follows the process [`kernel_policy`] and is
    /// byte-identical to [`super::matmul`]; quantized paths decode
    /// inline. Each call records into the per-kernel flop counters.
    pub fn matmul(&self, x: &Tensor) -> Tensor {
        let (m, k) = (x.shape()[0], x.shape()[1]);
        assert_eq!(k, self.k, "weight matmul inner dims {k} vs {}", self.k);
        let n = self.n;
        let t0 = Instant::now();
        let mut out = vec![0.0f32; m * n];
        let xd = x.data();
        let policy = kernel_policy();
        let kind = match self.data {
            WeightData::F32(_) => KernelKind::MatMul,
            WeightData::F16(_) => KernelKind::MatMulF16,
            WeightData::Bf16(_) => KernelKind::MatMulBf16,
            WeightData::Int8 { .. } => KernelKind::MatMulInt8,
        };
        for i in 0..m {
            let arow = &xd[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            match self.data {
                WeightData::F32(bd) => match policy {
                    KernelPolicy::Scalar => super::linalg::matmul_row(arow, bd, n, orow),
                    KernelPolicy::Blocked => row_f32_skip(arow, bd, n, orow),
                },
                WeightData::F16(bd) => row_f16(arow, bd, n, orow),
                WeightData::Bf16(bd) => row_bf16(arow, bd, n, orow),
                WeightData::Int8 { q, scales } => row_i8(arow, q, scales, n, orow),
            }
        }
        record(kind, 2 * (m * n * k) as u64, t0.elapsed().as_nanos() as u64);
        Tensor::new(&[m, n], out).expect("weight matmul shape")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, Rng};

    #[test]
    fn policy_parse_roundtrip() {
        for p in [KernelPolicy::Scalar, KernelPolicy::Blocked] {
            assert_eq!(p.to_string().parse::<KernelPolicy>().unwrap(), p);
        }
        assert!("fast".parse::<KernelPolicy>().is_err());
    }

    #[test]
    fn precision_parse_roundtrip() {
        for p in [Precision::F32, Precision::F16, Precision::Bf16, Precision::Int8] {
            assert_eq!(p.to_string().parse::<Precision>().unwrap(), p);
        }
        assert_eq!("fp16".parse::<Precision>().unwrap(), Precision::F16);
        assert_eq!("q8".parse::<Precision>().unwrap(), Precision::Int8);
        assert!("f64".parse::<Precision>().is_err());
    }

    #[test]
    fn set_policy_roundtrip() {
        // Restore the env-derived policy afterwards: other tests in
        // this process may consult the global (they remain correct
        // under either value — blocked is byte-identical — but the
        // CI env matrix expects its request to stick).
        let prev = kernel_policy();
        set_kernel_policy(KernelPolicy::Scalar);
        assert_eq!(kernel_policy(), KernelPolicy::Scalar);
        set_kernel_policy(KernelPolicy::Blocked);
        assert_eq!(kernel_policy(), KernelPolicy::Blocked);
        set_kernel_policy(prev);
    }

    #[test]
    fn f16_encode_cases() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff); // max finite f16
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00); // rounds to inf
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        assert_eq!(f32_to_f16_bits(f32::NAN) & 0x7fff, 0x7e00);
        // Subnormal rounding: 2^-24 is the smallest f16 subnormal;
        // 2^-25 ties to even (0); 3·2^-26 rounds up to one unit.
        assert_eq!(f32_to_f16_bits(f32::powi(2.0, -24)), 0x0001);
        assert_eq!(f32_to_f16_bits(f32::powi(2.0, -25)), 0x0000);
        assert_eq!(f32_to_f16_bits(3.0 * f32::powi(2.0, -26)), 0x0001);
        // Decode spot checks.
        assert_eq!(f16_bits_to_f32(0x3c00), 1.0);
        assert_eq!(f16_bits_to_f32(0x7bff), 65504.0);
        assert_eq!(f16_bits_to_f32(0x0001), f32::powi(2.0, -24));
        assert_eq!(f16_bits_to_f32(0x8000).to_bits(), (-0.0f32).to_bits());
        assert!(f16_bits_to_f32(0x7c01).is_nan());
    }

    #[test]
    fn f16_exhaustive_roundtrip() {
        // Every f16 bit pattern must survive decode→encode: NaNs come
        // back as the canonical quiet NaN with the sign preserved,
        // everything else must be bit-identical.
        for h in 0..=u16::MAX {
            let v = f16_bits_to_f32(h);
            let back = f32_to_f16_bits(v);
            if v.is_nan() {
                assert_eq!(back, (h & 0x8000) | 0x7e00, "h={h:#06x}");
            } else {
                assert_eq!(back, h, "h={h:#06x} v={v}");
            }
        }
    }

    #[test]
    fn bf16_exhaustive_roundtrip() {
        for h in 0..=u16::MAX {
            let v = bf16_bits_to_f32(h);
            let back = f32_to_bf16_bits(v);
            if v.is_nan() {
                assert_eq!(back, h | 0x0040, "h={h:#06x}");
            } else {
                assert_eq!(back, h, "h={h:#06x} v={v}");
            }
        }
    }

    #[test]
    fn bf16_encode_rounds_to_nearest_even() {
        // 1.0 + 2^-9 sits exactly between two bf16 values -> ties to
        // the even (lower) one; a bit more rounds up.
        let tie = f32::from_bits(0x3f80_8000);
        assert_eq!(f32_to_bf16_bits(tie), 0x3f80);
        let above = f32::from_bits(0x3f80_8001);
        assert_eq!(f32_to_bf16_bits(above), 0x3f81);
        // Max finite f32 overflows bf16's mantissa and rolls to inf.
        assert_eq!(f32_to_bf16_bits(f32::MAX), 0x7f80);
    }

    #[test]
    fn int8_rowwise_error_bound() {
        let mut rng = Rng::new(11);
        let t = Tensor::randn(&[16, 33], 0.7, &mut rng);
        let (q, scales) = quantize_rows_i8(t.data(), 16, 33);
        assert_eq!(scales.len(), 16);
        for p in 0..16 {
            for j in 0..33 {
                let v = t.at2(p, j);
                let deq = q[p * 33 + j] as f32 * scales[p];
                // Round-to-nearest in units of scale: error <= scale/2.
                assert!(
                    (deq - v).abs() <= scales[p] * 0.5 + 1e-6,
                    "row {p} col {j}: {v} vs {deq} (scale {})",
                    scales[p]
                );
            }
        }
        // Degenerate rows: all-zero stays zero with unit scale.
        let (qz, sz) = quantize_rows_i8(&[0.0; 8], 2, 4);
        assert!(qz.iter().all(|&b| b == 0));
        assert_eq!(sz, vec![1.0, 1.0]);
    }

    #[test]
    fn weightmat_f32_view_matmul_bitexact() {
        // The F32 weight view must reproduce tensor::matmul exactly,
        // under whatever policy is ambient (both policies are
        // byte-identical, so this holds regardless).
        let mut rng = Rng::new(21);
        let x = Tensor::randn(&[5, 19], 1.0, &mut rng);
        let mut w = Tensor::randn(&[19, 37], 1.0, &mut rng);
        w.data_mut()[7] = 0.0; // exercise the zero-skip
        let want = matmul(&x, &w);
        let wm = WeightMat::from_tensor(&w, Precision::F32);
        assert_eq!(wm.precision(), Precision::F32);
        let got = wm.view().matmul(&x);
        assert_eq!(got, want);
        let got2 = WeightView::from_tensor(&w).matmul(&x);
        assert_eq!(got2, want);
        // F32 dequantize is the identity.
        assert_eq!(wm.dequantize(), w);
    }

    #[test]
    fn weightmat_bytes_footprint() {
        let t = Tensor::zeros(&[8, 16]);
        assert_eq!(WeightMat::from_tensor(&t, Precision::F32).bytes(), 8 * 16 * 4);
        assert_eq!(WeightMat::from_tensor(&t, Precision::F16).bytes(), 8 * 16 * 2);
        assert_eq!(WeightMat::from_tensor(&t, Precision::Bf16).bytes(), 8 * 16 * 2);
        // int8: 1 byte per weight + one f32 scale per row.
        assert_eq!(WeightMat::from_tensor(&t, Precision::Int8).bytes(), 8 * 16 + 8 * 4);
    }

    #[test]
    fn quant_matmul_error_bounded() {
        // One weight matmul (not a full cell): the quantized kernels
        // must land well inside per-format rounding error.
        let mut rng = Rng::new(31);
        let x = Tensor::randn(&[7, 48], 0.5, &mut rng);
        let w = Tensor::randn(&[48, 65], 0.3, &mut rng);
        let want = matmul(&x, &w);
        for (prec, budget) in [
            (Precision::F16, 5e-3f32),
            (Precision::Bf16, 3e-2f32),
            (Precision::Int8, 3e-2f32),
        ] {
            let wm = WeightMat::from_tensor(&w, prec);
            let got = wm.view().matmul(&x);
            let err = got.rel_error(&want);
            assert!(err < budget, "{prec}: rel error {err} over {budget}");
            // And the kernel must agree with matmul against its own
            // dequantized weights bit-for-bit is NOT required (loop
            // shapes differ) — but numerically it is the same product:
            let deq = matmul(&x, &wm.dequantize());
            assert!(got.rel_error(&deq) < 1e-6, "{prec}: kernel vs dequantized");
        }
    }

    #[test]
    fn counters_accumulate() {
        // Counters are process-global and other tests run concurrently,
        // so assert monotonic growth, not exact values.
        let before: u64 = kernel_snapshot()
            .iter()
            .find(|s| s.name == "matmul_int8")
            .unwrap()
            .flops;
        let mut rng = Rng::new(41);
        let x = Tensor::randn(&[3, 8], 1.0, &mut rng);
        let w = Tensor::randn(&[8, 5], 1.0, &mut rng);
        WeightMat::from_tensor(&w, Precision::Int8).view().matmul(&x);
        let after = kernel_snapshot()
            .iter()
            .find(|s| s.name == "matmul_int8")
            .unwrap()
            .clone();
        assert!(after.flops >= before + 2 * 3 * 8 * 5, "{} -> {}", before, after.flops);
        assert!(after.calls >= 1);
        let (tf, _tn) = kernel_totals();
        assert!(tf >= after.flops);
        assert!(after.gflops() >= 0.0);
    }
}
