//! Deterministic PRNG (xoshiro256**) — no `rand` dependency.
//!
//! Used by the native model init, the BABILong generator and the
//! benchmark workload generators. Determinism matters: the rust side and
//! tests must be reproducible across runs and machines.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-7);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(9);
        let mut seen = [false; 5];
        for _ in 0..200 {
            seen[r.below(5)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn choose_distinct() {
        let mut r = Rng::new(11);
        let picks = r.choose(10, 4);
        assert_eq!(picks.len(), 4);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 4);
    }
}
