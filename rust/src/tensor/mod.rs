//! Minimal dense f32 tensor library.
//!
//! This is the substrate for the native reference model ([`crate::model`]),
//! the scheduler's group assembly, and test oracles. It is deliberately
//! simple: row-major `Vec<f32>` + shape, with exactly the ops the ARMT
//! cell needs. No broadcasting magic — every op states its contract.
//!
//! Split across submodules:
//! * [`ops`] — elementwise / reduction / activation ops,
//! * [`linalg`] — matmul family (incl. the grouped matmul used to mirror
//!   the L1 grouped-GEMM kernel), policy-dispatched between the scalar
//!   oracle and the blocked SIMD tier,
//! * [`kernels`] — the tiered GEMM kernel layer: [`KernelPolicy`],
//!   blocked row kernels, f16/bf16/int8 weight storage
//!   ([`WeightMat`]/[`WeightView`]), and per-kernel flop counters,
//! * [`rng`] — a tiny deterministic PRNG (xoshiro256**) so tests and
//!   workload generators never need the `rand` crate.

pub mod kernels;
mod linalg;
mod ops;
mod rng;

pub use kernels::{
    env_kernel_policy, env_precision, kernel_policy, kernel_snapshot, kernel_totals,
    set_kernel_policy, KernelPolicy, KernelSnapshot, Precision, WeightMat, WeightView,
};
pub use linalg::{
    grouped_matmul, matmul, matmul_at, matmul_at_blocked, matmul_at_scalar, matmul_blocked,
    matmul_bt, matmul_bt_blocked, matmul_bt_scalar, matmul_rows, matmul_rows_blocked,
    matmul_rows_scalar, matmul_scalar,
};
pub use ops::*;
pub use rng::Rng;

use crate::error::{Error, Result};

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Build from parts; checks element count.
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape {
                what: "Tensor::new",
                expected: vec![n],
                got: vec![data.len()],
            });
        }
        Ok(Self { shape: shape.to_vec(), data })
    }

    /// All-zero tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Constant-filled tensor.
    pub fn full(shape: &[usize], v: f32) -> Self {
        Self { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    /// Standard-normal-ish tensor from the deterministic PRNG.
    pub fn randn(shape: &[usize], scale: f32, rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal() * scale).collect();
        Self { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::Shape {
                what: "reshape",
                expected: shape.to_vec(),
                got: self.shape.clone(),
            });
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Scalar accessor for rank-2 tensors.
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.rank(), 2);
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    /// Leading-axis slice `[i]` of a rank-N tensor (N >= 1) as a view copy.
    pub fn index0(&self, i: usize) -> Tensor {
        let sub: usize = self.shape[1..].iter().product();
        Tensor {
            shape: self.shape[1..].to_vec(),
            data: self.data[i * sub..(i + 1) * sub].to_vec(),
        }
    }

    /// Write `src` into leading-axis slot `i` (inverse of [`index0`]).
    pub fn set_index0(&mut self, i: usize, src: &Tensor) {
        let sub: usize = self.shape[1..].iter().product();
        debug_assert_eq!(src.len(), sub, "set_index0 size");
        self.data[i * sub..(i + 1) * sub].copy_from_slice(&src.data);
    }

    /// Sub-tensor at `[i, j]` of the two leading axes (rank >= 2), as a
    /// copy — the (layer, lane) cell accessor of the batched wavefront.
    pub fn index01(&self, i: usize, j: usize) -> Tensor {
        debug_assert!(self.rank() >= 2);
        let sub: usize = self.shape[2..].iter().product();
        let off = (i * self.shape[1] + j) * sub;
        Tensor { shape: self.shape[2..].to_vec(), data: self.data[off..off + sub].to_vec() }
    }

    /// Write `src` into `[i, j]` of the two leading axes (inverse of
    /// [`index01`]).
    pub fn set_index01(&mut self, i: usize, j: usize, src: &Tensor) {
        debug_assert!(self.rank() >= 2);
        let sub: usize = self.shape[2..].iter().product();
        debug_assert_eq!(src.len(), sub, "set_index01 size");
        let off = (i * self.shape[1] + j) * sub;
        self.data[off..off + sub].copy_from_slice(&src.data);
    }

    /// Zero the sub-tensor at `[i, j]` of the two leading axes in place
    /// (state reset at a request boundary in a reused wavefront lane).
    pub fn zero_index01(&mut self, i: usize, j: usize) {
        debug_assert!(self.rank() >= 2);
        let sub: usize = self.shape[2..].iter().product();
        let off = (i * self.shape[1] + j) * sub;
        self.data[off..off + sub].fill(0.0);
    }

    /// Rows `[a, b)` along axis 0, as a copy.
    pub fn slice0(&self, a: usize, b: usize) -> Tensor {
        let sub: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = b - a;
        Tensor { shape, data: self.data[a * sub..b * sub].to_vec() }
    }

    /// Stack tensors of identical shape along a new leading axis.
    pub fn stack(parts: &[&Tensor]) -> Result<Tensor> {
        let first = parts.first().ok_or_else(|| Error::Config("stack of 0".into()))?;
        let mut shape = vec![parts.len()];
        shape.extend_from_slice(first.shape());
        let mut data = Vec::with_capacity(parts.len() * first.len());
        for p in parts {
            if p.shape() != first.shape() {
                return Err(Error::Shape {
                    what: "stack",
                    expected: first.shape().to_vec(),
                    got: p.shape().to_vec(),
                });
            }
            data.extend_from_slice(p.data());
        }
        Tensor::new(&shape, data)
    }

    /// Concatenate along axis 0 (shapes must agree on trailing axes).
    pub fn concat0(parts: &[&Tensor]) -> Result<Tensor> {
        let first = parts.first().ok_or_else(|| Error::Config("concat of 0".into()))?;
        let mut rows = 0usize;
        let mut data = Vec::new();
        for p in parts {
            if p.shape()[1..] != first.shape()[1..] {
                return Err(Error::Shape {
                    what: "concat0",
                    expected: first.shape().to_vec(),
                    got: p.shape().to_vec(),
                });
            }
            rows += p.shape()[0];
            data.extend_from_slice(p.data());
        }
        let mut shape = first.shape().to_vec();
        shape[0] = rows;
        Tensor::new(&shape, data)
    }

    /// Transpose a rank-2 tensor.
    pub fn t(&self) -> Tensor {
        debug_assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor { shape: vec![n, m], data: out }
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Relative Frobenius error ‖self − other‖ / ‖other‖ — the paper's
    /// Table 2 metric.
    pub fn rel_error(&self, other: &Tensor) -> f32 {
        debug_assert_eq!(self.shape, other.shape);
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        (num.sqrt() / den.sqrt().max(1e-30)) as f32
    }

    /// Max |a − b|.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Row-wise argmax for rank-2 tensors (greedy decode / top-1
    /// agreement). NaN-safe: NaN entries lose every comparison, so a
    /// numerically-diverged row deterministically yields index 0 instead
    /// of panicking (long random-weight recurrences can overflow f32 —
    /// see EXPERIMENTS.md Table 2 notes).
    pub fn argmax_rows(&self) -> Vec<usize> {
        debug_assert_eq!(self.rank(), 2);
        (0..self.shape[0])
            .map(|i| {
                let row = self.row(i);
                let mut best = 0usize;
                let mut best_v = f32::NEG_INFINITY;
                for (j, &v) in row.iter().enumerate() {
                    if v > best_v {
                        best = j;
                        best_v = v;
                    }
                }
                best
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_len() {
        assert!(Tensor::new(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn index_set_roundtrip() {
        let mut t = Tensor::zeros(&[3, 2, 2]);
        let part = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        t.set_index0(1, &part);
        assert_eq!(t.index0(1), part);
        assert_eq!(t.index0(0), Tensor::zeros(&[2, 2]));
    }

    #[test]
    fn index01_matches_nested_index0() {
        let mut rng = Rng::new(7);
        let t = Tensor::randn(&[3, 2, 4, 5], 1.0, &mut rng);
        for i in 0..3 {
            for j in 0..2 {
                assert_eq!(t.index01(i, j), t.index0(i).index0(j));
            }
        }
        let mut t2 = t.clone();
        let part = Tensor::full(&[4, 5], 9.0);
        t2.set_index01(2, 1, &part);
        assert_eq!(t2.index01(2, 1), part);
        assert_eq!(t2.index01(2, 0), t.index01(2, 0));
        t2.zero_index01(2, 1);
        assert_eq!(t2.index01(2, 1), Tensor::zeros(&[4, 5]));
        assert_eq!(t2.index01(0, 0), t.index01(0, 0));
    }

    #[test]
    fn stack_concat() {
        let a = Tensor::full(&[2, 2], 1.0);
        let b = Tensor::full(&[2, 2], 2.0);
        let s = Tensor::stack(&[&a, &b]).unwrap();
        assert_eq!(s.shape(), &[2, 2, 2]);
        let c = Tensor::concat0(&[&a, &b]).unwrap();
        assert_eq!(c.shape(), &[4, 2]);
        assert_eq!(c.at2(3, 1), 2.0);
    }

    #[test]
    fn transpose() {
        let a = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let t = a.t();
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(t.at2(2, 1), 6.0);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn rel_error_zero_for_self() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[4, 4], 1.0, &mut rng);
        assert_eq!(a.rel_error(&a), 0.0);
    }

    #[test]
    fn argmax_rows() {
        let a = Tensor::new(&[2, 3], vec![0., 5., 1., 9., 2., 3.]).unwrap();
        assert_eq!(a.argmax_rows(), vec![1, 0]);
    }
}
