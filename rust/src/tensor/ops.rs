//! Elementwise / reduction / transformer ops for the native backend.
//!
//! These mirror the L2 jnp semantics exactly (same formulas, f32) so that
//! the native model can serve as an oracle against HLO executables.

use super::Tensor;

/// a + b (same shape).
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    debug_assert_eq!(a.shape(), b.shape());
    let data = a.data().iter().zip(b.data()).map(|(x, y)| x + y).collect();
    Tensor::new(a.shape(), data).expect("add")
}

/// a - b (same shape).
pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    debug_assert_eq!(a.shape(), b.shape());
    let data = a.data().iter().zip(b.data()).map(|(x, y)| x - y).collect();
    Tensor::new(a.shape(), data).expect("sub")
}

/// a * b elementwise (same shape).
pub fn mul(a: &Tensor, b: &Tensor) -> Tensor {
    debug_assert_eq!(a.shape(), b.shape());
    let data = a.data().iter().zip(b.data()).map(|(x, y)| x * y).collect();
    Tensor::new(a.shape(), data).expect("mul")
}

/// a * s (scalar).
pub fn scale(a: &Tensor, s: f32) -> Tensor {
    let data = a.data().iter().map(|x| x * s).collect();
    Tensor::new(a.shape(), data).expect("scale")
}

/// In-place a += b.
pub fn add_assign(a: &mut Tensor, b: &Tensor) {
    debug_assert_eq!(a.shape(), b.shape());
    for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
        *x += y;
    }
}

/// Numerically matching jnp: sigmoid.
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// SiLU (a.k.a. swish): x * sigmoid(x).
pub fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// Map a scalar fn over a tensor.
pub fn map(a: &Tensor, f: impl Fn(f32) -> f32) -> Tensor {
    let data = a.data().iter().map(|&x| f(x)).collect();
    Tensor::new(a.shape(), data).expect("map")
}

/// Row-wise softmax on a rank-2 tensor (numerically stabilized like XLA).
pub fn softmax_rows(a: &Tensor) -> Tensor {
    debug_assert_eq!(a.rank(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let row = a.row(i);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for j in 0..n {
            let e = (row[j] - mx).exp();
            out[i * n + j] = e;
            sum += e;
        }
        for j in 0..n {
            out[i * n + j] /= sum;
        }
    }
    Tensor::new(&[m, n], out).expect("softmax")
}

/// RMSNorm over the last axis: x * rsqrt(mean(x^2) + eps) * g.
/// x: [T, d], g: [d].
pub fn rmsnorm(x: &Tensor, g: &Tensor, eps: f32) -> Tensor {
    debug_assert_eq!(x.rank(), 2);
    let (t, d) = (x.shape()[0], x.shape()[1]);
    debug_assert_eq!(g.len(), d);
    let mut out = vec![0.0f32; t * d];
    for i in 0..t {
        let row = x.row(i);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + eps).sqrt();
        for j in 0..d {
            out[i * d + j] = row[j] * r * g.data()[j];
        }
    }
    Tensor::new(&[t, d], out).expect("rmsnorm")
}

/// DPFP-nu feature map, matching `kernels/dpfp.py` exactly.
/// x: [T, k] -> [T, 2*nu*k].
pub fn dpfp(x: &Tensor, nu: usize) -> Tensor {
    debug_assert_eq!(x.rank(), 2);
    let (t, k) = (x.shape()[0], x.shape()[1]);
    let w = 2 * k;
    let p = nu * w;
    let mut out = vec![0.0f32; t * p];
    let mut xx = vec![0.0f32; w];
    for i in 0..t {
        let row = x.row(i);
        for j in 0..k {
            xx[j] = row[j].max(0.0);
            xx[k + j] = (-row[j]).max(0.0);
        }
        for r in 1..=nu {
            let base = i * p + (r - 1) * w;
            for j in 0..w {
                // jnp.roll(xx, -r): element j pairs with element (j + r) % w
                out[base + j] = xx[j] * xx[(j + r) % w];
            }
        }
    }
    Tensor::new(&[t, p], out).expect("dpfp")
}

/// RoPE rotation matching `ref.ref_rope`: x [T, hd] rotated by position.
pub fn rope_rows(x: &Tensor, theta: f32) -> Tensor {
    debug_assert_eq!(x.rank(), 2);
    let (t, hd) = (x.shape()[0], x.shape()[1]);
    debug_assert_eq!(hd % 2, 0);
    let half = hd / 2;
    let mut out = vec![0.0f32; t * hd];
    for pos in 0..t {
        let row = x.row(pos);
        for i in 0..half {
            let freq = 1.0 / theta.powf((2 * i) as f32 / hd as f32);
            let ang = pos as f32 * freq;
            let (s, c) = ang.sin_cos();
            let x1 = row[2 * i];
            let x2 = row[2 * i + 1];
            out[pos * hd + 2 * i] = x1 * c - x2 * s;
            out[pos * hd + 2 * i + 1] = x1 * s + x2 * c;
        }
    }
    Tensor::new(&[t, hd], out).expect("rope")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn add_sub_mul() {
        let a = Tensor::new(&[2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(&[2], vec![3.0, 4.0]).unwrap();
        assert_eq!(add(&a, &b).data(), &[4.0, 6.0]);
        assert_eq!(sub(&b, &a).data(), &[2.0, 2.0]);
        assert_eq!(mul(&a, &b).data(), &[3.0, 8.0]);
        assert_eq!(scale(&a, 2.0).data(), &[2.0, 4.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[4, 7], 3.0, &mut rng);
        let s = softmax_rows(&a);
        for i in 0..4 {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_handles_large_values() {
        let a = Tensor::new(&[1, 3], vec![1e30, -1e30, 0.0]).unwrap();
        let s = softmax_rows(&a);
        assert!((s.at2(0, 0) - 1.0).abs() < 1e-6);
        assert!(s.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn rmsnorm_unit_gain_unit_rms() {
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[3, 16], 2.0, &mut rng);
        let g = Tensor::full(&[16], 1.0);
        let y = rmsnorm(&x, &g, 1e-6);
        for i in 0..3 {
            let ms: f32 = y.row(i).iter().map(|v| v * v).sum::<f32>() / 16.0;
            assert!((ms - 1.0).abs() < 1e-3, "row {i} ms {ms}");
        }
    }

    #[test]
    fn dpfp_shape_and_nonneg() {
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[5, 8], 1.0, &mut rng);
        let p = dpfp(&x, 3);
        assert_eq!(p.shape(), &[5, 48]);
        assert!(p.data().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn dpfp_zero_is_zero() {
        let x = Tensor::zeros(&[2, 4]);
        assert_eq!(dpfp(&x, 3), Tensor::zeros(&[2, 24]));
    }

    #[test]
    fn rope_position_zero_is_identity() {
        let mut rng = Rng::new(4);
        let x = Tensor::randn(&[1, 8], 1.0, &mut rng);
        let y = rope_rows(&x, 10000.0);
        assert!(x.max_abs_diff(&y) < 1e-6);
    }

    #[test]
    fn rope_preserves_norm() {
        let mut rng = Rng::new(5);
        let x = Tensor::randn(&[6, 8], 1.0, &mut rng);
        let y = rope_rows(&x, 10000.0);
        for i in 0..6 {
            let nx: f32 = x.row(i).iter().map(|v| v * v).sum();
            let ny: f32 = y.row(i).iter().map(|v| v * v).sum();
            assert!((nx - ny).abs() < 1e-4);
        }
    }
}
