//! Matmul family for the native backend.
//!
//! Plain triple loops over a shared row-blocked kernel ([`matmul_row`])
//! — fast enough for the tiny CPU-validation configs, and *bit-stable*:
//! every output row's accumulation order is fixed in one place, so the
//! native diagonal and sequential executors agree bit-for-bit whether a
//! cell runs inline or on a pool worker (the property the scheduler
//! proptests and `parallel_parity` tests rely on). [`matmul_rows`]
//! exposes the row blocks directly: today's cell pool parallelizes
//! whole cells (which all funnel through this kernel), and row
//! partitioning is the proven-bit-exact building block for splitting a
//! single large cell across workers later.

use super::Tensor;

/// One output row of `A @ B`: `orow[j] += arow[p] * B[p, j]`. The
/// row-blocked kernel every matmul entry point shares — a row's
/// accumulation order is fixed here and nowhere else, so any partition
/// of rows across workers reproduces the full product bit-for-bit.
#[inline]
fn matmul_row(arow: &[f32], bd: &[f32], n: usize, orow: &mut [f32]) {
    for (p, &av) in arow.iter().enumerate() {
        if av == 0.0 {
            continue;
        }
        let brow = &bd[p * n..(p + 1) * n];
        for j in 0..n {
            orow[j] += av * brow[j];
        }
    }
}

/// C[m,n] = A[m,k] @ B[k,n].
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    matmul_rows(a, b, 0, a.shape()[0])
}

/// Rows `[r0, r1)` of `A[m,k] @ B[k,n]` as a `[r1 - r0, n]` tensor —
/// the independently-executable row block. Because each output row
/// touches only its own slice of `A` and accumulates in [`matmul_row`]'s
/// fixed order, workers computing disjoint row blocks produce exactly
/// the bytes of the corresponding [`matmul`] rows; stitching blocks
/// back together (in any order, by row index) is bit-identical to one
/// full-product call. [`matmul`] is the `[0, m)` block; no production
/// caller partitions yet — this is the bit-exactness-proven primitive
/// for intra-cell parallelism when single cells grow large enough to
/// need it.
pub fn matmul_rows(a: &Tensor, b: &Tensor, r0: usize, r1: usize) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    assert!(r0 <= r1 && r1 <= m, "row block [{r0}, {r1}) out of 0..{m}");
    let rows = r1 - r0;
    let mut out = vec![0.0f32; rows * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..rows {
        let arow = &ad[(r0 + i) * k..(r0 + i + 1) * k];
        matmul_row(arow, bd, n, &mut out[i * n..(i + 1) * n]);
    }
    Tensor::new(&[rows, n], out).expect("matmul_rows shape")
}

/// C[m,n] = A[k,m]^T @ B[k,n] (A stored transposed).
pub fn matmul_at(a: &Tensor, b: &Tensor) -> Tensor {
    let (k, m) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul_at inner dims");
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for p in 0..k {
        let arow = &ad[p * m..(p + 1) * m];
        let brow = &bd[p * n..(p + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    Tensor::new(&[m, n], out).expect("matmul_at shape")
}

/// C[m,n] = A[m,k] @ B[n,k]^T (B stored transposed — attention scores).
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, k2) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul_bt inner dims");
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &bd[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            orow[j] = acc;
        }
    }
    Tensor::new(&[m, n], out).expect("matmul_bt shape")
}

/// Grouped matmul: x[g,m,k] @ w[g,k,n] -> [g,m,n], executed as an ordered
/// loop over groups. This mirrors the L1 grouped-GEMM kernel semantics:
/// per-group results are *identical* to g independent [`matmul`] calls,
/// which is what makes native diagonal == native sequential bit-exact.
pub fn grouped_matmul(x: &Tensor, w: &Tensor) -> Tensor {
    assert_eq!(x.rank(), 3);
    assert_eq!(w.rank(), 3);
    let g = x.shape()[0];
    assert_eq!(g, w.shape()[0], "group dims");
    let parts: Vec<Tensor> = (0..g).map(|i| matmul(&x.index0(i), &w.index0(i))).collect();
    let refs: Vec<&Tensor> = parts.iter().collect();
    Tensor::stack(&refs).expect("grouped stack")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += a.at2(i, p) * b.at2(p, j);
                }
                out.data_mut()[i * n + j] = acc;
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[7, 5], 1.0, &mut rng);
        let b = Tensor::randn(&[5, 9], 1.0, &mut rng);
        let got = matmul(&a, &b);
        let want = naive(&a, &b);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn matmul_at_is_transposed_a() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[5, 7], 1.0, &mut rng);
        let b = Tensor::randn(&[5, 3], 1.0, &mut rng);
        let got = matmul_at(&a, &b);
        let want = matmul(&a.t(), &b);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn matmul_bt_is_transposed_b() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let b = Tensor::randn(&[5, 6], 1.0, &mut rng);
        let got = matmul_bt(&a, &b);
        let want = matmul(&a, &b.t());
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn grouped_equals_independent() {
        let mut rng = Rng::new(4);
        let x = Tensor::randn(&[3, 4, 5], 1.0, &mut rng);
        let w = Tensor::randn(&[3, 5, 6], 1.0, &mut rng);
        let g = grouped_matmul(&x, &w);
        for i in 0..3 {
            let want = matmul(&x.index0(i), &w.index0(i));
            // bit-exact, not approximately equal
            assert_eq!(g.index0(i), want);
        }
    }

    #[test]
    fn row_blocks_stitch_bitexact() {
        // The worker-pool contract: any row partition, reassembled by
        // row index, is byte-identical to the one-shot product.
        let mut rng = Rng::new(6);
        let a = Tensor::randn(&[9, 7], 1.0, &mut rng);
        let b = Tensor::randn(&[7, 5], 1.0, &mut rng);
        let full = matmul(&a, &b);
        for blocks in [vec![(0, 9)], vec![(0, 4), (4, 9)], vec![(0, 3), (3, 6), (6, 9)]] {
            let parts: Vec<Tensor> =
                blocks.iter().map(|&(r0, r1)| matmul_rows(&a, &b, r0, r1)).collect();
            let refs: Vec<&Tensor> = parts.iter().collect();
            let stitched = Tensor::concat0(&refs).unwrap();
            assert_eq!(stitched, full); // bit-exact, not approx
        }
        // Empty block is a valid (degenerate) partition member.
        assert_eq!(matmul_rows(&a, &b, 4, 4).shape(), &[0, 5]);
    }

    #[test]
    fn identity() {
        let mut eye = Tensor::zeros(&[4, 4]);
        for i in 0..4 {
            eye.data_mut()[i * 4 + i] = 1.0;
        }
        let mut rng = Rng::new(5);
        let a = Tensor::randn(&[4, 4], 1.0, &mut rng);
        assert_eq!(matmul(&a, &eye), a);
    }
}
