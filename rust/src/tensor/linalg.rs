//! Matmul family for the native backend.
//!
//! Two tiers behind one set of entry points ([`matmul`],
//! [`matmul_rows`], [`matmul_at`], [`matmul_bt`]):
//!
//! * the **scalar oracle** (`*_scalar`) — plain triple loops over a
//!   shared row kernel ([`matmul_row`]). Every output row's
//!   accumulation order is fixed in one place, so the native diagonal
//!   and sequential executors agree bit-for-bit whether a cell runs
//!   inline or on a pool worker (the property the scheduler proptests
//!   and `parallel_parity` tests rely on);
//! * the **blocked tier** (`*_blocked`) — cache-blocked,
//!   SIMD-dispatched kernels from [`super::kernels`] that preserve the
//!   oracle's per-element accumulation chains exactly, and are
//!   therefore *byte-identical* to it (enforced by
//!   `blocked_matches_scalar_bitexact_ragged` below and
//!   `tests/kernel_parity.rs`).
//!
//! The entry points dispatch on the process-wide
//! [`super::kernels::kernel_policy`] and record flops/elapsed into the
//! per-kernel counters; the forced `*_scalar` / `*_blocked` variants
//! are public for parity tests and microbenchmarks and stay
//! unrecorded. [`matmul_rows`] exposes row blocks directly: today's
//! cell pool parallelizes whole cells (which all funnel through these
//! kernels), and row partitioning is the proven-bit-exact building
//! block for splitting a single large cell across workers later.

use super::kernels::{self, KernelKind, KernelPolicy};
use super::Tensor;
use std::time::Instant;

/// One output row of `A @ B`: `orow[j] += arow[p] * B[p, j]`. The
/// row-blocked oracle kernel — a row's accumulation order is fixed here
/// (and mirrored, chain-for-chain, by the blocked tier), so any
/// partition of rows across workers reproduces the full product
/// bit-for-bit.
#[inline]
pub(crate) fn matmul_row(arow: &[f32], bd: &[f32], n: usize, orow: &mut [f32]) {
    for (p, &av) in arow.iter().enumerate() {
        if av == 0.0 {
            continue;
        }
        let brow = &bd[p * n..(p + 1) * n];
        for j in 0..n {
            orow[j] += av * brow[j];
        }
    }
}

/// C[m,n] = A[m,k] @ B[k,n], via the active [`kernels::kernel_policy`].
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    matmul_rows(a, b, 0, a.shape()[0])
}

/// [`matmul`] forced onto the scalar oracle (unrecorded).
pub fn matmul_scalar(a: &Tensor, b: &Tensor) -> Tensor {
    matmul_rows_scalar(a, b, 0, a.shape()[0])
}

/// [`matmul`] forced onto the blocked tier (unrecorded).
pub fn matmul_blocked(a: &Tensor, b: &Tensor) -> Tensor {
    matmul_rows_blocked(a, b, 0, a.shape()[0])
}

/// Rows `[r0, r1)` of `A[m,k] @ B[k,n]` as a `[r1 - r0, n]` tensor —
/// the independently-executable row block. Because each output row
/// touches only its own slice of `A` and accumulates in [`matmul_row`]'s
/// fixed order, workers computing disjoint row blocks produce exactly
/// the bytes of the corresponding [`matmul`] rows; stitching blocks
/// back together (in any order, by row index) is bit-identical to one
/// full-product call. [`matmul`] is the `[0, m)` block; no production
/// caller partitions yet — this is the bit-exactness-proven primitive
/// for intra-cell parallelism when single cells grow large enough to
/// need it.
pub fn matmul_rows(a: &Tensor, b: &Tensor, r0: usize, r1: usize) -> Tensor {
    let t0 = Instant::now();
    let out = match kernels::kernel_policy() {
        KernelPolicy::Scalar => matmul_rows_scalar(a, b, r0, r1),
        KernelPolicy::Blocked => matmul_rows_blocked(a, b, r0, r1),
    };
    let flops = 2 * ((r1 - r0) * a.shape()[1] * b.shape()[1]) as u64;
    kernels::record(KernelKind::MatMul, flops, t0.elapsed().as_nanos() as u64);
    out
}

/// [`matmul_rows`] forced onto the scalar oracle (unrecorded).
pub fn matmul_rows_scalar(a: &Tensor, b: &Tensor, r0: usize, r1: usize) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    assert!(r0 <= r1 && r1 <= m, "row block [{r0}, {r1}) out of 0..{m}");
    let rows = r1 - r0;
    let mut out = vec![0.0f32; rows * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..rows {
        let arow = &ad[(r0 + i) * k..(r0 + i + 1) * k];
        matmul_row(arow, bd, n, &mut out[i * n..(i + 1) * n]);
    }
    Tensor::new(&[rows, n], out).expect("matmul_rows shape")
}

/// [`matmul_rows`] forced onto the blocked tier (unrecorded).
pub fn matmul_rows_blocked(a: &Tensor, b: &Tensor, r0: usize, r1: usize) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    assert!(r0 <= r1 && r1 <= m, "row block [{r0}, {r1}) out of 0..{m}");
    let rows = r1 - r0;
    let mut out = vec![0.0f32; rows * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..rows {
        let arow = &ad[(r0 + i) * k..(r0 + i + 1) * k];
        kernels::row_f32_skip(arow, bd, n, &mut out[i * n..(i + 1) * n]);
    }
    Tensor::new(&[rows, n], out).expect("matmul_rows shape")
}

/// C[m,n] = A[k,m]^T @ B[k,n] (A stored transposed), via the active
/// [`kernels::kernel_policy`].
pub fn matmul_at(a: &Tensor, b: &Tensor) -> Tensor {
    let t0 = Instant::now();
    let out = match kernels::kernel_policy() {
        KernelPolicy::Scalar => matmul_at_scalar(a, b),
        KernelPolicy::Blocked => matmul_at_blocked(a, b),
    };
    let flops = 2 * (a.shape()[0] * a.shape()[1] * b.shape()[1]) as u64;
    kernels::record(KernelKind::MatMulAt, flops, t0.elapsed().as_nanos() as u64);
    out
}

/// [`matmul_at`] forced onto the scalar oracle (unrecorded).
pub fn matmul_at_scalar(a: &Tensor, b: &Tensor) -> Tensor {
    let (k, m) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul_at inner dims");
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for p in 0..k {
        let arow = &ad[p * m..(p + 1) * m];
        let brow = &bd[p * n..(p + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    Tensor::new(&[m, n], out).expect("matmul_at shape")
}

/// [`matmul_at`] forced onto the blocked tier (unrecorded): pack `A^T`
/// to row-major `[m, k]`, then run the skip row kernel. Packing moves
/// data, not arithmetic — each output element still accumulates in
/// ascending-`p` order with the same zero-skips, so the result is
/// byte-identical to the oracle's p-outer loop.
pub fn matmul_at_blocked(a: &Tensor, b: &Tensor) -> Tensor {
    let (k, m) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul_at inner dims");
    let ad = a.data();
    let bd = b.data();
    let mut at = vec![0.0f32; m * k];
    for p in 0..k {
        let arow = &ad[p * m..(p + 1) * m];
        for (i, &v) in arow.iter().enumerate() {
            at[i * k + p] = v;
        }
    }
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        kernels::row_f32_skip(&at[i * k..(i + 1) * k], bd, n, &mut out[i * n..(i + 1) * n]);
    }
    Tensor::new(&[m, n], out).expect("matmul_at shape")
}

/// C[m,n] = A[m,k] @ B[n,k]^T (B stored transposed — attention scores),
/// via the active [`kernels::kernel_policy`].
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let t0 = Instant::now();
    let out = match kernels::kernel_policy() {
        KernelPolicy::Scalar => matmul_bt_scalar(a, b),
        KernelPolicy::Blocked => matmul_bt_blocked(a, b),
    };
    let flops = 2 * (a.shape()[0] * a.shape()[1] * b.shape()[0]) as u64;
    kernels::record(KernelKind::MatMulBt, flops, t0.elapsed().as_nanos() as u64);
    out
}

/// [`matmul_bt`] forced onto the scalar oracle (unrecorded).
pub fn matmul_bt_scalar(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, k2) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul_bt inner dims");
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &bd[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            orow[j] = acc;
        }
    }
    Tensor::new(&[m, n], out).expect("matmul_bt shape")
}

/// [`matmul_bt`] forced onto the blocked tier (unrecorded): pack `B^T`
/// to row-major `[k, n]`, then run the dot row kernel (fresh zero
/// accumulator, no zero-skip, assignment — the oracle's exact
/// semantics for this variant).
pub fn matmul_bt_blocked(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, k2) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul_bt inner dims");
    let ad = a.data();
    let bd = b.data();
    let mut bt = vec![0.0f32; k * n];
    for j in 0..n {
        let brow = &bd[j * k..(j + 1) * k];
        for (p, &v) in brow.iter().enumerate() {
            bt[p * n + j] = v;
        }
    }
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        kernels::row_f32_dot(&ad[i * k..(i + 1) * k], &bt, n, &mut out[i * n..(i + 1) * n]);
    }
    Tensor::new(&[m, n], out).expect("matmul_bt shape")
}

/// Grouped matmul: x[g,m,k] @ w[g,k,n] -> [g,m,n], executed as an ordered
/// loop over groups. This mirrors the L1 grouped-GEMM kernel semantics:
/// per-group results are *identical* to g independent [`matmul`] calls,
/// which is what makes native diagonal == native sequential bit-exact.
pub fn grouped_matmul(x: &Tensor, w: &Tensor) -> Tensor {
    assert_eq!(x.rank(), 3);
    assert_eq!(w.rank(), 3);
    let g = x.shape()[0];
    assert_eq!(g, w.shape()[0], "group dims");
    let parts: Vec<Tensor> = (0..g).map(|i| matmul(&x.index0(i), &w.index0(i))).collect();
    let refs: Vec<&Tensor> = parts.iter().collect();
    Tensor::stack(&refs).expect("grouped stack")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += a.at2(i, p) * b.at2(p, j);
                }
                out.data_mut()[i * n + j] = acc;
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[7, 5], 1.0, &mut rng);
        let b = Tensor::randn(&[5, 9], 1.0, &mut rng);
        let got = matmul(&a, &b);
        let want = naive(&a, &b);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn matmul_at_is_transposed_a() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[5, 7], 1.0, &mut rng);
        let b = Tensor::randn(&[5, 3], 1.0, &mut rng);
        let got = matmul_at(&a, &b);
        let want = matmul(&a.t(), &b);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn matmul_bt_is_transposed_b() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let b = Tensor::randn(&[5, 6], 1.0, &mut rng);
        let got = matmul_bt(&a, &b);
        let want = matmul(&a, &b.t());
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn grouped_equals_independent() {
        let mut rng = Rng::new(4);
        let x = Tensor::randn(&[3, 4, 5], 1.0, &mut rng);
        let w = Tensor::randn(&[3, 5, 6], 1.0, &mut rng);
        let g = grouped_matmul(&x, &w);
        for i in 0..3 {
            let want = matmul(&x.index0(i), &w.index0(i));
            // bit-exact, not approximately equal
            assert_eq!(g.index0(i), want);
        }
    }

    #[test]
    fn row_blocks_stitch_bitexact() {
        // The worker-pool contract: any row partition, reassembled by
        // row index, is byte-identical to the one-shot product.
        let mut rng = Rng::new(6);
        let a = Tensor::randn(&[9, 7], 1.0, &mut rng);
        let b = Tensor::randn(&[7, 5], 1.0, &mut rng);
        let full = matmul(&a, &b);
        for blocks in [vec![(0, 9)], vec![(0, 4), (4, 9)], vec![(0, 3), (3, 6), (6, 9)]] {
            let parts: Vec<Tensor> =
                blocks.iter().map(|&(r0, r1)| matmul_rows(&a, &b, r0, r1)).collect();
            let refs: Vec<&Tensor> = parts.iter().collect();
            let stitched = Tensor::concat0(&refs).unwrap();
            assert_eq!(stitched, full); // bit-exact, not approx
        }
        // Empty block is a valid (degenerate) partition member.
        assert_eq!(matmul_rows(&a, &b, 4, 4).shape(), &[0, 5]);
    }

    #[test]
    fn identity() {
        let mut eye = Tensor::zeros(&[4, 4]);
        for i in 0..4 {
            eye.data_mut()[i * 4 + i] = 1.0;
        }
        let mut rng = Rng::new(5);
        let a = Tensor::randn(&[4, 4], 1.0, &mut rng);
        assert_eq!(matmul(&a, &eye), a);
    }

    /// The tentpole contract: the blocked tier is byte-identical
    /// (`to_bits`, not approx) to the scalar oracle for all four
    /// variants across ragged shapes straddling the register tile —
    /// 1, odd, JTILE-1, JTILE, JTILE+1, and a multi-tile size — with
    /// zeros (and negative zeros) sprinkled in to exercise the skip
    /// paths. The deeper grids live in `tests/kernel_parity.rs`.
    #[test]
    fn blocked_matches_scalar_bitexact_ragged() {
        let assert_bits = |x: &Tensor, y: &Tensor, ctx: &str| {
            assert_eq!(x.shape(), y.shape(), "{ctx}: shape");
            for (i, (a, b)) in x.data().iter().zip(y.data()).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: elem {i}: {a} vs {b}");
            }
        };
        let mut rng = Rng::new(0xB10C);
        for &(m, k, n) in
            &[(1, 1, 1), (3, 5, 31), (4, 7, 32), (2, 9, 33), (5, 33, 65), (7, 16, 96)]
        {
            let mut a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let mut b = Tensor::randn(&[k, n], 1.0, &mut rng);
            for (i, v) in a.data_mut().iter_mut().enumerate() {
                if i % 7 == 3 {
                    *v = 0.0;
                }
                if i % 11 == 5 {
                    *v = -0.0;
                }
            }
            if let Some(v) = b.data_mut().first_mut() {
                *v = -0.0;
            }
            let ctx = format!("m={m} k={k} n={n}");
            assert_bits(&matmul_blocked(&a, &b), &matmul_scalar(&a, &b), &ctx);
            // A^T path: reuse `a` transposed so shapes line up.
            let at = a.t();
            assert_bits(&matmul_at_blocked(&at, &b), &matmul_at_scalar(&at, &b), &ctx);
            // B^T path: b transposed to [n, k].
            let bt = b.t();
            assert_bits(&matmul_bt_blocked(&a, &bt), &matmul_bt_scalar(&a, &bt), &ctx);
            // Row blocks.
            let mid = m / 2;
            assert_bits(
                &matmul_rows_blocked(&a, &b, mid, m),
                &matmul_rows_scalar(&a, &b, mid, m),
                &ctx,
            );
        }
    }
}
