//! The benchmark subsystem: timing harness, suite registry and
//! machine-readable reports (criterion/serde are unavailable offline).
//!
//! Three layers:
//!
//! * **harness** (this file) — [`bench`]/[`bench_n`] time closures with
//!   warmup and robust statistics ([`Sample`]); [`Table`] prints the
//!   paper-table rows.
//! * **[`registry`]** — every benchmark is a named, tagged
//!   [`Suite`](registry::Suite) registered in [`suites::all`]. The
//!   `harness = false` binaries under `rust/benches/` are thin wrappers
//!   over [`registry::run_suite_main`]; the `diagonal-batching bench`
//!   subcommand runs any glob of suites in-process.
//! * **[`report`]** — the versioned `BENCH_*.json` schema
//!   ([`report::BenchReport`]) with run metadata (git sha, device,
//!   lanes) and the [`report::compare`] regression gate
//!   (`bench --compare BENCH_baseline.json --max-regression 1.15`).
//!
//! See `BENCHMARKS.md` at the repository root for the suite ↔ paper
//! figure/table mapping and the JSON schema reference.

pub mod registry;
pub mod report;
pub mod suites;

pub use registry::{glob_match, run_matching, run_suite_main, BenchSettings, Suite, SuiteCtx};
pub use report::{compare, BenchReport, CompareOutcome, SuiteStatus};

use std::time::{Duration, Instant};

/// Result of timing one benchmark case.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub stddev: Duration,
}

impl Sample {
    pub fn mean_s(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for Sample {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>10.3?} mean  {:>10.3?} median  {:>10.3?} min  ±{:>8.3?} ({} iters)",
            self.name, self.mean, self.median, self.min, self.stddev, self.iters
        )
    }
}

/// Time `f`, choosing the iteration count so total time ≈ `budget`.
/// Runs one untimed warmup call first (compilation caches, page faults).
pub fn bench(name: &str, budget: Duration, mut f: impl FnMut()) -> Sample {
    f(); // warmup
    let probe = {
        let t0 = Instant::now();
        f();
        t0.elapsed()
    };
    let iters = (budget.as_secs_f64() / probe.as_secs_f64().max(1e-9))
        .clamp(3.0, 1000.0) as usize;
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    summarize(name, &mut times)
}

/// Time `f` exactly `iters` times (no warmup heuristics) — for expensive
/// end-to-end cases where the caller controls the budget.
pub fn bench_n(name: &str, iters: usize, mut f: impl FnMut()) -> Sample {
    f(); // warmup
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    summarize(name, &mut times)
}

fn summarize(name: &str, times: &mut [Duration]) -> Sample {
    times.sort_unstable();
    let n = times.len();
    let total: Duration = times.iter().sum();
    let mean = total / n as u32;
    let median = times[n / 2];
    let min = times[0];
    let mean_s = mean.as_secs_f64();
    let var = times
        .iter()
        .map(|t| (t.as_secs_f64() - mean_s).powi(2))
        .sum::<f64>()
        / n as f64;
    Sample {
        name: name.to_string(),
        iters: n,
        mean,
        median,
        min,
        stddev: Duration::from_secs_f64(var.sqrt()),
    }
}

/// Markdown-ish table printer shared by the paper-table benches.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                } else {
                    widths.push(c.len());
                }
            }
        }
        println!("\n== {} ==", self.title);
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let w = widths.get(i).copied().unwrap_or(c.len());
                s.push_str(&format!(" {c:<w$} |"));
            }
            s
        };
        println!("{}", fmt_row(&self.header));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("{}", fmt_row(&sep));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

/// Format seconds the way the paper's tables do (3 significant digits).
pub fn fmt_s(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 10.0 {
        format!("{s:.1}")
    } else if s >= 1.0 {
        format!("{s:.2}")
    } else {
        format!("{s:.3}")
    }
}

/// Format a speedup like the paper: "x2.72".
pub fn fmt_x(x: f64) -> String {
    format!("x{x:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let s = bench_n("noop", 10, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters, 10);
        assert!(s.min <= s.median && s.median <= s.mean * 10);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_s(0.1234), "0.123");
        assert_eq!(fmt_s(2.345), "2.35");
        assert_eq!(fmt_s(23.45), "23.4");
        assert_eq!(fmt_s(234.5), "234");
        assert_eq!(fmt_x(2.716), "x2.72");
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print();
    }
}
