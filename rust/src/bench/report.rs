//! Machine-readable benchmark reports (`BENCH_*.json`) and the
//! baseline-comparison regression gate.
//!
//! A [`BenchReport`] is the versioned artifact `pallas-bench` writes:
//! run metadata (git sha, device model, lanes, host) plus one
//! [`SuiteReport`] per registered suite that matched the `--suite` glob.
//! Timing [`SampleStats`] are always lower-is-better; [`Metric`]s carry
//! an explicit [`Better`] direction so deterministic simulator outputs
//! (modeled seconds, speedups) can gate regressions across machines
//! while machine-dependent throughput numbers stay informational.
//!
//! Serialization uses the in-tree [`crate::json`] module (no serde in the
//! offline toolchain); [`BenchReport::from_json`] round-trips everything
//! [`BenchReport::to_json`] emits.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::bench::Sample;
use crate::error::{Error, Result};
use crate::json::Value;

/// Bump when the report layout changes incompatibly. Consumers must
/// reject versions they do not understand ([`BenchReport::from_json`]
/// does).
pub const SCHEMA_VERSION: usize = 1;

/// Which direction of change is an improvement for a metric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Better {
    /// Lower is better (modeled/measured seconds). Gated by `--compare`.
    Lower,
    /// Higher is better (speedups, occupancy). Gated by `--compare`.
    Higher,
    /// Informational only (machine-dependent throughput, counts);
    /// never gates.
    Info,
}

impl Better {
    fn as_str(self) -> &'static str {
        match self {
            Better::Lower => "lower",
            Better::Higher => "higher",
            Better::Info => "info",
        }
    }

    fn parse(s: &str) -> Result<Self> {
        match s {
            "lower" => Ok(Better::Lower),
            "higher" => Ok(Better::Higher),
            "info" => Ok(Better::Info),
            other => Err(Error::Bench(format!("unknown metric direction '{other}'"))),
        }
    }
}

/// One scalar result of a suite (deterministic simulator outputs or
/// measured serving statistics).
#[derive(Clone, Debug, PartialEq)]
pub struct Metric {
    pub name: String,
    pub value: f64,
    pub better: Better,
}

/// One timing measurement, in seconds (the JSON mirror of
/// [`Sample`](crate::bench::Sample)).
#[derive(Clone, Debug, PartialEq)]
pub struct SampleStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
    pub stddev_s: f64,
}

impl From<&Sample> for SampleStats {
    fn from(s: &Sample) -> Self {
        Self {
            name: s.name.clone(),
            iters: s.iters,
            mean_s: s.mean.as_secs_f64(),
            median_s: s.median.as_secs_f64(),
            min_s: s.min.as_secs_f64(),
            stddev_s: s.stddev.as_secs_f64(),
        }
    }
}

/// Outcome of one suite run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SuiteStatus {
    /// Ran to completion with every invariant check passing.
    Ok,
    /// Could not run here (e.g. HLO artifacts absent); `detail` says why.
    Skipped,
    /// An invariant check or the suite body failed; `detail` carries the
    /// error.
    Failed,
}

impl SuiteStatus {
    pub fn as_str(self) -> &'static str {
        match self {
            SuiteStatus::Ok => "ok",
            SuiteStatus::Skipped => "skipped",
            SuiteStatus::Failed => "failed",
        }
    }

    fn parse(s: &str) -> Result<Self> {
        match s {
            "ok" => Ok(SuiteStatus::Ok),
            "skipped" => Ok(SuiteStatus::Skipped),
            "failed" => Ok(SuiteStatus::Failed),
            other => Err(Error::Bench(format!("unknown suite status '{other}'"))),
        }
    }
}

/// Everything one suite produced.
#[derive(Clone, Debug, PartialEq)]
pub struct SuiteReport {
    pub name: String,
    pub tags: Vec<String>,
    pub status: SuiteStatus,
    /// Skip reason or failure message (empty when `status == Ok`).
    pub detail: String,
    pub samples: Vec<SampleStats>,
    pub metrics: Vec<Metric>,
    pub notes: Vec<String>,
}

impl SuiteReport {
    pub fn new(name: &str, tags: &[&str]) -> Self {
        Self {
            name: name.to_string(),
            tags: tags.iter().map(|t| t.to_string()).collect(),
            status: SuiteStatus::Ok,
            detail: String::new(),
            samples: Vec::new(),
            metrics: Vec::new(),
            notes: Vec::new(),
        }
    }
}

/// Run-level metadata: enough to interpret (and refuse to compare)
/// numbers from a different commit, device model or host.
#[derive(Clone, Debug, PartialEq)]
pub struct RunMeta {
    pub git_sha: String,
    pub crate_version: String,
    /// Simulated device model (`DeviceSpec::name`) the roofline suites
    /// used.
    pub device: String,
    pub peak_tflops: f64,
    pub mem_bw_gbs: f64,
    /// Wavefront lanes the serving suites ran with.
    pub lanes: usize,
    /// True when the CI-sized iteration budgets were used.
    pub fast: bool,
    /// Which step backends were available: always "native+simulated",
    /// plus "+hlo" when the AOT artifacts loaded.
    pub backend: String,
    pub os: String,
    pub arch: String,
    /// Seconds since the unix epoch at report creation.
    pub created_unix: u64,
}

/// The versioned `BENCH_*.json` document.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchReport {
    pub schema_version: usize,
    pub meta: RunMeta,
    pub suites: Vec<SuiteReport>,
}

impl BenchReport {
    pub fn to_json(&self) -> Value {
        let meta = Value::obj(vec![
            ("git_sha", Value::Str(self.meta.git_sha.clone())),
            ("crate_version", Value::Str(self.meta.crate_version.clone())),
            ("device", Value::Str(self.meta.device.clone())),
            ("peak_tflops", Value::Num(self.meta.peak_tflops)),
            ("mem_bw_gbs", Value::Num(self.meta.mem_bw_gbs)),
            ("lanes", Value::Num(self.meta.lanes as f64)),
            ("fast", Value::Bool(self.meta.fast)),
            ("backend", Value::Str(self.meta.backend.clone())),
            ("os", Value::Str(self.meta.os.clone())),
            ("arch", Value::Str(self.meta.arch.clone())),
            ("created_unix", Value::Num(self.meta.created_unix as f64)),
        ]);
        let suites = self
            .suites
            .iter()
            .map(|s| {
                Value::obj(vec![
                    ("name", Value::Str(s.name.clone())),
                    (
                        "tags",
                        Value::Arr(s.tags.iter().map(|t| Value::Str(t.clone())).collect()),
                    ),
                    ("status", Value::Str(s.status.as_str().to_string())),
                    ("detail", Value::Str(s.detail.clone())),
                    (
                        "samples",
                        Value::Arr(
                            s.samples
                                .iter()
                                .map(|m| {
                                    Value::obj(vec![
                                        ("name", Value::Str(m.name.clone())),
                                        ("iters", Value::Num(m.iters as f64)),
                                        ("mean_s", Value::Num(m.mean_s)),
                                        ("median_s", Value::Num(m.median_s)),
                                        ("min_s", Value::Num(m.min_s)),
                                        ("stddev_s", Value::Num(m.stddev_s)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    (
                        "metrics",
                        Value::Arr(
                            s.metrics
                                .iter()
                                .map(|m| {
                                    Value::obj(vec![
                                        ("name", Value::Str(m.name.clone())),
                                        ("value", Value::Num(m.value)),
                                        ("better", Value::Str(m.better.as_str().to_string())),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    (
                        "notes",
                        Value::Arr(s.notes.iter().map(|n| Value::Str(n.clone())).collect()),
                    ),
                ])
            })
            .collect();
        Value::obj(vec![
            ("schema_version", Value::Num(self.schema_version as f64)),
            ("meta", meta),
            ("suites", Value::Arr(suites)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let schema_version = v.req("schema_version")?.as_usize()?;
        if schema_version != SCHEMA_VERSION {
            return Err(Error::Bench(format!(
                "report schema version {schema_version} != supported {SCHEMA_VERSION}"
            )));
        }
        let m = v.req("meta")?;
        let meta = RunMeta {
            git_sha: m.req("git_sha")?.as_str()?.to_string(),
            crate_version: m.req("crate_version")?.as_str()?.to_string(),
            device: m.req("device")?.as_str()?.to_string(),
            peak_tflops: m.req("peak_tflops")?.as_f64()?,
            mem_bw_gbs: m.req("mem_bw_gbs")?.as_f64()?,
            lanes: m.req("lanes")?.as_usize()?,
            fast: m.req("fast")?.as_bool()?,
            backend: m.req("backend")?.as_str()?.to_string(),
            os: m.req("os")?.as_str()?.to_string(),
            arch: m.req("arch")?.as_str()?.to_string(),
            created_unix: m.req("created_unix")?.as_usize()? as u64,
        };
        let mut suites = Vec::new();
        for s in v.req("suites")?.as_arr()? {
            let mut samples = Vec::new();
            for m in s.req("samples")?.as_arr()? {
                samples.push(SampleStats {
                    name: m.req("name")?.as_str()?.to_string(),
                    iters: m.req("iters")?.as_usize()?,
                    mean_s: m.req("mean_s")?.as_f64()?,
                    median_s: m.req("median_s")?.as_f64()?,
                    min_s: m.req("min_s")?.as_f64()?,
                    stddev_s: m.req("stddev_s")?.as_f64()?,
                });
            }
            let mut metrics = Vec::new();
            for m in s.req("metrics")?.as_arr()? {
                metrics.push(Metric {
                    name: m.req("name")?.as_str()?.to_string(),
                    value: m.req("value")?.as_f64()?,
                    better: Better::parse(m.req("better")?.as_str()?)?,
                });
            }
            suites.push(SuiteReport {
                name: s.req("name")?.as_str()?.to_string(),
                tags: s
                    .req("tags")?
                    .as_arr()?
                    .iter()
                    .map(|t| Ok(t.as_str()?.to_string()))
                    .collect::<Result<Vec<_>>>()?,
                status: SuiteStatus::parse(s.req("status")?.as_str()?)?,
                detail: s.req("detail")?.as_str()?.to_string(),
                samples,
                metrics,
                notes: s
                    .req("notes")?
                    .as_arr()?
                    .iter()
                    .map(|n| Ok(n.as_str()?.to_string()))
                    .collect::<Result<Vec<_>>>()?,
            });
        }
        Ok(Self { schema_version, meta, suites })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, self.to_json().to_json() + "\n")?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Self::from_json(&Value::parse(&text)?)
    }

    /// True when no suite failed (skips are fine: a host without HLO
    /// artifacts must still get a green `pallas-bench` run).
    pub fn all_passed(&self) -> bool {
        self.suites.iter().all(|s| s.status != SuiteStatus::Failed)
    }
}

/// One gated quantity that got worse than the allowed ratio.
#[derive(Clone, Debug)]
pub struct Regression {
    pub suite: String,
    /// `sample:<name>` or `metric:<name>`.
    pub what: String,
    pub baseline: f64,
    pub current: f64,
    /// Worseness ratio, normalized so > 1.0 always means "worse"
    /// (current/baseline for lower-is-better, inverted for
    /// higher-is-better).
    pub ratio: f64,
}

/// Result of gating `current` against `baseline`.
#[derive(Clone, Debug, Default)]
pub struct CompareOutcome {
    /// Gated quantities present in both reports.
    pub compared: usize,
    /// Of those, how many got better or stayed equal.
    pub improved_or_equal: usize,
    pub regressions: Vec<Regression>,
    /// Suites/quantities in the baseline with no counterpart in the
    /// current report (warnings, not failures — a fast CI subset
    /// legitimately runs fewer suites than a full local baseline).
    pub missing_in_current: Vec<String>,
    /// RunMeta differences between the reports: a device-model mismatch
    /// makes every roofline number incomparable (see `incomparable`);
    /// lanes/fast mismatches are warnings (they shift the serving
    /// suites' gated utilization numbers).
    pub meta_mismatches: Vec<String>,
    /// True when the reports cannot be gated at all (different
    /// simulated device model) — `passed()` then fails loudly instead
    /// of passing vacuously.
    pub incomparable: bool,
}

impl CompareOutcome {
    pub fn passed(&self) -> bool {
        !self.incomparable && self.regressions.is_empty()
    }
}

/// Gate `current` against `baseline`: every timing sample and every
/// directional metric present in both reports must not be worse than
/// `max_ratio` times the baseline (e.g. 1.15 = 15% headroom).
/// `Better::Info` metrics and non-`Ok` suites never gate. Reports from
/// different simulated device models are refused (`incomparable`).
pub fn compare(baseline: &BenchReport, current: &BenchReport, max_ratio: f64) -> CompareOutcome {
    let mut out = CompareOutcome::default();
    // An unseeded placeholder baseline (no suites, or the seed's
    // sentinel git_sha) would compare zero quantities and "pass" every
    // run. That gate gates nothing — refuse it loudly instead.
    if baseline.suites.is_empty() || baseline.meta.git_sha == "unseeded-refresh-me" {
        let why = if baseline.suites.is_empty() {
            "it contains no suites"
        } else {
            "its git_sha is the unseeded sentinel"
        };
        out.meta_mismatches.push(format!(
            "baseline is an unseeded placeholder ({why}); gating against it would pass \
             vacuously — refresh it with `bench --json BENCH_baseline.json` on a \
             known-good commit"
        ));
        out.incomparable = true;
        return out;
    }
    if baseline.meta.device != current.meta.device {
        out.meta_mismatches.push(format!(
            "device: baseline '{}' vs current '{}' — roofline numbers are incomparable; \
             refresh the baseline on the same --device",
            baseline.meta.device, current.meta.device
        ));
        out.incomparable = true;
        return out;
    }
    if baseline.meta.lanes != current.meta.lanes {
        out.meta_mismatches.push(format!(
            "lanes: baseline {} vs current {} (serving-suite utilization gates are skewed)",
            baseline.meta.lanes, current.meta.lanes
        ));
    }
    if baseline.meta.fast != current.meta.fast {
        out.meta_mismatches.push(format!(
            "fast: baseline {} vs current {} (request counts / budgets differ)",
            baseline.meta.fast, current.meta.fast
        ));
    }
    let cur: BTreeMap<&str, &SuiteReport> =
        current.suites.iter().map(|s| (s.name.as_str(), s)).collect();
    for base in &baseline.suites {
        if base.status != SuiteStatus::Ok {
            continue;
        }
        let Some(&now) = cur.get(base.name.as_str()) else {
            out.missing_in_current.push(base.name.clone());
            continue;
        };
        if now.status != SuiteStatus::Ok {
            out.missing_in_current.push(format!("{} ({})", base.name, now.status.as_str()));
            continue;
        }
        let now_samples: BTreeMap<&str, &SampleStats> =
            now.samples.iter().map(|s| (s.name.as_str(), s)).collect();
        for bs in &base.samples {
            let Some(&ns) = now_samples.get(bs.name.as_str()) else {
                out.missing_in_current.push(format!("{}/sample:{}", base.name, bs.name));
                continue;
            };
            gate(&mut out, &base.name, &format!("sample:{}", bs.name), bs.mean_s, ns.mean_s, Better::Lower, max_ratio);
        }
        let now_metrics: BTreeMap<&str, &Metric> =
            now.metrics.iter().map(|m| (m.name.as_str(), m)).collect();
        for bm in &base.metrics {
            if bm.better == Better::Info {
                continue;
            }
            let Some(&nm) = now_metrics.get(bm.name.as_str()) else {
                out.missing_in_current.push(format!("{}/metric:{}", base.name, bm.name));
                continue;
            };
            gate(&mut out, &base.name, &format!("metric:{}", bm.name), bm.value, nm.value, bm.better, max_ratio);
        }
    }
    out
}

fn gate(
    out: &mut CompareOutcome,
    suite: &str,
    what: &str,
    baseline: f64,
    current: f64,
    better: Better,
    max_ratio: f64,
) {
    if !baseline.is_finite() || baseline <= 0.0 {
        return; // a degenerate baseline sets no bar
    }
    let ratio = match better {
        Better::Info => return,
        Better::Lower if current.is_finite() && current >= 0.0 => current / baseline,
        Better::Higher if current.is_finite() && current > 0.0 => baseline / current,
        // NaN, a negative timing, or a higher-is-better metric collapsing
        // to zero: the worst possible regression, not a silent pass.
        _ => f64::INFINITY,
    };
    out.compared += 1;
    if ratio <= 1.0 {
        out.improved_or_equal += 1;
    }
    if ratio > max_ratio {
        out.regressions.push(Regression {
            suite: suite.to_string(),
            what: what.to_string(),
            baseline,
            current,
            ratio,
        });
    }
}

/// Best-effort current commit sha, read straight from `.git` (no git
/// subprocess; works in the offline toolchain). Walks up from the
/// current directory so it works from the workspace root and from
/// `rust/` (where `cargo bench` runs). Returns "unknown" when no
/// repository is found.
pub fn git_sha() -> String {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    for _ in 0..6 {
        let git = dir.join(".git");
        if git.is_dir() {
            return read_git_head(&git).unwrap_or_else(|| "unknown".to_string());
        }
        if !dir.pop() {
            break;
        }
    }
    "unknown".to_string()
}

fn read_git_head(git: &Path) -> Option<String> {
    let head = std::fs::read_to_string(git.join("HEAD")).ok()?;
    let head = head.trim();
    if let Some(refname) = head.strip_prefix("ref: ") {
        if let Ok(sha) = std::fs::read_to_string(git.join(refname)) {
            return Some(sha.trim().to_string());
        }
        // Ref may only exist packed.
        let packed = std::fs::read_to_string(git.join("packed-refs")).ok()?;
        for line in packed.lines() {
            let line = line.trim();
            if let Some(sha) = line.strip_suffix(refname) {
                return Some(sha.trim().to_string());
            }
        }
        return None;
    }
    (head.len() == 40).then(|| head.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(mean_s: f64, speedup: f64) -> BenchReport {
        BenchReport {
            schema_version: SCHEMA_VERSION,
            meta: RunMeta {
                git_sha: "abc123".into(),
                crate_version: "0.2.0".into(),
                device: "A100-80G".into(),
                peak_tflops: 312.0,
                mem_bw_gbs: 2039.0,
                lanes: 2,
                fast: true,
                backend: "native+simulated".into(),
                os: "linux".into(),
                arch: "x86_64".into(),
                created_unix: 1_700_000_000,
            },
            suites: vec![SuiteReport {
                name: "table1_llama1b".into(),
                tags: vec!["table".into(), "simulated".into()],
                status: SuiteStatus::Ok,
                detail: String::new(),
                samples: vec![SampleStats {
                    name: "e2e".into(),
                    iters: 5,
                    mean_s,
                    median_s: mean_s,
                    min_s: mean_s * 0.9,
                    stddev_s: 0.01,
                }],
                metrics: vec![
                    Metric { name: "speedup@131072".into(), value: speedup, better: Better::Higher },
                    Metric { name: "tokens_per_s".into(), value: 1e6, better: Better::Info },
                ],
                notes: vec!["n".into()],
            }],
        }
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let r = report(0.123, 2.7);
        let text = r.to_json().to_json();
        let back = BenchReport::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn rejects_unknown_schema_version() {
        let v = r#"{"schema_version": 999, "meta": {}, "suites": []}"#;
        let parsed = Value::parse(v).unwrap();
        assert!(BenchReport::from_json(&parsed).is_err());
    }

    #[test]
    fn regression_gate_fires_on_slowdown() {
        // 50% slower sample than baseline: must fail a 15% gate.
        let baseline = report(0.100, 2.7);
        let slowed = report(0.150, 2.7);
        let out = compare(&baseline, &slowed, 1.15);
        assert!(!out.passed());
        assert_eq!(out.regressions.len(), 1);
        assert!(out.regressions[0].what.contains("sample:e2e"));
        assert!((out.regressions[0].ratio - 1.5).abs() < 1e-9);
    }

    #[test]
    fn regression_gate_fires_on_speedup_loss() {
        // Higher-is-better metric dropping 2.7 -> 2.0 is a regression.
        let baseline = report(0.100, 2.7);
        let worse = report(0.100, 2.0);
        let out = compare(&baseline, &worse, 1.15);
        assert!(!out.passed());
        assert!(out.regressions[0].what.contains("metric:speedup"));
    }

    #[test]
    fn device_mismatch_refuses_to_gate() {
        let baseline = report(0.100, 2.7);
        let mut h100 = report(0.100, 2.7);
        h100.meta.device = "H100-SXM".into();
        let out = compare(&baseline, &h100, 1.15);
        assert!(out.incomparable);
        assert!(!out.passed(), "a device mismatch must fail loudly, not pass vacuously");
        assert_eq!(out.compared, 0);
        assert!(out.meta_mismatches[0].contains("device"));
        // lanes/fast differences only warn.
        let mut lanes = report(0.100, 2.7);
        lanes.meta.lanes = 4;
        lanes.meta.fast = false;
        let out = compare(&baseline, &lanes, 1.15);
        assert!(out.passed());
        assert_eq!(out.meta_mismatches.len(), 2);
    }

    #[test]
    fn placeholder_baseline_refuses_to_gate() {
        // The seed ships an empty report with a sentinel git_sha; a
        // `--compare` against it compares nothing and must fail
        // loudly, not pass vacuously.
        let current = report(0.100, 2.7);
        let mut empty = report(0.100, 2.7);
        empty.suites.clear();
        let out = compare(&empty, &current, 1.15);
        assert!(out.incomparable);
        assert!(!out.passed());
        assert_eq!(out.compared, 0);
        assert!(out.meta_mismatches[0].contains("placeholder"), "{:?}", out.meta_mismatches);

        let mut sentinel = report(0.100, 2.7);
        sentinel.meta.git_sha = "unseeded-refresh-me".into();
        let out = compare(&sentinel, &current, 1.15);
        assert!(out.incomparable);
        assert!(!out.passed());
        assert!(out.meta_mismatches[0].contains("unseeded"), "{:?}", out.meta_mismatches);

        // A real baseline still gates normally.
        assert!(compare(&report(0.100, 2.7), &current, 1.15).passed());
    }

    #[test]
    fn collapsed_metric_is_a_regression_not_a_pass() {
        // A higher-is-better metric falling to 0 (or NaN) is the worst
        // regression there is — it must fail the gate, not skip it.
        let baseline = report(0.100, 2.7);
        let dead = report(0.100, 0.0);
        let out = compare(&baseline, &dead, 1.15);
        assert!(!out.passed());
        assert!(out
            .regressions
            .iter()
            .any(|r| r.what.contains("metric:speedup") && r.ratio.is_infinite()));

        let nan = report(f64::NAN, 2.7);
        let out = compare(&baseline, &nan, 1.15);
        assert!(!out.passed(), "NaN sample must not pass silently");
    }

    #[test]
    fn equal_and_improved_reports_pass() {
        let baseline = report(0.100, 2.7);
        assert!(compare(&baseline, &baseline, 1.15).passed());
        let faster = report(0.080, 3.0);
        let out = compare(&baseline, &faster, 1.15);
        assert!(out.passed());
        assert_eq!(out.improved_or_equal, out.compared);
    }

    #[test]
    fn info_metrics_and_missing_suites_never_gate() {
        let baseline = report(0.100, 2.7);
        let mut other = report(0.100, 2.7);
        other.suites[0].name = "renamed".into();
        other.suites[0].metrics[1].value = 1.0; // Info metric 1e6 -> 1.0
        let out = compare(&baseline, &other, 1.15);
        assert!(out.passed());
        assert_eq!(out.missing_in_current, vec!["table1_llama1b".to_string()]);
    }

    #[test]
    fn git_sha_reads_this_repo() {
        let sha = git_sha();
        // In a checkout this is a 40-hex sha; elsewhere "unknown".
        assert!(sha == "unknown" || sha.len() == 40, "{sha}");
    }
}
