//! The registered benchmark suites — every paper figure/table
//! reproduction and serving benchmark in one place.
//!
//! Each suite body is the old hand-rolled bench-binary `main()`,
//! reshaped over a [`SuiteCtx`]: human tables print exactly as before,
//! deterministic quantities (modeled seconds, speedups) are recorded as
//! gated metrics, wallclock measurements as samples, and the old
//! `assert!`s became `check`s that fail the suite instead of aborting
//! the whole run. Suites that need the AOT HLO artifacts skip cleanly
//! when `artifacts/manifest.json` (or PJRT itself) is unavailable —
//! the report still lists them, with status `skipped` and the reason.
//!
//! Simulated suites never skip: when the manifest is absent they fall
//! back to the built-in paper configs
//! ([`tables::paper_config`](crate::simulator::tables::paper_config)).

use std::time::Instant;

use crate::babilong::{accuracy, Episode, Generator, Task};
use crate::bench::registry::{Suite, SuiteCtx};
use crate::bench::{bench, bench_n, fmt_s, fmt_x, Table};
use crate::config::{BabilongSpec, ExecMode, ModelConfig};
use crate::coordinator::{Event, GenerateRequest, InferenceEngine, RequestQueue, Response};
use crate::error::{Error, Result};
use crate::gateway::{render_prometheus, FairScheduler, TenantSpec};
use crate::json::Value;
use crate::model::{NativeBackend, Params};
use crate::quality::{self, OverflowPolicy};
use crate::runtime::HloBackend;
use crate::server::{Client, Server, ServerOptions};
use crate::shard::{CoordinatorOptions, ShardCoordinator};
use crate::scheduler::{Executor, RunStats, ScheduleMode, StepBackend, WavefrontSession};
use crate::simulator::{ops, tables, DeviceSpec};
use crate::tensor::{
    grouped_matmul, kernel_policy, matmul, matmul_at_blocked, matmul_at_scalar, matmul_blocked,
    matmul_bt_blocked, matmul_bt_scalar, matmul_rows_blocked, matmul_rows_scalar, matmul_scalar,
    set_kernel_policy, KernelPolicy, Precision, Rng, Tensor, WeightMat,
};

/// Every registered suite, in paper order. The legacy bench binaries,
/// `pallas-bench` and the tests all select from this one list.
pub fn all() -> Vec<Suite> {
    vec![
        Suite {
            name: "fig1_headline",
            tags: &["fig", "simulated"],
            about: "Fig. 1: 1B ARMT + diagonal batching vs vanilla LLaMA-1B at 128k",
            run: fig1_headline,
        },
        Suite {
            name: "fig4_grouped_gemm",
            tags: &["fig", "simulated", "measured", "native"],
            about: "Fig. 4: grouped-GEMM throughput vs group size (+CPU analog)",
            run: fig4_grouped_gemm,
        },
        Suite {
            name: "fig5_attention",
            tags: &["fig", "simulated"],
            about: "Fig. 5: attention throughput vs batch size",
            run: fig5_attention,
        },
        Suite {
            name: "fig6_diag_vs_minibatch",
            tags: &["fig", "simulated"],
            about: "Fig. 6: time/segment, diagonal vs mini-batch vs ideal even load",
            run: fig6_diag_vs_minibatch,
        },
        Suite {
            name: "hotpath",
            tags: &["perf", "hlo", "measured"],
            about: "PJRT hot-path microbenchmarks (per-call costs, e2e schedules)",
            run: hotpath,
        },
        Suite {
            name: "table1_llama1b",
            tags: &["table", "simulated"],
            about: "Table 1: LLaMA-3.2-1B exec time, four (seg, mem) configurations",
            run: table1_llama1b,
        },
        Suite {
            name: "table2_error",
            tags: &["table", "hlo", "measured"],
            about: "Table 2: diagonal-vs-sequential logits drift on PJRT",
            run: table2_error,
        },
        Suite {
            name: "table5_llama3b",
            tags: &["table", "simulated"],
            about: "Table 5: llama-3.2-3b exec time vs sequence length",
            run: table5_llama3b,
        },
        Suite {
            name: "table6_llama8b",
            tags: &["table", "simulated"],
            about: "Table 6: llama-3.1-8b exec time vs sequence length",
            run: table6_llama8b,
        },
        Suite {
            name: "table7_llama160m",
            tags: &["table", "simulated"],
            about: "Table 7: llama-160m exec time vs sequence length",
            run: table7_llama160m,
        },
        Suite {
            name: "table8_vs_llama",
            tags: &["table", "simulated"],
            about: "Table 8: diagonal ARMT speedup vs full-attention LLaMA-1B",
            run: table8_vs_llama,
        },
        Suite {
            name: "table9_vs_armt",
            tags: &["table", "simulated", "hlo"],
            about: "Table 9: speedup vs sequential ARMT + measured runtime fallback",
            run: table9_vs_armt,
        },
        Suite {
            name: "throughput_packed",
            tags: &["serve", "native", "measured"],
            about: "Packed wavefront vs serial diagonal, 8 concurrent requests",
            run: throughput_packed,
        },
        Suite {
            name: "serve_latency",
            tags: &["serve", "native", "measured"],
            about: "serve_queue under concurrent synthetic load: p50/p90/p99",
            run: serve_latency,
        },
        Suite {
            name: "serve_generate",
            tags: &["serve", "native", "measured"],
            about: "multi-client generation burst: packed decode vs best solo run",
            run: serve_generate,
        },
        Suite {
            name: "parallel_scaling",
            tags: &["perf", "native", "measured"],
            about: "Pooled wavefront-step throughput at 1/2/4/8 worker threads",
            run: parallel_scaling,
        },
        Suite {
            name: "gemm_kernels",
            tags: &["perf", "native", "measured"],
            about: "GEMM tier: blocked SIMD vs scalar oracle + f16/bf16/int8 weight paths",
            run: gemm_kernels,
        },
        Suite {
            name: "cache_reuse",
            tags: &["serve", "native", "measured"],
            about: "Shared-prefix burst through the memory-state prefix cache",
            run: cache_reuse,
        },
        Suite {
            name: "shard_scaling",
            tags: &["serve", "native", "measured"],
            about: "Sharded serving: lane x1/x2 and layer-split pipelines vs 1 process",
            run: shard_scaling,
        },
        Suite {
            name: "gateway_fairness",
            tags: &["serve", "gateway", "native", "measured"],
            about: "Weighted-fair admission vs FIFO under a batch flood + token buckets",
            run: gateway_fairness,
        },
        Suite {
            name: "babilong_quality",
            tags: &["quality", "native", "measured"],
            about: "BABILong QA1/QA2 vs context: overflow off/select/chunked + quality gates",
            run: babilong_quality,
        },
    ]
}

/// Expected-invariant check: the paper-shape assertions of the old
/// bench binaries, as recoverable suite failures.
fn check(cond: bool, msg: impl Into<String>) -> Result<()> {
    if cond {
        Ok(())
    } else {
        Err(Error::Bench(msg.into()))
    }
}

/// Paper model config: from the manifest when present (source of
/// truth), else the built-in copy — so simulated suites run with zero
/// artifacts.
fn paper_cfg(ctx: &SuiteCtx, name: &str) -> Result<ModelConfig> {
    if let Some(m) = ctx.manifest() {
        if let Ok(c) = m.any_config(name) {
            return Ok(c.clone());
        }
    }
    tables::paper_config(name).ok_or_else(|| Error::Missing(format!("paper config '{name}'")))
}

// ---------------------------------------------------------------------------
// Figures
// ---------------------------------------------------------------------------

/// Fig. 1 headline: 1B ARMT with Diagonal Batching vs vanilla LLaMA-1B —
/// latency and memory at 128k tokens (paper: 3.3x faster, 167.1x memory
/// savings on A100, seg 1024).
fn fig1_headline(ctx: &mut SuiteCtx) -> Result<()> {
    let base = paper_cfg(ctx, "llama-3.2-1b")?;
    let dev = ctx.device();
    let rows = tables::fig1_rows(&base, &dev, &tables::SEQ_LENS);

    let mut t = Table::new(
        "Fig. 1 — LLaMA-1B: full attention vs ARMT + Diagonal Batching (seg 1024)",
        &["seq len", "llama (s)", "diag ARMT (s)", "speedup", "memory saving"],
    );
    for r in &rows {
        t.row(vec![
            r.seq_len.to_string(),
            fmt_s(r.llama_s),
            fmt_s(r.armt_diag_s),
            fmt_x(r.speedup),
            format!("{:.1}x", r.memory_saving),
        ]);
    }
    ctx.table(&t);

    let last = rows.last().unwrap();
    check(last.seq_len == 131072, "grid must end at 131072")?;
    check(last.speedup > 1.5, format!("128k speedup {}", last.speedup))?;
    check(last.memory_saving > 50.0, format!("memory saving {}", last.memory_saving))?;
    check(rows[0].speedup < 1.0, "short-context crossover must exist")?;
    ctx.metric_higher("speedup@131072", last.speedup);
    ctx.metric_higher("memory_saving@131072", last.memory_saving);
    ctx.metric_lower("armt_diag_s@131072", last.armt_diag_s);
    ctx.metric_lower("llama_s@131072", last.llama_s);
    ctx.note(format!(
        "headline @128k: {} faster, {:.1}x memory (paper: x3.3, 167.1x — same regime)",
        fmt_x(last.speedup),
        last.memory_saving
    ));
    Ok(())
}

/// Fig. 4: grouped GEMM throughput scales with group size like batched
/// GEMM scales with batch size (§4.1) — roofline curves plus a measured
/// CPU data point documenting why one core cannot show the GPU effect.
fn fig4_grouped_gemm(ctx: &mut SuiteCtx) -> Result<()> {
    let dev = ctx.device();
    let groups = [1usize, 2, 4, 8, 16, 32];

    for (label, key, m, n, k) in [
        ("LLaMA-1B linear: 1152 x 2048 x 2048", "1b", 1152usize, 2048usize, 2048usize),
        ("LLaMA-8B linear: 1152 x 4096 x 4096", "8b", 1152, 4096, 4096),
    ] {
        let rows = tables::fig4_grouped_gemm_rows(&dev, m, n, k, &groups);
        let mut t = Table::new(
            &format!("Fig. 4 — achieved TFLOP/s, {label} [simulated {}]", dev.name),
            &["group", "grouped GEMM", "batched GEMM"],
        );
        for (g, grouped, batched) in &rows {
            t.row(vec![g.to_string(), format!("{grouped:.1}"), format!("{batched:.1}")]);
        }
        ctx.table(&t);
        // monotone, and grouped tracks batched within 2x from group 4
        for w in rows.windows(2) {
            check(w[1].1 >= w[0].1 * 0.98, format!("{key}: non-monotone at group {}", w[1].0))?;
        }
        for (g, grouped, batched) in &rows {
            if *g >= 4 {
                check(grouped / batched > 0.5, format!("{key}: group {g} falls off batched"))?;
            }
        }
        let (_, grouped32, batched32) = rows.last().unwrap();
        ctx.metric_higher(format!("grouped_tflops@g32_{key}"), *grouped32);
        ctx.metric_higher(format!("batched_tflops@g32_{key}"), *batched32);
    }

    // measured CPU analog (small shapes; 1 core => flat scaling expected)
    let mut rng = Rng::new(1);
    let budget = ctx.budget(120);
    let mut t = Table::new(
        "Fig. 4 (CPU analog) — in-tree grouped matmul, 64x64x64, wallclock per group member",
        &["group", "grouped (us/member)", "independent (us/member)"],
    );
    for g in [1usize, 2, 4, 8] {
        let x = Tensor::randn(&[g, 64, 64], 1.0, &mut rng);
        let w = Tensor::randn(&[g, 64, 64], 1.0, &mut rng);
        let sg = bench(&format!("grouped g={g}"), budget, || {
            std::hint::black_box(grouped_matmul(&x, &w));
        });
        let xs: Vec<Tensor> = (0..g).map(|i| x.index0(i)).collect();
        let ws: Vec<Tensor> = (0..g).map(|i| w.index0(i)).collect();
        let si = bench(&format!("indep g={g}"), budget, || {
            for i in 0..g {
                std::hint::black_box(matmul(&xs[i], &ws[i]));
            }
        });
        t.row(vec![
            g.to_string(),
            format!("{:.1}", sg.mean_s() * 1e6 / g as f64),
            format!("{:.1}", si.mean_s() * 1e6 / g as f64),
        ]);
        // Info, not samples: this wallclock is machine-dependent and the
        // documented baseline refresh includes fig* — it must never gate
        // a CI runner against the refresh machine.
        ctx.metric_info(format!("grouped_us_per_member@g{g}"), sg.mean_s() * 1e6 / g as f64);
        ctx.metric_info(format!("indep_us_per_member@g{g}"), si.mean_s() * 1e6 / g as f64);
    }
    ctx.table(&t);
    ctx.note("shape checks passed");
    Ok(())
}

/// Fig. 5: attention throughput rises with batch size — diagonal
/// batching gets the same effect by treating the group as the batch
/// (§4.2, "our method does not modify the attention layer at all").
fn fig5_attention(ctx: &mut SuiteCtx) -> Result<()> {
    let base = paper_cfg(ctx, "llama-3.2-1b")?;
    let dev = ctx.device();
    let batches = [1usize, 2, 4, 8, 16, 32];

    for t_len in [640usize, 1152, 2176, 4224] {
        let rows = tables::fig5_attention_rows(&dev, &base, t_len, &batches);
        let mut t = Table::new(
            &format!(
                "Fig. 5 — attention relative FLOPS vs batch (T = {t_len}) [simulated {}]",
                dev.name
            ),
            &["batch", "relative FLOPS"],
        );
        for (b, rel) in &rows {
            t.row(vec![b.to_string(), format!("{rel:.2}x")]);
        }
        ctx.table(&t);
        check((rows[0].1 - 1.0).abs() < 1e-9, format!("T={t_len}: batch-1 baseline must be 1.0"))?;
        for w in rows.windows(2) {
            check(w[1].1 >= w[0].1 * 0.98, format!("T={t_len}: not monotone in batch"))?;
        }
    }
    // small segments leave more headroom: batch-16 gain shrinks with T
    let small = tables::fig5_attention_rows(&dev, &base, 640, &batches)[4].1;
    let large = tables::fig5_attention_rows(&dev, &base, 4224, &batches)[4].1;
    check(
        small >= large * 0.95,
        format!("short segments should gain at least as much from batching ({small} vs {large})"),
    )?;
    ctx.metric_higher("rel_flops@b16_t640", small);
    ctx.metric_higher("rel_flops@b16_t4224", large);
    ctx.note("shape checks passed");
    Ok(())
}

/// Fig. 6: time per segment — diagonal batching vs mini-batching of b
/// independent sequences vs the Ideal Even Load bound, per model.
fn fig6_diag_vs_minibatch(ctx: &mut SuiteCtx) -> Result<()> {
    let dev = ctx.device();
    let batches = [1usize, 2, 4, 8, 16];

    for model in tables::PAPER_MODELS {
        let base = paper_cfg(ctx, model)?;
        let rows = tables::fig6_rows(&base, &dev, 1024, 128, 32, &batches);
        let mut t = Table::new(
            &format!("Fig. 6 — time per segment, {model} (seg 1024, 32 segments)"),
            &["batch", "minibatch (s/seq-seg)", "diagonal (s/seg)", "ideal (s/seg)"],
        );
        for r in &rows {
            t.row(vec![
                r.batch.to_string(),
                fmt_s(r.minibatch_s),
                fmt_s(r.diagonal_s),
                fmt_s(r.ideal_s),
            ]);
        }
        ctx.table(&t);

        let b1 = &rows[0];
        check(
            b1.diagonal_s < b1.minibatch_s,
            format!("{model}: diagonal must beat unbatched sequential per-segment time"),
        )?;
        check(b1.ideal_s <= b1.diagonal_s * 1.02, format!("{model}: ideal is the bound"))?;
        // minibatch per-sequence time improves with batch; once the batch
        // exceeds L it can pass the L-wide "ideal even load" line (more
        // parallel work than the diagonal can ever expose), so the bound
        // only applies while batch <= n_layers.
        let blast = rows.last().unwrap();
        check(blast.minibatch_s < b1.minibatch_s, format!("{model}: batching must help"))?;
        if blast.batch <= base.n_layers {
            check(blast.minibatch_s >= blast.ideal_s * 0.90, format!("{model}: bound broken"))?;
        }
        ctx.metric_lower(format!("diagonal_s_per_seg@{model}"), b1.diagonal_s);
        ctx.metric_lower(format!("ideal_s_per_seg@{model}"), b1.ideal_s);
    }
    ctx.note("shape checks passed");
    Ok(())
}

// ---------------------------------------------------------------------------
// Hot-path microbenchmarks (real PJRT backend)
// ---------------------------------------------------------------------------

/// Hot-path microbenchmarks on the REAL PJRT backend: per-call cost of
/// every executable, end-to-end diagonal-vs-sequential wallclock, and
/// the launch-amortization demonstration on the launch-bound micro
/// model. Expectations on a 1-core CPU testbed: tiny (compute-bound)
/// loses wallclock under diagonal; micro (launch-bound) wins — the CPU
/// analog of the paper's GPU launch amortization.
fn hotpath(ctx: &mut SuiteCtx) -> Result<()> {
    let Some(manifest) = ctx.manifest().cloned() else {
        ctx.skip(format!(
            "{} not found (run `make artifacts` to build the AOT bundle)",
            ctx.settings().manifest_path
        ));
        return Ok(());
    };

    let mut loaded_any = false;
    for model in ["tiny", "tiny_ref", "toy", "micro"] {
        match HloBackend::load(&manifest, model) {
            Ok(backend) => {
                loaded_any = true;
                hotpath_per_step(ctx, backend, model)?;
            }
            Err(e) => ctx.note(format!("{model}: unavailable ({e})")),
        }
    }
    if !loaded_any {
        ctx.skip("no HLO model loaded (PJRT unavailable — see xla-stub crate docs)");
        return Ok(());
    }
    ctx.note("(tiny vs tiny_ref isolates interpret-mode Pallas overhead: same dims,");
    ctx.note(" jnp-lowered HLO instead of pallas interpret — the §Perf L2 A/B.)");

    ctx.note("-- end-to-end schedule comparison (PJRT CPU) --");
    let e2e_iters = ctx.iters(5);
    hotpath_end_to_end(ctx, &manifest, "tiny", 16, e2e_iters)?;
    hotpath_end_to_end(ctx, &manifest, "micro", 64, e2e_iters)?;

    // Launch-amortization table on the launch-bound model.
    let Ok(mut b) = HloBackend::load(&manifest, "micro") else {
        return Ok(());
    };
    let cfg = b.config().clone();
    let mut t = Table::new(
        "micro model: diagonal vs sequential wallclock by segment count",
        &["segments", "diag (ms)", "seq (ms)", "speedup"],
    );
    let iters = ctx.iters(3);
    let mut rng = Rng::new(13);
    for n_segments in [8usize, 16, 32, 64, 128] {
        let tokens: Vec<u32> =
            (0..n_segments * cfg.seg).map(|_| rng.below(cfg.vocab) as u32).collect();
        let d = bench_n("d", iters, || {
            std::hint::black_box(
                Executor::new(&mut b, ScheduleMode::Diagonal).run(&tokens).unwrap(),
            );
        });
        let s = bench_n("s", iters, || {
            std::hint::black_box(
                Executor::new(&mut b, ScheduleMode::Sequential).run(&tokens).unwrap(),
            );
        });
        t.row(vec![
            n_segments.to_string(),
            format!("{:.1}", d.mean_s() * 1e3),
            format!("{:.1}", s.mean_s() * 1e3),
            format!("x{:.2}", s.mean_s() / d.mean_s()),
        ]);
        if n_segments == 64 {
            ctx.metric_info("micro_speedup@s64", s.mean_s() / d.mean_s());
        }
    }
    ctx.table(&t);
    Ok(())
}

fn hotpath_per_step(ctx: &mut SuiteCtx, mut b: HloBackend, model: &str) -> Result<()> {
    let cfg = b.config().clone();
    let l = cfg.n_layers;
    let mut rng = Rng::new(7);
    let x = Tensor::randn(&[l, cfg.seg_total, cfg.d_model], 0.5, &mut rng);
    let a = Tensor::zeros(&[l, cfg.d_model, cfg.phi_dim]);
    let z = Tensor::zeros(&[l, cfg.phi_dim]);
    let mask = vec![1.0; l];
    let x1 = x.index0(0);
    let a1 = a.index0(0);
    let z1 = z.index0(0);
    let toks: Vec<u32> = (0..cfg.seg as u32).collect();

    ctx.note(format!("-- {model}: per-call costs (L = {l}) --"));
    let step_budget = ctx.budget(400);
    let aux_budget = ctx.budget(200);
    let g = bench(&format!("{model}/grouped_step"), step_budget, || {
        std::hint::black_box(b.grouped_step(&x, &a, &z, &mask).unwrap());
    });
    ctx.sample(&g);
    let s = bench(&format!("{model}/single_step"), step_budget, || {
        std::hint::black_box(b.single_step(0, &x1, &a1, &z1).unwrap());
    });
    ctx.sample(&s);
    let e = bench(&format!("{model}/embed"), aux_budget, || {
        std::hint::black_box(b.embed(&toks).unwrap());
    });
    ctx.sample(&e);
    let y = b.embed(&toks)?;
    let h = bench(&format!("{model}/lm_head"), aux_budget, || {
        std::hint::black_box(b.lm_head(&y).unwrap());
    });
    ctx.sample(&h);
    ctx.metric_info(format!("grouped_over_single@{model}"), g.mean_s() / s.mean_s());
    ctx.note(format!(
        "grouped/single ratio: {:.2} (L = {l}; < L means grouping amortizes overhead)",
        g.mean_s() / s.mean_s()
    ));
    // §Perf counterfactual: what every step would pay without resident
    // parameter buffers.
    let up = b.param_upload_cost()?;
    ctx.note(format!(
        "param re-upload counterfactual: {up:?}/step avoided ({:.0}% of a grouped step)",
        100.0 * up.as_secs_f64() / g.mean_s()
    ));
    Ok(())
}

fn hotpath_end_to_end(
    ctx: &mut SuiteCtx,
    manifest: &crate::config::Manifest,
    model: &str,
    n_segments: usize,
    iters: usize,
) -> Result<()> {
    let Ok(mut b) = HloBackend::load(manifest, model) else {
        return Ok(());
    };
    let cfg = b.config().clone();
    let mut rng = Rng::new(11);
    let tokens: Vec<u32> =
        (0..n_segments * cfg.seg).map(|_| rng.below(cfg.vocab) as u32).collect();

    let d = bench_n(&format!("{model}/e2e diagonal S={n_segments}"), iters, || {
        std::hint::black_box(
            Executor::new(&mut b, ScheduleMode::Diagonal).run(&tokens).unwrap(),
        );
    });
    let s = bench_n(&format!("{model}/e2e sequential S={n_segments}"), iters, || {
        std::hint::black_box(
            Executor::new(&mut b, ScheduleMode::Sequential).run(&tokens).unwrap(),
        );
    });
    ctx.sample(&d);
    ctx.sample(&s);
    ctx.note(format!(
        "diagonal speedup: x{:.2}  (launches {} vs {})",
        s.mean_s() / d.mean_s(),
        n_segments + cfg.n_layers - 1,
        n_segments * cfg.n_layers,
    ));
    Ok(())
}

// ---------------------------------------------------------------------------
// Tables
// ---------------------------------------------------------------------------

/// Table 1: LLaMA-3.2-1B ARMT execution time vs sequence length, four
/// (segment_size, memory_tokens) configurations, roofline model.
/// Paper shape: speedup grows with length, largest for small segments.
fn table1_llama1b(ctx: &mut SuiteCtx) -> Result<()> {
    let base = paper_cfg(ctx, "llama-3.2-1b")?;
    let dev = ctx.device();

    for (seg, mem) in [(512usize, 128usize), (1024, 128), (2048, 128), (4096, 128)] {
        let rows = tables::exec_time_rows(&base, &dev, seg, mem, &tables::SEQ_LENS);
        let mut t = Table::new(
            &format!("Table 1 — LLama-3.2-1B, configuration ({seg}, {mem}) [simulated {}]", dev.name),
            &["method", "4096", "8192", "16384", "32768", "65536", "131072"],
        );
        t.row(std::iter::once("Llama-3.2-1B".into())
            .chain(rows.iter().map(|r| fmt_s(r.llama_s))).collect());
        t.row(std::iter::once("LLama-3.2-1B-ARMT".into())
            .chain(rows.iter().map(|r| fmt_s(r.armt_seq_s))).collect());
        t.row(std::iter::once("Diagonal Batching".into())
            .chain(rows.iter().map(|r| fmt_s(r.armt_diag_s))).collect());
        t.row(std::iter::once("speedup".into())
            .chain(rows.iter().map(|r| fmt_x(r.speedup_vs_armt()))).collect());
        ctx.table(&t);

        let last = rows.last().unwrap();
        check(last.speedup_vs_armt() > 1.0, format!("diag must win at 131k (seg {seg})"))?;
        check(
            rows[0].speedup_vs_armt() < last.speedup_vs_armt(),
            "speedup must grow with length",
        )?;
        ctx.metric_higher(format!("speedup_vs_armt@seg{seg}@131072"), last.speedup_vs_armt());
        ctx.metric_lower(format!("armt_diag_s@seg{seg}@131072"), last.armt_diag_s);
    }
    // paper: smaller segments benefit more
    let s512 = tables::exec_time_rows(&base, &dev, 512, 128, &[131072])[0].speedup_vs_armt();
    let s4096 = tables::exec_time_rows(&base, &dev, 4096, 128, &[131072])[0].speedup_vs_armt();
    check(s512 > s4096, "seg 512 must out-speedup seg 4096")?;
    ctx.note(format!(
        "shape checks passed: speedup grows with length; seg 512 ({}) > seg 4096 ({})",
        fmt_x(s512),
        fmt_x(s4096)
    ));
    Ok(())
}

/// Table 2: error accumulation of Diagonal Batching vs sequential ARMT —
/// MEASURED on the real PJRT artifacts (not simulated). Paper bound:
/// relative logits drift < 2% out to 32 segments.
fn table2_error(ctx: &mut SuiteCtx) -> Result<()> {
    let Some(manifest) = ctx.manifest().cloned() else {
        ctx.skip(format!(
            "{} not found (run `make artifacts` to build the AOT bundle)",
            ctx.settings().manifest_path
        ));
        return Ok(());
    };
    let mut hlo = match HloBackend::load(&manifest, "tiny") {
        Ok(b) => b,
        Err(e) => {
            ctx.skip(format!("HLO backend unavailable: {e}"));
            return Ok(());
        }
    };
    let cfg = hlo.config().clone();
    let params = match Params::load(&manifest, "tiny") {
        Ok(p) => p,
        Err(e) => {
            ctx.skip(format!("params.bin unavailable: {e}"));
            return Ok(());
        }
    };
    let mut native = NativeBackend::new(cfg.clone(), params);

    let mut t = Table::new(
        "Table 2 — relative logits error (%) vs number of segments (tiny model, PJRT CPU)",
        &["segments", "diag vs seq (HLO)", "HLO vs native oracle", "argmax agreement %"],
    );

    let seg_counts: &[usize] =
        if ctx.settings().fast { &[1, 2, 4, 8] } else { &[1, 2, 4, 8, 16, 32] };
    let mut rng = Rng::new(2024);
    let mut worst_rel = 0.0f64;
    for &n_segments in seg_counts {
        let tokens: Vec<u32> =
            (0..n_segments * cfg.seg).map(|_| rng.below(cfg.vocab) as u32).collect();
        let d = Executor::new(&mut hlo, ScheduleMode::Diagonal).run(&tokens)?;
        let s = Executor::new(&mut hlo, ScheduleMode::Sequential).run(&tokens)?;
        let n = Executor::new(&mut native, ScheduleMode::Sequential).run(&tokens)?;
        let ds = d.stacked()?;
        let ss = s.stacked()?;
        let ns = n.stacked()?;
        let rel_hlo = ds.rel_error(&ss) as f64;
        let rel_native = ds.rel_error(&ns) as f64;
        let (ad, asq) = (ds.argmax_rows(), ss.argmax_rows());
        let agree =
            ad.iter().zip(&asq).filter(|(x, y)| x == y).count() as f64 / ad.len() as f64;
        t.row(vec![
            n_segments.to_string(),
            format!("{:.5}", rel_hlo * 100.0),
            format!("{:.5}", rel_native * 100.0),
            format!("{:.2}", agree * 100.0),
        ]);
        check(rel_hlo < 0.02, format!("paper bound: < 2% at S={n_segments}"))?;
        check(agree > 0.99, format!("argmax agreement at S={n_segments}"))?;
        worst_rel = worst_rel.max(rel_hlo);
    }
    ctx.table(&t);
    ctx.metric_info("worst_rel_err_pct", worst_rel * 100.0);
    ctx.note("all rows under the paper's 2% bound (CPU-PJRT reduction orders are");
    ctx.note("deterministic, so drift is far below the paper's CUDA measurement).");
    Ok(())
}

/// Shared body of Tables 5/6/7: one model's exec-time table at seg 1024
/// and 4096, with the "diag wins at long contexts" shape checks.
fn model_exec_table(
    ctx: &mut SuiteCtx,
    table_label: &str,
    model: &str,
    min_speedup_131k: f64,
) -> Result<()> {
    let base = paper_cfg(ctx, model)?;
    let dev = ctx.device();
    for seg in [1024usize, 4096] {
        let rows = tables::exec_time_rows(&base, &dev, seg, 128, &tables::SEQ_LENS);
        let mut t = Table::new(
            &format!("{table_label} — {model}, configuration ({seg}, 128) [simulated {}]", dev.name),
            &["method", "4096", "8192", "16384", "32768", "65536", "131072"],
        );
        t.row(std::iter::once(format!("{model} (full attn)"))
            .chain(rows.iter().map(|r| fmt_s(r.llama_s))).collect());
        t.row(std::iter::once("ARMT sequential".into())
            .chain(rows.iter().map(|r| fmt_s(r.armt_seq_s))).collect());
        t.row(std::iter::once("Diagonal Batching".into())
            .chain(rows.iter().map(|r| fmt_s(r.armt_diag_s))).collect());
        t.row(std::iter::once("speedup".into())
            .chain(rows.iter().map(|r| fmt_x(r.speedup_vs_armt()))).collect());
        ctx.table(&t);
        let last = rows.last().unwrap();
        check(
            last.speedup_vs_armt() > min_speedup_131k,
            format!("diag speedup at 131k (seg {seg}): {}", last.speedup_vs_armt()),
        )?;
        check(
            rows[0].speedup_vs_armt() <= last.speedup_vs_armt() + 1e-9,
            format!("{model}: speedup must not shrink with length (seg {seg})"),
        )?;
        ctx.metric_higher(format!("speedup_vs_armt@seg{seg}@131072"), last.speedup_vs_armt());
        ctx.metric_lower(format!("armt_diag_s@seg{seg}@131072"), last.armt_diag_s);
    }
    ctx.note("shape checks passed");
    Ok(())
}

fn table5_llama3b(ctx: &mut SuiteCtx) -> Result<()> {
    model_exec_table(ctx, "Table 5", "llama-3.2-3b", 1.05)
}

fn table6_llama8b(ctx: &mut SuiteCtx) -> Result<()> {
    model_exec_table(ctx, "Table 6", "llama-3.1-8b", 1.02)
}

fn table7_llama160m(ctx: &mut SuiteCtx) -> Result<()> {
    model_exec_table(ctx, "Table 7", "llama-160m", 1.3)
}

/// Table 8: Diagonal-Batching ARMT speedup over vanilla full-attention
/// LLaMA-3.2-1B. Paper shape: loses/ties at short lengths, wins
/// increasingly at long lengths.
fn table8_vs_llama(ctx: &mut SuiteCtx) -> Result<()> {
    let base = paper_cfg(ctx, "llama-3.2-1b")?;
    let dev = ctx.device();

    let mut t = Table::new(
        "Table 8 — Diagonal Batching speedup vs LLama-3.2-1B (full attention)",
        &["configuration", "4096", "8192", "16384", "32768", "65536", "131072"],
    );
    let mut growth_ok = true;
    let mut long_ctx_win = false;
    for seg in [512usize, 1024, 2048, 4096] {
        let rows = tables::exec_time_rows(&base, &dev, seg, 128, &tables::SEQ_LENS);
        t.row(
            std::iter::once(format!("({seg}, 128)"))
                .chain(rows.iter().map(|r| fmt_x(r.speedup_vs_llama())))
                .collect(),
        );
        let sp: Vec<f64> = rows.iter().map(|r| r.speedup_vs_llama()).collect();
        growth_ok &= sp.windows(2).all(|w| w[1] >= w[0] * 0.98);
        long_ctx_win |= *sp.last().unwrap() > 1.5;
        ctx.metric_higher(format!("speedup_vs_llama@seg{seg}@131072"), *sp.last().unwrap());
    }
    ctx.table(&t);
    check(growth_ok, "speedup vs llama must grow with length")?;
    check(long_ctx_win, "ARMT must clearly beat full attention at 131k")?;
    ctx.note("shape checks passed: monotone growth, long-context win");
    Ok(())
}

/// Table 9: Diagonal-Batching speedup over sequential ARMT, plus the
/// caption's runtime-fallback demonstration, measured on the PJRT CPU
/// backend when artifacts are available.
fn table9_vs_armt(ctx: &mut SuiteCtx) -> Result<()> {
    let base = paper_cfg(ctx, "llama-3.2-1b")?;
    let dev = ctx.device();

    let mut t = Table::new(
        "Table 9 — Diagonal Batching speedup vs sequential ARMT (LLama-3.2-1B)",
        &["configuration", "4096", "8192", "16384", "32768", "65536", "131072"],
    );
    for seg in [512usize, 1024, 2048, 4096] {
        let rows = tables::exec_time_rows(&base, &dev, seg, 128, &tables::SEQ_LENS);
        t.row(
            std::iter::once(format!("({seg}, 128)"))
                .chain(rows.iter().map(|r| fmt_x(r.speedup_vs_armt())))
                .collect(),
        );
        ctx.metric_higher(
            format!("speedup_vs_armt@seg{seg}@131072"),
            rows.last().unwrap().speedup_vs_armt(),
        );
    }
    ctx.table(&t);

    // ---- measured fallback policy on the real backend --------------------
    let measured = ctx.manifest().cloned().and_then(|m| HloBackend::load(&m, "micro").ok());
    let Some(backend) = measured else {
        ctx.note("fallback policy check skipped: micro HLO artifacts unavailable");
        return Ok(());
    };
    ctx.note("fallback policy (measured, micro model on PJRT CPU):");
    let mut engine = InferenceEngine::new(backend, ExecMode::Auto);
    let cal = engine.calibrate(ctx.iters(5))?;
    ctx.note(format!(
        "  calibrated: grouped {:.3} ms, single {:.3} ms, crossover {} segments",
        cal.grouped_step_s * 1e3,
        cal.single_step_s * 1e3,
        cal.crossover_segments()
    ));
    let seg = engine.config().seg;
    let vocab = engine.config().vocab as u32;
    for n_segments in [1usize, 2, 64] {
        let tokens: Vec<u32> = (0..n_segments * seg).map(|i| i as u32 % vocab).collect();
        let resp = engine.process(&GenerateRequest::new(n_segments as u64, tokens))?;
        ctx.note(format!(
            "  {n_segments:>3} segments -> {} ({:?})",
            resp.mode_used, resp.stats.wall
        ));
        if n_segments >= 64 {
            check(resp.mode_used == ExecMode::Diagonal, "long request must go diagonal")?;
        }
    }
    ctx.note("shape checks passed");
    Ok(())
}

// ---------------------------------------------------------------------------
// Serving
// ---------------------------------------------------------------------------

/// Tiny native-backend model for the serving suites (no artifacts
/// needed — the quantity under test is the scheduler's utilization and
/// the engine's latency distribution, not model quality).
fn serving_config() -> ModelConfig {
    ModelConfig {
        name: "serve-bench".into(),
        vocab: 64,
        d_model: 32,
        n_layers: 4,
        n_heads: 2,
        d_ff: 48,
        seg: 8,
        mem: 4,
        k_assoc: 8,
        dpfp_nu: 3,
        rope_theta: 10000.0,
        eps: 1e-6,
        attn_buckets: vec![],
        head_dim: 16,
        phi_dim: 48,
        seg_total: 12,
    }
}

struct PackedRow {
    label: String,
    stats: RunStats,
    wall_s: f64,
    tokens: usize,
}

/// Packed-wavefront serving throughput: 8 concurrent short requests
/// through one `WavefrontSession` vs the same requests run serially,
/// each as its own diagonal wavefront. Native backend only; the
/// quantity under test is the *scheduler's* utilization (launches, mean
/// group, occupancy) — on one CPU core wallclock is flat either way,
/// which the table makes visible rather than hiding.
fn throughput_packed(ctx: &mut SuiteCtx) -> Result<()> {
    let cfg = serving_config();
    let n_requests = 8;
    let segments = 6;
    let mut rng = Rng::new(2024);
    let reqs: Vec<Vec<u32>> = (0..n_requests)
        .map(|_| (0..segments * cfg.seg).map(|_| rng.below(cfg.vocab) as u32).collect())
        .collect();

    let serial = {
        let mut backend = NativeBackend::new(cfg.clone(), Params::random(&cfg, 7));
        let t0 = Instant::now();
        let mut agg = RunStats { mode_diagonal: true, ..RunStats::default() };
        for toks in &reqs {
            let out = Executor::new(&mut backend, ScheduleMode::Diagonal).run(toks)?;
            agg.segments += out.stats.segments;
            agg.launches += out.stats.launches;
            agg.cells += out.stats.cells;
            agg.slot_steps += out.stats.slot_steps;
            agg.padded_cells += out.stats.padded_cells;
            agg.tokens += out.stats.tokens;
        }
        PackedRow {
            label: "serial per-request diagonal".into(),
            wall_s: t0.elapsed().as_secs_f64(),
            tokens: agg.tokens,
            stats: agg,
        }
    };

    let packed = |lanes: usize| -> Result<PackedRow> {
        let mut backend = NativeBackend::new(cfg.clone(), Params::random(&cfg, 7));
        let mut session = WavefrontSession::new(cfg.clone(), lanes);
        let t0 = Instant::now();
        for (i, toks) in reqs.iter().enumerate() {
            session.submit(i as u64, toks)?;
        }
        session.run_to_completion(&mut backend)?;
        check(session.drain_completed().len() == reqs.len(), "all requests must complete")?;
        let stats = session.stats();
        Ok(PackedRow {
            label: format!("packed session, {lanes} lane{}", if lanes == 1 { "" } else { "s" }),
            wall_s: t0.elapsed().as_secs_f64(),
            tokens: stats.tokens,
            stats,
        })
    };

    let mut rows = vec![serial];
    for lanes in [1usize, 2, 4] {
        rows.push(packed(lanes)?);
    }

    let mut t = Table::new(
        &format!(
            "{n_requests} concurrent requests x {segments} segments (L = {}): \
             packed wavefront vs serial diagonal",
            cfg.n_layers
        ),
        &[
            "schedule",
            "launches",
            "mean group",
            "padded cells",
            "occupancy",
            "padded/request",
            "tokens/s",
        ],
    );
    for r in &rows {
        t.row(vec![
            r.label.clone(),
            r.stats.launches.to_string(),
            format!("{:.2}", r.stats.mean_group()),
            r.stats.padded_cells.to_string(),
            format!("{:.3}", r.stats.occupancy()),
            format!("{:.1}", r.stats.padded_cells as f64 / n_requests as f64),
            format!("{:.0}", r.tokens as f64 / r.wall_s),
        ]);
    }
    ctx.table(&t);

    // Acceptance shape: packing >= 2 concurrent requests beats serial
    // per-request diagonal on mean group / padded cells per request.
    let serial = &rows[0];
    for packed_row in &rows[1..] {
        check(
            packed_row.stats.mean_group() > serial.stats.mean_group(),
            format!(
                "{}: mean group {:.3} must beat serial {:.3}",
                packed_row.label,
                packed_row.stats.mean_group(),
                serial.stats.mean_group()
            ),
        )?;
        check(
            packed_row.stats.padded_cells < serial.stats.padded_cells,
            format!(
                "{}: padded {} must be below serial {}",
                packed_row.label, packed_row.stats.padded_cells, serial.stats.padded_cells
            ),
        )?;
        check(packed_row.stats.cells == serial.stats.cells, "same work either way")?;
    }
    let best = rows.last().unwrap();
    ctx.metric_higher("mean_group@lanes4", best.stats.mean_group());
    ctx.metric_higher("occupancy@lanes4", best.stats.occupancy());
    ctx.metric_info("tokens_per_s@lanes4", best.tokens as f64 / best.wall_s);
    ctx.note("OK: cross-request packing raised mean group and cut padded cells per request");
    Ok(())
}

/// Parallel wavefront-step throughput: the same long request through
/// the same 12-layer model on worker pools of 1/2/4/8 threads. Every
/// wavefront iteration carries up to `L = 12` independent cells, so a
/// `T`-thread pool should approach `min(T, cores, 12)x` step
/// throughput; the suite reports the measured speedup curve, verifies
/// the logits stay BYTE-identical across thread counts, and (on hosts
/// with >= 2 cores) gates that parallelism actually materializes.
/// Wallclock metrics are `info` — machine-dependent, never compared
/// against a baseline from another machine.
fn parallel_scaling(ctx: &mut SuiteCtx) -> Result<()> {
    // >= 12 layers (ISSUE acceptance) with cells heavy enough that
    // per-cell compute dwarfs the pool's channel round-trip.
    let cfg = ModelConfig {
        name: "parallel-bench".into(),
        vocab: 64,
        d_model: 96,
        n_layers: 12,
        n_heads: 2,
        d_ff: 192,
        seg: 16,
        mem: 4,
        k_assoc: 8,
        dpfp_nu: 2,
        rope_theta: 10000.0,
        eps: 1e-6,
        attn_buckets: vec![],
        head_dim: 48,
        phi_dim: 32,
        seg_total: 20,
    };
    let segments = if ctx.settings().fast { 20 } else { 40 };
    let reps = ctx.iters(3);
    let tokens: Vec<u32> =
        (0..(segments * cfg.seg) as u32).map(|t| (t * 31 + 7) % cfg.vocab as u32).collect();
    let iterations = (segments + cfg.n_layers - 1) as f64;
    let cells = (segments * cfg.n_layers) as f64;

    let thread_counts = [1usize, 2, 4, 8];
    let mut walls = Vec::new();
    let mut reference: Option<Vec<Tensor>> = None;
    for &threads in &thread_counts {
        let mut backend =
            NativeBackend::new(cfg.clone(), Params::random(&cfg, 11)).with_threads(threads);
        let mut best = f64::INFINITY;
        let mut logits = Vec::new();
        for _ in 0..reps {
            let mut session = WavefrontSession::new(cfg.clone(), 1);
            session.submit(1, &tokens)?;
            let t0 = Instant::now();
            session.run_to_completion(&mut backend)?;
            best = best.min(t0.elapsed().as_secs_f64());
            logits = session
                .pop_completed()
                .ok_or_else(|| Error::Bench("wavefront produced no output".into()))?
                .logits;
        }
        // The whole point: more threads may only change the wall-clock.
        match &reference {
            None => reference = Some(logits),
            Some(r) => check(
                *r == logits,
                format!("{threads} threads changed the output bytes"),
            )?,
        }
        walls.push(best);
    }

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut t = Table::new(
        &format!(
            "parallel_scaling — {segments} segments x {} layers, 1 lane ({} core host)",
            cfg.n_layers, cores
        ),
        &["threads", "wall (ms)", "steps/s", "cells/s", "speedup vs 1"],
    );
    for (&threads, &wall) in thread_counts.iter().zip(&walls) {
        t.row(vec![
            threads.to_string(),
            format!("{:.1}", wall * 1e3),
            format!("{:.1}", iterations / wall),
            format!("{:.0}", cells / wall),
            format!("x{:.2}", walls[0] / wall),
        ]);
        ctx.metric_info(format!("steps_per_s@t{threads}"), iterations / wall);
    }
    ctx.table(&t);

    let sp2 = walls[0] / walls[1];
    let sp4 = walls[0] / walls[2];
    ctx.metric_info("speedup@2threads", sp2);
    ctx.metric_info("speedup@4threads", sp4);
    ctx.metric_info("speedup@8threads", walls[0] / walls[3]);

    // Scaling gates, sized to the host: the pool cannot outrun the
    // physical cores. Fast mode (CI on shared, noisy-neighbor runners,
    // 2 short reps) records the curve without gating on it — the
    // byte-identity check above is the invariant there; full local runs
    // must actually show the speedup.
    if ctx.settings().fast {
        ctx.note("fast mode: speedup floor not gated (noisy shared runners); info metrics only");
    } else if cores >= 4 {
        check(sp4 > 1.5, format!("4-thread speedup x{sp4:.2} <= 1.5 on a {cores}-core host"))?;
    } else if cores >= 2 {
        check(
            sp4 > 1.2,
            format!("4-thread speedup x{sp4:.2} <= 1.2 on a {cores}-core host"),
        )?;
    } else {
        ctx.note("single-core host: scaling gate skipped (speedups recorded as info)");
    }

    // Kernel-tier end-to-end effect: the same 4-thread session once
    // under the scalar oracle and once under the blocked SIMD tier
    // (both bit-identical by construction — only wallclock may move).
    let prev_policy = kernel_policy();
    let mut policy_walls = Vec::new();
    for policy in [KernelPolicy::Scalar, KernelPolicy::Blocked] {
        set_kernel_policy(policy);
        let mut backend =
            NativeBackend::new(cfg.clone(), Params::random(&cfg, 11)).with_threads(4);
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let mut session = WavefrontSession::new(cfg.clone(), 1);
            session.submit(1, &tokens)?;
            let t0 = Instant::now();
            session.run_to_completion(&mut backend)?;
            best = best.min(t0.elapsed().as_secs_f64());
        }
        policy_walls.push(best);
    }
    set_kernel_policy(prev_policy);
    let kernel_speedup = policy_walls[0] / policy_walls[1];
    ctx.metric_info("kernel_blocked_speedup@4threads", kernel_speedup);
    if ctx.settings().fast {
        ctx.note(format!(
            "kernel tier @4 threads: blocked x{kernel_speedup:.2} over scalar (not gated in fast mode)"
        ));
    } else {
        check(
            kernel_speedup > 1.0,
            format!("blocked kernels must beat scalar end-to-end, got x{kernel_speedup:.2}"),
        )?;
        ctx.note(format!(
            "kernel tier @4 threads: blocked x{kernel_speedup:.2} over the scalar oracle"
        ));
    }

    ctx.note(format!(
        "OK: byte-identical logits at every thread count; speedup x{sp2:.2} @2t, x{sp4:.2} @4t"
    ));
    Ok(())
}

/// Byte-for-byte output equality, the kernel tier's exactness contract.
fn bits_eq(a: &Tensor, b: &Tensor) -> bool {
    a.shape() == b.shape()
        && a.data().iter().zip(b.data()).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// The tiered GEMM kernel layer, measured. Four parts: (1) the
/// cache-blocked SIMD f32 path vs the scalar oracle at the
/// `parallel_scaling` 12-layer bench-config sizes — byte-identity is
/// checked on the SAME inputs in the SAME run as the timing, and the
/// non-fast gate wants the blocked tier >= 2x; (2) the f16/bf16/int8
/// weight stores at a serving-scale memory-bound size (12 distinct
/// [1024,1024] weight matrices cycled under a decode-shaped m=1
/// activation — the working set defeats the LLC, so byte footprint is
/// destiny and int8 must clear 1.5x over blocked f32); (3) quantization
/// error, both weight round-trip and end-to-end logits drift;
/// (4) achieved GFLOP/s against the measured `ci_host` roofline.
fn gemm_kernels(ctx: &mut SuiteCtx) -> Result<()> {
    let mut rng = Rng::new(4096);
    let budget = ctx.budget(200);

    // ---- (1) blocked vs scalar f32, 12-layer bench-config sizes ----
    // d_model 96, d_ff 192, seg_total 20: the exact GEMM shapes one
    // parallel_scaling cell issues per layer step (qkv/up/down).
    let shapes = [(20usize, 96usize, 96usize), (20, 96, 192), (20, 192, 96)];
    let mut t = Table::new(
        "gemm_kernels — f32 scalar oracle vs cache-blocked SIMD (outputs bit-identical)",
        &["m x k x n", "scalar (us)", "blocked (us)", "blocked GFLOP/s", "speedup"],
    );
    let mut scalar_s = 0.0f64;
    let mut blocked_s = 0.0f64;
    let mut flops_total = 0.0f64;
    for &(m, k, n) in &shapes {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        // Same-run exactness: every blocked variant must reproduce its
        // scalar oracle to the bit on the tensors we are about to time.
        let at = a.t();
        let bt = b.t();
        check(
            bits_eq(&matmul_scalar(&a, &b), &matmul_blocked(&a, &b)),
            format!("matmul blocked != scalar at {m}x{k}x{n}"),
        )?;
        check(
            bits_eq(
                &matmul_rows_scalar(&a, &b, 1, m.max(2) - 1),
                &matmul_rows_blocked(&a, &b, 1, m.max(2) - 1),
            ),
            format!("matmul_rows blocked != scalar at {m}x{k}x{n}"),
        )?;
        check(
            bits_eq(&matmul_at_scalar(&at, &b), &matmul_at_blocked(&at, &b)),
            format!("matmul_at blocked != scalar at {m}x{k}x{n}"),
        )?;
        check(
            bits_eq(&matmul_bt_scalar(&a, &bt), &matmul_bt_blocked(&a, &bt)),
            format!("matmul_bt blocked != scalar at {m}x{k}x{n}"),
        )?;

        let flops = 2.0 * (m * k * n) as f64;
        let ss = bench(&format!("scalar {m}x{k}x{n}"), budget, || {
            std::hint::black_box(matmul_scalar(&a, &b));
        });
        let sb = bench(&format!("blocked {m}x{k}x{n}"), budget, || {
            std::hint::black_box(matmul_blocked(&a, &b));
        });
        t.row(vec![
            format!("{m}x{k}x{n}"),
            format!("{:.1}", ss.mean_s() * 1e6),
            format!("{:.1}", sb.mean_s() * 1e6),
            format!("{:.2}", flops / sb.mean_s() / 1e9),
            format!("x{:.2}", ss.mean_s() / sb.mean_s()),
        ]);
        ctx.metric_info(format!("blocked_gflops@{m}x{k}x{n}"), flops / sb.mean_s() / 1e9);
        scalar_s += ss.mean_s();
        blocked_s += sb.mean_s();
        flops_total += flops;
    }
    ctx.table(&t);
    let f32_speedup = scalar_s / blocked_s;
    ctx.metric_info("blocked_over_scalar_f32", f32_speedup);
    ctx.metric_info("blocked_gflops_total", flops_total / blocked_s / 1e9);

    // Roofline context: what the measured CI-host device model says
    // these exact shapes *could* sustain, and the fraction we achieve.
    let ci = DeviceSpec::ci_host();
    let roof_s: f64 =
        shapes.iter().map(|&(m, k, n)| ci.time(&ops::gemm(&ci, m, n, k, 1))).sum();
    let roofline_frac = roof_s / blocked_s;
    ctx.metric_info("roofline_fraction_vs_ci_host", roofline_frac);
    ctx.note(format!(
        "blocked f32: x{f32_speedup:.2} over scalar, {:.2} GFLOP/s \
         ({:.0}% of the {} roofline)",
        flops_total / blocked_s / 1e9,
        100.0 * roofline_frac,
        ci.name
    ));

    // ---- (2) reduced-precision weight stores, memory-bound ----------
    // Decode shape: one activation row against L distinct weight
    // matrices, so every sweep streams the full weight set from DRAM.
    let (layers, kq, nq) = if ctx.settings().fast { (6usize, 512usize, 512usize) } else { (12, 1024, 1024) };
    let x = Tensor::randn(&[1, kq], 1.0, &mut rng);
    let weights: Vec<Tensor> =
        (0..layers).map(|_| Tensor::randn(&[kq, nq], 0.5, &mut rng)).collect();
    let prev_policy = kernel_policy();
    set_kernel_policy(KernelPolicy::Blocked);
    let mut t = Table::new(
        &format!(
            "gemm_kernels — weight precision, {layers} x [{kq},{nq}] cycled, m=1 decode GEMV"
        ),
        &["precision", "weights (MB)", "sweep (ms)", "eff. GB/s", "speedup vs f32"],
    );
    let mut sweep_s = Vec::new();
    for prec in [Precision::F32, Precision::F16, Precision::Bf16, Precision::Int8] {
        let mats: Vec<WeightMat> =
            weights.iter().map(|w| WeightMat::from_tensor(w, prec)).collect();
        let bytes: usize = mats.iter().map(WeightMat::bytes).sum();
        let s = bench(&format!("sweep {prec}"), budget, || {
            for m in &mats {
                std::hint::black_box(m.view().matmul(&x));
            }
        });
        t.row(vec![
            prec.to_string(),
            format!("{:.1}", bytes as f64 / 1e6),
            format!("{:.2}", s.mean_s() * 1e3),
            format!("{:.1}", bytes as f64 / s.mean_s() / 1e9),
            format!("x{:.2}", sweep_s.first().copied().unwrap_or(s.mean_s()) / s.mean_s()),
        ]);
        ctx.metric_info(format!("sweep_ms@{prec}"), s.mean_s() * 1e3);
        sweep_s.push(s.mean_s());
    }
    set_kernel_policy(prev_policy);
    ctx.table(&t);
    let int8_speedup = sweep_s[0] / sweep_s[3];
    ctx.metric_info("int8_over_blocked_f32", int8_speedup);

    // ---- (3) quantization error: round-trip and end-to-end ----------
    let w = &weights[0];
    for (prec, bound) in
        [(Precision::F16, 1e-3f32), (Precision::Bf16, 1e-2), (Precision::Int8, 1e-2)]
    {
        let rel = w.rel_error(&WeightMat::from_tensor(w, prec).dequantize());
        check(
            rel < bound,
            format!("{prec} weight round-trip error {rel} over budget {bound}"),
        )?;
        ctx.metric_info(format!("weight_rt_rel_err@{prec}"), rel as f64);
    }
    // End-to-end drift: the same 4-segment request through the serving
    // model at each precision vs the f32 run. The recurrence compounds
    // per-cell error across segments, so this is a sanity bound, not
    // the per-cell budget the unit tests enforce.
    let cfg = serving_config();
    let tokens: Vec<u32> =
        (0..(4 * cfg.seg) as u32).map(|t| (t * 31 + 7) % cfg.vocab as u32).collect();
    let run_at = |prec: Precision| -> Result<Tensor> {
        let mut b =
            NativeBackend::new(cfg.clone(), Params::random(&cfg, 61)).with_precision(prec);
        Executor::new(&mut b, ScheduleMode::Diagonal).run(&tokens)?.stacked()
    };
    let exact = run_at(Precision::F32)?;
    for prec in [Precision::F16, Precision::Bf16, Precision::Int8] {
        let rel = exact.rel_error(&run_at(prec)?);
        check(rel < 0.5, format!("{prec} end-to-end logits drift {rel} is out of control"))?;
        ctx.metric_info(format!("e2e_logits_rel_err@{prec}"), rel as f64);
    }

    // ---- (4) gates ---------------------------------------------------
    if ctx.settings().fast {
        ctx.note(format!(
            "fast mode: perf floors not gated (noisy shared runners) — \
             blocked x{f32_speedup:.2}, int8 x{int8_speedup:.2}"
        ));
    } else {
        check(
            f32_speedup >= 2.0,
            format!("blocked f32 must be >= 2x the scalar oracle, got x{f32_speedup:.2}"),
        )?;
        check(
            int8_speedup >= 1.5,
            format!("int8 must be >= 1.5x blocked f32 when memory-bound, got x{int8_speedup:.2}"),
        )?;
        ctx.note(format!(
            "OK: blocked x{f32_speedup:.2} (gate 2.0), int8 x{int8_speedup:.2} (gate 1.5), \
             outputs bit-identical, quantization error within budget"
        ));
    }
    Ok(())
}

/// `serve_queue` under concurrent synthetic load: drives the
/// continuous-batching drain loop with N mixed-length requests on the
/// native backend and reports the engine's latency percentiles
/// (p50/p90/p99 — the same numbers the server exports via
/// `{"cmd": "stats"}`) plus the aggregate utilization counters.
fn serve_latency(ctx: &mut SuiteCtx) -> Result<()> {
    let cfg = serving_config();
    let lanes = ctx.settings().lanes.max(1);
    let n_requests: u64 = if ctx.settings().fast { 16 } else { 48 };

    let queue: RequestQueue<(GenerateRequest, u64)> = RequestQueue::new(n_requests as usize);
    let mut total_tokens = 0usize;
    for i in 0..n_requests {
        // Mixed lengths, 1..=6 segments, so short requests overtake long
        // ones and ramps overlap.
        let segs = 1 + (i as usize % 6);
        let tokens: Vec<u32> =
            (0..(segs * cfg.seg) as u32).map(|t| (t * 7 + i as u32) % cfg.vocab as u32).collect();
        total_tokens += tokens.len();
        queue.push((GenerateRequest::new(i, tokens), i))?;
    }
    queue.close();

    let backend = NativeBackend::new(cfg.clone(), Params::random(&cfg, 29));
    let mut engine =
        InferenceEngine::new(backend, ExecMode::Diagonal).with_lanes(lanes);
    let mut completed = 0u64;
    let mut failed = 0u64;
    let t0 = Instant::now();
    engine.serve_queue(&queue, |_ticket, ev| match ev {
        Event::Done { .. } => completed += 1,
        Event::Error { .. } => failed += 1,
        _ => {}
    })?;
    let wall_s = t0.elapsed().as_secs_f64();

    check(failed == 0, format!("{failed} requests failed"))?;
    check(completed == n_requests, format!("completed {completed}/{n_requests}"))?;

    let stats = &engine.stats;
    let p50 = stats.latency.quantile(0.5);
    let p90 = stats.latency.quantile(0.9);
    let p99 = stats.latency.quantile(0.99);
    check(p50 <= p90 && p90 <= p99, "latency percentiles must be monotone")?;
    check(stats.packed_requests.get() == n_requests, "every request must pack")?;

    let mut t = Table::new(
        &format!("serve_queue, {n_requests} concurrent requests, {lanes} lane(s)"),
        &["quantity", "value"],
    );
    t.row(vec!["requests".into(), stats.requests.get().to_string()]);
    t.row(vec!["launches".into(), stats.launches.get().to_string()]);
    t.row(vec!["mean group".into(), format!("{:.2}", stats.mean_group())]);
    t.row(vec!["occupancy".into(), format!("{:.3}", stats.occupancy.value())]);
    t.row(vec!["padded cells".into(), stats.padded_cells().to_string()]);
    t.row(vec!["latency p50".into(), format!("{:.3?}", p50)]);
    t.row(vec!["latency p90".into(), format!("{:.3?}", p90)]);
    t.row(vec!["latency p99".into(), format!("{:.3?}", p99)]);
    t.row(vec!["tokens/s".into(), format!("{:.0}", total_tokens as f64 / wall_s)]);
    ctx.table(&t);

    ctx.metric_higher("mean_group", stats.mean_group());
    ctx.metric_higher("occupancy", stats.occupancy.value());
    ctx.metric_info("latency_ms_p50", p50.as_secs_f64() * 1e3);
    ctx.metric_info("latency_ms_p90", p90.as_secs_f64() * 1e3);
    ctx.metric_info("latency_ms_p99", p99.as_secs_f64() * 1e3);
    ctx.metric_info("latency_ms_mean", stats.latency.mean().as_secs_f64() * 1e3);
    ctx.metric_info("tokens_per_s", total_tokens as f64 / wall_s);
    ctx.note(format!(
        "OK: {completed} requests served through one packed wavefront \
         (mean group {:.2}, occupancy {:.3})",
        stats.mean_group(),
        stats.occupancy.value()
    ));
    Ok(())
}

/// Memory-state prefix cache under a shared-prefix burst: client 0
/// cold-fills the store, then clients 1..N — all sharing its
/// 6-segment prompt prefix, diverging at the tail — run concurrently
/// through `serve_queue` with the cache enabled. Three gates, matching
/// the ISSUE's acceptance criteria: (1) every follow-up client hits
/// the cache (hit rate 1.0 > 0); (2) hit requests execute strictly
/// fewer prefill cells than the cold run of the same request; (3) the
/// outputs stay bit-identical to the cold run — generated tokens,
/// greedy tails and the computed logits (`f32::to_bits`) alike.
fn cache_reuse(ctx: &mut SuiteCtx) -> Result<()> {
    let cfg = serving_config();
    let lanes = ctx.settings().lanes.max(1);
    let n_clients: u64 = if ctx.settings().fast { 6 } else { 12 };
    let shared_segs = 6usize;
    let tail_segs = 2usize;
    let new_tokens = 2 * cfg.seg;
    let mut rng = Rng::new(77);
    let shared: Vec<u32> =
        (0..shared_segs * cfg.seg).map(|_| rng.below(cfg.vocab) as u32).collect();
    let prompt = |i: u64| -> Vec<u32> {
        let mut p = shared.clone();
        p.extend(
            (0..(tail_segs * cfg.seg) as u32)
                .map(|t| (t * 13 + 7 * i as u32 + 1) % cfg.vocab as u32),
        );
        p
    };

    // Drive ids through one engine's serve_queue, in submission order.
    let drain = |engine: &mut InferenceEngine<NativeBackend>,
                 ids: std::ops::Range<u64>|
     -> Result<Vec<crate::coordinator::Response>> {
        let count = (ids.end - ids.start) as usize;
        let base = ids.start;
        let queue: RequestQueue<(GenerateRequest, u64)> = RequestQueue::new(count.max(1));
        for i in ids {
            let mut r = GenerateRequest::new(i, prompt(i)).generate(new_tokens);
            r.want_logits = true;
            queue.push((r, i))?;
        }
        queue.close();
        let mut done: Vec<Option<crate::coordinator::Response>> =
            (0..count).map(|_| None).collect();
        let mut failed = 0u64;
        engine.serve_queue(&queue, |t, ev| match ev {
            Event::Done { stats } => done[(*t - base) as usize] = Some(*stats),
            Event::Error { .. } => failed += 1,
            _ => {}
        })?;
        check(failed == 0, format!("{failed} requests failed"))?;
        done.into_iter()
            .enumerate()
            .map(|(i, d)| d.ok_or_else(|| Error::Bench(format!("request {i} never completed"))))
            .collect()
    };

    // Cold reference: cache disabled, every request prefills in full.
    // Client 0 runs untimed first, mirroring the warm pass below, so
    // the cold/warm wallclocks cover the SAME burst (clients 1..N).
    let mut cold_engine = InferenceEngine::new(
        NativeBackend::new(cfg.clone(), Params::random(&cfg, 41)),
        ExecMode::Diagonal,
    )
    .with_lanes(lanes);
    let cold0 = drain(&mut cold_engine, 0..1)?;
    let t0 = Instant::now();
    let cold_burst = drain(&mut cold_engine, 1..n_clients)?;
    let cold_wall = t0.elapsed().as_secs_f64();
    let cold: Vec<crate::coordinator::Response> =
        cold0.into_iter().chain(cold_burst).collect();

    // Warm: same weights, cache on. Client 0 fills the store; the rest
    // of the burst reuses its shared prefix concurrently.
    let mut warm_engine = InferenceEngine::new(
        NativeBackend::new(cfg.clone(), Params::random(&cfg, 41)),
        ExecMode::Diagonal,
    )
    .with_lanes(lanes)
    .with_cache_bytes(16 << 20);
    let warm0 = drain(&mut warm_engine, 0..1)?;
    check(warm0[0].reused_segments == 0, "client 0 must be a cold fill")?;
    let t0 = Instant::now();
    let warm = drain(&mut warm_engine, 1..n_clients)?;
    let warm_wall = t0.elapsed().as_secs_f64();

    let mut hit_cells = 0u64;
    let mut cold_cells = 0u64;
    for (w, c) in warm.iter().zip(&cold[1..]) {
        check(
            w.reused_segments == shared_segs,
            format!("client {}: reused {} of {shared_segs} shared segments", w.id, w.reused_segments),
        )?;
        check(w.generated == c.generated, format!("client {}: decode diverged", w.id))?;
        check(w.greedy_tail == c.greedy_tail, format!("client {}: greedy tail diverged", w.id))?;
        let (wl, cl) = (w.logits.as_ref().unwrap(), c.logits.as_ref().unwrap());
        check(wl.len() + shared_segs == cl.len(), "computed-logit counts")?;
        for (a, b) in wl.iter().zip(&cl[shared_segs..]) {
            let eq = a.data().iter().zip(b.data()).all(|(x, y)| x.to_bits() == y.to_bits());
            check(eq, format!("client {}: computed logits diverged from the cold run", w.id))?;
        }
        check(
            w.stats.cells < c.stats.cells,
            format!(
                "client {}: a hit must execute strictly fewer cells ({} vs cold {})",
                w.id, w.stats.cells, c.stats.cells
            ),
        )?;
        hit_cells += w.stats.cells;
        cold_cells += c.stats.cells;
    }
    let stats = &warm_engine.stats;
    let hits = stats.cache_hits.get();
    check(
        hits == n_clients - 1,
        format!("hit-rate gate: {hits} hits for {} shared-prefix clients", n_clients - 1),
    )?;
    check(
        stats.cache_hit_segments.get() == (n_clients - 1) * shared_segs as u64,
        "every hit must reuse the whole shared prefix",
    )?;

    let mut t = Table::new(
        &format!(
            "cache_reuse — {n_clients} clients x ({} shared + {} tail segments, {} new tokens), \
             {lanes} lane(s)",
            shared_segs, tail_segs, new_tokens
        ),
        &["quantity", "cold", "warm (prefix cache)"],
    );
    let per = |cells: u64, n: u64| cells as f64 / n as f64;
    t.row(vec![
        "cells/request".into(),
        format!("{:.1}", per(cold_cells, n_clients - 1)),
        format!("{:.1}", per(hit_cells, n_clients - 1)),
    ]);
    t.row(vec!["wall (ms)".into(), format!("{:.1}", cold_wall * 1e3), format!("{:.1}", warm_wall * 1e3)]);
    t.row(vec![
        "cache".into(),
        "off".into(),
        format!("{} hits, {} bytes, {} evictions", hits, stats.cache_bytes.get(), stats.cache_evictions.get()),
    ]);
    ctx.table(&t);

    ctx.metric_higher("cache_hit_rate", hits as f64 / (n_clients - 1) as f64);
    ctx.metric_higher("prefill_cells_saved_frac", 1.0 - hit_cells as f64 / cold_cells as f64);
    ctx.metric_info("cache_bytes", stats.cache_bytes.get() as f64);
    ctx.metric_info("evictions", stats.cache_evictions.get() as f64);
    ctx.metric_info("cold_wall_s", cold_wall);
    ctx.metric_info("warm_wall_s", warm_wall);
    ctx.note(format!(
        "OK: {} hits / {} shared-prefix clients, {:.0}% of cells saved, outputs bit-exact vs cold",
        hits,
        n_clients - 1,
        100.0 * (1.0 - hit_cells as f64 / cold_cells as f64)
    ));
    Ok(())
}

/// Multi-client generation burst through `serve_queue`: every request
/// prefills AND decodes inside the one shared wavefront. Three gates:
/// (1) every continuation bit-matches the same request served solo
/// (decode is exact recurrence, packing included); (2) the burst's
/// aggregate `mean_group` beats the BEST solo diagonal run — including
/// the `L` ceiling a solo wavefront can never exceed; (3) nothing
/// fails. Latency percentiles and generated-token throughput are
/// reported alongside.
fn serve_generate(ctx: &mut SuiteCtx) -> Result<()> {
    let cfg = serving_config();
    // A decoding lane carries ~1 active cell while its frontier
    // travels, so beating the solo ceiling L needs lanes > L.
    let lanes = 2 * cfg.n_layers;
    let n_requests: u64 = if ctx.settings().fast { 8 } else { 16 };
    let prompt_segs = 2usize;
    let new_tokens = 3 * cfg.seg;
    let prompt = |i: u64| -> Vec<u32> {
        (0..(prompt_segs * cfg.seg) as u32)
            .map(|t| (t * 11 + i as u32) % cfg.vocab as u32)
            .collect()
    };

    // Solo baseline: each request alone (same weights), and the best
    // per-request mean_group any of them achieves.
    let mut best_solo = 0.0f64;
    let mut solo_generated: Vec<Vec<u32>> = Vec::new();
    {
        let mut solo = InferenceEngine::new(
            NativeBackend::new(cfg.clone(), Params::random(&cfg, 31)),
            ExecMode::Diagonal,
        );
        for i in 0..n_requests {
            let resp =
                solo.process(&GenerateRequest::new(i, prompt(i)).generate(new_tokens))?;
            best_solo = best_solo.max(resp.stats.mean_group());
            solo_generated.push(resp.generated);
        }
    }

    let queue: RequestQueue<(GenerateRequest, u64)> = RequestQueue::new(n_requests as usize);
    for i in 0..n_requests {
        queue.push((GenerateRequest::new(i, prompt(i)).generate(new_tokens), i))?;
    }
    queue.close();
    let backend = NativeBackend::new(cfg.clone(), Params::random(&cfg, 31));
    let mut engine = InferenceEngine::new(backend, ExecMode::Diagonal).with_lanes(lanes);
    let mut done: Vec<Option<crate::coordinator::Response>> =
        (0..n_requests).map(|_| None).collect();
    let mut failed = 0u64;
    let t0 = Instant::now();
    engine.serve_queue(&queue, |ticket, ev| match ev {
        Event::Done { stats } => done[*ticket as usize] = Some(*stats),
        Event::Error { .. } => failed += 1,
        _ => {}
    })?;
    let wall_s = t0.elapsed().as_secs_f64();

    check(failed == 0, format!("{failed} requests failed"))?;
    let mut total_generated = 0usize;
    for (i, d) in done.iter().enumerate() {
        let d = d
            .as_ref()
            .ok_or_else(|| Error::Bench(format!("request {i} never completed")))?;
        check(
            d.generated.len() == new_tokens,
            format!("request {i}: {} of {new_tokens} tokens", d.generated.len()),
        )?;
        check(
            d.generated == solo_generated[i],
            format!("request {i}: packed decode diverged from its solo run"),
        )?;
        total_generated += d.generated.len();
    }

    let stats = &engine.stats;
    let mg = stats.mean_group();
    // The acceptance gate: beat the best solo run AND the solo ceiling.
    let solo_bound = best_solo.max(cfg.n_layers as f64);
    check(
        mg > solo_bound,
        format!("burst mean_group {mg:.3} must beat the solo bound {solo_bound:.3}"),
    )?;

    let p50 = stats.latency.quantile(0.5);
    let p99 = stats.latency.quantile(0.99);
    let mut t = Table::new(
        &format!(
            "serve_generate — {n_requests} clients x ({} prompt + {new_tokens} new tokens), \
             {lanes} lanes",
            prompt_segs * cfg.seg
        ),
        &["quantity", "value"],
    );
    t.row(vec!["burst mean group".into(), format!("{mg:.2}")]);
    t.row(vec!["best solo mean group".into(), format!("{best_solo:.2}")]);
    t.row(vec!["solo ceiling (L)".into(), format!("{}", cfg.n_layers)]);
    t.row(vec!["occupancy".into(), format!("{:.3}", stats.occupancy.value())]);
    t.row(vec!["generated tokens".into(), total_generated.to_string()]);
    t.row(vec![
        "generated tokens/s".into(),
        format!("{:.0}", total_generated as f64 / wall_s),
    ]);
    t.row(vec!["latency p50".into(), format!("{p50:.3?}")]);
    t.row(vec!["latency p99".into(), format!("{p99:.3?}")]);
    ctx.table(&t);

    ctx.metric_higher("mean_group", mg);
    ctx.metric_higher("mean_group_gain_vs_solo", mg / solo_bound);
    ctx.metric_higher("occupancy", stats.occupancy.value());
    ctx.metric_info("generated_tokens_per_s", total_generated as f64 / wall_s);
    ctx.metric_info("latency_ms_p50", p50.as_secs_f64() * 1e3);
    ctx.metric_info("latency_ms_p99", p99.as_secs_f64() * 1e3);
    ctx.note(format!(
        "OK: {n_requests} concurrent generations stayed bit-exact and packed to \
         mean group {mg:.2} (> solo bound {solo_bound:.2})"
    ));
    Ok(())
}

/// Sharded serving scaling: the same concurrent greedy burst through
/// (1) one in-process engine, (2) a shard coordinator over 1 and then
/// 2 lane workers, and (3) a 2-stage layer-split pipeline — all over
/// real TCP on localhost. Gates: every topology's outputs are
/// bit-equal to the 1-process oracle and no phantom failovers fire;
/// the pipeline's per-segment hand-off cost is recorded and bounded.
fn shard_scaling(ctx: &mut SuiteCtx) -> Result<()> {
    let cfg = serving_config();
    let seed = 61u64;
    let n_requests: u64 = if ctx.settings().fast { 4 } else { 8 };
    let prompt_segs = 2usize;
    let new_tokens = 2 * cfg.seg;
    let prompt = |i: u64| -> Vec<u32> {
        (0..(prompt_segs * cfg.seg) as u32)
            .map(|t| (t * 11 + i as u32) % cfg.vocab as u32)
            .collect()
    };

    // 1-process oracle: the correctness reference for every topology
    // and the serial-latency baseline.
    let mut solo = InferenceEngine::new(
        NativeBackend::new(cfg.clone(), Params::random(&cfg, seed)),
        ExecMode::Diagonal,
    );
    let mut want: Vec<Vec<u32>> = Vec::new();
    let t0 = Instant::now();
    for i in 0..n_requests {
        want.push(solo.process(&GenerateRequest::new(i, prompt(i)).generate(new_tokens))?.generated);
    }
    let solo_wall = t0.elapsed().as_secs_f64();

    let start_worker = |with_shard: bool| -> Result<Server> {
        let engine = InferenceEngine::new(
            NativeBackend::new(cfg.clone(), Params::random(&cfg, seed)),
            ExecMode::Diagonal,
        );
        let backend = with_shard.then(|| {
            Box::new(NativeBackend::new(cfg.clone(), Params::random(&cfg, seed)))
                as Box<dyn StepBackend + Send>
        });
        Server::start_with(engine, "127.0.0.1:0", 32, ServerOptions { shard_backend: backend, fault: None })
    };

    // One concurrent client thread per request; every output is gated
    // against the oracle.
    let burst = |addr: String| -> Result<f64> {
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n_requests)
            .map(|i| {
                let addr = addr.clone();
                let p = prompt(i);
                std::thread::spawn(move || -> Result<Vec<u32>> {
                    let mut c = Client::connect(&addr)?;
                    let frame = Value::obj(vec![
                        ("id", Value::Num(i as f64)),
                        ("tokens", Value::arr_u32(&p)),
                        ("max_new_tokens", Value::Num(new_tokens as f64)),
                    ]);
                    let done = c.request_stream(&frame, |_| {})?;
                    done.req("generated")?.as_u32_vec()
                })
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let got = h.join().map_err(|_| Error::Bench("client thread panicked".into()))??;
            check(
                got == want[i],
                format!("request {i}: sharded output diverged from the 1-process oracle"),
            )?;
        }
        Ok(t0.elapsed().as_secs_f64())
    };

    let run_topology = |workers: usize, split: usize| -> Result<(f64, u64, u64, u64)> {
        let servers: Vec<Server> =
            (0..workers).map(|_| start_worker(split > 1)).collect::<Result<_>>()?;
        let addrs: Vec<String> = servers.iter().map(|s| s.addr.to_string()).collect();
        let coord = ShardCoordinator::start(
            cfg.clone(),
            &addrs,
            "127.0.0.1:0",
            CoordinatorOptions { layer_split: split, ..CoordinatorOptions::default() },
        )?;
        let wall = burst(coord.addr.to_string())?;
        let stats = coord.stats();
        let out = (
            wall,
            stats.shard_failovers.get(),
            stats.shard_handoffs.get(),
            stats.shard_handoff_bytes.get(),
        );
        coord.stop();
        for s in servers {
            s.stop();
        }
        Ok(out)
    };

    let (lane1_wall, f1, _, _) = run_topology(1, 1)?;
    let (lane2_wall, f2, _, _) = run_topology(2, 1)?;
    let (split_wall, f3, split_handoffs, split_bytes) = run_topology(2, 2)?;
    check(f1 + f2 + f3 == 0, "phantom failover on a healthy shard")?;
    check(split_handoffs > 0, "layer-split ran without hand-offs")?;
    let bytes_per_handoff = split_bytes as f64 / split_handoffs as f64;

    let total_tokens = (n_requests as usize * new_tokens) as f64;
    let tps = |wall: f64| total_tokens / wall;
    let mut t = Table::new(
        &format!(
            "shard_scaling — {n_requests} concurrent clients x ({} prompt + {new_tokens} new \
             tokens), TCP localhost",
            prompt_segs * cfg.seg
        ),
        &["topology", "wall (ms)", "tokens/s", "hand-off"],
    );
    t.row(vec!["1 process (serial)".into(), format!("{:.1}", solo_wall * 1e3), format!("{:.0}", tps(solo_wall)), "-".into()]);
    t.row(vec!["coordinator + 1 lane worker".into(), format!("{:.1}", lane1_wall * 1e3), format!("{:.0}", tps(lane1_wall)), "checkpoints absorbed".into()]);
    t.row(vec!["coordinator + 2 lane workers".into(), format!("{:.1}", lane2_wall * 1e3), format!("{:.0}", tps(lane2_wall)), "checkpoints absorbed".into()]);
    t.row(vec![
        "coordinator + 2-stage layer split".into(),
        format!("{:.1}", split_wall * 1e3),
        format!("{:.0}", tps(split_wall)),
        format!("{split_handoffs} x {:.0} B", bytes_per_handoff),
    ]);
    ctx.table(&t);

    // Deterministic gate: the per-segment hand-off is a constant-size
    // memory snapshot, not activations-times-sequence. Bound it by the
    // JSON-encoded size of the per-layer (a, z) state plus slack.
    let state_floats: usize = cfg.n_layers * (cfg.phi_dim * cfg.d_model + cfg.phi_dim);
    check(
        bytes_per_handoff < (state_floats * 16 + 4096) as f64,
        format!("hand-off blew up: {bytes_per_handoff:.0} bytes for {state_floats} state floats"),
    )?;

    ctx.metric_lower("handoff_bytes_per_segment", bytes_per_handoff);
    ctx.metric_info("tokens_per_s_1proc", tps(solo_wall));
    ctx.metric_info("tokens_per_s_lane1", tps(lane1_wall));
    ctx.metric_info("tokens_per_s_lane2", tps(lane2_wall));
    ctx.metric_info("tokens_per_s_split2", tps(split_wall));
    ctx.metric_info("lane2_vs_lane1_speedup", lane1_wall / lane2_wall);
    ctx.note(format!(
        "OK: {n_requests} clients bit-exact across 1-process, lane x1/x2 and layer-split \
         topologies; {:.0} B/segment hand-off",
        bytes_per_handoff
    ));
    Ok(())
}

/// Gateway admission under a batch flood: a batch-class tenant (weight
/// 0.25) queues a pile of long prompts, then an interactive tenant
/// (weight 4) queues short ones behind them. FIFO serves the flood
/// first; the weighted-fair scheduler must pull the interactive work
/// to the front — measured as mean completion rank (position in the
/// Done order), with identical outputs either way. Also gates the
/// token-bucket limiter and API-key auth on the same scheduler.
fn gateway_fairness(ctx: &mut SuiteCtx) -> Result<()> {
    let cfg = serving_config();
    let lanes = ctx.settings().lanes.max(1);
    let n_bulk: u64 = if ctx.settings().fast { 10 } else { 20 };
    let n_live: u64 = if ctx.settings().fast { 4 } else { 8 };
    let bulk_segs = 4usize;

    // Request i: bulk ids 0..n_bulk (4-segment prompts), live ids
    // n_bulk.. (1-segment). Same synthetic tokens in both runs.
    let request = |i: u64| -> GenerateRequest {
        let segs = if i < n_bulk { bulk_segs } else { 1 };
        let tokens: Vec<u32> =
            (0..(segs * cfg.seg) as u32).map(|t| (t * 11 + i as u32) % cfg.vocab as u32).collect();
        GenerateRequest::new(i, tokens)
    };
    let is_live = |id: u64| id >= n_bulk;
    let mean_live_rank = |order: &[u64]| -> f64 {
        let ranks: Vec<usize> =
            order.iter().enumerate().filter(|(_, id)| is_live(**id)).map(|(r, _)| r).collect();
        ranks.iter().sum::<usize>() as f64 / ranks.len() as f64
    };

    // Run 1 — FIFO baseline: the flood is pushed first and served
    // first; interactive requests eat the whole backlog as queueing
    // delay.
    let fifo: RequestQueue<(GenerateRequest, u64)> = RequestQueue::new((n_bulk + n_live) as usize);
    for i in 0..n_bulk + n_live {
        fifo.push((request(i), i))?;
    }
    fifo.close();
    let backend = NativeBackend::new(cfg.clone(), Params::random(&cfg, 31));
    let mut engine = InferenceEngine::new(backend, ExecMode::Diagonal).with_lanes(lanes);
    let mut fifo_order: Vec<u64> = Vec::new();
    let mut fifo_tails: Vec<(u64, Vec<usize>)> = Vec::new();
    let mut failed = 0u64;
    let t0 = Instant::now();
    engine.serve_queue(&fifo, |t, ev| match ev {
        Event::Done { stats } => {
            fifo_order.push(*t);
            fifo_tails.push((*t, stats.greedy_tail.clone()));
        }
        Event::Error { .. } => failed += 1,
        _ => {}
    })?;
    let fifo_wall = t0.elapsed().as_secs_f64();
    check(failed == 0, format!("{failed} fifo requests failed"))?;

    // Run 2 — weighted-fair: same push order, but the scheduler ranks
    // by virtual time, so the light high-weight tenant overtakes the
    // backlog at admission.
    let specs = vec![TenantSpec::parse("bulk:sk-bulk:batch")?, TenantSpec::parse("live:sk-live:interactive")?];
    let sched: FairScheduler<(GenerateRequest, u64)> =
        FairScheduler::new(specs, (n_bulk + n_live) as usize);
    for i in 0..n_bulk + n_live {
        let req = request(i);
        let tenant = if is_live(i) { 2 } else { 1 }; // 0 is the open local tenant
        let cost = (req.prompt.len() + req.max_new_tokens) as f64;
        sched.push(tenant, cost, (req, i))?;
    }
    sched.close();
    let backend = NativeBackend::new(cfg.clone(), Params::random(&cfg, 31));
    let mut engine = InferenceEngine::new(backend, ExecMode::Diagonal).with_lanes(lanes);
    let mut fair_order: Vec<u64> = Vec::new();
    let mut fair_tails: Vec<(u64, Vec<usize>)> = Vec::new();
    let mut failed = 0u64;
    let t0 = Instant::now();
    engine.serve_queue(&sched, |t, ev| match ev {
        Event::Done { stats } => {
            fair_order.push(*t);
            fair_tails.push((*t, stats.greedy_tail.clone()));
        }
        Event::Error { .. } => failed += 1,
        _ => {}
    })?;
    let fair_wall = t0.elapsed().as_secs_f64();
    check(failed == 0, format!("{failed} fair requests failed"))?;

    check(fifo_order.len() == (n_bulk + n_live) as usize, "fifo run dropped requests")?;
    check(fair_order.len() == (n_bulk + n_live) as usize, "fair run dropped requests")?;
    check(sched.stats.shed.get() == 0, "depth covers the workload: nothing sheds")?;
    check(sched.stats.admitted.get() == n_bulk + n_live, "admission counter drifted")?;

    // Fairness only reorders admission — outputs are identical.
    fifo_tails.sort_by_key(|(id, _)| *id);
    fair_tails.sort_by_key(|(id, _)| *id);
    check(fifo_tails == fair_tails, "greedy tails must be identical across schedulers")?;

    let fifo_rank = mean_live_rank(&fifo_order);
    let fair_rank = mean_live_rank(&fair_order);
    check(
        fair_rank < fifo_rank,
        format!("weighted-fair must beat FIFO for the light tenant: {fair_rank:.1} vs {fifo_rank:.1}"),
    )?;

    // Token bucket: `rate 0, burst 2` is a deterministic hard cap —
    // two admissions, then refusal. Auth: configured tenants refuse
    // missing/unknown keys.
    let capped: FairScheduler<u64> =
        FairScheduler::new(vec![TenantSpec::parse("capped:sk-c:standard:0:2")?], 4);
    let cap_t = capped.authenticate(Some("sk-c"))?;
    check(capped.try_acquire(cap_t) && capped.try_acquire(cap_t), "burst of 2 must admit twice")?;
    check(!capped.try_acquire(cap_t), "third acquire must trip the bucket")?;
    check(capped.authenticate(Some("wrong")).is_err(), "unknown key must be refused")?;
    check(capped.authenticate(None).is_err(), "missing key must be refused")?;

    let mut t = Table::new(
        &format!(
            "gateway_fairness — {n_bulk} batch x {} tok flood + {n_live} interactive x {} tok, \
             {lanes} lane(s)",
            bulk_segs * cfg.seg,
            cfg.seg
        ),
        &["scheduler", "live mean rank", "wall (ms)"],
    );
    t.row(vec!["FIFO".into(), format!("{fifo_rank:.1}"), format!("{:.1}", fifo_wall * 1e3)]);
    t.row(vec!["weighted-fair".into(), format!("{fair_rank:.1}"), format!("{:.1}", fair_wall * 1e3)]);
    ctx.table(&t);

    ctx.metric_higher("live_rank_gain", fifo_rank / fair_rank.max(1.0));
    ctx.metric_info("live_mean_rank_fifo", fifo_rank);
    ctx.metric_info("live_mean_rank_fair", fair_rank);
    ctx.metric_info("fifo_wall_ms", fifo_wall * 1e3);
    ctx.metric_info("fair_wall_ms", fair_wall * 1e3);
    ctx.note(format!(
        "OK: interactive mean completion rank {fair_rank:.1} under weighted-fair vs \
         {fifo_rank:.1} under FIFO; outputs identical; token bucket and auth gates hold"
    ));
    Ok(())
}

// ---------------------------------------------------------------------------
// Quality tier
// ---------------------------------------------------------------------------

/// Serving model widened to cover the synthetic BABILong vocabulary
/// (episode tokens reach `filler_base + n_filler = 96`). The memory
/// geometry (`seg`, `phi_dim`) is unchanged, so chunked routing's
/// predicted-saturation threshold stays at `1.5 x phi_dim = 72` prompt
/// tokens.
fn babilong_cfg() -> ModelConfig {
    ModelConfig { name: "babilong-bench".into(), vocab: 256, ..serving_config() }
}

/// The `babilong` module's canonical token layout (normally carried by
/// the manifest; inlined so the suite is artifact-free).
fn babilong_spec() -> BabilongSpec {
    BabilongSpec {
        pad: 0,
        bos: 1,
        query: 2,
        sep: 3,
        agent_base: 10,
        n_agents: 8,
        place_base: 24,
        n_places: 16,
        object_base: 44,
        n_objects: 8,
        filler_base: 56,
        n_filler: 40,
    }
}

/// BABILong QA1/QA2 accuracy vs context length under the three overflow
/// policies, plus the quality-tier observability gates. No trained
/// checkpoint ships with the repo, so absolute accuracy is floor-level
/// noise in every arm — the *invariants* are the quantity under test:
///
/// * policy-off logits are bit-identical to the plain [`Executor`]
///   (which predates the quality tier entirely): the saturation monitor
///   observes, never perturbs;
/// * the engine gates exactly the segments [`quality::plan_selection`]
///   names, and at the longest context selection never scores below off;
/// * `chunked` routes exactly the prompts whose predicted saturation
///   crosses [`quality::CHUNK_THRESHOLD`] (> 72 tokens here) and leaves
///   shorter ones on the normal path;
/// * saturation grows with context, and every quality counter reaches
///   the stats JSON and the Prometheus export.
fn babilong_quality(ctx: &mut SuiteCtx) -> Result<()> {
    let cfg = babilong_cfg();
    let seg = cfg.seg;
    let lens: &[usize] = if ctx.settings().fast { &[32, 96] } else { &[32, 96, 256, 1024] };
    let n_eps = if ctx.settings().fast { 2 } else { 6 };
    let longest = *lens.last().unwrap();

    // One engine per policy arm (same weights), so the per-engine
    // counters isolate each policy's footprint. The oracle backend runs
    // the raw executor — no engine, no monitor, no quality tier.
    let mut off_engine = InferenceEngine::new(
        NativeBackend::new(cfg.clone(), Params::random(&cfg, 97)),
        ExecMode::Diagonal,
    );
    let mut sel_engine = InferenceEngine::new(
        NativeBackend::new(cfg.clone(), Params::random(&cfg, 97)),
        ExecMode::Diagonal,
    );
    let mut chu_engine = InferenceEngine::new(
        NativeBackend::new(cfg.clone(), Params::random(&cfg, 97)),
        ExecMode::Diagonal,
    );
    let mut oracle = NativeBackend::new(cfg.clone(), Params::random(&cfg, 97));

    let mut next_id = 0u64;
    let mut run = |eng: &mut InferenceEngine<NativeBackend>,
                   e: &Episode,
                   policy: OverflowPolicy|
     -> Result<Response> {
        next_id += 1;
        let mut req = GenerateRequest::new(next_id, e.tokens.clone()).with_overflow(policy);
        req.want_logits = true;
        eng.process(&req)
    };
    // Greedy readout at the query position of the final segment (the
    // BABILong convention; chunked reruns keep the query segment intact,
    // so the same readout applies to the windowed answer).
    let predict = |resp: &Response, e: &Episode| -> Result<u32> {
        let last = resp
            .logits
            .as_ref()
            .and_then(|l| l.last())
            .ok_or_else(|| Error::Bench("run returned no logits".into()))?;
        Ok(last.argmax_rows()[e.query_pos % seg] as u32)
    };
    let bits = |ts: &[Tensor]| -> Vec<Vec<u32>> {
        ts.iter().map(|t| t.data().iter().map(|x| x.to_bits()).collect()).collect()
    };

    let mut t = Table::new(
        &format!(
            "babilong_quality — QA accuracy vs context, {n_eps} episode(s)/cell \
             (random weights: the gates are invariants, not absolute accuracy)"
        ),
        &["task", "tokens", "acc off", "acc select", "acc chunked", "skipped", "sat off", "routed"],
    );

    let mut hits_off_longest = 0usize;
    let mut hits_sel_longest = 0usize;
    let mut sat_shortest = 0.0f64;
    let mut sat_longest = 0.0f64;

    for (ti, task) in [Task::QA1, Task::QA2].into_iter().enumerate() {
        for (li, &len) in lens.iter().enumerate() {
            let seed = 4000 + 131 * li as u64 + 7 * ti as u64;
            let eps = Generator::new(babilong_spec(), seed).batch(task, len, n_eps);
            let mut preds: [Vec<u32>; 3] = [Vec::new(), Vec::new(), Vec::new()];
            let mut skipped = 0usize;
            let mut routed = 0usize;
            let mut sat_sum = 0.0f64;
            for e in &eps {
                let off = run(&mut off_engine, e, OverflowPolicy::Off)?;
                // Gate: the monitor observes but never perturbs —
                // policy-off logits match the no-monitor oracle bit for
                // bit, every segment, every episode.
                let oracle_out =
                    Executor::new(&mut oracle, ScheduleMode::Diagonal).run(&e.tokens)?;
                let off_logits = off
                    .logits
                    .as_ref()
                    .ok_or_else(|| Error::Bench("off run returned no logits".into()))?;
                check(
                    bits(off_logits) == bits(&oracle_out.logits),
                    format!("policy-off logits diverged from the no-monitor oracle at {len} tokens"),
                )?;
                check(
                    off.segments_skipped == 0 && !off.overflow_routed,
                    "policy off must not intervene",
                )?;
                check(
                    off.saturation > 0.0 && off.saturation <= 1.0,
                    format!("saturation {} out of range", off.saturation),
                )?;
                sat_sum += off.saturation;

                let sel = run(&mut sel_engine, e, OverflowPolicy::Select)?;
                // The engine must gate exactly the segments the pure
                // scoring function names — recompute independently.
                let planned = quality::plan_selection(&quality::segment_tokens(&e.tokens, seg))
                    .iter()
                    .filter(|&&s| s)
                    .count();
                check(
                    sel.segments_skipped == planned,
                    format!("engine gated {} segments, plan says {planned}", sel.segments_skipped),
                )?;
                skipped += sel.segments_skipped;

                let chu = run(&mut chu_engine, e, OverflowPolicy::Chunked)?;
                let should_route = quality::predicted_saturation(&cfg, e.tokens.len())
                    > quality::CHUNK_THRESHOLD;
                check(
                    chu.overflow_routed == should_route,
                    format!(
                        "chunked routing at {len} tokens: got {}, predicted saturation says \
                         {should_route}",
                        chu.overflow_routed
                    ),
                )?;
                routed += chu.overflow_routed as usize;

                preds[0].push(predict(&off, e)?);
                preds[1].push(predict(&sel, e)?);
                preds[2].push(predict(&chu, e)?);
            }
            let accs: Vec<f64> = preds.iter().map(|p| accuracy(&eps, p)).collect();
            if len == longest {
                hits_off_longest += (accs[0] * eps.len() as f64).round() as usize;
                hits_sel_longest += (accs[1] * eps.len() as f64).round() as usize;
                sat_longest += sat_sum / eps.len() as f64;
            }
            if len == lens[0] {
                sat_shortest += sat_sum / eps.len() as f64;
            }
            t.row(vec![
                task.to_string(),
                format!("{len}"),
                format!("{:.2}", accs[0]),
                format!("{:.2}", accs[1]),
                format!("{:.2}", accs[2]),
                format!("{skipped}"),
                format!("{:.2}", sat_sum / eps.len() as f64),
                format!("{routed}/{}", eps.len()),
            ]);
        }
    }
    ctx.table(&t);

    // Selection never loses accuracy at the longest context (pooled
    // over both tasks).
    check(
        hits_sel_longest >= hits_off_longest,
        format!(
            "selection lost accuracy at {longest} tokens: {hits_sel_longest} vs \
             {hits_off_longest} hits"
        ),
    )?;
    // Saturation grows with context: the fill term rises monotonically,
    // and the update/state energy ratio of an additive memory only
    // shrinks as the state accumulates.
    check(
        sat_longest > sat_shortest,
        format!("saturation must grow with context: {sat_longest:.3} vs {sat_shortest:.3}"),
    )?;
    // The counters CI greps for are really nonzero where they should
    // be, and untouched where they must not be.
    check(sel_engine.stats_handle().segments_skipped.get() > 0, "selection never gated a segment")?;
    check(chu_engine.stats_handle().overflow_routed.get() > 0, "chunked never routed a request")?;
    check(off_engine.stats_handle().segments_skipped.get() == 0, "off engine must never gate")?;
    check(off_engine.stats_handle().overflow_routed.get() == 0, "off engine must never route")?;
    check(
        sel_engine.stats_handle().saturation_milli.get() > 0,
        "saturation gauge never left zero",
    )?;
    // Observability: the quality fields reach the stats JSON (the
    // `{"cmd":"stats"}` body) and the Prometheus export.
    let js = sel_engine.stats_handle().to_json().to_json();
    for key in ["\"saturation\"", "\"segments_skipped\"", "\"overflow_routed\""] {
        check(js.contains(key), format!("{key} missing from stats JSON"))?;
    }
    let prom = render_prometheus(&sel_engine.stats_handle(), None);
    for series in
        ["pallas_saturation ", "pallas_segments_skipped_total ", "pallas_overflow_routed_total "]
    {
        check(prom.contains(series), format!("{series} missing from /metrics"))?;
    }

    let denom = (2 * n_eps) as f64;
    ctx.metric_info("acc_off_longest", hits_off_longest as f64 / denom);
    ctx.metric_info("acc_select_longest", hits_sel_longest as f64 / denom);
    ctx.metric_info(
        "segments_skipped_total",
        sel_engine.stats_handle().segments_skipped.get() as f64,
    );
    ctx.metric_info(
        "overflow_routed_total",
        chu_engine.stats_handle().overflow_routed.get() as f64,
    );
    ctx.metric_info("saturation_longest", sat_longest / 2.0);
    ctx.note(format!(
        "OK: policy-off bit-identical to the pre-quality executor on every episode; selection \
         gated {} memory writes with no accuracy loss at {longest} tokens; chunked routed exactly \
         the >72-token prompts; quality counters live in stats JSON and /metrics",
        sel_engine.stats_handle().segments_skipped.get()
    ));
    Ok(())
}
