//! Suite registry: every benchmark in the tree is a named, tagged
//! [`Suite`] over a [`SuiteCtx`], runnable three ways with one body:
//!
//! * `cargo bench --bench <name>` — the legacy per-suite binaries call
//!   [`run_suite_main`];
//! * `diagonal-batching bench --suite '<glob>'` — the CLI calls
//!   [`run_matching`] and gets a [`BenchReport`] back;
//! * in-process from tests (`rust/tests/bench_suites.rs`).
//!
//! A suite prints its human tables exactly as the old hand-rolled
//! `main()`s did, and *additionally* records [`SampleStats`] /
//! [`Metric`]s into the machine-readable report. Invariant checks return
//! `Err(Error::Bench(..))` instead of panicking, so one broken suite
//! marks itself `failed` without killing the rest of the run.

use std::time::Duration;

use crate::bench::report::{
    git_sha, BenchReport, Better, Metric, RunMeta, SampleStats, SuiteReport, SuiteStatus,
    SCHEMA_VERSION,
};
use crate::bench::{Sample, Table};
use crate::config::Manifest;
use crate::error::Result;
use crate::simulator::DeviceSpec;

/// A registered benchmark suite.
#[derive(Clone, Copy)]
pub struct Suite {
    /// Unique name; also the legacy bench-binary name.
    pub name: &'static str,
    /// Selection tags (`fig`, `table`, `perf`, `serve`) and substrate
    /// tags (`simulated`, `native`, `hlo`, `measured`). `--suite` globs
    /// match the name or any tag.
    pub tags: &'static [&'static str],
    /// One-line description (shown by `bench --list true`).
    pub about: &'static str,
    pub run: fn(&mut SuiteCtx) -> Result<()>,
}

/// Knobs shared by every suite in one run.
#[derive(Clone, Debug)]
pub struct BenchSettings {
    /// Where to look for the AOT artifacts; HLO suites skip when this
    /// does not load.
    pub manifest_path: String,
    /// Simulated device model: "a100" (default), "h100", or "ci" (the
    /// measured CI-host CPU).
    pub device: String,
    /// CI-sized iteration budgets (roughly 8x shorter measurements).
    pub fast: bool,
    /// Wavefront lanes for the serving suites.
    pub lanes: usize,
}

impl Default for BenchSettings {
    fn default() -> Self {
        Self {
            manifest_path: crate::config::DEFAULT_MANIFEST.to_string(),
            device: "a100".to_string(),
            fast: false,
            lanes: 2,
        }
    }
}

impl BenchSettings {
    pub fn device_spec(&self) -> DeviceSpec {
        match self.device.as_str() {
            "h100" => DeviceSpec::h100(),
            "ci" | "ci-host" => DeviceSpec::ci_host(),
            _ => DeviceSpec::a100(),
        }
    }
}

/// Per-suite execution context: settings in, report rows out.
pub struct SuiteCtx {
    settings: BenchSettings,
    manifest: Option<Manifest>,
    report: SuiteReport,
    skipped: Option<String>,
}

impl SuiteCtx {
    fn new(suite: &Suite, settings: &BenchSettings, manifest: Option<Manifest>) -> Self {
        Self {
            settings: settings.clone(),
            manifest,
            report: SuiteReport::new(suite.name, suite.tags),
            skipped: None,
        }
    }

    pub fn settings(&self) -> &BenchSettings {
        &self.settings
    }

    /// The loaded artifact manifest, when `manifest_path` parsed.
    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_ref()
    }

    pub fn device(&self) -> DeviceSpec {
        self.settings.device_spec()
    }

    /// Measurement budget: `full_ms` normally, ~1/8 (floor 20ms) in fast
    /// mode.
    pub fn budget(&self, full_ms: u64) -> Duration {
        Duration::from_millis(if self.settings.fast { (full_ms / 8).max(20) } else { full_ms })
    }

    /// Fixed iteration count: `full` normally, at most 2 in fast mode.
    pub fn iters(&self, full: usize) -> usize {
        if self.settings.fast {
            full.clamp(1, 2)
        } else {
            full.max(1)
        }
    }

    /// Declare the suite unrunnable here (missing artifacts, PJRT
    /// unavailable). The suite should return `Ok(())` right after.
    pub fn skip(&mut self, reason: impl Into<String>) {
        let reason = reason.into();
        println!("SKIP: {reason}");
        self.skipped = Some(reason);
    }

    /// Print and record a free-form observation.
    pub fn note(&mut self, msg: impl Into<String>) {
        let msg = msg.into();
        println!("{msg}");
        self.report.notes.push(msg);
    }

    /// Print a human table (tables are presentation-only; record the
    /// numbers behind them as metrics/samples).
    pub fn table(&mut self, t: &Table) {
        t.print();
    }

    /// Print and record one timing measurement.
    pub fn sample(&mut self, s: &Sample) {
        println!("{s}");
        self.report.samples.push(SampleStats::from(s));
    }

    /// Record a deterministic lower-is-better quantity (modeled
    /// seconds); gated by `--compare`.
    pub fn metric_lower(&mut self, name: impl Into<String>, value: f64) {
        self.push_metric(name.into(), value, Better::Lower);
    }

    /// Record a deterministic higher-is-better quantity (speedups);
    /// gated by `--compare`.
    pub fn metric_higher(&mut self, name: impl Into<String>, value: f64) {
        self.push_metric(name.into(), value, Better::Higher);
    }

    /// Record an informational quantity (machine-dependent throughput);
    /// never gated.
    pub fn metric_info(&mut self, name: impl Into<String>, value: f64) {
        self.push_metric(name.into(), value, Better::Info);
    }

    fn push_metric(&mut self, name: String, value: f64, better: Better) {
        self.report.metrics.push(Metric { name, value, better });
    }
}

/// Simple glob: `*` matches any run of characters; everything else is
/// literal. Patterns may be comma-separated ("fig*,serve*").
pub fn glob_match(pattern: &str, name: &str) -> bool {
    pattern.split(',').map(str::trim).filter(|p| !p.is_empty()).any(|p| glob_one(p, name))
}

fn glob_one(pattern: &str, name: &str) -> bool {
    fn inner(p: &[u8], n: &[u8]) -> bool {
        match p.split_first() {
            None => n.is_empty(),
            Some((&b'*', rest)) => (0..=n.len()).any(|i| inner(rest, &n[i..])),
            Some((c, rest)) => n.split_first().is_some_and(|(d, nr)| c == d && inner(rest, nr)),
        }
    }
    inner(pattern.as_bytes(), name.as_bytes())
}

fn suite_matches(suite: &Suite, pattern: &str) -> bool {
    glob_match(pattern, suite.name) || suite.tags.iter().any(|t| glob_match(pattern, t))
}

/// Run every registered suite whose name or tag matches `pattern`,
/// collecting a versioned [`BenchReport`].
pub fn run_matching(pattern: &str, settings: &BenchSettings) -> BenchReport {
    let manifest = Manifest::load(&settings.manifest_path).ok();
    let dev = settings.device_spec();
    let mut suites = Vec::new();
    for suite in crate::bench::suites::all() {
        if !suite_matches(&suite, pattern) {
            continue;
        }
        println!("\n==== suite {} ====", suite.name);
        suites.push(run_one(&suite, settings, manifest.clone()));
    }
    // "+hlo" means HLO execution actually works here (a model loads on
    // a live PJRT client) — not merely that the manifest lists
    // executables, which is also true under the non-executing xla-stub.
    let hlo_available = manifest
        .as_ref()
        .is_some_and(|m| m.models.keys().any(|name| crate::runtime::HloBackend::load(m, name).is_ok()));
    BenchReport {
        schema_version: SCHEMA_VERSION,
        meta: RunMeta {
            git_sha: git_sha(),
            crate_version: env!("CARGO_PKG_VERSION").to_string(),
            device: dev.name.to_string(),
            peak_tflops: dev.peak_flops / 1e12,
            mem_bw_gbs: dev.mem_bw / 1e9,
            lanes: settings.lanes,
            fast: settings.fast,
            backend: if hlo_available {
                "native+simulated+hlo".to_string()
            } else {
                "native+simulated".to_string()
            },
            os: std::env::consts::OS.to_string(),
            arch: std::env::consts::ARCH.to_string(),
            created_unix: std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
        },
        suites,
    }
}

fn run_one(suite: &Suite, settings: &BenchSettings, manifest: Option<Manifest>) -> SuiteReport {
    let mut ctx = SuiteCtx::new(suite, settings, manifest);
    let outcome = (suite.run)(&mut ctx);
    let mut report = ctx.report;
    match (outcome, ctx.skipped) {
        (Err(e), _) => {
            report.status = SuiteStatus::Failed;
            report.detail = e.to_string();
            println!("FAILED: {}", report.detail);
        }
        (Ok(()), Some(reason)) => {
            report.status = SuiteStatus::Skipped;
            report.detail = reason;
        }
        (Ok(()), None) => {
            report.status = SuiteStatus::Ok;
        }
    }
    report
}

/// Entry point for the legacy `cargo bench` binaries: run exactly one
/// suite with full (non-fast) budgets, print its tables, exit nonzero
/// if an invariant check failed. A skip (missing artifacts) exits zero,
/// mirroring how the artifact-gated tests skip.
pub fn run_suite_main(name: &str) -> std::process::ExitCode {
    let settings = BenchSettings::default();
    let Some(suite) = crate::bench::suites::all().into_iter().find(|s| s.name == name) else {
        eprintln!("error: suite '{name}' is not registered");
        return std::process::ExitCode::FAILURE;
    };
    let manifest = Manifest::load(&settings.manifest_path).ok();
    let report = run_one(&suite, &settings, manifest);
    match report.status {
        SuiteStatus::Failed => std::process::ExitCode::FAILURE,
        _ => std::process::ExitCode::SUCCESS,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glob_semantics() {
        assert!(glob_match("*", "anything"));
        assert!(glob_match("fig*", "fig1_headline"));
        assert!(!glob_match("fig*", "table1_llama1b"));
        assert!(glob_match("*llama*", "table1_llama1b"));
        assert!(glob_match("serve", "serve"));
        assert!(!glob_match("serve", "serve_latency"));
        assert!(glob_match("fig*,table*", "table5_llama3b"));
        assert!(glob_match(" fig* , serve* ", "serve_latency"));
        assert!(!glob_match("", "x"));
    }

    #[test]
    fn fast_mode_shrinks_budgets() {
        let mut settings = BenchSettings::default();
        let suite = crate::bench::suites::all()[0];
        let ctx = SuiteCtx::new(&suite, &settings, None);
        assert_eq!(ctx.budget(400), Duration::from_millis(400));
        assert_eq!(ctx.iters(5), 5);
        settings.fast = true;
        let ctx = SuiteCtx::new(&suite, &settings, None);
        assert_eq!(ctx.budget(400), Duration::from_millis(50));
        assert_eq!(ctx.iters(5), 2);
    }

    #[test]
    fn registry_names_are_unique_and_tagged() {
        let suites = crate::bench::suites::all();
        let mut names: Vec<_> = suites.iter().map(|s| s.name).collect();
        names.sort_unstable();
        let n = names.len();
        names.dedup();
        assert_eq!(names.len(), n, "duplicate suite names");
        for s in &suites {
            assert!(!s.tags.is_empty(), "{} has no tags", s.name);
            assert!(!s.about.is_empty(), "{} has no description", s.name);
        }
    }
}
