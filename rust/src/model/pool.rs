//! [`ParallelCellPool`] — the native backend's persistent worker-thread
//! pool, turning the diagonal wavefront from a scheduling *simulation*
//! into an actually-parallel runtime.
//!
//! The paper's core observation (arXiv 2506.05229) is that every cell of
//! a diagonal wavefront is independent: cell `(r, s, l)` depends only on
//! `(r, s-1, l)` and `(r, s, l-1)`, both of which completed in earlier
//! wavefront iterations, so the `L x B` grid of one `grouped_step` can
//! run concurrently. The pool makes that true on the native backend:
//!
//! * **fan-out** — each active `(layer, lane)` cell becomes one
//!   [`CellJob`] on a shared queue; `threads` persistent workers pull
//!   jobs and execute [`cell_task`](crate::model::cell_task) against a
//!   shared `Arc<Params>` snapshot (no copies, no locks on the weights).
//!   The snapshot carries the params' prepared kernel weights, so every
//!   worker inherits the backend's [`Precision`](crate::tensor::Precision)
//!   — f32, f16, bf16, or int8 — automatically, and
//!   [`NativeBackend::with_precision`](crate::model::NativeBackend::with_precision)
//!   rebuilds the pool so re-preparation can never race a running step;
//! * **join** — [`execute`](ParallelCellPool::execute) blocks until
//!   every job of the step has returned, *before* the session's memory
//!   hand-off (the shift that feeds cell outputs to the next diagonal);
//! * **determinism** — each cell writes a disjoint `(layer, lane)` slot
//!   and its math runs on exactly one thread with a fixed accumulation
//!   order, so results are written back by slot index and the step is
//!   **bit-identical** to the sequential loop regardless of which worker
//!   finishes first. `rust/tests/parallel_parity.rs` and proptest P10
//!   enforce this byte-for-byte.
//!
//! The pool uses only `std` threads + channels (the offline toolchain
//! has no rayon/crossbeam). Workers live as long as the owning
//! [`NativeBackend`](crate::model::NativeBackend) and shut down when the
//! job channel closes on drop.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::metrics::Counter;
use crate::model::{cell, Params};
use crate::tensor::Tensor;

/// One wavefront cell, packaged for a worker: the slot index it must be
/// written back to, the layer whose weights apply, and owned copies of
/// the cell's `x [T, d]`, `a [d, p]`, `z [p]` inputs.
pub struct CellJob {
    /// Row-major `(layer, lane)` slot index (`layer * lanes + lane`).
    pub slot: usize,
    pub layer: usize,
    pub x: Tensor,
    pub a: Tensor,
    pub z: Tensor,
}

/// A completed cell: `(y, a', z')` tagged with the slot it came from.
pub struct CellResult {
    pub slot: usize,
    pub y: Tensor,
    pub a2: Tensor,
    pub z2: Tensor,
}

/// Aggregate worker counters (shared: workers write, anyone snapshots).
#[derive(Default)]
pub struct PoolStats {
    /// Cells executed on pool workers.
    pub cells: Counter,
    /// Summed per-cell compute time across all workers, in nanoseconds
    /// (accumulated at ns so sub-microsecond cells still register;
    /// divide by `threads x wall` for utilization).
    pub busy_ns: Counter,
}

impl PoolStats {
    /// Accumulated busy time in whole microseconds (truncated once, at
    /// read time, over the ns total).
    pub fn busy_us(&self) -> u64 {
        self.busy_ns.get() / 1_000
    }
}

/// Default worker count: the `PALLAS_THREADS` env var when set to a
/// positive integer (the CI single-thread parity pass forces
/// `PALLAS_THREADS=1`), else the host's available parallelism.
pub fn default_threads() -> usize {
    if let Ok(s) = std::env::var("PALLAS_THREADS") {
        if let Ok(n) = s.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Join-side deadlock tripwire: far beyond any cell this repo's CPU
/// configs can take, small enough that a lost worker fails the step
/// with a diagnostic instead of hanging the serving loop forever.
const JOIN_TIMEOUT: Duration = Duration::from_secs(120);

/// Persistent worker-thread pool executing wavefront cells.
///
/// Constructed by
/// [`NativeBackend::with_threads`](crate::model::NativeBackend::with_threads);
/// `threads = 1` callers skip the pool entirely (the inline loop *is*
/// the single-threaded path — there is no channel hop to pay).
pub struct ParallelCellPool {
    job_tx: Option<Sender<CellJob>>,
    results: Receiver<CellResult>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
    stats: Arc<PoolStats>,
    /// Test-only scheduling-jitter hook: when nonzero, each worker
    /// sleeps a pseudorandom `0..jitter_us` microseconds before every
    /// cell, scrambling completion order. Proptest P10 uses this to
    /// prove results are invariant to worker scheduling.
    jitter_us: Arc<AtomicU64>,
}

impl ParallelCellPool {
    /// Spawn `threads` workers sharing `params` (one `Arc` clone per
    /// worker — the weights are never copied).
    pub fn new(cfg: ModelConfig, params: Arc<Params>, threads: usize) -> Self {
        let threads = threads.max(1);
        let (job_tx, job_rx) = channel::<CellJob>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (res_tx, results) = channel::<CellResult>();
        let stats = Arc::new(PoolStats::default());
        let jitter_us = Arc::new(AtomicU64::new(0));
        let cfg = Arc::new(cfg);
        let mut workers = Vec::with_capacity(threads);
        for w in 0..threads {
            let job_rx = Arc::clone(&job_rx);
            let res_tx = res_tx.clone();
            let cfg = Arc::clone(&cfg);
            let params = Arc::clone(&params);
            let stats = Arc::clone(&stats);
            let jitter = Arc::clone(&jitter_us);
            let handle = std::thread::Builder::new()
                .name(format!("pallas-cell-{w}"))
                .spawn(move || worker_loop(w, &job_rx, &res_tx, &cfg, &params, &stats, &jitter))
                .expect("spawn cell worker");
            workers.push(handle);
        }
        Self { job_tx: Some(job_tx), results, workers, threads, stats, jitter_us }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn stats(&self) -> &PoolStats {
        &self.stats
    }

    /// Enable (`max_us > 0`) or disable the per-cell scheduling jitter.
    /// Determinism-test hook only — never set in production paths.
    pub fn set_test_jitter(&self, max_us: u64) {
        self.jitter_us.store(max_us, Ordering::Relaxed);
    }

    /// Fan one wavefront step's cells out and join: blocks until every
    /// job has produced its [`CellResult`]. Results arrive in completion
    /// order; callers MUST write them back by `slot`, never by arrival
    /// position — that is the determinism rule that keeps the pooled
    /// step bit-identical to the sequential loop.
    pub fn execute(&self, jobs: Vec<CellJob>) -> Result<Vec<CellResult>> {
        // Defensive: a previous step that timed out may have left
        // straggler results behind; they must not be attributed to this
        // step's slots.
        while self.results.try_recv().is_ok() {}
        let n = jobs.len();
        let tx = self.job_tx.as_ref().expect("pool is alive until dropped");
        for job in jobs {
            tx.send(job)
                .map_err(|_| Error::Schedule("cell pool: every worker exited".into()))?;
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            match self.results.recv_timeout(JOIN_TIMEOUT) {
                Ok(r) => out.push(r),
                Err(e) => {
                    // Distinguish "a worker died mid-job" (its result
                    // will never arrive) from a genuinely stuck cell,
                    // so the error names the real failure instead of a
                    // generic stall.
                    let dead = self.workers.iter().filter(|h| h.is_finished()).count();
                    return Err(Error::Schedule(format!(
                        "cell pool stalled after {}s waiting for {} of {n} cells \
                         ({dead} of {} workers dead): {e}",
                        JOIN_TIMEOUT.as_secs(),
                        n - out.len(),
                        self.threads,
                    )));
                }
            }
        }
        Ok(out)
    }
}

impl Drop for ParallelCellPool {
    fn drop(&mut self) {
        // Closing the job channel ends every worker's recv loop.
        drop(self.job_tx.take());
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    index: usize,
    jobs: &Mutex<Receiver<CellJob>>,
    results: &Sender<CellResult>,
    cfg: &ModelConfig,
    params: &Params,
    stats: &PoolStats,
    jitter_us: &AtomicU64,
) {
    // Per-worker xorshift state for the test-jitter hook (seeded by
    // worker index so sleeps differ across workers).
    let mut rng =
        0x9E37_79B9_7F4A_7C15u64 ^ ((index as u64 + 1).wrapping_mul(0xBF58_476D_1CE4_E5B9));
    loop {
        // Hold the queue lock only for the dequeue; compute runs
        // unlocked and fully parallel.
        let msg = match jobs.lock() {
            Ok(rx) => rx.recv(),
            Err(_) => break, // a sibling worker panicked mid-recv
        };
        let Ok(job) = msg else { break };
        let max = jitter_us.load(Ordering::Relaxed);
        if max > 0 {
            rng ^= rng << 13;
            rng ^= rng >> 7;
            rng ^= rng << 17;
            std::thread::sleep(Duration::from_micros(rng % max));
        }
        let t0 = Instant::now();
        let (y, a2, z2) = cell::cell_task(cfg, params, job.layer, &job.x, &job.a, &job.z);
        stats.busy_ns.add(t0.elapsed().as_nanos() as u64);
        stats.cells.inc();
        if results.send(CellResult { slot: job.slot, y, a2, z2 }).is_err() {
            break; // pool dropped mid-flight
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn cfg() -> ModelConfig {
        crate::model::tests::test_config()
    }

    fn jobs_for(cfg: &ModelConfig, n: usize, seed: u64) -> Vec<CellJob> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| CellJob {
                slot: i,
                layer: i % cfg.n_layers,
                x: Tensor::randn(&[cfg.seg_total, cfg.d_model], 0.5, &mut rng),
                a: Tensor::randn(&[cfg.d_model, cfg.phi_dim], 0.1, &mut rng),
                z: Tensor::randn(&[cfg.phi_dim], 0.1, &mut rng),
            })
            .collect()
    }

    #[test]
    fn pool_matches_inline_cell_task_bitexact() {
        let c = cfg();
        let params = Arc::new(Params::random(&c, 3));
        let pool = ParallelCellPool::new(c.clone(), Arc::clone(&params), 4);
        let jobs = jobs_for(&c, 9, 7);
        let want: Vec<(Tensor, Tensor, Tensor)> = jobs
            .iter()
            .map(|j| cell::cell_task(&c, &params, j.layer, &j.x, &j.a, &j.z))
            .collect();
        let mut got = pool.execute(jobs).unwrap();
        got.sort_by_key(|r| r.slot);
        assert_eq!(got.len(), want.len());
        for (r, (y, a2, z2)) in got.iter().zip(&want) {
            assert_eq!(&r.y, y, "slot {}", r.slot);
            assert_eq!(&r.a2, a2, "slot {}", r.slot);
            assert_eq!(&r.z2, z2, "slot {}", r.slot);
        }
        assert_eq!(pool.stats().cells.get(), 9);
    }

    #[test]
    fn jitter_scrambles_schedule_not_results() {
        let c = cfg();
        let params = Arc::new(Params::random(&c, 4));
        let quiet = ParallelCellPool::new(c.clone(), Arc::clone(&params), 3);
        let noisy = ParallelCellPool::new(c.clone(), Arc::clone(&params), 3);
        noisy.set_test_jitter(300);
        let mut a = quiet.execute(jobs_for(&c, 12, 9)).unwrap();
        let mut b = noisy.execute(jobs_for(&c, 12, 9)).unwrap();
        a.sort_by_key(|r| r.slot);
        b.sort_by_key(|r| r.slot);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.y, y.y);
            assert_eq!(x.a2, y.a2);
            assert_eq!(x.z2, y.z2);
        }
    }

    #[test]
    fn pool_survives_many_steps_and_counts_busy_time() {
        let c = cfg();
        let params = Arc::new(Params::random(&c, 5));
        let pool = ParallelCellPool::new(c.clone(), params, 2);
        for step in 0..5 {
            let out = pool.execute(jobs_for(&c, 4, step)).unwrap();
            assert_eq!(out.len(), 4);
        }
        assert_eq!(pool.stats().cells.get(), 20);
        // Busy time accumulates at ns granularity, so even release-mode
        // sub-microsecond cells must register.
        assert!(pool.stats().busy_ns.get() > 0);
    }

    #[test]
    fn empty_execute_is_a_no_op() {
        let c = cfg();
        let pool = ParallelCellPool::new(c.clone(), Arc::new(Params::random(&c, 6)), 2);
        assert!(pool.execute(Vec::new()).unwrap().is_empty());
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }
}
