//! The ARMT cell math (DESIGN.md "ARMT cell semantics"), mirroring the L2
//! jax model op-for-op: associative read (eq. 6) -> RMSNorm -> causal MHA
//! with RoPE -> residual -> RMSNorm -> SwiGLU -> residual -> delta-rule
//! memory update (eqs. 3-5).

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::model::params::{LayerTensors, Params, QuantLayer};
use crate::tensor::{self, Tensor, WeightView};

/// Re-exported alias: a materialized single-layer parameter view.
pub type LayerView<'a> = LayerTensors<'a>;

/// Borrowed kernel-facing weight set for one layer. Every *weight*
/// matmul in the cell goes through a [`WeightView`] — which may be
/// exact f32 (byte-identical to the plain tensor path) or a prepared
/// f16/bf16/int8 [`WeightMat`](crate::tensor::WeightMat) — while
/// activation-by-activation products (attention scores, `probs @ v`,
/// the delta-rule state math) and the small norm/bias vectors stay
/// plain f32 tensors. This is the single seam through which the whole
/// wavefront runs on quantized weights end-to-end.
pub(crate) struct CellWeights<'a> {
    wq: WeightView<'a>,
    wk: WeightView<'a>,
    wv: WeightView<'a>,
    wo: WeightView<'a>,
    wg: WeightView<'a>,
    wu: WeightView<'a>,
    wd: WeightView<'a>,
    aq: WeightView<'a>,
    ak: WeightView<'a>,
    av: WeightView<'a>,
    n1: &'a Tensor,
    n2: &'a Tensor,
    ab: &'a Tensor,
}

impl<'a> CellWeights<'a> {
    /// Exact-f32 views over a materialized layer (the legacy path —
    /// byte-identical to pre-kernel-tier behavior).
    pub(crate) fn from_layer(lt: &'a LayerTensors<'a>) -> Self {
        Self {
            wq: WeightView::from_tensor(&lt.wq),
            wk: WeightView::from_tensor(&lt.wk),
            wv: WeightView::from_tensor(&lt.wv),
            wo: WeightView::from_tensor(&lt.wo),
            wg: WeightView::from_tensor(&lt.wg),
            wu: WeightView::from_tensor(&lt.wu),
            wd: WeightView::from_tensor(&lt.wd),
            aq: WeightView::from_tensor(&lt.aq),
            ak: WeightView::from_tensor(&lt.ak),
            av: WeightView::from_tensor(&lt.av),
            n1: &lt.n1,
            n2: &lt.n2,
            ab: &lt.ab,
        }
    }

    /// Views over prepared kernel weights (any [`Precision`]
    /// (crate::tensor::Precision)) — what [`cell_task`] uses once
    /// [`Params::prepare`] has run.
    pub(crate) fn from_quant(q: &'a QuantLayer) -> Self {
        Self {
            wq: q.wq.view(),
            wk: q.wk.view(),
            wv: q.wv.view(),
            wo: q.wo.view(),
            wg: q.wg.view(),
            wu: q.wu.view(),
            wd: q.wd.view(),
            aq: q.aq.view(),
            ak: q.ak.view(),
            av: q.av.view(),
            n1: &q.n1,
            n2: &q.n2,
            ab: &q.ab,
        }
    }
}

/// Associative read with residual (eq. 6):
/// `x_i += A phi(W_Q x_i) / (z^T phi(W_Q x_i) + eps)`.
///
/// x: [T, d], a: [d, p], z: [p], wq: [d, k]. With a = z = 0 this is an
/// exact identity (segment 0 needs no gate).
pub fn assoc_read(cfg: &ModelConfig, x: &Tensor, a: &Tensor, z: &Tensor, wq: &Tensor) -> Tensor {
    assoc_read_w(cfg, x, a, z, WeightView::from_tensor(wq))
}

fn assoc_read_w(
    cfg: &ModelConfig,
    x: &Tensor,
    a: &Tensor,
    z: &Tensor,
    wq: WeightView<'_>,
) -> Tensor {
    let q = tensor::dpfp(&wq.matmul(x), cfg.dpfp_nu); // [T, p]
    let num = tensor::matmul_bt(&q, a); // [T, d] = q @ a^T
    let (t, d) = (x.shape()[0], x.shape()[1]);
    let mut out = x.clone();
    for i in 0..t {
        let qrow = q.row(i);
        let den: f32 =
            qrow.iter().zip(z.data()).map(|(a, b)| a * b).sum::<f32>() + cfg.eps;
        let orow = &mut out.data_mut()[i * d..(i + 1) * d];
        let nrow = num.row(i);
        for j in 0..d {
            orow[j] += nrow[j] / den;
        }
    }
    out
}

/// Delta-rule memory update (eqs. 3-5) over the memory-token outputs.
/// y_mem: [m, d]; returns (a', z').
pub fn assoc_update(
    cfg: &ModelConfig,
    y_mem: &Tensor,
    a: &Tensor,
    z: &Tensor,
    ak: &Tensor,
    av: &Tensor,
    ab: &Tensor,
) -> (Tensor, Tensor) {
    assoc_update_w(
        cfg,
        y_mem,
        a,
        z,
        WeightView::from_tensor(ak),
        WeightView::from_tensor(av),
        ab,
    )
}

fn assoc_update_w(
    cfg: &ModelConfig,
    y_mem: &Tensor,
    a: &Tensor,
    z: &Tensor,
    ak: WeightView<'_>,
    av: WeightView<'_>,
    ab: &Tensor,
) -> (Tensor, Tensor) {
    let eps = cfg.eps;
    let k = tensor::dpfp(&ak.matmul(y_mem), cfg.dpfp_nu); // [m, p]
    let v = av.matmul(y_mem); // [m, d]
    let m = y_mem.shape()[0];
    let d = cfg.d_model;
    let p = cfg.phi_dim;

    let mut a2 = a.clone();
    let mut z2 = z.clone();
    // Accumulate per-token rank-1 deltas; the sum over i matches the
    // kernel's single fused matmul because addition order over i is fixed.
    let mut da = vec![0.0f32; d * p];
    let mut dz = vec![0.0f32; p];
    for i in 0..m {
        let yrow = y_mem.row(i);
        let krow = k.row(i);
        let beta = tensor::sigmoid(
            yrow.iter().zip(ab.data()).map(|(a, b)| a * b).sum::<f32>(),
        );
        let den: f32 = krow.iter().zip(z.data()).map(|(a, b)| a * b).sum();
        // v_bar_i = A phi(k_i) / (den + eps)
        let mut v_bar = vec![0.0f32; d];
        for r in 0..d {
            let arow = &a.data()[r * p..(r + 1) * p];
            let mut acc = 0.0f32;
            for c in 0..p {
                acc += arow[c] * krow[c];
            }
            v_bar[r] = acc / (den + eps);
        }
        let norm2: f32 = krow.iter().map(|x| x * x).sum();
        let gamma = 1.0 - den / (norm2 + eps);
        let vrow = v.row(i);
        for r in 0..d {
            let coeff = beta * (vrow[r] - v_bar[r]);
            let darow = &mut da[r * p..(r + 1) * p];
            for c in 0..p {
                darow[c] += coeff * krow[c];
            }
        }
        for c in 0..p {
            dz[c] += gamma * krow[c];
        }
    }
    for (x, y) in a2.data_mut().iter_mut().zip(&da) {
        *x += y;
    }
    for (x, y) in z2.data_mut().iter_mut().zip(&dz) {
        *x += y;
    }
    (a2, z2)
}

/// Multi-head attention with RoPE and the ARMT mask (causal for segment
/// tokens, full visibility for trailing memory tokens). x: [T, d].
pub fn attention(
    cfg: &ModelConfig,
    x: &Tensor,
    wq: &Tensor,
    wk: &Tensor,
    wv: &Tensor,
    wo: &Tensor,
    seg: usize,
) -> Tensor {
    attention_w(
        cfg,
        x,
        WeightView::from_tensor(wq),
        WeightView::from_tensor(wk),
        WeightView::from_tensor(wv),
        WeightView::from_tensor(wo),
        seg,
    )
}

fn attention_w(
    cfg: &ModelConfig,
    x: &Tensor,
    wq: WeightView<'_>,
    wk: WeightView<'_>,
    wv: WeightView<'_>,
    wo: WeightView<'_>,
    seg: usize,
) -> Tensor {
    let (t, d) = (x.shape()[0], x.shape()[1]);
    let h = cfg.n_heads;
    let hd = d / h;
    let q = wq.matmul(x);
    let k = wk.matmul(x);
    let v = wv.matmul(x);

    let head = |m: &Tensor, hi: usize| -> Tensor {
        let mut out = Tensor::zeros(&[t, hd]);
        for i in 0..t {
            out.data_mut()[i * hd..(i + 1) * hd]
                .copy_from_slice(&m.row(i)[hi * hd..(hi + 1) * hd]);
        }
        out
    };

    let scale = 1.0 / (hd as f32).sqrt();
    let mut merged = Tensor::zeros(&[t, d]);
    for hi in 0..h {
        let qh = tensor::rope_rows(&head(&q, hi), cfg.rope_theta);
        let kh = tensor::rope_rows(&head(&k, hi), cfg.rope_theta);
        let vh = head(&v, hi);
        let mut scores = tensor::scale(&tensor::matmul_bt(&qh, &kh), scale);
        for i in 0..t {
            for j in 0..t {
                let allowed = j <= i || i >= seg;
                if !allowed {
                    scores.data_mut()[i * t + j] = -1e30;
                }
            }
        }
        let probs = tensor::softmax_rows(&scores);
        let oh = tensor::matmul(&probs, &vh); // [t, hd]
        for i in 0..t {
            merged.data_mut()[i * d + hi * hd..i * d + (hi + 1) * hd]
                .copy_from_slice(oh.row(i));
        }
    }
    wo.matmul(&merged)
}

/// SwiGLU MLP: (silu(x wg) * (x wu)) wd. x: [T, d].
pub fn swiglu(x: &Tensor, wg: &Tensor, wu: &Tensor, wd: &Tensor) -> Tensor {
    swiglu_w(
        x,
        WeightView::from_tensor(wg),
        WeightView::from_tensor(wu),
        WeightView::from_tensor(wd),
    )
}

fn swiglu_w(x: &Tensor, wg: WeightView<'_>, wu: WeightView<'_>, wd: WeightView<'_>) -> Tensor {
    let gate = tensor::map(&wg.matmul(x), tensor::silu);
    let up = wu.matmul(x);
    wd.matmul(&tensor::mul(&gate, &up))
}

/// One full (segment, layer) cell: read -> transformer layer -> update.
/// x: [T, d], a: [d, p], z: [p]. Returns (y, a', z').
pub fn layer_step(
    cfg: &ModelConfig,
    lp: &LayerTensors<'_>,
    x: &Tensor,
    a: &Tensor,
    z: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    layer_step_w(cfg, &CellWeights::from_layer(lp), x, a, z)
}

/// [`layer_step`] over any [`CellWeights`] — the one implementation
/// both the legacy tensor path and the prepared kernel path share.
pub(crate) fn layer_step_w(
    cfg: &ModelConfig,
    w: &CellWeights<'_>,
    x: &Tensor,
    a: &Tensor,
    z: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let xr = assoc_read_w(cfg, x, a, z, w.aq);
    let attn = attention_w(
        cfg,
        &tensor::rmsnorm(&xr, w.n1, cfg.eps),
        w.wq,
        w.wk,
        w.wv,
        w.wo,
        cfg.seg,
    );
    let h = tensor::add(&xr, &attn);
    let mlp = swiglu_w(&tensor::rmsnorm(&h, w.n2, cfg.eps), w.wg, w.wu, w.wd);
    let y = tensor::add(&h, &mlp);
    let y_mem = y.slice0(cfg.seg, cfg.seg_total);
    let (a2, z2) = assoc_update_w(cfg, &y_mem, a, z, w.ak, w.av, w.ab);
    (y, a2, z2)
}

/// One wavefront cell as a self-contained work unit: materialize layer
/// `l`'s weights from `params` and run [`layer_step`] — the function a
/// [`ParallelCellPool`](crate::model::ParallelCellPool) worker executes.
///
/// This is the compute/mutation split that makes cells parallelizable:
/// everything here is a pure function of `(params, layer, x, a, z)` —
/// no backend counters, no shared slot tensors — so any thread may run
/// any cell. All shared-state mutation (writing `y/a'/z'` back into the
/// wavefront's slot tensors, bumping `cells_computed`) stays on the
/// caller's thread, keyed by slot index. Bit-identical to the inline
/// sequential loop by construction: same code path, same accumulation
/// order, disjoint outputs.
///
/// When `params` has been [`Params::prepare`]d, the cell runs on the
/// shared kernel-ready weights (no per-cell tensor copies; possibly
/// quantized). Unprepared params fall back to materializing the layer
/// — the original, byte-identical path.
pub fn cell_task(
    cfg: &ModelConfig,
    params: &Params,
    layer: usize,
    x: &Tensor,
    a: &Tensor,
    z: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    match params.kernel_layer(layer) {
        Some(q) => layer_step_w(cfg, &CellWeights::from_quant(q), x, a, z),
        None => {
            let view = params.layer(layer);
            layer_step(cfg, &view, x, a, z)
        }
    }
}

/// Vanilla full-attention forward over the whole context (the quadratic
/// baseline; no memory, fully causal).
pub fn full_attn_forward(cfg: &ModelConfig, params: &Params, tokens: &[u32]) -> Result<Tensor> {
    let n = tokens.len();
    let d = cfg.d_model;
    let emb = params.global("emb")?;
    let mut h = Tensor::zeros(&[n, d]);
    for (i, &t) in tokens.iter().enumerate() {
        if t as usize >= cfg.vocab {
            return Err(Error::Request(format!("token {t} >= vocab")));
        }
        h.data_mut()[i * d..(i + 1) * d].copy_from_slice(emb.row(t as usize));
    }
    for l in 0..cfg.n_layers {
        let lp = params.layer(l);
        // fully causal: every position is a "segment token" (seg = n)
        let attn = attention(cfg, &tensor::rmsnorm(&h, &lp.n1, cfg.eps), &lp.wq, &lp.wk, &lp.wv, &lp.wo, n);
        let h1 = tensor::add(&h, &attn);
        let mlp = swiglu(&tensor::rmsnorm(&h1, &lp.n2, cfg.eps), &lp.wg, &lp.wu, &lp.wd);
        h = tensor::add(&h1, &mlp);
    }
    let nf = params.global("nf")?;
    let w_out = params.global("w_out")?;
    Ok(tensor::matmul(&tensor::rmsnorm(&h, nf, cfg.eps), w_out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn cfg() -> ModelConfig {
        crate::model::tests::test_config()
    }

    #[test]
    fn assoc_read_zero_state_identity() {
        let c = cfg();
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[c.seg_total, c.d_model], 0.5, &mut rng);
        let a = Tensor::zeros(&[c.d_model, c.phi_dim]);
        let z = Tensor::zeros(&[c.phi_dim]);
        let wq = Tensor::randn(&[c.d_model, c.k_assoc], 0.3, &mut rng);
        let out = assoc_read(&c, &x, &a, &z, &wq);
        assert!(out.max_abs_diff(&x) < 1e-5);
    }

    #[test]
    fn assoc_update_changes_state() {
        let c = cfg();
        let mut rng = Rng::new(2);
        let y = Tensor::randn(&[c.mem, c.d_model], 0.5, &mut rng);
        let a = Tensor::zeros(&[c.d_model, c.phi_dim]);
        let z = Tensor::zeros(&[c.phi_dim]);
        let ak = Tensor::randn(&[c.d_model, c.k_assoc], 0.3, &mut rng);
        let av = Tensor::randn(&[c.d_model, c.d_model], 0.1, &mut rng);
        let ab = Tensor::randn(&[c.d_model], 0.3, &mut rng);
        let (a2, z2) = assoc_update(&c, &y, &a, &z, &ak, &av, &ab);
        assert!(a2.norm() > 0.0);
        assert!(z2.norm() > 0.0);
    }

    #[test]
    fn write_then_read_recovers_beta_v() {
        // Same invariant as python test_assoc_write_then_read_recovers_value.
        let c = cfg();
        let mut rng = Rng::new(3);
        let y = Tensor::randn(&[1, c.d_model], 1.0, &mut rng);
        let a = Tensor::zeros(&[c.d_model, c.phi_dim]);
        let z = Tensor::zeros(&[c.phi_dim]);
        let ak = Tensor::randn(&[c.d_model, c.k_assoc], 0.3, &mut rng);
        let av = Tensor::randn(&[c.d_model, c.d_model], 0.1, &mut rng);
        let ab = Tensor::randn(&[c.d_model], 0.3, &mut rng);
        let (a2, z2) = assoc_update(&c, &y, &a, &z, &ak, &av, &ab);
        let read = assoc_read(&c, &y, &a2, &z2, &ak);
        let beta = tensor::sigmoid(
            y.row(0).iter().zip(ab.data()).map(|(a, b)| a * b).sum::<f32>(),
        );
        let want = tensor::scale(&tensor::matmul(&y, &av), beta);
        let got = tensor::sub(&read, &y);
        let rel = got.rel_error(&want);
        assert!(rel < 0.05, "rel {rel}");
    }

    #[test]
    fn attention_causal_within_segment() {
        let c = cfg();
        let mut rng = Rng::new(4);
        let x = Tensor::randn(&[c.seg_total, c.d_model], 0.5, &mut rng);
        let ws: Vec<Tensor> =
            (0..4).map(|_| Tensor::randn(&[c.d_model, c.d_model], 0.2, &mut rng)).collect();
        let base = attention(&c, &x, &ws[0], &ws[1], &ws[2], &ws[3], c.seg);
        let mut x2 = x.clone();
        x2.data_mut()[(c.seg - 1) * c.d_model] += 5.0; // perturb last seg token
        let pert = attention(&c, &x2, &ws[0], &ws[1], &ws[2], &ws[3], c.seg);
        let head = base.slice0(0, c.seg - 1);
        let head2 = pert.slice0(0, c.seg - 1);
        assert!(head.max_abs_diff(&head2) < 1e-5);
        // memory tokens see everything, so they must change
        let tail = base.slice0(c.seg, c.seg_total);
        let tail2 = pert.slice0(c.seg, c.seg_total);
        assert!(tail.max_abs_diff(&tail2) > 1e-4);
    }

    #[test]
    fn cell_task_is_layer_step_and_send() {
        // The worker unit must be dispatchable across threads...
        fn assert_send<T: Send>(_: &T) {}
        let c = cfg();
        let p = Params::random(&c, 8);
        let mut rng = Rng::new(9);
        let x = Tensor::randn(&[c.seg_total, c.d_model], 0.5, &mut rng);
        let a = Tensor::randn(&[c.d_model, c.phi_dim], 0.1, &mut rng);
        let z = Tensor::randn(&[c.phi_dim], 0.1, &mut rng);
        assert_send(&c);
        assert_send(&p);
        assert_send(&x);
        // ...and bit-identical to the in-place layer_step it wraps.
        for l in 0..c.n_layers {
            let (y1, a1, z1) = cell_task(&c, &p, l, &x, &a, &z);
            let (y2, a2, z2) = layer_step(&c, &p.layer(l), &x, &a, &z);
            assert_eq!(y1, y2);
            assert_eq!(a1, a2);
            assert_eq!(z1, z2);
        }
    }

    #[test]
    fn layer_step_shapes_and_state_motion() {
        let c = cfg();
        let p = Params::random(&c, 5);
        let lp = p.layer(0);
        let mut rng = Rng::new(6);
        let x = Tensor::randn(&[c.seg_total, c.d_model], 0.5, &mut rng);
        let a = Tensor::zeros(&[c.d_model, c.phi_dim]);
        let z = Tensor::zeros(&[c.phi_dim]);
        let (y, a2, z2) = layer_step(&c, &lp, &x, &a, &z);
        assert_eq!(y.shape(), &[c.seg_total, c.d_model]);
        assert!(a2.norm() > 0.0, "memory must be written");
        assert!(z2.norm() > 0.0);
    }

    #[test]
    fn prepared_f32_cell_is_bit_identical() {
        // Preparing at F32 changes where the weights live, not one bit
        // of the math: cell_task over prepared params must equal the
        // legacy materialized-layer path exactly.
        let c = cfg();
        let p = Params::random(&c, 10);
        let mut prepared = p.clone();
        prepared.prepare(crate::tensor::Precision::F32);
        let mut rng = Rng::new(11);
        let x = Tensor::randn(&[c.seg_total, c.d_model], 0.5, &mut rng);
        let a = Tensor::randn(&[c.d_model, c.phi_dim], 0.1, &mut rng);
        let z = Tensor::randn(&[c.phi_dim], 0.1, &mut rng);
        for l in 0..c.n_layers {
            let (y1, a1, z1) = cell_task(&c, &p, l, &x, &a, &z);
            let (y2, a2, z2) = cell_task(&c, &prepared, l, &x, &a, &z);
            assert_eq!(y1, y2, "layer {l}: y");
            assert_eq!(a1, a2, "layer {l}: A'");
            assert_eq!(z1, z2, "layer {l}: z'");
        }
    }

    #[test]
    fn quantized_cell_error_within_budget() {
        use crate::tensor::{
            Precision, BF16_CELL_ERR_BUDGET, F16_CELL_ERR_BUDGET, INT8_CELL_ERR_BUDGET,
        };
        let c = cfg();
        let p = Params::random(&c, 12);
        let mut rng = Rng::new(13);
        let x = Tensor::randn(&[c.seg_total, c.d_model], 0.5, &mut rng);
        let a = Tensor::randn(&[c.d_model, c.phi_dim], 0.1, &mut rng);
        let z = Tensor::randn(&[c.phi_dim], 0.1, &mut rng);
        let (y_ref, a_ref, z_ref) = cell_task(&c, &p, 0, &x, &a, &z);
        for (prec, budget) in [
            (Precision::F16, F16_CELL_ERR_BUDGET),
            (Precision::Bf16, BF16_CELL_ERR_BUDGET),
            (Precision::Int8, INT8_CELL_ERR_BUDGET),
        ] {
            let mut q = p.clone();
            q.prepare(prec);
            let (y, a2, z2) = cell_task(&c, &q, 0, &x, &a, &z);
            assert!(
                y.rel_error(&y_ref) < budget,
                "{prec}: y rel error {} over {budget}",
                y.rel_error(&y_ref)
            );
            assert!(a2.rel_error(&a_ref) < budget, "{prec}: A'");
            assert!(z2.rel_error(&z_ref) < budget, "{prec}: z'");
        }
    }

    #[test]
    fn full_attn_is_causal() {
        let c = cfg();
        let p = Params::random(&c, 7);
        let tokens: Vec<u32> = (0..16u32).map(|i| i % c.vocab as u32).collect();
        let base = full_attn_forward(&c, &p, &tokens).unwrap();
        let mut t2 = tokens.clone();
        t2[15] = (t2[15] + 1) % c.vocab as u32;
        let pert = full_attn_forward(&c, &p, &t2).unwrap();
        assert!(base.slice0(0, 15).max_abs_diff(&pert.slice0(0, 15)) < 1e-5);
    }
}
