//! Parameter store: loads `params.bin` per the manifest index, or
//! synthesizes random weights for tests (same shapes as python's
//! `init_params`, different values — tests that need *equal* values load
//! the real blob).

use std::collections::HashMap;
use std::io::Read;
use std::sync::Arc;

use crate::config::{Manifest, ModelConfig};
use crate::error::{Error, Result};
use crate::tensor::{Precision, Rng, Tensor, WeightMat};

/// Stacked per-layer parameter names, in the artifact order (must match
/// python `model.PARAM_ORDER`).
pub const PARAM_ORDER: [&str; 13] = [
    "wq", "wk", "wv", "wo", "wg", "wu", "wd", "n1", "n2", "aq", "ak", "av", "ab",
];
/// Global parameter names (python `model.GLOBAL_ORDER`).
pub const GLOBAL_ORDER: [&str; 4] = ["emb", "mem_emb", "nf", "w_out"];

/// The stacked parameter order as a const fn (for modules that want it
/// without importing the array directly).
pub const fn params_order() -> [&'static str; 13] {
    PARAM_ORDER
}

/// All model weights, keyed by name; per-layer tensors are stacked [L, ...].
#[derive(Clone)]
pub struct Params {
    tensors: HashMap<String, Tensor>,
    n_layers: usize,
    /// Kernel-ready weights prepared at one [`Precision`] (None until
    /// [`Params::prepare`] runs). Behind an `Arc` so `Clone` stays
    /// cheap and every pool worker shares a single prepared copy.
    kernel: Option<Arc<KernelWeights>>,
}

/// One layer's ten weight matrices in kernel-ready [`WeightMat`]
/// storage, plus the small f32 vectors the cell math reads directly
/// (norm gains and the assoc gate bias are elementwise — quantizing
/// them buys nothing and costs accuracy).
pub struct QuantLayer {
    /// Attention query projection `[d, d]`.
    pub wq: WeightMat,
    /// Attention key projection `[d, d]`.
    pub wk: WeightMat,
    /// Attention value projection `[d, d]`.
    pub wv: WeightMat,
    /// Attention output projection `[d, d]`.
    pub wo: WeightMat,
    /// SwiGLU gate projection `[d, f]`.
    pub wg: WeightMat,
    /// SwiGLU up projection `[d, f]`.
    pub wu: WeightMat,
    /// SwiGLU down projection `[f, d]`.
    pub wd: WeightMat,
    /// Associative-memory query projection `[d, k_assoc]`.
    pub aq: WeightMat,
    /// Associative-memory key projection `[d, k_assoc]`.
    pub ak: WeightMat,
    /// Associative-memory value projection `[d, d]`.
    pub av: WeightMat,
    /// Pre-attention RMSNorm gain `[d]` (f32 always).
    pub n1: Tensor,
    /// Pre-MLP RMSNorm gain `[d]` (f32 always).
    pub n2: Tensor,
    /// Associative write-gate bias `[d]` (f32 always).
    pub ab: Tensor,
}

impl QuantLayer {
    /// Bytes of stored weight-matrix payload in this layer.
    pub fn weight_bytes(&self) -> usize {
        [
            &self.wq, &self.wk, &self.wv, &self.wo, &self.wg, &self.wu, &self.wd, &self.aq,
            &self.ak, &self.av,
        ]
        .iter()
        .map(|w| w.bytes())
        .sum()
    }
}

/// All layers' weights prepared at one precision — what
/// [`Params::prepare`] builds and the cell kernels consume.
pub struct KernelWeights {
    precision: Precision,
    layers: Vec<QuantLayer>,
}

impl KernelWeights {
    /// The precision every layer was prepared at.
    pub fn precision(&self) -> Precision {
        self.precision
    }
}

/// Borrowed single-layer view used by the cell math.
pub struct LayerTensors<'a> {
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    pub wo: Tensor,
    pub wg: Tensor,
    pub wu: Tensor,
    pub wd: Tensor,
    pub n1: Tensor,
    pub n2: Tensor,
    pub aq: Tensor,
    pub ak: Tensor,
    pub av: Tensor,
    pub ab: Tensor,
    _marker: std::marker::PhantomData<&'a ()>,
}

impl Params {
    /// Load the weight blob for `model` from the manifest.
    pub fn load(manifest: &Manifest, model: &str) -> Result<Self> {
        let entry = manifest.model(model)?;
        let path = manifest.params_path(entry);
        let mut bytes = Vec::new();
        std::fs::File::open(&path)?.read_to_end(&mut bytes)?;
        let total: usize = entry.params.iter().map(|p| p.size_elems).sum();
        if bytes.len() != 4 * total {
            return Err(Error::Config(format!(
                "params.bin {} bytes, manifest says {}",
                bytes.len(),
                4 * total
            )));
        }
        let mut tensors = HashMap::new();
        for p in &entry.params {
            let start = 4 * p.offset_elems;
            let end = start + 4 * p.size_elems;
            let data: Vec<f32> = bytes[start..end]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.insert(p.name.clone(), Tensor::new(&p.shape, data)?);
        }
        let s = Self { tensors, n_layers: entry.config.n_layers, kernel: None };
        s.validate(&entry.config)?;
        Ok(s)
    }

    /// Random weights with the artifact shapes (unit tests / proptests).
    pub fn random(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let (l, d, f, k) = (cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.k_assoc);
        let mut tensors = HashMap::new();
        let shapes: Vec<(&str, Vec<usize>)> = vec![
            ("wq", vec![l, d, d]),
            ("wk", vec![l, d, d]),
            ("wv", vec![l, d, d]),
            ("wo", vec![l, d, d]),
            ("wg", vec![l, d, f]),
            ("wu", vec![l, d, f]),
            ("wd", vec![l, f, d]),
            ("n1", vec![l, d]),
            ("n2", vec![l, d]),
            ("aq", vec![l, d, k]),
            ("ak", vec![l, d, k]),
            ("av", vec![l, d, d]),
            ("ab", vec![l, d]),
            ("emb", vec![cfg.vocab, d]),
            ("mem_emb", vec![cfg.mem, d]),
            ("nf", vec![d]),
            ("w_out", vec![d, cfg.vocab]),
        ];
        for (name, shape) in shapes {
            let t = match name {
                "n1" | "n2" | "nf" => Tensor::full(&shape, 1.0),
                "emb" | "mem_emb" => Tensor::randn(&shape, 0.02, &mut rng),
                "av" => {
                    let fan_in = shape[shape.len() - 2] as f32;
                    Tensor::randn(&shape, 0.1 / fan_in.sqrt(), &mut rng)
                }
                _ => {
                    let fan_in = shape[shape.len() - 2] as f32;
                    Tensor::randn(&shape, 1.0 / fan_in.sqrt(), &mut rng)
                }
            };
            tensors.insert(name.to_string(), t);
        }
        Self { tensors, n_layers: l, kernel: None }
    }

    fn validate(&self, cfg: &ModelConfig) -> Result<()> {
        for name in PARAM_ORDER {
            let t = self.tensors.get(name).ok_or_else(|| Error::Missing(name.into()))?;
            if t.shape()[0] != cfg.n_layers {
                return Err(Error::Shape {
                    what: "stacked param layer dim",
                    expected: vec![cfg.n_layers],
                    got: vec![t.shape()[0]],
                });
            }
        }
        for name in GLOBAL_ORDER {
            if !self.tensors.contains_key(name) {
                return Err(Error::Missing(name.into()));
            }
        }
        Ok(())
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Raw stacked tensor by name.
    pub fn stacked(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).ok_or_else(|| Error::Missing(format!("param '{name}'")))
    }

    /// Global (unstacked) tensor by name.
    pub fn global(&self, name: &str) -> Result<&Tensor> {
        self.stacked(name)
    }

    /// Materialized single-layer view (copies the rows; the native cell
    /// is not the hot path, clarity wins).
    pub fn layer(&self, l: usize) -> LayerTensors<'_> {
        debug_assert!(l < self.n_layers);
        let g = |name: &str| self.tensors[name].index0(l);
        LayerTensors {
            wq: g("wq"),
            wk: g("wk"),
            wv: g("wv"),
            wo: g("wo"),
            wg: g("wg"),
            wu: g("wu"),
            wd: g("wd"),
            n1: g("n1"),
            n2: g("n2"),
            aq: g("aq"),
            ak: g("ak"),
            av: g("av"),
            ab: g("ab"),
            _marker: std::marker::PhantomData,
        }
    }

    /// Overwrite one stacked/global tensor (trainer support). If the
    /// params were [`Params::prepare`]d and a stacked weight changed,
    /// the kernel weights are rebuilt at the same precision so the
    /// kernel tier never serves stale weights.
    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        match self.tensors.get(name) {
            Some(old) if old.shape() == t.shape() => {
                self.tensors.insert(name.to_string(), t);
                if PARAM_ORDER.contains(&name) {
                    if let Some(prec) = self.precision() {
                        self.prepare(prec);
                    }
                }
                Ok(())
            }
            Some(old) => Err(Error::Shape {
                what: "Params::set",
                expected: old.shape().to_vec(),
                got: t.shape().to_vec(),
            }),
            None => Err(Error::Missing(name.into())),
        }
    }

    /// Build (or rebuild) the kernel-ready weight storage at `prec`.
    /// F32 is worth preparing too: the cell then reads shared
    /// [`WeightMat`]s instead of copying 13 tensors out of the stacked
    /// store per cell step.
    pub fn prepare(&mut self, prec: Precision) {
        let layers = (0..self.n_layers)
            .map(|l| {
                let lt = self.layer(l);
                QuantLayer {
                    wq: WeightMat::from_tensor(&lt.wq, prec),
                    wk: WeightMat::from_tensor(&lt.wk, prec),
                    wv: WeightMat::from_tensor(&lt.wv, prec),
                    wo: WeightMat::from_tensor(&lt.wo, prec),
                    wg: WeightMat::from_tensor(&lt.wg, prec),
                    wu: WeightMat::from_tensor(&lt.wu, prec),
                    wd: WeightMat::from_tensor(&lt.wd, prec),
                    aq: WeightMat::from_tensor(&lt.aq, prec),
                    ak: WeightMat::from_tensor(&lt.ak, prec),
                    av: WeightMat::from_tensor(&lt.av, prec),
                    n1: lt.n1,
                    n2: lt.n2,
                    ab: lt.ab,
                }
            })
            .collect();
        self.kernel = Some(Arc::new(KernelWeights { precision: prec, layers }));
    }

    /// The precision the params were prepared at (None: not prepared —
    /// the cell falls back to the legacy per-layer tensor copies).
    pub fn precision(&self) -> Option<Precision> {
        self.kernel.as_ref().map(|k| k.precision)
    }

    /// Kernel-ready weights for layer `l`, if prepared.
    pub fn kernel_layer(&self, l: usize) -> Option<&QuantLayer> {
        self.kernel.as_ref().map(|k| &k.layers[l])
    }

    /// Total stored weight-matrix bytes across all prepared layers
    /// (0 when unprepared) — the footprint the quantized tiers shrink.
    pub fn kernel_weight_bytes(&self) -> usize {
        self.kernel
            .as_ref()
            .map(|k| k.layers.iter().map(|l| l.weight_bytes()).sum())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        crate::model::tests::test_config()
    }

    #[test]
    fn random_has_all_names() {
        let p = Params::random(&cfg(), 0);
        for n in PARAM_ORDER {
            assert!(p.stacked(n).is_ok(), "{n}");
        }
        for n in GLOBAL_ORDER {
            assert!(p.global(n).is_ok(), "{n}");
        }
        assert!(p.stacked("nope").is_err());
    }

    #[test]
    fn layer_view_shapes() {
        let c = cfg();
        let p = Params::random(&c, 1);
        let v = p.layer(0);
        assert_eq!(v.wq.shape(), &[c.d_model, c.d_model]);
        assert_eq!(v.wg.shape(), &[c.d_model, c.d_ff]);
        assert_eq!(v.wd.shape(), &[c.d_ff, c.d_model]);
        assert_eq!(v.aq.shape(), &[c.d_model, c.k_assoc]);
        assert_eq!(v.ab.shape(), &[c.d_model]);
    }

    #[test]
    fn set_rejects_bad_shape() {
        let c = cfg();
        let mut p = Params::random(&c, 2);
        assert!(p.set("nf", Tensor::zeros(&[c.d_model])).is_ok());
        assert!(p.set("nf", Tensor::zeros(&[c.d_model + 1])).is_err());
        assert!(p.set("missing", Tensor::zeros(&[1])).is_err());
    }

    #[test]
    fn norm_gains_init_to_one() {
        let p = Params::random(&cfg(), 3);
        assert!(p.global("nf").unwrap().data().iter().all(|&v| v == 1.0));
    }

    #[test]
    fn prepare_builds_every_layer_at_the_asked_precision() {
        let c = cfg();
        let mut p = Params::random(&c, 4);
        assert!(p.precision().is_none());
        assert!(p.kernel_layer(0).is_none());
        assert_eq!(p.kernel_weight_bytes(), 0);

        p.prepare(Precision::Int8);
        assert_eq!(p.precision(), Some(Precision::Int8));
        for l in 0..c.n_layers {
            let q = p.kernel_layer(l).unwrap();
            assert_eq!(q.wq.precision(), Precision::Int8);
            assert_eq!(q.wq.shape(), (c.d_model, c.d_model));
            assert_eq!(q.wg.shape(), (c.d_model, c.d_ff));
            assert_eq!(q.wd.shape(), (c.d_ff, c.d_model));
            assert_eq!(q.aq.shape(), (c.d_model, c.k_assoc));
            assert_eq!(q.n1.shape(), &[c.d_model]);
        }
        let int8_bytes = p.kernel_weight_bytes();
        p.prepare(Precision::F32);
        // int8 stores ~1/4 of the f32 payload (plus per-row scales).
        let f32_bytes = p.kernel_weight_bytes();
        assert!(int8_bytes * 3 < f32_bytes, "{int8_bytes} vs {f32_bytes}");
    }

    #[test]
    fn prepared_f32_dequantizes_bit_equal() {
        let c = cfg();
        let mut p = Params::random(&c, 5);
        p.prepare(Precision::F32);
        let q = p.kernel_layer(1).unwrap();
        assert_eq!(q.wq.dequantize(), p.layer(1).wq);
        assert_eq!(q.wd.dequantize(), p.layer(1).wd);
    }

    #[test]
    fn quantized_dequantize_error_bounded() {
        let c = cfg();
        let mut p = Params::random(&c, 6);
        for (prec, budget) in
            [(Precision::F16, 1e-3f32), (Precision::Bf16, 1e-2f32), (Precision::Int8, 1e-2f32)]
        {
            p.prepare(prec);
            let q = p.kernel_layer(0).unwrap();
            let err = q.wv.dequantize().rel_error(&p.layer(0).wv);
            assert!(err < budget, "{prec}: rel error {err}");
        }
    }

    #[test]
    fn set_rebuilds_prepared_weights() {
        let c = cfg();
        let mut p = Params::random(&c, 7);
        p.prepare(Precision::F32);
        let shape = p.stacked("wq").unwrap().shape().to_vec();
        p.set("wq", Tensor::full(&shape, 0.25)).unwrap();
        // The prepared copy must reflect the new stacked tensor.
        let q = p.kernel_layer(0).unwrap();
        assert!(q.wq.dequantize().data().iter().all(|&v| v == 0.25));
        assert_eq!(p.precision(), Some(Precision::F32));
        // Global (unstacked) sets keep the prepared copy as-is but
        // must not clear it.
        let nf_shape = p.global("nf").unwrap().shape().to_vec();
        p.set("nf", Tensor::zeros(&nf_shape)).unwrap();
        assert_eq!(p.precision(), Some(Precision::F32));
    }
}
