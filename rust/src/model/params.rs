//! Parameter store: loads `params.bin` per the manifest index, or
//! synthesizes random weights for tests (same shapes as python's
//! `init_params`, different values — tests that need *equal* values load
//! the real blob).

use std::collections::HashMap;
use std::io::Read;

use crate::config::{Manifest, ModelConfig};
use crate::error::{Error, Result};
use crate::tensor::{Rng, Tensor};

/// Stacked per-layer parameter names, in the artifact order (must match
/// python `model.PARAM_ORDER`).
pub const PARAM_ORDER: [&str; 13] = [
    "wq", "wk", "wv", "wo", "wg", "wu", "wd", "n1", "n2", "aq", "ak", "av", "ab",
];
/// Global parameter names (python `model.GLOBAL_ORDER`).
pub const GLOBAL_ORDER: [&str; 4] = ["emb", "mem_emb", "nf", "w_out"];

/// The stacked parameter order as a const fn (for modules that want it
/// without importing the array directly).
pub const fn params_order() -> [&'static str; 13] {
    PARAM_ORDER
}

/// All model weights, keyed by name; per-layer tensors are stacked [L, ...].
#[derive(Clone)]
pub struct Params {
    tensors: HashMap<String, Tensor>,
    n_layers: usize,
}

/// Borrowed single-layer view used by the cell math.
pub struct LayerTensors<'a> {
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    pub wo: Tensor,
    pub wg: Tensor,
    pub wu: Tensor,
    pub wd: Tensor,
    pub n1: Tensor,
    pub n2: Tensor,
    pub aq: Tensor,
    pub ak: Tensor,
    pub av: Tensor,
    pub ab: Tensor,
    _marker: std::marker::PhantomData<&'a ()>,
}

impl Params {
    /// Load the weight blob for `model` from the manifest.
    pub fn load(manifest: &Manifest, model: &str) -> Result<Self> {
        let entry = manifest.model(model)?;
        let path = manifest.params_path(entry);
        let mut bytes = Vec::new();
        std::fs::File::open(&path)?.read_to_end(&mut bytes)?;
        let total: usize = entry.params.iter().map(|p| p.size_elems).sum();
        if bytes.len() != 4 * total {
            return Err(Error::Config(format!(
                "params.bin {} bytes, manifest says {}",
                bytes.len(),
                4 * total
            )));
        }
        let mut tensors = HashMap::new();
        for p in &entry.params {
            let start = 4 * p.offset_elems;
            let end = start + 4 * p.size_elems;
            let data: Vec<f32> = bytes[start..end]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.insert(p.name.clone(), Tensor::new(&p.shape, data)?);
        }
        let s = Self { tensors, n_layers: entry.config.n_layers };
        s.validate(&entry.config)?;
        Ok(s)
    }

    /// Random weights with the artifact shapes (unit tests / proptests).
    pub fn random(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let (l, d, f, k) = (cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.k_assoc);
        let mut tensors = HashMap::new();
        let shapes: Vec<(&str, Vec<usize>)> = vec![
            ("wq", vec![l, d, d]),
            ("wk", vec![l, d, d]),
            ("wv", vec![l, d, d]),
            ("wo", vec![l, d, d]),
            ("wg", vec![l, d, f]),
            ("wu", vec![l, d, f]),
            ("wd", vec![l, f, d]),
            ("n1", vec![l, d]),
            ("n2", vec![l, d]),
            ("aq", vec![l, d, k]),
            ("ak", vec![l, d, k]),
            ("av", vec![l, d, d]),
            ("ab", vec![l, d]),
            ("emb", vec![cfg.vocab, d]),
            ("mem_emb", vec![cfg.mem, d]),
            ("nf", vec![d]),
            ("w_out", vec![d, cfg.vocab]),
        ];
        for (name, shape) in shapes {
            let t = match name {
                "n1" | "n2" | "nf" => Tensor::full(&shape, 1.0),
                "emb" | "mem_emb" => Tensor::randn(&shape, 0.02, &mut rng),
                "av" => {
                    let fan_in = shape[shape.len() - 2] as f32;
                    Tensor::randn(&shape, 0.1 / fan_in.sqrt(), &mut rng)
                }
                _ => {
                    let fan_in = shape[shape.len() - 2] as f32;
                    Tensor::randn(&shape, 1.0 / fan_in.sqrt(), &mut rng)
                }
            };
            tensors.insert(name.to_string(), t);
        }
        Self { tensors, n_layers: l }
    }

    fn validate(&self, cfg: &ModelConfig) -> Result<()> {
        for name in PARAM_ORDER {
            let t = self.tensors.get(name).ok_or_else(|| Error::Missing(name.into()))?;
            if t.shape()[0] != cfg.n_layers {
                return Err(Error::Shape {
                    what: "stacked param layer dim",
                    expected: vec![cfg.n_layers],
                    got: vec![t.shape()[0]],
                });
            }
        }
        for name in GLOBAL_ORDER {
            if !self.tensors.contains_key(name) {
                return Err(Error::Missing(name.into()));
            }
        }
        Ok(())
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Raw stacked tensor by name.
    pub fn stacked(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).ok_or_else(|| Error::Missing(format!("param '{name}'")))
    }

    /// Global (unstacked) tensor by name.
    pub fn global(&self, name: &str) -> Result<&Tensor> {
        self.stacked(name)
    }

    /// Materialized single-layer view (copies the rows; the native cell
    /// is not the hot path, clarity wins).
    pub fn layer(&self, l: usize) -> LayerTensors<'_> {
        debug_assert!(l < self.n_layers);
        let g = |name: &str| self.tensors[name].index0(l);
        LayerTensors {
            wq: g("wq"),
            wk: g("wk"),
            wv: g("wv"),
            wo: g("wo"),
            wg: g("wg"),
            wu: g("wu"),
            wd: g("wd"),
            n1: g("n1"),
            n2: g("n2"),
            aq: g("aq"),
            ak: g("ak"),
            av: g("av"),
            ab: g("ab"),
            _marker: std::marker::PhantomData,
        }
    }

    /// Overwrite one stacked/global tensor (trainer support).
    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        match self.tensors.get(name) {
            Some(old) if old.shape() == t.shape() => {
                self.tensors.insert(name.to_string(), t);
                Ok(())
            }
            Some(old) => Err(Error::Shape {
                what: "Params::set",
                expected: old.shape().to_vec(),
                got: t.shape().to_vec(),
            }),
            None => Err(Error::Missing(name.into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        crate::model::tests::test_config()
    }

    #[test]
    fn random_has_all_names() {
        let p = Params::random(&cfg(), 0);
        for n in PARAM_ORDER {
            assert!(p.stacked(n).is_ok(), "{n}");
        }
        for n in GLOBAL_ORDER {
            assert!(p.global(n).is_ok(), "{n}");
        }
        assert!(p.stacked("nope").is_err());
    }

    #[test]
    fn layer_view_shapes() {
        let c = cfg();
        let p = Params::random(&c, 1);
        let v = p.layer(0);
        assert_eq!(v.wq.shape(), &[c.d_model, c.d_model]);
        assert_eq!(v.wg.shape(), &[c.d_model, c.d_ff]);
        assert_eq!(v.wd.shape(), &[c.d_ff, c.d_model]);
        assert_eq!(v.aq.shape(), &[c.d_model, c.k_assoc]);
        assert_eq!(v.ab.shape(), &[c.d_model]);
    }

    #[test]
    fn set_rejects_bad_shape() {
        let c = cfg();
        let mut p = Params::random(&c, 2);
        assert!(p.set("nf", Tensor::zeros(&[c.d_model])).is_ok());
        assert!(p.set("nf", Tensor::zeros(&[c.d_model + 1])).is_err());
        assert!(p.set("missing", Tensor::zeros(&[1])).is_err());
    }

    #[test]
    fn norm_gains_init_to_one() {
        let p = Params::random(&cfg(), 3);
        assert!(p.global("nf").unwrap().data().iter().all(|&v| v == 1.0));
    }
}
