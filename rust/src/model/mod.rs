//! Native rust ARMT reference model.
//!
//! A bit-stable CPU implementation of exactly the semantics the L2 jax
//! model lowers to HLO (DESIGN.md "ARMT cell semantics"). It serves three
//! roles:
//!
//! 1. **Oracle** — integration tests compare HLO executables against it;
//! 2. **Backend** — the scheduler can run entirely natively (no
//!    artifacts), which is how the proptests establish that the diagonal
//!    schedule is *bit-exact* vs the sequential one when the kernel math
//!    is order-preserving;
//! 3. **Trainer substrate** — `examples/train_steps.rs` drives the HLO
//!    backward executable and needs native forward pieces for checks.

mod cell;
mod params;
mod pool;

pub use cell::{assoc_read, assoc_update, attention, cell_task, layer_step, swiglu, LayerView};
pub use params::{params_order, KernelWeights, Params, QuantLayer, GLOBAL_ORDER, PARAM_ORDER};
pub use pool::{default_threads, CellJob, CellResult, ParallelCellPool, PoolStats};

use std::sync::Arc;

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::scheduler::{StepBackend, WorkerStats};
use crate::tensor::{self, Precision, Tensor};

/// Pure-rust [`StepBackend`].
///
/// Single-threaded by default (the bit-exact reference oracle). With
/// [`with_threads`](Self::with_threads)` > 1`, each `grouped_step` fans
/// its active `(layer, lane)` cells out across a persistent
/// [`ParallelCellPool`] and joins before returning — bit-identical
/// results (each cell's math is order-preserving on exactly one thread,
/// and cells write disjoint slots), but wavefront steps now actually
/// run `min(threads, active cells)` wide.
pub struct NativeBackend {
    cfg: ModelConfig,
    params: Arc<Params>,
    pool: Option<ParallelCellPool>,
    step_calls: u64,
    cells_computed: u64,
}

impl NativeBackend {
    /// Single-threaded backend. Prepares the params' kernel-ready f32
    /// weight storage if the caller hasn't already — byte-identical to
    /// the unprepared path, but cells share one weight copy instead of
    /// materializing 13 tensors per cell step. Use
    /// [`with_precision`](Self::with_precision) for f16/bf16/int8.
    pub fn new(cfg: ModelConfig, mut params: Params) -> Self {
        if params.precision().is_none() {
            params.prepare(Precision::F32);
        }
        Self { cfg, params: Arc::new(params), pool: None, step_calls: 0, cells_computed: 0 }
    }

    /// Execute grouped steps on a `threads`-wide worker pool
    /// (`threads <= 1` keeps the inline sequential loop — today's code
    /// path, no pool, no channels). See
    /// [`default_threads`] for the CLI's auto setting.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.pool = if threads > 1 {
            Some(ParallelCellPool::new(self.cfg.clone(), Arc::clone(&self.params), threads))
        } else {
            None
        };
        self
    }

    /// Worker threads executing cells (1 = inline).
    pub fn threads(&self) -> usize {
        self.pool.as_ref().map(|p| p.threads()).unwrap_or(1)
    }

    /// Re-prepare the weights at `prec` (f32 exact; f16/bf16/int8
    /// quantized with f32 accumulation — bounded-error, see the
    /// `*_CELL_ERR_BUDGET` constants in [`crate::tensor::kernels`]).
    /// Rebuilds the worker pool so every worker sees the new weights;
    /// order-independent with [`with_threads`](Self::with_threads).
    pub fn with_precision(mut self, prec: Precision) -> Self {
        if self.params.precision() == Some(prec) {
            return self;
        }
        let mut p = (*self.params).clone();
        p.prepare(prec);
        self.params = Arc::new(p);
        let threads = self.threads();
        if threads > 1 {
            self.pool = Some(ParallelCellPool::new(
                self.cfg.clone(),
                Arc::clone(&self.params),
                threads,
            ));
        }
        self
    }

    /// The weight precision the backend is running at.
    pub fn precision(&self) -> Precision {
        self.params.precision().unwrap_or(Precision::F32)
    }

    /// Determinism-test hook: randomized per-cell worker sleep (no-op
    /// without a pool). See [`ParallelCellPool::set_test_jitter`].
    pub fn set_test_jitter(&self, max_us: u64) {
        if let Some(p) = &self.pool {
            p.set_test_jitter(max_us);
        }
    }

    pub fn params(&self) -> &Params {
        &self.params
    }

    /// Cells actually computed (diagnostics: the diagonal executor wastes
    /// ramp-up/-down slots; native skips masked slots instead).
    pub fn cells_computed(&self) -> u64 {
        self.cells_computed
    }

    /// Vanilla full-attention forward (the quadratic baseline), usable at
    /// any length (native code has no AOT length buckets).
    pub fn full_attn_forward(&self, tokens: &[u32]) -> Result<Tensor> {
        cell::full_attn_forward(&self.cfg, &self.params, tokens)
    }
}

impl StepBackend for NativeBackend {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn grouped_step(
        &mut self,
        x: &Tensor,
        a: &Tensor,
        z: &Tensor,
        mask: &[f32],
    ) -> Result<(Tensor, Tensor, Tensor)> {
        let (l_total, b_total) = crate::scheduler::grouped_dims(&self.cfg, x, a, z, mask)?;
        let lanes = x.rank() == 4;
        self.step_calls += 1;
        let mut y = x.clone();
        let mut a2 = a.clone();
        let mut z2 = z.clone();
        // Active (layer, lane) cells in slot order; masked slots are
        // skipped entirely (bit-freeze). Each cell is independent — the
        // grouped kernel's contract — so they may run inline or fanned
        // out across the pool, and lane order never affects a cell's
        // math, which is what makes packed == per-request execution
        // bit-exact.
        let active: Vec<(usize, usize)> = (0..l_total)
            .flat_map(|l| (0..b_total).map(move |lane| (l, lane)))
            .filter(|&(l, lane)| mask[l * b_total + lane] != 0.0)
            .collect();
        self.cells_computed += active.len() as u64;

        let fetch = |l: usize, lane: usize| {
            if lanes {
                (x.index01(l, lane), a.index01(l, lane), z.index01(l, lane))
            } else {
                (x.index0(l), a.index0(l), z.index0(l))
            }
        };

        if let Some(pool) = &self.pool {
            // Fan-out/join: one job per active cell, joined before the
            // caller's memory hand-off. A single-cell wavefront (ramp
            // tip) runs inline — the channel hop buys nothing.
            if active.len() > 1 {
                let jobs = active
                    .iter()
                    .map(|&(l, lane)| {
                        let (xc, ac, zc) = fetch(l, lane);
                        CellJob { slot: l * b_total + lane, layer: l, x: xc, a: ac, z: zc }
                    })
                    .collect();
                // Determinism rule: write-back is keyed by slot index,
                // never by completion order.
                for r in pool.execute(jobs)? {
                    let (l, lane) = (r.slot / b_total, r.slot % b_total);
                    if lanes {
                        y.set_index01(l, lane, &r.y);
                        a2.set_index01(l, lane, &r.a2);
                        z2.set_index01(l, lane, &r.z2);
                    } else {
                        y.set_index0(l, &r.y);
                        a2.set_index0(l, &r.a2);
                        z2.set_index0(l, &r.z2);
                    }
                }
                return Ok((y, a2, z2));
            }
        }

        // Inline path (`--threads 1`, or <= 1 active cell): the same
        // per-cell task, executed in slot order on this thread.
        for &(l, lane) in &active {
            let (xc, ac, zc) = fetch(l, lane);
            let (yl, al, zl) = cell::cell_task(&self.cfg, &self.params, l, &xc, &ac, &zc);
            if lanes {
                y.set_index01(l, lane, &yl);
                a2.set_index01(l, lane, &al);
                z2.set_index01(l, lane, &zl);
            } else {
                y.set_index0(l, &yl);
                a2.set_index0(l, &al);
                z2.set_index0(l, &zl);
            }
        }
        Ok((y, a2, z2))
    }

    fn single_step(
        &mut self,
        layer: usize,
        x: &Tensor,
        a: &Tensor,
        z: &Tensor,
    ) -> Result<(Tensor, Tensor, Tensor)> {
        if layer >= self.cfg.n_layers {
            return Err(Error::Missing(format!("layer {layer}")));
        }
        self.step_calls += 1;
        self.cells_computed += 1;
        Ok(cell::cell_task(&self.cfg, &self.params, layer, x, a, z))
    }

    fn embed(&mut self, tokens: &[u32]) -> Result<Tensor> {
        if tokens.len() != self.cfg.seg {
            return Err(Error::Shape {
                what: "embed tokens",
                expected: vec![self.cfg.seg],
                got: vec![tokens.len()],
            });
        }
        let emb = self.params.global("emb")?;
        let mem = self.params.global("mem_emb")?;
        let d = self.cfg.d_model;
        let mut out = Tensor::zeros(&[self.cfg.seg_total, d]);
        for (i, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            if t >= self.cfg.vocab {
                return Err(Error::Request(format!("token {t} >= vocab {}", self.cfg.vocab)));
            }
            out.data_mut()[i * d..(i + 1) * d].copy_from_slice(emb.row(t));
        }
        for i in 0..self.cfg.mem {
            let dst = (self.cfg.seg + i) * d;
            out.data_mut()[dst..dst + d].copy_from_slice(mem.row(i));
        }
        Ok(out)
    }

    fn lm_head(&mut self, y: &Tensor) -> Result<Tensor> {
        let nf = self.params.global("nf")?;
        let w_out = self.params.global("w_out")?;
        let h = tensor::rmsnorm(&y.slice0(0, self.cfg.seg), nf, self.cfg.eps);
        Ok(tensor::matmul(&h, w_out))
    }

    fn full_attn(&mut self, tokens: &[u32]) -> Result<Tensor> {
        self.full_attn_forward(tokens)
    }

    fn step_calls(&self) -> u64 {
        self.step_calls
    }

    fn worker_stats(&self) -> WorkerStats {
        match &self.pool {
            Some(p) => WorkerStats {
                threads: p.threads(),
                pool_cells: p.stats().cells.get(),
                busy_us: p.stats().busy_us(),
            },
            None => WorkerStats::default(),
        }
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::tensor::Rng;

    pub(crate) fn test_config() -> ModelConfig {
        ModelConfig {
            name: "unit".into(),
            vocab: 64,
            d_model: 32,
            n_layers: 3,
            n_heads: 2,
            d_ff: 48,
            seg: 8,
            mem: 4,
            k_assoc: 8,
            dpfp_nu: 3,
            rope_theta: 10000.0,
            eps: 1e-6,
            attn_buckets: vec![],
            head_dim: 16,
            phi_dim: 48,
            seg_total: 12,
        }
    }

    #[test]
    fn backend_shapes() {
        let cfg = test_config();
        let params = Params::random(&cfg, 0);
        let mut b = NativeBackend::new(cfg.clone(), params);
        let tokens: Vec<u32> = (0..cfg.seg as u32).collect();
        let x = b.embed(&tokens).unwrap();
        assert_eq!(x.shape(), &[cfg.seg_total, cfg.d_model]);
        let a = Tensor::zeros(&[cfg.d_model, cfg.phi_dim]);
        let z = Tensor::zeros(&[cfg.phi_dim]);
        let (y, a2, z2) = b.single_step(0, &x, &a, &z).unwrap();
        assert_eq!(y.shape(), x.shape());
        assert_eq!(a2.shape(), a.shape());
        assert_eq!(z2.shape(), z.shape());
        let logits = b.lm_head(&y).unwrap();
        assert_eq!(logits.shape(), &[cfg.seg, cfg.vocab]);
    }

    #[test]
    fn grouped_matches_single_steps_bitexact() {
        let cfg = test_config();
        let params = Params::random(&cfg, 1);
        let mut b = NativeBackend::new(cfg.clone(), params);
        let l = cfg.n_layers;
        let mut rng = Rng::new(5);
        let x = Tensor::randn(&[l, cfg.seg_total, cfg.d_model], 0.5, &mut rng);
        let a = Tensor::randn(&[l, cfg.d_model, cfg.phi_dim], 0.1, &mut rng);
        let z = Tensor::randn(&[l, cfg.phi_dim], 0.1, &mut rng);
        let mask = vec![1.0; l];
        let (y, a2, z2) = b.grouped_step(&x, &a, &z, &mask).unwrap();
        for i in 0..l {
            let (yi, ai, zi) =
                b.single_step(i, &x.index0(i), &a.index0(i), &z.index0(i)).unwrap();
            assert_eq!(y.index0(i), yi, "slot {i} y");
            assert_eq!(a2.index0(i), ai, "slot {i} A");
            assert_eq!(z2.index0(i), zi, "slot {i} z");
        }
    }

    #[test]
    fn lane_batched_grouped_matches_single_steps_bitexact() {
        // Rank-4 [L, B, T, d] slots: every (layer, lane) cell must equal
        // an independent single_step with that layer's weights.
        let cfg = test_config();
        let params = Params::random(&cfg, 8);
        let mut b = NativeBackend::new(cfg.clone(), params);
        let (l, lanes) = (cfg.n_layers, 2usize);
        let mut rng = Rng::new(9);
        let x = Tensor::randn(&[l, lanes, cfg.seg_total, cfg.d_model], 0.5, &mut rng);
        let a = Tensor::randn(&[l, lanes, cfg.d_model, cfg.phi_dim], 0.1, &mut rng);
        let z = Tensor::randn(&[l, lanes, cfg.phi_dim], 0.1, &mut rng);
        let mut mask = vec![1.0; l * lanes];
        mask[lanes + 1] = 0.0; // freeze cell (layer 1, lane 1)
        let (y, a2, z2) = b.grouped_step(&x, &a, &z, &mask).unwrap();
        for li in 0..l {
            for bi in 0..lanes {
                if mask[li * lanes + bi] == 0.0 {
                    assert_eq!(y.index01(li, bi), x.index01(li, bi));
                    assert_eq!(a2.index01(li, bi), a.index01(li, bi));
                    assert_eq!(z2.index01(li, bi), z.index01(li, bi));
                    continue;
                }
                let (yi, ai, zi) = b
                    .single_step(li, &x.index01(li, bi), &a.index01(li, bi), &z.index01(li, bi))
                    .unwrap();
                assert_eq!(y.index01(li, bi), yi, "cell ({li},{bi}) y");
                assert_eq!(a2.index01(li, bi), ai, "cell ({li},{bi}) A");
                assert_eq!(z2.index01(li, bi), zi, "cell ({li},{bi}) z");
            }
        }
    }

    #[test]
    fn masked_slot_frozen() {
        let cfg = test_config();
        let params = Params::random(&cfg, 2);
        let mut b = NativeBackend::new(cfg.clone(), params);
        let l = cfg.n_layers;
        let mut rng = Rng::new(6);
        let x = Tensor::randn(&[l, cfg.seg_total, cfg.d_model], 0.5, &mut rng);
        let a = Tensor::randn(&[l, cfg.d_model, cfg.phi_dim], 0.1, &mut rng);
        let z = Tensor::randn(&[l, cfg.phi_dim], 0.1, &mut rng);
        let mut mask = vec![1.0; l];
        mask[1] = 0.0;
        let (y, a2, z2) = b.grouped_step(&x, &a, &z, &mask).unwrap();
        assert_eq!(y.index0(1), x.index0(1));
        assert_eq!(a2.index0(1), a.index0(1));
        assert_eq!(z2.index0(1), z.index0(1));
    }

    #[test]
    fn pooled_grouped_step_bitexact_vs_inline() {
        // The tentpole contract at its smallest: the pool changes the
        // wall-clock, never the bytes — including frozen masked slots.
        let cfg = test_config();
        let (l, lanes) = (cfg.n_layers, 3usize);
        let mut rng = Rng::new(21);
        let x = Tensor::randn(&[l, lanes, cfg.seg_total, cfg.d_model], 0.5, &mut rng);
        let a = Tensor::randn(&[l, lanes, cfg.d_model, cfg.phi_dim], 0.1, &mut rng);
        let z = Tensor::randn(&[l, lanes, cfg.phi_dim], 0.1, &mut rng);
        let mut mask = vec![1.0; l * lanes];
        mask[1] = 0.0;
        mask[lanes + 2] = 0.0;

        let mut inline = NativeBackend::new(cfg.clone(), Params::random(&cfg, 22));
        let (y1, a1, z1) = inline.grouped_step(&x, &a, &z, &mask).unwrap();
        for threads in [2usize, 5] {
            let mut pooled =
                NativeBackend::new(cfg.clone(), Params::random(&cfg, 22)).with_threads(threads);
            assert_eq!(pooled.threads(), threads);
            let (y2, a2, z2) = pooled.grouped_step(&x, &a, &z, &mask).unwrap();
            assert_eq!(y1, y2, "{threads} threads: y");
            assert_eq!(a1, a2, "{threads} threads: A");
            assert_eq!(z1, z2, "{threads} threads: z");
            assert_eq!(pooled.cells_computed(), inline.cells_computed());
        }
    }

    #[test]
    fn with_threads_one_is_inline() {
        let cfg = test_config();
        let b = NativeBackend::new(cfg.clone(), Params::random(&cfg, 23)).with_threads(1);
        assert_eq!(b.threads(), 1);
        assert_eq!(b.worker_stats(), WorkerStats::default());
    }

    #[test]
    fn pooled_worker_stats_count_cells() {
        let cfg = test_config();
        let l = cfg.n_layers;
        let mut b = NativeBackend::new(cfg.clone(), Params::random(&cfg, 24)).with_threads(2);
        let mut rng = Rng::new(25);
        let x = Tensor::randn(&[l, cfg.seg_total, cfg.d_model], 0.5, &mut rng);
        let a = Tensor::zeros(&[l, cfg.d_model, cfg.phi_dim]);
        let z = Tensor::zeros(&[l, cfg.phi_dim]);
        let mask = vec![1.0; l];
        b.grouped_step(&x, &a, &z, &mask).unwrap();
        let ws = b.worker_stats();
        assert_eq!(ws.threads, 2);
        assert_eq!(ws.pool_cells, l as u64);
        // single_step stays inline — pool counters must not move.
        b.single_step(0, &x.index0(0), &a.index0(0), &z.index0(0)).unwrap();
        assert_eq!(b.worker_stats().pool_cells, l as u64);
    }

    #[test]
    fn backend_prepares_f32_and_stays_bitexact() {
        // NativeBackend::new auto-prepares at F32; results must be
        // byte-identical to the never-prepared cell path.
        let cfg = test_config();
        let mut b = NativeBackend::new(cfg.clone(), Params::random(&cfg, 30));
        assert_eq!(b.precision(), Precision::F32);
        let mut rng = Rng::new(31);
        let x = Tensor::randn(&[cfg.seg_total, cfg.d_model], 0.5, &mut rng);
        let a = Tensor::randn(&[cfg.d_model, cfg.phi_dim], 0.1, &mut rng);
        let z = Tensor::randn(&[cfg.phi_dim], 0.1, &mut rng);
        let (y, a2, z2) = b.single_step(1, &x, &a, &z).unwrap();
        let raw = Params::random(&cfg, 30);
        let (y0, a0, z0) = cell::layer_step(&cfg, &raw.layer(1), &x, &a, &z);
        assert_eq!(y, y0);
        assert_eq!(a2, a0);
        assert_eq!(z2, z0);
    }

    #[test]
    fn quantized_grouped_step_pooled_matches_inline_bitexact() {
        // Quantization changes the numbers vs f32, but pooled vs inline
        // must still agree byte-for-byte at any precision: every cell
        // runs the same kernels in the same order on exactly one
        // thread.
        let cfg = test_config();
        let l = cfg.n_layers;
        let mut rng = Rng::new(33);
        let x = Tensor::randn(&[l, cfg.seg_total, cfg.d_model], 0.5, &mut rng);
        let a = Tensor::randn(&[l, cfg.d_model, cfg.phi_dim], 0.1, &mut rng);
        let z = Tensor::randn(&[l, cfg.phi_dim], 0.1, &mut rng);
        let mask = vec![1.0; l];

        let mut inline = NativeBackend::new(cfg.clone(), Params::random(&cfg, 34))
            .with_precision(Precision::Int8);
        assert_eq!(inline.precision(), Precision::Int8);
        let (y1, a1, z1) = inline.grouped_step(&x, &a, &z, &mask).unwrap();

        // Both construction orders must work: threads-then-precision
        // and precision-then-threads.
        let mut p1 = NativeBackend::new(cfg.clone(), Params::random(&cfg, 34))
            .with_threads(3)
            .with_precision(Precision::Int8);
        let mut p2 = NativeBackend::new(cfg.clone(), Params::random(&cfg, 34))
            .with_precision(Precision::Int8)
            .with_threads(3);
        for b in [&mut p1, &mut p2] {
            let (y2, a2, z2) = b.grouped_step(&x, &a, &z, &mask).unwrap();
            assert_eq!(y1, y2);
            assert_eq!(a1, a2);
            assert_eq!(z1, z2);
        }

        // And the quantized run stays within the checked-in budget of
        // the f32 oracle.
        let mut f32b = NativeBackend::new(cfg.clone(), Params::random(&cfg, 34));
        let (yf, _, _) = f32b.grouped_step(&x, &a, &z, &mask).unwrap();
        let err = y1.rel_error(&yf);
        assert!(err < crate::tensor::kernels::INT8_CELL_ERR_BUDGET, "int8 rel error {err}");
    }

    #[test]
    fn embed_rejects_bad_tokens() {
        let cfg = test_config();
        let params = Params::random(&cfg, 3);
        let mut b = NativeBackend::new(cfg.clone(), params);
        let mut tokens = vec![0u32; cfg.seg];
        tokens[0] = cfg.vocab as u32; // out of range
        assert!(b.embed(&tokens).is_err());
        assert!(b.embed(&[0u32; 3]).is_err()); // wrong length
    }
}
